"""Markdown link checker for the docs lane (no network, no deps).

    python tools/check_links.py README.md docs/*.md

Verifies that every *relative* markdown link target — `[text](path)` and
`[text](path#fragment)` — resolves to an existing file or directory,
relative to the linking document. External (`http://`, `https://`,
`mailto:`) links are skipped: CI must not flake on the internet.
Exits non-zero listing every broken link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — excluding images' src handled identically via ![
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def broken_links(path: Path) -> list[str]:
    """Relative link targets in ``path`` that do not exist on disk."""
    bad = []
    text = path.read_text()
    # drop fenced code blocks — `[x](y)` inside code is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for target in _LINK.findall(text):
        if target.startswith(_SKIP):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (path.parent / rel).exists():
            bad.append(target)
    return bad


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] or [Path("README.md")]
    failures = 0
    for f in files:
        for target in broken_links(f):
            print(f"{f}: broken link -> {target}")
            failures += 1
    if failures:
        print(f"{failures} broken link(s)")
        return 1
    print(f"{len(files)} file(s) checked, all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
