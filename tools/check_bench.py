"""Benchmark regression gate for the CI bench lanes (no deps, no jax).

    python tools/check_bench.py --fresh BENCH_rz.json \
        --baseline benchmarks/baselines/BENCH_rz.json [--tol 0.25]

Compares a freshly produced benchmark JSON (``benchmarks/bench_rz_pallas``
or ``benchmarks/bench_serve`` artifact) against the committed baseline
and **fails (exit 1) on a throughput regression beyond the tolerance
band** — by default a fresh ``contracts/sec`` more than 25% below the
baseline.  Improvements never fail; they print a hint to refresh the
baseline (``--write-baseline`` copies fresh over baseline).

Two metric classes per bench:

  * **throughput** (contracts/sec) — machine-dependent, gated only when
    the identifying config (tree depth, request count, ...) matches the
    baseline's; CI runners are assumed comparable run-to-run, and the
    tolerance band absorbs their jitter.
  * **ratios** (pallas-vs-jnp, scheduler-vs-per-request speedup) —
    dimensionless, gated even when the config differs (the nightly lane
    runs deeper trees than the PR lane against the same baseline file).

Unknown bench kinds fall back to gating every ``*contracts_per_sec``
path found in both files.

Benches that emit a **roofline matrix** (``roofline.matrix`` — a list of
per-``(op, backend, platform, dtype)`` cells with achieved-vs-peak
flops/bytes, see ``repro/roofline/pricing.py``) are additionally gated
cell-by-cell: cells are matched on their identity key, the achieved
throughput columns gate like any other machine-dependent metric (config
must match), and a baseline cell missing from the fresh artifact is a
coverage failure (a kernel silently dropped out of the matrix).  Cells
for *other* platforms in the baseline are skipped, not failed — the CPU
lane cannot regress the GPU column.

Non-finite metric values (``Infinity``/``NaN`` — which ``json`` parses
happily from a buggy artifact) are rejected as failures rather than
compared: a ratio against inf passes every gate silently.
"""
from __future__ import annotations

import argparse
import json
import math
import shutil
import sys
from pathlib import Path

# per-bench metric registry: dotted paths into the report JSON
_BENCHES = {
    "rz_grid_backends": {
        "config": ("n_steps", "contracts", "capacity", "repeats",
                   "levels", "block", "interpret", "device"),
        "throughput": ("jnp.contracts_per_sec", "pallas.contracts_per_sec"),
        "ratios": ("pallas_over_jnp",),
        "matrix": True,
    },
    "serve_scheduler_vs_per_request": {
        "config": ("requests", "max_batch", "n_steps", "tc_fraction",
                   "capacity", "seed", "device"),
        "throughput": ("scheduler.contracts_per_sec",
                       "baseline.contracts_per_sec"),
        "ratios": ("speedup", "speedup_nocache"),
    },
    "gateway_replicas": {
        "config": ("requests", "max_batch", "n_steps", "capacity",
                   "crash_at", "restart_s", "seed", "ticks", "device"),
        "throughput": ("one_replica.quotes_per_sec",
                       "two_replica.quotes_per_sec",
                       "process_pool.quotes_per_sec"),
        "ratios": ("two_over_one", "process_over_thread"),
    },
    "pwl_envelope_ops": {
        "config": ("lanes", "capacity", "repeats", "device"),
        "throughput": ("envelope.ops_per_sec", "cone.ops_per_sec",
                       "level_step.ops_per_sec"),
        "ratios": (),
        "matrix": True,
    },
    "lsmc_paths": {
        "config": ("contracts", "n_steps", "paths", "n_exercise",
                   "repeats", "device"),
        "throughput": ("single.paths_per_sec", "mesh8.paths_per_sec"),
        "ratios": ("mesh8_over_single",),
    },
}


def _finite_number(v) -> bool:
    """True only for real finite numbers (bool is not a metric)."""
    return (isinstance(v, (int, float)) and not isinstance(v, bool)
            and math.isfinite(v))


def _get(report: dict, dotted: str):
    cur = report
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _scan_throughput(report: dict, prefix: str = "") -> list[str]:
    """Every dotted path ending in contracts_per_sec (fallback gating)."""
    found = []
    for k, v in report.items():
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            found.extend(_scan_throughput(v, path + "."))
        elif k == "contracts_per_sec" and isinstance(v, (int, float)):
            found.append(path)
    return found


def check(fresh: dict, baseline: dict, tol: float) -> list[str]:
    """Return a list of human-readable regression failures (empty = pass).

    Prints a PASS/GATE line per metric as it goes.
    """
    kind = fresh.get("bench")
    spec = _BENCHES.get(kind)
    failures: list[str] = []
    if baseline.get("bench") != kind:
        return [f"baseline is for bench {baseline.get('bench')!r}, "
                f"fresh is {kind!r} — wrong baseline file"]
    if spec is None:
        metrics = sorted(set(_scan_throughput(fresh))
                         & set(_scan_throughput(baseline)))
        ratios, config_ok = (), True
        print(f"unknown bench {kind!r}: generic gate over {metrics}")
    else:
        config_ok = all(_get(fresh, k) == _get(baseline, k)
                        for k in spec["config"])
        metrics, ratios = spec["throughput"], spec["ratios"]
        if not config_ok:
            diffs = {k: (_get(fresh, k), _get(baseline, k))
                     for k in spec["config"]
                     if _get(fresh, k) != _get(baseline, k)}
            print(f"config differs from baseline {diffs}: "
                  "gating dimensionless ratios only")

    def gate(path: str, klass: str) -> None:
        f, b = _get(fresh, path), _get(baseline, path)
        if f is None or b is None:
            print(f"  SKIP {path}: missing "
                  f"({'fresh' if f is None else 'baseline'})")
            return
        # json.loads happily parses the non-standard Infinity/NaN tokens
        # a buggy bench can emit (json.dumps allows them by default); a
        # ratio against inf/nan would then "pass" every gate or fail with
        # a meaningless message.  Reject the metric outright instead —
        # a non-finite baseline means the baseline needs regenerating.
        for side, v in (("fresh", f), ("baseline", b)):
            if not _finite_number(v):
                print(f"  FAIL {path} ({klass}): {side} value {v!r} is "
                      "not a finite number")
                failures.append(
                    f"{path}: {side} value {v!r} is not a finite number"
                    + (" — regenerate the baseline (--write-baseline)"
                       if side == "baseline" else
                       " — the bench emitted a broken metric"))
                return
        floor = b * (1.0 - tol)
        status = "PASS" if f >= floor else "FAIL"
        print(f"  {status} {path} ({klass}): fresh {f:.4g} vs baseline "
              f"{b:.4g} (floor {floor:.4g}, tol {tol:.0%})")
        if f < floor:
            failures.append(
                f"{path}: {f:.4g} is {(1 - f / b):.1%} below baseline "
                f"{b:.4g} (tolerance {tol:.0%})")
        elif f > b * (1.0 + tol):
            print(f"       {path} improved {(f / b - 1):.1%} — consider "
                  "refreshing the baseline (--write-baseline)")

    if config_ok:
        for m in metrics:
            gate(m, "throughput")
    for m in ratios:
        gate(m, "ratio")
    if spec is not None and spec.get("matrix"):
        _gate_matrix(fresh, baseline, tol, config_ok, failures)
    return failures


_MATRIX_KEY = ("op", "backend", "platform", "dtype")
_MATRIX_THROUGHPUT = ("achieved_flops_per_sec", "achieved_bytes_per_sec")


def _cells(report: dict) -> dict:
    cells = _get(report, "roofline.matrix") or []
    return {tuple(c.get(k) for k in _MATRIX_KEY): c for c in cells
            if isinstance(c, dict)}


def _gate_matrix(fresh: dict, baseline: dict, tol: float, config_ok: bool,
                 failures: list[str]) -> None:
    """Cell-by-cell gate of the roofline achieved-vs-peak matrix."""
    fc, bc = _cells(fresh), _cells(baseline)
    if not bc:
        if fc:
            print(f"  NOTE roofline matrix: {len(fc)} fresh cell(s), no "
                  "baseline matrix yet — consider --write-baseline")
        return
    this_platform = {k[2] for k in fc} or {None}
    for key, bcell in sorted(bc.items()):
        label = "/".join(str(k) for k in key)
        if key not in fc:
            # a cell for a platform this runner cannot produce is
            # expected absent; a same-platform cell vanishing is not
            if key[2] not in this_platform:
                print(f"  SKIP roofline[{label}]: other platform")
                continue
            print(f"  FAIL roofline[{label}]: cell missing from fresh "
                  "matrix")
            failures.append(f"roofline[{label}]: cell missing from fresh "
                            "matrix — kernel dropped out of the roofline")
            continue
        if not config_ok:
            print(f"  SKIP roofline[{label}]: config differs "
                  "(machine-dependent cells not gated)")
            continue
        fcell = fc[key]
        for metric in _MATRIX_THROUGHPUT:
            f, b = fcell.get(metric), bcell.get(metric)
            if not (_finite_number(f) and _finite_number(b)):
                print(f"  SKIP roofline[{label}].{metric}: non-finite or "
                      "missing")
                continue
            floor = b * (1.0 - tol)
            status = "PASS" if f >= floor else "FAIL"
            print(f"  {status} roofline[{label}].{metric}: fresh {f:.4g} "
                  f"vs baseline {b:.4g} (floor {floor:.4g})")
            if f < floor:
                failures.append(
                    f"roofline[{label}].{metric}: {f:.4g} is "
                    f"{(1 - f / b):.1%} below baseline {b:.4g} "
                    f"(tolerance {tol:.0%})")
    extra = set(fc) - set(bc)
    if extra:
        print(f"  NOTE roofline matrix: new cell(s) not in baseline: "
              f"{sorted('/'.join(map(str, k)) for k in extra)} — refresh "
              "with --write-baseline to start gating them")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--baseline", required=True,
                    help="committed benchmarks/baselines/BENCH_*.json")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25 = "
                         "fail on >25%% contracts/sec drop)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy fresh over baseline instead of gating "
                         "(after a verified perf improvement)")
    args = ap.parse_args()

    fresh_p, base_p = Path(args.fresh), Path(args.baseline)
    if not fresh_p.exists():
        print(f"fresh benchmark {fresh_p} not found — did the bench run?")
        return 1
    if args.write_baseline:
        base_p.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(fresh_p, base_p)
        print(f"baseline refreshed: {fresh_p} -> {base_p}")
        return 0
    if not base_p.exists():
        print(f"no committed baseline {base_p}; seed it with "
              f"--write-baseline")
        return 1
    fresh = json.loads(fresh_p.read_text())
    baseline = json.loads(base_p.read_text())
    print(f"check_bench: {fresh_p} vs {base_p} "
          f"(bench={fresh.get('bench')!r})")
    failures = check(fresh, baseline, args.tol)
    if failures:
        print("\nBENCH REGRESSION:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("bench within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
