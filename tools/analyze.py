#!/usr/bin/env python
"""Run the repo-wide invariant analyzers (``repro.analysis``).

Usage::

    python tools/analyze.py                      # report everything
    python tools/analyze.py --fail-on-findings   # CI gate (exit 1)
    python tools/analyze.py --checker guarded-by --checker wire-schema
    python tools/analyze.py --json findings.json # machine-readable dump

Findings are matched against the checked-in waiver file
(``tools/analysis_waivers.toml`` by default); a waiver must carry a
written reason and is reported as *stale* when nothing matches it any
more.  Exit codes: 0 clean (or findings without ``--fail-on-findings``),
1 unwaived findings under ``--fail-on-findings``, 2 configuration error
(unreadable/invalid waiver file or unknown checker).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import CHECKERS, apply_waivers, load_waivers  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repo-wide invariant analyzer",
        epilog="checkers: " + ", ".join(CHECKERS))
    ap.add_argument("--checker", action="append", default=None,
                    metavar="NAME", help="run only this checker "
                    "(repeatable; default: all)")
    ap.add_argument("--waivers", type=pathlib.Path,
                    default=REPO_ROOT / "tools" / "analysis_waivers.toml",
                    help="waiver file (default: tools/analysis_waivers.toml)")
    ap.add_argument("--json", type=pathlib.Path, default=None,
                    metavar="PATH", help="write findings as JSON")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 when any unwaived finding remains")
    ap.add_argument("--list-checkers", action="store_true",
                    help="print checker names and exit")
    args = ap.parse_args(argv)

    if args.list_checkers:
        for name in CHECKERS:
            print(name)
        return 0

    names = list(CHECKERS) if args.checker is None else args.checker
    unknown = [n for n in names if n not in CHECKERS]
    if unknown:
        print(f"error: unknown checker(s) {unknown}; "
              f"available: {list(CHECKERS)}", file=sys.stderr)
        return 2
    try:
        waivers = load_waivers(args.waivers)
    except ValueError as e:
        print(f"error: bad waiver file {args.waivers}: {e}", file=sys.stderr)
        return 2

    findings = []
    for name in names:
        findings.extend(CHECKERS[name]())
    unwaived, waived, stale = apply_waivers(findings, waivers)

    if args.json is not None:
        args.json.write_text(json.dumps({
            "checkers": names,
            "unwaived": [f.to_json() for f in unwaived],
            "waived": [{"finding": f.to_json(), "reason": w.reason}
                       for f, w in waived],
            "stale_waivers": [{"checker": w.checker, "file": w.file,
                               "symbol": w.symbol, "reason": w.reason}
                              for w in stale],
        }, indent=2) + "\n")

    for f in unwaived:
        print(f.format())
    for f, w in waived:
        print(f"[waived] {f.format()}\n         reason: {w.reason}")
    for w in stale:
        print(f"[stale waiver] {w.checker} {w.file} {w.symbol} — nothing "
              "matches it any more; delete it", file=sys.stderr)
    print(f"{len(unwaived)} finding(s), {len(waived)} waived, "
          f"{len(stale)} stale waiver(s) "
          f"[checkers: {', '.join(names)}]")
    if args.fail_on_findings and unwaived:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
