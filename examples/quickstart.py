"""Quickstart: price an American option under proportional transaction
costs (the paper's §3/§5 workload) and sanity-check it against the
friction-free price.

    PYTHONPATH=src python examples/quickstart.py

For the stable top-level API (single quotes + scenario grids) see
``repro.api`` and ``examples/scenario_grid.py``.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (LatticeModel, american_put, bull_spread,
                        price_notc_np, price_ref)
from repro.core.rz import price_rz


def main():
    # the paper's American put: K=100, T=0.25, sigma=0.2, R=0.1
    put = american_put(100.0)
    model = LatticeModel(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                         n_steps=100, cost_rate=0.005)

    res = price_rz(model, put, capacity=32)           # vectorised engine
    classic = price_notc_np(model.with_(cost_rate=0.0), put)

    print(f"American put  K=100 S0=100 T=0.25 N={model.n_steps} k=0.5%")
    print(f"  ask (seller's price) : {res.ask:.6f}")
    print(f"  bid (buyer's price)  : {res.bid:.6f}")
    print(f"  friction-free price  : {classic:.6f}")
    print(f"  PWL knots needed     : {res.max_pieces}")
    assert res.bid <= classic <= res.ask

    # cash-settled American bull spread (paper §5, k=1%)
    model2 = model.with_(cost_rate=0.01, n_steps=60)
    res2 = price_rz(model2, bull_spread(), capacity=48)
    print(f"\nBull spread (S-95)^+-(S-105)^+  N=60 k=1%")
    print(f"  ask: {res2.ask:.6f}   bid: {res2.bid:.6f}")

    # cross-check a small tree against the exact sequential oracle
    small = model.with_(n_steps=20)
    exact = price_ref(small, put)
    fast = price_rz(small, put, capacity=32)
    assert abs(exact.ask - fast.ask) < 1e-9
    assert abs(exact.bid - fast.bid) < 1e-9
    print("\noracle cross-check at N=20: exact match ✓")


if __name__ == "__main__":
    main()
