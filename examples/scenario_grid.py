"""Scenario-grid pricing: quote a whole ask/bid surface in one call.

    PYTHONPATH=src python examples/scenario_grid.py

Builds the cartesian grid spot x cost-rate x payoff family, prices it
through ``repro.api.price_grid`` (one compiled call, finite-difference
Greeks fused in), and prints the put slice as a small surface table.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import ScenarioGrid, price_grid


def main():
    # sized for the 1-core CI container: ~30 s end to end.  Scale n_steps /
    # axes up freely on real hardware — the call stays a single compiled
    # program.
    grid = ScenarioGrid.cartesian(
        s0=(90.0, 95.0, 100.0, 105.0, 110.0),
        cost_rate=(0.0, 0.005, 0.01),           # lambda: 0, 0.5%, 1%
        payoff=("put", "call"),
        strike=100.0,
        sigma=0.2, rate=0.1, maturity=0.25, n_steps=30)
    res = price_grid(grid, greeks=True, capacity=24)
    print(f"priced {grid.n_scenarios} scenarios in one compiled call "
          f"(max PWL knots {res.max_pieces})\n")

    # put slice: ask(lambda) per spot, widening with the cost rate
    g = grid
    flat = {k: a.ravel() for k, a in
            dict(ask=res.ask, bid=res.bid, delta=res.delta_ask).items()}
    print("American put K=100:  S0    ask(0)   ask(0.5%)  ask(1%)   "
          "bid(1%)   delta")
    rows = {}
    for i in range(g.n_scenarios):
        if g.payoff[i] != "put":
            continue
        rows.setdefault(g.s0[i], {})[g.cost_rate[i]] = i
    for s0v in sorted(rows):
        by_k = rows[s0v]
        i0, i5, i10 = by_k[0.0], by_k[0.005], by_k[0.01]
        print(f"                    {s0v:5.0f}  {flat['ask'][i0]:8.4f} "
              f"{flat['ask'][i5]:9.4f} {flat['ask'][i10]:8.4f} "
              f"{flat['bid'][i10]:8.4f}  {flat['delta'][i10]:+.4f}")

    # interval structure: at lambda = 0 the interval collapses to a point
    assert abs(res.ask[:, :, :, :, 0] - res.bid[:, :, :, :, 0]).max() < 1e-9
    assert (res.spread >= -1e-12).all()
    print("\ninterval structure holds across the whole grid ✓")


if __name__ == "__main__":
    main()
