"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen3-0.6b]
    PYTHONPATH=src python examples/train_lm.py --tiny      # CI-sized run

Uses the full production substrate: config registry, synthetic data
pipeline, AdamW + warmup-cosine, microbatched train step, async
checkpointing with resume (re-run the same command after a kill and it
continues from the last checkpoint).
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.models.transformer import RunCfg  # noqa: E402
from repro.train.trainer import TrainerConfig, train  # noqa: E402


def hundred_m_config():
    """~100M-param decoder (qwen3-family block, CPU-trainable)."""
    base = get_config("qwen3-0.6b")
    return dataclasses.replace(
        base, name="repro-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32768)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.tiny:
        cfg = reduced_config(get_config("qwen3-0.6b"))
        tc = TrainerConfig(steps=min(args.steps, 30), global_batch=4,
                           seq_len=64, n_micro=1, ckpt_every=10,
                           log_every=5, ckpt_dir=args.ckpt)
    else:
        cfg = hundred_m_config()
        tc = TrainerConfig(steps=args.steps, global_batch=args.batch,
                           seq_len=args.seq, n_micro=2, peak_lr=6e-4,
                           warmup=20, ckpt_every=50, log_every=10,
                           ckpt_dir=args.ckpt)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{tc.steps} steps, batch {tc.global_batch}x{tc.seq_len}")
    out = train(cfg, tc, RunCfg(dtype=jnp.float32))
    print(f"done: final loss {out['final_loss']:.4f} "
          f"(started ~{out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
