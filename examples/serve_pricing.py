"""Pricing-desk service: batched ask/bid quoting over the distributed
lattice engine (contracts on the data axis, tree nodes on the model axis).

    PYTHONPATH=src python examples/serve_pricing.py

On this container the mesh is 1x1; on a pod the same code runs on the
16x16 production mesh (see repro/launch/price.py).
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.serve.engine import PriceRequest, PricingEngine  # noqa: E402


def main():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = PricingEngine(mesh, n_steps=100, batch=8, capacity=32,
                        round_depth=8)

    # a strike/spot/cost grid, as a desk would quote it
    reqs = [PriceRequest(s0=s0, sigma=0.2, rate=0.1, maturity=0.25,
                         cost_rate=k)
            for s0 in (92.0, 96.0, 100.0, 104.0, 108.0)
            for k in (0.0, 0.005, 0.01)]
    ids = [eng.submit(r) for r in reqs]

    t0 = time.perf_counter()
    out = eng.flush()
    dt = time.perf_counter() - t0

    print(f"priced {len(reqs)} contracts in {dt:.2f}s "
          f"({len(reqs)/dt:.1f} contracts/s, N=100 lattice, incl. compile)")
    print(f"{'S0':>6} {'k':>7} {'ask':>9} {'bid':>9} {'spread':>8}")
    for req, rid in zip(reqs, ids):
        ask, bid = out[rid]
        print(f"{req.s0:>6.1f} {req.cost_rate:>7.3%} {ask:>9.4f} "
              f"{bid:>9.4f} {ask-bid:>8.4f}")

    # invariant: spreads grow with the cost rate at fixed spot
    for s0 in (92.0, 96.0, 100.0, 104.0, 108.0):
        sp = [out[ids[i]][0] - out[ids[i]][1]
              for i, r in enumerate(reqs) if r.s0 == s0]
        assert sp[0] <= sp[1] <= sp[2] + 1e-9
    print("spread monotonicity ✓")


if __name__ == "__main__":
    main()
