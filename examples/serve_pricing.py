"""Pricing-desk service: continuous-batching ask/bid quoting over the
compiled grid engines (see docs/SERVING.md for the operator's guide).

    PYTHONPATH=src python examples/serve_pricing.py

A strike/spot/cost quote surface is submitted as a stream of
single-contract requests; the scheduler coalesces them — frictionless
requests onto the cheap no-TC lattice, transaction-cost requests onto
the Roux–Zastawniak engine — pads each micro-batch to a power-of-two
bucket, and reports batching/caching/latency metrics.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.serve.engine import PriceRequest  # noqa: E402
from repro.serve.scheduler import PricingService  # noqa: E402

SPOTS = (92.0, 96.0, 100.0, 104.0, 108.0)
COSTS = (0.0, 0.005, 0.01)


def main():
    desk = PricingService(max_batch=16, deadline_ms=5.0,
                          default_n_steps=24, capacity=24)

    # a strike/spot/cost grid, as a desk would quote it (N=24 keeps the
    # RZ batch CPU-friendly; scale n_steps up freely on accelerators)
    reqs = [PriceRequest(s0=s0, sigma=0.2, rate=0.1, maturity=0.25,
                         cost_rate=k)
            for s0 in SPOTS for k in COSTS]
    ids = [desk.submit(r) for r in reqs]

    t0 = time.perf_counter()
    desk.flush()
    dt = time.perf_counter() - t0
    out = {rid: desk.result(rid) for rid in ids}

    print(f"priced {len(reqs)} contracts in {dt:.2f}s "
          f"({len(reqs)/dt:.1f} contracts/s, N=24 lattice, incl. compile)")
    print(f"{'S0':>6} {'k':>7} {'ask':>9} {'bid':>9} {'spread':>8}")
    for req, rid in zip(reqs, ids):
        q = out[rid]
        print(f"{req.s0:>6.1f} {req.cost_rate:>7.3%} {q.ask:>9.4f} "
              f"{q.bid:>9.4f} {q.spread:>8.4f}")

    # invariant: spreads grow with the cost rate at fixed spot
    for s0 in SPOTS:
        sp = [out[ids[i]].spread
              for i, r in enumerate(reqs) if r.s0 == s0]
        assert sp[0] <= sp[1] <= sp[2] + 1e-9
    print("spread monotonicity ✓")

    m = desk.metrics()
    print(f"batches: {m['batches']} (engines {m['engine_batches']}), "
          f"pad waste {m['pad_waste']:.0%}, "
          f"p50/p99 latency {m['p50_latency_ms']:.0f}/"
          f"{m['p99_latency_ms']:.0f} ms")


if __name__ == "__main__":
    main()
