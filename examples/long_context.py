"""Long-context decode with a sub-quadratic stack (the long_500k cell,
CPU-scaled): a reduced falcon-mamba generates against an O(1)-state
"cache" that never grows with context length, and a reduced
recurrentgemma does the same with its windowed-attention ring.

    PYTHONPATH=src python examples/long_context.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.models.transformer import (RunCfg, decode_step, init_lm,  # noqa: E402
                                      prefill)


def main():
    run = RunCfg(dtype=jnp.float32)
    for arch in ("falcon-mamba-7b", "recurrentgemma-2b"):
        cfg = reduced_config(get_config(arch))
        key = jax.random.PRNGKey(0)
        params, _ = init_lm(key, cfg)
        B, S0, NNEW = 1, 64, 16
        toks = jax.random.randint(key, (B, S0), 0, cfg.vocab)

        logits, cache = prefill(params, {"tokens": toks}, cfg, run,
                                max_len=S0 + NNEW)
        state_bytes = sum(
            np.prod(a.shape) * a.dtype.itemsize
            for a in jax.tree.leaves(cache))
        dec = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, run))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs = []
        for i in range(NNEW):
            outs.append(int(tok[0, 0]))
            logits, cache = dec(params, cache, tok, jnp.int32(S0 + i))
            tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        print(f"{arch}: generated {outs}")
        print(f"  decode state: {state_bytes/1e6:.2f} MB "
              f"({'O(1) SSM state' if cfg.attention_free else 'windowed KV'})"
              f" — independent of total context beyond the window")


if __name__ == "__main__":
    main()
