"""Benchmark harness — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--list] [name ...]

Prints a ``name,us_per_call,derived`` CSV summary after the per-table
detail blocks.  Tables II/III cannot be wall-clock-reproduced on this
1-core container; their modules reproduce the *schedule* with measured
node costs (see each module's docstring and EXPERIMENTS.md).

Benches are looked up by short name (``rz_pallas``) or module name
(``bench_rz_pallas``); ``--list`` prints the registry without importing
any bench module (importing pulls in jax), and unknown names fail fast
with the available set instead of a mid-run KeyError.
"""
from __future__ import annotations

import sys
import traceback

# short name -> module under benchmarks/ holding a run() -> list[str]
# entry.  Lazy: modules import only when their bench is actually run.
_REGISTRY = {
    "table1": "table1_node_counts",
    "table2": "table2_tc_speedup",
    "table3": "table3_notc_speedup",
    "fig9": "fig9_spreads",
    "convergence": "rz_convergence",
    "kernels": "bench_kernels",
    "grid": "scenario_grid",
    "rz_pallas": "bench_rz_pallas",
    "serve": "bench_serve",
    "gateway": "bench_gateway",
    "pwl": "bench_pwl",
    "lsmc": "bench_lsmc",
}
# module-name aliases: `python -m benchmarks.run bench_serve` works too
_ALIASES = {mod: short for short, mod in _REGISTRY.items()}


def resolve(name: str) -> str:
    """Canonical short name for ``name`` (short or module spelling)."""
    if name in _REGISTRY:
        return name
    if name in _ALIASES:
        return _ALIASES[name]
    raise SystemExit(
        f"unknown bench {name!r}; available: {', '.join(_REGISTRY)} "
        f"(module names {', '.join(_ALIASES)} also accepted)")


def _load(short: str):
    import importlib
    return importlib.import_module(f"benchmarks.{_REGISTRY[short]}").run


def main() -> None:
    argv = sys.argv[1:]
    if "--list" in argv:
        for short, mod in _REGISTRY.items():
            print(f"{short}  (benchmarks/{mod}.py)")
        return
    wanted = [resolve(n) for n in argv] or list(_REGISTRY)
    csv_rows = []
    failures = []
    for name in wanted:
        print(f"\n==== {name} " + "=" * (60 - len(name)))
        try:
            csv_rows.extend(_load(name)())
        except Exception as e:                      # keep the harness alive
            traceback.print_exc()
            failures.append(name)
            csv_rows.append(f"{name},nan,FAILED={type(e).__name__}")
    print("\n==== CSV " + "=" * 55)
    print("name,us_per_call,derived")
    for r in csv_rows:
        print(r)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
