"""Benchmark harness — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [name ...]

Prints a ``name,us_per_call,derived`` CSV summary after the per-table
detail blocks.  Tables II/III cannot be wall-clock-reproduced on this
1-core container; their modules reproduce the *schedule* with measured
node costs (see each module's docstring and EXPERIMENTS.md).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_kernels, bench_rz_pallas, bench_serve,
                   fig9_spreads, rz_convergence, scenario_grid,
                   table1_node_counts, table2_tc_speedup,
                   table3_notc_speedup)
    all_benches = {
        "table1": table1_node_counts.run,
        "table2": table2_tc_speedup.run,
        "table3": table3_notc_speedup.run,
        "fig9": fig9_spreads.run,
        "convergence": rz_convergence.run,
        "kernels": bench_kernels.run,
        "grid": scenario_grid.run,
        "rz_pallas": bench_rz_pallas.run,
        "serve": bench_serve.run,
    }
    wanted = sys.argv[1:] or list(all_benches)
    csv_rows = []
    failures = []
    for name in wanted:
        print(f"\n==== {name} " + "=" * (60 - len(name)))
        try:
            csv_rows.extend(all_benches[name]())
        except Exception as e:                      # keep the harness alive
            traceback.print_exc()
            failures.append(name)
            csv_rows.append(f"{name},nan,FAILED={type(e).__name__}")
    print("\n==== CSV " + "=" * 55)
    print("name,us_per_call,derived")
    for r in csv_rows:
        print(r)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
