"""Paper §5 correctness sweep (RZ09 Table 1/2 structure): ask/bid vs N.

The paper validates by matching RZ09's Tables 1–2 over N in [20, 1000]
and k in [0, 2%].  Those reference values are not available offline; what
IS checkable offline:

  * the k = 0 column collapses onto the classic binomial price at every N
    and converges (CRR O(1/N));
  * the k > 0 columns show the *known divergence*: at fixed proportional
    cost rate, refining the lattice adds rebalancing dates, so hedging
    friction accumulates — the ask grows toward the trivial-superhedge
    bound and the bid decays toward 0 (Soner–Shreve–Cvitanić 1995; also
    visible in RZ09's own tables, where prices move with N at fixed k).
    Our engine reproduces exactly this structure — a fidelity check, not
    a numerical defect.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import LatticeModel, american_put, price_notc_np
from repro.core.rz import price_rz

NS = (20, 40, 80, 160, 320)
K_RATE = 0.005
PUT = american_put(100.0)


def run() -> list[str]:
    t0 = time.perf_counter()
    print(f"{'N':>5} {'ask(k=0.5%)':>12} {'bid(k=0.5%)':>12} "
          f"{'ask(k=0)':>10} {'classic':>10}")
    asks, bids, zeros = [], [], []
    ok_zero = True
    for n in NS:
        m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25,
                         n_steps=n, cost_rate=K_RATE)
        r = price_rz(m, PUT, capacity=32)
        m0 = m.with_(cost_rate=0.0)
        r0 = price_rz(m0, PUT, capacity=32)
        classic = price_notc_np(m0, PUT)
        ok_zero &= abs(r0.ask - classic) < 1e-9 and abs(r0.bid - classic) < 1e-9
        asks.append(r.ask)
        bids.append(r.bid)
        zeros.append(classic)
        print(f"{n:>5} {r.ask:>12.6f} {r.bid:>12.6f} {r0.ask:>10.6f} "
              f"{classic:>10.6f}")
    # k=0: CRR convergence (successive diffs shrink)
    dz = [abs(zeros[i + 1] - zeros[i]) for i in range(len(NS) - 1)]
    k0_conv = dz[-1] < dz[0]
    # k>0: the theoretically expected monotone widening with N
    widening = all(asks[i + 1] >= asks[i] - 1e-9 for i in range(len(NS) - 1)) \
        and all(bids[i + 1] <= bids[i] + 1e-9 for i in range(len(NS) - 1))
    dt = time.perf_counter() - t0
    return [f"rz_convergence,{dt*1e6/len(NS):.0f},"
            f"k0_converges={k0_conv};k0_exact={ok_zero};"
            f"tc_widens_with_N={widening};final_spread={asks[-1]-bids[-1]:.4f}"]
