"""Scenario-grid throughput: one compiled grid call vs. per-contract loop.

The north-star workload beyond the paper: a pricing desk quoting a whole
surface (spots x vols x cost rates x payoff families) at once.  This
bench prices the same scenario set two ways and reports contracts/sec:

  * ``grid``  — ``repro.scenarios.price_grid_rz``: one jitted vmap over
    the flat scenario batch (compile excluded; steady-state serving cost);
  * ``loop``  — ``repro.core.rz.price_rz`` per contract, the pre-grid
    serving path (jit cache warm, so the gap measured is batching +
    dispatch, not compilation).

Also times the friction-free grid through both the jnp backend and the
payoff-parameterised Pallas lattice kernel (interpret mode on CPU — the
kernel-path numbers are correctness anchors, not TPU throughput).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import LatticeModel, american_call, american_put, bull_spread
from repro.core.rz import price_rz
from repro.scenarios import ScenarioGrid, price_grid_notc, price_grid_rz

N_STEPS = 24        # CPU-budget bound; scale up freely on accelerators
CAPACITY = 24


def _grid() -> ScenarioGrid:
    return ScenarioGrid.cartesian(
        s0=(90.0, 95.0, 100.0, 105.0, 110.0),
        sigma=(0.15, 0.25),
        cost_rate=(0.0, 0.005, 0.01),
        payoff=("put", "call", "bull_spread"),
        strike=100.0,
        n_steps=N_STEPS)


# payoff objects are static jit arguments — reuse one instance per family
# or the per-contract loop recompiles on every call
_PAYOFFS = {}


def _payoff_of(kind: str, k1: float, k2: float):
    key = (kind, k1, k2)
    if key not in _PAYOFFS:
        mk = {"put": american_put, "call": american_call}
        _PAYOFFS[key] = (bull_spread(k1, k2) if kind == "bull_spread"
                         else mk[kind](k1))
    return _PAYOFFS[key]


def _loop_all(grid: ScenarioGrid) -> None:
    for i in range(grid.n_scenarios):
        pay = _payoff_of(grid.payoff[i], grid.strike[i], grid.strike2[i])
        model = LatticeModel(
            s0=grid.s0[i], sigma=grid.sigma[i], rate=grid.rate[i],
            maturity=grid.maturity[i], n_steps=grid.n_steps,
            cost_rate=grid.cost_rate[i])
        price_rz(model, pay, capacity=CAPACITY)


def run() -> list[str]:
    grid = _grid()
    n = grid.n_scenarios
    print(f"{n} scenarios (mixed payoffs, lambda in {{0, 0.5%, 1%}}), "
          f"N={N_STEPS}, capacity={CAPACITY}")

    # ---- TC engine: compiled grid call vs. per-contract loop ----------
    price_grid_rz(grid, capacity=CAPACITY)                  # compile
    t0 = time.perf_counter()
    res = price_grid_rz(grid, capacity=CAPACITY)
    t_grid = time.perf_counter() - t0

    _loop_all(grid)                                         # warm jit cache
    t0 = time.perf_counter()
    _loop_all(grid)
    t_loop = time.perf_counter() - t0

    cs_grid = n / t_grid
    cs_loop = n / t_loop
    print(f"grid call : {t_grid*1e3:8.1f} ms  ({cs_grid:8.1f} contracts/s)")
    print(f"loop      : {t_loop*1e3:8.1f} ms  ({cs_loop:8.1f} contracts/s)")
    print(f"speedup   : {t_loop / t_grid:.2f}x  "
          f"(max PWL knots {res.max_pieces}/{CAPACITY})")

    # ---- TC engine, blocked-Pallas backend (kernels/rz_step.py) -------
    price_grid_rz(grid, capacity=CAPACITY, backend="pallas")    # compile
    t0 = time.perf_counter()
    res_pal = price_grid_rz(grid, capacity=CAPACITY, backend="pallas")
    t_rz_pal = time.perf_counter() - t0
    gap_tc = float(max(np.max(np.abs(res.ask - res_pal.ask)),
                       np.max(np.abs(res.bid - res_pal.bid))))
    print(f"pallas    : {t_rz_pal*1e3:8.1f} ms  ({n / t_rz_pal:8.1f} "
          f"contracts/s, interpret)  max|diff|={gap_tc:.1e}  "
          f"(deeper-tree head-to-head: benchmarks/bench_rz_pallas.py)")

    # ---- greeks fused into the same call ------------------------------
    price_grid_rz(grid, capacity=CAPACITY, greeks=True)     # compile
    t0 = time.perf_counter()
    price_grid_rz(grid, capacity=CAPACITY, greeks=True)
    t_greeks = time.perf_counter() - t0
    print(f"grid+greeks (5x batch): {t_greeks*1e3:8.1f} ms "
          f"({t_greeks / t_grid:.2f}x the plain grid)")

    # ---- friction-free grid, jnp vs Pallas-kernel backend -------------
    nog = ScenarioGrid.cartesian(
        s0=tuple(np.linspace(90.0, 110.0, 16)), payoff=("put", "call"),
        strike=100.0, n_steps=N_STEPS)
    price_grid_notc(nog)                                    # compile
    t0 = time.perf_counter()
    r_jnp = price_grid_notc(nog)
    t_jnp = time.perf_counter() - t0
    price_grid_notc(nog, backend="pallas", levels=16, block=64)
    t0 = time.perf_counter()
    r_pal = price_grid_notc(nog, backend="pallas", levels=16, block=64)
    t_pal = time.perf_counter() - t0
    gap = float(np.max(np.abs(r_jnp.price - r_pal.price)))
    print(f"no-TC grid ({nog.n_scenarios} scen): jnp {t_jnp*1e3:.1f} ms, "
          f"pallas(interpret) {t_pal*1e3:.1f} ms, max|diff|={gap:.2e}")

    return [
        f"scenario_grid,{t_grid*1e6/n:.0f},"
        f"grid_cps={cs_grid:.0f};loop_cps={cs_loop:.0f};"
        f"speedup={t_loop/t_grid:.2f}x",
        f"scenario_grid_rz_pallas,{t_rz_pal*1e6/n:.0f},"
        f"vs_jnp={t_grid/t_rz_pal:.2f}x;gap={gap_tc:.1e}",
        f"scenario_grid_greeks,{t_greeks*1e6/n:.0f},"
        f"rel_cost={t_greeks/t_grid:.2f}x",
        f"scenario_grid_notc,{t_jnp*1e6/nog.n_scenarios:.0f},"
        f"pallas_gap={gap:.1e}",
    ]
