"""Serving throughput: coalescing scheduler vs one-flush-per-request desk.

Replays the same synthetic request trace (mixed payoff families, strikes,
spots, vols and tree depths — ``repro.launch.serve_pricing.synth_trace``)
through

  * ``scheduler`` — :class:`repro.serve.scheduler.PricingService` with
    size-triggered micro-batches (``--max-batch``), power-of-two padding
    and the result LRU cache (also measured with the cache disabled, so
    the coalescing win is reported separately from the caching win);
  * ``baseline``  — one ``flush`` per request through ``PricingEngine``
    (batch 1, no cache): the pre-scheduler serving shape.

and writes ``BENCH_serve.json`` with contracts/sec for each, the
scheduler/baseline speedup, and an **oracle audit**: every quote the
scheduler returned is checked against ``repro.api.price_american`` at
1e-9.  Replays are measured jit-warm (a warm-up replay compiles every
batch shape first) — steady-state serving cost, the repo's benchmark
convention.  ``BENCH_*.json`` files are git-ignored; CI uploads this one
as an artifact.

    PYTHONPATH=src python -m benchmarks.bench_serve \
        [--requests 1000] [--max-batch 64] [--n-steps 16,24] \
        [--tc-fraction 0.0] [--capacity 16] [--out BENCH_serve.json]

``--tc-fraction`` adds a transaction-cost slice; the RZ engine compiles
for ~15 s per batch shape on this CPU and coalesces to only ~2x
per-contract, so the slice defaults to 0 (route-correctness for TC
traffic is covered by ``tests/test_serve.py``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.api import price_american
from repro.launch.serve_pricing import synth_trace
from repro.serve.engine import PricingEngine
from repro.serve.scheduler import PricingService

HARNESS_REQUESTS = 200
DEFAULT_REQUESTS = 1000


def _replay_scheduler(trace, *, max_batch, capacity, cache_size):
    svc = PricingService(max_batch=max_batch, capacity=capacity,
                         result_cache_size=cache_size, deadline_ms=1e9)
    t0 = time.perf_counter()
    ids = [svc.submit(r) for r in trace]   # size trigger flushes full buckets
    svc.flush()
    dt = time.perf_counter() - t0
    return {rid: svc.result(rid) for rid in ids}, dt, svc.metrics()


def _replay_baseline(trace, *, capacity):
    eng = PricingEngine(None, n_steps=trace[0].n_steps, batch=1,
                        capacity=capacity)
    quotes = {}
    t0 = time.perf_counter()
    for req in trace:
        rid = eng.submit(req)
        quotes[rid] = eng.flush()[rid]
    dt = time.perf_counter() - t0
    return quotes, dt, eng.service.metrics()


def _audit(trace, quotes, ids_in_order):
    """max |quote - price_american| over the whole trace (dedup by key)."""
    refs = {}
    worst = 0.0
    for req, rid in zip(trace, ids_in_order):
        key = (req.s0, req.sigma, req.rate, req.maturity, req.cost_rate,
               req.payoff, req.strike, req.n_steps)
        if key not in refs:
            refs[key] = price_american(
                s0=req.s0, sigma=req.sigma, rate=req.rate,
                maturity=req.maturity, n_steps=req.n_steps,
                payoff=req.payoff, strike=req.strike,
                cost_rate=req.cost_rate, capacity=32)
        ref = refs[key]
        q = quotes[rid]
        ask, bid = (q.ask, q.bid) if hasattr(q, "ask") else q
        worst = max(worst, abs(ask - ref.ask), abs(bid - ref.bid))
    return worst, len(refs)


def bench(requests: int = DEFAULT_REQUESTS, max_batch: int = 64,
          n_steps=(16, 24), tc_fraction: float = 0.0, capacity: int = 16,
          seed: int = 0, out: str = "BENCH_serve.json") -> dict:
    import jax
    trace = synth_trace(requests, n_steps=n_steps, tc_fraction=tc_fraction,
                        seed=seed)
    n = len(trace)
    print(f"{n}-request mixed trace (payoffs x strikes x spots x vols x "
          f"depths {n_steps}, tc_fraction={tc_fraction})")

    # warm-up replays: compile every batch shape both paths will hit
    _replay_scheduler(trace, max_batch=max_batch, capacity=capacity,
                      cache_size=4096)
    _replay_baseline(trace, capacity=capacity)

    quotes, t_sched, m_sched = _replay_scheduler(
        trace, max_batch=max_batch, capacity=capacity, cache_size=4096)
    print(f"scheduler          : {t_sched:7.3f} s "
          f"({n / t_sched:9.1f} contracts/s)  "
          f"batches={m_sched['batches']} "
          f"cache_hits={m_sched['cache_hits']} "
          f"pad_waste={m_sched['pad_waste']:.1%}")
    _, t_nc, m_nc = _replay_scheduler(
        trace, max_batch=max_batch, capacity=capacity, cache_size=0)
    print(f"scheduler (no LRU) : {t_nc:7.3f} s "
          f"({n / t_nc:9.1f} contracts/s)  batches={m_nc['batches']}")
    base_quotes, t_base, m_base = _replay_baseline(trace, capacity=capacity)
    print(f"per-request flush  : {t_base:7.3f} s "
          f"({n / t_base:9.1f} contracts/s)  batches={m_base['batches']}")

    speedup = t_base / t_sched
    speedup_nocache = t_base / t_nc
    worst, distinct = _audit(trace, quotes, sorted(quotes))
    worst_base, _ = _audit(trace, base_quotes, sorted(base_quotes))
    print(f"speedup: {speedup:.2f}x with result cache, "
          f"{speedup_nocache:.2f}x coalescing only (criterion: >= 2x)")
    print(f"oracle audit: {distinct} distinct scenarios, "
          f"max|err| scheduler {worst:.2e} baseline {worst_base:.2e} "
          f"(tol 1e-9)")
    assert worst < 1e-9 and worst_base < 1e-9

    report = {
        "bench": "serve_scheduler_vs_per_request",
        "requests": n, "max_batch": max_batch, "n_steps": list(n_steps),
        "tc_fraction": tc_fraction, "capacity": capacity, "seed": seed,
        "device": jax.devices()[0].platform,
        "scheduler": {"seconds": t_sched, "contracts_per_sec": n / t_sched,
                      "metrics": m_sched},
        "scheduler_nocache": {"seconds": t_nc,
                              "contracts_per_sec": n / t_nc,
                              "metrics": m_nc},
        "baseline": {"seconds": t_base, "contracts_per_sec": n / t_base,
                     "metrics": m_base},
        "speedup": speedup, "speedup_nocache": speedup_nocache,
        "meets_2x_criterion": bool(speedup_nocache >= 2.0),
        "oracle": {"distinct_scenarios": distinct,
                   "max_abs_err_scheduler": worst,
                   "max_abs_err_baseline": worst_base, "tol": 1e-9},
    }
    Path(out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


def run() -> list[str]:
    """benchmarks.run entry — harness-sized trace, full JSON artifact."""
    rep = bench(requests=HARNESS_REQUESTS)
    us = rep["scheduler"]["seconds"] * 1e6 / rep["requests"]
    return [
        f"serve,{us:.0f},"
        f"speedup={rep['speedup']:.2f}x;"
        f"nocache={rep['speedup_nocache']:.2f}x;"
        f"sched_cps={rep['scheduler']['contracts_per_sec']:.0f};"
        f"base_cps={rep['baseline']['contracts_per_sec']:.0f}",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--n-steps", default="16,24")
    ap.add_argument("--tc-fraction", type=float, default=0.0)
    ap.add_argument("--capacity", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    a = ap.parse_args()
    bench(requests=a.requests, max_batch=a.max_batch,
          n_steps=tuple(int(x) for x in a.n_steps.split(",")),
          tc_fraction=a.tc_fraction, capacity=a.capacity, seed=a.seed,
          out=a.out)


if __name__ == "__main__":
    main()
