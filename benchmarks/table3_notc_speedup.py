"""Paper Table III / Fig. 11: the no-transaction-cost appendix workload.

Three parts:
  1. the paper's computed price (13.906) re-verified through both the
     vectorised engine and the Pallas kernel path (timed);
  2. the schedule-model speedups for L=50 vs paper Table III (same
     per-node cost model as table2, sync amortised over 50-level rounds;
     the no-TC node cost is ~100x smaller, so c_sync in node units is
     far larger and bends the small-N speedups exactly like the paper's);
  3. honesty note: Table III contains *super-linear* points (p=4,
     N=40000 -> S=4.39) that the paper attributes to L2-cache/FSB
     aggregation of its 2008 Xeon; a node-count schedule model cannot
     encode that hardware artefact, so the residual error here (~18%
     mean) is dominated by those cells.  The load-balance reproduction
     anchors are Table I (exact) and Table II (0.7% mean).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import LatticeModel, american_put, price_notc_jax
from repro.core.partition import simulate_schedule

# paper Table III, American put, L=50: speedups by (p, N)
PAPER = {
    (2, 5000): 1.83, (2, 40000): 1.81,
    (4, 5000): 2.43, (4, 40000): 4.39,
    (8, 5000): 2.57, (8, 10000): 3.87, (8, 20000): 5.58, (8, 40000): 7.17,
}


def _model_speedup(n: int, p: int) -> float:
    serial = simulate_schedule(n, 1, 50)
    par = simulate_schedule(n, p, 50)
    t1 = serial.total_nodes
    init = max(par._init_counts)
    tp = init + sum(max(r.per_thread) for r in par.rounds)
    # scalar nodes are ~ns-scale: synchronisation costs thousands of node
    # units; constants fitted over all 8 published points
    tp += 9000.0 * len(par.rounds)
    tp *= 1.2
    return t1 / tp


def run() -> list[str]:
    rows = []
    # --- price anchor -----------------------------------------------------
    m = LatticeModel(s0=100, sigma=0.3, rate=0.06, maturity=3.0,
                     n_steps=20000)
    t0 = time.perf_counter()
    price = price_notc_jax(m, american_put(100.0))
    dt = time.perf_counter() - t0
    print(f"price(N=20000) = {price:.6f}  (paper: 13.906)  [{dt:.2f}s]")
    rows.append(f"table3_price_13906,{dt*1e6:.0f},price={price:.4f}")

    # --- schedule-model speedups ------------------------------------------
    errs = []
    print(f"{'p':>2} {'N':>6} {'paper':>6} {'model':>6} {'err%':>6}")
    for (p, n), want in sorted(PAPER.items()):
        got = _model_speedup(n, p)
        errs.append(abs(got - want) / want)
        print(f"{p:>2} {n:>6} {want:>6.2f} {got:>6.2f} "
              f"{100 * (got - want) / want:>5.1f}%")
    rows.append(f"table3_notc_speedup,0,mean_rel_err={np.mean(errs):.3f}")
    return rows
