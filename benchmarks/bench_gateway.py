"""Gateway availability under replica failure: 1 vs 2 replicas.

Replays the same 1k-request synthetic trace (``synth_trace`` — the
``bench_serve`` mix) through the asyncio
:class:`repro.serve.gateway.PricingGateway`, injecting a **replica
crash mid-replay** (``FaultyReplica`` crash at chunk call ``--crash-at``,
restart after ``--restart-s`` — modelling a pricing process respawn):

  * ``one_replica`` — the crash stalls the whole gateway for the
    restart window (plus retry backoff) before the replay can resume;
  * ``two_replica`` — the in-flight chunk fails over to the healthy
    replica immediately; the restart window is masked;
  * ``process_pool`` — the same 2-replica replay with every replica a
    real spawned worker process (``serve/procpool.py``) and the crash a
    genuine mid-chunk SIGKILL; ``process_over_thread`` is the
    process-vs-thread throughput ratio (wire-schema pickling + per-
    process compiles are the honest cost of real isolation).

Each timed replay is followed by a streaming segment (``run_stream``
over a mixed :class:`~repro.serve.streaming.StreamingBook` and a
``synth_ticks`` feed) so the artifact also carries tick-to-quote
staleness percentiles.  ``BENCH_gateway.json`` reports quotes/sec per
configuration, the ``two_over_one`` availability ratio (acceptance:
>= 1.5x), latency/staleness p99, and an **oracle audit**: every quote
either replay delivered — including the chunks requeued across the
crash — is checked against ``repro.api.price_american`` at 1e-9.

**Honest framing for 1-core hosts** (CI, this container): two replicas
cannot beat one on raw compute — both drain the same core and jax's jit
cache is process-wide.  The ratio measures *availability under
failure*: the second replica masks the ``--restart-s`` outage that the
single-replica run eats in full.  That is the property the gateway
exists to provide, and it is what the baseline gates.

    PYTHONPATH=src python -m benchmarks.bench_gateway \
        [--requests 1000] [--max-batch 64] [--n-steps 16,24] \
        [--crash-at 1] [--restart-s 1.0] [--pool both|thread|process] \
        [--out BENCH_gateway.json]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time
from pathlib import Path

from repro.api import price_american
from repro.launch.serve_pricing import synth_trace
from repro.serve.gateway import PricingGateway
from repro.serve.procpool import ProcessReplica, ReplicaPool, warmup_chunk
from repro.serve.replica import FaultyReplica, LocalReplica
from repro.serve.streaming import StreamingBook, synth_ticks

HARNESS_REQUESTS = 200
DEFAULT_REQUESTS = 1000
DEADLINE_MS = 25.0
TICKS = 16


def _replicas(n: int, crash_at, pool: str = "thread", warmup=None):
    """Replica 0 optionally crashes at its ``crash_at``-th chunk; the
    rest are clean workers.  ``pool="process"`` backs every worker with
    a spawned process and makes the crash a real mid-chunk SIGKILL."""
    if pool == "process":
        first = ProcessReplica(
            "replica-0", warmup=warmup,
            faults=None if crash_at is None
            else {int(crash_at): "sigkill"})
        return [first] + [ProcessReplica(f"replica-{i}", warmup=warmup)
                          for i in range(1, n)]
    first = (LocalReplica(name="replica-0") if crash_at is None else
             FaultyReplica(faults={int(crash_at): "crash"},
                           name="replica-0"))
    return [first] + [LocalReplica(name=f"replica-{i}")
                      for i in range(1, n)]


def _stream_book(n_steps):
    return StreamingBook.mixed(n_underlyings=2, per_underlying=6,
                               n_steps=tuple(n_steps), capacity=16)


async def _replay(trace, *, n_replicas, crash_at, restart_s, max_batch,
                  capacity, n_steps, ticks, pool="thread"):
    """One full replay: unary trace, then a streaming segment.  Returns
    (quotes, unary_seconds, metrics, stream_summary)."""
    wu = (warmup_chunk(n_steps=min(n_steps), capacity=capacity)
          if pool == "process" else None)
    # the factory drives restart_s respawn: a killed worker comes back
    # healthy and of the same pool kind
    rp = ReplicaPool(pool, warmup=wu)
    async with PricingGateway(
            replicas=_replicas(n_replicas, crash_at, pool, wu),
            replica_factory=rp.factory,
            max_batch=max_batch, deadline_ms=DEADLINE_MS,
            capacity=capacity, result_cache_size=0,
            restart_s=restart_s, retry_backoff_s=0.05,
            overload_factor=None) as gw:
        t0 = time.perf_counter()
        rids = [await gw.submit(r) for r in trace]
        quotes = {rid: await gw.result(rid) for rid in rids}
        dt = time.perf_counter() - t0
        m_unary = gw.metrics()        # snapshot before the tick feed
        stream = await gw.run_stream(
            _stream_book(n_steps),
            synth_ticks(ticks, n_underlyings=2, seed=1))
        return quotes, dt, m_unary, gw.metrics(), stream


def _audit(trace, quotes, rids):
    """max |quote - price_american| over the trace (dedup by scenario)."""
    refs, worst = {}, 0.0
    for req, rid in zip(trace, rids):
        key = (req.s0, req.sigma, req.rate, req.maturity, req.cost_rate,
               req.payoff, req.strike, req.n_steps)
        if key not in refs:
            refs[key] = price_american(
                s0=req.s0, sigma=req.sigma, rate=req.rate,
                maturity=req.maturity, n_steps=req.n_steps,
                payoff=req.payoff, strike=req.strike,
                cost_rate=req.cost_rate, capacity=32)
        ref, q = refs[key], quotes[rid]
        worst = max(worst, abs(q.ask - ref.ask), abs(q.bid - ref.bid))
    return worst, len(refs)


def bench(requests: int = DEFAULT_REQUESTS, max_batch: int = 64,
          n_steps=(16, 24), capacity: int = 16, crash_at: int = 1,
          restart_s: float = 1.0, seed: int = 0, pool: str = "both",
          out: str = "BENCH_gateway.json") -> dict:
    import jax
    trace = synth_trace(requests, n_steps=n_steps, seed=seed)
    n = len(trace)
    print(f"{n}-request trace, crash at replica chunk #{crash_at}, "
          f"restart after {restart_s}s")

    def replay(n_replicas, crash, pool_kind="thread"):
        return asyncio.run(_replay(
            trace, n_replicas=n_replicas, crash_at=crash,
            restart_s=restart_s, max_batch=max_batch, capacity=capacity,
            n_steps=n_steps, ticks=TICKS, pool=pool_kind))

    # warm-up: compile every unary + streaming batch shape, no faults
    # (process workers warm themselves — each spawns with a warmup chunk)
    replay(2, None)

    configs = [("one_replica", 1, "thread"), ("two_replica", 2, "thread"),
               ("process_pool", 2, "process")]
    if pool == "thread":
        configs = configs[:2]
    elif pool == "process":
        configs = configs[2:]
    results = {}
    for label, n_replicas, pool_kind in configs:
        quotes, dt, m, m_final, stream = replay(n_replicas, crash_at,
                                                pool_kind)
        assert len(quotes) == n and m_final["failed"] == 0, \
            f"{label}: dropped/failed quotes despite failover"
        # the crash must land inside the timed unary replay (sticky
        # affinity means replica-0 only sees its own bucket's chunks —
        # keep --crash-at below that count)
        assert m["replica_crashes"] == 1, \
            f"{label}: crash did not fire during the unary replay"
        worst, distinct = _audit(trace, quotes, sorted(quotes))
        assert worst < 1e-9, f"{label}: oracle audit failed ({worst:.2e})"
        results[label] = {
            "seconds": dt, "quotes_per_sec": n / dt,
            "requeues": m["requeues"], "retries": m["retries"],
            "replica_restarts": m_final["replica_restarts"],
            "p99_latency_ms": m["p99_latency_ms"],
            "staleness_p50_ms": stream["staleness_p50_ms"],
            "staleness_p99_ms": stream["staleness_p99_ms"],
            "oracle_max_abs_err": worst,
        }
        print(f"{label:12s}: {dt:7.3f} s ({n / dt:9.1f} quotes/s)  "
              f"requeues={m['requeues']} "
              f"restarts={m_final['replica_restarts']} "
              f"stale_p99={stream['staleness_p99_ms']:.1f}ms  "
              f"oracle max|err|={worst:.2e} over {distinct} scenarios")

    report = {
        "bench": "gateway_replicas",
        "requests": n, "max_batch": max_batch, "n_steps": list(n_steps),
        "capacity": capacity, "crash_at": crash_at,
        "restart_s": restart_s, "seed": seed, "ticks": TICKS,
        "device": jax.devices()[0].platform,
        "oracle": {"tol": 1e-9},
        **results,
    }
    if "one_replica" in results and "two_replica" in results:
        ratio = (results["two_replica"]["quotes_per_sec"]
                 / results["one_replica"]["quotes_per_sec"])
        print(f"two_over_one: {ratio:.2f}x (criterion: >= 1.5x — the "
              "second replica masks the restart outage)")
        report["two_over_one"] = ratio
        report["meets_1p5x_criterion"] = bool(ratio >= 1.5)
    if "process_pool" in results and "two_replica" in results:
        pratio = (results["process_pool"]["quotes_per_sec"]
                  / results["two_replica"]["quotes_per_sec"])
        print(f"process_over_thread: {pratio:.2f}x (wire pickling + "
              "per-process compiles are the cost of real isolation)")
        report["process_over_thread"] = pratio
    Path(out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


def run() -> list[str]:
    """benchmarks.run entry — harness-sized trace, full JSON artifact."""
    rep = bench(requests=HARNESS_REQUESTS)
    us = rep["two_replica"]["seconds"] * 1e6 / rep["requests"]
    return [
        f"gateway,{us:.0f},"
        f"two_over_one={rep['two_over_one']:.2f}x;"
        f"proc_over_thread={rep['process_over_thread']:.2f}x;"
        f"one_qps={rep['one_replica']['quotes_per_sec']:.0f};"
        f"two_qps={rep['two_replica']['quotes_per_sec']:.0f};"
        f"proc_qps={rep['process_pool']['quotes_per_sec']:.0f};"
        f"stale_p99={rep['two_replica']['staleness_p99_ms']:.0f}ms",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--n-steps", default="16,24")
    ap.add_argument("--capacity", type=int, default=16)
    ap.add_argument("--crash-at", type=int, default=1)
    ap.add_argument("--restart-s", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pool", default="both",
                    choices=["both", "thread", "process"],
                    help="which replica pools to replay: thread "
                         "(one_replica/two_replica), process "
                         "(process_pool — spawned workers, real "
                         "SIGKILL), or both")
    ap.add_argument("--out", default="BENCH_gateway.json")
    a = ap.parse_args()
    bench(requests=a.requests, max_batch=a.max_batch,
          n_steps=tuple(int(x) for x in a.n_steps.split(",")),
          capacity=a.capacity, crash_at=a.crash_at,
          restart_s=a.restart_s, seed=a.seed, pool=a.pool, out=a.out)


if __name__ == "__main__":
    main()
