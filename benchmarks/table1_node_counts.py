"""Paper Table I: nodes processed by thread p0 (L=5).

Exact reproduction: the text-semantics schedule matches every cell to the
node; the literal pseudo-code drifts 0.13-0.17% (the line-25 typo finding,
see core/partition.py).
"""
from __future__ import annotations

import time

from repro.core.partition import simulate_schedule, table1_reference


def run() -> list[str]:
    rows = []
    ref = table1_reference()
    t0 = time.perf_counter()
    max_err = 0.0
    print(f"{'p':>2} {'N':>5} {'paper':>9} {'ours':>9} {'err':>6} "
          f"{'N^2/2p':>9} {'est err%':>8}")
    for (p, n), want in sorted(ref.items()):
        got = simulate_schedule(n, p, 5).p0_nodes
        est = n * n // (2 * p)
        err = abs(got - want)
        max_err = max(max_err, err)
        print(f"{p:>2} {n:>5} {want:>9} {got:>9} {err:>6} {est:>9} "
              f"{100 * (est - want) / want:>7.2f}%")
    us = (time.perf_counter() - t0) * 1e6 / len(ref)
    rows.append(f"table1_node_counts,{us:.1f},max_abs_err={max_err:.0f}")
    return rows
