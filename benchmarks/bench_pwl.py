"""PWL envelope-algebra micro-benchmark: ops/sec of the sort-free hot path.

The Roux–Zastawniak engines spend essentially all their time in three
``core/pwl.py`` operations, batched over the lattice node axis:
``envelope2`` (pointwise max/min), ``cone_infconv`` (transaction-cost
slope restriction) and their composition in one full level step
(``core/rz.py::rz_level_step_lanes``).  This bench times exactly those
three, jit-warm, on a fixed synthetic lane batch — the unit the
merge-path rewrite (no ``sort``/``argsort`` primitives; binary-search
rank computation + gathers) is meant to speed up — and writes a
machine-readable ``BENCH_pwl.json`` gated by ``tools/check_bench.py``:

    PYTHONPATH=src python -m benchmarks.bench_pwl \
        [--lanes 514] [--capacity 24] [--repeats 30] [--out BENCH_pwl.json]

"ops/sec" is lane-operations per second: one op = one PWL record through
one envelope (or cone, or full level step).  The default 514 lanes is the
node-axis width of the N=512 acceptance tree; reference numbers live in
``docs/ARCHITECTURE.md`` §3.2.  ``BENCH_*.json`` files are git-ignored
(CI uploads the artifact; the committed baseline lives under
``benchmarks/baselines/``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

DEFAULT_LANES = 514
DEFAULT_CAPACITY = 24
DEFAULT_REPEATS = 30


def _lane_batch(lanes: int, capacity: int, seed: int = 0):
    """A reproducible batch of small random PWL records (SoA layout)."""
    import jax.numpy as jnp
    from repro.core import pwl as P

    rng = np.random.default_rng(seed)
    m = rng.integers(1, 7, size=lanes)
    xs = np.full((lanes, capacity), P.BIG)
    ys = np.zeros((lanes, capacity))
    for i in range(lanes):
        xs[i, : m[i]] = np.sort(rng.normal(0.0, 2.0, m[i])) \
            + np.arange(m[i]) * 0.05
        ys[i, : m[i]] = rng.normal(0.0, 50.0, m[i])
    # end slopes inside the cost cone so cone_infconv is bounded below
    sl = rng.uniform(-150.0, -130.0, lanes)
    sr = rng.uniform(-20.0, -10.0, lanes)
    return P.PWL(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(sl),
                 jnp.asarray(sr), jnp.asarray(m, jnp.int32))


def _time(fn, *args, repeats: int) -> float:
    import jax
    out = fn(*args)                                   # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def bench(lanes: int = DEFAULT_LANES, capacity: int = DEFAULT_CAPACITY,
          repeats: int = DEFAULT_REPEATS, out: str = "BENCH_pwl.json") -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core import pwl as P
    from repro.core.payoff import american_put
    from repro.core.rz import rz_level_step_lanes

    f = _lane_batch(lanes, capacity, seed=0)
    g = _lane_batch(lanes, capacity, seed=1)
    print(f"{lanes} lanes, capacity={capacity}, repeats={repeats}")

    env = jax.jit(lambda a, b: P.envelope2(a, b, capacity, take_max=True))
    t_env = _time(env, f, g, repeats=repeats)
    print(f"envelope2   : {t_env * 1e3:8.2f} ms  "
          f"({lanes / t_env:12.0f} ops/s)")

    cone = jax.jit(lambda a: P.cone_infconv(a, 120.0, 80.0, capacity))
    t_cone = _time(cone, f, repeats=repeats)
    print(f"cone_infconv: {t_cone * 1e3:8.2f} ms  "
          f"({lanes / t_cone:12.0f} ops/s)")

    params = dict(s0=jnp.float64(100.0), k=jnp.float64(0.005),
                  sig_sqrt_dt=jnp.float64(0.01), r=jnp.float64(1.0001))
    payoff = american_put(100.0)
    step = jax.jit(lambda z: rz_level_step_lanes(
        z, jnp.float64(lanes - 2.0), params, capacity=capacity, seller=True,
        payoff=payoff, dtype=jnp.float64))
    t_step = _time(step, f, repeats=max(1, repeats // 3))
    print(f"level step  : {t_step * 1e3:8.2f} ms  "
          f"({lanes / t_step:12.0f} ops/s)")

    # roofline matrix: the three jitted closures are the compiled
    # programs themselves — exact XLA flop/byte counts vs. platform peak
    from repro.core.platform import platform_summary
    from repro.roofline.pricing import compiled_cost, matrix_entry
    matrix = []
    for op, fn, args, secs in (("envelope2", env, (f, g), t_env),
                               ("cone_infconv", cone, (f,), t_cone),
                               ("level_step", step, (f,), t_step)):
        cell = matrix_entry(op=op, backend="jnp", dtype="float64",
                            seconds=secs,
                            cost=compiled_cost(fn, *args))
        if cell is not None:
            matrix.append(cell)
            print(f"roofline {op:12s}: "
                  f"{cell['achieved_flops_per_sec']:.3g} flop/s "
                  f"({(cell['frac_peak_flops'] or 0) * 100:.2f}% peak), "
                  f"{cell['achieved_bytes_per_sec']:.3g} B/s "
                  f"({(cell['frac_peak_bw'] or 0) * 100:.2f}% peak), "
                  f"{cell['bound']}-bound")

    report = {
        "bench": "pwl_envelope_ops",
        "lanes": lanes, "capacity": capacity, "repeats": repeats,
        "device": jax.devices()[0].platform,
        "platform": platform_summary(),
        "envelope": {"seconds": t_env, "ops_per_sec": lanes / t_env},
        "cone": {"seconds": t_cone, "ops_per_sec": lanes / t_cone},
        "level_step": {"seconds": t_step, "ops_per_sec": lanes / t_step},
        "roofline": {"matrix": matrix},
    }
    Path(out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


def run() -> list[str]:
    """benchmarks.run entry — default sizing, full JSON artifact."""
    rep = bench()
    us = rep["level_step"]["seconds"] * 1e6 / rep["lanes"]
    return [
        f"pwl,{us:.2f},"
        f"env_ops={rep['envelope']['ops_per_sec']:.0f};"
        f"cone_ops={rep['cone']['ops_per_sec']:.0f};"
        f"step_ops={rep['level_step']['ops_per_sec']:.0f};"
        f"lanes={rep['lanes']}",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lanes", type=int, default=DEFAULT_LANES)
    ap.add_argument("--capacity", type=int, default=DEFAULT_CAPACITY)
    ap.add_argument("--repeats", type=int, default=DEFAULT_REPEATS)
    ap.add_argument("--out", default="BENCH_pwl.json")
    a = ap.parse_args()
    bench(lanes=a.lanes, capacity=a.capacity, repeats=a.repeats, out=a.out)


if __name__ == "__main__":
    main()
