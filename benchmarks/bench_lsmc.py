"""LSMC engine throughput: Monte Carlo paths/sec and contracts/sec.

Prices one flat batch of Bermudan put contracts through
``scenarios.price_grid_lsmc`` twice — plain single-device jit and the
``devices=8`` mesh layout — and reports paths/sec (= contracts x paths
per wall-second, the MC analogue of the lattice benches' nodes/sec) and
contracts/sec for both, plus the mesh/single ratio.  On a machine
without 8 devices the mesh cell runs the bit-identical *simulated*
layout (docs/KNOWN_ISSUES.md) — the JSON records which — so the ratio
then measures pure shard-plan code-path overhead, not a speedup; expose
fake devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
to measure the real ``shard_map`` path.

Timings are jit-warm (a warm-up call compiles both layouts first), the
repo's benchmark convention; results are the same bits either way — the
per-row fold_in keys make the draw independent of batch layout.

    PYTHONPATH=src python -m benchmarks.bench_lsmc \
        [--contracts 32] [--n-steps 50] [--paths 4096] \
        [--every 5] [--repeats 5] [--out BENCH_lsmc.json]

``BENCH_*.json`` files are git-ignored; the committed baseline lives in
``benchmarks/baselines/BENCH_lsmc.json`` (gated by tools/check_bench.py).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.scenarios import ScenarioGrid, price_grid_lsmc

DEFAULT_CONTRACTS = 32
DEFAULT_N_STEPS = 50
DEFAULT_PATHS = 4096
DEFAULT_EVERY = 5
DEFAULT_REPEATS = 5


def _grid(contracts: int, n_steps: int, every: int) -> ScenarioGrid:
    schedule = tuple(range(every, n_steps + 1, every))
    return ScenarioGrid.explicit(
        s0=np.linspace(85.0, 115.0, contracts), sigma=0.2, rate=0.1,
        maturity=0.25, strike=100.0, payoff="put", n_steps=n_steps,
        exercise_steps=schedule)


def _time(grid, *, paths: int, repeats: int, devices):
    run = lambda: price_grid_lsmc(grid, n_paths=paths, seed=0,  # noqa: E731
                                  devices=devices)
    res = run()                                   # warm-up: compile
    best = min(_once(run) for _ in range(repeats))
    return res, best


def _once(run) -> float:
    t0 = time.perf_counter()
    run()
    return time.perf_counter() - t0


def bench(*, contracts: int = DEFAULT_CONTRACTS,
          n_steps: int = DEFAULT_N_STEPS, paths: int = DEFAULT_PATHS,
          every: int = DEFAULT_EVERY, repeats: int = DEFAULT_REPEATS,
          out: str = "BENCH_lsmc.json") -> dict:
    grid = _grid(contracts, n_steps, every)
    n_ex = len(grid.exercise_steps)
    cells = {}
    res_single, t_single = _time(grid, paths=paths, repeats=repeats,
                                 devices=None)
    res_mesh, t_mesh = _time(grid, paths=paths, repeats=repeats, devices=8)
    # layout must not change the draws — assert before reporting numbers
    np.testing.assert_array_equal(res_single.ask, res_mesh.ask)
    for name, t in (("single", t_single), ("mesh8", t_mesh)):
        cells[name] = {
            "seconds": t,
            "contracts_per_sec": contracts / t,
            "paths_per_sec": contracts * paths / t,
        }
        print(f"{name:7s}: {t * 1e3:8.2f} ms  "
              f"({cells[name]['contracts_per_sec']:10.1f} contracts/s, "
              f"{cells[name]['paths_per_sec']:14.0f} paths/s)")
    si = res_mesh.shard_info
    report = {
        "bench": "lsmc_paths",
        "contracts": contracts, "n_steps": n_steps, "paths": paths,
        "n_exercise": n_ex, "repeats": repeats,
        "device": jax.devices()[0].platform,
        "mesh_simulated": bool(si.simulated) if si is not None else True,
        "single": cells["single"], "mesh8": cells["mesh8"],
        "mesh8_over_single": t_single / t_mesh,
    }
    Path(out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


def run() -> list[str]:
    """benchmarks.run entry — default sizing, full JSON artifact."""
    rep = bench()
    us = rep["single"]["seconds"] * 1e6 / rep["contracts"]
    return [
        f"lsmc,{us:.2f},"
        f"paths_per_sec={rep['single']['paths_per_sec']:.0f};"
        f"mesh8_over_single={rep['mesh8_over_single']:.3f};"
        f"contracts={rep['contracts']};paths={rep['paths']}",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--contracts", type=int, default=DEFAULT_CONTRACTS)
    ap.add_argument("--n-steps", type=int, default=DEFAULT_N_STEPS)
    ap.add_argument("--paths", type=int, default=DEFAULT_PATHS)
    ap.add_argument("--every", type=int, default=DEFAULT_EVERY)
    ap.add_argument("--repeats", type=int, default=DEFAULT_REPEATS)
    ap.add_argument("--out", default="BENCH_lsmc.json")
    a = ap.parse_args()
    bench(contracts=a.contracts, n_steps=a.n_steps, paths=a.paths,
          every=a.every, repeats=a.repeats, out=a.out)


if __name__ == "__main__":
    main()
