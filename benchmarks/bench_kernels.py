"""Kernel micro-benchmarks (CPU wall times of the XLA reference paths +
derived per-node / per-token costs; the Pallas kernels themselves target
TPU and are validated in interpret mode — their roofline numbers live in
EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LatticeModel, american_put
from repro.core.notc import price_notc_jax
from repro.core.rz import price_rz
from repro.kernels.binomial_ref import lattice_levels_ref


def _time(fn, *args, reps=3):
    fn(*args)                                  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> list[str]:
    rows = []

    # lattice stencil: XLA path, per-node cost
    N = 20000
    v = jnp.linspace(0.0, 50.0, N + 1)
    scalars = jnp.asarray([N, 0.53, 0.999, 100.0, 100.0, 0.002], jnp.float64)
    f = jax.jit(lambda vv: lattice_levels_ref(vv, scalars, levels=50))
    dt = _time(f, v)
    rows.append(f"lattice_stencil_50lvl,{dt*1e6:.0f},"
                f"ns_per_node={dt/(50*(N+1))*1e9:.2f}")

    # end-to-end no-TC price (the appendix serial baseline on this host)
    m = LatticeModel(s0=100, sigma=0.3, rate=0.06, maturity=3.0,
                     n_steps=10000)
    t0 = time.perf_counter()
    price_notc_jax(m, american_put(100.0))
    dt = time.perf_counter() - t0
    rows.append(f"notc_price_N10000,{dt*1e6:.0f},serial_baseline")

    # TC pricing per-node cost (the paper's §5 workload, small N on CPU).
    # NOTE: reuse ONE payoff object — the jit cache keys on it.
    m2 = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25,
                      n_steps=60, cost_rate=0.005)
    put = american_put(100.0)
    t0 = time.perf_counter()
    price_rz(m2, put, capacity=32)
    dt_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    price_rz(m2, put, capacity=32)
    dt = time.perf_counter() - t0
    nodes = (m2.n_steps + 2) * (m2.n_steps + 3) / 2
    rows.append(f"tc_price_N60,{dt*1e6:.0f},"
                f"us_per_pwl_node={dt/nodes*1e6:.2f};compile_s={dt_compile:.1f}")
    return rows
