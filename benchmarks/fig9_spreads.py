"""Paper Fig. 9: ask/bid curves under different transaction cost rates.

Reprices the paper's American put (K=100, T=0.25, sigma=0.2, R=0.1) for
S0 in [90, 110] under k in {0, 0.25%, 0.5%} and checks the figure's
ordering pointwise:

    bid(k2) <= bid(k1) <= pi(0) = ask(0) = bid(0) <= ask(k1) <= ask(k2)

Emits a CSV of the curves (the numbers behind the figure).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import LatticeModel, american_put, price_notc_np
from repro.core.rz import price_rz_batch

N_STEPS = 60        # figure-resolution lattice (CPU-budget bound)
SPOTS = np.linspace(90.0, 110.0, 9)
RATES = (0.0, 0.0025, 0.005)


def run() -> list[str]:
    put = american_put(100.0)
    t0 = time.perf_counter()
    curves = {}
    for k in RATES:
        ask, bid, _ = price_rz_batch(
            SPOTS, np.full_like(SPOTS, 0.2), np.full_like(SPOTS, 0.1),
            np.full_like(SPOTS, 0.25), np.full_like(SPOTS, k),
            n_steps=N_STEPS, capacity=32, payoff=put)
        curves[k] = (np.asarray(ask), np.asarray(bid))
    dt = time.perf_counter() - t0

    print("S0," + ",".join(f"ask(k={k}),bid(k={k})" for k in RATES))
    for i, s in enumerate(SPOTS):
        row = [f"{s:.1f}"]
        for k in RATES:
            row += [f"{curves[k][0][i]:.4f}", f"{curves[k][1][i]:.4f}"]
        print(",".join(row))

    a0, b0 = curves[0.0]
    a1, b1 = curves[0.0025]
    a2, b2 = curves[0.005]
    ok = (np.all(b2 <= b1 + 1e-9) and np.all(b1 <= b0 + 1e-9)
          and np.all(np.abs(a0 - b0) < 1e-9)
          and np.all(a0 <= a1 + 1e-9) and np.all(a1 <= a2 + 1e-9))
    max_spread = float(np.max(a2 - b2))
    return [f"fig9_spreads,{dt*1e6/len(SPOTS)/len(RATES):.0f},"
            f"ordering_ok={ok};max_spread={max_spread:.3f}"]
