"""TC grid engine backends head-to-head: jnp vs blocked Pallas rounds.

Prices the same transaction-cost scenario batch through both
``price_grid_rz`` backends (compile excluded; steady-state serving cost)
and writes a machine-readable ``BENCH_rz.json`` so the perf trajectory of
the paper's headline workload is tracked, not anecdotal:

    PYTHONPATH=src python -m benchmarks.bench_rz_pallas \
        [--n-steps 512] [--contracts 2] [--capacity 24] [--repeats 1] \
        [--lambda 0.005] [--levels L] [--block B] [--out BENCH_rz.json]

Both backends walk the ``core/partition.py::kernel_round_plan`` schedule
(the paper's §4.2 thread shedding, ~N^2/2 lane-levels) with the seller
and buyer sides fused into one ``(2, P)`` state, on top of the sort-free
merge-path PWL algebra (docs/ARCHITECTURE.md §3.2) — so on CPU the two
are ~at parity and ``pallas_over_jnp`` is a drift canary around 1, not a
banked win.  The Pallas backend's remaining value is the VMEM-resident
block scheme a TPU lowering keeps.  ``BENCH_*.json`` files are
deliberately git-ignored (machine-local measurements; CI uploads them as
artifacts, reference numbers live in docs/ARCHITECTURE.md).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.scenarios import ScenarioGrid, price_grid_rz, rz_grid_cost

# harness (benchmarks.run) defaults: sized for the 1-core CPU budget;
# the acceptance configuration is the CLI default --n-steps 512.
HARNESS_N_STEPS = 96
DEFAULT_N_STEPS = 512


def _bench(grid, *, capacity, backend, repeats, levels=None, block=None):
    kw = dict(capacity=capacity, backend=backend, levels=levels, block=block)
    res = price_grid_rz(grid, **kw)                       # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        res = price_grid_rz(grid, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return res, dt


def bench(n_steps: int = DEFAULT_N_STEPS, contracts: int = 2,
          capacity: int = 24, cost_rate: float = 0.005, repeats: int = 1,
          levels=None, block=None, out: str = "BENCH_rz.json") -> dict:
    import jax
    grid = ScenarioGrid.explicit(
        s0=tuple(np.linspace(95.0, 105.0, contracts)),
        sigma=0.2, rate=0.1, maturity=0.25, cost_rate=cost_rate,
        payoff="put", strike=100.0, n_steps=n_steps)
    n = grid.n_scenarios
    print(f"{n} contracts (put, lambda={cost_rate}), N={n_steps}, "
          f"capacity={capacity}")

    r_jnp, t_jnp = _bench(grid, capacity=capacity, backend="jnp",
                          repeats=repeats)
    print(f"jnp    : {t_jnp:8.2f} s  ({n / t_jnp:8.3f} contracts/s)")
    r_pal, t_pal = _bench(grid, capacity=capacity, backend="pallas",
                          repeats=repeats, levels=levels, block=block)
    print(f"pallas : {t_pal:8.2f} s  ({n / t_pal:8.3f} contracts/s)  "
          f"[interpret mode]")
    gap_ask = float(np.max(np.abs(r_jnp.ask - r_pal.ask)))
    gap_bid = float(np.max(np.abs(r_jnp.bid - r_pal.bid)))
    ratio = t_jnp / t_pal
    print(f"pallas/jnp contracts/s: {ratio:.2f}x   "
          f"max|diff| ask {gap_ask:.2e} bid {gap_bid:.2e}   "
          f"max_pieces {r_pal.max_pieces}/{capacity}")

    # per-backend/per-platform roofline matrix: exact XLA flop/byte
    # counts of the compiled rows programs vs. nominal platform peaks
    from repro.core.platform import platform_summary, resolve_interpret
    from repro.roofline.pricing import matrix_entry
    matrix = []
    for bk, secs, kw in (("jnp", t_jnp, {}),
                         ("pallas", t_pal,
                          dict(levels=levels, block=block))):
        cell = matrix_entry(
            op="rz_grid", backend=bk, dtype="float64", seconds=secs,
            cost=rz_grid_cost(grid, capacity=capacity, backend=bk, **kw))
        if cell is not None:
            matrix.append(cell)
            print(f"roofline {bk:6s}: {cell['achieved_flops_per_sec']:.3g} "
                  f"flop/s ({(cell['frac_peak_flops'] or 0) * 100:.2f}% "
                  f"peak), {cell['achieved_bytes_per_sec']:.3g} B/s "
                  f"({(cell['frac_peak_bw'] or 0) * 100:.2f}% peak), "
                  f"{cell['bound']}-bound")

    report = {
        "bench": "rz_grid_backends",
        "n_steps": n_steps, "contracts": n, "capacity": capacity,
        "payoff": "put", "cost_rate": cost_rate, "repeats": repeats,
        "levels": levels, "block": block,
        "interpret": resolve_interpret(None),
        "device": jax.devices()[0].platform,
        "platform": platform_summary(),
        "jnp": {"seconds": t_jnp, "contracts_per_sec": n / t_jnp},
        "pallas": {"seconds": t_pal, "contracts_per_sec": n / t_pal},
        "pallas_over_jnp": ratio,
        "max_abs_diff_ask": gap_ask, "max_abs_diff_bid": gap_bid,
        "max_pieces": int(r_pal.max_pieces),
        "max_pieces_jnp": int(r_jnp.max_pieces),
        "roofline": {"matrix": matrix},
    }
    Path(out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


def run() -> list[str]:
    """benchmarks.run entry — harness-sized depth, full JSON artifact."""
    rep = bench(n_steps=HARNESS_N_STEPS)
    us = rep["pallas"]["seconds"] * 1e6 / rep["contracts"]
    return [
        f"rz_pallas,{us:.0f},"
        f"ratio={rep['pallas_over_jnp']:.2f}x;"
        f"jnp_cps={rep['jnp']['contracts_per_sec']:.3f};"
        f"pallas_cps={rep['pallas']['contracts_per_sec']:.3f};"
        f"N={rep['n_steps']}",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-steps", type=int, default=DEFAULT_N_STEPS)
    ap.add_argument("--contracts", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=24)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--lambda", dest="cost_rate", type=float, default=0.005)
    ap.add_argument("--levels", type=int, default=None)
    ap.add_argument("--block", type=int, default=None)
    ap.add_argument("--out", default="BENCH_rz.json")
    a = ap.parse_args()
    bench(n_steps=a.n_steps, contracts=a.contracts, capacity=a.capacity,
          cost_rate=a.cost_rate, repeats=a.repeats, levels=a.levels,
          block=a.block, out=a.out)


if __name__ == "__main__":
    main()
