"""Paper Table II: parallel speedups pricing under transaction costs.

This container has ONE CPU core, so the paper's wall-clock pthread
speedups cannot be re-measured.  What can be reproduced is the *schedule*:
Algorithm 1's round structure determines each thread's critical path
(nodes on the busiest thread per round + per-round synchronisation).  With

    T_p = c_node * (init_p + sum_r max_i nodes_r_i) + c_sync * sum_r p_r

and c_node measured from our sequential engine, the model reproduces the
paper's speedup shape; c_sync is calibrated once against the paper's
(p=8, N=1500) point and held fixed for every other cell.

Columns: model speedup vs paper Table II speedup (American put, k=0.5%,
L=5).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.partition import simulate_schedule

# paper Table II, American put k=0.5%, L=5: speedups by (p, N)
PAPER = {
    (2, 450): 1.41, (2, 900): 1.40, (2, 1500): 1.41,
    (3, 1500): 2.10, (4, 1500): 2.74, (5, 1500): 3.40,
    (6, 1500): 4.02, (7, 1500): 4.63, (8, 450): 4.48, (8, 900): 5.00,
    (8, 1500): 5.26,
}


def _model_speedup(n: int, p: int, c_sync_over_c_node: float) -> float:
    serial = simulate_schedule(n, 1, 5)
    par = simulate_schedule(n, p, 5)
    t1 = serial.total_nodes
    init = max(par._init_counts)
    tp = init + sum(max(r.per_thread) for r in par.rounds)
    tp += c_sync_over_c_node * len(par.rounds)
    # the paper's parallel build pays a near-constant code overhead vs the
    # optimised sequential program (measured efficiency is ~flat: 70% at
    # p=2 -> 66% at p=8), plus a mild per-thread contention slope
    tp *= 1.40 + 0.01 * (p - 2)
    return t1 / tp


def run() -> list[str]:
    t0 = time.perf_counter()
    c_sync = 20.0                      # in node-costs; one global constant
    errs = []
    print(f"{'p':>2} {'N':>5} {'paper':>6} {'model':>6} {'err%':>6}")
    for (p, n), want in sorted(PAPER.items()):
        got = _model_speedup(n, p, c_sync)
        errs.append(abs(got - want) / want)
        print(f"{p:>2} {n:>5} {want:>6.2f} {got:>6.2f} "
              f"{100 * (got - want) / want:>5.1f}%")
    us = (time.perf_counter() - t0) * 1e6 / len(PAPER)
    return [f"table2_tc_speedup,{us:.1f},"
            f"mean_rel_err={float(np.mean(errs)):.3f}"]
