"""Pallas TPU kernel: chunked linear-recurrence scan (RG-LRU / Mamba).

Computes h_t = a_t * h_{t-1} + b_t along the sequence, the state update
shared by recurrentgemma's RG-LRU and falcon-mamba's selective SSM
(diagonal A).  Structure = the paper's block scheme on the time axis:

  * sequence tiled into chunks (grid minor axis, executed sequentially);
  * the running state h is the inter-chunk "halo": it lives in a VMEM
    scratch accumulator that persists across grid steps — one chunk's
    worth of (a, b) streams HBM->VMEM per step, the state never leaves;
  * width is tiled over the second grid axis (VPU lanes).

The wrapper reshapes (B, T, W) -> (B, n_chunks, chunk, W); the kernel
writes h for every position (h_seq), and the wrapper returns
(h_seq, h_last).  Oracle: ``repro.models.layers._linear_scan_chunked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.platform import resolve_interpret

__all__ = ["lru_scan", "lru_scan_ref"]


def _lru_kernel(a_ref, b_ref, h0_ref, o_ref, h_scratch, *, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0, 0]                               # (chunk, bw)
    b = b_ref[0, 0]
    h = h_scratch[...]                            # (1, bw)

    rows = []
    for j in range(chunk):                        # static unroll in VMEM
        h = a[j][None, :] * h + b[j][None, :]
        rows.append(h)
    out = jnp.concatenate(rows, axis=0)           # (chunk, bw)
    h_scratch[...] = h
    o_ref[0, 0] = out.astype(o_ref.dtype)


def lru_scan(a, b, h0, *, chunk: int = 256, interpret: bool | None = None,
             block_w: int = 128):
    """a, b: (B, T, W) f32; h0: (B, W) f32 -> (h_seq (B,T,W), h_last).

    ``interpret=None`` resolves from the platform policy.
    """
    interpret = resolve_interpret(interpret)
    B, T, W = a.shape
    chunk = min(chunk, T)
    block_w = min(block_w, W)
    assert T % chunk == 0 and W % block_w == 0
    nc = T // chunk
    ar = a.reshape(B, nc, chunk, W)
    br = b.reshape(B, nc, chunk, W)

    kernel = functools.partial(_lru_kernel, chunk=chunk)
    h_seq = pl.pallas_call(
        kernel,
        grid=(B, W // block_w, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, block_w),
                         lambda bi, wi, c: (bi, c, 0, wi)),
            pl.BlockSpec((1, 1, chunk, block_w),
                         lambda bi, wi, c: (bi, c, 0, wi)),
            pl.BlockSpec((1, block_w), lambda bi, wi, c: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, block_w),
                               lambda bi, wi, c: (bi, c, 0, wi)),
        out_shape=jax.ShapeDtypeStruct((B, nc, chunk, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(ar, br, h0)
    h_seq = h_seq.reshape(B, T, W)
    return h_seq, h_seq[:, T - 1, :]


def lru_scan_ref(a, b, h0, *, chunk: int = 256):
    """Oracle: the chunked associative scan used by the model layers."""
    from ..models.layers import _linear_scan_chunked
    return _linear_scan_chunked(a, b, h0, chunk)
