"""Pallas TPU kernel: causal/windowed GQA flash attention.

BlockSpec tiling: one q block of ``block_q`` rows per grid step, online
softmax over KV chunks of ``block_kv`` — live VMEM is
O(block_q * block_kv + block_q * hd); the S x S score matrix never
materialises.  GQA is handled in the index map: query head h reads KV head
h // (H // KVH).

Oracle: ``repro.models.layers._attn_flash`` (itself validated against the
naive materialised-scores path) via ``flash_ref`` below.  The sweep tests
run the kernel in interpret mode over shapes x dtypes x (causal, window).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.platform import resolve_interpret

__all__ = ["flash_attention", "flash_ref"]

_NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_kv: int,
               seq_kv: int, causal: bool, window, scale: float):
    iq = pl.program_id(1)
    q = q_ref[...][0]                              # (bq, hd)
    hd = q.shape[-1]
    nkv = seq_kv // block_kv

    pos_q = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(j, carry):
        m, l, acc = carry
        # NOTE: int indexers inside pl.load break interpret-mode discharge
        # on jax 0.4.x; use a width-1 dslice and drop the axis after load.
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(j * block_kv, block_kv),
                            slice(None)))[0]       # (bkv, hd)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(j * block_kv, block_kv),
                            slice(None)))[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)
        pos_k = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= pos_q >= pos_k
        if window is not None:
            mask &= pos_q - pos_k < window
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_new = acc * corr + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, hd), jnp.float32)
    # int32 bounds: python ints canonicalise the loop counter (and the
    # j * block_kv offsets) to int64 under x64, off the compiled-path
    # lowering contract
    m, l, acc = jax.lax.fori_loop(jnp.int32(0), jnp.int32(nkv), body,
                                  (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)[None]


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool | None = None):
    """q: (B, T, H, hd); k, v: (B, S, KVH, hd) -> (B, T, H, hd).

    ``interpret=None`` resolves from the platform policy.
    """
    interpret = resolve_interpret(interpret)
    B, T, H, hd = q.shape
    S, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    block_q = min(block_q, T)
    block_kv = min(block_kv, S)
    assert T % block_q == 0 and S % block_kv == 0

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_kv=block_kv, seq_kv=S,
        causal=causal, window=window, scale=1.0 / math.sqrt(hd))

    kv_index = lambda bh, iq: ((bh // H) * KVH + (bh % H) // G, 0, 0)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, T // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq: (bh, iq, 0)),
            pl.BlockSpec((1, S, hd), kv_index),
            pl.BlockSpec((1, S, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T, hd).transpose(0, 2, 1, 3)


def flash_ref(q, k, v, *, causal: bool = True, window=None):
    """Oracle: the validated pure-jnp online-softmax implementation."""
    from ..models.layers import _attn_flash
    B, T, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qq = q.reshape(B, T, KVH, G, hd)
    pos = jnp.arange(T)
    pos_k = jnp.arange(k.shape[1])
    out = _attn_flash(qq, k, v, pos, pos_k, causal=causal, window=window,
                      q_chunk=min(64, T), kv_chunk=min(64, k.shape[1]))
    return out.reshape(B, T, H, hd)
