"""Pure-jnp oracle for the binomial lattice kernel.

The level-by-level reference the Pallas kernel is swept against; also
re-exports the numpy oracle used by the pricing tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.notc import price_notc_np  # noqa: F401  (re-export)

__all__ = ["lattice_levels_ref", "price_notc_np"]


def lattice_levels_ref(v, scalars, *, levels: int, kind: str = "put"):
    """Advance all nodes ``levels`` levels: the exact computation the
    kernel performs, as plain jnp on the full array."""
    lvl0, p_up, inv_r, strike, s0, sig = (scalars[i] for i in range(6))
    idx = jnp.arange(v.shape[0], dtype=v.dtype)

    def payoff(lvl):
        s = s0 * jnp.exp((2.0 * idx - lvl) * sig)
        pay = strike - s if kind == "put" else s - strike
        return jnp.maximum(pay, 0.0)

    for j in range(levels):
        lvl = lvl0 - (j + 1)
        cont = (p_up * jnp.roll(v, -1) + (1.0 - p_up) * v) * inv_r
        new = jnp.maximum(payoff(lvl), cont)
        v = jnp.where(lvl >= 0, new, v)
    return v
