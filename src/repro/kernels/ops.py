"""Jitted public wrappers around the Pallas kernels.

``price_notc_kernel`` prices the paper's appendix American put end-to-end
through the blocked lattice kernel: fori_loop over rounds on the host,
one ``lattice_round`` (L levels, one HBM round-trip per block) per
iteration — the whole-program analogue of the paper's Algorithm 1 with
pthread signals replaced by grid/block independence.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..core.lattice import LatticeModel
from ..core.platform import default_dtype, resolve_interpret
from .binomial_step import DEFAULT_BLOCK, lattice_round

__all__ = ["price_notc_kernel", "flash_attention", "lru_scan"]


@partial(jax.jit, static_argnames=("n_steps", "levels", "block", "kind",
                                   "interpret", "dtype"))
def _price_notc_impl(s0, sigma, rate, maturity, strike, *, n_steps: int,
                     levels: int, block: int, kind: str, interpret: bool,
                     dtype):
    dt = maturity / n_steps
    u = jnp.exp(sigma * jnp.sqrt(dt))
    r = jnp.exp(rate * dt)
    p_up = (r - 1.0 / u) / (u - 1.0 / u)
    sig = sigma * jnp.sqrt(dt)

    P = -(-(n_steps + 1) // block) * block
    idx = jnp.arange(P, dtype=dtype)
    s_leaf = s0 * jnp.exp((2.0 * idx - n_steps) * sig)
    pay = strike - s_leaf if kind == "put" else s_leaf - strike
    v0 = jnp.maximum(pay, 0.0)

    rounds = -(-n_steps // levels)

    def body(rr, v):
        lvl0 = jnp.asarray(n_steps - rr * levels, dtype)
        scalars = jnp.stack([lvl0, p_up.astype(dtype), (1.0 / r).astype(dtype),
                             jnp.asarray(strike, dtype), jnp.asarray(s0, dtype),
                             sig.astype(dtype)])
        return lattice_round(v, scalars, levels=levels, block=block,
                             kind=kind, interpret=interpret)

    v = jax.lax.fori_loop(0, rounds, body, v0)
    return v[0]


def price_notc_kernel(model: LatticeModel, strike: float, *,
                      kind: str = "put", levels: int = 64,
                      block: int = DEFAULT_BLOCK,
                      interpret: bool | None = None,
                      dtype=None) -> float:
    """Price through the blocked lattice kernel.

    ``interpret=None`` / ``dtype=None`` resolve from the platform policy
    (``core/platform.py``): interpret + float64 on CPU, compiled +
    float32 on GPU/TPU.
    """
    interpret = resolve_interpret(interpret)
    if dtype is None:
        dtype = default_dtype()
    out = _price_notc_impl(
        jnp.asarray(model.s0, dtype), jnp.asarray(model.sigma, dtype),
        jnp.asarray(model.rate, dtype), jnp.asarray(model.maturity, dtype),
        jnp.asarray(strike, dtype), n_steps=model.n_steps, levels=levels,
        block=block, kind=kind, interpret=interpret, dtype=dtype)
    return float(out)


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool | None = None):
    """Pallas causal/windowed GQA flash attention.

    q: (B, T, H, hd);  k, v: (B, S, KVH, hd);  returns (B, T, H, hd).
    """
    from .flash_attention import flash_attention as _fa
    return _fa(q, k, v, causal=causal, window=window, block_q=block_q,
               block_kv=block_kv, interpret=interpret)


def lru_scan(a, b, h0, *, chunk: int = 256, interpret: bool | None = None):
    """Pallas chunked linear recurrence h_t = a_t h_{t-1} + b_t.

    a, b: (B, T, W); h0: (B, W); returns (h_seq (B,T,W), h_last (B,W)).
    """
    from .lru_scan import lru_scan as _ls
    return _ls(a, b, h0, chunk=chunk, interpret=interpret)
