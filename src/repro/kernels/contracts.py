"""Per-kernel Mosaic/Triton lowering-compatibility contracts.

``core/platform.py`` decides *where* a Pallas kernel runs compiled; this
registry declares *what each kernel promises* a compiled lowering so the
promise can be asserted statically on CPU, long before a GPU/TPU lane
ever lowers it:

  * **no sort primitives** — Mosaic has no sort lowering; the merge-path
    PWL engine (PR 5) exists precisely to keep ``sort``/``argsort`` out
    of the trace;
  * **dtype policy** — a kernel traced at float32 must stay
    ``{float32, int32, bool}``: a stray float64 (weak-typed Python
    scalars) or int64 (x64-canonicalised ``arange``/``cumsum``/loop
    counters) would either fail to lower or silently double register
    pressure on hardware with no native 64-bit lanes;
  * **declared dynamic gathers** — data-dependent ``gather`` /
    ``dynamic_slice`` patterns (the PWL binary search, halo indexing)
    are legal but must be declared per kernel, so a new undeclared one
    is a reviewable event, not an accident.

``tests/test_lowering_contract.py`` (marker ``lowering``) asserts every
contract statically on every platform and re-runs the kernels
``interpret=False`` against the interpret oracle where the platform has
a compiled lowering (:func:`repro.core.platform.supports_compiled_pallas`).

The registry is *closed over the repo*: the conformance suite scans the
source tree for pallas-call sites and asserts every module containing
one is covered here, so a new kernel without a declared contract fails
CI.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "FORBIDDEN_PRIMITIVES", "ALLOWED_INT_DTYPES", "GATHER_PRIMITIVES",
    "LoweringContract", "CONTRACTS", "trace_kernel", "jaxpr_summary",
    "check_static_contract", "run_kernel",
]

# Primitives with no Mosaic lowering (and no place in a lattice kernel).
FORBIDDEN_PRIMITIVES = frozenset(
    {"sort", "sort_key_val", "argsort", "top_k", "approx_top_k"})

# Bookkeeping dtypes a compiled lowering accepts alongside the value
# dtype.  int64 is deliberately absent: x64 canonicalisation leaks it.
ALLOWED_INT_DTYPES = frozenset({"bool", "int32", "uint32"})

# Data-dependent addressing primitives a kernel must declare to use.
GATHER_PRIMITIVES = frozenset(
    {"gather", "dynamic_slice", "dynamic_update_slice"})


@dataclasses.dataclass(frozen=True)
class LoweringContract:
    """What one Pallas kernel promises a compiled (non-interpret) lowering.

    ``build(dtype, interpret)`` returns ``(fn, args)`` with ``fn(*args)``
    a jit-traceable closed call of the kernel at that dtype — small
    shapes, fixed values, usable both for :func:`jax.make_jaxpr` (static
    checks) and execution (interpret-vs-compiled differencing).
    """
    name: str
    module: str                       # repo module owning the pallas_call
    build: Callable[..., Tuple[Callable, tuple]]
    dtypes: Tuple[str, ...] = ("float64", "float32")
    dynamic_gather: bool = False      # declared data-dependent addressing
    tol: Tuple[Tuple[str, float], ...] = (("float64", 1e-12),
                                          ("float32", 1e-5))

    def tolerance(self, dtype) -> float:
        return dict(self.tol)[str(jnp.dtype(dtype))]


# --------------------------------------------------------------------- #
# example-trace builders (tiny fixed workloads, one per kernel)
# --------------------------------------------------------------------- #
def _build_rz_round(dtype, interpret=None):
    from ..core import pwl as P
    from ..core.payoff import american_put
    from .rz_step import RZ_SCALARS, rz_round
    lanes, capacity, levels, block = 8, 8, 2, 8
    slope = jnp.tile(jnp.asarray([-1.0, -0.5], dtype)[:, None], (1, lanes))
    val0 = jnp.full((2, lanes), 100.0, dtype)
    z = P.make_affine(slope, val0, capacity, dtype)
    # [lvl0, s0, sig_sqrt_dt, r, k, *payoff params] — a live put workload
    scalars = jnp.asarray([6.0, 100.0, 0.05, 1.001, 0.01,
                           *american_put(100.0).params], dtype)
    assert scalars.shape == (RZ_SCALARS,)
    fn = lambda z, s: rz_round(z, s, levels=levels, block=block,
                               interpret=interpret)
    return fn, (z, scalars)


def _build_lattice_round(dtype, interpret=None):
    from .binomial_step import lattice_round
    v = jnp.linspace(0.0, 10.0, 16).astype(dtype)
    # [lvl0, p_up, inv_r, strike, s0, sig_sqrt_dt]
    scalars = jnp.asarray([8.0, 0.5, 0.999, 100.0, 100.0, 0.05], dtype)
    fn = lambda v, s: lattice_round(v, s, levels=4, block=8,
                                    interpret=interpret)
    return fn, (v, scalars)


def _build_lattice_round_param(dtype, interpret=None):
    from .binomial_step import PARAM_SCALARS, lattice_round_param
    v = jnp.linspace(0.0, 10.0, 16).astype(dtype)
    scalars = jnp.zeros((PARAM_SCALARS,), dtype)
    scalars = scalars.at[0].set(8.0).at[1].set(0.5).at[2].set(0.999)
    fn = lambda v, s: lattice_round_param(v, s, levels=4, block=8,
                                          interpret=interpret)
    return fn, (v, scalars)


def _build_flash_attention(dtype, interpret=None):
    from .flash_attention import flash_attention
    B, T, H, KVH, hd = 1, 8, 2, 1, 4
    q = jnp.cos(jnp.arange(B * T * H * hd, dtype=dtype)).reshape(
        B, T, H, hd) * 0.1
    k = jnp.sin(jnp.arange(B * T * KVH * hd, dtype=dtype)).reshape(
        B, T, KVH, hd) * 0.1
    v = k + 0.5
    fn = lambda q, k, v: flash_attention(q, k, v, block_q=4, block_kv=4,
                                         interpret=interpret)
    return fn, (q, k, v)


def _build_lru_scan(dtype, interpret=None):
    from .lru_scan import lru_scan
    B, T, W = 2, 8, 4
    a = jnp.full((B, T, W), 0.9, dtype)
    b = jnp.sin(jnp.arange(B * T * W, dtype=dtype)).reshape(B, T, W)
    h0 = jnp.zeros((B, W), dtype)
    fn = lambda a, b, h: lru_scan(a, b, h, chunk=4, interpret=interpret)
    return fn, (a, b, h0)


CONTRACTS: Dict[str, LoweringContract] = {c.name: c for c in [
    LoweringContract(
        name="rz_round", module="repro.kernels.rz_step",
        build=_build_rz_round, dynamic_gather=True,   # PWL binary search
        tol=(("float64", 1e-12), ("float32", 1e-4))),
    LoweringContract(
        name="lattice_round", module="repro.kernels.binomial_step",
        build=_build_lattice_round),
    LoweringContract(
        name="lattice_round_param", module="repro.kernels.binomial_step",
        build=_build_lattice_round_param),
    # the LM-side kernels accumulate in float32 by construction (flash
    # attention softmax stats, LRU scratch carry) — f32-only contracts
    LoweringContract(
        name="flash_attention", module="repro.kernels.flash_attention",
        build=_build_flash_attention, dtypes=("float32",),
        tol=(("float32", 2e-6),)),
    LoweringContract(
        name="lru_scan", module="repro.kernels.lru_scan",
        build=_build_lru_scan, dtypes=("float32",),
        tol=(("float32", 2e-6),)),
]}


# --------------------------------------------------------------------- #
# static analysis
# --------------------------------------------------------------------- #
def trace_kernel(contract: LoweringContract, dtype,
                 interpret: bool | None = True):
    """The kernel's closed jaxpr at ``dtype`` (default: interpret trace —
    identical structure to the compiled one, minus the backend lowering,
    so it is traceable on any platform)."""
    fn, example = contract.build(jnp.dtype(dtype), interpret)
    return jax.make_jaxpr(fn)(*example)


def jaxpr_summary(jaxpr) -> Tuple[set, set]:
    """``(primitive names, outvar dtypes)`` over the whole call tree."""
    prims: set = set()
    dtypes: set = set()
    _walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, prims, dtypes)
    return prims, dtypes


def _walk(jaxpr, prims: set, dtypes: set) -> None:
    is_leaf = lambda x: isinstance(x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))
    for eqn in jaxpr.eqns:
        prims.add(eqn.primitive.name)
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                dtypes.add(str(aval.dtype))
        for val in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(val, is_leaf=is_leaf):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    _walk(sub.jaxpr, prims, dtypes)
                elif isinstance(sub, jax.core.Jaxpr):
                    _walk(sub, prims, dtypes)


def check_static_contract(contract: LoweringContract, dtype) -> list:
    """All violations of ``contract`` in the kernel's trace at ``dtype``.

    Empty list = conforming.  Each violation is one human-readable
    string; the conformance test asserts the list is empty so a failure
    names every violation at once.
    """
    dtype = jnp.dtype(dtype)
    prims, seen = jaxpr_summary(trace_kernel(contract, dtype))
    bad = []
    forbidden = prims & FORBIDDEN_PRIMITIVES
    if forbidden:
        bad.append(f"forbidden primitives {sorted(forbidden)}")
    allowed = {str(dtype)} | ALLOWED_INT_DTYPES
    stray = seen - allowed
    if stray:
        bad.append(f"dtypes {sorted(stray)} outside policy "
                   f"{sorted(allowed)}")
    gathers = prims & GATHER_PRIMITIVES
    if gathers and not contract.dynamic_gather:
        bad.append(f"undeclared dynamic gathers {sorted(gathers)} "
                   "(set dynamic_gather=True if intended)")
    return bad


def run_kernel(contract: LoweringContract, dtype, *, interpret: bool):
    """Execute the example workload; returns flat numpy leaves (the
    interpret-vs-compiled differencing surface)."""
    import numpy as np
    fn, example = contract.build(jnp.dtype(dtype), interpret)
    out = jax.jit(fn)(*example)
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(out)]
