"""Pallas kernel: blocked Roux–Zastawniak PWL rounds (transaction costs).

This is the paper's *headline* workload — American option pricing under
proportional transaction costs (§3) — run through the §4 block/region
scheme as a Pallas kernel, the TC sibling of ``binomial_step.py``:

  * the node axis is tiled into blocks of ``block`` lanes; each lane
    carries one fixed-capacity SoA PWL record (``core/pwl.py``:
    ``xs, ys: (lanes, K)``, ``sl, sr: (lanes,)``, ``m: (lanes,)``);
  * one kernel invocation advances a block ``levels`` (the paper's L)
    levels toward the root entirely in VMEM — per level the full §3
    recursion ``w = max(z_up, z); v = cone(w / r); z = max/min(u, v)``
    (``core/rz.py::rz_level_step_lanes``), data-parallel over lanes;
  * the dependency window (paper's region B) is satisfied by mapping the
    *same* HBM arrays through two BlockSpecs — the block and its right
    neighbour — so each invocation sees ``2*block`` lanes and can take up
    to ``levels <= block`` steps before the stale tail reaches its owned
    lanes;
  * blocks are independent within a round (region-A property); rounds
    iterate on the host (``core/rz.py::rz_backward_pallas``) following the
    static schedule of ``core/partition.py::kernel_round_plan``, which
    also re-balances the lane extent as the tree narrows (§4.2's thread
    shedding).  A single-block round (``nblk == 1``) skips the halo
    operands entirely: the whole live level is the block.

Capacity overflow reporting is identical to the jnp path: the kernel's
second output is the per-block maximum of the raw (pre-truncation) knot
counts over *owned, live* lanes; the engine carries the running max and
the caller raises ``OverflowError`` if it exceeded K.  Halo lanes are
excluded — their values go stale within a round, and their owning block
reports the authoritative count.

The PWL level step is built from sorts/scatters the Mosaic TPU compiler
does not take today, so this kernel family targets **interpret mode**
(CPU-exact, float64, used by the parity tests and benchmarks); the no-TC
``binomial_step.py`` remains the compiled-TPU showcase.  The BlockSpec /
grid structure is the one a future Mosaic lowering would keep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import pwl as P
from ..core.payoff import param_payoff
from ..core.rz import rz_level_step_lanes

__all__ = ["rz_round", "RZ_SCALARS"]

# scalar-vector layout of the round kernel:
#   [lvl0, s0, sig_sqrt_dt, r, k, alpha, zeta, w1, w2, k1, k2]
# lvl0 is the base level B (levels B-1 .. B-levels are computed); the
# payoff tail is the 4-parameter family of core/payoff.py::param_payoff.
RZ_SCALARS = 11


def _rz_round_kernel(sc_ref, *refs, levels: int, block: int, seller: bool,
                     halo: bool):
    """Advance one block of PWL lanes ``levels`` levels toward the root."""
    ncomp = 5                                   # xs, ys, sl, sr, m
    lvl0, s0, sig, r, k = (sc_ref[j] for j in range(5))
    pay = param_payoff(*(sc_ref[5 + j] for j in range(6)))
    params = dict(s0=s0, k=k, sig_sqrt_dt=sig, r=r)

    if halo:
        cur, nxt = refs[:ncomp], refs[ncomp:2 * ncomp]
        z = P.PWL(*(jnp.concatenate([c[...], n[...]])
                    for c, n in zip(cur, nxt)))
        outs = refs[2 * ncomp:]
    else:
        z = P.PWL(*(c[...] for c in refs[:ncomp]))
        outs = refs[ncomp:]
    dtype = z.xs.dtype
    capacity = z.capacity
    lanes = z.sl.shape[0]
    idx0 = pl.program_id(0) * block
    owned = jnp.arange(lanes) < block

    def body(j, carry):
        z, pieces = carry
        lvl = lvl0 - (j + 1).astype(dtype)
        z, pc = rz_level_step_lanes(z, lvl, params, capacity=capacity,
                                    seller=seller, payoff=pay, dtype=dtype,
                                    idx_offset=idx0)
        pieces = jnp.maximum(pieces, jnp.max(jnp.where(owned, pc, 0)))
        return z, pieces

    z, pieces = jax.lax.fori_loop(0, levels, body,
                                  (z, jnp.zeros((), jnp.int32)))
    for ref, arr in zip(outs[:ncomp], z):
        ref[...] = arr[:block]
    outs[ncomp][...] = pieces[None]


def rz_round(z: P.PWL, scalars, *, levels: int, block: int,
             seller: bool, interpret: bool = True):
    """One round of ``levels`` TC level-steps over all node blocks.

    z: PWL with node axis of P lanes, P a multiple of ``block``; scalars:
    (RZ_SCALARS,) array (dtype of z.xs).  Multi-block rounds require
    ``levels <= block`` (halo staleness bound).  Returns ``(z_new,
    pieces)`` with ``pieces`` the scalar int32 max raw knot count over
    owned live lanes — the overflow signal the engines carry.
    """
    lanes = z.sl.shape[0]
    # loud ValueErrors, not asserts: these are user-reachable contracts and
    # a violation misprices silently (a short scalars vector clamp-indexes
    # inside the kernel; levels > block lets halo staleness reach owned
    # lanes) — they must survive python -O
    if lanes % block != 0:
        raise ValueError(f"lanes {lanes} not a multiple of block {block}")
    if scalars.shape != (RZ_SCALARS,):
        raise ValueError(f"scalars must have shape ({RZ_SCALARS},), "
                         f"got {scalars.shape}")
    nblk = lanes // block
    halo = nblk > 1
    if halo and levels > block:
        raise ValueError(f"multi-block round needs levels <= block "
                         f"(halo staleness bound), got levels={levels} "
                         f"> block={block}")
    K = z.capacity
    dtype = z.xs.dtype

    cur_specs = [
        pl.BlockSpec((block, K), lambda i: (i, 0)),          # xs
        pl.BlockSpec((block, K), lambda i: (i, 0)),          # ys
        pl.BlockSpec((block,), lambda i: (i,)),              # sl
        pl.BlockSpec((block,), lambda i: (i,)),              # sr
        pl.BlockSpec((block,), lambda i: (i,)),              # m
    ]
    nxt = lambda i: jnp.minimum(i + 1, nblk - 1)             # clamped halo
    nxt_specs = [
        pl.BlockSpec((block, K), lambda i: (nxt(i), 0)),
        pl.BlockSpec((block, K), lambda i: (nxt(i), 0)),
        pl.BlockSpec((block,), lambda i: (nxt(i),)),
        pl.BlockSpec((block,), lambda i: (nxt(i),)),
        pl.BlockSpec((block,), lambda i: (nxt(i),)),
    ]
    in_specs = [pl.BlockSpec(memory_space=pl.ANY)] + cur_specs
    operands = [scalars, *z]
    if halo:
        in_specs += nxt_specs
        operands += list(z)

    kernel = functools.partial(_rz_round_kernel, levels=levels, block=block,
                               seller=seller, halo=halo)
    out = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=in_specs,
        out_specs=[*cur_specs, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((lanes, K), dtype),         # xs
            jax.ShapeDtypeStruct((lanes, K), dtype),         # ys
            jax.ShapeDtypeStruct((lanes,), dtype),           # sl
            jax.ShapeDtypeStruct((lanes,), dtype),           # sr
            jax.ShapeDtypeStruct((lanes,), jnp.int32),       # m
            jax.ShapeDtypeStruct((nblk,), jnp.int32),        # pieces/block
        ],
        interpret=interpret,
    )(*operands)
    return P.PWL(*out[:5]), jnp.max(out[5])
