"""Pallas kernel: blocked Roux–Zastawniak PWL rounds (transaction costs).

This is the paper's *headline* workload — American option pricing under
proportional transaction costs (§3) — run through the §4 block/region
scheme as a Pallas kernel, the TC sibling of ``binomial_step.py``:

  * the node axis is tiled into blocks of ``block`` lanes; each lane
    carries one fixed-capacity SoA PWL record (``core/pwl.py``:
    ``xs, ys: (lanes, K)``, ``sl, sr: (lanes,)``, ``m: (lanes,)``);
  * one kernel invocation advances a block ``levels`` (the paper's L)
    levels toward the root entirely in VMEM — per level the full §3
    recursion ``w = max(z_up, z); v = cone(w / r); z = max/min(u, v)``
    (``core/rz.py::rz_level_step_lanes``), data-parallel over lanes;
  * the dependency window (paper's region B) is satisfied by mapping the
    *same* HBM arrays through two BlockSpecs — the block and its right
    neighbour — so each invocation sees ``2*block`` lanes and can take up
    to ``levels <= block`` steps before the stale tail reaches its owned
    lanes;
  * blocks are independent within a round (region-A property); rounds
    iterate on the host (``core/rz.py::rz_backward_pallas``) following the
    static schedule of ``core/partition.py::kernel_round_plan``, which
    also re-balances the lane extent as the tree narrows (§4.2's thread
    shedding).  A single-block round (``nblk == 1``) skips the halo
    operands entirely: the whole live level is the block.

Capacity overflow reporting is identical to the jnp path: the kernel's
second output is the per-block maximum of the raw (pre-truncation) knot
counts over *owned, live* lanes; the engine carries the running max and
the caller raises ``OverflowError`` if it exceeded K.  Halo lanes are
excluded — their values go stale within a round, and their owning block
reports the authoritative count.

The PWL level step is now **sort-free** (``core/pwl.py``'s merge-path
envelope algebra: binary-search rank computation + gathers — no
``sort``/``argsort`` primitives, jaxpr-asserted by
``tests/test_pwl_merge.py``), which removes the original blocker this
kernel family was quarantined to interpret mode for.  What remains
between it and a compiled Mosaic lowering is narrower and mechanical:
the per-lane dynamic gathers of the binary searches and the int32
knot-count bookkeeping — both now *declared* in the kernel's lowering
contract (``kernels/contracts.py``) and statically asserted against the
traced jaxpr by ``tests/test_lowering_contract.py``.  The execution
mode is platform policy (``core/platform.py``): ``interpret=None``
resolves to interpret on CPU (no compiled Pallas lowering there —
CPU-exact float64, used by the parity tests and benchmarks) and to a
real compiled lowering on GPU/TPU.  The BlockSpec / grid structure is
unchanged — it was designed to be kept once the sorts disappeared, and
they now have.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import pwl as P
from ..core.payoff import param_payoff
from ..core.platform import resolve_interpret
from ..core.rz import rz_level_step_lanes

__all__ = ["rz_round", "RZ_SCALARS"]

# scalar-vector layout of the round kernel:
#   [lvl0, s0, sig_sqrt_dt, r, k, alpha, zeta, w1, w2, k1, k2]
# lvl0 is the base level B (levels B-1 .. B-levels are computed); the
# payoff tail is the 4-parameter family of core/payoff.py::param_payoff.
RZ_SCALARS = 11


def _rz_round_kernel(sc_ref, *refs, levels: int, block: int,
                     sellers: tuple, halo: bool):
    """Advance one block of PWL lanes ``levels`` levels toward the root.

    The leading axis of every PWL component is the *side* axis (seller /
    buyer), walked fused in one pass: ``rz_level_step_lanes`` takes the
    per-side flags as a traced ``(S, 1)`` array, so max/min envelopes and
    the expense sign are per-lane selects, not separate kernels.  Lanes
    of different sides never mix — the level recursion couples lane l to
    l+1 within its own side row only.
    """
    ncomp = 5                                   # xs, ys, sl, sr, m
    lvl0, s0, sig, r, k = (sc_ref[j] for j in range(5))
    pay = param_payoff(*(sc_ref[5 + j] for j in range(6)))
    params = dict(s0=s0, k=k, sig_sqrt_dt=sig, r=r)

    if halo:
        cur, nxt = refs[:ncomp], refs[ncomp:2 * ncomp]
        z = P.PWL(*(jnp.concatenate([c[...], n[...]], axis=1)
                    for c, n in zip(cur, nxt)))
        outs = refs[2 * ncomp:]
    else:
        z = P.PWL(*(c[...] for c in refs[:ncomp]))
        outs = refs[ncomp:]
    dtype = z.xs.dtype
    capacity = z.capacity
    lanes = z.sl.shape[-1]
    idx0 = pl.program_id(0) * block
    owned = jax.lax.broadcasted_iota(jnp.int32, (lanes,), 0) < block
    # (S, 1) per-side seller flags, broadcast against the lane axis.
    # Built from an iota, not jnp.asarray(sellers): pallas kernels may
    # not capture array constants (scalar literals fold fine).
    S = z.sl.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (S, 1), 0)
    side = jnp.zeros((S, 1), bool)
    for j, s_j in enumerate(sellers):
        if s_j:
            side = side | (row == j)

    def body(j, carry):
        z, pieces = carry
        lvl = lvl0 - (j + 1).astype(dtype)
        z, pc = rz_level_step_lanes(z, lvl, params, capacity=capacity,
                                    seller=side, payoff=pay, dtype=dtype,
                                    idx_offset=idx0)
        pieces = jnp.maximum(pieces, jnp.max(jnp.where(owned, pc, 0)))
        return z, pieces

    # int32 loop bounds keep the carried counter int32 (python ints would
    # canonicalise to int64 under x64 — a compiled-path contract violation)
    z, pieces = jax.lax.fori_loop(jnp.int32(0), jnp.int32(levels), body,
                                  (z, jnp.zeros((), jnp.int32)))
    for ref, arr in zip(outs[:ncomp], z):
        ref[...] = arr[:, :block]
    outs[ncomp][...] = pieces[None]


def rz_round(z: P.PWL, scalars, *, levels: int, block: int,
             sellers: tuple = (True, False),
             interpret: bool | None = None):
    """One round of ``levels`` fused TC level-steps over all node blocks.

    z: PWL with a leading side axis of ``len(sellers)`` rows (the engine
    walks ``(seller, buyer)``; the white-box tests use a single side) and
    a node axis of P lanes, P a multiple of ``block``; scalars:
    (RZ_SCALARS,) array (dtype of z.xs).  Multi-block rounds require
    ``levels <= block`` (halo staleness bound).  Returns ``(z_new,
    pieces)`` with ``pieces`` the scalar int32 max raw knot count over
    owned live lanes of every side — the overflow signal the engines
    carry.

    ``interpret=None`` resolves from the platform policy
    (``core/platform.py``: interpret on CPU, compiled on GPU/TPU).
    """
    interpret = resolve_interpret(interpret)
    S, lanes = z.sl.shape
    # loud ValueErrors, not asserts: these are user-reachable contracts and
    # a violation misprices silently (a short scalars vector clamp-indexes
    # inside the kernel; levels > block lets halo staleness reach owned
    # lanes) — they must survive python -O
    if S != len(sellers):
        raise ValueError(f"side axis {S} != len(sellers) {len(sellers)}")
    if lanes % block != 0:
        raise ValueError(f"lanes {lanes} not a multiple of block {block}")
    if scalars.shape != (RZ_SCALARS,):
        raise ValueError(f"scalars must have shape ({RZ_SCALARS},), "
                         f"got {scalars.shape}")
    nblk = lanes // block
    halo = nblk > 1
    if halo and levels > block:
        raise ValueError(f"multi-block round needs levels <= block "
                         f"(halo staleness bound), got levels={levels} "
                         f"> block={block}")
    K = z.capacity
    dtype = z.xs.dtype

    cur_specs = [
        pl.BlockSpec((S, block, K), lambda i: (0, i, 0)),    # xs
        pl.BlockSpec((S, block, K), lambda i: (0, i, 0)),    # ys
        pl.BlockSpec((S, block), lambda i: (0, i)),          # sl
        pl.BlockSpec((S, block), lambda i: (0, i)),          # sr
        pl.BlockSpec((S, block), lambda i: (0, i)),          # m
    ]
    nxt = lambda i: jnp.minimum(i + 1, nblk - 1)             # clamped halo
    nxt_specs = [
        pl.BlockSpec((S, block, K), lambda i: (0, nxt(i), 0)),
        pl.BlockSpec((S, block, K), lambda i: (0, nxt(i), 0)),
        pl.BlockSpec((S, block), lambda i: (0, nxt(i))),
        pl.BlockSpec((S, block), lambda i: (0, nxt(i))),
        pl.BlockSpec((S, block), lambda i: (0, nxt(i))),
    ]
    in_specs = [pl.BlockSpec(memory_space=pl.ANY)] + cur_specs
    operands = [scalars, *z]
    if halo:
        in_specs += nxt_specs
        operands += list(z)

    kernel = functools.partial(_rz_round_kernel, levels=levels, block=block,
                               sellers=tuple(bool(s) for s in sellers),
                               halo=halo)
    out = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=in_specs,
        out_specs=[*cur_specs, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((S, lanes, K), dtype),      # xs
            jax.ShapeDtypeStruct((S, lanes, K), dtype),      # ys
            jax.ShapeDtypeStruct((S, lanes), dtype),         # sl
            jax.ShapeDtypeStruct((S, lanes), dtype),         # sr
            jax.ShapeDtypeStruct((S, lanes), jnp.int32),     # m
            jax.ShapeDtypeStruct((nblk,), jnp.int32),        # pieces/block
        ],
        interpret=interpret,
    )(*operands)
    return P.PWL(*out[:5]), jnp.max(out[5])
