"""Pallas TPU kernel: blocked binomial backward induction (no-TC lattice).

This is the paper's appendix workload (classic American option pricing,
Tables III / Fig. 11) as a TPU kernel, and the VMEM realisation of the
paper's §4 block scheme:

  * the node axis is tiled into blocks of ``block`` lanes;
  * each kernel invocation advances a block ``levels`` levels (the paper's
    L) entirely in VMEM — the inter-level dependency v[i] <- f(v[i],
    v[i+1]) never leaves the core;
  * the dependency window (paper's region B / our halo) is satisfied by
    mapping the *same* HBM array through two BlockSpecs — the block and
    its right neighbour — so each invocation sees 2*block lanes and can
    take up to ``levels <= block`` steps before the stale tail reaches
    its owned lanes;
  * grid = (padded_nodes / block,) — blocks are independent within a
    round (the paper's region-A property), rounds iterate on the host via
    ``lax.fori_loop`` in ops.py.

Numerics are float64 by default to match the sequential oracle digit for
digit (the paper reports its computed price 13.906 in doubles); float32
is supported for the TPU-throughput configuration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lattice_round", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 256


def _round_kernel(lvl_ref, cur_ref, nxt_ref, out_ref, *, levels: int,
                  block: int, kind: str):
    """Advance one block of nodes ``levels`` levels toward the root.

    lvl_ref: SMEM scalars [lvl0, p_up, inv_r, strike, s0, sig_sqrt_dt];
    cur_ref/nxt_ref: this block and its right neighbour (same array);
    out_ref: updated block.
    """
    i = pl.program_id(0)
    lvl0 = lvl_ref[0]
    p_up = lvl_ref[1]
    inv_r = lvl_ref[2]
    strike = lvl_ref[3]
    s0 = lvl_ref[4]
    sig = lvl_ref[5]

    buf = jnp.concatenate([cur_ref[...], nxt_ref[...]])        # (2*block,)
    dtype = buf.dtype
    idx = (i * block + jax.lax.broadcasted_iota(jnp.int32, (2 * block,), 0)
           ).astype(dtype)

    def payoff(lvl):
        s = s0 * jnp.exp((2.0 * idx - lvl) * sig)
        pay = strike - s if kind == "put" else s - strike
        return jnp.maximum(pay, jnp.zeros_like(pay))

    for j in range(levels):                                    # static unroll
        lvl = lvl0 - (j + 1)
        cont = (p_up * jnp.roll(buf, -1) + (1.0 - p_up) * buf) * inv_r
        new = jnp.maximum(payoff(lvl), cont)
        # final (short) round: levels below 0 are no-ops
        buf = jnp.where(lvl >= 0, new, buf)

    out_ref[...] = buf[:block]


def lattice_round(v, scalars, *, levels: int, block: int = DEFAULT_BLOCK,
                  kind: str = "put", interpret: bool = True):
    """One round of ``levels`` backward steps over all node blocks.

    v: (P,) node values, P a multiple of ``block``;  scalars: (6,) array
    [lvl0, p_up, inv_r, strike, s0, sig_sqrt_dt] (dtype of v).
    """
    P = v.shape[0]
    assert P % block == 0 and levels <= block
    nblk = P // block
    grid = (nblk,)
    kernel = functools.partial(_round_kernel, levels=levels, block=block,
                               kind=kind)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),     # scalars, loaded whole
            pl.BlockSpec((block,), lambda i: (i,)),
            # right-neighbour halo: same array, shifted one block (clamped
            # at the boundary; those lanes are beyond the live tree)
            pl.BlockSpec((block,), lambda i: (jnp.minimum(i + 1, nblk - 1),)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), v.dtype),
        interpret=interpret,
    )(scalars, v, v)
