"""Pallas TPU kernel: blocked binomial backward induction (no-TC lattice).

This is the paper's appendix workload (classic American option pricing,
Tables III / Fig. 11) as a TPU kernel, and the VMEM realisation of the
paper's §4 block scheme:

  * the node axis is tiled into blocks of ``block`` lanes;
  * each kernel invocation advances a block ``levels`` levels (the paper's
    L) entirely in VMEM — the inter-level dependency v[i] <- f(v[i],
    v[i+1]) never leaves the core;
  * the dependency window (paper's region B / our halo) is satisfied by
    mapping the *same* HBM array through two BlockSpecs — the block and
    its right neighbour — so each invocation sees 2*block lanes and can
    take up to ``levels <= block`` steps before the stale tail reaches
    its owned lanes;
  * grid = (padded_nodes / block,) — blocks are independent within a
    round (the paper's region-A property), rounds iterate on the host via
    ``lax.fori_loop`` in ops.py.

Numerics are float64 by default to match the sequential oracle digit for
digit (the paper reports its computed price 13.906 in doubles); float32
is supported for the TPU-throughput configuration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.platform import resolve_interpret

__all__ = ["lattice_round", "lattice_round_param", "DEFAULT_BLOCK",
           "PARAM_SCALARS"]

DEFAULT_BLOCK = 256

# scalar-vector layout of the payoff-parameterised kernel:
#   [lvl0, p_up, inv_r, s0, sig_sqrt_dt, alpha, zeta, w1, w2, k1, k2]
# intrinsic(s) = max(alpha*k1 + w1*(s-k1)^+ + w2*(s-k2)^+ + zeta*s, 0)
# (put: alpha=1, zeta=-1; call: alpha=-1, zeta=+1; bull spread: w1=1, w2=-1)
PARAM_SCALARS = 11


def _block_inputs(cur_ref, nxt_ref, block: int):
    """(buf, idx): this block + its right-neighbour halo and the global
    column index of each of the 2*block lanes."""
    i = pl.program_id(0)
    buf = jnp.concatenate([cur_ref[...], nxt_ref[...]])        # (2*block,)
    idx = (i * block + jax.lax.broadcasted_iota(jnp.int32, (2 * block,), 0)
           ).astype(buf.dtype)
    return buf, idx


def _backward_steps(buf, lvl0, p_up, inv_r, payoff, levels: int):
    """``levels`` backward induction steps on one lane buffer."""
    for j in range(levels):                                    # static unroll
        lvl = lvl0 - (j + 1)
        cont = (p_up * jnp.roll(buf, -1) + (1.0 - p_up) * buf) * inv_r
        new = jnp.maximum(payoff(lvl), cont)
        # final (short) round: levels below 0 are no-ops
        buf = jnp.where(lvl >= 0, new, buf)
    return buf


def _round_kernel(lvl_ref, cur_ref, nxt_ref, out_ref, *, levels: int,
                  block: int, kind: str):
    """Advance one block of nodes ``levels`` levels toward the root.

    lvl_ref: SMEM scalars [lvl0, p_up, inv_r, strike, s0, sig_sqrt_dt];
    cur_ref/nxt_ref: this block and its right neighbour (same array);
    out_ref: updated block.
    """
    lvl0, p_up, inv_r, strike, s0, sig = (lvl_ref[j] for j in range(6))
    buf, idx = _block_inputs(cur_ref, nxt_ref, block)

    def payoff(lvl):
        s = s0 * jnp.exp((2.0 * idx - lvl) * sig)
        pay = strike - s if kind == "put" else s - strike
        return jnp.maximum(pay, jnp.zeros_like(pay))

    buf = _backward_steps(buf, lvl0, p_up, inv_r, payoff, levels)
    out_ref[...] = buf[:block]


def _round_kernel_param(sc_ref, cur_ref, nxt_ref, out_ref, *, levels: int,
                        block: int):
    """Payoff-parameterised variant of :func:`_round_kernel`.

    The payoff family is data, not code: the intrinsic is the branchless
    4-parameter form documented at ``PARAM_SCALARS``, so one compiled
    kernel serves puts, calls and cash-settled spreads — the scenario-grid
    engine batches mixed payoffs through it with a single ``vmap``.
    """
    lvl0, p_up, inv_r, s0, sig = (sc_ref[j] for j in range(5))
    alpha, zeta, w1, w2, k1, k2 = (sc_ref[5 + j] for j in range(6))
    buf, idx = _block_inputs(cur_ref, nxt_ref, block)

    def payoff(lvl):
        s = s0 * jnp.exp((2.0 * idx - lvl) * sig)
        pay = (alpha * k1 + w1 * jnp.maximum(s - k1, 0.0)
               + w2 * jnp.maximum(s - k2, 0.0) + zeta * s)
        return jnp.maximum(pay, jnp.zeros_like(pay))

    buf = _backward_steps(buf, lvl0, p_up, inv_r, payoff, levels)
    out_ref[...] = buf[:block]


def _round_call(kernel, v, scalars, block: int, interpret: bool):
    """Shared pallas_call scaffolding: per-block grid, double BlockSpec
    (own block + right-neighbour halo over the same HBM array, clamped at
    the boundary where lanes are beyond the live tree)."""
    P = v.shape[0]
    nblk = P // block
    return pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),     # scalars, loaded whole
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (jnp.minimum(i + 1, nblk - 1),)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), v.dtype),
        interpret=interpret,
    )(scalars, v, v)


def lattice_round_param(v, scalars, *, levels: int,
                        block: int = DEFAULT_BLOCK,
                        interpret: bool | None = None):
    """One round of ``levels`` steps with the payoff passed as data.

    v: (P,) node values, P a multiple of ``block``; scalars: (11,) array
    with the ``PARAM_SCALARS`` layout (dtype of v).  ``interpret=None``
    resolves from the platform policy (``core/platform.py``).
    """
    interpret = resolve_interpret(interpret)
    assert v.shape[0] % block == 0 and levels <= block
    kernel = functools.partial(_round_kernel_param, levels=levels,
                               block=block)
    return _round_call(kernel, v, scalars, block, interpret)


def lattice_round(v, scalars, *, levels: int, block: int = DEFAULT_BLOCK,
                  kind: str = "put", interpret: bool | None = None):
    """One round of ``levels`` backward steps over all node blocks.

    v: (P,) node values, P a multiple of ``block``;  scalars: (6,) array
    [lvl0, p_up, inv_r, strike, s0, sig_sqrt_dt] (dtype of v).
    ``interpret=None`` resolves from the platform policy.
    """
    interpret = resolve_interpret(interpret)
    assert v.shape[0] % block == 0 and levels <= block
    kernel = functools.partial(_round_kernel, levels=levels, block=block,
                               kind=kind)
    return _round_call(kernel, v, scalars, block, interpret)
