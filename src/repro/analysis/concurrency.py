"""Concurrency lint over the serving stack.

Two passes, both pure AST (no imports of the checked code):

**blocking-in-async** — inside every ``async def`` body in ``serve/``,
flag calls that block the event loop: ``time.sleep``, synchronous
``Connection.recv``/``poll``, ``Lock.acquire``/``with self._lock``,
``subprocess`` waits, thread ``join``, executor ``shutdown(wait=True)``
and direct engine execution (``execute_chunk``/``price_chunk``/
``price_flat``/``price_grid``/``price_american`` — a jit dispatch is a
long synchronous call).  A call is exempt when it is ``await``-ed or
appears inside the arguments of an async wrapper
(``run_in_executor``, ``to_thread``, ``create_task``, ``gather``,
``wait_for``, …): routing the blocking work off the loop is exactly the
sanctioned pattern.

**lock-cycle** — extract every ``with self.<lock>`` region (plus
helpers annotated ``# locked: <lock>`` on their ``def`` line or named
``*_locked``, which are treated as running under that lock), resolve
``self.x()`` / ``super().x()`` / typed-attribute calls
(``self.metrics_.bump(...)`` → ``ServiceMetrics.bump``) transitively,
and build the *acquires-while-holding* graph whose nodes are
``(owning class, lock attr)`` — inherited locks unify to the base class
that creates them, so ``GatewayMetrics._lock`` *is*
``ServiceMetrics._lock``.  Any cycle (including a self-edge: these are
non-reentrant ``threading.Lock``s) is a potential deadlock and fails.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .engine import Finding, REPO_ROOT, SymbolMap, parse_module, rel_path

CHECKER = "concurrency"

#: ``module.attr`` calls that block the calling thread.
BLOCKING_MODULE_CALLS = {
    ("time", "sleep"),
    ("subprocess", "run"), ("subprocess", "call"),
    ("subprocess", "check_call"), ("subprocess", "check_output"),
    ("os", "waitpid"), ("os", "wait"),
}

#: Method tails that block on the objects serve/ passes around
#: (multiprocessing.Connection, threading.Lock/Thread/Process).
BLOCKING_METHOD_NAMES = {"acquire", "recv", "poll", "join"}

#: Direct engine execution — a jit dispatch is a long synchronous call.
ENGINE_CALL_NAMES = {"execute_chunk", "price_chunk", "price_flat",
                     "price_grid", "price_american"}

#: Wrappers whose call arguments are the sanctioned off-loop route.
ASYNC_WRAPPERS = {"run_in_executor", "to_thread", "create_task",
                  "ensure_future", "gather", "wait", "wait_for", "shield"}

_LOCK_FACTORY = {"Lock", "RLock", "Condition"}
_LOCKED_COMMENT = re.compile(r"#\s*locked:\s*(\w+)")


def _tail(fn) -> Optional[str]:
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _dotted(fn) -> Optional[Tuple[str, str]]:
    """``mod.attr`` for a ``Name.attr`` callee, else None."""
    if (isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name)):
        return (fn.value.id, fn.attr)
    return None


def _self_attr(expr) -> Optional[str]:
    """``self.<attr>`` → attr name."""
    if (isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"):
        return expr.attr
    return None


def _blocking_reason(call: ast.Call) -> Optional[str]:
    fn = call.func
    dotted = _dotted(fn)
    if dotted in BLOCKING_MODULE_CALLS:
        return f"blocking call {dotted[0]}.{dotted[1]}()"
    tail = _tail(fn)
    if tail in BLOCKING_METHOD_NAMES and isinstance(fn, ast.Attribute):
        return f"blocking .{tail}() (sync Connection/Lock/Thread API)"
    if tail in ENGINE_CALL_NAMES:
        return f"engine execution {tail}() (jit dispatch blocks the loop)"
    if tail == "shutdown" and isinstance(fn, ast.Attribute):
        for kw in call.keywords:
            if (kw.arg == "wait" and isinstance(kw.value, ast.Constant)
                    and kw.value.value is False):
                return None
        return "executor .shutdown() without wait=False joins worker threads"
    return None


def _exempt_calls(async_fn: ast.AsyncFunctionDef) -> Set[int]:
    """ids of Call nodes that are awaited or ride inside the arguments
    of an async wrapper call (the executor route)."""
    exempt: Set[int] = set()
    for node in ast.walk(async_fn):
        if isinstance(node, ast.Await):
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Call):
                    exempt.add(id(sub))
        if (isinstance(node, ast.Call)
                and _tail(node.func) in ASYNC_WRAPPERS):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call):
                        exempt.add(id(sub))
    return exempt


def _lock_like(attr: str, known_locks: Set[str]) -> bool:
    return attr in known_locks or attr.endswith("_lock") or attr == "_lock"


def check_blocking_in_async(path, tree=None,
                            known_locks: Optional[Set[str]] = None,
                            ) -> List[Finding]:
    tree = tree if tree is not None else parse_module(path)
    symbols = SymbolMap(tree)
    known_locks = known_locks or set()
    findings = []
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, ast.AsyncFunctionDef)]:
        exempt = _exempt_calls(fn)
        # nested sync defs are deferred bodies (executor / callback
        # targets), not code the event loop runs inline — skip them
        nested = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.FunctionDef):
                for sub in ast.walk(node):
                    nested.add(id(sub))
        for node in ast.walk(fn):
            if id(node) in nested:
                continue
            if isinstance(node, ast.Call) and id(node) not in exempt:
                reason = _blocking_reason(node)
                if reason:
                    findings.append(Finding(
                        checker=CHECKER, rule="blocking-in-async",
                        file=rel_path(path), line=node.lineno,
                        symbol=symbols.at(node.lineno),
                        message=f"{reason} inside async def {fn.name}; "
                                "route through run_in_executor/to_thread"))
            if isinstance(node, ast.With):
                for item in node.items:
                    attr = _self_attr(item.context_expr)
                    if attr and _lock_like(attr, known_locks):
                        findings.append(Finding(
                            checker=CHECKER, rule="blocking-in-async",
                            file=rel_path(path), line=node.lineno,
                            symbol=symbols.at(node.lineno),
                            message=f"'with self.{attr}' (threading lock) "
                                    f"inside async def {fn.name} can stall "
                                    "the event loop"))
    return findings


# --------------------------------------------------------------------- #
# lock-order extraction
# --------------------------------------------------------------------- #
class _ClassInfo:
    def __init__(self, node: ast.ClassDef, file: str):
        self.node = node
        self.file = file
        self.name = node.name
        self.bases = [b.id for b in node.bases if isinstance(b, ast.Name)]
        self.lock_attrs: Set[str] = set()
        self.methods: Dict[str, ast.AST] = {}
        #: method name -> lock attr it is documented to run under
        self.locked_helpers: Dict[str, str] = {}


def _collect_classes(paths, sources) -> Dict[str, _ClassInfo]:
    classes: Dict[str, _ClassInfo] = {}
    for path, (tree, text) in zip(paths, sources):
        lines = text.splitlines()
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = _ClassInfo(node, rel_path(path))
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    info.methods[item.name] = item
                    defline = lines[item.lineno - 1]
                    m = _LOCKED_COMMENT.search(defline)
                    if m:
                        info.locked_helpers[item.name] = m.group(1)
                    elif item.name.endswith("_locked"):
                        info.locked_helpers[item.name] = "_lock"
                # GUARDED_BY = {"attr": "_lock", ...} class registry
                if (isinstance(item, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "GUARDED_BY"
                                for t in item.targets)
                        and isinstance(item.value, ast.Dict)):
                    for v in item.value.values:
                        if (isinstance(v, ast.Constant)
                                and isinstance(v.value, str)
                                and v.value != "owner"):
                            info.lock_attrs.add(v.value)
            # any `self.X = threading.Lock()`-style assignment
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign) and isinstance(
                        sub.value, ast.Call):
                    tail = _tail(sub.value.func)
                    if tail in _LOCK_FACTORY:
                        for t in sub.targets:
                            attr = _self_attr(t)
                            if attr:
                                info.lock_attrs.add(attr)
            classes[node.name] = classes.get(node.name, info)
    return classes


def _lock_owner(classes: Dict[str, _ClassInfo], cls: str,
                attr: str) -> str:
    """Basemost analyzed class that creates ``attr`` — inherited locks
    unify to their defining class."""
    info = classes.get(cls)
    if info is None:
        return cls
    for base in info.bases:
        if base in classes:
            owner = _lock_owner(classes, base, attr)
            if owner in classes and attr in classes[owner].lock_attrs:
                return owner
    return cls


def _all_lock_attrs(classes: Dict[str, _ClassInfo], cls: str) -> Set[str]:
    out: Set[str] = set()
    info = classes.get(cls)
    if info is None:
        return out
    out |= info.lock_attrs
    for base in info.bases:
        out |= _all_lock_attrs(classes, base)
    return out


def _resolve_callee(classes, cls: str, call: ast.Call,
                    attr_types: Dict[str, str]) -> Optional[Tuple[str, str]]:
    """(class, method) for self./super()./typed-attribute calls."""
    fn = call.func
    if not isinstance(fn, ast.Attribute):
        return None
    base = fn.value
    # self.m()
    if isinstance(base, ast.Name) and base.id == "self":
        target = cls
        while target in classes:
            if fn.attr in classes[target].methods:
                return (target, fn.attr)
            bases = classes[target].bases
            target = bases[0] if bases and bases[0] in classes else None
            if target is None:
                break
        return None
    # super().m()
    if (isinstance(base, ast.Call) and _tail(base.func) == "super"):
        info = classes.get(cls)
        if info:
            for b in info.bases:
                target = b
                while target in classes:
                    if fn.attr in classes[target].methods:
                        return (target, fn.attr)
                    bs = classes[target].bases
                    target = bs[0] if bs and bs[0] in classes else None
        return None
    # self.<typed attr>.m()
    attr = _self_attr(base)
    if attr and attr in attr_types and attr_types[attr] in classes:
        target = attr_types[attr]
        while target in classes:
            if fn.attr in classes[target].methods:
                return (target, fn.attr)
            bs = classes[target].bases
            target = bs[0] if bs and bs[0] in classes else None
        return None
    return None


def _infer_attr_types(classes: Dict[str, _ClassInfo]) -> Dict[str, str]:
    """``self.x = KnownClass(...)`` assignments → {attr: class}."""
    out: Dict[str, str] = {}
    for info in classes.values():
        for sub in ast.walk(info.node):
            if (isinstance(sub, ast.Assign)
                    and isinstance(sub.value, ast.Call)):
                tail = _tail(sub.value.func)
                if tail in classes:
                    for t in sub.targets:
                        attr = _self_attr(t)
                        if attr:
                            out[attr] = tail
    return out


LockNode = Tuple[str, str]       # (owning class, lock attr)


def build_lock_graph(paths) -> Tuple[Dict[LockNode, Set[LockNode]],
                                     Dict[Tuple[LockNode, LockNode],
                                          Tuple[str, int, str]]]:
    """Acquires-while-holding graph over the given files, plus one
    witness ``(file, line, symbol)`` per edge."""
    sources = [(parse_module(p), pathlib.Path(p).read_text())
               for p in paths]
    classes = _collect_classes(paths, sources)
    attr_types = _infer_attr_types(classes)

    # (class, method) -> [(held locks at call, callee key, line)]
    held_calls: Dict[Tuple[str, str],
                     List[Tuple[FrozenSet[LockNode], Tuple[str, str], int]]] = {}
    # (class, method) -> [(held locks, acquired lock, line)]
    held_acquires: Dict[Tuple[str, str],
                        List[Tuple[FrozenSet[LockNode], LockNode, int]]] = {}

    def _walk_with_only(cls, mname, body, held):
        """Only With statements change the held set below the top level
        — find them (calls were already collected by ast.walk)."""
        key = (cls, mname)
        for node in body:
            if isinstance(node, ast.With):
                new_held = set(held)
                for item in node.items:
                    attr = _self_attr(item.context_expr)
                    if attr and attr in _all_lock_attrs(classes, cls):
                        lock = (_lock_owner(classes, cls, attr), attr)
                        held_acquires.setdefault(key, []).append(
                            (frozenset(held), lock, node.lineno))
                        new_held.add(lock)
                # re-collect the calls under the *extended* held set
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call):
                        callee = _resolve_callee(classes, cls, sub,
                                                 attr_types)
                        if callee:
                            held_calls.setdefault(key, []).append(
                                (frozenset(new_held), callee, sub.lineno))
                _walk_with_only(cls, mname, node.body, frozenset(new_held))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            else:
                for field in ("body", "orelse", "finalbody"):
                    sub_body = getattr(node, field, None)
                    if sub_body:
                        _walk_with_only(cls, mname, sub_body, held)
                for h in getattr(node, "handlers", []) or []:
                    _walk_with_only(cls, mname, h.body, held)

    for cname, info in classes.items():
        for mname, mnode in info.methods.items():
            base_held: Set[LockNode] = set()
            if mname in info.locked_helpers:
                lattr = info.locked_helpers[mname]
                base_held.add((_lock_owner(classes, cname, lattr), lattr))
            held0 = frozenset(base_held)
            key = (cname, mname)
            held_calls.setdefault(key, [])
            held_acquires.setdefault(key, [])
            # top-level sweep: collect every call at held0, then refine
            # the ones under With blocks
            for sub in ast.walk(mnode):
                if isinstance(sub, ast.Call):
                    callee = _resolve_callee(classes, cname, sub, attr_types)
                    if callee:
                        held_calls[key].append((held0, callee, sub.lineno))
            _walk_with_only(cname, mname, mnode.body, held0)

    # pass 2: fixpoint — locks each method may acquire (direct + callees)
    acquires: Dict[Tuple[str, str], Set[LockNode]] = {
        k: {lock for (_h, lock, _l) in v}
        for k, v in held_acquires.items()}
    changed = True
    while changed:
        changed = False
        for key, clist in held_calls.items():
            for (_held, callee, _line) in clist:
                extra = acquires.get(callee, set()) - acquires.setdefault(
                    key, set())
                if extra:
                    acquires[key] |= extra
                    changed = True

    # pass 3: edges lockA -> lockB with a witness site
    graph: Dict[LockNode, Set[LockNode]] = {}
    witness: Dict[Tuple[LockNode, LockNode], Tuple[str, int, str]] = {}

    def add_edge(a: LockNode, b: LockNode, cls: str, mname: str, line: int):
        graph.setdefault(a, set()).add(b)
        graph.setdefault(b, set())
        file = classes[cls].file if cls in classes else "?"
        witness.setdefault((a, b), (file, line, f"{cls}.{mname}"))

    for (cls, mname), alist in held_acquires.items():
        for (held, lock, line) in alist:
            for h in held:
                add_edge(h, lock, cls, mname, line)
    for (cls, mname), clist in held_calls.items():
        for (held, callee, line) in clist:
            if not held:
                continue
            for b in acquires.get(callee, set()):
                for h in held:
                    add_edge(h, b, cls, mname, line)
    return graph, witness


def find_lock_cycles(graph: Dict[LockNode, Set[LockNode]]
                     ) -> List[List[LockNode]]:
    cycles: List[List[LockNode]] = []
    seen_cycles = set()
    for start in graph:
        stack = [(start, [start])]
        while stack:
            node, path = stack.pop()
            for nxt in graph.get(node, ()):
                if nxt == start:
                    canon = tuple(sorted(path))
                    if canon not in seen_cycles:
                        seen_cycles.add(canon)
                        cycles.append(path + [start])
                elif nxt not in path:
                    stack.append((nxt, path + [nxt]))
    return cycles


SERVE_FILES = ("core.py", "gateway.py", "procpool.py", "replica.py",
               "scheduler.py", "streaming.py")


def serve_paths(serve_root=None) -> List[pathlib.Path]:
    root = (pathlib.Path(serve_root) if serve_root
            else REPO_ROOT / "src" / "repro" / "serve")
    return [root / f for f in SERVE_FILES if (root / f).exists()]


def check_files(paths) -> List[Finding]:
    findings = []
    sources = [(parse_module(p), pathlib.Path(p).read_text())
               for p in paths]
    classes = _collect_classes(paths, sources)
    known_locks: Set[str] = set()
    for info in classes.values():
        known_locks |= info.lock_attrs
    for p, (tree, _text) in zip(paths, sources):
        findings += check_blocking_in_async(p, tree, known_locks)
    graph, witness = build_lock_graph(paths)
    for cycle in find_lock_cycles(graph):
        a, b = cycle[0], cycle[1]
        file, line, sym = witness.get((a, b), ("?", 1, "?"))
        pretty = " -> ".join(f"{c}.{l}" for c, l in cycle)
        findings.append(Finding(
            checker=CHECKER, rule="lock-cycle",
            file=file, line=line, symbol=sym,
            message=f"lock acquisition cycle {pretty} (witness edge "
                    f"in {sym})"))
    return findings


def check_repo(serve_root=None) -> List[Finding]:
    return check_files(serve_paths(serve_root))
