"""Shared machinery for the repo's static analyzers.

Every checker in ``repro.analysis`` produces :class:`Finding` records —
one defect, anchored at ``file:line`` with a stable ``symbol`` — and the
CLI (``tools/analyze.py``) subtracts the checked-in waivers
(``tools/analysis_waivers.toml``) before deciding the exit code.  The
waiver schema is deliberately strict: every entry must carry a
non-empty ``reason`` string (a waiver without a written justification is
a config error, not a pass), and waivers that match nothing are reported
as *stale* so they cannot outlive the code they excused.

This module owns no policy — just findings, waivers, and the AST
helpers (module parsing, line→qualified-symbol maps) the individual
checkers share.  It imports neither jax nor the serving stack, so the
purely syntactic checkers stay runnable in a bare interpreter.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

try:                                    # 3.11+
    import tomllib as _toml
except ImportError:                     # the pinned 3.10 container
    import tomli as _toml               # vendored by pytest's dep set

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]

__all__ = ["Finding", "Waiver", "REPO_ROOT", "load_waivers",
           "apply_waivers", "parse_module", "rel_path", "SymbolMap",
           "iter_py_files"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect: ``checker/rule`` at ``file:line``, anchored to a
    stable ``symbol`` (``Class.attr``, ``Class.method`` or a function
    name) so waivers survive unrelated line churn."""
    checker: str
    rule: str
    file: str          # repo-relative posix path (or a synthetic name)
    line: int
    symbol: str
    message: str

    @property
    def location(self) -> str:
        return f"{self.file}:{self.line}"

    def format(self) -> str:
        return (f"{self.location} [{self.checker}/{self.rule}] "
                f"{self.symbol}: {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Waiver:
    """One checked-in exception.  ``file``/``symbol``/``rule`` are
    fnmatch patterns matched against a finding; ``reason`` is required
    and must be non-empty — the reviewable justification."""
    checker: str
    file: str
    symbol: str
    reason: str
    rule: str = "*"

    def matches(self, f: Finding) -> bool:
        return (self.checker == f.checker
                and fnmatch.fnmatchcase(f.file, self.file)
                and fnmatch.fnmatchcase(f.symbol, self.symbol)
                and fnmatch.fnmatchcase(f.rule, self.rule))


def load_waivers(path) -> List[Waiver]:
    """Parse ``analysis_waivers.toml``; raises ``ValueError`` on a
    malformed entry (missing keys, empty reason) so a bad waiver can
    never silently suppress findings."""
    path = pathlib.Path(path)
    if not path.exists():
        return []
    data = _toml.loads(path.read_text())
    waivers = []
    for i, entry in enumerate(data.get("waiver", [])):
        missing = [k for k in ("checker", "file", "symbol", "reason")
                   if k not in entry]
        if missing:
            raise ValueError(f"waiver #{i} in {path.name} is missing "
                             f"required keys {missing}: {entry!r}")
        if not str(entry["reason"]).strip():
            raise ValueError(f"waiver #{i} in {path.name} "
                             f"({entry['checker']}/{entry['symbol']}) has "
                             "an empty reason — every waiver must say why")
        waivers.append(Waiver(checker=str(entry["checker"]),
                              file=str(entry["file"]),
                              symbol=str(entry["symbol"]),
                              reason=str(entry["reason"]),
                              rule=str(entry.get("rule", "*"))))
    return waivers


def apply_waivers(findings: Sequence[Finding],
                  waivers: Sequence[Waiver],
                  ) -> Tuple[List[Finding],
                             List[Tuple[Finding, Waiver]],
                             List[Waiver]]:
    """Split findings into ``(unwaived, waived-with-their-waiver,
    stale-waivers-that-matched-nothing)``."""
    unwaived: List[Finding] = []
    waived: List[Tuple[Finding, Waiver]] = []
    used = set()
    for f in findings:
        hit = next((w for w in waivers if w.matches(f)), None)
        if hit is None:
            unwaived.append(f)
        else:
            waived.append((f, hit))
            used.add(id(hit))
    stale = [w for w in waivers if id(w) not in used]
    return unwaived, waived, stale


# --------------------------------------------------------------------- #
# AST helpers
# --------------------------------------------------------------------- #
def rel_path(path) -> str:
    """Repo-relative posix path (absolute paths outside the repo — the
    synthetic negative-control modules in tmp dirs — stay absolute)."""
    p = pathlib.Path(path).resolve()
    try:
        return p.relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return p.as_posix()


def parse_module(path) -> ast.Module:
    return ast.parse(pathlib.Path(path).read_text(),
                     filename=str(path))


def iter_py_files(root) -> Iterable[pathlib.Path]:
    root = pathlib.Path(root)
    if root.is_file():
        yield root
        return
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" not in p.parts:
            yield p


class SymbolMap:
    """Line → innermost qualified symbol (``Class.method``) for one
    module — what anchors a finding to something stabler than a line."""

    def __init__(self, tree: ast.Module):
        self._spans: List[Tuple[int, int, str]] = []
        self._walk(tree.body, ())

    def _walk(self, body, stack: tuple) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                qual = stack + (node.name,)
                self._spans.append((node.lineno, node.end_lineno,
                                    ".".join(qual)))
                self._walk(node.body, qual)

    def at(self, line: int) -> str:
        """The innermost enclosing def/class qualname ('<module>' at
        top level)."""
        best: Optional[Tuple[int, str]] = None
        for lo, hi, qual in self._spans:
            if lo <= line <= hi and (best is None or lo > best[0]):
                best = (lo, qual)
        return best[1] if best else "<module>"


def class_defs(tree: ast.Module) -> Dict[str, ast.ClassDef]:
    """Top-level (and one-level nested) class definitions by name."""
    out: Dict[str, ast.ClassDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            out[node.name] = node
    return out
