"""Compile-key / bucket-key completeness auditor (the PR 7 bug class).

A field is **program** when changing it changes the traced jaxpr, the
padded shapes, or which compiled executable runs — those fields MUST be
folded into :meth:`SchedulerCore.chunk_compile_key` (and, where they
decide queue identity, into :meth:`SchedulerCore.bucket_key`).  A field
is **data** when it only changes array *values* inside one compiled
program.  Every field of :class:`ChunkSpec` and
:class:`~repro.configs.pricing.ExecutionConfig` must be classified here
— an unclassified field fails the audit, so adding a knob without
deciding its key-ness is impossible.

Three passes:

* **role audit** (static) — the registries below must match
  ``dataclasses.fields`` exactly in both directions, and every
  ``ExecutionConfig`` program field must have a ``ChunkSpec``
  counterpart (an execution knob the chunk cannot carry is silently
  dropped at the serving boundary — the basis/degree/antithetic bug).
* **key probes** (functional) — for every program field, a baseline
  chunk and a single-field variant must produce *distinct* compile
  keys through ``key_fn`` (injectable; the negative-control tests pass
  a key function with the field deliberately dropped and must see the
  finding).
* **bucket probes** (functional) — scenario pairs that must live in
  different buckets (American vs Bermudan frictionless — exactly PR 7's
  collision — TC vs no-TC, different depths/MC shapes) and pairs that
  must coalesce (strike/payoff are data).  ``bucket_fn`` is injectable
  the same way; ``tests/test_analysis.py`` reverts the PR 7 fix
  in-test and shows the auditor catches it.

The differential side — "keys must differ whenever the jaxprs differ"
— is the ``analysis``-marked fuzz test in ``tests/test_analysis.py``,
which traces the lsmc row program under each static variation and
asserts jaxpr inequality implies key inequality.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional

from .engine import Finding, REPO_ROOT, parse_module

CHECKER = "compile-key"

#: ChunkSpec field -> role.  "program": changes the compiled program or
#: bucket identity → must be in the compile key.  "data": array values
#: only.  "derived": computed from other fields (bucket = f(n_steps,
#: engine, n_assets, exercise_steps) — audited via the bucket probes).
CHUNK_FIELD_ROLES: Dict[str, str] = {
    "n_steps": "program",        # tree depth is shape-static
    "engine": "program",         # notc/rz/lsmc are different programs
    "capacity": "program",       # PWL knot budget is a shape parameter
    "backend": "program",        # jnp vs pallas lowering
    "padded": "program",         # batch shape
    "devices": "program",        # mesh width changes the partitioning
    "shard_plan": "program",     # (n_shards, lanes) shape the program
    "n_assets": "program",       # lsmc path-state width
    "exercise_steps": "program",  # Bermudan schedule is static control flow
    "n_paths": "program",        # lsmc path-count shape
    "interpret": "program",      # interpret vs compiled executables
    "basis": "program",          # lsmc regression design matrix shape/op
    "degree": "program",         # ... and its column count
    "antithetic": "program",     # pairing halves the driver shape
    "requests": "data",          # which contracts ride along
    "cols": "data",              # scenario columns are payoff-as-data
    "mc_seed": "data",           # PRNG key values, same program
    "bucket": "derived",
}

#: ExecutionConfig field -> role.  "local-policy" fields resolve on the
#: executing host and must NOT cross the wire (platform identity is the
#: worker's business — a chunk pinned to the scheduler's platform would
#: break heterogeneous pools).
EXECUTION_FIELD_ROLES: Dict[str, str] = {
    "engine": "program",
    "backend": "program",
    "interpret": "program",
    "devices": "program",
    "n_paths": "program",
    "basis": "program",
    "degree": "program",
    "antithetic": "program",
    "mc_seed": "data",
    "platform": "local-policy",
}


def _field_lines(path, class_name: str) -> Dict[str, int]:
    tree = parse_module(path)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            out = {}
            for item in node.body:
                if (isinstance(item, ast.AnnAssign)
                        and isinstance(item.target, ast.Name)):
                    out[item.target.id] = item.lineno
            return out
    return {}


def _core_path():
    return REPO_ROOT / "src" / "repro" / "serve" / "core.py"


def _pricing_path():
    return REPO_ROOT / "src" / "repro" / "configs" / "pricing.py"


def check_field_roles() -> List[Finding]:
    """Registries vs ``dataclasses.fields`` in both directions, and the
    ExecutionConfig→ChunkSpec carry-through."""
    from repro.configs.pricing import ExecutionConfig
    from repro.serve.core import ChunkSpec
    findings: List[Finding] = []
    for cls, roles, path in ((ChunkSpec, CHUNK_FIELD_ROLES, _core_path()),
                             (ExecutionConfig, EXECUTION_FIELD_ROLES,
                              _pricing_path())):
        lines = _field_lines(path, cls.__name__)
        actual = {f.name for f in dataclasses.fields(cls)}
        for name in sorted(actual - set(roles)):
            findings.append(Finding(
                checker=CHECKER, rule="unclassified-field",
                file=str(path.relative_to(REPO_ROOT).as_posix()),
                line=lines.get(name, 1),
                symbol=f"{cls.__name__}.{name}",
                message=f"{cls.__name__}.{name} has no program/data role "
                        "in repro.analysis.compile_key — decide whether it "
                        "changes the compiled program and register it"))
        for name in sorted(set(roles) - actual):
            findings.append(Finding(
                checker=CHECKER, rule="stale-role",
                file="src/repro/analysis/compile_key.py", line=1,
                symbol=f"{cls.__name__}.{name}",
                message=f"role registry names {cls.__name__}.{name} but the "
                        "dataclass has no such field"))
    chunk_fields = {f.name for f in dataclasses.fields(ChunkSpec)}
    exec_lines = _field_lines(_pricing_path(), "ExecutionConfig")
    for name, role in sorted(EXECUTION_FIELD_ROLES.items()):
        if role == "program" and name != "engine" and name not in chunk_fields:
            findings.append(Finding(
                checker=CHECKER, rule="missing-chunk-field",
                file=str(_pricing_path().relative_to(REPO_ROOT).as_posix()),
                line=exec_lines.get(name, 1),
                symbol=f"ExecutionConfig.{name}",
                message=f"program-role execution knob '{name}' has no "
                        "ChunkSpec field — the serving layer drops it at "
                        "the chunk boundary"))
    return findings


# --------------------------------------------------------------------- #
# functional probes
# --------------------------------------------------------------------- #
def _baseline_chunks():
    from repro.core.partition import ShardPlan
    from repro.serve.core import ChunkSpec
    lattice = ChunkSpec(
        bucket=(8, "rz"), requests=[], n_steps=8, engine="rz",
        capacity=16, backend="jnp", padded=4,
        cols=((100.0,), (0.2,), (0.1,), (0.25,), (0.01,), ("put",),
              (100.0,), (110.0,)),
        interpret=True)
    lsmc = ChunkSpec(
        bucket=(8, "lsmc", 2, (4, 8)), requests=[], n_steps=8,
        engine="lsmc", capacity=16, backend="jnp", padded=4,
        cols=((100.0,), (0.2,), (0.1,), (0.25,), (0.0,), ("put",),
              (100.0,), (110.0,)),
        n_assets=2, exercise_steps=(4, 8), n_paths=512, mc_seed=0,
        interpret=True)
    plan = ShardPlan(n_shards=2, shards=((0, 2), (2, 4)),
                     work=(1.0, 1.0), lanes=2, n_rows=4)
    #: program field -> (baseline chunk, variant value)
    variants = {
        "n_steps": (lattice, 10),
        "engine": (lattice, "notc"),
        "capacity": (lattice, 32),
        "backend": (lattice, "pallas"),
        "padded": (lattice, 8),
        "devices": (lattice, 2),
        "shard_plan": (lattice, plan),
        "interpret": (lattice, False),
        "n_assets": (lsmc, 3),
        "exercise_steps": (lsmc, (2, 4, 8)),
        "n_paths": (lsmc, 1024),
        "basis": (lsmc, "laguerre"),
        "degree": (lsmc, 4),
        "antithetic": (lsmc, False),
    }
    return variants


def check_key_probes(key_fn: Optional[Callable] = None) -> List[Finding]:
    """Every program-role ChunkSpec field must perturb the compile key.

    ``key_fn(chunk) -> hashable`` defaults to the scheduler's real
    :meth:`SchedulerCore.chunk_compile_key`; negative-control tests
    inject a key function with a field dropped."""
    from repro.serve.core import SchedulerCore
    if key_fn is None:
        key_fn = SchedulerCore.chunk_compile_key
    lines = _field_lines(_core_path(), "ChunkSpec")
    rel = str(_core_path().relative_to(REPO_ROOT).as_posix())
    findings: List[Finding] = []
    for field, (base, variant) in sorted(_baseline_chunks().items()):
        if CHUNK_FIELD_ROLES.get(field) != "program":
            continue
        changed = dataclasses.replace(base, **{field: variant})
        if key_fn(base) == key_fn(changed):
            findings.append(Finding(
                checker=CHECKER, rule="key-omits-field",
                file=rel, line=lines.get(field, 1),
                symbol=f"ChunkSpec.{field}",
                message=f"program field '{field}' does not perturb the "
                        f"compile key ({field}={getattr(base, field)!r} vs "
                        f"{variant!r} keyed identically) — two different "
                        "compiled programs would share one key"))
    return findings


def _scenario(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
              cost_rate=0.0, payoff="put", strike=100.0, strike2=110.0,
              n_steps=8, n_assets=1, ex=None) -> tuple:
    return (s0, sigma, rate, maturity, cost_rate, payoff, strike,
            strike2, n_steps, n_assets, ex)


#: (label, key_a, key_b) pairs that MUST bucket differently — the first
#: is PR 7's collision: a frictionless Bermudan must not coalesce into
#: the frictionless-American notc bucket (different engines, different
#: programs, and the Bermudan's schedule is static control flow).
DISTINCT_BUCKET_PAIRS = (
    ("american-vs-bermudan-frictionless",
     _scenario(cost_rate=0.0, ex=None),
     _scenario(cost_rate=0.0, ex=(4, 8))),
    ("tc-vs-no-tc",
     _scenario(cost_rate=0.0), _scenario(cost_rate=0.01)),
    ("tree-depth",
     _scenario(n_steps=8), _scenario(n_steps=16)),
    ("lsmc-n-assets",
     _scenario(n_assets=1, ex=(4, 8)), _scenario(n_assets=2, ex=(4, 8))),
    ("lsmc-schedule",
     _scenario(ex=(4, 8)), _scenario(ex=(2, 4, 8))),
)

#: Pairs that MUST coalesce (payoff family and strike are data).
COALESCE_BUCKET_PAIRS = (
    ("strike-is-data",
     _scenario(strike=100.0), _scenario(strike=95.0)),
    ("payoff-is-data",
     _scenario(payoff="put"), _scenario(payoff="call")),
)


def check_bucket_probes(bucket_fn: Optional[Callable] = None
                        ) -> List[Finding]:
    """Scenario pairs route to the right buckets.  ``bucket_fn(key) ->
    bucket`` defaults to the scheduler's real :meth:`bucket_key`."""
    from repro.serve.core import SchedulerCore
    if bucket_fn is None:
        bucket_fn = SchedulerCore.bucket_key
    rel = str(_core_path().relative_to(REPO_ROOT).as_posix())
    line = _bucket_key_line()
    findings: List[Finding] = []
    for label, a, b in DISTINCT_BUCKET_PAIRS:
        if bucket_fn(a) == bucket_fn(b):
            findings.append(Finding(
                checker=CHECKER, rule="bucket-collision",
                file=rel, line=line, symbol="SchedulerCore.bucket_key",
                message=f"scenarios that need different compiled programs "
                        f"share bucket {bucket_fn(a)!r} ({label})"))
    for label, a, b in COALESCE_BUCKET_PAIRS:
        if bucket_fn(a) != bucket_fn(b):
            findings.append(Finding(
                checker=CHECKER, rule="bucket-split",
                file=rel, line=line, symbol="SchedulerCore.bucket_key",
                message=f"data-only scenario difference splits buckets "
                        f"({label}: {bucket_fn(a)!r} vs {bucket_fn(b)!r}) "
                        "— coalescing regression"))
    return findings


def _bucket_key_line() -> int:
    tree = parse_module(_core_path())
    for node in ast.walk(tree):
        if (isinstance(node, ast.FunctionDef)
                and node.name == "bucket_key"):
            return node.lineno
    return 1


def check_repo() -> List[Finding]:
    return (check_field_roles() + check_key_probes()
            + check_bucket_probes())
