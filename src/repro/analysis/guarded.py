"""Guarded-by checker: declared lock discipline for shared mutable state.

Every serving-stack class declares which lock protects each mutable
attribute, either in a class-body registry::

    class ServiceMetrics:
        GUARDED_BY = {"requests": "_lock", "latencies": "_lock"}

or with a trailing comment on the attribute's ``__init__`` assignment::

    self.calls = 0    # guarded-by: _lock

The special guard ``"owner"`` declares *thread confinement* instead of a
lock: only the owning thread (the asyncio event loop for the gateway,
the single caller thread for the cooperative service) may write the
attribute.  Owner confinement is unprovable statically — the runtime
shadow mode (``repro.analysis.shadow``) pins the first writer thread
per instance and raises on a cross-thread write, so what the static
pass cannot check, the gateway/procpool fault suites exercise.

Static rules (``__init__``/``__post_init__``/``__new__`` writes and
methods annotated ``# locked: <lock>`` on their ``def`` line — or named
``*_locked`` — are exempt/pre-locked):

* ``undeclared-attr`` — a checked class writes an attribute outside
  ``__init__`` with no declaration at all (new shared state must say
  what guards it — the PR 6 unlocked-``ServiceMetrics`` bug class);
* ``unguarded-write`` — a lock-guarded attribute is written outside a
  ``with self.<that lock>`` block;
* ``unguarded-setattr`` — ``setattr(self, ...)`` in a class with
  lock-guarded attributes, outside the lock (``ServiceMetrics.bump``'s
  shape, done wrong);
* ``locked-helper-call`` — a ``# locked:``/``*_locked`` helper called
  without its lock held;
* ``cross-object-write`` — a write to *another* object's attribute
  whose name is lock-guarded in some checked class (the writer cannot
  be holding the right instance's lock statically).  Owner-guarded
  names are exempt — cross-object owner writes (the gateway mutating
  its ``_Slot``s) are the owning thread's business, shadow-checked.

Declarations merge down the AST base-class chain, so
``GatewayMetrics`` inherits every ``ServiceMetrics`` guard.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .engine import Finding, REPO_ROOT, parse_module, rel_path

CHECKER = "guarded-by"

INIT_METHODS = {"__init__", "__post_init__", "__new__"}

#: Container-method calls that mutate the receiver.
MUTATORS = {"append", "extend", "insert", "pop", "popitem", "clear",
            "update", "setdefault", "remove", "discard", "add",
            "move_to_end", "appendleft", "extendleft", "sort", "reverse"}

#: Serving-stack classes that must declare their shared mutable state
#: even if they carry no GUARDED_BY registry yet.
SERVE_REQUIRED = ("ServiceMetrics", "GatewayMetrics", "SchedulerCore",
                  "PricingGateway", "_Slot", "ProcessReplica",
                  "LocalReplica", "FaultyReplica", "PricingService",
                  "StreamingBook")

_GUARDED_COMMENT = re.compile(r"#\s*guarded-by:\s*(\w+)")
_LOCKED_COMMENT = re.compile(r"#\s*locked:\s*(\w+)")


def _self_attr(expr) -> Optional[str]:
    if (isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"):
        return expr.attr
    return None


class _ClassDecl:
    def __init__(self, node: ast.ClassDef, file: str):
        self.node = node
        self.file = file
        self.name = node.name
        self.bases = [b.id for b in node.bases if isinstance(b, ast.Name)]
        self.guards: Dict[str, str] = {}       # attr -> lock attr | "owner"
        self.methods: Dict[str, ast.AST] = {}
        self.locked_helpers: Dict[str, str] = {}  # method -> lock attr


def _collect(paths) -> Dict[str, _ClassDecl]:
    classes: Dict[str, _ClassDecl] = {}
    for path in paths:
        text = pathlib.Path(path).read_text()
        tree = ast.parse(text, filename=str(path))
        lines = text.splitlines()
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            decl = _ClassDecl(node, rel_path(path))
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    decl.methods[item.name] = item
                    m = _LOCKED_COMMENT.search(lines[item.lineno - 1])
                    if m:
                        decl.locked_helpers[item.name] = m.group(1)
                    elif item.name.endswith("_locked"):
                        decl.locked_helpers[item.name] = "_lock"
                if (isinstance(item, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "GUARDED_BY"
                                for t in item.targets)
                        and isinstance(item.value, ast.Dict)):
                    for k, v in zip(item.value.keys, item.value.values):
                        if (isinstance(k, ast.Constant)
                                and isinstance(v, ast.Constant)):
                            decl.guards[str(k.value)] = str(v.value)
            # inline `self.x = ...  # guarded-by: _lock` declarations
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                    targets = (sub.targets if isinstance(sub, ast.Assign)
                               else [sub.target])
                    line = lines[sub.lineno - 1]
                    m = _GUARDED_COMMENT.search(line)
                    if m:
                        for t in targets:
                            attr = _self_attr(t)
                            if attr:
                                decl.guards[attr] = m.group(1)
            classes[node.name] = decl
    return classes


def _merged_guards(classes: Dict[str, _ClassDecl],
                   name: str) -> Dict[str, str]:
    decl = classes.get(name)
    if decl is None:
        return {}
    merged: Dict[str, str] = {}
    for base in decl.bases:
        merged.update(_merged_guards(classes, base))
    merged.update(decl.guards)
    return merged


class _Write:
    __slots__ = ("attr", "line", "held", "kind", "target_is_self")

    def __init__(self, attr, line, held, kind, target_is_self):
        self.attr = attr
        self.line = line
        self.held = held
        self.kind = kind
        self.target_is_self = target_is_self


def _target_writes(t, line, held, out: List[_Write]) -> None:
    if isinstance(t, (ast.Tuple, ast.List)):
        for el in t.elts:
            _target_writes(el, line, held, out)
        return
    if isinstance(t, ast.Starred):
        _target_writes(t.value, line, held, out)
        return
    base = t
    if isinstance(base, ast.Subscript):
        base = base.value
    if isinstance(base, ast.Attribute):
        attr = base.attr
        is_self = (isinstance(base.value, ast.Name)
                   and base.value.id == "self")
        out.append(_Write(attr, line, held, "assign", is_self))


def _expr_writes(node, line, held, out: List[_Write]) -> None:
    """Mutator/setattr calls anywhere in an expression tree."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        fn = sub.func
        if (isinstance(fn, ast.Name) and fn.id == "setattr"
                and sub.args and isinstance(sub.args[0], ast.Name)
                and sub.args[0].id == "self"):
            out.append(_Write(None, sub.lineno, held, "setattr", True))
        if isinstance(fn, ast.Attribute) and fn.attr in MUTATORS:
            base = fn.value
            if isinstance(base, ast.Subscript):
                base = base.value
            if isinstance(base, ast.Attribute):
                is_self = (isinstance(base.value, ast.Name)
                           and base.value.id == "self")
                out.append(_Write(base.attr, sub.lineno, held, "mutate",
                                  is_self))


def _method_writes(decl: _ClassDecl, mname: str,
                   mnode) -> Tuple[List[_Write],
                                   List[Tuple[str, int, Set[str]]]]:
    """All attribute writes in one method with the set of lock attrs
    held at each, plus ``(helper, line, held)`` for locked-helper call
    sites."""
    writes: List[_Write] = []
    helper_calls: List[Tuple[str, int, Set[str]]] = []
    base_held: Set[str] = set()
    if mname in decl.locked_helpers:
        base_held.add(decl.locked_helpers[mname])

    def stmt(node, held: Set[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                       # nested defs run elsewhere/later
        if isinstance(node, ast.With):
            new_held = set(held)
            for item in node.items:
                attr = _self_attr(item.context_expr)
                if attr:
                    new_held.add(attr)
                _expr_writes(item.context_expr, node.lineno, held, writes)
            for s in node.body:
                stmt(s, new_held)
            return
        if isinstance(node, ast.Assign):
            for t in node.targets:
                _target_writes(t, node.lineno, frozenset(held), writes)
            _expr_writes(node.value, node.lineno, frozenset(held), writes)
            _calls(node, held)
            return
        if isinstance(node, ast.AugAssign):
            _target_writes(node.target, node.lineno, frozenset(held), writes)
            _expr_writes(node.value, node.lineno, frozenset(held), writes)
            _calls(node, held)
            return
        if isinstance(node, ast.AnnAssign) and node.value is not None:
            _target_writes(node.target, node.lineno, frozenset(held), writes)
            _expr_writes(node.value, node.lineno, frozenset(held), writes)
            _calls(node, held)
            return
        # compound statements: scan header expressions, recurse bodies
        for field in ("test", "iter", "value", "exc", "msg", "items"):
            sub = getattr(node, field, None)
            if isinstance(sub, ast.AST):
                _expr_writes(sub, node.lineno, frozenset(held), writes)
                _calls_in(sub, node.lineno, held)
        for field in ("body", "orelse", "finalbody"):
            for s in getattr(node, field, []) or []:
                stmt(s, held)
        for h in getattr(node, "handlers", []) or []:
            for s in h.body:
                stmt(s, held)

    def _calls_in(expr, line, held: Set[str]) -> None:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call):
                attr = _self_attr(sub.func)
                if attr and attr in decl.locked_helpers:
                    helper_calls.append((attr, sub.lineno, set(held)))

    def _calls(node, held: Set[str]) -> None:
        _calls_in(node, node.lineno, held)

    for s in mnode.body:
        stmt(s, set(base_held))
    return writes, helper_calls


def check_files(paths, require: Sequence[str] = (),
                require_all: bool = False) -> List[Finding]:
    classes = _collect(paths)
    findings: List[Finding] = []
    # names declared "owner" anywhere are exempt from the cross-object
    # rule (thread confinement is the runtime shadow mode's job)
    owner_names: Set[str] = set()
    lock_guarded_names: Set[str] = set()
    checked: Set[str] = set()
    for name, decl in classes.items():
        guards = _merged_guards(classes, name)
        if guards or name in require or require_all:
            checked.add(name)
        for attr, g in guards.items():
            (owner_names if g == "owner" else lock_guarded_names).add(attr)

    for name in sorted(checked):
        decl = classes[name]
        guards = _merged_guards(classes, name)
        has_lock_guards = any(g != "owner" for g in guards.values())
        # merge inherited locked helpers so calls resolve across bases
        helpers: Dict[str, str] = {}
        chain = [name]
        while chain:
            c = chain.pop()
            d = classes.get(c)
            if d is None:
                continue
            for h, lk in d.locked_helpers.items():
                helpers.setdefault(h, lk)
            chain.extend(d.bases)
        for mname, mnode in decl.methods.items():
            if mname in INIT_METHODS:
                continue
            writes, helper_calls = _method_writes(decl, mname, mnode)
            for w in writes:
                sym = f"{name}.{mname}.{w.attr or 'setattr'}"
                if w.kind == "setattr":
                    if has_lock_guards and not (w.held & set(
                            g for g in guards.values() if g != "owner")):
                        findings.append(Finding(
                            checker=CHECKER, rule="unguarded-setattr",
                            file=decl.file, line=w.line, symbol=sym,
                            message=f"setattr(self, ...) in {name}."
                                    f"{mname} outside the instance lock"))
                    continue
                if w.target_is_self:
                    g = guards.get(w.attr)
                    if g is None:
                        findings.append(Finding(
                            checker=CHECKER, rule="undeclared-attr",
                            file=decl.file, line=w.line, symbol=sym,
                            message=f"{name}.{mname} writes self.{w.attr} "
                                    "outside __init__ but no GUARDED_BY/"
                                    "guarded-by declaration covers it"))
                    elif g != "owner" and g not in w.held:
                        findings.append(Finding(
                            checker=CHECKER, rule="unguarded-write",
                            file=decl.file, line=w.line, symbol=sym,
                            message=f"self.{w.attr} is guarded by "
                                    f"self.{g} but {name}.{mname} writes "
                                    "it without holding the lock"))
                else:
                    if (w.attr in lock_guarded_names
                            and w.attr not in owner_names):
                        findings.append(Finding(
                            checker=CHECKER, rule="cross-object-write",
                            file=decl.file, line=w.line, symbol=sym,
                            message=f"{name}.{mname} writes .{w.attr} on "
                                    "another object; that attribute is "
                                    "lock-guarded in its owning class"))
            for (helper, line, held) in helper_calls:
                lk = helpers.get(helper)
                if lk is not None and lk not in held:
                    findings.append(Finding(
                        checker=CHECKER, rule="locked-helper-call",
                        file=decl.file, line=line,
                        symbol=f"{name}.{mname}.{helper}",
                        message=f"{name}.{mname} calls self.{helper}() "
                                f"without holding self.{lk} (helper is "
                                "declared to run under it)"))
    return findings


def serve_paths(serve_root=None) -> List[pathlib.Path]:
    from .concurrency import SERVE_FILES
    root = (pathlib.Path(serve_root) if serve_root
            else REPO_ROOT / "src" / "repro" / "serve")
    return [root / f for f in SERVE_FILES if (root / f).exists()]


def check_repo(serve_root=None) -> List[Finding]:
    return check_files(serve_paths(serve_root), require=SERVE_REQUIRED)


def guard_map(paths=None) -> Dict[str, Dict[str, str]]:
    """Merged ``{class: {attr: guard}}`` over the serve files — what the
    runtime shadow mode instruments."""
    classes = _collect(paths if paths is not None else serve_paths())
    return {name: _merged_guards(classes, name) for name in classes}
