"""Repo-wide invariant analyzers.

Four checkers guard the invariants that past PRs broke (or nearly
broke) and that ordinary unit tests are bad at holding:

* ``source-scan``   — kernel-contract coverage, ``interpret=True``
  hard-codes outside the platform layer, sort-primitive bans in
  hot-path modules (one AST engine behind the lowering-contract tests).
* ``concurrency``   — blocking calls inside ``async def`` bodies in
  the serving stack, plus a lock-order graph that fails on cycles.
* ``guarded-by``    — declared shared-mutable attributes must only be
  written under their declared lock (or stay owner-confined); has a
  runtime shadow mode (``repro.analysis.shadow``).
* ``compile-key``   — every ``ChunkSpec``/``ExecutionConfig`` field
  that can change a traced jaxpr or bucket identity must be folded
  into the scheduler's compile/bucket keys (the PR 7 bug class),
  checked by differential probes.
* ``wire-schema``   — every wire-dataclass field must be covered by
  ``to_wire``/``from_wire`` and be JSON-safe or codec'd (the PR 9
  ``mesh`` bug class), plus a round-trip probe.

Run them all via ``tools/analyze.py``; waive individual findings in
``tools/analysis_waivers.toml`` (a written reason is mandatory).
"""
from __future__ import annotations

from typing import Callable, Dict, List

from . import compile_key, concurrency, guarded, source_scan, wire
from .engine import (Finding, Waiver, apply_waivers, load_waivers,
                     REPO_ROOT)

__all__ = ["CHECKERS", "run_all", "Finding", "Waiver", "apply_waivers",
           "load_waivers", "REPO_ROOT"]

#: checker name -> zero-arg callable returning its findings on the
#: real tree.  Order is the report order.
CHECKERS: Dict[str, Callable[[], List[Finding]]] = {
    source_scan.CHECKER: source_scan.check_repo,
    concurrency.CHECKER: concurrency.check_repo,
    guarded.CHECKER: guarded.check_repo,
    compile_key.CHECKER: compile_key.check_repo,
    wire.CHECKER: wire.check_repo,
}


def run_all(checkers=None) -> List[Finding]:
    """Run the named checkers (default: all) over the repository."""
    names = list(CHECKERS) if checkers is None else list(checkers)
    findings: List[Finding] = []
    for name in names:
        findings.extend(CHECKERS[name]())
    return findings
