"""Runtime shadow mode for the guarded-by contract.

The static checker (``repro.analysis.guarded``) proves lock discipline
where it can see it; two things it cannot prove are (a) that a method
documented to run under a lock really does at runtime, and (b) *owner*
(thread-confinement) declarations.  Shadow mode closes that gap: with
``REPRO_SHADOW_GUARDS=1`` the gateway/procpool fault suites run with
every declared class instrumented —

* each lock-guard attribute (``"_lock"``-style guards) is backed by a
  :class:`ShadowLock` that records its owning thread; any ``setattr``
  of a guarded attribute while the current thread does NOT hold the
  lock raises :class:`GuardViolation` at the exact write site;
* each owner-guard attribute pins the first post-``__init__`` writer
  thread per instance; a write from any other thread raises.

Instrumentation patches ``__init__`` (to mark construction writes
exempt and swap declared locks for shadow locks) and ``__setattr__``
(the check) — plain attribute rebinds and ``setattr`` are caught;
in-place container mutation (``list.append``) is not, which is exactly
the granularity at which the gateway's hot counters (``+=`` rebinds)
race, so the known PR 6 bug class is covered.

The declarations come from the same source as the static pass
(``GUARDED_BY`` registries parsed by ``guarded.guard_map``), so runtime
and static can never disagree about what is guarded.
:data:`SHADOW_EXEMPT` mirrors the checked-in waivers for writes that
are lock-free by design (``ProcessReplica.close`` setting ``_dead``).
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterable, Optional, Tuple

__all__ = ["ShadowLock", "GuardViolation", "install", "DEFAULT_CLASSES",
           "SHADOW_EXEMPT"]


class GuardViolation(AssertionError):
    """A declared guarded-by contract was broken at runtime."""


#: (class name, attr) writes exempt from shadow enforcement — each one
#: mirrors a reasoned waiver in ``tools/analysis_waivers.toml``.
SHADOW_EXEMPT: set = {
    # ProcessReplica.close() is lock-free by design: SIGKILL must
    # unblock a concurrent price_chunk via the process sentinel (see
    # the waiver for ProcessReplica.close._dead).
    ("ProcessReplica", "_dead"),
    # ProcessReplica.start() runs from __init__ before the replica is
    # shared (waiver ProcessReplica.start.*).
    ("ProcessReplica", "_conn"), ("ProcessReplica", "_proc"),
    ("ProcessReplica", "_ready"), ("ProcessReplica", "_warmup_deadline"),
}


class ShadowLock:
    """A ``threading.Lock`` work-alike that knows its owner thread."""

    def __init__(self):
        self._inner = threading.Lock()
        self._owner: Optional[int] = None

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
        return got

    def release(self) -> None:
        self._owner = None
        self._inner.release()

    def __enter__(self) -> "ShadowLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def held_by_me(self) -> bool:
        return (self._inner.locked()
                and self._owner == threading.get_ident())


def _default_classes() -> Dict[str, type]:
    from repro.serve.core import SchedulerCore, ServiceMetrics
    from repro.serve.gateway import GatewayMetrics, PricingGateway, _Slot
    from repro.serve.procpool import ProcessReplica
    from repro.serve.replica import FaultyReplica, LocalReplica
    from repro.serve.scheduler import PricingService
    from repro.serve.streaming import StreamingBook
    return {c.__name__: c for c in (
        ServiceMetrics, GatewayMetrics, SchedulerCore, _Slot,
        LocalReplica, FaultyReplica, ProcessReplica, PricingGateway,
        PricingService, StreamingBook)}


DEFAULT_CLASSES = _default_classes


def install(classes: Optional[Iterable[type]] = None) -> Callable[[], None]:
    """Instrument ``classes`` (default: the serving stack); returns an
    ``uninstall()`` that restores the originals."""
    from .guarded import guard_map
    guards_by_class = guard_map()
    if classes is None:
        classes = _default_classes().values()
    originals = []

    for cls in classes:
        # merge declarations down the *runtime* MRO so subclasses see
        # their bases' guards even when only the base is declared
        guards: Dict[str, str] = {}
        for klass in reversed(cls.__mro__):
            guards.update(guards_by_class.get(klass.__name__, {}))
        if not guards:
            continue
        lock_attrs = sorted({g for g in guards.values() if g != "owner"})
        orig_init = cls.__init__
        orig_setattr = cls.__setattr__
        originals.append((cls, orig_init, orig_setattr))

        def make_init(orig_init, lock_attrs):
            def __init__(self, *args, **kwargs):
                object.__setattr__(self, "_shadow_in_init", True)
                try:
                    orig_init(self, *args, **kwargs)
                finally:
                    for lattr in lock_attrs:
                        if isinstance(getattr(self, lattr, None),
                                      threading.Lock().__class__):
                            object.__setattr__(self, lattr, ShadowLock())
                    object.__setattr__(self, "_shadow_in_init", False)
            return __init__

        def make_setattr(orig_setattr, guards, cls_name):
            def __setattr__(self, name, value):
                guard = guards.get(name)
                if (guard is not None
                        and not getattr(self, "_shadow_in_init", True)
                        and (cls_name, name) not in SHADOW_EXEMPT
                        and (type(self).__name__, name) not in SHADOW_EXEMPT):
                    if guard == "owner":
                        owners = getattr(self, "_shadow_owners", None)
                        if owners is None:
                            owners = {}
                            object.__setattr__(self, "_shadow_owners",
                                               owners)
                        me = threading.get_ident()
                        pinned = owners.setdefault(name, me)
                        if pinned != me:
                            raise GuardViolation(
                                f"{type(self).__name__}.{name} is "
                                "owner-confined: first written by thread "
                                f"{pinned}, now written by {me}")
                    else:
                        lock = getattr(self, guard, None)
                        if (isinstance(lock, ShadowLock)
                                and not lock.held_by_me()):
                            raise GuardViolation(
                                f"{type(self).__name__}.{name} is guarded "
                                f"by self.{guard} but was written without "
                                "holding it")
                orig_setattr(self, name, value)
            return __setattr__

        cls.__init__ = make_init(orig_init, lock_attrs)
        cls.__setattr__ = make_setattr(orig_setattr, guards, cls.__name__)

    def uninstall() -> None:
        for cls, orig_init, orig_setattr in originals:
            cls.__init__ = orig_init
            cls.__setattr__ = orig_setattr

    return uninstall
