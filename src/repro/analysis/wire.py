"""Wire-schema lint for ``ChunkSpec``/``ChunkResult`` (the PR 9 bug
class: a dataclass field added without serialization crosses the pipe
as whatever pickle makes of it — or not at all).

Static half (pure AST over ``serve/core.py``): every dataclass field
must appear as a key in the ``to_wire`` dict literal AND be read back
in ``from_wire`` (``wire["f"]`` or ``wire.get("f")``); every field's
annotation must be plain-data/JSON-safe (``int``/``float``/``str``/
``bool``/``tuple`` and ``Optional`` of those) unless the field has a
registered codec in :data:`WIRE_CODECS` (``requests`` travels as rid
tuples, ``shard_plan``/``shard_info`` through their ``_plan_to_wire``
helpers, result arrays as numpy).  A field that is neither plain nor
codec'd is exactly the ``mesh`` bug — an opaque object on the wire.

Runtime half: a populated ``ChunkSpec`` (shard plan and all) must
survive ``to_wire -> json -> from_wire`` unchanged, and a
``ChunkResult`` must survive ``to_wire -> from_wire`` with bit-equal
arrays.  The static pass proves coverage; the round trip proves the
codecs actually invert.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .engine import Finding, REPO_ROOT, parse_module, rel_path

CHECKER = "wire-schema"

#: Annotation heads that are JSON-safe as-is.
PLAIN_TYPES = {"int", "float", "str", "bool", "tuple", "Tuple", "None"}

#: (class, field) pairs with an explicit non-plain codec in
#: ``serve/core.py`` (helpers invert them; the round-trip probe checks).
WIRE_CODECS: Set[Tuple[str, str]] = {
    ("ChunkSpec", "requests"),       # List[_Pending] <-> rid tuples
    ("ChunkSpec", "shard_plan"),     # ShardPlan <-> _plan_to_wire dict
    ("ChunkResult", "ask"),          # numpy arrays (pipe pickles them)
    ("ChunkResult", "bid"),
    ("ChunkResult", "row_pieces"),
    ("ChunkResult", "stderr"),
    ("ChunkResult", "shard_info"),   # ShardExecInfo <-> helper dict
}

WIRE_CLASSES = ("ChunkSpec", "ChunkResult")

#: Wire dict keys that are schema metadata, not fields.
META_KEYS = {"version", "kind"}


def _annotation_head(ann) -> str:
    """``Optional[int]`` → ``int``, ``List[_Pending]`` → ``List``."""
    if isinstance(ann, ast.Subscript):
        head = _annotation_head(ann.value)
        if head == "Optional":
            return _annotation_head(ann.slice)
        return head
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Constant):
        return str(ann.value)
    return ast.dump(ann)


def _class_wire_shape(node: ast.ClassDef):
    """(fields{name: (line, annotation-head)}, encoded keys, decoded
    keys) for one wire dataclass."""
    fields: Dict[str, Tuple[int, str]] = {}
    encoded: Set[str] = set()
    decoded: Set[str] = set()
    for item in node.body:
        if (isinstance(item, ast.AnnAssign)
                and isinstance(item.target, ast.Name)
                and not item.target.id.startswith("_")):
            fields[item.target.id] = (item.lineno,
                                      _annotation_head(item.annotation))
    for item in node.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if item.name == "to_wire":
            for sub in ast.walk(item):
                if isinstance(sub, ast.Dict):
                    for k in sub.keys:
                        if isinstance(k, ast.Constant):
                            encoded.add(str(k.value))
        if item.name == "from_wire":
            for sub in ast.walk(item):
                if (isinstance(sub, ast.Subscript)
                        and isinstance(sub.value, ast.Name)
                        and sub.value.id == "wire"
                        and isinstance(sub.slice, ast.Constant)):
                    decoded.add(str(sub.slice.value))
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr == "get"
                        and isinstance(sub.func.value, ast.Name)
                        and sub.func.value.id == "wire"
                        and sub.args
                        and isinstance(sub.args[0], ast.Constant)):
                    decoded.add(str(sub.args[0].value))
    return fields, encoded - META_KEYS, decoded - META_KEYS


def check_wire_static(path=None,
                      classes: Sequence[str] = WIRE_CLASSES,
                      codecs: Optional[Set[Tuple[str, str]]] = None,
                      ) -> List[Finding]:
    path = path if path is not None else (
        REPO_ROOT / "src" / "repro" / "serve" / "core.py")
    codecs = WIRE_CODECS if codecs is None else codecs
    tree = parse_module(path)
    file = rel_path(path)
    findings: List[Finding] = []
    found_classes = {n.name: n for n in ast.walk(tree)
                     if isinstance(n, ast.ClassDef)}
    for cname in classes:
        node = found_classes.get(cname)
        if node is None:
            findings.append(Finding(
                checker=CHECKER, rule="wire-class-missing",
                file=file, line=1, symbol=cname,
                message=f"wire class {cname} not found in {file}"))
            continue
        fields, encoded, decoded = _class_wire_shape(node)
        for name, (line, head) in sorted(fields.items()):
            sym = f"{cname}.{name}"
            if name not in encoded:
                findings.append(Finding(
                    checker=CHECKER, rule="wire-missing-encode",
                    file=file, line=line, symbol=sym,
                    message=f"dataclass field {sym} is not written by "
                            "to_wire — it silently vanishes at the "
                            "process boundary"))
            if name not in decoded:
                findings.append(Finding(
                    checker=CHECKER, rule="wire-missing-decode",
                    file=file, line=line, symbol=sym,
                    message=f"dataclass field {sym} is not read back by "
                            "from_wire — decoded chunks get the default"))
            if head not in PLAIN_TYPES and (cname, name) not in codecs:
                findings.append(Finding(
                    checker=CHECKER, rule="wire-opaque-type",
                    file=file, line=line, symbol=sym,
                    message=f"{sym} is typed '{head}' — not JSON-safe "
                            "plain data and no codec is registered in "
                            "repro.analysis.wire.WIRE_CODECS (the "
                            "ChunkSpec.mesh bug class)"))
        for name in sorted(encoded - set(fields)):
            findings.append(Finding(
                checker=CHECKER, rule="wire-stale-key",
                file=file, line=node.lineno, symbol=f"{cname}.{name}",
                message=f"to_wire emits key '{name}' with no matching "
                        f"dataclass field on {cname}"))
    return findings


def check_roundtrip() -> List[Finding]:
    """A populated ChunkSpec survives to_wire → json → from_wire; a
    ChunkResult survives to_wire → from_wire with equal arrays."""
    import dataclasses
    import json

    import numpy as np

    from repro.core.partition import ShardPlan
    from repro.serve.core import ChunkResult, ChunkSpec, _Pending
    file = "src/repro/serve/core.py"
    findings: List[Finding] = []
    plan = ShardPlan(n_shards=2, shards=((0, 2), (2, 4)),
                     work=(1.0, 1.0), lanes=2, n_rows=4)
    spec = ChunkSpec(
        bucket=(8, "lsmc", 2, (4, 8)),
        requests=[_Pending(7, (100.0, 0.2, 0.1, 0.25, 0.0, "put", 100.0,
                               110.0, 8, 2, (4, 8)), 1.5)],
        n_steps=8, engine="lsmc", capacity=16, backend="jnp", padded=4,
        cols=((100.0,), (0.2,), (0.1,), (0.25,), (0.0,), ("put",),
              (100.0,), (110.0,)),
        devices=2, shard_plan=plan, n_assets=2, exercise_steps=(4, 8),
        n_paths=512, mc_seed=3, interpret=True, basis="poly", degree=2,
        antithetic=False)
    try:
        hopped = json.loads(json.dumps(spec.to_wire()))
    except TypeError as e:
        return [Finding(checker=CHECKER, rule="wire-roundtrip", file=file,
                        line=1, symbol="ChunkSpec.to_wire",
                        message=f"ChunkSpec wire dict is not JSON "
                                f"serializable: {e}")]
    back = ChunkSpec.from_wire(hopped)
    if back != spec:
        diffs = [f.name for f in dataclasses.fields(spec)
                 if getattr(back, f.name) != getattr(spec, f.name)]
        findings.append(Finding(
            checker=CHECKER, rule="wire-roundtrip", file=file, line=1,
            symbol="ChunkSpec.from_wire",
            message=f"ChunkSpec wire round trip (via JSON) changed "
                    f"fields {diffs}"))
    res = ChunkResult(ask=np.array([1.0, 2.0]), bid=np.array([0.5, 1.5]),
                      max_pieces=7, row_pieces=np.array([3, 7]),
                      seconds=0.25, stderr=np.array([0.01, 0.02]))
    rback = ChunkResult.from_wire(res.to_wire())
    same = (np.array_equal(rback.ask, res.ask)
            and np.array_equal(rback.bid, res.bid)
            and rback.max_pieces == res.max_pieces
            and np.array_equal(rback.row_pieces, res.row_pieces)
            and rback.seconds == res.seconds
            and np.array_equal(rback.stderr, res.stderr))
    if not same:
        findings.append(Finding(
            checker=CHECKER, rule="wire-roundtrip", file=file, line=1,
            symbol="ChunkResult.from_wire",
            message="ChunkResult wire round trip changed values"))
    return findings


def check_repo() -> List[Finding]:
    return check_wire_static() + check_roundtrip()
