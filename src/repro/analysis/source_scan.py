"""Generic AST source-scan rules (the engine behind the old
``kernels/contracts.py`` regex sweep).

Three repo policies run through one rule engine:

* **pallas-coverage** — every module containing a ``pl.pallas_call(``
  site must be declared by a :class:`repro.kernels.contracts.Contract`
  (and every declared module must still contain one), so a new kernel
  cannot land without a lowering contract.
* **interpret-hardcode** — ``interpret=True`` literal call kwargs are
  banned in ``src/repro`` outside ``core/platform.py``; interpret-mode
  selection is platform policy, not a per-call-site decision.
* **sort-ban** — sort-engine primitives (``jnp.sort``/``argsort``/
  ``lexsort``/``sort_key_val``, ``jax.lax.sort``/``top_k``) are banned
  in the hot-path modules (``core/pwl.py``, ``core/rz.py``,
  ``core/notc.py``, ``kernels/``) that PR 5 rewrote sort-free.  The
  two retained bysort references in ``core/pwl.py`` are waived.

All rules are AST-based (``ast.Call`` nodes), so prose mentions of
``jnp.sort`` in docstrings/comments never false-positive.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from .engine import (Finding, REPO_ROOT, SymbolMap, iter_py_files,
                     parse_module, rel_path)

CHECKER = "source-scan"

#: Hot-path modules under src/repro where sort primitives are banned
#: (jaxpr-asserted sort-free since PR 5).  *_ref.py oracles are exempt.
SORT_BAN_MODULES = ("core/pwl.py", "core/rz.py", "core/notc.py")
SORT_BAN_GLOBS = ("kernels/*.py",)

#: Call names (attribute tails) that reach a sort engine.
SORT_CALL_NAMES = {"sort", "argsort", "lexsort", "sort_key_val",
                   "top_k", "approx_top_k"}


def _call_name(node: ast.Call) -> Optional[str]:
    """Tail name of the callee: ``jnp.argsort(...)`` → ``argsort``."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def find_calls(tree: ast.Module, names: Set[str]) -> List[ast.Call]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.Call) and _call_name(n) in names]


def has_pallas_call(tree: ast.Module) -> bool:
    return bool(find_calls(tree, {"pallas_call"}))


def _interpret_true_kwargs(tree: ast.Module) -> List[ast.Call]:
    """Calls passing a literal ``interpret=True`` kwarg.  A ``True``
    *default* on a ``def`` is fine — only call sites hardcode policy."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if (kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                out.append(node)
    return out


def scan_interpret_hardcode(src_root=None) -> List[Finding]:
    """``interpret=True`` call kwargs anywhere in src/repro except
    ``core/platform.py`` (which owns the policy) and the analysis
    package itself (whose differential probes pin interpret to a
    literal so compile keys are deterministic under test)."""
    root = pathlib.Path(src_root) if src_root else REPO_ROOT / "src" / "repro"
    findings = []
    for path in iter_py_files(root):
        if path.name == "platform.py" and path.parent.name == "core":
            continue
        if path.parent.name == "analysis":
            continue
        tree = parse_module(path)
        symbols = SymbolMap(tree)
        for call in _interpret_true_kwargs(tree):
            findings.append(Finding(
                checker=CHECKER, rule="interpret-hardcode",
                file=rel_path(path), line=call.lineno,
                symbol=symbols.at(call.lineno),
                message="literal interpret=True at a call site; route "
                        "through core/platform.py resolve_interpret()"))
    return findings


def scan_sort_ban(src_root=None) -> List[Finding]:
    root = pathlib.Path(src_root) if src_root else REPO_ROOT / "src" / "repro"
    paths = []
    for mod in SORT_BAN_MODULES:
        p = root / mod
        if p.exists():
            paths.append(p)
    for pat in SORT_BAN_GLOBS:
        paths.extend(sorted(root.glob(pat)))
    findings = []
    for path in paths:
        tree = parse_module(path)
        symbols = SymbolMap(tree)
        for call in find_calls(tree, SORT_CALL_NAMES):
            findings.append(Finding(
                checker=CHECKER, rule="sort-ban",
                file=rel_path(path), line=call.lineno,
                symbol=symbols.at(call.lineno),
                message=f"sort-engine primitive '{_call_name(call)}' in a "
                        "hot-path module (sort-free since the merge-path "
                        "rewrite)"))
    return findings


def pallas_call_modules(src_root=None) -> Set[str]:
    """Dotted ``repro.*`` module names containing a pallas_call site."""
    root = pathlib.Path(src_root) if src_root else REPO_ROOT / "src" / "repro"
    found = set()
    for path in iter_py_files(root):
        if has_pallas_call(parse_module(path)):
            mod = ".".join(("repro",)
                           + path.relative_to(root).with_suffix("").parts)
            found.add(mod)
    return found


def scan_pallas_coverage(src_root=None,
                         declared: Optional[Set[str]] = None,
                         ) -> List[Finding]:
    """Both directions: every pallas_call module declared by a kernel
    contract, every declared module still hosting a pallas_call."""
    if declared is None:
        from repro.kernels.contracts import CONTRACTS
        declared = {c.module for c in CONTRACTS.values()}
    actual = pallas_call_modules(src_root)
    findings = []
    for mod in sorted(actual - declared):
        findings.append(Finding(
            checker=CHECKER, rule="pallas-uncovered",
            file="src/" + mod.replace(".", "/") + ".py", line=1,
            symbol=mod,
            message="module contains pl.pallas_call but no "
                    "kernels.contracts entry declares it"))
    for mod in sorted(declared - actual):
        findings.append(Finding(
            checker=CHECKER, rule="pallas-stale-contract",
            file="src/repro/kernels/contracts.py", line=1,
            symbol=mod,
            message=f"contract declares module '{mod}' but it has no "
                    "pl.pallas_call site"))
    return findings


def check_repo(src_root=None) -> List[Finding]:
    return (scan_pallas_coverage(src_root)
            + scan_interpret_hardcode(src_root)
            + scan_sort_ban(src_root))
