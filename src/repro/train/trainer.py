"""Training loop with fault tolerance, restart, and elastic re-meshing.

Failure model (single-host container, thousands-of-nodes design):

  * **checkpoint/restart** — AsyncCheckpointer every ``ckpt_every`` steps;
    on (re)start the trainer restores the newest complete checkpoint and
    the *stateless* data pipeline seeks to that step, so a killed job
    resumes bit-exactly (tested by killing mid-run in
    tests/test_fault_tolerance.py).
  * **node failure / elastic scaling** — the mesh is an input; restore
    re-device_puts every leaf with the new mesh's shardings (ZeRO shards
    are re-laid-out automatically since checkpoints store full logical
    arrays).  ``--simulate-failure N`` raises after N steps to exercise
    the path.
  * **straggler mitigation** — per-step wall times feed an EWMA; steps
    slower than ``straggler_factor`` x EWMA are logged with the step data
    hash so an external scheduler can blame/evict the slow worker.  (With
    SPMD all devices step together; detection is what the single program
    can do — eviction is the platform's job, re-meshing is handled by the
    elastic restore above.)
  * **gradient compression** — optional int8+error-feedback DP psum
    (optim/compression.py) in the explicit-DP mode.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from ..checkpoint import ckpt as ckpt_lib
from ..configs.base import ModelConfig
from ..data.pipeline import SyntheticSource
from ..models.transformer import RunCfg
from ..optim.adamw import AdamWConfig
from ..optim.schedule import warmup_cosine
from .step import TrainState, init_train_state, make_train_step, state_specs

__all__ = ["TrainerConfig", "train"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    n_micro: int = 1
    peak_lr: float = 3e-4
    warmup: int = 10
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    simulate_failure_at: Optional[int] = None


def train(cfg: ModelConfig, tc: TrainerConfig, run: Optional[RunCfg] = None,
          rules=None, log=print) -> dict:
    """Runs (or resumes) training; returns final metrics."""
    run = run or RunCfg(dtype=jax.numpy.float32)
    key = jax.random.PRNGKey(tc.seed)

    state, specs = init_train_state(key, cfg)
    opt_cfg = AdamWConfig(lr=warmup_cosine(tc.peak_lr, tc.warmup, tc.steps))
    step_fn = jax.jit(make_train_step(cfg, run, opt_cfg, rules),
                      donate_argnums=(0,))

    start_step = 0
    latest = ckpt_lib.latest_step(tc.ckpt_dir)
    if latest is not None:
        state = ckpt_lib.restore(tc.ckpt_dir, like=state)
        start_step = latest
        log(f"[trainer] resumed from step {start_step}")

    source = SyntheticSource(vocab=cfg.vocab, global_batch=tc.global_batch,
                             seq_len=tc.seq_len, n_micro=tc.n_micro,
                             seed=tc.seed)
    saver = ckpt_lib.AsyncCheckpointer(tc.ckpt_dir)

    ewma = None
    losses = []
    metrics = {}
    for step in range(start_step, tc.steps):
        if tc.simulate_failure_at is not None and step == tc.simulate_failure_at:
            saver.wait()
            raise RuntimeError(f"simulated node failure at step {step}")
        batch = jax.tree.map(jax.numpy.asarray, source.batch(step))
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > tc.straggler_factor * ewma and step > start_step + 2:
            log(f"[straggler] step {step} took {dt:.3f}s vs EWMA {ewma:.3f}s")
        losses.append(loss)
        if step % tc.log_every == 0:
            log(f"[trainer] step {step} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if (step + 1) % tc.ckpt_every == 0 or step + 1 == tc.steps:
            saver.save(step + 1, state)
    saver.wait()
    return {"final_loss": losses[-1] if losses else None,
            "losses": losses, "last_step": tc.steps,
            "grad_norm": float(metrics["grad_norm"]) if losses else None}
