"""Pipeline parallelism: GPipe schedule over the pod axis.

Why pods: inter-pod (DCN) links are an order of magnitude slower than
intra-pod ICI, so at multi-pod scale the standard layout is pipeline
stages across pods with FSDP+TP inside each pod.  This module implements
that: the layer stack splits into ``stages`` equal groups mapped onto the
mesh's ``pipe`` axis (the production multi-pod mesh's ``pod`` axis); the
data/model axes keep their FSDP/TP roles *inside* the shard_map via the
auto-axes mechanism.

Schedule: GPipe — the tick loop runs n_micro + stages - 1 steps; at tick
t, stage s processes microbatch t - s.  Activations move stage->stage via
one ``lax.ppermute`` per tick, which is *differentiable* (its transpose
is the reverse permute), so ``jax.grad`` of the pipelined loss runs the
backward pipeline automatically with the reversed schedule — no manual
1F1B bookkeeping.  Memory is the GPipe profile (activations stashed per
in-flight microbatch); the stage body is rematerialised.

The first/last-stage-only work (embedding lookup / LM head + loss) is
gated by ``lax.cond`` on the stage index (uniform per device, so SPMD
keeps real branches).

Limitations (stated): homogeneous decoder patterns only (pattern groups
must split evenly across stages); no interleaved virtual stages; enc-dec
not supported (encoder would pipeline separately).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from ..compat import shard_map
from ..configs.base import ModelConfig
from ..models import layers as L
from ..models.transformer import RunCfg, _super_block, init_lm
from ..optim.adamw import AdamWConfig, adamw_update

__all__ = ["split_stages", "make_pp_loss", "make_pp_train_step"]


def split_stages(params, cfg: ModelConfig, stages: int):
    """Restack scan params (reps, ...) into (stages, reps/stages, ...)."""
    pat = len(cfg.block_pattern)
    reps = cfg.n_layers // pat
    if cfg.n_layers % pat or reps % stages:
        raise ValueError(
            f"{cfg.n_layers} layers (pattern {pat}) do not split into "
            f"{stages} equal pipeline stages")
    if cfg.n_encoder_layers:
        raise ValueError("enc-dec models are not supported by the pipeline")
    per = reps // stages
    stage_blocks = jax.tree.map(
        lambda a: a.reshape((stages, per) + a.shape[1:]), params["scan"])
    rest = {k: v for k, v in params.items() if k != "scan"}
    return {"stages": stage_blocks, **rest}


def make_pp_loss(cfg: ModelConfig, run: RunCfg, mesh, *, stages: int,
                 pipe_axis: str = "pod"):
    """Returns loss(params_pp, batch) with batch (n_micro, mb, S)."""
    from ..models.sharding import MeshRules, logical

    pat = len(cfg.block_pattern)
    per = (cfg.n_layers // pat) // stages
    perm_fwd = [(i, (i + 1) % stages) for i in range(stages)]
    # data/model stay AUTO axes inside the pipe-manual shard_map, so the
    # usual FSDP/TP sharding constraints apply within each stage (hybrid
    # manual/auto shard_map)
    axes = [a for a in mesh.axis_names if a != pipe_axis]
    pp_rules = MeshRules(mesh=mesh,
                         fsdp=tuple(a for a in axes if a != "model"),
                         tp=("model",) if "model" in axes else ())

    def stage_body(blocks, x, positions):
        def body(h, pp):
            h, _, _, aux = _super_block(pp, h, cfg, run, pp_rules,
                                        positions=positions, causal=True,
                                        enc_out=None, states=None)
            return h, aux
        body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, blocks,
                               unroll=per if run.unroll else 1)
        return x, jnp.sum(auxs)

    def piped(params_pp, batch):
        stage = jax.lax.axis_index(pipe_axis)
        # the pipe-sharded stage stack arrives as (1, per, ...): drop the
        # local stage axis
        params_pp = dict(params_pp,
                         stages=jax.tree.map(lambda a: a[0],
                                             params_pp["stages"]))
        tokens_all = batch["tokens"]
        targets_all = batch["targets"]
        n_micro, mb, S = tokens_all.shape
        T = n_micro + stages - 1
        positions = jnp.arange(S)
        emb = params_pp["embed"]
        head = (emb.T if cfg.tie_embeddings else params_pp["lm_head"])

        def embed_micro(idx):
            toks = jnp.take(tokens_all, jnp.clip(idx, 0, n_micro - 1), axis=0)
            return emb.astype(run.dtype)[toks]

        def head_loss(h, idx):
            h = L.rmsnorm(params_pp["final_norm"], h, cfg.norm_eps)
            logits = (h @ head.astype(run.dtype)).astype(jnp.float32)
            logits = logical(logits, pp_rules, "dp", None, "tp")
            tgt = jnp.take(targets_all, jnp.clip(idx, 0, n_micro - 1), axis=0)
            lse = jax.nn.logsumexp(logits, axis=-1)
            onehot = jax.nn.one_hot(tgt, cfg.vocab, dtype=jnp.float32)
            true_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
            return jnp.mean(lse - true_logit)

        def tick(carry, t):
            h_in, loss_acc = carry
            # stage 0 injects microbatch t (garbage beyond n_micro; masked)
            h = jax.lax.cond(stage == 0,
                             lambda: embed_micro(t),
                             lambda: h_in.astype(run.dtype))
            h, _aux = stage_body(params_pp["stages"], h, positions)
            # last stage consumes microbatch t - (stages-1)
            midx = t - (stages - 1)
            is_last = stage == stages - 1
            valid = jnp.logical_and(is_last,
                                    jnp.logical_and(midx >= 0,
                                                    midx < n_micro))
            lm = jax.lax.cond(is_last,
                              lambda: head_loss(h, midx),
                              lambda: jnp.zeros((), jnp.float32))
            loss_acc = loss_acc + jnp.where(valid, lm, 0.0)
            h_out = jax.lax.ppermute(h.astype(run.dtype), pipe_axis, perm_fwd)
            return (h_out, loss_acc), None

        h0 = jnp.zeros((mb, S, cfg.d_model), run.dtype)
        (_, loss_acc), _ = jax.lax.scan(tick, (h0, jnp.zeros((), jnp.float32)),
                                        jnp.arange(T))
        # only the last stage accumulated loss; share it with every stage
        total = jax.lax.psum(loss_acc, pipe_axis) / n_micro
        return total

    def loss_fn(params_pp, batch):
        return shard_map(
            piped, mesh=mesh,
            in_specs=(_pp_in_specs(params_pp, pipe_axis),
                      jax.tree.map(lambda _: PS(), batch)),
            out_specs=PS(),
            axis_names={pipe_axis},
            check_vma=False,
        )(params_pp, batch)

    return loss_fn


def _pp_in_specs(params_pp, pipe_axis):
    """Stage-stacked blocks shard over the pipe axis; embed/head/norms are
    replicated across stages (resident where used)."""
    specs = {}
    for k, v in params_pp.items():
        if k == "stages":
            specs[k] = jax.tree.map(lambda _: PS(pipe_axis), v)
        else:
            specs[k] = jax.tree.map(lambda _: PS(), v)
    return specs


def make_pp_train_step(cfg: ModelConfig, run: RunCfg, opt_cfg: AdamWConfig,
                       mesh, *, stages: int, pipe_axis: str = "pod"):
    """Full pipelined train step: value_and_grad THROUGH the shard_map
    (transposed ppermutes run the backward pipeline), optimizer outside in
    pjit-land so global-norm clipping sees all stages."""
    loss_fn = make_pp_loss(cfg, run, mesh, stages=stages,
                           pipe_axis=pipe_axis)
    grad_fn = jax.value_and_grad(loss_fn)

    def step(state, batch):
        params, opt = state
        loss, grads = grad_fn(params, batch)
        params, opt, om = adamw_update(opt_cfg, grads, opt, params)
        return (params, opt), {"loss": loss, **om}

    return step
