"""Train-step factory: grad accumulation, clipping, AdamW, sharding.

``make_train_step`` returns a jit-able ``step(state, batch) -> (state,
metrics)`` with:

  * microbatched gradient accumulation (``lax.scan`` over the leading
    microbatch axis — batch arrives as (n_micro, B/n_micro, S)),
  * loss in f32, params in f32, compute in the RunCfg dtype (bf16),
  * optimizer state sharded like params (ZeRO-3 on the fsdp axis),
  * donated state for in-place buffer reuse.

``TrainState`` is a plain NamedTuple pytree so checkpointing is trivial.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.sharding import MeshRules
from ..models.transformer import RunCfg, init_lm, lm_loss
from ..optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "init_train_state",
           "state_specs", "batch_specs"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(key, cfg: ModelConfig, param_dtype=None,
                     opt_cfg: Optional[AdamWConfig] = None):
    """param_dtype=bf16 stores compute params in bf16 with an fp32 master
    inside the optimizer state (requires opt_cfg.master_fp32)."""
    params, specs = init_lm(key, cfg)
    if param_dtype is not None:
        params = jax.tree.map(lambda p: p.astype(param_dtype), params)
    return TrainState(params, adamw_init(params, opt_cfg)), specs


def state_specs(specs, master_fp32: bool = False) -> TrainState:
    """Optimizer state shards exactly like params; step is replicated."""
    return TrainState(
        params=specs,
        opt=AdamWState(step=(), m=specs, v=specs,
                       master=specs if master_fp32 else None))


def make_train_step(cfg: ModelConfig, run: RunCfg, opt_cfg: AdamWConfig,
                    rules: Optional[MeshRules] = None):
    """batch: dict of arrays with leading (n_micro, local_batch) axes."""

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg, run, rules)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, batch):
        n_micro = jax.tree.leaves(batch)[0].shape[0]

        if n_micro == 1:
            mb = jax.tree.map(lambda a: a[0], batch)
            (lsum, _), grads = grad_fn(state.params, mb)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = grad_fn(state.params, mb)
                gsum = jax.tree.map(
                    jnp.add, gsum,
                    jax.tree.map(lambda x: x.astype(jnp.float32), g))
                return (gsum, lsum + loss), metrics["tokens"]

            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (gzero, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
        params, opt, om = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": lsum / n_micro, **om, "step": opt.step}
        return TrainState(params, opt), metrics

    return step
