"""Model layers: norms, rotary, GQA attention, MLP, MoE, RG-LRU, Mamba.

Pure-functional: every layer has ``init_*(key, cfg) -> (params, specs)``
and an apply function.  ``params`` are float32 pytrees; compute casts to
the configured activation dtype (bf16 by default).  ``specs`` is a
parallel pytree of *logical* PartitionSpecs (see
:mod:`repro.models.sharding`) resolved against the production mesh at jit
time.

Attention supports:
  * GQA / MQA (n_kv_heads <= n_heads), optional per-head qk RMS-norm
    (qwen3 / chameleon), optional qkv bias (qwen2.5),
  * causal, bidirectional (encoder), sliding-window (recurrentgemma,
    window size cfg.local_window), and cross attention (seamless),
  * three implementations: "naive" (materialises S x S scores), "flash"
    (online-softmax over KV chunks, O(chunk^2) memory — the pure-jnp
    oracle of the Pallas kernel), and KV-cache decode.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from ..compat import shard_map
from ..configs.base import ModelConfig

Params = Dict[str, Any]

DEFAULT_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- #
# initialisers
# --------------------------------------------------------------------- #
def _dense_init(key, shape, in_axis=0):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": (None,)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


# --------------------------------------------------------------------- #
# rotary position embedding
# --------------------------------------------------------------------- #
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd), positions: (B, T) or (T,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq        # (B,T,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #
def init_attention(key, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(ks[0], (d, h * hd)),
        "wk": _dense_init(ks[1], (d, kvh * hd)),
        "wv": _dense_init(ks[2], (d, kvh * hd)),
        "wo": _dense_init(ks[3], (h * hd, d)),
    }
    specs = {
        "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
        "wo": ("tp", "fsdp"),
    }
    if cfg.qkv_bias:
        params.update(bq=jnp.zeros((h * hd,), jnp.float32),
                      bk=jnp.zeros((kvh * hd,), jnp.float32),
                      bv=jnp.zeros((kvh * hd,), jnp.float32))
        specs.update(bq=("tp",), bk=("tp",), bv=("tp",))
    if cfg.qk_norm:
        params.update(q_norm=jnp.ones((hd,), jnp.float32),
                      k_norm=jnp.ones((hd,), jnp.float32))
        specs.update(q_norm=(None,), k_norm=(None,))
    return params, specs


def _qk_head_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


_MASK_NEG = -1e30  # finite: keeps online-softmax NaN-free on fully-masked
                   # KV chunks (exp(-1e30 - m) underflows to exactly 0)


def _mask_bias(pos_q, pos_k, *, causal: bool, window: Optional[int]):
    """(Tq, Tk) additive mask in f32 (0 / -1e30)."""
    m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        m &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        m &= pos_q[:, None] - pos_k[None, :] < window
    return jnp.where(m, 0.0, _MASK_NEG).astype(jnp.float32)


def _attn_naive(q, k, v, bias):
    """q: (B,T,KVH,G,hd)  k,v: (B,S,KVH,hd)  bias: (T,S) additive."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("btkgh,bskh->bkgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = scores + bias[None, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _attn_flash(q, k, v, pos_q, pos_k, *, causal, window,
                q_chunk: int = 1024, kv_chunk: int = 1024):
    """Online-softmax attention, O(q_chunk * kv_chunk) live scores.

    Same signature semantics as _attn_naive but masks are built per chunk.
    This is also the pure-jnp oracle for kernels/flash_attention.
    """
    B, T, KVH, G, hd = q.shape
    S = k.shape[1]
    q_chunk = min(q_chunk, T)
    kv_chunk = min(kv_chunk, S)
    nq, nk = T // q_chunk, S // kv_chunk
    assert T % q_chunk == 0 and S % kv_chunk == 0
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(B, nq, q_chunk, KVH, G, hd)
    kr = k.reshape(B, nk, kv_chunk, KVH, hd)
    vr = v.reshape(B, nk, kv_chunk, KVH, hd)
    pq = pos_q.reshape(nq, q_chunk)
    pk = pos_k.reshape(nk, kv_chunk)

    def q_block(qi_and_posq):
        qi, posq = qi_and_posq                     # (B,Cq,KVH,G,hd), (Cq,)

        def kv_step(carry, kj_and):
            m, l, acc = carry
            kj, vj, posk = kj_and
            b = _mask_bias(posq, posk, causal=causal, window=window)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            s = s + b[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, KVH, G, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4), pk))
        l = jnp.maximum(l, 1e-30)
        return acc / l.transpose(0, 3, 1, 2)[..., None]

    out = jax.lax.map(q_block, (qr.transpose(1, 0, 2, 3, 4, 5), pq))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, KVH, G, hd)
    return out.astype(v.dtype)


def attention(params, x, cfg: ModelConfig, *,
              kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              x_kv: Optional[jax.Array] = None,
              positions: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              causal: bool = True, window: Optional[int] = None,
              impl: str = "naive", dtype=DEFAULT_DTYPE,
              q_chunk: int = 1024, kv_chunk: int = 1024,
              use_rope: Optional[bool] = None):
    """Self / cross attention.

    kv: precomputed (k, v) cache (decode);  x_kv: encoder output (cross).
    use_rope: override rotary application (default: self-attention only —
    cross attention against a cached encoder must pass False explicitly
    when kv= is used, since kv= alone cannot distinguish the two).
    Returns (out, (k, v)) so callers can build KV caches.
    """
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    g = h // kvh
    cast = lambda w: w.astype(dtype)

    q = x @ cast(params["wq"])
    src = x if x_kv is None else x_kv
    k = src @ cast(params["wk"])
    v = src @ cast(params["wv"])
    if cfg.qkv_bias:
        q = q + cast(params["bq"])
        k = k + cast(params["bk"])
        v = v + cast(params["bv"])
    q = q.reshape(B, T, kvh, g, hd)
    k = k.reshape(B, src.shape[1], kvh, hd)
    v = v.reshape(B, src.shape[1], kvh, hd)
    if cfg.qk_norm:
        q = _qk_head_norm(q, params["q_norm"], cfg.norm_eps)
        k = _qk_head_norm(k, params["k_norm"], cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(T)
    if use_rope is None:
        use_rope = x_kv is None          # rope only for self attention
    if use_rope:
        q = apply_rope(q.reshape(B, T, kvh * g, hd), positions,
                       cfg.rope_theta).reshape(B, T, kvh, g, hd)
        if kv is None:
            k = apply_rope(k, kv_positions if kv_positions is not None
                           else positions, cfg.rope_theta)

    if kv is not None:                     # decode against cache
        k_full, v_full = kv
        S = k_full.shape[1]
        pos_k = jnp.arange(S)
        bias = _mask_bias(jnp.atleast_1d(positions.reshape(-1)), pos_k,
                          causal=causal, window=window)
        out = _attn_naive(q, k_full.astype(dtype), v_full.astype(dtype), bias)
    else:
        S = src.shape[1]
        pos_q = positions if positions.ndim == 1 else positions[0]
        pos_k = (kv_positions if kv_positions is not None else
                 (jnp.arange(S) if x_kv is not None else pos_q))
        if impl == "flash" and T > 1:
            out = _attn_flash(q, k, v, pos_q, pos_k, causal=causal,
                              window=window, q_chunk=q_chunk,
                              kv_chunk=kv_chunk)
        else:
            bias = _mask_bias(pos_q, pos_k, causal=causal, window=window)
            out = _attn_naive(q, k, v, bias)

    out = out.reshape(B, T, h * hd) @ cast(params["wo"])
    return out, (k, v)


# --------------------------------------------------------------------- #
# feed-forward
# --------------------------------------------------------------------- #
def init_mlp(key, d: int, f: int, kind: str):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        params = {"wi": _dense_init(ks[0], (d, f)),
                  "wg": _dense_init(ks[1], (d, f)),
                  "wo": _dense_init(ks[2], (f, d))}
        specs = {"wi": ("fsdp", "tp"), "wg": ("fsdp", "tp"),
                 "wo": ("tp", "fsdp")}
    else:  # gated gelu
        params = {"wi": _dense_init(ks[0], (d, f)),
                  "wg": _dense_init(ks[1], (d, f)),
                  "wo": _dense_init(ks[2], (f, d))}
        specs = {"wi": ("fsdp", "tp"), "wg": ("fsdp", "tp"),
                 "wo": ("tp", "fsdp")}
    return params, specs


def mlp(params, x, kind: str, dtype=DEFAULT_DTYPE):
    cast = lambda w: w.astype(dtype)
    gate = x @ cast(params["wg"])
    act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
    return (act * (x @ cast(params["wi"]))) @ cast(params["wo"])


# --------------------------------------------------------------------- #
# mixture of experts (expert-parallel over the tp axis)
# --------------------------------------------------------------------- #
def init_moe(key, cfg: ModelConfig):
    e = cfg.moe
    d = cfg.d_model
    f = e.d_ff_expert
    ks = jax.random.split(key, 5)
    params = {
        "router": _dense_init(ks[0], (d, e.num_experts)),
        "wi": _dense_init(ks[1], (e.num_experts, d, f), in_axis=1),
        "wg": _dense_init(ks[2], (e.num_experts, d, f), in_axis=1),
        "wo": _dense_init(ks[3], (e.num_experts, f, d), in_axis=1),
    }
    specs = {
        "router": ("fsdp", None),
        "wi": ("tp", "fsdp", None), "wg": ("tp", "fsdp", None),
        "wo": ("tp", None, "fsdp"),
    }
    if e.shared_expert:
        p2, s2 = init_mlp(ks[4], d, cfg.d_ff, cfg.mlp_kind)
        params["shared"] = p2
        specs["shared"] = s2
    return params, specs


def moe_dense(params, x, cfg: ModelConfig, dtype=DEFAULT_DTYPE):
    """Reference/smoke MoE: computes every expert densely then mixes by the
    (top-k masked) gate.  Exact and simple; used on small configs and as
    the oracle for the dispatched version."""
    e = cfg.moe
    cast = lambda w: w.astype(dtype)
    logits = (x @ cast(params["router"])).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(probs, e.top_k)
    onehot = jax.nn.one_hot(top_i, e.num_experts, dtype=jnp.float32)
    gates = jnp.sum(onehot * top_v[..., None], axis=-2)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    up = jnp.einsum("bsd,edf->ebsf", x, cast(params["wi"]))
    gt = jnp.einsum("bsd,edf->ebsf", x, cast(params["wg"]))
    act = jax.nn.silu(gt) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(gt)
    y = jnp.einsum("ebsf,efd->ebsd", act * up, cast(params["wo"]))
    out = jnp.einsum("ebsd,bse->bsd", y, gates.astype(dtype))
    aux = _router_aux(probs, top_i, e.num_experts)
    if e.shared_expert:
        out = out + mlp(params["shared"], x, cfg.mlp_kind, dtype)
    return out.astype(x.dtype), aux


def _router_aux(probs, top_i, n_exp):
    """Switch-style load-balancing loss."""
    onehot = jax.nn.one_hot(top_i[..., 0], n_exp, dtype=jnp.float32)
    frac_tokens = jnp.mean(onehot, axis=tuple(range(onehot.ndim - 1)))
    mean_probs = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return n_exp * jnp.sum(frac_tokens * mean_probs)


def moe_dispatch(params, x, cfg: ModelConfig, rules, dtype=DEFAULT_DTYPE,
                 psum_bf16: bool = False):
    """Expert-parallel MoE: experts sharded over the tp axis; activations
    arrive replicated over tp (Megatron layout), so each device gathers the
    tokens routed to *its* experts from its own replica — no all_to_all —
    computes them at capacity C, scatter-adds, and one psum over tp
    combines.  Active-FLOPs faithful (no dense over-compute).
    """
    e = cfg.moe
    tp_axes = rules.tp
    tp_size = rules.axis_size(tp_axes)
    if e.num_experts % tp_size != 0:
        out, aux = moe_dense(params, x, cfg, dtype)   # fallback (smoke)
        return out, aux
    e_per = e.num_experts // tp_size
    B, S, D = x.shape
    dp_axes = rules.dp
    dp_size = rules.axis_size(dp_axes)
    assert B % dp_size == 0, "batch must divide the data axis"
    b_local = B // dp_size
    tokens = b_local * S
    C = int(math.ceil(e.capacity_factor * tokens * e.top_k / e.num_experts))
    C = min(C, tokens)

    cast = lambda w: w.astype(dtype)
    logits = (x @ cast(params["router"])).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(probs, e.top_k)
    top_v = top_v / jnp.maximum(jnp.sum(top_v, -1, keepdims=True), 1e-9)
    aux = _router_aux(probs, top_i, e.num_experts)

    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    tp_spec = tp_axes if len(tp_axes) > 1 else tp_axes[0]

    assert len(tp_axes) == 1, "expert parallelism expects a single tp axis"

    def body(xb, ti, tv, wi, wg, wo):
        # xb: (b_local, S, D) replicated over tp; wi/wg/wo: local experts
        xt = xb.reshape(tokens, D)
        ti = ti.reshape(tokens, e.top_k)
        tv = tv.reshape(tokens, e.top_k)
        tp_idx = jax.lax.axis_index(tp_axes[0])
        out = jnp.zeros((tokens, D), jnp.float32)
        for le in range(e_per):
            ge = tp_idx * e_per + le
            sel = jnp.any(ti == ge, axis=-1)
            gate = jnp.sum(jnp.where(ti == ge, tv, 0.0), axis=-1)
            # capacity-C gather of selected tokens (drop overflow)
            rank = jnp.cumsum(sel) - 1
            keep = sel & (rank < C)
            slot = jnp.where(keep, rank, C)
            buf = jnp.zeros((C + 1, D), dtype)
            buf = buf.at[slot].add(xt.astype(dtype))
            xe = buf[:C]
            up = xe @ cast(wi[le])
            gt = xe @ cast(wg[le])
            act = jax.nn.silu(gt) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(gt)
            ye = (act * up) @ cast(wo[le])                     # (C, D)
            # scatter back: token slots -> token rows
            back = jnp.zeros((tokens, D), jnp.float32)
            src_rows = jnp.where(keep, slot, C)
            ye_pad = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], 0)
            back = ye_pad[src_rows].astype(jnp.float32) * keep[:, None]
            out = out + back * gate[:, None]
        if psum_bf16:
            # local accumulation stays f32; only the cross-shard reduction
            # is bf16 (each token sums <= top_k non-zero contributions, so
            # the rounding is one bf16 quantisation per expert term)
            out = jax.lax.psum(out.astype(jnp.bfloat16), tp_axes)
        else:
            out = jax.lax.psum(out, tp_axes)
        return out.reshape(b_local, S, D).astype(xb.dtype)

    in_specs = (PS(dp_spec), PS(dp_spec), PS(dp_spec),
                PS(tp_spec), PS(tp_spec), PS(tp_spec))
    y = shard_map(
        body, mesh=rules.mesh,
        in_specs=in_specs, out_specs=PS(dp_spec),
        check_vma=False,
    )(x, top_i, top_v, params["wi"], params["wg"], params["wo"])
    if e.shared_expert:
        y = y + mlp(params["shared"], x, cfg.mlp_kind, dtype)
    return y, aux


# --------------------------------------------------------------------- #
# RG-LRU recurrent block (recurrentgemma / Griffin)
# --------------------------------------------------------------------- #
def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.recurrent.lru_width or d
    dc = cfg.recurrent.d_conv
    ks = jax.random.split(key, 6)
    params = {
        "w_in": _dense_init(ks[0], (d, w)),
        "w_gate": _dense_init(ks[1], (d, w)),
        "conv": _dense_init(ks[2], (dc, w)) * 0.1,
        "lam": jnp.full((w,), 4.0, jnp.float32),   # sigma(4)=0.982 slow decay
        "w_ig": jnp.ones((w,), jnp.float32) * 0.5,  # diagonal input gate
        "w_rg": jnp.ones((w,), jnp.float32) * 0.5,  # diagonal recurrence gate
        "w_out": _dense_init(ks[5], (w, d)),
    }
    specs = {"w_in": ("fsdp", "tp"), "w_gate": ("fsdp", "tp"),
             "conv": (None, "tp"), "lam": ("tp",), "w_ig": ("tp",),
             "w_rg": ("tp",), "w_out": ("tp", "fsdp")}
    return params, specs


_RGLRU_C = 8.0


def _rglru_coeffs(params, xw, dtype):
    """a_t, b_t of h_t = a_t h + b_t from the conv output xw (B, T, W)."""
    xf = xw.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * params["w_rg"])
    i = jax.nn.sigmoid(xf * params["w_ig"])
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, b


def _linear_scan_chunked(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t along axis 1, chunked associative scan.

    a, b: (B, T, W) f32; h0: (B, W).  Returns (h_all (B,T,W), h_last).
    Chunking bounds live memory to O(B * chunk * W) — the same round/L
    blocking as the paper's lattice rounds (DESIGN.md §2/§4).
    """
    B, T, W = a.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    ar = a.reshape(B, nc, chunk, W).transpose(1, 0, 2, 3)
    br = b.reshape(B, nc, chunk, W).transpose(1, 0, 2, 3)

    def step(h, ab):
        ac, bc = ab

        def comb(l, r):
            al, bl = l
            ar_, br_ = r
            return al * ar_, bl * ar_ + br_

        aa, bb = jax.lax.associative_scan(comb, (ac, bc), axis=1)
        h_all = aa * h[:, None, :] + bb
        return h_all[:, -1, :], h_all

    h_last, h_seq = jax.lax.scan(step, h0, (ar, br))
    h_seq = h_seq.transpose(1, 0, 2, 3).reshape(B, T, W)
    return h_seq, h_last


def rglru_block(params, x, cfg: ModelConfig, *, state=None, chunk: int = 1024,
                dtype=DEFAULT_DTYPE):
    """Returns (out, new_state); state = (conv_state, h) for decode."""
    cast = lambda w: w.astype(dtype)
    B, T, _ = x.shape
    w = cfg.recurrent.lru_width or cfg.d_model
    dc = cfg.recurrent.d_conv
    xb = x @ cast(params["w_in"])                      # (B, T, W)
    gate = x @ cast(params["w_gate"])
    conv_w = cast(params["conv"])                      # (dc, W)
    if state is None:
        conv_state = jnp.zeros((B, dc - 1, w), dtype)
        h0 = jnp.zeros((B, w), jnp.float32)
    else:
        conv_state, h0 = state
    xpad = jnp.concatenate([conv_state, xb], axis=1)
    xc = sum(xpad[:, i:i + T, :] * conv_w[i] for i in range(dc))
    new_conv_state = xpad[:, -(dc - 1):, :] if dc > 1 else conv_state
    a, b = _rglru_coeffs(params, xc, dtype)
    if T == 1:
        h = a[:, 0] * h0 + b[:, 0]
        h_seq = h[:, None, :]
        h_last = h
    else:
        h_seq, h_last = _linear_scan_chunked(a, b, h0, chunk)
    out = (h_seq.astype(dtype) * jax.nn.gelu(gate)) @ cast(params["w_out"])
    return out, (new_conv_state, h_last)


# --------------------------------------------------------------------- #
# Mamba-1 selective SSM block (falcon-mamba)
# --------------------------------------------------------------------- #
def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    ds = s.d_state
    dtr = s.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 6)
    params = {
        "in_proj": _dense_init(ks[0], (d, 2 * di)),
        "conv": _dense_init(ks[1], (s.d_conv, di)) * 0.1,
        "x_proj": _dense_init(ks[2], (di, dtr + 2 * ds)),
        "dt_proj": _dense_init(ks[3], (dtr, di)),
        "dt_bias": jnp.zeros((di,), jnp.float32) + math.log(math.e - 1.0),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[5], (di, d)),
    }
    specs = {"in_proj": ("fsdp", "tp"), "conv": (None, "tp"),
             "x_proj": ("tp", None), "dt_proj": (None, "tp"),
             "dt_bias": ("tp",), "A_log": ("tp", None), "D": ("tp",),
             "out_proj": ("tp", "fsdp")}
    return params, specs


def mamba_block(params, x, cfg: ModelConfig, *, state=None, chunk: int = 512,
                dtype=DEFAULT_DTYPE):
    """Returns (out, new_state); state = (conv_state, h (B, di, ds))."""
    cast = lambda w: w.astype(dtype)
    B, T, _ = x.shape
    s = cfg.ssm
    di = s.expand * cfg.d_model
    ds = s.d_state
    xz = x @ cast(params["in_proj"])
    xb, z = jnp.split(xz, 2, axis=-1)                   # (B,T,di) each
    conv_w = cast(params["conv"])
    if state is None:
        conv_state = jnp.zeros((B, s.d_conv - 1, di), dtype)
        h0 = jnp.zeros((B, di, ds), jnp.float32)
    else:
        conv_state, h0 = state
    xpad = jnp.concatenate([conv_state, xb], axis=1)
    xc = sum(xpad[:, i:i + T, :] * conv_w[i] for i in range(s.d_conv))
    new_conv_state = xpad[:, -(s.d_conv - 1):, :]
    xc = jax.nn.silu(xc)

    proj = xc @ cast(params["x_proj"])                  # (B,T,dtr+2ds)
    dtr = params["dt_proj"].shape[0]
    dt, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus((dt @ cast(params["dt_proj"])).astype(jnp.float32)
                            + params["dt_bias"])       # (B,T,di)
    A = -jnp.exp(params["A_log"])                      # (di, ds)
    a = jnp.exp(delta[..., None] * A)                  # (B,T,di,ds)
    bx = (delta * xc.astype(jnp.float32))[..., None] * \
        Bc.astype(jnp.float32)[:, :, None, :]          # (B,T,di,ds)

    if T == 1:
        h = a[:, 0] * h0 + bx[:, 0]
        y = jnp.einsum("bds,bs->bd", h, Cc[:, 0].astype(jnp.float32))[:, None]
        h_last = h
    else:
        af = a.reshape(B, T, di * ds)
        bf = bx.reshape(B, T, di * ds)
        h_seq, h_last = _linear_scan_chunked(af, bf, h0.reshape(B, di * ds),
                                             chunk)
        h_seq = h_seq.reshape(B, T, di, ds)
        h_last = h_last.reshape(B, di, ds)
        y = jnp.einsum("btds,bts->btd", h_seq, Cc.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * params["D"]
    out = (y.astype(dtype) * jax.nn.silu(z)) @ cast(params["out_proj"])
    return out, (new_conv_state, h_last)
