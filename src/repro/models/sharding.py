"""Logical->physical sharding rules.

Parameters and activations are annotated with *logical* axes; a
``MeshRules`` instance maps them onto the production mesh's physical axes
(single-pod ``(data, model)`` or multi-pod ``(pod, data, model)``):

    fsdp  — parameter / optimizer-state sharding axis (ZeRO-3 style);
            maps to ("data",) or ("pod", "data")
    tp    — tensor-parallel axis (heads / ffn / vocab / experts);
            maps to ("model",)
    dp    — batch axis for activations; same physical axes as fsdp

Divisibility fallback: a dimension that does not divide by the physical
axis size is replicated instead (e.g. recurrentgemma's 10 attention heads
on a 16-way model axis) — recorded so EXPERIMENTS.md can report it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as PS

__all__ = ["MeshRules", "logical"]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    fsdp: Tuple[str, ...] = ("data",)
    tp: Tuple[str, ...] = ("model",)

    @property
    def dp(self) -> Tuple[str, ...]:
        return self.fsdp

    def axis_size(self, names: Tuple[str, ...]) -> int:
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n

    def resolve(self, logical_axis: Optional[str], dim_size: int):
        """Map one logical axis name to mesh axes, with divisibility check."""
        if logical_axis is None:
            return None
        names = {"fsdp": self.fsdp, "dp": self.fsdp, "tp": self.tp}[logical_axis]
        if not names:                        # axis role absent on this mesh
            return None
        if dim_size % self.axis_size(names) != 0:
            return None                      # replicate (fallback)
        return names if len(names) > 1 else names[0]

    def spec(self, *axes: Optional[str], shape: Optional[Tuple[int, ...]] = None) -> PS:
        """Build a PartitionSpec from logical axis names.

        ``shape`` (same length) enables the divisibility fallback; without
        it the mapping is unchecked.
        """
        out = []
        for i, ax in enumerate(axes):
            size = shape[i] if shape is not None else 0
            if ax is None:
                out.append(None)
            elif shape is None:
                names = {"fsdp": self.fsdp, "dp": self.fsdp,
                         "tp": self.tp}[ax]
                out.append(names if len(names) > 1 else names[0])
            else:
                out.append(self.resolve(ax, size))
        return PS(*out)


def logical(x: jax.Array, rules: MeshRules, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axis names (shape-checked)."""
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh,
                                      rules.spec(*axes, shape=x.shape)))
