"""Model assembly: decoder-only LMs, hybrid stacks, and enc-dec backbones.

A model is ``(params, specs)`` pytrees + pure apply functions.  The layer
stack is grouped into *pattern repetitions* so homogeneous runs compile as
one ``lax.scan`` step (critical for 40-64 layer dry-run compile times):

    reps = n_layers // len(block_pattern)  -> scanned super-block
    rem  = n_layers %  len(block_pattern)  -> unrolled remainder layers

Each super-block applies the config's block pattern in order (e.g.
recurrentgemma's (rglru, rglru, local)).  Every block is pre-norm with a
residual; attention-bearing blocks carry an FFN (or MoE) sub-layer, mamba
blocks are single-mixer (d_ff = 0).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .sharding import MeshRules, logical

Params = Dict[str, Any]


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _init_block(key, cfg: ModelConfig, kind: str, cross: bool = False):
    """One block's params/specs. kind in {attn, local, rglru, mamba}."""
    ks = jax.random.split(key, 6)
    p: Params = {}
    s: Params = {}
    p["norm1"], s["norm1"] = L.init_rmsnorm(cfg.d_model)
    if kind in ("attn", "local"):
        p["attn"], s["attn"] = L.init_attention(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"], s["rglru"] = L.init_rglru(ks[0], cfg)
    elif kind == "mamba":
        p["mamba"], s["mamba"] = L.init_mamba(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"], s["norm_x"] = L.init_rmsnorm(cfg.d_model)
        p["cross"], s["cross"] = L.init_attention(ks[1], cfg)
    if kind != "mamba":
        p["norm2"], s["norm2"] = L.init_rmsnorm(cfg.d_model)
        if cfg.moe is not None:
            p["moe"], s["moe"] = L.init_moe(ks[2], cfg)
        elif cfg.d_ff:
            p["mlp"], s["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                            cfg.mlp_kind)
    return p, s


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_specs(spec):
    return jax.tree.map(lambda s: (None,) + tuple(s), spec,
                        is_leaf=lambda x: isinstance(x, tuple))


def init_lm(key, cfg: ModelConfig):
    """Returns (params, specs) for the full model."""
    pat = cfg.block_pattern
    reps = cfg.n_layers // len(pat)
    rem = cfg.n_layers % len(pat)
    n_keys = reps * len(pat) + rem + cfg.n_encoder_layers + 8
    keys = jax.random.split(key, n_keys)
    ki = iter(range(len(keys)))

    params: Params = {}
    specs: Params = {}
    params["embed"] = L._dense_init(keys[next(ki)], (cfg.vocab, cfg.d_model))
    specs["embed"] = ("tp", "fsdp")

    cross = cfg.n_encoder_layers > 0

    def make_stack(n_reps, with_cross):
        ps, ss = [], []
        for _ in range(n_reps):
            pp, sp = {}, {}
            for j, kind in enumerate(pat):
                pp[f"b{j}"], sp[f"b{j}"] = _init_block(
                    keys[next(ki)], cfg, kind, cross=with_cross)
            ps.append(pp)
            ss.append(sp)
        return (_stack(ps) if n_reps > 1 else ps[0],
                _stack_specs(ss[0]) if n_reps > 1 else ss[0])

    if reps > 0:
        params["scan"], specs["scan"] = make_stack(reps, cross)
    for r in range(rem):
        kind = pat[r % len(pat)]
        params[f"rem{r}"], specs[f"rem{r}"] = _init_block(
            keys[next(ki)], cfg, kind, cross=cross)

    if cfg.n_encoder_layers:
        enc_reps = cfg.n_encoder_layers // len(pat)
        pe, se = [], []
        for _ in range(enc_reps):
            pp, sp = {}, {}
            for j, kind in enumerate(pat):
                pp[f"b{j}"], sp[f"b{j}"] = _init_block(keys[next(ki)], cfg, kind)
            pe.append(pp)
            se.append(sp)
        params["enc_scan"] = _stack(pe) if enc_reps > 1 else pe[0]
        specs["enc_scan"] = _stack_specs(se[0]) if enc_reps > 1 else se[0]
        params["enc_norm"], specs["enc_norm"] = L.init_rmsnorm(cfg.d_model)

    params["final_norm"], specs["final_norm"] = L.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(keys[next(ki)], (cfg.d_model, cfg.vocab))
        specs["lm_head"] = ("fsdp", "tp")
    return params, specs


# --------------------------------------------------------------------- #
# block application
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RunCfg:
    impl: str = "naive"            # attention impl: naive | flash
    dtype: Any = L.DEFAULT_DTYPE
    remat: str = "none"            # none | full | dots
    scan_chunk: int = 1024         # linear-recurrence chunk length
    moe_impl: str = "dense"        # dense | dispatch
    unroll: bool = False           # unroll layer scans (cost calibration:
                                   # XLA cost_analysis counts loop bodies
                                   # once, so rooflines are extracted from
                                   # unrolled truncated configs)
    attn_q_chunk: int = 1024       # flash attention tile sizes; calibration
    attn_kv_chunk: int = 1024      # sets these to the full sequence so the
                                   # attention loop collapses to one body
    moe_psum_bf16: bool = False    # bf16 cross-shard MoE combine (§Perf)


def _apply_block(p, x, cfg: ModelConfig, kind: str, run: RunCfg,
                 rules: Optional[MeshRules], *, positions, causal=True,
                 enc_out=None, state=None):
    """Pre-norm block (train/prefill path). Returns (x, state, kv, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    window = cfg.local_window if kind == "local" else None
    new_state, new_kv = state, None
    if kind in ("attn", "local"):
        out, (k, v) = L.attention(
            p["attn"], h, cfg, positions=positions, causal=causal,
            window=window, impl=run.impl, dtype=run.dtype,
            q_chunk=run.attn_q_chunk, kv_chunk=run.attn_kv_chunk)
        new_kv = (k, v)
    elif kind == "rglru":
        out, new_state = L.rglru_block(p["rglru"], h, cfg, state=state,
                                       chunk=run.scan_chunk, dtype=run.dtype)
    elif kind == "mamba":
        out, new_state = L.mamba_block(p["mamba"], h, cfg, state=state,
                                       chunk=run.scan_chunk, dtype=run.dtype)
    x = x + out
    if enc_out is not None:
        hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        out, _ = L.attention(p["cross"], hx, cfg, x_kv=enc_out,
                             positions=positions, causal=False,
                             impl=run.impl, dtype=run.dtype,
                             q_chunk=run.attn_q_chunk,
                             kv_chunk=run.attn_kv_chunk)
        x = x + out
    if kind != "mamba":
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            if run.moe_impl == "dispatch" and rules is not None:
                mo, aux = L.moe_dispatch(p["moe"], h2, cfg, rules, run.dtype,
                                         psum_bf16=run.moe_psum_bf16)
            else:
                mo, aux = L.moe_dense(p["moe"], h2, cfg, run.dtype)
        elif cfg.d_ff:
            mo = L.mlp(p["mlp"], h2, cfg.mlp_kind, run.dtype)
        else:
            mo = jnp.zeros_like(x)
        x = x + mo
    if rules is not None:
        x = logical(x, rules, "dp", None, None)
    return x, new_state, new_kv, aux


def _super_block(pp, x, cfg, run, rules, *, positions, causal, enc_out,
                 states, decode=False):
    """Apply the whole block pattern once. states: per-sub-block pytrees."""
    new_states = []
    new_kvs = []
    aux_total = jnp.zeros((), jnp.float32)
    for j, kind in enumerate(cfg.block_pattern):
        st = states[j] if states is not None else None
        x, ns, nkv, aux = _apply_block(
            pp[f"b{j}"], x, cfg, kind, run, rules, positions=positions,
            causal=causal, enc_out=enc_out, state=st)
        new_states.append(ns)
        new_kvs.append(nkv)
        aux_total = aux_total + aux
    return x, new_states, new_kvs, aux_total


def _maybe_remat(fn, run: RunCfg):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------- #
def embed_tokens(params, tokens, cfg: ModelConfig, run: RunCfg,
                 rules: Optional[MeshRules]):
    emb = params["embed"].astype(run.dtype)
    x = emb[tokens]
    if rules is not None:
        x = logical(x, rules, "dp", None, None)
    return x


def _run_stack(params, x, cfg: ModelConfig, run: RunCfg, rules, *,
               positions, causal=True, enc_out=None, prefix=""):
    """Scan + remainder over the layer stack.

    Returns (x, aux, groups) where groups is a dict mirroring the param
    grouping: {"scan": (kvs, states), "rem{r}": (kv, state)} — consumed by
    :func:`prefill` to build a decode cache.
    """
    pat = cfg.block_pattern
    n_layers = cfg.n_encoder_layers if prefix == "enc_" else cfg.n_layers
    reps = n_layers // len(pat)
    rem = n_layers % len(pat)
    aux_total = jnp.zeros((), jnp.float32)
    groups = {}

    scan_key = prefix + "scan"
    if reps == 1:
        x, states, kv, aux = _super_block(params[scan_key], x, cfg, run, rules,
                                          positions=positions, causal=causal,
                                          enc_out=enc_out, states=None)
        aux_total += aux
        groups["scan"] = (kv, states)
    elif reps > 1:
        def body(carry, pp):
            x, aux = carry
            x, states, kv, a = _super_block(pp, x, cfg, run, rules,
                                            positions=positions, causal=causal,
                                            enc_out=enc_out, states=None)
            return (x, aux + a), (kv, states)
        body = _maybe_remat(body, run)
        (x, aux_total), (kv_stack, state_stack) = jax.lax.scan(
            body, (x, aux_total), params[scan_key],
            unroll=reps if run.unroll else 1)
        groups["scan"] = (kv_stack, state_stack)
    for r in range(rem):
        kind = pat[r % len(pat)]
        x, st, nkv, aux = _apply_block(
            params[prefix + f"rem{r}"], x, cfg, kind, run, rules,
            positions=positions, causal=causal, enc_out=enc_out, state=None)
        aux_total += aux
        groups[f"rem{r}"] = ([nkv], [st])
    return x, aux_total, groups


def lm_loss(params, batch, cfg: ModelConfig, run: RunCfg,
            rules: Optional[MeshRules] = None):
    """Causal-LM (or enc-dec) cross entropy. batch: tokens/targets (+ enc)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)

    enc_out = None
    if cfg.n_encoder_layers:
        if cfg.frontend == "audio_stub":
            xe = batch["enc_embeds"].astype(run.dtype)   # (B, S_enc, D)
        else:
            xe = embed_tokens(params, batch["enc_tokens"], cfg, run, rules)
        pe = jnp.arange(xe.shape[1])
        xe, _, _ = _run_stack(params, xe, cfg, run, rules, positions=pe,
                              causal=False, prefix="enc_")
        enc_out = L.rmsnorm(params["enc_norm"], xe, cfg.norm_eps)

    x = embed_tokens(params, tokens, cfg, run, rules)
    x, aux, _ = _run_stack(params, x, cfg, run, rules, positions=positions,
                           causal=True, enc_out=enc_out)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(run.dtype)
    if rules is not None:
        logits = logical(logits, rules, "dp", None, "tp")
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=jnp.float32)
    true_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    nll = (lse - true_logit) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    metrics = {"loss": loss, "aux_loss": aux,
               "tokens": jnp.sum(mask)}
    return loss + 0.01 * aux, metrics


# --------------------------------------------------------------------- #
# decode (one token against a KV cache / recurrent state)
# --------------------------------------------------------------------- #
def init_cache_block(cfg: ModelConfig, kind: str, B: int, max_len: int,
                     dtype, cross_len: int = 0):
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    c: Dict[str, Any] = {}
    if kind in ("attn", "local"):
        c["k"] = jnp.zeros((B, max_len, kvh, hd), dtype)
        c["v"] = jnp.zeros((B, max_len, kvh, hd), dtype)
    elif kind == "rglru":
        w = cfg.recurrent.lru_width or cfg.d_model
        c["conv"] = jnp.zeros((B, cfg.recurrent.d_conv - 1, w), dtype)
        c["h"] = jnp.zeros((B, w), jnp.float32)
    elif kind == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        c["conv"] = jnp.zeros((B, cfg.ssm.d_conv - 1, di), dtype)
        c["h"] = jnp.zeros((B, di, cfg.ssm.d_state), jnp.float32)
    if cross_len:
        c["xk"] = jnp.zeros((B, cross_len, kvh, hd), dtype)
        c["xv"] = jnp.zeros((B, cross_len, kvh, hd), dtype)
    return c


def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=L.DEFAULT_DTYPE,
               cross_len: int = 0):
    """Cache pytree mirroring the stack grouping of init_lm."""
    pat = cfg.block_pattern
    reps = cfg.n_layers // len(pat)
    rem = cfg.n_layers % len(pat)
    mk = lambda kind: init_cache_block(cfg, kind, B, max_len, dtype, cross_len)
    cache: Dict[str, Any] = {}
    if reps >= 1:
        one = {f"b{j}": mk(k) for j, k in enumerate(pat)}
        if reps > 1:
            cache["scan"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (reps,) + a.shape), one)
        else:
            cache["scan"] = one
    for r in range(rem):
        cache[f"rem{r}"] = mk(pat[r % len(pat)])
    return cache


def _decode_block(p, c, x, cfg: ModelConfig, kind: str, run: RunCfg,
                  rules, *, pos, enc_out_used: bool):
    """One block, T = 1, against its cache slice. Returns (x, new_cache)."""
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    window = cfg.local_window if kind == "local" else None
    newc = dict(c)
    B = x.shape[0]
    positions = jnp.full((1,), pos)
    if kind in ("attn", "local"):
        hd = cfg.resolved_head_dim
        kvh = cfg.n_kv_heads
        g = cfg.n_heads // kvh
        cast = lambda w_: w_.astype(run.dtype)
        q = h @ cast(p["attn"]["wq"])
        k = h @ cast(p["attn"]["wk"])
        v = h @ cast(p["attn"]["wv"])
        if cfg.qkv_bias:
            q = q + cast(p["attn"]["bq"])
            k = k + cast(p["attn"]["bk"])
            v = v + cast(p["attn"]["bv"])
        q = q.reshape(B, 1, kvh, g, hd)
        k = k.reshape(B, 1, kvh, hd)
        v = v.reshape(B, 1, kvh, hd)
        if cfg.qk_norm:
            q = L._qk_head_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
            k = L._qk_head_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
        q = L.apply_rope(q.reshape(B, 1, kvh * g, hd), positions,
                         cfg.rope_theta).reshape(B, 1, kvh, g, hd)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(c["k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(c["v"], v, pos, axis=1)
        newc["k"], newc["v"] = ck, cv
        S = ck.shape[1]
        pos_k = jnp.arange(S)
        live = pos_k <= pos
        if window is not None:
            live &= pos - pos_k < window
        bias = jnp.where(live, 0.0, -jnp.inf).astype(jnp.float32)[None, :]
        out = L._attn_naive(q, ck, cv, bias)
        out = out.reshape(B, 1, cfg.n_heads * hd) @ cast(p["attn"]["wo"])
    elif kind == "rglru":
        out, (conv, hh) = L.rglru_block(p["rglru"], h, cfg,
                                        state=(c["conv"], c["h"]),
                                        dtype=run.dtype)
        newc["conv"], newc["h"] = conv, hh
    elif kind == "mamba":
        out, (conv, hh) = L.mamba_block(p["mamba"], h, cfg,
                                        state=(c["conv"], c["h"]),
                                        dtype=run.dtype)
        newc["conv"], newc["h"] = conv, hh
    x = x + out
    if enc_out_used:
        hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        qx, _ = L.attention(p["cross"], hx, cfg,
                            kv=(c["xk"], c["xv"]),
                            positions=positions, causal=False,
                            impl="naive", dtype=run.dtype,
                            use_rope=False)
        x = x + qx
    if kind != "mamba":
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            mo, _ = L.moe_dense(p["moe"], h2, cfg, run.dtype)
        elif cfg.d_ff:
            mo = L.mlp(p["mlp"], h2, cfg.mlp_kind, run.dtype)
        else:
            mo = jnp.zeros_like(x)
        x = x + mo
    if rules is not None:
        x = logical(x, rules, "dp", None, None)
    return x, newc


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, run: RunCfg,
                rules: Optional[MeshRules] = None):
    """One decoding step. tokens: (B, 1) int32; pos: scalar int32.

    Returns (logits (B, 1, V), new_cache).  For enc-dec models the cross
    K/V live in the cache (filled at prefill from the encoder output).
    """
    pat = cfg.block_pattern
    reps = cfg.n_layers // len(pat)
    rem = cfg.n_layers % len(pat)
    cross = cfg.n_encoder_layers > 0
    x = embed_tokens(params, tokens, cfg, run, rules)

    def super_dec(pp, cc, x):
        newc = dict(cc)
        for j, kind in enumerate(pat):
            x, nc = _decode_block(pp[f"b{j}"], cc[f"b{j}"], x, cfg, kind, run,
                                  rules, pos=pos, enc_out_used=cross)
            newc[f"b{j}"] = nc
        return x, newc

    if reps == 1:
        x, cache_scan = super_dec(params["scan"], cache["scan"], x)
        cache = dict(cache, scan=cache_scan)
    elif reps > 1:
        def body(x, pc):
            pp, cc = pc
            x, nc = super_dec(pp, cc, x)
            return x, nc
        x, new_scan = jax.lax.scan(body, x, (params["scan"], cache["scan"]),
                                   unroll=reps if run.unroll else 1)
        cache = dict(cache, scan=new_scan)
    for r in range(rem):
        kind = pat[r % len(pat)]
        x, nc = _decode_block(params[f"rem{r}"], cache[f"rem{r}"], x, cfg,
                              kind, run, rules, pos=pos, enc_out_used=cross)
        cache = dict(cache, **{f"rem{r}": nc})

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(run.dtype)
    if rules is not None:
        logits = logical(logits, rules, "dp", None, "tp")
    return logits.astype(jnp.float32), cache


# --------------------------------------------------------------------- #
# prefill: full forward that returns last-token logits + a decode cache
# --------------------------------------------------------------------- #
def prefill(params, batch, cfg: ModelConfig, run: RunCfg,
            rules: Optional[MeshRules] = None, max_len: Optional[int] = None):
    """Serve-side prefill. batch: tokens (B, S) (+ enc inputs for enc-dec).

    Returns (last_logits (B, V), cache) with the KV cache filled for
    positions [0, S) (cache length = max_len or S).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    positions = jnp.arange(S)
    cross_len = 0

    enc_out = None
    if cfg.n_encoder_layers:
        if cfg.frontend == "audio_stub":
            xe = batch["enc_embeds"].astype(run.dtype)
        else:
            xe = embed_tokens(params, batch["enc_tokens"], cfg, run, rules)
        pe = jnp.arange(xe.shape[1])
        xe, _, _ = _run_stack(params, xe, cfg, run, rules, positions=pe,
                              causal=False, prefix="enc_")
        enc_out = L.rmsnorm(params["enc_norm"], xe, cfg.norm_eps)
        cross_len = enc_out.shape[1]

    x = embed_tokens(params, tokens, cfg, run, rules)
    x, _, groups = _run_stack(params, x, cfg, run, rules, positions=positions,
                              causal=True, enc_out=enc_out)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    last_logits = (x[:, -1] @ head.astype(run.dtype)).astype(jnp.float32)

    # ---- assemble the decode cache ------------------------------------
    cache = init_cache(cfg, B, max_len, run.dtype, cross_len=cross_len)

    def fill_kv(c, kv, state, stacked: bool):
        newc = dict(c)
        if kv is not None:
            k, v = kv
            if stacked:
                newc["k"] = jax.lax.dynamic_update_slice(
                    c["k"], k.astype(c["k"].dtype), (0, 0, 0, 0, 0))
                newc["v"] = jax.lax.dynamic_update_slice(
                    c["v"], v.astype(c["v"].dtype), (0, 0, 0, 0, 0))
            else:
                newc["k"] = jax.lax.dynamic_update_slice(
                    c["k"], k.astype(c["k"].dtype), (0, 0, 0, 0))
                newc["v"] = jax.lax.dynamic_update_slice(
                    c["v"], v.astype(c["v"].dtype), (0, 0, 0, 0))
        if state is not None:
            conv, h = state
            newc["conv"] = conv.astype(c["conv"].dtype)
            newc["h"] = h
        if cross_len and enc_out is not None:
            pass  # filled below (cross kv shared per block)
        return newc

    pat = cfg.block_pattern
    reps = cfg.n_layers // len(pat)
    if reps >= 1:
        kvs, states = groups["scan"]
        stacked = reps > 1
        new_scan = {}
        for j in range(len(pat)):
            new_scan[f"b{j}"] = fill_kv(cache["scan"][f"b{j}"], kvs[j],
                                        states[j], stacked)
        cache["scan"] = new_scan
    rem = cfg.n_layers % len(pat)
    for r in range(rem):
        kvs, states = groups[f"rem{r}"]
        cache[f"rem{r}"] = fill_kv(cache[f"rem{r}"], kvs[0], states[0], False)

    # cross-attention K/V (enc-dec): computed once from the encoder output
    if cross_len:
        def fill_cross(c, p, stacked: bool):
            cast = lambda w: w.astype(run.dtype)
            if stacked:
                # p["cross"]["wk"]: (reps, D, KVH*hd)
                xk = jnp.einsum("bsd,rdk->rbsk", enc_out, cast(p["cross"]["wk"]))
                xv = jnp.einsum("bsd,rdk->rbsk", enc_out, cast(p["cross"]["wv"]))
                hd = cfg.resolved_head_dim
                xk = xk.reshape(xk.shape[:3] + (cfg.n_kv_heads, hd))
                xv = xv.reshape(xv.shape[:3] + (cfg.n_kv_heads, hd))
            else:
                xk, _ = None, None
                kproj = enc_out @ cast(p["cross"]["wk"])
                vproj = enc_out @ cast(p["cross"]["wv"])
                hd = cfg.resolved_head_dim
                xk = kproj.reshape(B, cross_len, cfg.n_kv_heads, hd)
                xv = vproj.reshape(B, cross_len, cfg.n_kv_heads, hd)
            c = dict(c)
            c["xk"], c["xv"] = xk.astype(run.dtype), xv.astype(run.dtype)
            return c

        if reps >= 1:
            stacked = reps > 1
            new_scan = dict(cache["scan"])
            for j in range(len(pat)):
                pj = params["scan"][f"b{j}"]
                new_scan[f"b{j}"] = fill_cross(new_scan[f"b{j}"], pj, stacked)
            cache["scan"] = new_scan
        for r in range(rem):
            cache[f"rem{r}"] = fill_cross(cache[f"rem{r}"],
                                          params[f"rem{r}"], False)
    return last_logits, cache
