"""Ring attention — sequence(context)-parallel exact attention.

The prefill_32k cells shard the batch only; at long context the S×S score
working set per device grows quadratically.  Ring attention shards the
*sequence* over the tp axis and rotates KV blocks around the ring with one
``ppermute`` per step, merging partial results with the online-softmax
rule — the same rotate-halo-and-accumulate structure as the paper's
lattice rounds (a KV block is a halo that visits every shard instead of
only its neighbour).

Exactness: identical math to flash attention — per-step partial
(m, l, acc) merged across ring steps; validated against the naive
materialised-scores oracle on virtual devices
(tests/test_context_parallel.py).

Layout (inside shard_map over ``axis_name``):
    q, k, v: (B, S_local, KVH[, G], hd) — the global sequence is the
    concatenation over shards; causal masking uses global positions.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS

from ..compat import axis_size, shard_map

__all__ = ["ring_attention_local", "make_ring_attention"]

_NEG = -1e30


def ring_attention_local(q, k, v, axis_name: str, *, causal: bool = True,
                         window: Optional[int] = None):
    """Per-shard body (call inside shard_map).

    q: (B, Sl, KVH, G, hd); k, v: (B, Sl, KVH, hd).  Returns (B, Sl, KVH,
    G, hd) — exact global attention over the ring.
    """
    W = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    B, Sl, KVH, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    pos_q = (me * Sl + jnp.arange(Sl))[:, None]          # (Sl, 1)
    perm = [(i, (i - 1) % W) for i in range(W)]          # kv moves left

    def step(j, carry):
        m, l, acc, kj, vj = carry
        src = (me + j) % W                               # kv block origin
        pos_k = (src * Sl + jnp.arange(Sl))[None, :]     # (1, Sl)
        s = jnp.einsum("bqkgh,bskh->bkgqs", q, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sl, Sl), bool)
        if causal:
            mask &= pos_q >= pos_k
        if window is not None:
            mask &= pos_q - pos_k < window
        s = jnp.where(mask[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        kj = jax.lax.ppermute(kj, axis_name, perm)
        vj = jax.lax.ppermute(vj, axis_name, perm)
        return m_new, l_new, acc_new, kj, vj

    m0 = jnp.full((B, KVH, G, Sl), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sl), jnp.float32)
    a0 = jnp.zeros((B, Sl, KVH, G, hd), jnp.float32)
    m, l, acc, _, _ = jax.lax.fori_loop(0, W, step, (m0, l0, a0, k, v))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(v.dtype)


def make_ring_attention(mesh: Mesh, axis_name: str = "model", *,
                        causal: bool = True, window: Optional[int] = None):
    """Host-level wrapper: q (B, S, KVH, G, hd), k/v (B, S, KVH, hd) with S
    sharded over ``axis_name``; returns the same global result as
    single-device attention."""
    body = partial(ring_attention_local, axis_name=axis_name, causal=causal,
                   window=window)
    seq_spec = PS(None, axis_name)
    return shard_map(
        body, mesh=mesh,
        in_specs=(seq_spec, seq_spec, seq_spec),
        out_specs=seq_spec,
        check_vma=False)
