"""Token data pipeline: deterministic synthetic stream + memmap corpus.

Production shape: the trainer asks for global batches of
(n_micro, global_batch/n_micro, seq_len) int32 tokens; the pipeline builds
them on host and device_puts with the batch NamedSharding (so each host
only materialises its addressable shard in a real multi-host setting —
here single-host, the slicing path is exercised through the same API).

Sources:
  * ``SyntheticSource`` — deterministic per-step PRNG tokens; loss curves
    are reproducible across restarts (checkpoint/restart tests rely on it).
  * ``MemmapSource``    — flat binary token file (np.memmap), sharded by
    step offset; the standard "tokenized corpus on disk" format.

Both expose ``batch(step) -> np.ndarray`` so the trainer is source-
agnostic and *stateless* (resume = seek by step, no iterator state in the
checkpoint).
"""
from __future__ import annotations

import dataclasses
import threading
from queue import Queue
from typing import Iterator, Optional

import jax
import numpy as np

__all__ = ["SyntheticSource", "MemmapSource", "Prefetcher", "make_batches"]


@dataclasses.dataclass
class SyntheticSource:
    vocab: int
    global_batch: int
    seq_len: int
    n_micro: int = 1
    seed: int = 0

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        mb = self.global_batch // self.n_micro
        toks = rng.integers(
            0, self.vocab, (self.n_micro, mb, self.seq_len + 1), np.int32)
        return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}


@dataclasses.dataclass
class MemmapSource:
    path: str
    vocab: int
    global_batch: int
    seq_len: int
    n_micro: int = 1

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self.n_tokens = self._data.shape[0]

    def batch(self, step: int) -> dict:
        mb = self.global_batch // self.n_micro
        need = self.global_batch * (self.seq_len + 1)
        start = (step * need) % max(self.n_tokens - need, 1)
        flat = np.asarray(self._data[start:start + need])
        toks = flat.reshape(self.n_micro, mb, self.seq_len + 1)
        return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}


class Prefetcher:
    """Background-thread prefetch of device-put batches (depth-bounded)."""

    def __init__(self, source, sharding=None, depth: int = 2,
                 start_step: int = 0):
        self.source = source
        self.sharding = sharding
        self.q: Queue = Queue(maxsize=depth)
        self.step = start_step
        self._stop = False
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        while not self._stop:
            b = self.source.batch(self.step)
            if self.sharding is not None:
                b = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), b,
                    self.sharding if isinstance(self.sharding, dict)
                    else jax.tree.map(lambda _: self.sharding, b))
            self.q.put((self.step, b))
            self.step += 1

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop = True
        try:
            while True:
                self.q.get_nowait()
        except Exception:
            pass


def make_batches(source, sharding=None, start_step: int = 0) -> Iterator:
    """Simple (non-threaded) batch iterator; deterministic, resumable."""
    step = start_step
    while True:
        b = source.batch(step)
        if sharding is not None:
            b = jax.tree.map(lambda a: jax.device_put(a, sharding), b)
        yield step, b
        step += 1
