"""Checkpointing: atomic save, async save, topology-aware restore.

Format: one directory per step containing
  * ``meta.json``      — step, tree structure, leaf paths/dtypes/shapes
  * ``arrays.npz``     — every leaf, keyed by its flattened tree path

Fault-tolerance properties:
  * **atomic**: writes land in ``<dir>/tmp.<step>`` and are renamed into
    place only after fsync — a killed process never leaves a torn
    checkpoint (restore picks the newest *complete* step).
  * **async**: ``AsyncCheckpointer`` snapshots to host (device_get) on the
    caller's thread, then serialises on a background thread so the train
    loop only blocks for the device->host copy.
  * **topology-aware restore**: leaves are restored as numpy then
    device_put with the *target* sharding — restarting on a different mesh
    (elastic up/down-scaling, the multi-pod <-> single-pod case) is just
    ``restore(dir, like=state_sds, sharding=new_shardings)``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_SEP = "/"

# np.savez cannot serialise ml_dtypes (bf16/f8) natively: bit-cast on save,
# view back on restore using the logical dtype recorded in meta.json.
_BITCAST = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _flatten(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    dtypes = {}
    for path, leaf in leaves_with_paths:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if str(arr.dtype) in _BITCAST:
            arr = arr.view(_BITCAST[str(arr.dtype)][0])
        out[key] = arr
    return out, dtypes


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    """Synchronous atomic checkpoint write."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp.{step}"
    final = ckpt_dir / f"step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, dtypes = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    meta = {"step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                       for k, v in flat.items()}}
    with open(tmp / "meta.json", "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "meta.json").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: Optional[int] = None, *,
            like: Any, sharding: Any = None) -> Any:
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs).  ``sharding``: optional matching pytree of
    NamedShardings for the *current* mesh (elastic restore)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:010d}"
    data = np.load(d / "arrays.npz")
    with open(d / "meta.json") as f:
        meta = json.load(f)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(sharding)
                    if sharding is not None else [None] * len(leaves_with_paths))
    out = []
    for (path, leaf), sh in zip(leaves_with_paths, shard_leaves):
        key = _SEP.join(_path_str(p) for p in path)
        arr = data[key]
        logical = meta["leaves"][key]["dtype"]
        if logical in _BITCAST:
            arr = arr.view(_BITCAST[logical][1])
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        if arr.dtype != want_dtype:
            # cast via jnp: numpy lacks cast kernels for ml_dtypes pairs
            arr = jax.numpy.asarray(arr).astype(want_dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Device->host snapshot on call; disk write on a worker thread."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any):
        self.wait()                       # one in flight at a time
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            save(self.dir, step, host_tree)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.dir.iterdir()
            if d.name.startswith("step_") and (d / "meta.json").exists())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
