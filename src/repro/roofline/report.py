"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from results/."""
from __future__ import annotations

import json
from pathlib import Path

from .analysis import roofline_for_record

GB = 1024 ** 3


def dryrun_table(results: Path, tag: str = "baseline") -> str:
    rows = ["| arch | shape | mesh | params/dev GB | temp GB | collectives "
            "(per loop-body occurrence) | compile s |",
            "|---|---|---|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        d = results / tag / mesh
        if not d.exists():
            continue
        for f in sorted(d.glob("*.json")):
            r = json.loads(f.read_text())
            if r.get("skipped"):
                rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | — | — "
                            f"| skipped: {r['skipped']} | — |")
                continue
            if not r.get("ok"):
                rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | — | — "
                            f"| **FAILED** {r.get('error')} | — |")
                continue
            m = r["memory_analysis"]
            arg = (m.get("argument_size_in_bytes") or 0) / GB
            tmp = (m.get("temp_size_in_bytes") or 0) / GB
            cc = r["collectives"]["count_by_op"]
            cstr = " ".join(f"{k}:{v}" for k, v in sorted(cc.items()))
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | {arg:.2f} "
                        f"| {tmp:.2f} | {cstr} | {r['compile_s']} |")
    return "\n".join(rows)


def roofline_table(results: Path, tag: str = "baseline") -> str:
    rows = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
            "MODEL/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    d = results / tag / "16x16"
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("skipped") or not r.get("ok"):
            continue
        cr = roofline_for_record(r)
        if cr is None:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"no calibration | — | — |")
            continue
        dom = max(cr.t_compute,
                  cr.t_memory if cr.t_memory == cr.t_memory else 0,
                  cr.t_collective if cr.t_collective == cr.t_collective else 0)
        frac = cr.t_compute / dom if dom > 0 else float("nan")
        rows.append(
            f"| {cr.arch} | {cr.shape} | {cr.t_compute*1e3:.1f} | "
            f"{cr.t_memory*1e3:.1f} | {cr.t_collective*1e3:.1f} | "
            f"{cr.bottleneck} | {cr.useful_ratio:.2f} | {frac:.2f} |")
    return "\n".join(rows)


def perf_compare(results: Path, tags, arch: str, shape: str) -> str:
    """Side-by-side roofline terms for one cell across optimisation tags."""
    rows = [f"**{arch} / {shape}**", "",
            "| tag | t_comp ms | t_mem ms | t_coll ms | bound | dominant Δ |",
            "|---|---|---|---|---|---|"]
    prev = None
    for tag in tags:
        f = results / tag / "16x16" / f"{arch}__{shape}.json"
        if not f.exists():
            rows.append(f"| {tag} | — | — | — | missing | — |")
            continue
        r = json.loads(f.read_text())
        cr = roofline_for_record(r)
        if cr is None:
            rows.append(f"| {tag} | — | — | — | no calib | — |")
            continue
        dom = {"compute": cr.t_compute, "memory": cr.t_memory,
               "collective": cr.t_collective}[cr.bottleneck]
        delta = "" if prev is None else f"{(dom-prev)/prev*100:+.0f}%"
        prev = dom
        rows.append(f"| {tag} | {cr.t_compute*1e3:.1f} | {cr.t_memory*1e3:.1f}"
                    f" | {cr.t_collective*1e3:.1f} | {cr.bottleneck} | "
                    f"{delta} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    base = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parents[3] / "results"
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    tag = sys.argv[3] if len(sys.argv) > 3 else "baseline"
    if which == "dryrun":
        print(dryrun_table(base, tag))
    else:
        print(roofline_table(base, tag))
