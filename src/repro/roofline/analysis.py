"""Three-term roofline from the dry-run's compiled artifacts.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Methodology (see EXPERIMENTS.md §Roofline):

  * XLA's ``cost_analysis()`` counts each loop body ONCE, so the dry-run
    records, per cell, two extra truncated lowerings (1 and 2 pattern
    groups, scans unrolled, single microbatch).  The delta is the exact
    per-group cost; totals are reconstructed as

        total = n_micro * (fixed + delta * n_groups)        (train)
        total = fixed + delta * n_groups                    (prefill/decode)

    with fixed = 2*c1 - c2 (embed/head/loss/optimizer paths) and
    n_groups = n_layers / len(block_pattern) (fractional for remainder
    layers).  The optimizer update is inside ``fixed`` and so is counted
    once per microbatch instead of once per step — a <0.5% overcount,
    noted here and ignored.

  * collective_bytes come from regex-summing operand shapes of every
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute in the optimized per-device HLO, reconstructed
    through the same calibration.  Per-device wire traffic applies
    op factors: all-reduce 2x (reduce+broadcast ring), reduce-scatter
    (n-1)x its (scattered) output, others 1x.

  * the compute term uses the bf16 peak for LM cells; the lattice engine
    runs f64/f32 (factor applied by its own benchmark).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional

PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link
TP_DEGREE = 16                    # model-axis size on the production mesh

__all__ = ["roofline_for_record", "build_table", "CellRoofline"]


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # 6ND (train) / 2ND (inference), global
    hlo_flops_per_chip: float
    useful_ratio: float
    note: str

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.t_compute*1e3:.2f} | "
                f"{self.t_memory*1e3:.2f} | {self.t_collective*1e3:.2f} | "
                f"{self.bottleneck} | {self.useful_ratio:.2f} | {self.note} |")


def _coll_effective_bytes(by_op: Dict[str, float]) -> float:
    f = {"all-gather": 1.0, "all-reduce": 2.0,
         "reduce-scatter": float(TP_DEGREE - 1), "all-to-all": 1.0,
         "collective-permute": 1.0}
    return sum(f.get(op, 1.0) * b for op, b in by_op.items())


def _reconstruct(rec: dict, key: str, coll: bool = False) -> Optional[float]:
    """Total per-chip quantity from the g1/g2 calibration."""
    c1, c2 = rec.get("calib_g1"), rec.get("calib_g2")
    if not c1 or not c2:
        return None
    if coll:
        v1 = _coll_effective_bytes(c1.get("collective_bytes_by_op", {}))
        v2 = _coll_effective_bytes(c2.get("collective_bytes_by_op", {}))
    else:
        v1, v2 = c1.get(key), c2.get(key)
    if v1 is None or v2 is None:
        return None
    delta = max(v2 - v1, 0.0)
    fixed = max(v1 - delta, 0.0)
    total = fixed + delta * rec["n_groups"]
    if rec["mode"] == "train":
        total *= rec["n_micro"]
    return total


def _tokens(rec: dict) -> float:
    from ..configs.base import SHAPES
    s = SHAPES[rec["shape"]]
    if rec["mode"] == "decode":
        return s.global_batch * 1.0
    return s.global_batch * s.seq_len


def roofline_for_record(rec: dict, chips: int = 256) -> Optional[CellRoofline]:
    if not rec.get("ok"):
        return None
    flops = _reconstruct(rec, "flops_per_device")
    mem = _reconstruct(rec, "bytes_accessed_per_device")
    coll = _reconstruct(rec, "flops_per_device", coll=True)
    if flops is None:
        return None
    t_c = flops / PEAK_FLOPS_BF16
    t_m = mem / HBM_BW if mem is not None else float("nan")
    t_x = coll / ICI_BW if coll is not None else float("nan")
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=lambda k: (terms[k]
                                           if terms[k] == terms[k] else -1))
    n = rec["n_params_active"]        # = n_params for dense; 6*N_active*D
    mult = 6.0 if rec["mode"] == "train" else 2.0
    model_flops = mult * n * _tokens(rec)
    useful = model_flops / chips / flops if flops else 0.0
    note = {
        "compute": "MXU-bound: raise arithmetic intensity only by cutting "
                   "recompute (remat policy) or redundant ops",
        "memory": "HBM-bound: fuse / shrink activation dtype, raise "
                  "per-chip batch, or cut optimizer-state traffic",
        "collective": "ICI-bound: bigger per-chip shards (less TP), overlap "
                      "collectives with compute, or compress gradients",
    }[bottleneck]
    return CellRoofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_flops=model_flops,
        hlo_flops_per_chip=flops, useful_ratio=useful, note=note)


def build_table(results_dir: Path, mesh: str = "16x16",
                tag: str = "baseline") -> str:
    rows = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
            "useful | note |",
            "|---|---|---|---|---|---|---|---|"]
    cells = []
    for f in sorted((results_dir / tag / mesh).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped | — | {rec['skipped']} |")
            continue
        cr = roofline_for_record(rec)
        if cr is None:
            rows.append(f"| {rec.get('arch')} | {rec.get('shape')} | — | — "
                        f"| — | FAILED | — | {rec.get('error', '?')} |")
            continue
        cells.append(cr)
        rows.append(cr.row())
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    base = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path(__file__).resolve().parents[3] / "results"
    print(build_table(base))
