"""Pricing-kernel roofline: achieved vs. peak bytes/flops per platform.

``analysis.py`` rooflines the LM dry-run artifacts; this module is the
lattice-engine counterpart the bench lanes embed.  Each benchmark times
a jitted pricing program, asks XLA for that program's exact operation
counts (``lowered.compile().cost_analysis()`` — works on every backend,
CPU included), and emits one **matrix entry** per
``(platform, backend, op, dtype)`` cell::

    {"op": "rz_grid", "backend": "pallas", "platform": "cpu",
     "dtype": "float64", "flops": ..., "bytes": ...,
     "achieved_flops_per_sec": ..., "frac_peak_flops": ...,
     "achieved_bytes_per_sec": ..., "frac_peak_bw": ...,
     "intensity_flops_per_byte": ..., "bound": "memory"}

``tools/check_bench.py`` gates the achieved columns of matching cells
against the committed baselines; the peak denominators below are
*nominal* per-platform numbers (documented in docs/PLATFORMS.md) — the
fractions are for trend tracking and bottleneck attribution, not
marketing.
"""
from __future__ import annotations

from typing import Optional

__all__ = ["PRICING_PEAKS", "compiled_cost", "matrix_entry"]

# Nominal peaks per platform: {dtype: flop/s} and HBM/DRAM bytes/s.
#   cpu — one CI core, 4-wide f64 FMA @ ~3 GHz, single-core stream BW;
#   gpu — A100-40GB datasheet (f64 via FP64 tensor cores);
#   tpu — v5e per chip (bf16 peak from roofline/analysis.py; f32 half).
PRICING_PEAKS = {
    "cpu": {"flops": {"float64": 24e9, "float32": 48e9}, "bw": 20e9},
    "gpu": {"flops": {"float64": 9.7e12, "float32": 19.5e12}, "bw": 1555e9},
    "tpu": {"flops": {"float64": 0.0, "float32": 98.5e12}, "bw": 819e9},
}


def compiled_cost(fn, *args, **kwargs) -> Optional[dict]:
    """Exact ``{"flops", "bytes"}`` of the compiled program for ``fn``.

    ``fn`` must be jit-compatible (it is wrapped in ``jax.jit`` here);
    ``cost_analysis()`` returns one dict per computation — summed.
    Returns ``None`` when the backend exposes no cost model (some
    plugin runtimes) rather than raising: the bench then simply omits
    the matrix entry.
    """
    import jax
    try:
        compiled = jax.jit(fn).lower(*args, **kwargs).compile()
        costs = compiled.cost_analysis()
    except Exception:
        return None
    if costs is None:
        return None
    if isinstance(costs, dict):          # newer jax returns a flat dict
        costs = [costs]
    flops = sum(float(c.get("flops", 0.0)) for c in costs)
    nbytes = sum(float(c.get("bytes accessed", 0.0)) for c in costs)
    return {"flops": flops, "bytes": nbytes}


def matrix_entry(*, op: str, backend: str, dtype: str, seconds: float,
                 cost: Optional[dict], platform: Optional[str] = None,
                 ) -> Optional[dict]:
    """One per-backend/per-platform roofline matrix cell (or ``None``
    when the cost model was unavailable)."""
    from ..core.platform import active_platform
    if cost is None or seconds <= 0.0:
        return None
    platform = platform or active_platform()
    peaks = PRICING_PEAKS.get(platform, PRICING_PEAKS["cpu"])
    peak_flops = peaks["flops"].get(str(dtype), 0.0)
    peak_bw = peaks["bw"]
    flops, nbytes = cost["flops"], cost["bytes"]
    ach_f, ach_b = flops / seconds, nbytes / seconds
    t_comp = flops / peak_flops if peak_flops else float("inf")
    t_mem = nbytes / peak_bw if peak_bw else float("inf")
    return {
        "op": op, "backend": backend, "platform": platform,
        "dtype": str(dtype),
        "flops": flops, "bytes": nbytes, "seconds": seconds,
        "achieved_flops_per_sec": ach_f,
        "frac_peak_flops": ach_f / peak_flops if peak_flops else None,
        "achieved_bytes_per_sec": ach_b,
        "frac_peak_bw": ach_b / peak_bw if peak_bw else None,
        "intensity_flops_per_byte": flops / nbytes if nbytes else None,
        "bound": "compute" if t_comp >= t_mem else "memory",
    }
