"""Replica workers for the asyncio pricing gateway.

A *replica* is anything with a ``price_chunk(ChunkSpec) -> ChunkResult``
method.  The gateway runs each replica on its own single-thread executor
(one engine call in flight per replica — jax dispatch is not re-entrant
per program anyway) and treats the boundary as untrusted: a replica may
crash (:class:`ReplicaCrash`), hang past the gateway's timeout, or raise
a *request* error like ``OverflowError`` (the chunk's own fault — the
replica stays healthy, the chunk retries/errors out).

:class:`LocalReplica` is the in-process reference replica over
``serve/core.py::execute_chunk``.  :class:`FaultyReplica` wraps any
replica with a call-indexed fault schedule — the fault-injection
harness's probe (``tests/test_gateway_faults.py``), exported here so the
bench can inject the same faults it tests.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from .core import ChunkResult, ChunkSpec, execute_chunk

__all__ = ["ReplicaCrash", "LocalReplica", "FaultyReplica"]


class ReplicaCrash(RuntimeError):
    """The replica process/worker died — an infrastructure failure, not a
    property of the chunk.  The gateway marks the replica dead and
    re-queues the in-flight chunk to a healthy replica."""


class LocalReplica:
    """In-process replica: prices chunks through the compiled engines.

    Each replica keeps engine warmth implicitly — jax's jit cache is
    process-wide, so in-process replicas share compilations; the sticky
    bucket→replica affinity is what keeps *per-process* replicas warm
    when the pool is later backed by real processes.
    """

    def __init__(self, name: str = "replica"):
        self.name = name
        self.calls = 0

    # the gateway gives each replica a single-thread executor, so calls
    # is confined to that one worker thread (repro.analysis.guarded)
    GUARDED_BY = {"calls": "owner"}

    def price_chunk(self, chunk: ChunkSpec) -> ChunkResult:
        self.calls += 1
        return execute_chunk(chunk)


class FaultyReplica:
    """Fault-injection wrapper: fail specific calls by index.

    ``faults`` maps the replica-local call index (0-based, counting every
    ``price_chunk`` invocation) to a fault kind:

    * ``"crash"``    — raise :class:`ReplicaCrash` (replica dies);
    * ``"hang"``     — block until :meth:`release` (or ``hang_s``, a
      safety bound so an un-released hang cannot wedge the test process:
      executor threads are non-daemon), then die;
    * ``"overflow"`` — raise ``OverflowError`` (a *request* error: the
      replica survives and the chunk is retried).

    Un-scheduled calls delegate to the wrapped replica.
    """

    def __init__(self, inner: Optional[LocalReplica] = None,
                 faults: Optional[Dict[int, str]] = None, *,
                 hang_s: float = 60.0, name: str = "faulty"):
        self.inner = inner if inner is not None else LocalReplica()
        self.faults = dict(faults or {})
        self.hang_s = float(hang_s)
        self.name = name
        self.calls = 0
        self._release = threading.Event()

    # single-thread executor confinement, same as LocalReplica
    GUARDED_BY = {"calls": "owner"}

    def release(self) -> None:
        """Unblock a hanging call (test teardown — without it the worker
        thread would outlive the test by up to ``hang_s``)."""
        self._release.set()

    def price_chunk(self, chunk: ChunkSpec) -> ChunkResult:
        i = self.calls
        self.calls += 1
        fault = self.faults.get(i)
        if fault == "crash":
            raise ReplicaCrash(f"{self.name}: injected crash on call {i}")
        if fault == "hang":
            self._release.wait(self.hang_s)
            # by the time the hang releases the gateway has long timed
            # this call out and re-queued the chunk elsewhere; die like
            # the wedged worker this simulates rather than return a
            # duplicate (stale) result
            raise ReplicaCrash(f"{self.name}: hung call {i} released")
        if fault == "overflow":
            raise OverflowError(
                f"{self.name}: injected PWL capacity overflow on call {i}")
        return self.inner.price_chunk(chunk)
