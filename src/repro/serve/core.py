"""Transport-free scheduler core shared by every serving front end.

PR 6 split ``serve/scheduler.py`` in two.  This module is the half with
no opinion about *when* or *where* work runs: request normalisation,
bucket queues, the result LRU, chunk assembly, result distribution and
metrics — pure bookkeeping over numpy arrays.  The other half is a
transport:

  * :class:`~repro.serve.scheduler.PricingService` — the original
    cooperative in-process driver (``submit``/``step`` price inline);
  * :class:`~repro.serve.gateway.PricingGateway` — the asyncio
    multi-replica gateway (timer-driven deadline flushes, replica pool,
    fault recovery, streaming repricing).

The unit of work handed to a transport is a :class:`ChunkSpec` — one
micro-batch of one bucket, padded to a power of two, carrying plain
arrays so it can cross a thread *or process* boundary — and the unit
coming back is a :class:`ChunkResult`.  :func:`execute_chunk` is the
reference executor over ``repro.api.price_flat``; replicas wrap it.

Both chunk types additionally define an explicit **wire schema**
(:meth:`ChunkSpec.to_wire` / :meth:`ChunkSpec.from_wire`, and the same
pair on :class:`ChunkResult`): a versioned dict of plain
scalars/strings/tuples (numpy arrays on the result side) that a
process-backed replica (``serve/procpool.py``) ships over its pipe.
Nothing device-bound crosses the wire — sharding travels as a
``devices=`` *count* each worker resolves to its own mesh locally
(``core/distributed.py::resolve_grid_mesh``), and the
:class:`~repro.core.partition.ShardPlan` is already plain data.  The
schema carries ``version`` = :data:`WIRE_VERSION`; decoding rejects a
*newer* version (the sender knows fields this reader does not) and
ignores unknown fields (additive evolution: bump the version when a new
field changes meaning, not when one is merely added).  See
``docs/SERVING.md`` for the versioning rules.

``ServiceMetrics`` lives here too and is **thread-safe**: gateway
flushes complete on replica worker threads concurrently, so every
mutation goes through methods that hold the instance lock
(:meth:`ServiceMetrics.bump`, :meth:`~ServiceMetrics.add_latency`,
:meth:`~ServiceMetrics.record_flush`) and :meth:`~ServiceMetrics.snapshot`
reads under the same lock.  Plain ``metrics.field += 1`` from two
threads loses updates (a read-modify-write race) — the regression test
``tests/test_serve.py::test_service_metrics_thread_safe`` pins this.

The lock discipline is *declared*, not just documented: each class
carries a ``GUARDED_BY`` registry mapping shared-mutable attributes to
the lock that guards them (``"owner"`` = single-threaded by design, the
event-loop/owner thread).  ``tools/analyze.py`` statically verifies
every write site against these declarations (``repro.analysis.guarded``)
and the fault-injection suites can enforce them at runtime via shadow
locks (``repro.analysis.shadow``, ``REPRO_SHADOW_GUARDS=1``).  See
``docs/ANALYSIS.md``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..core.partition import ShardPlan, _next_pow2
from ..core.platform import resolve_interpret
from ..scenarios import PAYOFF_FAMILIES, ShardExecInfo, route_engine

__all__ = ["ServiceMetrics", "SchedulerCore", "ChunkSpec", "ChunkResult",
           "execute_chunk", "WIRE_VERSION"]

# Wire-schema version for ChunkSpec/ChunkResult dicts.  Policy (see the
# module docstring and docs/SERVING.md): decoding accepts any version
# 1..WIRE_VERSION, rejects newer, and silently ignores unknown fields —
# adding a field is NOT a version bump; changing the meaning or type of
# an existing field is.
WIRE_VERSION = 1


def _as_tuple(x):
    """Recursively normalise lists to tuples (wire dicts that crossed a
    JSON hop come back with lists where the scheduler had tuples)."""
    if isinstance(x, (list, tuple)):
        return tuple(_as_tuple(v) for v in x)
    return x


def _check_wire(wire, kind: str, required: tuple) -> None:
    if not isinstance(wire, dict):
        raise ValueError(f"{kind} wire must be a dict, got {type(wire)}")
    v = wire.get("version")
    if not isinstance(v, int) or isinstance(v, bool) or v < 1:
        raise ValueError(f"{kind} wire has no valid version field: {v!r}")
    if v > WIRE_VERSION:
        raise ValueError(
            f"{kind} wire version {v} is newer than this process supports "
            f"({WIRE_VERSION}) — upgrade the worker, not the schema")
    got = wire.get("kind")
    if got != kind:
        raise ValueError(f"expected a {kind!r} wire dict, got {got!r}")
    missing = [k for k in required if k not in wire]
    if missing:
        raise ValueError(f"{kind} wire missing required fields {missing}")


def _plan_to_wire(plan) -> Optional[dict]:
    if plan is None:
        return None
    return {"n_shards": int(plan.n_shards), "shards": plan.shards,
            "work": plan.work, "lanes": int(plan.lanes),
            "n_rows": int(plan.n_rows)}


def _plan_from_wire(w) -> Optional[ShardPlan]:
    if w is None:
        return None
    return ShardPlan(n_shards=int(w["n_shards"]),
                     shards=_as_tuple(w["shards"]),
                     work=tuple(float(x) for x in w["work"]),
                     lanes=int(w["lanes"]), n_rows=int(w["n_rows"]))


def _shard_info_to_wire(info) -> Optional[dict]:
    if info is None:
        return None
    return {"plan": _plan_to_wire(info.plan),
            "mesh_shape": info.mesh_shape, "simulated": bool(info.simulated),
            "per_shard_pieces": info.per_shard_pieces,
            "per_shard_rows": info.per_shard_rows,
            "measured_work": info.measured_work}


def _shard_info_from_wire(w) -> Optional[ShardExecInfo]:
    if w is None:
        return None
    return ShardExecInfo(plan=_plan_from_wire(w["plan"]),
                         mesh_shape=_as_tuple(w["mesh_shape"]),
                         simulated=bool(w["simulated"]),
                         per_shard_pieces=_as_tuple(w["per_shard_pieces"]),
                         per_shard_rows=_as_tuple(w["per_shard_rows"]),
                         measured_work=_as_tuple(w["measured_work"]))


@dataclasses.dataclass(frozen=True)
class _Pending:
    rid: int
    key: tuple            # full scenario tuple (the result-cache key)
    t_submit: float


@dataclasses.dataclass
class ServiceMetrics:
    """Counters a pricing front end accumulates (all cumulative).

    Thread-safe: mutate only through :meth:`bump` / :meth:`add_latency`
    / :meth:`record_flush`; read through :meth:`snapshot`.
    """
    requests: int = 0            # single-contract requests submitted
    completed: int = 0           # ... with a result available
    batches: int = 0             # engine flushes (micro-batches priced)
    contracts: int = 0           # real (un-padded) contracts priced
    padded: int = 0              # lanes submitted to the engines
    cache_hits: int = 0          # result-LRU short-circuits
    compile_hits: int = 0        # batch shapes seen before
    compile_misses: int = 0      # batch shapes compiled fresh
    engine_seconds: float = 0.0  # time inside the compiled engines
    engine_batches: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"notc": 0, "rz": 0, "lsmc": 0})
    grids: int = 0               # GridRequests priced
    grid_scenarios: int = 0
    shard_batches: int = 0       # flushes routed onto the device mesh
    rebalances: int = 0          # measured-seconds feedbacks folded in
    # p50/p99 are computed over a bounded window of recent samples so a
    # long-running service doesn't grow without limit
    latencies: List[float] = dataclasses.field(default_factory=list)
    latency_window: int = 4096

    # Checked statically by repro.analysis.guarded and at runtime (shadow
    # mode) — every write outside __init__ must hold the named lock.
    GUARDED_BY = {
        "requests": "_lock", "completed": "_lock", "batches": "_lock",
        "contracts": "_lock", "padded": "_lock", "cache_hits": "_lock",
        "compile_hits": "_lock", "compile_misses": "_lock",
        "engine_seconds": "_lock", "engine_batches": "_lock",
        "grids": "_lock", "grid_scenarios": "_lock",
        "shard_batches": "_lock", "rebalances": "_lock",
        "latencies": "_lock",
    }

    def __post_init__(self):
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ #
    # locked mutation
    # ------------------------------------------------------------------ #
    def bump(self, **deltas) -> None:
        """Atomically add ``deltas`` to the named counters."""
        with self._lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def count_engine(self, engine: str) -> None:
        with self._lock:
            self.engine_batches[engine] += 1

    def add_latency(self, seconds: float) -> None:
        with self._lock:
            self._add_latency_locked(seconds)

    def _add_latency_locked(self, seconds: float) -> None:
        self.latencies.append(seconds)
        if len(self.latencies) > 2 * self.latency_window:
            del self.latencies[:-self.latency_window]

    def record_flush(self, *, contracts: int, padded: int, engine: str,
                     seconds: float, latencies) -> None:
        """Fold one completed micro-batch in as a single atomic update."""
        with self._lock:
            self.batches += 1
            self.contracts += contracts
            self.padded += padded
            self.completed += contracts
            self.engine_seconds += seconds
            self.engine_batches[engine] += 1
            for s in latencies:
                self._add_latency_locked(s)

    # ------------------------------------------------------------------ #
    # locked read
    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """One atomic, self-consistent view of every counter.

        Subclasses extend :meth:`_snapshot_locked` (NOT this method) so
        the whole — base and subclass fields alike — is read under a
        single lock acquisition; overriding ``snapshot`` and taking the
        lock twice yields a torn read (base counters from one instant,
        subclass counters from another)."""
        with self._lock:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> dict:
        lat = (np.asarray(self.latencies) if self.latencies
               else np.zeros(1))
        waste = (1.0 - self.contracts / self.padded
                 if self.padded else 0.0)
        # before any engine flush there is no throughput to report:
        # 0.0, not inf — json.dumps would emit non-standard
        # `Infinity` into the BENCH_serve.json artifact (strict JSON
        # parsers reject it, and tools/check_bench.py refuses
        # non-finite metrics)
        cps = (self.contracts / self.engine_seconds
               if self.engine_seconds > 0 else 0.0)
        return {
            "requests": self.requests, "completed": self.completed,
            "batches": self.batches, "contracts": self.contracts,
            "padded": self.padded, "pad_waste": waste,
            "cache_hits": self.cache_hits,
            "compile_hits": self.compile_hits,
            "compile_misses": self.compile_misses,
            "engine_seconds": self.engine_seconds,
            "contracts_per_sec": cps,
            "engine_batches": dict(self.engine_batches),
            "grids": self.grids,
            "grid_scenarios": self.grid_scenarios,
            "shard_batches": self.shard_batches,
            "rebalances": self.rebalances,
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
        }


@dataclasses.dataclass
class ChunkSpec:
    """One dispatchable micro-batch: a slice of one bucket, padded.

    Carries plain numpy columns (s0, sigma, rate, maturity, cost_rate,
    payoff, strike, strike2 — the :func:`repro.api.price_flat`
    signature) so it can cross a worker boundary without touching the
    scheduler's queues.  ``devices``/``shard_plan`` are set by
    transports that route chunks onto a device mesh: ``devices`` is a
    *count*, not a live mesh object — each executor resolves it to its
    own mesh locally (``resolve_grid_mesh``), so a chunk pickles cleanly
    across a process boundary and never pins work to the scheduler's
    devices.  ``n_assets``/``exercise_steps``/``n_paths``/``mc_seed``
    configure the ``lsmc`` engine (harmless defaults for the lattice
    engines).  ``interpret`` is the Pallas execution mode the scheduler
    resolved for this chunk (``None`` = defer to the executing process's
    platform policy — what a cross-process replica on different
    hardware wants).
    """
    bucket: tuple
    requests: List[_Pending]
    n_steps: int
    engine: str
    capacity: int
    backend: str
    padded: int
    cols: tuple
    devices: Optional[int] = None
    shard_plan: Optional[ShardPlan] = None
    n_assets: int = 1
    exercise_steps: Optional[tuple] = None
    n_paths: int = 4096
    mc_seed: int = 0
    interpret: Optional[bool] = None
    # lsmc regression design: every one is compile-key material (the
    # basis/degree decide the design-matrix shape, antithetic halves the
    # driver) — see repro.analysis.compile_key.CHUNK_FIELD_ROLES
    basis: str = "poly"
    degree: int = 3
    antithetic: bool = True

    @property
    def n(self) -> int:
        return len(self.requests)

    _WIRE_REQUIRED = ("bucket", "requests", "n_steps", "engine", "capacity",
                      "backend", "padded", "cols")

    def to_wire(self) -> dict:
        """Encode as the versioned wire dict (plain scalars/strings/
        tuples only — JSON- and pickle-transportable)."""
        return {
            "version": WIRE_VERSION, "kind": "chunk_spec",
            "bucket": self.bucket,
            "requests": tuple((p.rid, p.key, p.t_submit)
                              for p in self.requests),
            "n_steps": int(self.n_steps), "engine": self.engine,
            "capacity": int(self.capacity), "backend": self.backend,
            "padded": int(self.padded),
            "cols": tuple(tuple(c) for c in self.cols),
            "devices": None if self.devices is None else int(self.devices),
            "shard_plan": _plan_to_wire(self.shard_plan),
            "n_assets": int(self.n_assets),
            "exercise_steps": self.exercise_steps,
            "n_paths": int(self.n_paths), "mc_seed": int(self.mc_seed),
            "interpret": self.interpret,
            "basis": str(self.basis), "degree": int(self.degree),
            "antithetic": bool(self.antithetic),
        }

    @classmethod
    def from_wire(cls, wire: dict) -> "ChunkSpec":
        """Decode a wire dict (any version up to :data:`WIRE_VERSION`;
        unknown fields are ignored, missing required fields raise)."""
        _check_wire(wire, "chunk_spec", cls._WIRE_REQUIRED)
        requests = [_Pending(rid=int(r[0]), key=_as_tuple(r[1]),
                             t_submit=float(r[2]))
                    for r in wire["requests"]]
        devices = wire.get("devices")
        ex = wire.get("exercise_steps")
        return cls(
            bucket=_as_tuple(wire["bucket"]), requests=requests,
            n_steps=int(wire["n_steps"]), engine=str(wire["engine"]),
            capacity=int(wire["capacity"]), backend=str(wire["backend"]),
            padded=int(wire["padded"]),
            cols=tuple(tuple(c) for c in wire["cols"]),
            devices=None if devices is None else int(devices),
            shard_plan=_plan_from_wire(wire.get("shard_plan")),
            n_assets=int(wire.get("n_assets", 1)),
            exercise_steps=None if ex is None else _as_tuple(ex),
            n_paths=int(wire.get("n_paths", 4096)),
            mc_seed=int(wire.get("mc_seed", 0)),
            interpret=wire.get("interpret"),
            basis=str(wire.get("basis", "poly")),
            degree=int(wire.get("degree", 3)),
            antithetic=bool(wire.get("antithetic", True)))


@dataclasses.dataclass
class ChunkResult:
    """What comes back from pricing a :class:`ChunkSpec`.

    ``row_pieces`` is the exact per-lane peak PWL knot count
    (``GridResult.row_pieces``) over the padded batch — all zero on the
    friction-free path — so every delivered quote carries its *own*
    ``max_pieces``, matching ``price_american`` exactly.  ``seconds`` is
    the executor-measured wall time inside the engine call.  ``stderr``
    is the per-lane Monte Carlo standard error (zeros from the
    deterministic lattice engines).
    """
    ask: np.ndarray
    bid: np.ndarray
    max_pieces: int
    row_pieces: np.ndarray
    seconds: float
    shard_info: Any = None
    stderr: Optional[np.ndarray] = None

    _WIRE_REQUIRED = ("ask", "bid", "max_pieces", "row_pieces", "seconds")

    def to_wire(self) -> dict:
        """Encode as the versioned wire dict.  Arrays stay numpy (the
        pipe pickles them efficiently); everything else is plain."""
        return {
            "version": WIRE_VERSION, "kind": "chunk_result",
            "ask": np.asarray(self.ask), "bid": np.asarray(self.bid),
            "max_pieces": int(self.max_pieces),
            "row_pieces": np.asarray(self.row_pieces),
            "seconds": float(self.seconds),
            "shard_info": _shard_info_to_wire(self.shard_info),
            "stderr": (None if self.stderr is None
                       else np.asarray(self.stderr)),
        }

    @classmethod
    def from_wire(cls, wire: dict) -> "ChunkResult":
        _check_wire(wire, "chunk_result", cls._WIRE_REQUIRED)
        se = wire.get("stderr")
        return cls(ask=np.asarray(wire["ask"]), bid=np.asarray(wire["bid"]),
                   max_pieces=int(wire["max_pieces"]),
                   row_pieces=np.asarray(wire["row_pieces"]),
                   seconds=float(wire["seconds"]),
                   shard_info=_shard_info_from_wire(wire.get("shard_info")),
                   stderr=None if se is None else np.asarray(se))


def execute_chunk(chunk: ChunkSpec) -> ChunkResult:
    """Price one chunk through ``repro.api.price_flat`` (the reference
    executor — replicas and the in-process service both route here)."""
    from ..api import price_flat
    from ..configs.pricing import ExecutionConfig
    cols = chunk.cols
    t0 = time.perf_counter()
    res = price_flat(
        s0=np.asarray(cols[0]), sigma=np.asarray(cols[1]),
        rate=np.asarray(cols[2]), maturity=np.asarray(cols[3]),
        cost_rate=np.asarray(cols[4]), payoff=tuple(cols[5]),
        strike=np.asarray(cols[6]), strike2=np.asarray(cols[7]),
        n_steps=chunk.n_steps, n_assets=chunk.n_assets,
        exercise_steps=chunk.exercise_steps,
        execution=ExecutionConfig(
            engine=chunk.engine, backend=chunk.backend,
            interpret=chunk.interpret, devices=chunk.devices,
            n_paths=chunk.n_paths, mc_seed=chunk.mc_seed,
            basis=chunk.basis, degree=chunk.degree,
            antithetic=chunk.antithetic),
        capacity=chunk.capacity,
        pad_to=chunk.padded, shard_plan=chunk.shard_plan)
    seconds = time.perf_counter() - t0
    rp = res.row_pieces
    rp = (np.zeros(chunk.padded, dtype=int) if rp is None
          else np.asarray(rp).ravel().astype(int))
    se = (np.zeros(chunk.padded) if res.stderr is None
          else np.asarray(res.stderr).ravel())
    return ChunkResult(ask=np.asarray(res.ask).ravel(),
                       bid=np.asarray(res.bid).ravel(),
                       max_pieces=int(res.max_pieces), row_pieces=rp,
                       seconds=seconds, shard_info=res.shard_info,
                       stderr=se)


class SchedulerCore:
    """Coalescing/bucketing/caching core, with no flush policy attached.

    Owns: request-id allocation, scenario normalisation, the bucket
    queues keyed ``(n_steps, engine)`` — plus the lsmc static config
    for MC buckets, so an lsmc bucket can never coalesce with a lattice
    bucket of the same depth — the result LRU, the bounded
    completed-result store, the compile-key accounting and the
    shared :class:`ServiceMetrics`.  Transports decide *when* to call
    :meth:`take_chunk` (size trigger, deadline timer) and *where* the
    chunk executes (inline, a replica worker); they hand results back
    through :meth:`complete` or return work through :meth:`requeue`.
    """

    def __init__(self, *, max_batch: int = 64, deadline_ms: float = 5.0,
                 capacity: int = 48, backend: str = "jnp",
                 interpret: Optional[bool] = None,
                 default_n_steps: int = 100, default_payoff: str = "put",
                 default_strike: float = 100.0,
                 result_cache_size: int = 1024, max_results: int = 65536,
                 n_paths: int = 4096, mc_seed: int = 0,
                 basis: str = "poly", degree: int = 3,
                 antithetic: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 metrics: Optional[ServiceMetrics] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.deadline_s = float(deadline_ms) * 1e-3
        self.capacity = int(capacity)
        self.backend = backend
        # Pallas execution mode for every chunk this core cuts; None =
        # the executing process's platform policy (core/platform.py)
        self.interpret = interpret
        self.default_n_steps = int(default_n_steps)
        self.default_payoff = default_payoff
        self.default_strike = float(default_strike)
        self.n_paths = int(n_paths)
        self.mc_seed = int(mc_seed)
        self.basis = str(basis)
        self.degree = int(degree)
        self.antithetic = bool(antithetic)
        self._clock = clock
        self.max_results = int(max_results)
        self.buckets: Dict[tuple, List[_Pending]] = {}
        self._results: OrderedDict = OrderedDict()
        self._result_cache: OrderedDict = OrderedDict()
        self._result_cache_size = int(result_cache_size)
        self._compiled: Dict[tuple, int] = {}
        self._next_id = 0
        self.metrics_ = metrics if metrics is not None else ServiceMetrics()

    # Queue/cache state is owner-confined: every mutation happens on the
    # transport's driving thread (the asyncio event loop in the gateway,
    # the caller in PricingService) — replica worker threads never touch
    # the core directly, they hand results back to the loop.  Checked by
    # repro.analysis.guarded ("owner" = pin to the first writer thread).
    GUARDED_BY = {
        "buckets": "owner", "_results": "owner", "_result_cache": "owner",
        "_compiled": "owner", "_next_id": "owner",
    }

    # ------------------------------------------------------------------ #
    # request intake
    # ------------------------------------------------------------------ #
    def scenario_key(self, req) -> tuple:
        """Normalise a PriceRequest to the full scenario tuple.

        Unset (None) payoff/strike/n_steps fields take the service
        defaults — per-request values are always honoured (they batch as
        payoff *data*, so heterogeneous batches stay one compiled call).
        """
        payoff = req.payoff if req.payoff is not None else self.default_payoff
        if payoff not in PAYOFF_FAMILIES:
            raise ValueError(f"unknown payoff family {payoff!r}; "
                             f"supported: {PAYOFF_FAMILIES}")
        strike = (self.default_strike if req.strike is None
                  else float(req.strike))
        strike2 = (strike + 10.0 if getattr(req, "strike2", None) is None
                   else float(req.strike2))
        n_steps = (self.default_n_steps if req.n_steps is None
                   else int(req.n_steps))
        n_assets = int(getattr(req, "n_assets", None) or 1)
        ex = getattr(req, "exercise_steps", None)
        if ex is not None:
            from ..core.lsmc import exercise_schedule
            ex = exercise_schedule(n_steps, ex)
        return (float(req.s0), float(req.sigma), float(req.rate),
                float(req.maturity), float(req.cost_rate), payoff,
                strike, strike2, n_steps, n_assets, ex)

    def submit(self, req):
        """Enqueue one contract.

        Returns ``(rid, bucket, quote)``: a result-LRU hit completes
        inline (``bucket`` is None, ``quote`` the cached PriceQuote);
        otherwise the request joined ``bucket``'s queue and the caller
        decides whether its length warrants a size-trigger flush.
        """
        key = self.scenario_key(req)
        rid = self._next_id
        self._next_id += 1
        self.metrics_.bump(requests=1)
        now = self._clock()
        if key in self._result_cache:
            self._result_cache.move_to_end(key)
            quote = self._result_cache[key]
            self.store_result(rid, quote)
            self.metrics_.bump(cache_hits=1, completed=1)
            self.metrics_.add_latency(self._clock() - now)
            return rid, None, quote
        bucket = self.bucket_key(key)
        self.buckets.setdefault(bucket, []).append(
            _Pending(rid=rid, key=key, t_submit=now))
        return rid, bucket, None

    @staticmethod
    def bucket_key(key: tuple) -> tuple:
        """Queue identity of a normalised scenario tuple.

        ``(n_steps, engine)`` — the engine NAME, not a bool: an lsmc
        bucket must never coalesce with a lattice bucket of the same
        depth, and lsmc buckets additionally key on their static MC
        shape ``(n_assets, exercise_steps)``.  Anything that changes
        the compiled program must split the bucket; anything that is
        array data (strike, payoff family, spot/vol/rate) must NOT —
        ``repro.analysis.compile_key.check_bucket_probes`` audits both
        directions (the PR 7 American-vs-Bermudan collision class)."""
        engine = route_engine(any_tc=key[4] > 0.0, n_assets=key[9],
                              exercise_steps=key[10])
        return ((key[8], engine) if engine != "lsmc"
                else (key[8], "lsmc", key[9], key[10]))

    # ------------------------------------------------------------------ #
    # chunk lifecycle
    # ------------------------------------------------------------------ #
    def take_chunk(self, bucket: tuple,
                   limit: Optional[int] = None) -> Optional[ChunkSpec]:
        """Pop up to ``limit`` (default ``max_batch``) oldest requests of
        ``bucket`` as a dispatchable :class:`ChunkSpec` (None if empty)."""
        pending = self.buckets.get(bucket)
        if not pending:
            return None
        limit = self.max_batch if limit is None else max(1, int(limit))
        chunk_reqs, rest = pending[:limit], pending[limit:]
        if rest:
            self.buckets[bucket] = rest
        else:
            self.buckets.pop(bucket, None)
        n_steps, engine = bucket[0], bucket[1]
        # only the 8 price_flat columns cross the worker boundary — the
        # bucket-constant tail (n_steps, n_assets, schedule) rides as
        # chunk fields
        cols = tuple(zip(*(p.key[:8] for p in chunk_reqs)))
        return ChunkSpec(bucket=bucket, requests=chunk_reqs,
                         n_steps=n_steps, engine=engine,
                         capacity=self.capacity, backend=self.backend,
                         padded=_next_pow2(len(chunk_reqs)), cols=cols,
                         n_assets=bucket[2] if engine == "lsmc" else 1,
                         exercise_steps=(bucket[3] if engine == "lsmc"
                                         else None),
                         n_paths=self.n_paths, mc_seed=self.mc_seed,
                         interpret=self.interpret, basis=self.basis,
                         degree=self.degree, antithetic=self.antithetic)

    def requeue(self, chunk: ChunkSpec) -> None:
        """Return a chunk's requests to the *front* of their bucket (no
        request is ever silently lost on an engine/replica failure)."""
        self.buckets[chunk.bucket] = (list(chunk.requests)
                                      + self.buckets.get(chunk.bucket, []))

    def complete(self, chunk: ChunkSpec, res: ChunkResult, now: float, *,
                 engine_seconds: Optional[float] = None) -> Dict[int, Any]:
        """Distribute one chunk's results; returns ``{rid: PriceQuote}``.

        Each quote carries its row's exact ``row_pieces`` as
        ``max_pieces`` — identical to pricing the contract alone through
        ``price_american`` (lanes are independent in the grid engines).
        """
        from ..api import PriceQuote
        seconds = res.seconds if engine_seconds is None else engine_seconds
        done: Dict[int, Any] = {}
        lats = []
        se = res.stderr
        for i, p in enumerate(chunk.requests):
            quote = PriceQuote(ask=float(res.ask[i]), bid=float(res.bid[i]),
                               max_pieces=int(res.row_pieces[i]),
                               stderr=float(se[i]) if se is not None else 0.0)
            self.store_result(p.rid, quote)
            done[p.rid] = quote
            self.remember(p.key, quote)
            lats.append(now - p.t_submit)
        self.metrics_.record_flush(contracts=chunk.n, padded=chunk.padded,
                                  engine=chunk.engine, seconds=seconds,
                                  latencies=lats)
        plan = chunk.shard_plan
        self.compile_key_seen(chunk.padded, chunk.n_steps, chunk.engine,
                              False, backend=chunk.backend,
                              interpret=chunk.interpret,
                              shard=(plan.n_shards, plan.lanes)
                              if plan is not None else None,
                              extra=self.chunk_compile_extra(chunk),
                              devices=chunk.devices)
        return done

    @staticmethod
    def chunk_compile_extra(chunk: ChunkSpec) -> Optional[tuple]:
        """The lsmc static config that shapes its compiled program —
        appended to the compile key so two MC chunks differing only in
        path count, schedule or regression design never count as one
        program."""
        if chunk.engine != "lsmc":
            return None
        return (chunk.n_paths, chunk.n_assets, chunk.exercise_steps,
                chunk.basis, chunk.degree, chunk.antithetic)

    def compile_key(self, padded: int, n_steps: int, engine: str,
                    greeks: bool, *, backend: Optional[str] = None,
                    interpret: Optional[bool] = None,
                    devices: Optional[int] = None,
                    shard: Optional[tuple] = None,
                    extra: Optional[tuple] = None) -> tuple:
        """The compiled-program identity tuple.  Every field that can
        change the traced jaxpr, the padded shapes or which executable
        runs is folded in — ``repro.analysis.compile_key`` audits that
        this stays true as fields are added."""
        # interpret-mode and compiled Pallas programs are distinct
        # executables — resolve ``None`` through the platform policy so
        # "unset" and "explicitly the policy value" key identically
        return (padded, n_steps, engine,
                self.backend if backend is None else backend,
                resolve_interpret(self.interpret if interpret is None
                                  else interpret), greeks,
                self.capacity, devices, shard, extra)

    @staticmethod
    def chunk_compile_key(chunk: ChunkSpec, greeks: bool = False) -> tuple:
        """Compile key of a fully-specified :class:`ChunkSpec` (every
        program field read off the chunk itself — nothing defaulted from
        scheduler state, so two schedulers agree on a chunk's key)."""
        plan = chunk.shard_plan
        return (chunk.padded, chunk.n_steps, chunk.engine, chunk.backend,
                resolve_interpret(chunk.interpret), greeks,
                chunk.capacity, chunk.devices,
                (plan.n_shards, plan.lanes) if plan is not None else None,
                SchedulerCore.chunk_compile_extra(chunk))

    def compile_key_seen(self, padded: int, n_steps: int, engine: str,
                         greeks: bool, backend: Optional[str] = None,
                         interpret: Optional[bool] = None,
                         shard: Optional[tuple] = None,
                         extra: Optional[tuple] = None,
                         devices: Optional[int] = None) -> None:
        """Count a *successful* engine call against its compiled-program
        key.  Called only after the call returns: a failed call (e.g. a
        capacity overflow) compiled nothing worth counting, and raising
        ``capacity`` — a shape parameter, hence part of the key — then
        retrying is a genuine fresh compile, not a hit.  ``shard`` is
        ``(n_shards, lanes)`` when the call ran on the device mesh and
        ``devices`` the mesh width — all change the compiled program's
        shape, so they are part of the key; ``extra`` carries
        engine-specific static config (the lsmc path/schedule/basis
        shape, see :meth:`chunk_compile_extra`)."""
        ck = self.compile_key(padded, n_steps, engine, greeks,
                              backend=backend, interpret=interpret,
                              devices=devices, shard=shard, extra=extra)
        if ck in self._compiled:
            self._compiled[ck] += 1
            self.metrics_.bump(compile_hits=1)
        else:
            self._compiled[ck] = 1
            self.metrics_.bump(compile_misses=1)

    # ------------------------------------------------------------------ #
    # results / caches
    # ------------------------------------------------------------------ #
    def store_result(self, rid: int, quote) -> None:
        """Keep completed quotes retrievable via :meth:`result`, bounded
        to the most recent ``max_results`` so a long-running service
        doesn't grow without limit — collect results promptly."""
        self._results[rid] = quote
        while len(self._results) > self.max_results:
            self._results.popitem(last=False)

    def remember(self, key: tuple, quote) -> None:
        if self._result_cache_size <= 0:
            return
        self._result_cache[key] = quote
        self._result_cache.move_to_end(key)
        while len(self._result_cache) > self._result_cache_size:
            self._result_cache.popitem(last=False)

    def result(self, rid: int):
        return self._results.get(rid)

    @property
    def pending_count(self) -> int:
        return sum(len(p) for p in self.buckets.values())

    # ------------------------------------------------------------------ #
    # deadline bookkeeping (policy-free: transports ask, then act)
    # ------------------------------------------------------------------ #
    def due_buckets(self, now: float) -> List[tuple]:
        """Buckets whose oldest request has waited at least the deadline."""
        return [b for b, pend in self.buckets.items()
                if pend and now - pend[0].t_submit >= self.deadline_s]

    def next_deadline(self) -> Optional[float]:
        """Absolute clock time the earliest pending deadline expires
        (None when no request is queued) — what a timer sleeps until."""
        oldest = [pend[0].t_submit for pend in self.buckets.values() if pend]
        return min(oldest) + self.deadline_s if oldest else None
