"""Tick-feed streaming repricing over a live scenario book.

A :class:`StreamingBook` is a flat batch of quoted contracts ("rows")
kept live against a market-data feed: each row references an underlying
id, and a :class:`Tick` moves one underlying's spot or vol.  Because the
grid engines price rows as independent vmap lanes, a tick only
invalidates the rows of *its* underlying — the book requotes exactly
those rows (grouped back into the scheduler's ``(n_steps, tc)`` buckets,
padded to a power of two so streaming traffic reuses the serving
layer's compiled shapes) and leaves every other quote untouched.

The correctness claim, and what makes incremental requoting safe, is
**differential equivalence**: after any tick sequence, the incrementally
maintained book is bit-equal (well under the repo-wide 1e-9) to a full
reprice of the post-tick book — prices, per-row ``max_pieces``
(``GridResult.row_pieces``), *and* OverflowError behaviour (a touched
row that would blow the PWL capacity budget raises either way; untouched
rows already priced within budget cannot start overflowing).
``tests/test_streaming_hypothesis.py`` checks this property over random
tick sequences.

:func:`synth_ticks` generates a reproducible synthetic feed; the
gateway's :meth:`~repro.serve.gateway.PricingGateway.run_stream`
consumes any iterable of ticks against a book.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from ..core.partition import _next_pow2

__all__ = ["Tick", "synth_ticks", "StreamingBook"]

_TICK_FIELDS = ("s0", "sigma")


@dataclasses.dataclass(frozen=True)
class Tick:
    """One market-data update: ``underlying``'s ``field`` is now
    ``value`` (an absolute level, not an increment — feeds publish
    levels, and levels keep replays idempotent)."""
    underlying: int
    field: str            # "s0" | "sigma"
    value: float


def synth_ticks(n: int, *, n_underlyings: int, seed: int = 0,
                s0_range=(90.0, 112.0), sigma_range=(0.15, 0.35),
                p_sigma: float = 0.3) -> List[Tick]:
    """A reproducible synthetic tick feed: ``n`` ticks over
    ``n_underlyings`` ids, spot levels uniform in ``s0_range`` and (with
    probability ``p_sigma``) vol levels uniform in ``sigma_range``."""
    rng = np.random.default_rng(seed)
    ticks = []
    for _ in range(n):
        u = int(rng.integers(n_underlyings))
        if rng.random() < p_sigma:
            ticks.append(Tick(u, "sigma",
                              float(rng.uniform(*sigma_range))))
        else:
            ticks.append(Tick(u, "s0", float(rng.uniform(*s0_range))))
    return ticks


class StreamingBook:
    """A flat batch of live-quoted contracts over shared underlyings.

    Row ``i``'s inputs live in parallel arrays (``s0``, ``sigma``,
    ``rate``, ``maturity``, ``cost_rate``, ``payoff``, ``strike``,
    ``strike2``, ``n_steps``, ``underlying``); its current quote in
    ``ask``/``bid``/``row_pieces`` (NaN / -1 until first priced).
    ``moneyness`` and ``vol_scale`` map an underlying's ticked level to
    the row (``s0 = level * moneyness`` — rows quoting the same
    underlying at offsets stay consistent under one tick).
    """

    def __init__(self, *, underlying, s0, sigma, rate, maturity, cost_rate,
                 payoff, strike, strike2, n_steps, moneyness=None,
                 vol_scale=None, capacity: int = 48, backend: str = "jnp"):
        self.underlying = np.asarray(underlying, dtype=int)
        n = self.underlying.shape[0]
        as_f = lambda a: np.broadcast_to(
            np.asarray(a, dtype=np.float64), (n,)).copy()
        self.s0 = as_f(s0)
        self.sigma = as_f(sigma)
        self.rate = as_f(rate)
        self.maturity = as_f(maturity)
        self.cost_rate = as_f(cost_rate)
        self.strike = as_f(strike)
        # None mirrors the service default: second strike 10 above the first
        self.strike2 = (self.strike + 10.0 if strike2 is None
                        else as_f(strike2))
        self.payoff = np.broadcast_to(np.asarray(payoff, dtype=object),
                                      (n,)).copy()
        self.n_steps = np.broadcast_to(np.asarray(n_steps, dtype=int),
                                       (n,)).copy()
        self.moneyness = as_f(1.0 if moneyness is None else moneyness)
        self.vol_scale = as_f(1.0 if vol_scale is None else vol_scale)
        self.capacity = int(capacity)
        self.backend = backend
        self.ask = np.full(n, np.nan)
        self.bid = np.full(n, np.nan)
        self.row_pieces = np.full(n, -1, dtype=int)

    # a book is driven by exactly one stream consumer (run_stream on the
    # gateway's event loop) — owner-confined (repro.analysis.guarded)
    GUARDED_BY = {
        "s0": "owner", "sigma": "owner", "ask": "owner", "bid": "owner",
        "row_pieces": "owner",
    }

    # ------------------------------------------------------------------ #
    # construction helpers
    # ------------------------------------------------------------------ #
    @classmethod
    def mixed(cls, *, n_underlyings: int = 2, per_underlying: int = 6,
              n_steps: Sequence[int] = (6, 8),
              cost_rates: Sequence[float] = (0.0, 0.01),
              sigma0: float = 0.2, capacity: int = 48,
              backend: str = "jnp") -> "StreamingBook":
        """A small 108-style mixed book: every underlying quotes a cycle
        of payoff families x strikes x cost rates x tree depths — the
        same heterogeneity the paper's 108-scenario grid exercises, as a
        flat streaming batch."""
        families = ("put", "call", "bull_spread")
        strikes = (90.0, 95.0, 100.0, 105.0, 110.0)
        rows: dict = {k: [] for k in ("underlying", "s0", "sigma",
                                      "cost_rate", "payoff", "strike",
                                      "n_steps")}
        for u in range(n_underlyings):
            for j in range(per_underlying):
                rows["underlying"].append(u)
                rows["s0"].append(100.0 + u)
                rows["sigma"].append(sigma0 + 0.02 * u)
                rows["cost_rate"].append(cost_rates[j % len(cost_rates)])
                rows["payoff"].append(families[j % len(families)])
                rows["strike"].append(strikes[j % len(strikes)])
                rows["n_steps"].append(int(n_steps[j % len(n_steps)]))
        return cls(rate=0.05, maturity=0.5, strike2=None,
                   capacity=capacity, backend=backend, **rows)

    @property
    def n_rows(self) -> int:
        return self.underlying.shape[0]

    @property
    def max_pieces(self) -> int:
        """Book-wide peak PWL knot count over priced rows — exactly what
        a full reprice of the current book would report."""
        priced = self.row_pieces[self.row_pieces >= 0]
        return int(priced.max()) if priced.size else 0

    def copy(self) -> "StreamingBook":
        """Independent snapshot (inputs and quotes) — the differential
        tests full-reprice a copy and diff it against the original."""
        out = StreamingBook(
            underlying=self.underlying, s0=self.s0, sigma=self.sigma,
            rate=self.rate, maturity=self.maturity,
            cost_rate=self.cost_rate, payoff=self.payoff,
            strike=self.strike, strike2=self.strike2, n_steps=self.n_steps,
            moneyness=self.moneyness, vol_scale=self.vol_scale,
            capacity=self.capacity, backend=self.backend)
        out.ask, out.bid = self.ask.copy(), self.bid.copy()
        out.row_pieces = self.row_pieces.copy()
        return out

    # ------------------------------------------------------------------ #
    # the feed side
    # ------------------------------------------------------------------ #
    def apply(self, tick: Tick) -> np.ndarray:
        """Fold one tick into the inputs; returns the indices of the rows
        it touched (the rows whose quotes are now stale)."""
        if tick.field not in _TICK_FIELDS:
            raise ValueError(f"unknown tick field {tick.field!r}; "
                             f"supported: {_TICK_FIELDS}")
        idx = np.nonzero(self.underlying == tick.underlying)[0]
        if tick.field == "s0":
            self.s0[idx] = tick.value * self.moneyness[idx]
        else:
            self.sigma[idx] = tick.value * self.vol_scale[idx]
        return idx

    # ------------------------------------------------------------------ #
    # the pricing side
    # ------------------------------------------------------------------ #
    def to_requests(self, idx) -> list:
        """The touched rows as PriceRequests (the gateway's streaming
        path submits these through the ordinary intake)."""
        from .engine import PriceRequest
        return [PriceRequest(
            s0=float(self.s0[i]), sigma=float(self.sigma[i]),
            rate=float(self.rate[i]), maturity=float(self.maturity[i]),
            cost_rate=float(self.cost_rate[i]),
            payoff=str(self.payoff[i]), strike=float(self.strike[i]),
            strike2=float(self.strike2[i]), n_steps=int(self.n_steps[i]))
            for i in np.asarray(idx, dtype=int)]

    def apply_quotes(self, idx, quotes) -> None:
        """Write delivered quotes back onto the touched rows."""
        for i, q in zip(np.asarray(idx, dtype=int), quotes):
            self.ask[i] = q.ask
            self.bid[i] = q.bid
            self.row_pieces[i] = q.max_pieces

    def requote(self, idx, pricer: Optional[Callable] = None) -> None:
        """Reprice exactly the rows in ``idx``, in place.

        Rows group into the serving buckets ``(n_steps, cost_rate>0)``
        and each group prices as one padded flat batch through
        ``pricer`` (default :func:`repro.api.price_flat`).  Raises
        ``OverflowError`` if any touched row needs more than
        ``capacity`` PWL knots — identical to a full reprice, because
        untouched rows already priced within budget.
        """
        if pricer is None:
            from ..api import price_flat
            pricer = price_flat
        from ..configs.pricing import ExecutionConfig
        idx = np.asarray(idx, dtype=int)
        buckets: dict = {}
        for i in idx:
            buckets.setdefault(
                (int(self.n_steps[i]), self.cost_rate[i] > 0.0),
                []).append(int(i))
        for (n_steps, _), rows in sorted(buckets.items()):
            rows = np.asarray(rows, dtype=int)
            res = pricer(
                s0=self.s0[rows], sigma=self.sigma[rows],
                rate=self.rate[rows], maturity=self.maturity[rows],
                cost_rate=self.cost_rate[rows],
                payoff=tuple(self.payoff[rows]),
                strike=self.strike[rows], strike2=self.strike2[rows],
                n_steps=n_steps, capacity=self.capacity,
                execution=ExecutionConfig(backend=self.backend),
                pad_to=_next_pow2(len(rows)))
            n = len(rows)
            self.ask[rows] = np.asarray(res.ask).ravel()[:n]
            self.bid[rows] = np.asarray(res.bid).ravel()[:n]
            rp = res.row_pieces
            self.row_pieces[rows] = (
                np.zeros(n, dtype=int) if rp is None
                else np.asarray(rp).ravel()[:n].astype(int))

    def full_reprice(self, pricer: Optional[Callable] = None) -> None:
        """Reprice every row (the reference the differential tests
        compare the incremental path against)."""
        self.requote(np.arange(self.n_rows), pricer)
