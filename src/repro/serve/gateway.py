"""Asyncio multi-replica pricing gateway over the scheduler core.

The second transport over ``serve/core.py::SchedulerCore`` (the first is
the cooperative :class:`~repro.serve.scheduler.PricingService`), fixing
the three limits ``docs/KNOWN_ISSUES.md`` recorded for the in-process
service:

* **Timer-driven deadlines.**  A background flusher task sleeps until
  the earliest pending deadline and dispatches due buckets itself — a
  request is flushed within ``deadline_ms`` with *zero* driver
  involvement (the old service only honoured deadlines when the driver
  happened to call ``step()``).
* **A replica pool.**  Flushed chunks run on N replica workers, each on
  its own single-thread executor, so a slow RZ compile on one replica
  no longer blocks intake or the other replicas.  Buckets route with
  sticky ``(n_steps, engine)`` affinity — the same bucket keeps hitting
  the same replica for compile/kernel warmth (Pagès–Wilbertz's GPGPU
  batching argument), falling over to the least-loaded healthy replica
  only when the sticky one dies.
* **Fault tolerance.**  The replica boundary is untrusted: a replica
  that crashes (:class:`~repro.serve.replica.ReplicaCrash`) or hangs
  past ``replica_timeout_s`` is marked dead (and respawned after
  ``restart_s`` when configured), and its in-flight chunk is re-queued
  to a healthy replica under bounded retry with exponential backoff.
  Request-level errors (an ``OverflowError`` from the PWL capacity
  check) retry the same way but leave the replica healthy; when retries
  exhaust, the error is delivered on the request's future — no request
  is ever silently dropped.

Under sustained overload the gateway degrades before it sheds: when the
backlog stays above ``overload_factor x max_batch x healthy_replicas``
for ``overload_grace_s``, the effective ``max_batch`` halves (smaller
flush quanta bound each engine call's head-of-line blocking so the
backlog drains in shorter, preemptible steps), recovering by doubling
once the backlog clears; only past ``shed_factor`` x the degrade
threshold does :meth:`submit` refuse work (:class:`GatewayOverloaded`).

**Streaming mode** (:meth:`run_stream`): subscribe a
:class:`~repro.serve.streaming.StreamingBook` to a tick feed and
incrementally requote only the rows a tick touched — grid-engine lanes
are independent, so incremental requotes match a full reprice of the
post-tick book bit-for-bit, including per-row ``max_pieces``
(``tests/test_streaming_hypothesis.py`` is the differential proof).

Everything time-related goes through the injectable ``clock`` /
``sleeper`` pair so the deadline machinery is testable against a fake
clock (``tests/test_gateway_deadline.py``); the replica hang timeout is
the exception — it guards against wall-clock wedged workers and always
uses real event-loop time.
"""
from __future__ import annotations

import asyncio
import contextlib
import dataclasses
from concurrent.futures import ThreadPoolExecutor
import time
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from ..configs.pricing import ExecutionConfig
from .core import ChunkSpec, SchedulerCore, ServiceMetrics
from .procpool import ReplicaPool, warmup_chunk
from .replica import LocalReplica, ReplicaCrash

__all__ = ["PricingGateway", "GatewayMetrics", "GatewayError",
           "GatewayOverloaded"]


class GatewayError(RuntimeError):
    """Gateway-level failure (e.g. no healthy replica and no restart)."""


class GatewayOverloaded(GatewayError):
    """submit() refused: backlog past the shedding threshold."""


@dataclasses.dataclass
class GatewayMetrics(ServiceMetrics):
    """ServiceMetrics plus the gateway's fault/overload/streaming
    counters (same thread-safety contract: mutate via the locked
    methods)."""
    retries: int = 0             # chunk re-dispatches after a failure
    requeues: int = 0            # failures that put a chunk back in play
    backoffs: int = 0            # exponential-backoff sleeps taken
    backoff_seconds: float = 0.0
    failed: int = 0              # requests completed *with an error*
    replica_crashes: int = 0
    replica_hangs: int = 0
    replica_restarts: int = 0
    affinity_moves: int = 0      # sticky bucket re-homed to another replica
    degraded: int = 0            # effective max_batch halvings
    restored: int = 0            # ... doublings on recovery
    shed: int = 0                # submits refused (GatewayOverloaded)
    deadline_flushes: int = 0    # dispatches fired by the timer
    size_flushes: int = 0        # ... by the size trigger
    forced_flushes: int = 0      # ... by drain()/streaming
    ticks: int = 0               # streaming ticks consumed
    rows_requoted: int = 0       # rows incrementally requoted
    staleness: List[float] = dataclasses.field(default_factory=list)

    # extends ServiceMetrics.GUARDED_BY (registries merge down the base
    # chain in repro.analysis.guarded)
    GUARDED_BY = {
        "retries": "_lock", "requeues": "_lock", "backoffs": "_lock",
        "backoff_seconds": "_lock", "failed": "_lock",
        "replica_crashes": "_lock", "replica_hangs": "_lock",
        "replica_restarts": "_lock", "affinity_moves": "_lock",
        "degraded": "_lock", "restored": "_lock", "shed": "_lock",
        "deadline_flushes": "_lock", "size_flushes": "_lock",
        "forced_flushes": "_lock", "ticks": "_lock",
        "rows_requoted": "_lock", "staleness": "_lock",
    }

    def add_staleness(self, seconds: float) -> None:
        """Tick-to-delivered-quote seconds (bounded like latencies)."""
        with self._lock:
            self.staleness.append(seconds)
            if len(self.staleness) > 2 * self.latency_window:
                del self.staleness[:-self.latency_window]

    def _snapshot_locked(self) -> dict:
        # extend the BASE snapshot under the SAME lock acquisition: an
        # override of snapshot() that locked a second time produced a
        # torn read — base counters from one instant, gateway counters
        # from another (e.g. completed != requests - failed mid-flush)
        snap = super()._snapshot_locked()
        stale = (np.asarray(self.staleness) if self.staleness
                 else np.zeros(1))
        snap.update({
            "retries": self.retries, "requeues": self.requeues,
            "backoffs": self.backoffs,
            "backoff_seconds": self.backoff_seconds,
            "failed": self.failed,
            "replica_crashes": self.replica_crashes,
            "replica_hangs": self.replica_hangs,
            "replica_restarts": self.replica_restarts,
            "affinity_moves": self.affinity_moves,
            "degraded": self.degraded, "restored": self.restored,
            "shed": self.shed,
            "deadline_flushes": self.deadline_flushes,
            "size_flushes": self.size_flushes,
            "forced_flushes": self.forced_flushes,
            "ticks": self.ticks,
            "rows_requoted": self.rows_requoted,
            "staleness_p50_ms": float(np.percentile(stale, 50) * 1e3),
            "staleness_p99_ms": float(np.percentile(stale, 99) * 1e3),
        })
        return snap


class _Slot:
    """One replica worker: the replica object, its single-thread
    executor, and its health/affinity state."""

    def __init__(self, index: int, replica):
        self.index = index
        self.replica = replica
        self.name = getattr(replica, "name", f"replica-{index}")
        self.executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"gw-{self.name}")
        self.healthy = True
        self.dead_reason: Optional[str] = None
        self.inflight = 0
        self.calls = 0
        self.sticky: Set[tuple] = set()

    # slot state is event-loop-confined: the executor thread only runs
    # replica.price_chunk, never touches the slot (repro.analysis.guarded)
    GUARDED_BY = {
        "healthy": "owner", "dead_reason": "owner", "inflight": "owner",
        "calls": "owner", "sticky": "owner",
    }

    def kill(self, reason: str) -> None:
        self.healthy = False
        self.dead_reason = reason
        self.sticky.clear()
        # a process-backed replica holds a real worker: SIGKILL it first,
        # which also unblocks the executor thread waiting on its pipe
        close = getattr(self.replica, "close", None)
        if close is not None:
            close()
        # a hung worker thread cannot be interrupted; abandon the
        # executor (its thread unwinds when the replica call returns)
        self.executor.shutdown(wait=False, cancel_futures=True)


class PricingGateway:
    """Async multi-replica front end over :class:`SchedulerCore`.

    Usage (see docs/SERVING.md for the operator's guide)::

        async with PricingGateway(replicas=2, deadline_ms=5.0) as gw:
            rid = await gw.submit(PriceRequest(s0=100.0, sigma=0.2,
                                               rate=0.1, maturity=0.25))
            quote = await gw.result(rid)

    ``replicas`` is a count (spawning workers via ``replica_factory``)
    or an explicit list of replica objects (the fault harness passes
    :class:`~repro.serve.replica.FaultyReplica`).  ``pool`` selects what
    a spawned replica *is*: ``"thread"`` (default) keeps the in-process
    :class:`LocalReplica` workers; ``"process"`` backs every slot with a
    real spawned process (``serve/procpool.py::ProcessReplica``) —
    per-process jit caches, warmup chunk on start, SIGKILL-and-respawn
    on hang — behind the *same* failover machinery.  Pass a
    :class:`~repro.serve.procpool.ReplicaPool` instance for custom
    warmup/deadline settings; an explicit ``replica_factory`` wins over
    ``pool``.  ``execution`` consolidates the engine-selection knobs
    (fields set on it override ``backend``/``interpret``/``n_paths``/
    ``mc_seed``).
    """

    def __init__(self, *, replicas=2, max_batch: int = 64,
                 deadline_ms: float = 5.0, capacity: int = 48,
                 backend: str = "jnp", interpret: Optional[bool] = None,
                 default_n_steps: int = 100,
                 default_payoff: str = "put", default_strike: float = 100.0,
                 result_cache_size: int = 1024, max_results: int = 65536,
                 replica_timeout_s: float = 300.0, max_retries: int = 3,
                 retry_backoff_s: float = 0.05,
                 restart_s: Optional[float] = None,
                 replica_factory: Optional[Callable[[int], object]] = None,
                 pool="thread", n_paths: int = 4096, mc_seed: int = 0,
                 basis: str = "poly", degree: int = 3,
                 antithetic: bool = True,
                 execution: Optional[ExecutionConfig] = None,
                 overload_factor: Optional[float] = 8.0,
                 overload_grace_s: float = 0.25, shed_factor: float = 4.0,
                 min_batch: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 sleeper=None):
        if execution is not None:
            s = execution.set_fields()
            backend = execution.backend if "backend" in s else backend
            interpret = (execution.interpret if "interpret" in s
                         else interpret)
            n_paths = execution.n_paths if "n_paths" in s else n_paths
            mc_seed = execution.mc_seed if "mc_seed" in s else mc_seed
            # every program-role execution knob must survive to the chunk
            # (repro.analysis.compile_key audits the carry-through)
            basis = execution.basis if "basis" in s else basis
            degree = execution.degree if "degree" in s else degree
            antithetic = (execution.antithetic if "antithetic" in s
                          else antithetic)
        self.core = SchedulerCore(
            max_batch=max_batch, deadline_ms=deadline_ms, capacity=capacity,
            backend=backend, interpret=interpret,
            default_n_steps=default_n_steps,
            default_payoff=default_payoff, default_strike=default_strike,
            result_cache_size=result_cache_size, max_results=max_results,
            n_paths=n_paths, mc_seed=mc_seed,
            basis=basis, degree=degree, antithetic=antithetic,
            clock=clock, metrics=GatewayMetrics())
        self.max_batch = int(max_batch)
        self.effective_max_batch = int(max_batch)
        self.min_batch = max(1, int(min_batch))
        self.replica_timeout_s = float(replica_timeout_s)
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.restart_s = restart_s
        self.overload_factor = overload_factor
        self.overload_grace_s = float(overload_grace_s)
        self.shed_factor = float(shed_factor)
        if replica_factory is not None:
            self._factory = replica_factory
        else:
            if isinstance(pool, ReplicaPool):
                rp = pool
            elif pool == "process":
                # per-process warmup pre-compiles the pool's default
                # bucket; the per-call deadline mirrors the gateway's
                # hang timeout so a wedged engine call is SIGKILLed
                rp = ReplicaPool(
                    "process",
                    warmup=warmup_chunk(n_steps=default_n_steps,
                                        backend=backend, capacity=capacity,
                                        interpret=interpret),
                    call_timeout_s=replica_timeout_s)
            elif pool == "thread":
                rp = ReplicaPool("thread")
            else:
                raise ValueError(
                    f"pool must be 'thread', 'process' or a ReplicaPool, "
                    f"got {pool!r}")
            self._factory = rp.factory
        if isinstance(replicas, int):
            self._initial = [self._factory(i) for i in range(replicas)]
        else:
            self._initial = list(replicas)
        if not self._initial:
            raise ValueError("need at least one replica")
        self._sleeper = sleeper
        self._slots: List[_Slot] = []
        self._sticky: Dict[tuple, _Slot] = {}
        self._futures: Dict[int, asyncio.Future] = {}
        self._chunk_tasks: Set[asyncio.Task] = set()
        self._bg_tasks: Set[asyncio.Task] = set()
        self._inflight_rows = 0
        self._over_since: Optional[float] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._flusher: Optional[asyncio.Task] = None
        self._closed = False

    # gateway mutable state is event-loop-confined by design: replica
    # worker threads return results through run_in_executor futures, and
    # all bookkeeping happens back on the loop (repro.analysis.guarded
    # verifies statically; shadow mode pins the owner thread at runtime)
    GUARDED_BY = {
        "effective_max_batch": "owner", "_slots": "owner",
        "_sticky": "owner", "_futures": "owner", "_chunk_tasks": "owner",
        "_bg_tasks": "owner", "_inflight_rows": "owner",
        "_over_since": "owner", "_loop": "owner", "_flusher": "owner",
        "_closed": "owner", "_wake": "owner", "_replica_up": "owner",
    }

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    async def start(self) -> "PricingGateway":
        self._loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self._replica_up = asyncio.Event()
        self._slots = [_Slot(i, r) for i, r in enumerate(self._initial)]
        self._flusher = self._loop.create_task(self._deadline_loop(),
                                               name="gw-deadline-flusher")
        return self

    async def __aenter__(self) -> "PricingGateway":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.aclose(drain=exc == (None, None, None))

    async def aclose(self, drain: bool = True) -> None:
        if self._closed:
            return
        if drain:
            await self.drain()
        self._closed = True
        for task in [self._flusher, *self._bg_tasks, *self._chunk_tasks]:
            if task is not None:
                task.cancel()
        for task in [self._flusher, *self._bg_tasks, *self._chunk_tasks]:
            if task is not None:
                with contextlib.suppress(asyncio.CancelledError, Exception):
                    await task
        for fut in self._futures.values():
            if not fut.done():
                fut.cancel()
        for slot in self._slots:
            close = getattr(slot.replica, "close", None)
            if close is not None:
                close()
            slot.executor.shutdown(wait=False, cancel_futures=True)

    async def drain(self) -> None:
        """Force-flush everything pending and wait for delivery."""
        while True:
            for bucket in list(self.core.buckets):
                self.metrics_.bump(forced_flushes=1)
                self._dispatch_bucket(bucket, force=True)
            tasks = [t for t in self._chunk_tasks if not t.done()]
            if not tasks and not self.core.buckets:
                return
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
            else:
                await asyncio.sleep(0)

    # ------------------------------------------------------------------ #
    # intake
    # ------------------------------------------------------------------ #
    async def submit(self, req) -> int:
        """Enqueue one contract; returns a request id whose quote (or
        error) arrives on :meth:`result`.  Raises
        :class:`GatewayOverloaded` past the shedding threshold."""
        if self._closed:
            raise GatewayError("gateway is closed")
        self._check_overload()
        rid, bucket, quote = self.core.submit(req)
        fut = self._loop.create_future()
        self._futures[rid] = fut
        if quote is not None:
            fut.set_result(quote)
        elif len(self.core.buckets[bucket]) >= self.effective_max_batch:
            self.metrics_.bump(size_flushes=1)
            self._dispatch_bucket(bucket)
        else:
            self._wake.set()        # flusher: re-aim the deadline timer
        return rid

    async def result(self, rid: int):
        """Await the quote for ``rid``; raises the request's error if its
        chunk exhausted retries."""
        fut = self._futures.get(rid)
        if fut is None:
            quote = self.core.result(rid)
            if quote is None:
                raise KeyError(f"unknown or expired request id {rid}")
            return quote
        try:
            return await fut
        finally:
            self._futures.pop(rid, None)

    def metrics(self) -> dict:
        snap = self.metrics_.snapshot()
        snap["healthy_replicas"] = sum(s.healthy for s in self._slots)
        snap["effective_max_batch"] = self.effective_max_batch
        return snap

    @property
    def metrics_(self) -> GatewayMetrics:
        return self.core.metrics_

    @property
    def pending_count(self) -> int:
        """Queued plus in-flight (dispatched, not yet delivered) rows."""
        return self.core.pending_count + self._inflight_rows

    def replica_states(self) -> List[dict]:
        return [{"name": s.name, "healthy": s.healthy,
                 "dead_reason": s.dead_reason, "calls": s.calls,
                 "sticky_buckets": len(s.sticky)} for s in self._slots]

    # ------------------------------------------------------------------ #
    # overload control: degrade (halve max_batch), then shed
    # ------------------------------------------------------------------ #
    def _check_overload(self) -> None:
        if self.overload_factor is None:
            return
        now = self.core._clock()
        healthy = max(1, sum(s.healthy for s in self._slots))
        degrade_hwm = self.overload_factor * self.max_batch * healthy
        pending = self.pending_count
        if pending >= self.shed_factor * degrade_hwm:
            self.metrics_.bump(shed=1)
            raise GatewayOverloaded(
                f"{pending} rows pending >= shed threshold "
                f"{self.shed_factor * degrade_hwm:.0f}; resubmit later")
        if pending > degrade_hwm:
            if self._over_since is None:
                self._over_since = now
            elif (now - self._over_since >= self.overload_grace_s
                  and self.effective_max_batch > self.min_batch):
                self.effective_max_batch = max(
                    self.min_batch, self.effective_max_batch // 2)
                self.metrics_.bump(degraded=1)
                self._over_since = now      # re-arm for another halving
        else:
            self._over_since = None

    def _maybe_recover_batch(self) -> None:
        if (self.overload_factor is None
                or self.effective_max_batch >= self.max_batch):
            return
        healthy = max(1, sum(s.healthy for s in self._slots))
        low_wm = self.overload_factor * self.max_batch * healthy / 4.0
        if self.pending_count < low_wm:
            self.effective_max_batch = min(self.max_batch,
                                           self.effective_max_batch * 2)
            self.metrics_.bump(restored=1)

    # ------------------------------------------------------------------ #
    # timer-driven deadline flusher
    # ------------------------------------------------------------------ #
    async def _sleep(self, seconds: float) -> None:
        if self._sleeper is not None:
            await self._sleeper(seconds)
        else:
            await asyncio.sleep(seconds)

    async def _wake_or_sleep(self, seconds: float) -> None:
        """Race the wake event (a submit changed the queue picture)
        against the timer; whichever fires first wins.  (The parameter
        is ``seconds``, not ``timeout``: this helper deliberately does
        NOT cancel the awaited work on expiry the way ``wait_for`` does
        — ruff ASYNC109 flags the misleading name.)"""
        waiter = self._loop.create_task(self._wake.wait())
        sleeper = self._loop.create_task(self._sleep(seconds))
        _, pending = await asyncio.wait({waiter, sleeper},
                                        return_when=asyncio.FIRST_COMPLETED)
        for task in pending:
            task.cancel()
        if pending:
            # reap with wait() (which never unwraps results): awaiting a
            # cancelled inner task under suppress() would also swallow an
            # *outer* cancellation landing here, wedging aclose() forever
            await asyncio.wait(pending)

    async def _deadline_loop(self) -> None:
        while True:
            self._wake.clear()
            now = self.core._clock()
            for bucket in self.core.due_buckets(now):
                self.metrics_.bump(deadline_flushes=1)
                self._dispatch_bucket(bucket, force=True)
            self._maybe_recover_batch()
            nxt = self.core.next_deadline()
            if nxt is None:
                delay = 1.0             # idle: only a submit matters,
            else:                       # and submit sets the wake event
                delay = max(nxt - self.core._clock(), 1e-4)
            await self._wake_or_sleep(delay)

    # ------------------------------------------------------------------ #
    # dispatch to replicas
    # ------------------------------------------------------------------ #
    def _dispatch_bucket(self, bucket: tuple, force: bool = False) -> None:
        while True:
            pend = self.core.buckets.get(bucket)
            if not pend or (not force
                            and len(pend) < self.effective_max_batch):
                return
            chunk = self.core.take_chunk(bucket, self.effective_max_batch)
            self._inflight_rows += chunk.n
            task = self._loop.create_task(self._run_chunk(chunk))
            self._chunk_tasks.add(task)
            task.add_done_callback(self._chunk_tasks.discard)

    def _pick_slot(self, bucket: tuple) -> Optional[_Slot]:
        cur = self._sticky.get(bucket)
        if cur is not None and cur.healthy:
            return cur
        healthy = [s for s in self._slots if s.healthy]
        if not healthy:
            return None
        slot = min(healthy, key=lambda s: (len(s.sticky), s.inflight,
                                           s.index))
        if cur is not None:
            self.metrics_.bump(affinity_moves=1)
        self._sticky[bucket] = slot
        slot.sticky.add(bucket)
        return slot

    def _mark_dead(self, slot: _Slot, reason: str, counter: str) -> None:
        if not slot.healthy:
            return
        slot.kill(reason)
        self.metrics_.bump(**{counter: 1})
        if self.restart_s is not None:
            self._spawn_bg(self._restart_slot(slot.index))

    async def _restart_slot(self, index: int) -> None:
        await self._sleep(self.restart_s)
        self._slots[index] = _Slot(index, self._factory(index))
        self.metrics_.bump(replica_restarts=1)
        self._replica_up.set()
        self._wake.set()

    def _spawn_bg(self, coro) -> None:
        task = self._loop.create_task(coro)
        self._bg_tasks.add(task)
        task.add_done_callback(self._bg_tasks.discard)

    async def _await_replica(self) -> bool:
        """Wait for any healthy replica; False when none will ever come
        back (no restart policy)."""
        while not any(s.healthy for s in self._slots):
            if self.restart_s is None:
                return False
            self._replica_up.clear()
            if any(s.healthy for s in self._slots):
                break
            await self._replica_up.wait()
        return True

    async def _run_chunk(self, chunk: ChunkSpec) -> None:
        """Price one chunk with failover: bounded retries, exponential
        backoff, replica health bookkeeping."""
        attempts = 0
        while True:
            slot = self._pick_slot(chunk.bucket)
            if slot is None:
                if not await self._await_replica():
                    self._fail_chunk(chunk, GatewayError(
                        "no healthy replica and restart_s is not set"))
                    return
                continue
            slot.inflight += 1
            try:
                result = await asyncio.wait_for(
                    self._loop.run_in_executor(
                        slot.executor, slot.replica.price_chunk, chunk),
                    timeout=self.replica_timeout_s)
            except asyncio.TimeoutError:
                err = GatewayError(
                    f"replica {slot.name} hung past "
                    f"{self.replica_timeout_s}s on bucket {chunk.bucket}")
                self._mark_dead(slot, "hung", "replica_hangs")
            except asyncio.CancelledError:
                if slot.healthy:
                    # genuine outer cancellation (gateway shutdown)
                    slot.inflight -= 1
                    raise
                # the slot died while this chunk sat in its executor
                # queue — kill() cancels queued work items, and wait_for
                # re-raises that inner cancellation here.  Same failure
                # as the crash that killed the slot: requeue elsewhere.
                err = GatewayError(
                    f"replica {slot.name} died with this chunk queued "
                    f"({slot.dead_reason})")
            except ReplicaCrash as e:
                err = e
                self._mark_dead(slot, "crashed", "replica_crashes")
            except Exception as e:
                # a *request* error (e.g. OverflowError from the PWL
                # capacity check): the replica is fine, the chunk is the
                # problem — retry it, then surface on the futures
                err = e
            else:
                slot.inflight -= 1
                slot.calls += 1
                now = self.core._clock()
                done = self.core.complete(chunk, result, now,
                                          engine_seconds=result.seconds)
                self._inflight_rows -= chunk.n
                for rid, quote in done.items():
                    fut = self._futures.get(rid)
                    if fut is not None and not fut.done():
                        fut.set_result(quote)
                return
            slot.inflight -= 1
            attempts += 1
            self.metrics_.bump(requeues=1)
            if attempts > self.max_retries:
                self._fail_chunk(chunk, err)
                return
            self.metrics_.bump(retries=1)
            backoff = self.retry_backoff_s * (2.0 ** (attempts - 1))
            if backoff > 0:
                self.metrics_.bump(backoffs=1, backoff_seconds=backoff)
                await self._sleep(backoff)

    def _fail_chunk(self, chunk: ChunkSpec, err: BaseException) -> None:
        """Deliver ``err`` on every request of the chunk — failure is an
        answer too; nothing is silently dropped."""
        self._inflight_rows -= chunk.n
        self.metrics_.bump(failed=chunk.n)
        for p in chunk.requests:
            fut = self._futures.get(p.rid)
            if fut is not None and not fut.done():
                fut.set_exception(err)

    # ------------------------------------------------------------------ #
    # streaming repricing
    # ------------------------------------------------------------------ #
    async def run_stream(self, book, ticks) -> dict:
        """Consume a tick feed, incrementally requoting only the book
        rows each tick touched (see ``serve/streaming.py``).

        Each tick's touched rows are submitted as ordinary requests (so
        they coalesce into buckets, hit the result LRU, and enjoy the
        full failover machinery) and force-flushed as one natural batch;
        the tick's staleness — tick arrival to last delivered quote — is
        recorded in the metrics.  Returns a summary dict.
        """
        for tick in ticks:
            t_tick = self.core._clock()
            idx = book.apply(tick)
            self.metrics_.bump(ticks=1, rows_requoted=len(idx))
            if len(idx) == 0:
                continue
            rids = []
            for req in book.to_requests(idx):
                rids.append(await self.submit(req))
            for bucket in list(self.core.buckets):
                self.metrics_.bump(forced_flushes=1)
                self._dispatch_bucket(bucket, force=True)
            quotes = [await self.result(rid) for rid in rids]
            book.apply_quotes(idx, quotes)
            self.metrics_.add_staleness(self.core._clock() - t_tick)
        snap = self.metrics()
        return {"ticks": snap["ticks"],
                "rows_requoted": snap["rows_requoted"],
                "staleness_p50_ms": snap["staleness_p50_ms"],
                "staleness_p99_ms": snap["staleness_p99_ms"]}
