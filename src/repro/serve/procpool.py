"""Process-backed replica pool for the asyncio pricing gateway.

:class:`ProcessReplica` satisfies the same ``price_chunk(ChunkSpec) ->
ChunkResult`` protocol as ``serve/replica.py::LocalReplica`` but executes
every chunk in a **spawned worker process** — one process per replica, so
replicas stop sharing a GIL and a jit cache, and a replica "crash" is a
real ``kill -9``, not an injected exception.  The paper's §4.2 workers
are exactly this shape: independent processes with explicit
synchronisation, reassigned work when one falls behind.

Lifecycle (see ``docs/SERVING.md`` for the operator's guide)::

    spawn ──► warmup chunk (compiles the bucket's program) ──► ready
      │            │                                            │
      │            │ never acks within warmup_timeout_s         │ price_chunk
      │            ▼                                            ▼
      │        SIGKILL + ReplicaCrash                   send ChunkSpec.to_wire()
      │                                                         │
      │     ┌── deadline (call_timeout_s) ── SIGKILL ──┐        │
      └─────┤                                          ├◄───────┤
            └── pipe EOF / worker exit ── ReplicaCrash ┘        ▼
                                                     recv ChunkResult.from_wire()

Everything crossing the pipe is the versioned wire schema of
``serve/core.py`` (``to_wire``/``from_wire``) — plain scalars, tuples and
numpy arrays, never a live mesh or a callable.  The worker resolves the
chunk's ``devices=`` *count* against its own jax runtime, so a pool can
in principle span heterogeneous hosts.

Fault semantics match the gateway's thread-pool contract exactly:

* a **hung** worker (no reply within ``call_timeout_s``) is killed with
  SIGKILL and :class:`~repro.serve.replica.ReplicaCrash` raised — the
  gateway marks the slot dead, re-queues the in-flight chunk, and (with
  ``restart_s``) respawns a fresh process through the same factory;
* a **dead** worker is detected by pipe EOF or the process sentinel
  (exitcode), again surfacing as :class:`ReplicaCrash`;
* a **request** error (e.g. a PWL capacity ``OverflowError``) is
  re-raised under its own type — the worker stays alive and healthy.

:class:`ReplicaPool` is the factory the gateway consumes via
``pool={"thread","process"}``: ``factory(i)`` builds replica ``i`` and is
also what ``restart_s`` respawn calls, so a killed process is replaced by
a *new* process, warmup and all.
"""
from __future__ import annotations

import contextlib
import multiprocessing
import multiprocessing.connection
import os
import signal
import threading
import time
from typing import Dict, Optional

from .core import ChunkResult, ChunkSpec, _Pending
from .replica import LocalReplica, ReplicaCrash

__all__ = ["ProcessReplica", "ReplicaPool", "warmup_chunk"]


def warmup_chunk(*, n_steps: int = 8, backend: str = "jnp",
                 capacity: int = 16, engine: str = "notc",
                 interpret: Optional[bool] = None,
                 n_paths: int = 256, n_assets: int = 1,
                 exercise_steps: Optional[tuple] = None) -> dict:
    """Wire dict for a 1-row chunk a worker prices on start.

    Pricing it imports jax, sets the platform policy and compiles the
    (padded=1) program for the pool's default bucket — the first real
    request then hits a warm process.  ``rid=-1`` marks it synthetic;
    the result is discarded, only the ack matters.
    """
    key = (100.0, 0.2, 0.1, 0.25, 0.0, "put", 100.0, 110.0,
           n_steps, n_assets, exercise_steps)
    chunk = ChunkSpec(
        bucket=(n_steps, engine), requests=[_Pending(-1, key, 0.0)],
        n_steps=n_steps, engine=engine, capacity=capacity, backend=backend,
        padded=1,
        cols=((100.0,), (0.2,), (0.1,), (0.25,), (0.0,), ("put",),
              (100.0,), (110.0,)),
        n_assets=n_assets, exercise_steps=exercise_steps,
        n_paths=n_paths, interpret=interpret)
    return chunk.to_wire()


def _worker_main(conn, cfg: dict) -> None:
    """Worker process entry point (module-level so spawn can pickle it).

    A strict request/reply loop over ``conn``: every message is a tuple
    whose first element is the op.  Engine execution goes through the
    same ``execute_chunk`` as every other transport — importing it pulls
    in ``repro.core`` whose package init sets the x64 policy, so a spawn
    worker prices bit-identically to the parent.

    ``cfg["faults"]`` maps the worker-local chunk index to a fault kind
    (``"sigkill"`` | ``"exit"`` | ``"hang"``) and ``cfg["hang_warmup"]``
    wedges the warmup ack — the real-process analogue of
    ``FaultyReplica``, used by the fault suite and the kill-injection
    bench.  Faults are *real*: ``sigkill`` is ``os.kill(…, SIGKILL)`` on
    itself, not an exception.
    """
    from .core import execute_chunk      # late: after spawn bootstraps
    faults = {int(k): v for k, v in (cfg.get("faults") or {}).items()}
    calls = 0
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return                       # parent closed its end / died
        op = msg[0]
        if op == "stop":
            return
        if op == "warmup":
            if cfg.get("hang_warmup"):
                time.sleep(3600.0)       # never acks; parent SIGKILLs us
            t0 = time.perf_counter()
            execute_chunk(ChunkSpec.from_wire(msg[1]))
            conn.send(("ready", os.getpid(), time.perf_counter() - t0))
            continue
        if op == "chunk":
            i, calls = calls, calls + 1
            fault = faults.get(i)
            if fault == "sigkill":
                os.kill(os.getpid(), signal.SIGKILL)
            if fault == "exit":
                # pipe EOF on the result read: close our end, then die
                # without flushing anything
                conn.close()
                os._exit(3)
            if fault == "hang":
                time.sleep(3600.0)       # parent's deadline SIGKILLs us
            try:
                res = execute_chunk(ChunkSpec.from_wire(msg[1]))
            except BaseException as e:   # noqa: BLE001 — forwarded whole
                conn.send(("err", type(e).__name__, str(e)))
            else:
                conn.send(("ok", res.to_wire()))
            continue
        conn.send(("err", "ValueError", f"unknown op {op!r}"))


class ProcessReplica:
    """A replica that prices chunks in its own spawned process.

    Satisfies the gateway's replica protocol (``name``, ``calls``,
    ``price_chunk``) and adds ``pid``/``alive``/``close()``.  All
    infrastructure failures — deadline exceeded (worker SIGKILLed),
    pipe EOF, worker exit — raise :class:`ReplicaCrash`; once dead the
    replica stays dead (the gateway respawns through the pool factory).

    ``price_chunk`` is serialized by a lock (the gateway runs one call
    in flight per replica anyway); ``close()`` deliberately does *not*
    take it, so killing the process unblocks a concurrent call via the
    process sentinel.
    """

    def __init__(self, name: str = "proc", *, warmup: Optional[dict] = None,
                 call_timeout_s: Optional[float] = None,
                 warmup_timeout_s: float = 120.0,
                 faults: Optional[Dict[int, str]] = None,
                 hang_warmup: bool = False, start: bool = True):
        self.name = name
        self.calls = 0
        self.call_timeout_s = call_timeout_s
        self.warmup_timeout_s = float(warmup_timeout_s)
        self._warmup = warmup
        self._cfg = {"faults": dict(faults or {}),
                     "hang_warmup": bool(hang_warmup)}
        self._lock = threading.Lock()
        self._dead: Optional[str] = None
        self._ready = False
        self._warmup_deadline: Optional[float] = None
        self._conn = None
        self._proc = None
        if start:
            self.start()

    # price_chunk (and everything it calls) runs under _lock; close()
    # is deliberately lock-free — see the class docstring and the
    # reasoned waivers in tools/analysis_waivers.toml.
    GUARDED_BY = {
        "_dead": "_lock", "_ready": "_lock", "calls": "_lock",
        "warmup_seconds": "_lock", "_conn": "_lock", "_proc": "_lock",
        "_warmup_deadline": "_lock",
    }

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> None:
        ctx = multiprocessing.get_context("spawn")
        parent, child = ctx.Pipe()
        self._proc = ctx.Process(target=_worker_main, args=(child, self._cfg),
                                 name=self.name, daemon=True)
        self._proc.start()
        child.close()                    # child's end lives in the child
        self._conn = parent
        if self._warmup is None:
            self._ready = True
        else:
            self._conn.send(("warmup", self._warmup))
            self._warmup_deadline = (time.monotonic()
                                     + self.warmup_timeout_s)

    @property
    def pid(self) -> Optional[int]:
        return None if self._proc is None else self._proc.pid

    @property
    def alive(self) -> bool:
        return (self._dead is None and self._proc is not None
                and self._proc.is_alive())

    def close(self) -> None:
        """Kill the worker and release the pipe (idempotent; called by
        the gateway's slot teardown).  Lock-free by design — a blocked
        ``price_chunk`` wakes up via the process sentinel."""
        self._dead = self._dead or "closed"
        self._kill()
        if self._conn is not None:
            with contextlib.suppress(OSError):
                self._conn.close()

    def _kill(self) -> None:
        if self._proc is not None and self._proc.is_alive():
            self._proc.kill()            # SIGKILL — no cooperation needed
            self._proc.join(timeout=10.0)

    def _exitcode(self):
        """The worker's exitcode for diagnostics (joins briefly so a
        just-died process settles to its real code, e.g. -9)."""
        if self._proc is None:
            return None
        self._proc.join(timeout=1.0)
        return self._proc.exitcode

    def _die(self, reason: str) -> ReplicaCrash:  # locked: _lock
        """Mark dead and build (not raise) the crash for the caller.
        Called only from under ``price_chunk``'s lock."""
        self._dead = reason
        if self._conn is not None:
            with contextlib.suppress(OSError):
                self._conn.close()
        return ReplicaCrash(f"{self.name}: {reason}")

    # ------------------------------------------------------------------ #
    # wire I/O
    # ------------------------------------------------------------------ #
    def _recv(self, timeout: Optional[float], what: str):  # locked: _lock
        """One reply off the pipe, racing the worker's death sentinel.

        ``timeout`` None = wait forever (modulo the sentinel).  On
        deadline the worker is SIGKILLed first — a wedged engine call
        holds the jax runtime, so the only safe recovery is a fresh
        process — then :class:`ReplicaCrash` raises.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            remaining = (None if deadline is None
                         else max(0.0, deadline - time.monotonic()))
            try:
                ready = multiprocessing.connection.wait(
                    [self._conn, self._proc.sentinel], timeout=remaining)
            except OSError:
                raise self._die(f"pipe failed waiting for {what}") from None
            if self._conn in ready:
                try:
                    return self._conn.recv()
                except (EOFError, OSError):
                    raise self._die(
                        f"pipe EOF reading {what} "
                        f"(exitcode {self._exitcode()})") from None
            if ready:                    # sentinel fired: worker exited
                if self._conn.poll(0.1):  # drain a result racing the exit
                    with contextlib.suppress(EOFError, OSError):
                        return self._conn.recv()
                raise self._die(f"worker exited before {what} "
                                f"(exitcode {self._exitcode()})")
            self._kill()                 # timeout: SIGKILL, then report
            raise self._die(
                f"no {what} within {timeout:.3g}s deadline "
                "(worker SIGKILLed)")

    def _ensure_ready(self) -> None:  # locked: _lock
        if self._ready:
            return
        remaining = self._warmup_deadline - time.monotonic()
        if remaining <= 0:
            self._kill()
            raise self._die("never acked the warmup chunk "
                            f"(worker SIGKILLed, pid {self.pid})")
        msg = self._recv(remaining, "warmup ack")
        if msg[0] != "ready":
            raise self._die(f"bad warmup ack {msg[0]!r}")
        self._ready = True
        self.warmup_seconds = float(msg[2])

    # ------------------------------------------------------------------ #
    # replica protocol
    # ------------------------------------------------------------------ #
    def price_chunk(self, chunk: ChunkSpec) -> ChunkResult:
        with self._lock:
            if self._dead is not None:
                raise ReplicaCrash(f"{self.name}: dead ({self._dead})")
            self._ensure_ready()
            self.calls += 1
            try:
                self._conn.send(("chunk", chunk.to_wire()))
            except (BrokenPipeError, OSError):
                raise self._die(
                    f"pipe broke sending chunk "
                    f"(exitcode {self._exitcode()})") from None
            msg = self._recv(self.call_timeout_s, "chunk result")
            if msg[0] == "ok":
                return ChunkResult.from_wire(msg[1])
            if msg[0] == "err":
                _, kind, text = msg
                # request errors come back under their own type so the
                # gateway's healthy-replica retry semantics hold
                if kind == "OverflowError":
                    raise OverflowError(f"{self.name}: {text}")
                raise RuntimeError(f"{self.name}: {kind}: {text}")
            raise self._die(f"bad reply op {msg[0]!r}")


class ReplicaPool:
    """Replica factory the gateway consumes (``pool="thread"|"process"``).

    ``factory(i)`` builds replica ``i``; the gateway calls it both at
    startup and on ``restart_s`` respawn, so a SIGKILLed process replica
    is replaced by a *fresh* process (new pid, new warmup).  The thread
    kind builds :class:`~repro.serve.replica.LocalReplica` — exactly the
    pre-pool behaviour.
    """

    KINDS = ("thread", "process")

    def __init__(self, kind: str = "thread", *,
                 warmup: Optional[dict] = None,
                 call_timeout_s: Optional[float] = None,
                 warmup_timeout_s: float = 120.0,
                 name_prefix: str = "replica"):
        if kind not in self.KINDS:
            raise ValueError(f"pool kind must be one of {self.KINDS}, "
                             f"got {kind!r}")
        self.kind = kind
        self.warmup = warmup
        self.call_timeout_s = call_timeout_s
        self.warmup_timeout_s = warmup_timeout_s
        self.name_prefix = name_prefix

    def factory(self, i: int):
        name = f"{self.name_prefix}-{i}"
        if self.kind == "thread":
            return LocalReplica(name)
        return ProcessReplica(name, warmup=self.warmup,
                              call_timeout_s=self.call_timeout_s,
                              warmup_timeout_s=self.warmup_timeout_s)

    def build(self, n: int) -> list:
        return [self.factory(i) for i in range(n)]
