"""Continuous-batching pricing service over the compiled grid engines.

The orchestration layer the ``serve/engine.py`` docstring promised: a
:class:`PricingService` accepts a *stream* of single-contract
:class:`~repro.serve.engine.PriceRequest`\\ s (plus whole
:class:`~repro.serve.engine.GridRequest`\\ s), coalesces them across payoff
family and strike — payoff-as-data (``core/payoff.py::param_payoff``)
makes a heterogeneous batch one compiled call — and flushes micro-batches
through ``repro.api.price_flat`` on a **size-or-deadline** trigger:

    submit() ──► bucket queues (n_steps, engine) ──► pad to 2^k
        ──► engine="auto" (no-TC lattice | Roux–Zastawniak | LSMC) ──►
        unpad ──► per-request PriceQuote + latency sample

Design points (see ``docs/SERVING.md`` for the operator's guide):

* **Buckets.**  Requests are queued by ``(n_steps, engine)`` — the
  things that force a different compiled program (tree depth is
  shape-static; the frictionless, transaction-cost and Monte Carlo
  engines are different programs).  The engine is routed per request by
  contract shape (``repro.scenarios.route_engine``): multi-asset or
  Bermudan requests go to ``lsmc`` and additionally key their bucket on
  ``(n_assets, exercise_steps)`` — the MC contract shape is static.
  Everything else (payoff family, strike, spot, vol, rate, maturity,
  λ value) is *data* and batches freely.
* **Padding.**  A flushed batch is padded up to the next power of two
  (by repeating its last row) so arbitrary traffic sizes hit at most
  ``log2(max_batch)+1`` compiled shapes per bucket.
* **Triggers.**  A bucket flushes when it reaches ``max_batch``
  (size trigger, inside :meth:`submit`) or when its oldest request has
  waited ``deadline_ms`` (deadline trigger, inside :meth:`step` — the
  driver loop calls ``step()`` each tick).  :meth:`flush` force-drains.
* **Caches.**  A *compile cache* is keyed on
  ``(padded_batch, n_steps, engine, backend, greeks)`` with hit/miss
  counters (it mirrors — and lets you observe — jax's jit cache: a miss
  is a new XLA compilation, seconds for the RZ engine).  A small LRU
  *result cache* keyed on the full scenario tuple short-circuits repeat
  scenarios without touching the engines at all.
* **Metrics.**  ``requests``, ``batches``, ``p50/p99`` latency, pad
  waste, contracts/sec, per-engine batch counts — :meth:`metrics`.
* **Device mesh.**  ``devices=``/``mesh=`` route every flushed
  micro-batch (and every :meth:`price_grid` call) onto a 1-D device
  mesh: each flush is planned by the cost model
  (``core/partition.py::plan_shards`` — TC rows ~``max_pieces`` x a
  frictionless row), and after the flush the **rebalance hook** feeds
  the measured seconds back (:class:`~repro.core.partition.ShardRebalancer`)
  so the next plan steers work away from shards that ran slow — the
  paper's §4.2 per-round reassignment at device granularity.  The
  compile cache is additionally keyed on the mesh shape and the plan's
  per-device lane count (both change the compiled program).

Since PR 6 the queueing/caching state machine lives in
``serve/core.py::SchedulerCore``; this class is the *cooperative,
in-process transport* over it (``submit``/``step`` price inline on the
caller's thread).  The asyncio multi-replica front end over the same
core — timer-driven deadline flushes, replica fault recovery, streaming
repricing — is ``serve/gateway.py::PricingGateway``; see
``docs/KNOWN_ISSUES.md`` for when the cooperative service stops being
enough.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import numpy as np

from ..configs.pricing import ExecutionConfig
from ..core.partition import _next_pow2
from .core import SchedulerCore, ServiceMetrics, execute_chunk

__all__ = ["PricingService", "ServiceMetrics"]


class PricingService:
    """Continuous-batching front end for the compiled pricing engines."""

    def __init__(self, *, max_batch: int = 64, deadline_ms: float = 5.0,
                 capacity: int = 48, backend: str = "jnp",
                 interpret: Optional[bool] = None,
                 default_n_steps: int = 100, default_payoff: str = "put",
                 default_strike: float = 100.0,
                 result_cache_size: int = 1024, max_results: int = 65536,
                 min_grid_bucket: Optional[int] = None,
                 n_paths: int = 4096, mc_seed: int = 0,
                 devices: Optional[int] = None, mesh=None,
                 rebalance_ema: float = 0.5,
                 execution: Optional[ExecutionConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        # execution= is the consolidated knob surface: any field set on it
        # overrides the corresponding individual kwarg
        if execution is not None:
            s = execution.set_fields()
            backend = execution.backend if "backend" in s else backend
            interpret = (execution.interpret if "interpret" in s
                         else interpret)
            n_paths = execution.n_paths if "n_paths" in s else n_paths
            mc_seed = execution.mc_seed if "mc_seed" in s else mc_seed
            devices = execution.devices if "devices" in s else devices
            # program-role knobs must not be dropped at the service
            # boundary (repro.analysis.compile_key audits this)
            basis = execution.basis if "basis" in s else "poly"
            degree = execution.degree if "degree" in s else 3
            antithetic = (execution.antithetic if "antithetic" in s
                          else True)
        else:
            basis, degree, antithetic = "poly", 3, True
        self.core = SchedulerCore(
            max_batch=max_batch, deadline_ms=deadline_ms, capacity=capacity,
            backend=backend, interpret=interpret,
            default_n_steps=default_n_steps,
            default_payoff=default_payoff, default_strike=default_strike,
            result_cache_size=result_cache_size, max_results=max_results,
            n_paths=n_paths, mc_seed=mc_seed,
            basis=basis, degree=degree, antithetic=antithetic, clock=clock)
        # device-mesh routing (lazy imports: the jax-touching modules load
        # only when sharding is actually requested)
        if devices is not None or mesh is not None:
            from ..core.distributed import resolve_grid_mesh
            from ..core.partition import ShardRebalancer
            self._mesh, self._n_shards = resolve_grid_mesh(devices, mesh)
            self._rebalancer = (ShardRebalancer(ema=rebalance_ema)
                                if self._n_shards > 1 else None)
        else:
            self._mesh, self._n_shards = None, 1
            self._rebalancer = None
        self.min_grid_bucket = (self.max_batch if min_grid_bucket is None
                                else int(min_grid_bucket))
        self._clock = clock
        self._deferred_error: Optional[BaseException] = None

    # the in-process service is cooperatively driven by one caller
    # thread (submit/step/flush) — owner-confined (repro.analysis.guarded)
    GUARDED_BY = {"_deferred_error": "owner"}

    # core-owned configuration/state, re-exposed under the historical
    # names so operator code (and the shard tests) keep working
    @property
    def max_batch(self) -> int:
        return self.core.max_batch

    @property
    def deadline_s(self) -> float:
        return self.core.deadline_s

    @property
    def capacity(self) -> int:
        return self.core.capacity

    @property
    def backend(self) -> str:
        return self.core.backend

    @property
    def default_n_steps(self) -> int:
        return self.core.default_n_steps

    @property
    def default_payoff(self) -> str:
        return self.core.default_payoff

    @property
    def default_strike(self) -> float:
        return self.core.default_strike

    @property
    def max_results(self) -> int:
        return self.core.max_results

    @property
    def metrics_(self) -> ServiceMetrics:
        return self.core.metrics_

    @property
    def _buckets(self) -> Dict:
        return self.core.buckets

    @property
    def _compiled(self) -> Dict[tuple, int]:
        return self.core._compiled

    # ------------------------------------------------------------------ #
    # request intake
    # ------------------------------------------------------------------ #
    def _scenario_key(self, req) -> tuple:
        return self.core.scenario_key(req)

    def submit(self, req) -> int:
        """Enqueue one contract; returns a request id.

        Flushes the request's bucket inline if it reaches ``max_batch``
        (size trigger).  A result-cache hit completes immediately.
        """
        rid, bucket, _ = self.core.submit(req)
        if (bucket is not None
                and len(self.core.buckets[bucket]) >= self.max_batch):
            # an engine error here must not swallow the request id the
            # caller is owed: the chunk is already re-queued by
            # _flush_bucket, so defer the exception to the next
            # step()/flush() and hand the rid back
            try:
                self._flush_bucket(bucket)
            except Exception as e:
                self._deferred_error = e
        return rid

    # ------------------------------------------------------------------ #
    # flush machinery
    # ------------------------------------------------------------------ #
    def _compile_key_seen(self, padded: int, n_steps: int, engine: str,
                          greeks: bool, backend: Optional[str] = None,
                          interpret: Optional[bool] = None,
                          shard: Optional[tuple] = None,
                          extra: Optional[tuple] = None,
                          devices: Optional[int] = None) -> None:
        self.core.compile_key_seen(padded, n_steps, engine, greeks,
                                   backend=backend, interpret=interpret,
                                   shard=shard, extra=extra,
                                   devices=devices)

    # ------------------------------------------------------------------ #
    # device-mesh shard planning / rebalance hook
    # ------------------------------------------------------------------ #
    def _shard_plan(self, bucket: tuple, cost_rates, n_steps: int,
                    padded: int):
        """Cost-model shard plan for one padded micro-batch (None when
        the service runs single-device).  Lanes round up to a power of
        two so each bucket's flushes reuse a handful of per-device
        compiled shapes — the pad-to-bucket discipline, per device."""
        if self._rebalancer is None:
            return None
        cr = np.asarray(cost_rates, np.float64)
        cr = np.concatenate([cr, np.repeat(cr[-1:], padded - cr.shape[0])])
        return self._shard_plan_from_costs(bucket, n_steps, cr,
                                           engine=bucket[1],
                                           n_assets=(bucket[2]
                                                     if bucket[1] == "lsmc"
                                                     else 1),
                                           exercise_steps=(bucket[3]
                                                           if bucket[1]
                                                           == "lsmc"
                                                           else None))

    def _shard_plan_from_costs(self, key, n_steps: int, cost_rates_padded,
                               *, copies: int = 1, engine: str = "notc",
                               n_assets: int = 1, exercise_steps=None):
        """Rebalancer-steered plan over a padded batch's cost-model costs
        (``copies`` > 1 tiles for the greeks bump blocks)."""
        from ..core.partition import scenario_costs
        n_ex = (None if exercise_steps is None else len(exercise_steps))
        costs = scenario_costs(n_steps, cost_rates_padded,
                               capacity=self.capacity,
                               engine=engine if engine == "lsmc" else None,
                               n_paths=self.core.n_paths, n_exercise=n_ex,
                               n_assets=n_assets)
        if copies > 1:
            costs = np.tile(costs, copies)
        return self._rebalancer.plan(key, costs, self._n_shards,
                                     lanes_pow2=True)

    def _observe_flush(self, bucket: tuple, res, seconds: float) -> None:
        """Fold one sharded flush's measurement into the rebalancer.

        SPMD shards run in lockstep, so true per-shard wall seconds are
        not observable from the host; the flush's total seconds are
        attributed by each shard's *measured* work (the cost model
        re-evaluated with the measured ``max_pieces`` — see
        ``ShardExecInfo.measured_work``).  Operators with per-device
        profiles can feed real timings via :meth:`observe_shard_seconds`.
        """
        info = getattr(res, "shard_info", None)
        if self._rebalancer is None or info is None:
            return
        self.metrics_.bump(shard_batches=1)
        work = np.asarray(info.measured_work, np.float64)
        if work.sum() <= 0 or seconds <= 0:
            return                   # nothing measurable to fold in
        per_shard = seconds * work / work.sum()
        self._rebalancer.observe(bucket, info.plan, per_shard)
        self.metrics_.bump(rebalances=1)

    def observe_shard_seconds(self, bucket: tuple, plan,
                              per_shard_seconds) -> None:
        """Feed externally measured per-shard seconds (e.g. from a device
        profiler) into the rebalance loop for ``bucket``."""
        if self._rebalancer is None:
            raise ValueError("service is not sharded (pass devices=/mesh=)")
        self._rebalancer.observe(bucket, plan, per_shard_seconds)
        self.metrics_.bump(rebalances=1)

    def shard_speed(self, bucket: tuple):
        """Current per-device speed estimates for ``bucket`` (None when
        single-device) — what the next flush's plan will steer by."""
        if self._rebalancer is None:
            return None
        return self._rebalancer.speed(bucket, self._n_shards)

    def _prepare_chunk(self, chunk, bucket: tuple) -> None:
        """Attach the service's sharding to a drained chunk.

        ``devices`` is a plain *count* (the wire-schema spec — whoever
        executes the chunk resolves its own 1-D mesh locally, see
        ``serve/core.py``), never the service's live mesh object, so the
        chunk pickles cleanly and a process-pool worker is free to build
        the mesh over *its* devices.
        """
        chunk.devices = self._n_shards if self._n_shards > 1 else None
        chunk.shard_plan = self._shard_plan(
            bucket, chunk.cols[4], chunk.n_steps, chunk.padded)

    def _flush_bucket(self, bucket: tuple) -> Dict[int, "PriceQuote"]:
        done: Dict[int, "PriceQuote"] = {}
        while True:
            chunk = self.core.take_chunk(bucket, self.max_batch)
            if chunk is None:
                break
            self._prepare_chunk(chunk, bucket)
            t0 = self._clock()
            try:
                res = execute_chunk(chunk)
            except Exception:
                # no request is ever silently lost: re-queue this chunk
                # (the rest of the bucket is still queued behind it),
                # then surface the error (e.g. a PWL OverflowError —
                # raise `capacity` and flush again)
                self.core.requeue(chunk)
                raise
            now = self._clock()
            self._observe_flush(bucket, res, now - t0)
            # the cooperative service measures engine time with its own
            # clock (fake-clock tests steer it); the executor-measured
            # res.seconds is what the gateway's replica workers report
            done.update(self.core.complete(chunk, res, now,
                                           engine_seconds=now - t0))
        return done

    def _store_result(self, rid: int, quote) -> None:
        self.core.store_result(rid, quote)

    def _remember(self, key: tuple, quote) -> None:
        self.core.remember(key, quote)

    def _raise_deferred(self) -> None:
        if self._deferred_error is not None:
            e, self._deferred_error = self._deferred_error, None
            raise e

    def step(self, now: Optional[float] = None) -> Dict[int, "PriceQuote"]:
        """Deadline tick: flush every bucket whose oldest request has
        waited at least ``deadline_ms``.  Drivers call this each loop;
        returns the quotes this tick completed.  An engine error deferred
        from a ``submit`` size-trigger flush re-raises here."""
        self._raise_deferred()
        now = self._clock() if now is None else now
        done: Dict[int, "PriceQuote"] = {}
        for bucket in self.core.due_buckets(now):
            done.update(self._flush_bucket(bucket))
        return done

    def flush(self) -> Dict[int, "PriceQuote"]:
        """Force-flush every pending bucket; returns the quotes this call
        completed (look earlier ones up with :meth:`result`).  An engine
        error deferred from a ``submit`` size-trigger flush re-raises
        here."""
        self._raise_deferred()
        done: Dict[int, "PriceQuote"] = {}
        for bucket in list(self.core.buckets):
            done.update(self._flush_bucket(bucket))
        return done

    # ------------------------------------------------------------------ #
    # results / introspection
    # ------------------------------------------------------------------ #
    def result(self, rid: int):
        """The :class:`~repro.api.PriceQuote` for ``rid`` (None if still
        pending — call :meth:`step` or :meth:`flush`)."""
        return self.core.result(rid)

    @property
    def pending_count(self) -> int:
        return self.core.pending_count

    def metrics(self) -> dict:
        return self.core.metrics_.snapshot()

    # ------------------------------------------------------------------ #
    # whole-grid requests (cartesian surfaces)
    # ------------------------------------------------------------------ #
    def price_grid(self, req):
        """Price a :class:`~repro.serve.engine.GridRequest` now.

        Grids are already batches, so they bypass the queues; they share
        the pad-to-bucket compile reuse (padded to a power of two, at
        least ``min_grid_bucket``) and ``engine="auto"`` routing —
        all-frictionless grids take the cheap no-TC lattice, anything
        with a positive ``cost_rate`` the Roux–Zastawniak engine.
        """
        from ..api import price_grid
        from ..scenarios import GridResult, ScenarioGrid, route_engine
        grid = ScenarioGrid.cartesian(
            s0=req.s0, sigma=req.sigma, rate=req.rate,
            maturity=req.maturity, cost_rate=req.cost_rate,
            payoff=req.payoff, strike=req.strike, strike2=req.strike2,
            n_steps=req.n_steps, n_assets=getattr(req, "n_assets", 1),
            exercise_steps=getattr(req, "exercise_steps", None))
        n = grid.n_scenarios
        bucket = max(self.min_grid_bucket, _next_pow2(n))
        engine = route_engine(any_tc=bool(np.any(grid.cost_rate > 0.0)),
                              n_assets=grid.n_assets,
                              exercise_steps=grid.exercise_steps)
        # a GridRequest may carry its own ExecutionConfig; fields set on
        # it win over the request's individual knobs and the service's
        # defaults (engine="auto" still routes by contract shape)
        ex = getattr(req, "execution", None)
        exs = ex.set_fields() if ex is not None else ()
        if "engine" in exs and ex.engine != "auto":
            engine = ex.engine
        backend = ex.backend if "backend" in exs else req.backend
        interpret = (ex.interpret if "interpret" in exs
                     else (self.core.interpret
                           if getattr(req, "interpret", None) is None
                           else req.interpret))
        n_paths = ex.n_paths if "n_paths" in exs else self.core.n_paths
        mc_seed = ex.mc_seed if "mc_seed" in exs else self.core.mc_seed
        basis = ex.basis if "basis" in exs else self.core.basis
        degree = ex.degree if "degree" in exs else self.core.degree
        antithetic = (ex.antithetic if "antithetic" in exs
                      else self.core.antithetic)
        # grids rebalance under their own stream key: plan through the
        # rebalancer (greeks bump the batch 5x — the plan must cover the
        # bumped rows) so measured-seconds feedback actually steers the
        # next grid of the same depth/engine
        gkey = ("grid", grid.n_steps, engine)
        plan = None
        if self._rebalancer is not None:
            cr = np.concatenate([grid.cost_rate,
                                 np.repeat(grid.cost_rate[-1:],
                                           bucket - n)])
            plan = self._shard_plan_from_costs(
                gkey, grid.n_steps, cr, copies=5 if req.greeks else 1,
                engine=engine, n_assets=grid.n_assets,
                exercise_steps=grid.exercise_steps)
        t0 = self._clock()
        cfg = ExecutionConfig(
            engine=engine, backend=backend, interpret=interpret,
            n_paths=n_paths, mc_seed=mc_seed,
            basis=basis, degree=degree, antithetic=antithetic)
        res = price_grid(grid.pad_to(bucket), execution=cfg,
                         capacity=self.capacity, greeks=req.greeks,
                         mesh=self._mesh, shard_plan=plan)
        elapsed = self._clock() - t0
        self.metrics_.bump(engine_seconds=elapsed, grids=1,
                           grid_scenarios=n)
        self._observe_flush(gkey, res, elapsed)
        info = res.shard_info
        # the key reads the *resolved* n_paths/basis/degree/antithetic —
        # a per-request ExecutionConfig override compiles a different
        # program than the service default and must key separately
        # (keying self.core.n_paths here once hid exactly that)
        self._compile_key_seen(bucket, grid.n_steps, engine, req.greeks,
                               backend=backend, interpret=interpret,
                               shard=(info.plan.n_shards, info.plan.lanes)
                               if info else None,
                               extra=((n_paths, grid.n_assets,
                                       grid.exercise_steps, basis, degree,
                                       antithetic)
                                      if engine == "lsmc" else None),
                               devices=(self._n_shards
                                        if self._n_shards > 1 else None))
        self.metrics_.count_engine(engine)
        cut = lambda a: (None if a is None
                         else a.ravel()[:n].reshape(grid.shape))
        rp = getattr(res, "row_pieces", None)
        se = getattr(res, "stderr", None)
        return GridResult(
            grid=grid, ask=cut(res.ask), bid=cut(res.bid),
            max_pieces=res.max_pieces,
            delta_ask=cut(res.delta_ask), delta_bid=cut(res.delta_bid),
            vega_ask=cut(res.vega_ask), vega_bid=cut(res.vega_bid),
            shard_info=res.shard_info,
            row_pieces=None if rp is None else cut(np.asarray(rp)),
            stderr=None if se is None else cut(np.asarray(se)),
            engine=getattr(res, "engine", engine))
