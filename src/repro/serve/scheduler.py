"""Continuous-batching pricing service over the compiled grid engines.

The orchestration layer the ``serve/engine.py`` docstring promised: a
:class:`PricingService` accepts a *stream* of single-contract
:class:`~repro.serve.engine.PriceRequest`\\ s (plus whole
:class:`~repro.serve.engine.GridRequest`\\ s), coalesces them across payoff
family and strike — payoff-as-data (``core/payoff.py::param_payoff``)
makes a heterogeneous batch one compiled call — and flushes micro-batches
through ``repro.api.price_flat`` on a **size-or-deadline** trigger:

    submit() ──► bucket queues (n_steps, frictionless?) ──► pad to 2^k
        ──► engine="auto" (no-TC lattice | Roux–Zastawniak) ──► unpad
        ──► per-request PriceQuote + latency sample

Design points (see ``docs/SERVING.md`` for the operator's guide):

* **Buckets.**  Requests are queued by ``(n_steps, cost_rate > 0)`` —
  the two things that force a different compiled program (tree depth is
  shape-static; the frictionless and transaction-cost engines are
  different programs).  Everything else (payoff family, strike, spot,
  vol, rate, maturity, λ value) is *data* and batches freely.
* **Padding.**  A flushed batch is padded up to the next power of two
  (by repeating its last row) so arbitrary traffic sizes hit at most
  ``log2(max_batch)+1`` compiled shapes per bucket.
* **Triggers.**  A bucket flushes when it reaches ``max_batch``
  (size trigger, inside :meth:`submit`) or when its oldest request has
  waited ``deadline_ms`` (deadline trigger, inside :meth:`step` — the
  driver loop calls ``step()`` each tick).  :meth:`flush` force-drains.
* **Caches.**  A *compile cache* is keyed on
  ``(padded_batch, n_steps, engine, backend, greeks)`` with hit/miss
  counters (it mirrors — and lets you observe — jax's jit cache: a miss
  is a new XLA compilation, seconds for the RZ engine).  A small LRU
  *result cache* keyed on the full scenario tuple short-circuits repeat
  scenarios without touching the engines at all.
* **Metrics.**  ``requests``, ``batches``, ``p50/p99`` latency, pad
  waste, contracts/sec, per-engine batch counts — :meth:`metrics`.
* **Device mesh.**  ``devices=``/``mesh=`` route every flushed
  micro-batch (and every :meth:`price_grid` call) onto a 1-D device
  mesh: each flush is planned by the cost model
  (``core/partition.py::plan_shards`` — TC rows ~``max_pieces`` x a
  frictionless row), and after the flush the **rebalance hook** feeds
  the measured seconds back (:class:`~repro.core.partition.ShardRebalancer`)
  so the next plan steers work away from shards that ran slow — the
  paper's §4.2 per-round reassignment at device granularity.  The
  compile cache is additionally keyed on the mesh shape and the plan's
  per-device lane count (both change the compiled program).

The service is deliberately single-process and cooperative (no threads:
``submit``/``step`` do the work inline) — see ``docs/KNOWN_ISSUES.md``
for the resulting limits and the multi-process outlook.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.partition import _next_pow2
from ..scenarios import PAYOFF_FAMILIES

__all__ = ["PricingService", "ServiceMetrics"]


@dataclasses.dataclass(frozen=True)
class _Pending:
    rid: int
    key: tuple            # full scenario tuple (the result-cache key)
    t_submit: float


@dataclasses.dataclass
class ServiceMetrics:
    """Counters a :class:`PricingService` accumulates (all cumulative)."""
    requests: int = 0            # single-contract requests submitted
    completed: int = 0           # ... with a result available
    batches: int = 0             # engine flushes (micro-batches priced)
    contracts: int = 0           # real (un-padded) contracts priced
    padded: int = 0              # lanes submitted to the engines
    cache_hits: int = 0          # result-LRU short-circuits
    compile_hits: int = 0        # batch shapes seen before
    compile_misses: int = 0      # batch shapes compiled fresh
    engine_seconds: float = 0.0  # time inside the compiled engines
    engine_batches: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"notc": 0, "rz": 0})
    grids: int = 0               # GridRequests priced
    grid_scenarios: int = 0
    shard_batches: int = 0       # flushes routed onto the device mesh
    rebalances: int = 0          # measured-seconds feedbacks folded in
    # p50/p99 are computed over a bounded window of recent samples so a
    # long-running service doesn't grow without limit
    latencies: List[float] = dataclasses.field(default_factory=list)
    latency_window: int = 4096

    def add_latency(self, seconds: float) -> None:
        self.latencies.append(seconds)
        if len(self.latencies) > 2 * self.latency_window:
            del self.latencies[:-self.latency_window]

    def snapshot(self) -> dict:
        lat = np.asarray(self.latencies) if self.latencies else np.zeros(1)
        waste = (1.0 - self.contracts / self.padded) if self.padded else 0.0
        # before any engine flush there is no throughput to report: 0.0,
        # not inf — json.dumps would emit non-standard `Infinity` into the
        # BENCH_serve.json artifact (strict JSON parsers reject it, and
        # tools/check_bench.py refuses non-finite metrics)
        cps = (self.contracts / self.engine_seconds
               if self.engine_seconds > 0 else 0.0)
        return {
            "requests": self.requests, "completed": self.completed,
            "batches": self.batches, "contracts": self.contracts,
            "padded": self.padded, "pad_waste": waste,
            "cache_hits": self.cache_hits,
            "compile_hits": self.compile_hits,
            "compile_misses": self.compile_misses,
            "engine_seconds": self.engine_seconds,
            "contracts_per_sec": cps,
            "engine_batches": dict(self.engine_batches),
            "grids": self.grids, "grid_scenarios": self.grid_scenarios,
            "shard_batches": self.shard_batches,
            "rebalances": self.rebalances,
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
        }


class PricingService:
    """Continuous-batching front end for the compiled pricing engines."""

    def __init__(self, *, max_batch: int = 64, deadline_ms: float = 5.0,
                 capacity: int = 48, backend: str = "jnp",
                 default_n_steps: int = 100, default_payoff: str = "put",
                 default_strike: float = 100.0,
                 result_cache_size: int = 1024, max_results: int = 65536,
                 min_grid_bucket: Optional[int] = None,
                 devices: Optional[int] = None, mesh=None,
                 rebalance_ema: float = 0.5,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        # device-mesh routing (lazy imports: the jax-touching modules load
        # only when sharding is actually requested)
        if devices is not None or mesh is not None:
            from ..core.distributed import resolve_grid_mesh
            from ..core.partition import ShardRebalancer
            self._mesh, self._n_shards = resolve_grid_mesh(devices, mesh)
            self._rebalancer = (ShardRebalancer(ema=rebalance_ema)
                                if self._n_shards > 1 else None)
        else:
            self._mesh, self._n_shards = None, 1
            self._rebalancer = None
        self.deadline_s = float(deadline_ms) * 1e-3
        self.capacity = int(capacity)
        self.backend = backend
        self.default_n_steps = int(default_n_steps)
        self.default_payoff = default_payoff
        self.default_strike = float(default_strike)
        self.min_grid_bucket = (self.max_batch if min_grid_bucket is None
                                else int(min_grid_bucket))
        self._clock = clock
        self.max_results = int(max_results)
        self._buckets: Dict[tuple, List[_Pending]] = {}
        self._results: OrderedDict = OrderedDict()
        self._result_cache: OrderedDict = OrderedDict()
        self._result_cache_size = int(result_cache_size)
        self._compiled: Dict[tuple, int] = {}
        self._next_id = 0
        self._deferred_error: Optional[BaseException] = None
        self.metrics_ = ServiceMetrics()

    # ------------------------------------------------------------------ #
    # request intake
    # ------------------------------------------------------------------ #
    def _scenario_key(self, req) -> tuple:
        """Normalise a PriceRequest to the full scenario tuple.

        Unset (None) payoff/strike/n_steps fields take the service
        defaults — per-request values are always honoured (they batch as
        payoff *data*, so heterogeneous batches stay one compiled call).
        """
        payoff = req.payoff if req.payoff is not None else self.default_payoff
        if payoff not in PAYOFF_FAMILIES:
            raise ValueError(f"unknown payoff family {payoff!r}; "
                             f"supported: {PAYOFF_FAMILIES}")
        strike = (self.default_strike if req.strike is None
                  else float(req.strike))
        strike2 = (strike + 10.0 if getattr(req, "strike2", None) is None
                   else float(req.strike2))
        n_steps = (self.default_n_steps if req.n_steps is None
                   else int(req.n_steps))
        return (float(req.s0), float(req.sigma), float(req.rate),
                float(req.maturity), float(req.cost_rate), payoff,
                strike, strike2, n_steps)

    def submit(self, req) -> int:
        """Enqueue one contract; returns a request id.

        Flushes the request's bucket inline if it reaches ``max_batch``
        (size trigger).  A result-cache hit completes immediately.
        """
        key = self._scenario_key(req)
        rid = self._next_id
        self._next_id += 1
        self.metrics_.requests += 1
        now = self._clock()
        if key in self._result_cache:
            self._result_cache.move_to_end(key)
            self._store_result(rid, self._result_cache[key])
            self.metrics_.cache_hits += 1
            self.metrics_.completed += 1
            self.metrics_.add_latency(self._clock() - now)
            return rid
        bucket = (key[8], key[4] > 0.0)          # (n_steps, needs TC engine)
        self._buckets.setdefault(bucket, []).append(
            _Pending(rid=rid, key=key, t_submit=now))
        if len(self._buckets[bucket]) >= self.max_batch:
            # an engine error here must not swallow the request id the
            # caller is owed: the chunk is already re-queued by
            # _flush_bucket, so defer the exception to the next
            # step()/flush() and hand the rid back
            try:
                self._flush_bucket(bucket)
            except Exception as e:
                self._deferred_error = e
        return rid

    # ------------------------------------------------------------------ #
    # flush machinery
    # ------------------------------------------------------------------ #
    def _compile_key_seen(self, padded: int, n_steps: int, engine: str,
                          greeks: bool, backend: Optional[str] = None,
                          shard: Optional[tuple] = None) -> None:
        """Count a *successful* engine call against its compiled-program
        key.  Called only after the call returns: a failed call (e.g. a
        capacity overflow) compiled nothing worth counting, and raising
        ``capacity`` — a shape parameter, hence part of the key — then
        retrying is a genuine fresh compile, not a hit.  ``shard`` is
        ``(n_shards, lanes)`` when the call ran on the device mesh —
        both change the compiled program's shape, so they are part of
        the key."""
        ck = (padded, n_steps, engine,
              self.backend if backend is None else backend, greeks,
              self.capacity, shard)
        if ck in self._compiled:
            self._compiled[ck] += 1
            self.metrics_.compile_hits += 1
        else:
            self._compiled[ck] = 1
            self.metrics_.compile_misses += 1

    # ------------------------------------------------------------------ #
    # device-mesh shard planning / rebalance hook
    # ------------------------------------------------------------------ #
    def _shard_plan(self, bucket: tuple, cost_rates, n_steps: int,
                    padded: int):
        """Cost-model shard plan for one padded micro-batch (None when
        the service runs single-device).  Lanes round up to a power of
        two so each bucket's flushes reuse a handful of per-device
        compiled shapes — the pad-to-bucket discipline, per device."""
        if self._rebalancer is None:
            return None
        cr = np.asarray(cost_rates, np.float64)
        cr = np.concatenate([cr, np.repeat(cr[-1:], padded - cr.shape[0])])
        return self._shard_plan_from_costs(bucket, n_steps, cr)

    def _shard_plan_from_costs(self, key, n_steps: int, cost_rates_padded,
                               *, copies: int = 1):
        """Rebalancer-steered plan over a padded batch's cost-model costs
        (``copies`` > 1 tiles for the greeks bump blocks)."""
        from ..core.partition import scenario_costs
        costs = scenario_costs(n_steps, cost_rates_padded,
                               capacity=self.capacity)
        if copies > 1:
            costs = np.tile(costs, copies)
        return self._rebalancer.plan(key, costs, self._n_shards,
                                     lanes_pow2=True)

    def _observe_flush(self, bucket: tuple, res, seconds: float) -> None:
        """Fold one sharded flush's measurement into the rebalancer.

        SPMD shards run in lockstep, so true per-shard wall seconds are
        not observable from the host; the flush's total seconds are
        attributed by each shard's *measured* work (the cost model
        re-evaluated with the measured ``max_pieces`` — see
        ``ShardExecInfo.measured_work``).  Operators with per-device
        profiles can feed real timings via :meth:`observe_shard_seconds`.
        """
        info = getattr(res, "shard_info", None)
        if self._rebalancer is None or info is None:
            return
        self.metrics_.shard_batches += 1
        work = np.asarray(info.measured_work, np.float64)
        if work.sum() <= 0 or seconds <= 0:
            return                   # nothing measurable to fold in
        per_shard = seconds * work / work.sum()
        self._rebalancer.observe(bucket, info.plan, per_shard)
        self.metrics_.rebalances += 1

    def observe_shard_seconds(self, bucket: tuple, plan,
                              per_shard_seconds) -> None:
        """Feed externally measured per-shard seconds (e.g. from a device
        profiler) into the rebalance loop for ``bucket``."""
        if self._rebalancer is None:
            raise ValueError("service is not sharded (pass devices=/mesh=)")
        self._rebalancer.observe(bucket, plan, per_shard_seconds)
        self.metrics_.rebalances += 1

    def shard_speed(self, bucket: tuple):
        """Current per-device speed estimates for ``bucket`` (None when
        single-device) — what the next flush's plan will steer by."""
        if self._rebalancer is None:
            return None
        return self._rebalancer.speed(bucket, self._n_shards)

    def _flush_bucket(self, bucket: tuple) -> Dict[int, "PriceQuote"]:
        from ..api import PriceQuote, price_flat
        pending = self._buckets.pop(bucket, [])
        n_steps, has_tc = bucket
        done: Dict[int, "PriceQuote"] = {}
        while pending:
            chunk, pending = pending[:self.max_batch], pending[self.max_batch:]
            n = len(chunk)
            padded = _next_pow2(n)
            cols = list(zip(*(p.key for p in chunk)))
            engine = "rz" if has_tc else "notc"
            plan = self._shard_plan(bucket, cols[4], n_steps, padded)
            t0 = self._clock()
            try:
                res = price_flat(
                    s0=np.asarray(cols[0]), sigma=np.asarray(cols[1]),
                    rate=np.asarray(cols[2]), maturity=np.asarray(cols[3]),
                    cost_rate=np.asarray(cols[4]), payoff=tuple(cols[5]),
                    strike=np.asarray(cols[6]), strike2=np.asarray(cols[7]),
                    n_steps=n_steps, engine=engine, capacity=self.capacity,
                    backend=self.backend, pad_to=padded,
                    mesh=self._mesh, shard_plan=plan)
            except Exception:
                # no request is ever silently lost: re-queue this chunk and
                # everything behind it, then surface the error (e.g. a PWL
                # OverflowError — raise `capacity` and flush again)
                self._buckets[bucket] = (chunk + pending
                                         + self._buckets.get(bucket, []))
                raise
            now = self._clock()
            self._observe_flush(bucket, res, now - t0)
            self._compile_key_seen(
                padded, n_steps, engine, False,
                shard=(plan.n_shards, plan.lanes) if plan else None)
            ask, bid = res.ask.ravel(), res.bid.ravel()
            for i, p in enumerate(chunk):
                # max_pieces is the *micro-batch* peak PWL knot count — a
                # conservative per-contract upper bound (the engines reduce
                # over the batch); 0 on the no-TC path as everywhere else
                quote = PriceQuote(ask=float(ask[i]), bid=float(bid[i]),
                                   max_pieces=res.max_pieces)
                self._store_result(p.rid, quote)
                done[p.rid] = quote
                self._remember(p.key, quote)
                self.metrics_.add_latency(now - p.t_submit)
            m = self.metrics_
            m.batches += 1
            m.contracts += n
            m.padded += padded
            m.completed += n
            m.engine_seconds += now - t0
            m.engine_batches[engine] += 1
        return done

    def _store_result(self, rid: int, quote) -> None:
        """Keep completed quotes retrievable via :meth:`result`, bounded to
        the most recent ``max_results`` so a long-running service doesn't
        grow without limit — collect results promptly (the driver loop
        does; see docs/KNOWN_ISSUES.md)."""
        self._results[rid] = quote
        while len(self._results) > self.max_results:
            self._results.popitem(last=False)

    def _remember(self, key: tuple, quote) -> None:
        if self._result_cache_size <= 0:
            return
        self._result_cache[key] = quote
        self._result_cache.move_to_end(key)
        while len(self._result_cache) > self._result_cache_size:
            self._result_cache.popitem(last=False)

    def _raise_deferred(self) -> None:
        if self._deferred_error is not None:
            e, self._deferred_error = self._deferred_error, None
            raise e

    def step(self, now: Optional[float] = None) -> Dict[int, "PriceQuote"]:
        """Deadline tick: flush every bucket whose oldest request has
        waited at least ``deadline_ms``.  Drivers call this each loop;
        returns the quotes this tick completed.  An engine error deferred
        from a ``submit`` size-trigger flush re-raises here."""
        self._raise_deferred()
        now = self._clock() if now is None else now
        due = [b for b, pend in self._buckets.items()
               if pend and now - pend[0].t_submit >= self.deadline_s]
        done: Dict[int, "PriceQuote"] = {}
        for bucket in due:
            done.update(self._flush_bucket(bucket))
        return done

    def flush(self) -> Dict[int, "PriceQuote"]:
        """Force-flush every pending bucket; returns the quotes this call
        completed (look earlier ones up with :meth:`result`).  An engine
        error deferred from a ``submit`` size-trigger flush re-raises
        here."""
        self._raise_deferred()
        done: Dict[int, "PriceQuote"] = {}
        for bucket in list(self._buckets):
            done.update(self._flush_bucket(bucket))
        return done

    # ------------------------------------------------------------------ #
    # results / introspection
    # ------------------------------------------------------------------ #
    def result(self, rid: int):
        """The :class:`~repro.api.PriceQuote` for ``rid`` (None if still
        pending — call :meth:`step` or :meth:`flush`)."""
        return self._results.get(rid)

    @property
    def pending_count(self) -> int:
        return sum(len(p) for p in self._buckets.values())

    def metrics(self) -> dict:
        return self.metrics_.snapshot()

    # ------------------------------------------------------------------ #
    # whole-grid requests (cartesian surfaces)
    # ------------------------------------------------------------------ #
    def price_grid(self, req):
        """Price a :class:`~repro.serve.engine.GridRequest` now.

        Grids are already batches, so they bypass the queues; they share
        the pad-to-bucket compile reuse (padded to a power of two, at
        least ``min_grid_bucket``) and ``engine="auto"`` routing —
        all-frictionless grids take the cheap no-TC lattice, anything
        with a positive ``cost_rate`` the Roux–Zastawniak engine.
        """
        from ..api import price_grid
        from ..scenarios import GridResult, ScenarioGrid
        grid = ScenarioGrid.cartesian(
            s0=req.s0, sigma=req.sigma, rate=req.rate,
            maturity=req.maturity, cost_rate=req.cost_rate,
            payoff=req.payoff, strike=req.strike, strike2=req.strike2,
            n_steps=req.n_steps)
        n = grid.n_scenarios
        bucket = max(self.min_grid_bucket, _next_pow2(n))
        engine = "rz" if np.any(grid.cost_rate > 0.0) else "notc"
        # grids rebalance under their own stream key: plan through the
        # rebalancer (greeks bump the batch 5x — the plan must cover the
        # bumped rows) so measured-seconds feedback actually steers the
        # next grid of the same depth/engine
        gkey = ("grid", grid.n_steps, engine)
        plan = None
        if self._rebalancer is not None:
            cr = np.concatenate([grid.cost_rate,
                                 np.repeat(grid.cost_rate[-1:],
                                           bucket - n)])
            plan = self._shard_plan_from_costs(
                gkey, grid.n_steps, cr, copies=5 if req.greeks else 1)
        t0 = self._clock()
        res = price_grid(grid.pad_to(bucket), engine=engine,
                         capacity=self.capacity, greeks=req.greeks,
                         backend=req.backend, mesh=self._mesh,
                         shard_plan=plan)
        elapsed = self._clock() - t0
        self.metrics_.engine_seconds += elapsed
        self._observe_flush(gkey, res, elapsed)
        info = res.shard_info
        self._compile_key_seen(bucket, grid.n_steps, engine, req.greeks,
                               backend=req.backend,
                               shard=(info.plan.n_shards, info.plan.lanes)
                               if info else None)
        self.metrics_.engine_batches[engine] += 1
        self.metrics_.grids += 1
        self.metrics_.grid_scenarios += n
        cut = lambda a: (None if a is None
                         else a.ravel()[:n].reshape(grid.shape))
        return GridResult(
            grid=grid, ask=cut(res.ask), bid=cut(res.bid),
            max_pieces=res.max_pieces,
            delta_ask=cut(res.delta_ask), delta_bid=cut(res.delta_bid),
            vega_ask=cut(res.vega_ask), vega_bid=cut(res.vega_bid),
            shard_info=res.shard_info)
