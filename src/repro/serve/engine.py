"""Serving engines.

Two first-class services:

1. ``PricingEngine`` — the paper's workload as a production service: a
   batched option-pricing desk.  Single-contract requests (``submit`` /
   ``flush``) are queued, padded to the compiled contract-batch size, and
   priced with the distributed lattice engine (contracts over the data
   axis, lattice nodes over the model axis).  Whole scenario grids
   (``price_grid`` with a :class:`GridRequest`) go through the
   ``repro.scenarios`` batch engine instead: the flat scenario batch is
   padded to a small set of bucket sizes so repeat grid traffic reuses the
   already-compiled program (one compile per (bucket, n_steps, greeks)).

2. ``LMEngine`` — LM prefill + decode loop with a batched KV cache
   (the serve path exercised by the decode_32k / long_500k dry-run cells).

Both engines are deliberately synchronous-batched (continuous batching is
an orchestration layer above the compiled steps and out of scope for the
dry-run; the hooks — per-slot position/validity — are in place).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.payoff import american_call, american_put, bull_spread

__all__ = ["PriceRequest", "GridRequest", "PricingEngine", "LMEngine"]


@dataclasses.dataclass
class PriceRequest:
    s0: float
    sigma: float
    rate: float
    maturity: float
    cost_rate: float
    payoff: str = "put"
    strike: float = 100.0


@dataclasses.dataclass
class GridRequest:
    """A scenario-grid pricing request (cartesian axes; scalars allowed).

    Each axis may be a scalar or a sequence; the engine prices the full
    cartesian product in one compiled call (see ``repro.scenarios``).
    ``n_steps`` is compile-time static per request.
    """
    s0: Any = 100.0
    sigma: Any = 0.2
    rate: Any = 0.1
    maturity: Any = 0.25
    cost_rate: Any = 0.0
    payoff: Any = "put"
    strike: Any = 100.0
    strike2: Any = None
    n_steps: int = 100
    greeks: bool = False
    backend: str = "jnp"     # TC engine implementation: "jnp" | "pallas"


class PricingEngine:
    """Batched ask/bid pricing service on a (data, model) mesh."""

    def __init__(self, mesh, *, n_steps: int, batch: int, capacity: int = 48,
                 round_depth: int = 8, payoff: str = "put",
                 strike: float = 100.0, data_axes=("data",)):
        from ..core.distributed import build_rz_sharded
        self.batch = batch
        self.n_steps = n_steps
        self.capacity = capacity
        pay = {"put": american_put(strike), "call": american_call(strike),
               "bull_spread": bull_spread()}[payoff]
        self._fn = jax.jit(build_rz_sharded(
            mesh, n_steps=n_steps, payoff=pay, capacity=capacity,
            round_depth=round_depth, data_axes=data_axes))
        self._pending: List[Tuple[PriceRequest, int]] = []
        self._results: Dict[int, Tuple[float, float]] = {}
        self._next_id = 0
        self.grid_stats: Dict[str, int] = {"grids": 0, "scenarios": 0}

    def submit(self, req: PriceRequest) -> int:
        rid = self._next_id
        self._next_id += 1
        self._pending.append((req, rid))
        return rid

    def flush(self) -> Dict[int, Tuple[float, float]]:
        """Price all pending requests (padding the final partial batch)."""
        out: Dict[int, Tuple[float, float]] = {}
        while self._pending:
            chunk = self._pending[:self.batch]
            self._pending = self._pending[self.batch:]
            pad = self.batch - len(chunk)
            reqs = [c[0] for c in chunk] + [chunk[-1][0]] * pad
            arr = lambda f: jnp.asarray([getattr(r, f) for r in reqs],
                                        jnp.float64)
            ask, bid, stat = self._fn(arr("s0"), arr("sigma"), arr("rate"),
                                      arr("maturity"), arr("cost_rate"))
            ask, bid = np.asarray(ask), np.asarray(bid)
            for i, (_, rid) in enumerate(chunk):
                out[rid] = (float(ask[i]), float(bid[i]))
        self._results.update(out)
        return out

    # ---- scenario-grid path (repro.scenarios batch engine) ------------ #
    @staticmethod
    def _pad_grid(grid, to: int):
        """Pad the flat scenario batch to ``to`` rows (repeat the last)."""
        from ..scenarios import ScenarioGrid
        n = grid.n_scenarios
        pad = to - n
        rep = lambda a: np.concatenate([a, np.repeat(a[-1:], pad)])
        return ScenarioGrid(
            s0=rep(grid.s0), sigma=rep(grid.sigma), rate=rep(grid.rate),
            maturity=rep(grid.maturity), cost_rate=rep(grid.cost_rate),
            strike=rep(grid.strike), strike2=rep(grid.strike2),
            payoff=grid.payoff + (grid.payoff[-1],) * pad,
            n_steps=grid.n_steps, shape=(to,))

    def price_grid(self, req: GridRequest):
        """Price a :class:`GridRequest` through the scenario batch engine.

        The flat batch is padded up to the next power-of-two bucket so a
        stream of differently-sized grids hits a handful of compiled
        programs; results are unpadded and reshaped to the grid's logical
        (cartesian) shape before returning.
        """
        from ..scenarios import GridResult, ScenarioGrid, price_grid_rz
        grid = ScenarioGrid.cartesian(
            s0=req.s0, sigma=req.sigma, rate=req.rate,
            maturity=req.maturity, cost_rate=req.cost_rate,
            payoff=req.payoff, strike=req.strike, strike2=req.strike2,
            n_steps=req.n_steps)
        n = grid.n_scenarios
        bucket = max(self.batch, 1 << (n - 1).bit_length())
        res = price_grid_rz(self._pad_grid(grid, bucket),
                            capacity=self.capacity, greeks=req.greeks,
                            backend=req.backend)
        cut = lambda a: (None if a is None
                         else a.ravel()[:n].reshape(grid.shape))
        self.grid_stats["grids"] += 1
        self.grid_stats["scenarios"] += n
        return GridResult(
            grid=grid, ask=cut(res.ask), bid=cut(res.bid),
            max_pieces=res.max_pieces,
            delta_ask=cut(res.delta_ask), delta_bid=cut(res.delta_bid),
            vega_ask=cut(res.vega_ask), vega_bid=cut(res.vega_bid))


class LMEngine:
    """Prefill-then-decode engine over a fixed request batch."""

    def __init__(self, params, cfg: ModelConfig, run, *, batch: int,
                 max_len: int, rules=None):
        from ..models.transformer import decode_step, init_cache, prefill
        self.cfg = cfg
        self.run = run
        self.batch = batch
        self.max_len = max_len
        self.params = params
        self.rules = rules
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, run, rules, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, run, rules))

    def generate(self, tokens: np.ndarray, n_new: int,
                 enc_embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """Greedy generation. tokens: (B, S0) prompt; returns (B, n_new)."""
        B, S0 = tokens.shape
        assert B == self.batch and S0 + n_new <= self.max_len
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if enc_embeds is not None:
            batch["enc_embeds"] = jnp.asarray(enc_embeds)
        logits, cache = self._prefill(self.params, batch)
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for i in range(n_new):
            outs.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(S0 + i))
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return np.stack(outs, axis=1)
