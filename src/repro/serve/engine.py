"""Serving engines.

Two first-class services:

1. ``PricingEngine`` — the paper's workload as a production service: a
   batched option-pricing desk.  Requests (contract parameter sets) are
   queued, padded to the compiled contract-batch size, priced with the
   distributed lattice engine (contracts over the data axis, lattice nodes
   over the model axis), and answered with (ask, bid).

2. ``LMEngine`` — LM prefill + decode loop with a batched KV cache
   (the serve path exercised by the decode_32k / long_500k dry-run cells).

Both engines are deliberately synchronous-batched (continuous batching is
an orchestration layer above the compiled steps and out of scope for the
dry-run; the hooks — per-slot position/validity — are in place).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.payoff import american_call, american_put, bull_spread

__all__ = ["PriceRequest", "PricingEngine", "LMEngine"]


@dataclasses.dataclass
class PriceRequest:
    s0: float
    sigma: float
    rate: float
    maturity: float
    cost_rate: float
    payoff: str = "put"
    strike: float = 100.0


class PricingEngine:
    """Batched ask/bid pricing service on a (data, model) mesh."""

    def __init__(self, mesh, *, n_steps: int, batch: int, capacity: int = 48,
                 round_depth: int = 8, payoff: str = "put",
                 strike: float = 100.0, data_axes=("data",)):
        from ..core.distributed import build_rz_sharded
        self.batch = batch
        self.n_steps = n_steps
        pay = {"put": american_put(strike), "call": american_call(strike),
               "bull_spread": bull_spread()}[payoff]
        self._fn = jax.jit(build_rz_sharded(
            mesh, n_steps=n_steps, payoff=pay, capacity=capacity,
            round_depth=round_depth, data_axes=data_axes))
        self._pending: List[Tuple[PriceRequest, int]] = []
        self._results: Dict[int, Tuple[float, float]] = {}
        self._next_id = 0

    def submit(self, req: PriceRequest) -> int:
        rid = self._next_id
        self._next_id += 1
        self._pending.append((req, rid))
        return rid

    def flush(self) -> Dict[int, Tuple[float, float]]:
        """Price all pending requests (padding the final partial batch)."""
        out: Dict[int, Tuple[float, float]] = {}
        while self._pending:
            chunk = self._pending[:self.batch]
            self._pending = self._pending[self.batch:]
            pad = self.batch - len(chunk)
            reqs = [c[0] for c in chunk] + [chunk[-1][0]] * pad
            arr = lambda f: jnp.asarray([getattr(r, f) for r in reqs],
                                        jnp.float64)
            ask, bid, stat = self._fn(arr("s0"), arr("sigma"), arr("rate"),
                                      arr("maturity"), arr("cost_rate"))
            ask, bid = np.asarray(ask), np.asarray(bid)
            for i, (_, rid) in enumerate(chunk):
                out[rid] = (float(ask[i]), float(bid[i]))
        self._results.update(out)
        return out


class LMEngine:
    """Prefill-then-decode engine over a fixed request batch."""

    def __init__(self, params, cfg: ModelConfig, run, *, batch: int,
                 max_len: int, rules=None):
        from ..models.transformer import decode_step, init_cache, prefill
        self.cfg = cfg
        self.run = run
        self.batch = batch
        self.max_len = max_len
        self.params = params
        self.rules = rules
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, run, rules, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, run, rules))

    def generate(self, tokens: np.ndarray, n_new: int,
                 enc_embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """Greedy generation. tokens: (B, S0) prompt; returns (B, n_new)."""
        B, S0 = tokens.shape
        assert B == self.batch and S0 + n_new <= self.max_len
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if enc_embeds is not None:
            batch["enc_embeds"] = jnp.asarray(enc_embeds)
        logits, cache = self._prefill(self.params, batch)
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for i in range(n_new):
            outs.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(S0 + i))
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return np.stack(outs, axis=1)
