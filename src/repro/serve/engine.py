"""Serving engines.

Two first-class services:

1. ``PricingEngine`` — the paper's workload as a production service: a
   batched option-pricing desk.  Single-contract requests (``submit`` /
   ``flush``) and whole scenario grids (``price_grid`` with a
   :class:`GridRequest`) are routed through the continuous-batching
   scheduler (:class:`repro.serve.scheduler.PricingService`): requests
   coalesce across payoff family and strike (payoff-as-data), batches pad
   to power-of-two buckets so repeat traffic reuses compiled programs,
   and ``engine="auto"`` sends frictionless batches down the cheap no-TC
   lattice instead of the Roux–Zastawniak PWL engine.  This class is the
   synchronous adapter (submit-then-flush); drive ``PricingService``
   directly for deadline-triggered continuous batching
   (``docs/SERVING.md``).

2. ``LMEngine`` — LM prefill + decode loop with a batched KV cache
   (the serve path exercised by the decode_32k / long_500k dry-run cells).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .scheduler import PricingService

__all__ = ["PriceRequest", "GridRequest", "PricingEngine", "LMEngine"]


@dataclasses.dataclass
class PriceRequest:
    """One contract.  ``payoff``/``strike``/``n_steps`` left at ``None``
    take the service defaults; set per request they are *honoured* — the
    scheduler batches them as payoff-family data, so a heterogeneous
    stream still coalesces into one compiled call per bucket.

    ``n_assets > 1`` (a basket) or an explicit ``exercise_steps``
    Bermudan schedule routes the request to the ``lsmc`` Monte Carlo
    engine — such requests land in their own buckets keyed by the MC
    contract shape (see ``SchedulerCore.submit``)."""
    s0: float
    sigma: float
    rate: float
    maturity: float
    cost_rate: float
    payoff: Optional[str] = None
    strike: Optional[float] = None
    strike2: Optional[float] = None
    n_steps: Optional[int] = None
    n_assets: Optional[int] = None
    exercise_steps: Optional[tuple] = None


@dataclasses.dataclass
class GridRequest:
    """A scenario-grid pricing request (cartesian axes; scalars allowed).

    Each axis may be a scalar or a sequence; the engine prices the full
    cartesian product in one compiled call (see ``repro.scenarios``).
    ``n_steps`` is compile-time static per request.
    """
    s0: Any = 100.0
    sigma: Any = 0.2
    rate: Any = 0.1
    maturity: Any = 0.25
    cost_rate: Any = 0.0
    payoff: Any = "put"
    strike: Any = 100.0
    strike2: Any = None
    n_steps: int = 100
    greeks: bool = False
    backend: str = "jnp"     # TC engine implementation: "jnp" | "pallas"
    interpret: Any = None    # Pallas mode; None = platform policy
    n_assets: int = 1        # > 1 routes the grid to the lsmc engine
    exercise_steps: Any = None   # Bermudan schedule -> lsmc engine
    # consolidated execution knobs (repro.configs.pricing.ExecutionConfig);
    # fields set here win over backend/interpret above
    execution: Any = None


class PricingEngine:
    """Synchronous batched pricing desk (adapter over ``PricingService``).

    Kept as the submit-then-flush surface the examples and tests use; all
    batching, bucketing, caching and engine routing live in the
    scheduler.  ``mesh``/``round_depth``/``data_axes`` are accepted for
    signature compatibility with the pre-scheduler distributed engine
    (drive ``core/distributed.py::build_rz_sharded`` directly for
    multi-device lattice sharding — the scheduler is single-process, see
    ``docs/KNOWN_ISSUES.md``).
    """

    def __init__(self, mesh=None, *, n_steps: int, batch: int,
                 capacity: int = 48, round_depth: int = 8,
                 payoff: str = "put", strike: float = 100.0,
                 data_axes=("data",)):
        del mesh, round_depth, data_axes    # scheduler path: single process
        self.batch = batch
        self.n_steps = n_steps
        self.capacity = capacity
        self.service = PricingService(
            max_batch=batch, default_n_steps=n_steps, capacity=capacity,
            default_payoff=payoff, default_strike=strike,
            result_cache_size=0,    # engine semantics: always re-price
            min_grid_bucket=batch)
        self.grid_stats: Dict[str, int] = {"grids": 0, "scenarios": 0}
        self._open: set = set()

    def submit(self, req: PriceRequest) -> int:
        rid = self.service.submit(req)
        self._open.add(rid)
        return rid

    def flush(self) -> Dict[int, Tuple[float, float]]:
        """Price all pending requests (padding each partial batch).

        Per-request ``payoff``/``strike`` are honoured (batched as payoff
        data); requests that leave them ``None`` take the engine defaults.
        Returns ``{request id: (ask, bid)}`` for every request not yet
        returned by a previous ``flush`` (full buckets may already have
        been priced inline by ``submit``'s size trigger).
        """
        self.service.flush()
        out: Dict[int, Tuple[float, float]] = {}
        for rid in sorted(self._open):
            q = self.service.result(rid)
            if q is not None:
                out[rid] = (q.ask, q.bid)
        self._open.difference_update(out)
        return out

    def price_grid(self, req: GridRequest):
        """Price a :class:`GridRequest` through the scenario batch engine.

        Routes ``engine="auto"``: an all-frictionless grid takes the
        cheap no-TC lattice, any positive ``cost_rate`` the RZ engine.
        The flat batch is padded up to the next power-of-two bucket so a
        stream of differently-sized grids hits a handful of compiled
        programs; results are unpadded and reshaped to the grid's logical
        (cartesian) shape before returning.
        """
        res = self.service.price_grid(req)
        self.grid_stats["grids"] += 1
        self.grid_stats["scenarios"] += res.grid.n_scenarios
        return res


class LMEngine:
    """Prefill-then-decode engine over a fixed request batch."""

    def __init__(self, params, cfg: ModelConfig, run, *, batch: int,
                 max_len: int, rules=None):
        from ..models.transformer import decode_step, init_cache, prefill
        self.cfg = cfg
        self.run = run
        self.batch = batch
        self.max_len = max_len
        self.params = params
        self.rules = rules
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, run, rules, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, run, rules))

    def generate(self, tokens: np.ndarray, n_new: int,
                 enc_embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """Greedy generation. tokens: (B, S0) prompt; returns (B, n_new)."""
        B, S0 = tokens.shape
        assert B == self.batch and S0 + n_new <= self.max_len
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if enc_embeds is not None:
            batch["enc_embeds"] = jnp.asarray(enc_embeds)
        logits, cache = self._prefill(self.params, batch)
        outs = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for i in range(n_new):
            outs.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(S0 + i))
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return np.stack(outs, axis=1)
