"""Error-feedback int8 gradient compression for data-parallel reduction.

Scope (stated honestly): under GSPMD/pjit the data-parallel gradient
reduction is inserted by XLA inside the backward pass, where user code
cannot intercept it.  Compression therefore applies in the *explicit* DP
mode used by the elastic trainer (`train/trainer.py --dp-mode=shard_map`),
where gradients are psum'd by user code:

    g_local -> quantize(int8, per-leaf scale) -> psum -> dequantize

with error feedback: the quantisation residual is added back into the next
step's gradient, which keeps SGD/Adam convergence (Karimireddy et al.,
2019).  The quantised all-reduce moves 4x fewer bytes on the DP axis —
on the production mesh that axis is the 16-way (or 2x16 multi-pod) ring,
which §Roofline shows is the bound for small models.

``compress``/``decompress`` are also used by the checkpoint codec.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["EFState", "ef_init", "quantize_int8", "dequantize_int8",
           "compressed_psum"]


class EFState(NamedTuple):
    residual: Any          # same structure as grads, fp32


def ef_init(grads_like) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, ef: Optional[EFState], axis_name: str):
    """int8 + error-feedback psum over ``axis_name`` (inside shard_map).

    Returns (reduced_fp32_grads, new_ef).  Scales are psum-maxed first so
    every participant uses the same dequantisation factor.
    """
    if ef is None:
        ef = ef_init(grads)

    def one(g, r):
        g = g.astype(jnp.float32) + r
        amax = jnp.max(jnp.abs(g))
        amax = jax.lax.pmax(amax, axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127)
        deq = q * scale
        new_r = g - deq                      # local quantisation error
        total = jax.lax.psum(q, axis_name) * scale
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total / n, new_r

    out = jax.tree.map(one, grads, ef.residual)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return red, EFState(res)
