"""AdamW with fp32 state, decoupled weight decay and global-norm clipping.

Written against plain pytrees (no optax dependency in this environment).
Optimizer state shards exactly like the parameters (same logical specs),
which is what makes FSDP work: ZeRO-3 = params + m + v all sharded on the
fsdp axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # params with ndim <= 1 (norm scales, biases) skip weight decay
    decay_min_ndim: int = 2
    # keep an fp32 master copy when params are stored in bf16 (the
    # "bf16-params" memory/collective optimisation, EXPERIMENTS.md §Perf)
    master_fp32: bool = False


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any = None       # fp32 master params (only when cfg.master_fp32)


def adamw_init(params, cfg: Optional["AdamWConfig"] = None) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = None
    if cfg is not None and cfg.master_fp32:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      master=master)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, tree), norm


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics).

    With ``cfg.master_fp32`` the update reads/writes the fp32 master in the
    optimizer state and emits bf16 params — compute layers then all-gather
    2-byte weights instead of 4-byte (FSDP traffic and HBM both halve).
    """
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cfg.lr(step) if callable(cfg.lr) else jnp.asarray(cfg.lr, jnp.float32)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    base = state.master if cfg.master_fp32 else params

    def upd(p32, g, m, v, out_dtype):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p32.ndim >= cfg.decay_min_ndim and cfg.weight_decay:
            delta = delta + cfg.weight_decay * p32.astype(jnp.float32)
        pnew = p32.astype(jnp.float32) - lr * delta
        return pnew.astype(out_dtype), pnew, m, v

    out = jax.tree.map(
        lambda p32, p, g, m, v: upd(p32, g, m, v, p.dtype),
        base, params, grads, state.m, state.v)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_params = pick(0)
    new_master = pick(1) if cfg.master_fp32 else None
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, pick(2), pick(3), new_master), metrics
