"""Learning-rate schedules (warmup + cosine / linear / constant)."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant", "warmup_linear"]


def constant(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) *
                         0.5 * (1.0 + jnp.cos(math.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos).astype(jnp.float32)
    return fn


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        return jnp.where(step < warmup_steps, warm,
                         peak_lr * (1.0 - t)).astype(jnp.float32)
    return fn
