"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import, and tests/benches must keep seeing a single device.

Single pod:  (16, 16)        axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "mesh_rules"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (virtual) devices the test process has."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_rules(mesh):
    """MeshRules bound to this mesh: fsdp over (pod,)data, tp over model."""
    from ..models.sharding import MeshRules
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return MeshRules(mesh=mesh, fsdp=fsdp, tp=("model",))
