"""Distributed pricing launcher (the paper's workload as a service).

    PYTHONPATH=src python -m repro.launch.price --n-steps 500 \
        --contracts 8 [--data 1 --model 1] [--tc | --no-tc]

Contracts shard over the data axis; the lattice node axis shards over the
model axis with the paper's round/halo schedule (core/distributed.py).

Scenario-grid mode (one compiled call over the cartesian product of the
given axes, via ``repro.scenarios``):

    PYTHONPATH=src python -m repro.launch.price --grid \
        --n-steps 100 --s0 90,100,110 --sigmas 0.15,0.25 \
        --lambdas 0,0.005,0.01 --payoffs put,call,bull_spread [--greeks] \
        [--backend pallas [--levels L] [--block B]] [--devices W]

``--backend pallas`` routes the transaction-cost engine through the
blocked Pallas kernel rounds (kernels/rz_step.py); the friction-free
engine (all lambdas 0) likewise uses its Pallas lattice kernel.
``--devices W`` shards the scenario batch over a 1-D mesh of W devices
under the cost-model shard plan (core/partition.py::plan_shards); on
CPU, expose fake devices first with
``XLA_FLAGS=--xla_force_host_platform_device_count=W`` (asking for more
devices than the process has runs the identical plan single-device —
the simulated mesh, see docs/KNOWN_ISSUES.md).

Monte Carlo engine (grid mode): ``--engine lsmc`` — or ``--n-assets``
> 1 / ``--exercise-dates`` under ``--engine auto`` — routes the grid
through the least-squares Monte Carlo engine (core/lsmc.py)::

    PYTHONPATH=src python -m repro.launch.price --grid --engine lsmc \
        --n-steps 50 --s0 90,100,110 --paths 8192 \
        --exercise-dates 10,25,50 --n-assets 3 [--mc-seed 0] \
        [--basis laguerre --degree 4]

``--exercise-dates`` is a comma list of lattice step indices (must
include the terminal step ``--n-steps``); lsmc output adds the
per-scenario MC standard error column.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributed import build_notc_sharded, build_rz_sharded
from ..core.payoff import american_put, bull_spread
from .mesh import make_test_mesh


def _floats(csv: str):
    return tuple(float(x) for x in csv.split(","))


def _steps(csv):
    return (None if csv is None
            else tuple(int(x) for x in csv.split(",")))


def run_grid(args) -> None:
    from ..api import price_grid
    grid_kwargs = dict(
        s0=_floats(args.s0), sigma=_floats(args.sigmas),
        rate=_floats(args.rates), maturity=_floats(args.maturities),
        cost_rate=_floats(args.lambdas),
        payoff=tuple(args.payoffs.split(",")),
        strike=_floats(args.strikes), n_assets=args.n_assets,
        exercise_steps=_steps(args.exercise_dates))
    t0 = time.perf_counter()
    from ..configs.pricing import ExecutionConfig
    res = price_grid(n_steps=args.n_steps,
                     execution=ExecutionConfig(
                         engine=args.engine, backend=args.backend,
                         interpret=args.interpret, platform=args.platform,
                         devices=args.devices, n_paths=args.paths,
                         mc_seed=args.mc_seed, basis=args.basis,
                         degree=args.degree),
                     capacity=args.capacity, greeks=args.greeks,
                     levels=args.levels, block=args.block, **grid_kwargs)
    n = res.grid.n_scenarios
    dt = time.perf_counter() - t0
    if res.shard_info is not None:
        si = res.shard_info
        kind = "simulated" if si.simulated else "device"
        print(f"[{kind} mesh: {si.plan.n_shards} shards, "
              f"{si.plan.lanes} lanes/shard, rows {si.plan.sizes}, "
              f"predicted work spread {si.plan.work_spread:.1%}]")
    ask, bid = res.ask.ravel(), res.bid.ravel()
    se = None if res.stderr is None else res.stderr.ravel()
    g = res.grid
    for i in range(n):
        line = (f"{g.payoff[i]:>11s} K={g.strike[i]:6.1f} "
                f"S0={g.s0[i]:6.1f} sig={g.sigma[i]:.2f} "
                f"lam={g.cost_rate[i]:.3f}  ask={ask[i]:9.6f} "
                f"bid={bid[i]:9.6f}")
        if se is not None:
            line += f"  se={se[i]:.6f}"
        if args.greeks:
            line += (f"  delta={res.delta_ask.ravel()[i]:+.4f} "
                     f"vega={res.vega_ask.ravel()[i]:8.4f}")
        print(line)
    extra = (f", engine={res.engine}" if res.engine else "")
    print(f"\n{n} scenarios, N={args.n_steps}{extra}: {dt:.2f}s incl. "
          f"compile ({n / dt:.1f} contracts/s; re-run hits the compile "
          "cache)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-steps", type=int, default=500)
    ap.add_argument("--contracts", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--round-depth", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=48)
    ap.add_argument("--cost-rate", type=float, default=0.005)
    ap.add_argument("--payoff", default="put", choices=["put", "bull_spread"])
    ap.add_argument("--no-tc", action="store_true")
    # scenario-grid mode
    ap.add_argument("--grid", action="store_true",
                    help="price the cartesian scenario grid in one call")
    ap.add_argument("--s0", default="90,100,110")
    ap.add_argument("--sigmas", default="0.2")
    ap.add_argument("--rates", default="0.1")
    ap.add_argument("--maturities", default="0.25")
    ap.add_argument("--lambdas", default="0,0.005,0.01")
    ap.add_argument("--payoffs", default="put")
    ap.add_argument("--strikes", default="100")
    ap.add_argument("--greeks", action="store_true")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"],
                    help="grid-engine implementation: vectorised jnp "
                         "recursion or the blocked Pallas kernel rounds")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="pin the platform policy (core/platform.py): "
                         "interpret mode, default dtype and XLA flags "
                         "(default: auto-detect)")
    ap.add_argument("--interpret", default="auto",
                    choices=["auto", "on", "off"],
                    help="Pallas execution mode; auto = platform policy "
                         "(interpret on CPU, compiled on GPU/TPU)")
    ap.add_argument("--levels", type=int, default=None,
                    help="Pallas round depth L (default: partition.py pick)")
    ap.add_argument("--block", type=int, default=None,
                    help="Pallas node-block size (default: one re-balanced "
                         "block per round)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the scenario batch over a 1-D mesh of this "
                         "many devices (grid mode; cost-model shard plan)")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "notc", "rz", "lsmc"],
                    help="grid engine (auto routes by contract shape then "
                         "cost rate; lsmc = least-squares Monte Carlo)")
    ap.add_argument("--paths", type=int, default=4096,
                    help="Monte Carlo paths per scenario (lsmc engine)")
    ap.add_argument("--exercise-dates", default=None,
                    help="comma list of Bermudan exercise step indices "
                         "(must include --n-steps; routes to lsmc)")
    ap.add_argument("--n-assets", type=int, default=1,
                    help="basket size per scenario (>1 routes to lsmc)")
    ap.add_argument("--mc-seed", type=int, default=0,
                    help="PRNG seed for the lsmc engine (deterministic)")
    ap.add_argument("--basis", default="poly",
                    choices=["poly", "laguerre"],
                    help="lsmc regression basis")
    ap.add_argument("--degree", type=int, default=3,
                    help="lsmc regression basis degree")
    args = ap.parse_args()
    args.interpret = {"auto": None, "on": True, "off": False}[args.interpret]
    if args.platform is not None:
        from ..core.platform import set_platform
        set_platform(args.platform)

    if args.grid:
        run_grid(args)
        return

    mesh = make_test_mesh(args.data, args.model)
    n = args.contracts
    s0 = jnp.linspace(90.0, 110.0, n).astype(jnp.float64)
    sig = jnp.full((n,), 0.2)
    rate = jnp.full((n,), 0.1)
    mat = jnp.full((n,), 0.25)

    if args.no_tc:
        f = jax.jit(build_notc_sharded(mesh, n_steps=args.n_steps,
                                       strike=100.0,
                                       round_depth=args.round_depth))
        t0 = time.perf_counter()
        price = np.asarray(f(s0, sig, rate, mat))
        dt = time.perf_counter() - t0
        for i in range(n):
            print(f"S0={float(s0[i]):6.1f}  price={price[i]:.6f}")
    else:
        pay = american_put(100.0) if args.payoff == "put" else bull_spread()
        f = jax.jit(build_rz_sharded(
            mesh, n_steps=args.n_steps, payoff=pay, capacity=args.capacity,
            round_depth=args.round_depth))
        k = jnp.full((n,), args.cost_rate)
        t0 = time.perf_counter()
        ask, bid, pieces = f(s0, sig, rate, mat, k)
        ask, bid = np.asarray(ask), np.asarray(bid)
        dt = time.perf_counter() - t0
        for i in range(n):
            print(f"S0={float(s0[i]):6.1f}  ask={ask[i]:.6f}  "
                  f"bid={bid[i]:.6f}")
        print(f"max PWL knots: {int(pieces)} (capacity {args.capacity})")
    print(f"{n} contracts, N={args.n_steps}: {dt:.2f}s (incl. compile)")


if __name__ == "__main__":
    main()
