import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# Pipeline-parallel dry-run: the multi-pod mesh with the POD axis as the
# pipeline dimension (stages across pods, FSDP+TP inside each pod) — the
# realistic multi-pod layout since inter-pod DCN is ~10x slower than ICI.
# Lowers + compiles the pipelined train step and records the collective
# schedule (the per-tick collective-permute is the activation hand-off).

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from ..configs.base import SHAPES, get_config  # noqa: E402
from .dryrun import (RESULTS_DIR, _param_specs, collective_bytes,  # noqa: E402
                     shardings_from_specs)
from .mesh import make_production_mesh  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--micro", type=int, default=8)
    ap.add_argument("--tag", default="pp")
    args = ap.parse_args()

    from ..models.transformer import RunCfg, init_lm
    from ..optim.adamw import AdamWConfig, adamw_init
    from ..train.pipeline import make_pp_train_step, split_stages

    from ..models.sharding import MeshRules

    mesh = make_production_mesh(multi_pod=True)      # (pod, data, model)
    stages = mesh.shape["pod"]
    # inside-stage sharding: FSDP over data, TP over model (pod is pipe)
    rules = MeshRules(mesh=mesh, fsdp=("data",), tp=("model",))
    cfg = get_config(args.arch)
    shape = SHAPES["train_4k"]
    run = RunCfg(impl="flash", remat="full")
    opt_cfg = AdamWConfig()

    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(lambda k: init_lm(k, cfg)[0], key)
    pp_sds = jax.eval_shape(lambda p: split_stages(p, cfg, stages),
                            params_sds)
    opt_sds = jax.eval_shape(adamw_init, pp_sds)

    # logical specs: stage stack gets a leading "pipe" dim; the original
    # scan spec already starts with None for the (now per-stage) reps axis
    base_specs = _param_specs(cfg)
    pp_specs = {"stages": jax.tree.map(
        lambda s: ("pipe_pod",) + tuple(s), base_specs["scan"],
        is_leaf=lambda x: isinstance(x, tuple) and
        all(e is None or isinstance(e, str) for e in x))}
    for k, v in base_specs.items():
        if k != "scan":
            pp_specs[k] = v
    # XLA SPMD CHECK-fails partitioning the embedding gather under the
    # hybrid manual(pipe)/auto(data,model) context (spmd_partitioner_util
    # ExpandDeviceGroupsWithIota); replicate the embedding/head tables in
    # PP mode — stage weights keep full FSDP/TP sharding.
    def _replicate(spec_tree):
        return jax.tree.map(
            lambda s: tuple(None for _ in s), spec_tree,
            is_leaf=lambda x: isinstance(x, tuple) and
            all(e is None or isinstance(e, str) for e in x))
    for k in ("embed", "lm_head"):
        if k in pp_specs:
            pp_specs[k] = _replicate(pp_specs[k])

    class _PPRules(MeshRules):
        def resolve(self, logical_axis, dim_size):
            if logical_axis == "pipe_pod":
                return "pod"
            return super().resolve(logical_axis, dim_size)

    pp_rules = _PPRules(mesh=mesh, fsdp=("data",), tp=("model",))
    p_sh = shardings_from_specs(pp_sds, pp_specs, pp_rules)
    from ..optim.adamw import AdamWState
    o_sh = AdamWState(step=NamedSharding(mesh, PS()),
                      m=p_sh, v=p_sh, master=None)

    mb = shape.global_batch // args.micro
    batch_sds = {
        "tokens": jax.ShapeDtypeStruct((args.micro, mb, shape.seq_len),
                                       jnp.int32),
        "targets": jax.ShapeDtypeStruct((args.micro, mb, shape.seq_len),
                                        jnp.int32)}
    b_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, PS(None, "data", None)), batch_sds)

    step = make_pp_train_step(cfg, run, opt_cfg, mesh, stages=stages)
    jfn = jax.jit(step, in_shardings=((p_sh, o_sh), b_sh),
                  donate_argnums=(0,))
    t0 = time.time()
    lowered = jfn.lower((pp_sds, opt_sds), batch_sds)
    compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    rec = {"arch": args.arch, "mode": "pp-train", "mesh": "2x16x16",
           "stages": stages, "n_micro": args.micro,
           "collectives": coll,
           "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
           "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
           "compile_s": round(dt, 1), "ok": True}
    out = RESULTS_DIR / args.tag / f"{args.arch}__pp_train.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
