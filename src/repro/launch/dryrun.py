import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the multi-pod dry-run: for every
# (architecture x input-shape x mesh) cell it lowers + compiles the real
# train/prefill/decode step on the production mesh and records
# memory_analysis / cost_analysis / the collective schedule — proving the
# distribution config is coherent without TPU hardware.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as PS  # noqa: E402

from ..configs.base import SHAPES, get_config, list_archs    # noqa: E402
from .mesh import make_production_mesh, mesh_rules           # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results"


# --------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------- #
def input_specs(cfg, shape, n_micro: int = 8):
    """ShapeDtypeStructs for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.mode == "train":
        n_micro = min(n_micro, B)
        mb = B // n_micro
        batch = {"tokens": jax.ShapeDtypeStruct((n_micro, mb, S), i32),
                 "targets": jax.ShapeDtypeStruct((n_micro, mb, S), i32)}
        if cfg.n_encoder_layers:
            if cfg.frontend == "audio_stub":
                batch["enc_embeds"] = jax.ShapeDtypeStruct(
                    (n_micro, mb, S, cfg.d_model), jnp.bfloat16)
            else:
                batch["enc_tokens"] = jax.ShapeDtypeStruct((n_micro, mb, S), i32)
        return batch
    if shape.mode == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.n_encoder_layers:
            if cfg.frontend == "audio_stub":
                batch["enc_embeds"] = jax.ShapeDtypeStruct(
                    (B, S, cfg.d_model), jnp.bfloat16)
            else:
                batch["enc_tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


# --------------------------------------------------------------------- #
# sharding resolution
# --------------------------------------------------------------------- #
def _is_spec_leaf(x):
    """A logical spec is a (possibly empty) tuple of axis names / None —
    NOT a NamedTuple container like TrainState/AdamWState."""
    return (isinstance(x, tuple) and type(x) is tuple
            and all(e is None or isinstance(e, str) for e in x))


def shardings_from_specs(sds_tree, spec_tree, rules):
    def one(spec, sds):
        if spec is None:
            return NamedSharding(rules.mesh, PS())
        return NamedSharding(rules.mesh,
                             rules.spec(*spec, shape=sds.shape))
    return jax.tree.map(one, spec_tree, sds_tree, is_leaf=_is_spec_leaf)


def cache_shardings(cache_sds, rules):
    """KV caches: batch over dp, sequence over tp (sequence-parallel decode
    attention); recurrent states: width over tp."""
    from jax.tree_util import tree_map_with_path

    def one(path, sds):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = sds.ndim
        if name in ("k", "v", "xk", "xv"):
            spec = ("dp", "tp", None, None) if nd == 4 else \
                   (None, "dp", "tp", None, None)
        elif name == "h":
            spec = ("dp", "tp") if nd == 2 else \
                   ("dp", "tp", None) if nd == 3 else \
                   (None, "dp", "tp") if nd == 3 else (None, "dp", "tp", None)
        elif name == "conv":
            spec = ("dp", None, "tp") if nd == 3 else (None, "dp", None, "tp")
        else:
            spec = (None,) * nd
        return NamedSharding(rules.mesh, rules.spec(*spec, shape=sds.shape))

    return tree_map_with_path(one, cache_sds)


def batch_shardings(batch_sds, rules, mode: str):
    def one(sds):
        if sds.ndim == 0:
            return NamedSharding(rules.mesh, PS())
        if mode == "train":   # (n_micro, mb, ...)
            spec = (None, "dp") + (None,) * (sds.ndim - 2)
        else:                 # (B, ...)
            spec = ("dp",) + (None,) * (sds.ndim - 1)
        return NamedSharding(rules.mesh, rules.spec(*spec, shape=sds.shape))
    return jax.tree.map(one, batch_sds)


# --------------------------------------------------------------------- #
# collective schedule extraction
# --------------------------------------------------------------------- #
_COLL_RE = re.compile(
    r"(\w[\w\.\-]*)\s*=\s*(\w+\[[^\]]*\][^ ]*|\([^)]*\))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|c64)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "c64": 8}


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in optimized HLO.

    Loop bodies are counted once (static text); the per-step roofline
    multiplies by trip counts analytically where needed — recorded as-is
    plus an op histogram for the report.
    """
    totals = {}
    counts = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op = m.group(2), m.group(3)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shape_txt):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        totals[op] = totals.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": totals, "count_by_op": counts,
            "total_bytes": sum(totals.values())}


# --------------------------------------------------------------------- #
# cell construction
# --------------------------------------------------------------------- #
def build_cell(arch: str, shape_name: str, mesh, *, n_micro=8,
               impl="flash", remat="full", moe_impl="dispatch",
               groups=None, unroll=False, param_dtype="float32",
               moe_psum_bf16=False):
    """groups/unroll: cost-calibration mode — truncate the stack to
    ``groups`` pattern repetitions and unroll every layer scan, so
    cost_analysis (which counts loop bodies once) is exact; the roofline
    reconstructs totals from the g=1 / g=2 delta."""
    import dataclasses as _dc

    from ..models.transformer import (RunCfg, decode_step as dec_fn,
                                      init_cache as ic, init_lm,
                                      prefill as prefill_fn)
    from ..optim.adamw import AdamWConfig
    from ..train import step as step_mod
    from ..train.step import make_train_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if groups is not None:
        pat = len(cfg.block_pattern)
        cfg = _dc.replace(
            cfg, n_layers=groups * pat,
            n_encoder_layers=(groups * pat if cfg.n_encoder_layers else 0))
        if shape.mode == "train":
            # keep the per-microbatch token count identical to production
            shape = _dc.replace(shape,
                                global_batch=shape.global_batch // n_micro)
            n_micro = 1
    rules = mesh_rules(mesh)
    if unroll:
        # calibration: every lax loop must collapse/unroll so that XLA's
        # count-body-once cost analysis sees the whole computation
        big = 1 << 30
        run = RunCfg(impl=impl, remat=remat, moe_impl=moe_impl, unroll=True,
                     attn_q_chunk=big, attn_kv_chunk=big, scan_chunk=big,
                     moe_psum_bf16=moe_psum_bf16)
    else:
        run = RunCfg(impl=impl, remat=remat, moe_impl=moe_impl,
                     moe_psum_bf16=moe_psum_bf16)
    key = jax.random.PRNGKey(0)
    params_specs = _param_specs(cfg)

    pdtype = jnp.bfloat16 if param_dtype == "bfloat16" else jnp.float32
    master = param_dtype == "bfloat16"
    opt_cfg = AdamWConfig(master_fp32=master)

    if shape.mode == "train":
        specs = step_mod.state_specs(params_specs, master_fp32=master)
        state_sds = jax.eval_shape(
            lambda k: step_mod.init_train_state(k, cfg, pdtype, opt_cfg)[0],
            key)
        batch_sds = input_specs(cfg, shape, n_micro)
        st_sh = shardings_from_specs(state_sds, specs, rules)
        b_sh = batch_shardings(batch_sds, rules, "train")
        fn = make_train_step(cfg, run, opt_cfg, rules)
        jfn = jax.jit(fn, in_shardings=(st_sh, b_sh),
                      out_shardings=(st_sh, None), donate_argnums=(0,))
        return jfn, (state_sds, batch_sds), cfg

    params_sds = jax.eval_shape(
        lambda k: jax.tree.map(lambda p: p.astype(pdtype),
                               init_lm(k, cfg)[0]), key)
    p_sh = shardings_from_specs(params_sds, params_specs, rules)

    if shape.mode == "prefill":
        batch_sds = input_specs(cfg, shape)
        b_sh = batch_shardings(batch_sds, rules, "prefill")
        fn = lambda params, batch: prefill_fn(params, batch, cfg, run, rules)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
        return jfn, (params_sds, batch_sds), cfg

    # decode
    B, S = shape.global_batch, shape.seq_len
    cross = S if cfg.n_encoder_layers else 0
    cache_sds = jax.eval_shape(
        lambda: ic(cfg, B, S, jnp.bfloat16, cross_len=cross))
    c_sh = cache_shardings(cache_sds, rules)
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    t_sh = NamedSharding(mesh, rules.spec("dp", None, shape=(B, 1)))
    fn = lambda params, cache, tok, pos: dec_fn(params, cache, tok, pos,
                                                cfg, run, rules)
    jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh, NamedSharding(mesh, PS())),
                  out_shardings=(None, c_sh), donate_argnums=(1,))
    return jfn, (params_sds, cache_sds, tok_sds, pos_sds), cfg


def _param_specs(cfg):
    """Static reconstruction of the init_lm spec tree (no tracing)."""
    from ..models.transformer import init_lm
    import jax.random as jr
    # init_lm returns (params, specs); specs is static python data, but we
    # must not allocate params — eval_shape the params and grab specs from a
    # shape-only trace: init only uses key shapes, so call under eval_shape
    # and capture specs via closure.
    out = {}

    def capture(k):
        params, specs = init_lm(k, cfg)
        out["specs"] = specs
        return params

    jax.eval_shape(capture, jr.PRNGKey(0))
    return out["specs"]


# --------------------------------------------------------------------- #
# skip rules (per assignment)
# --------------------------------------------------------------------- #
def cell_skip_reason(arch: str, shape_name: str):
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "long_500k skipped: full quadratic attention (see DESIGN.md)"
    return None


def _measure(jfn, args_sds):
    t0 = time.time()
    lowered = jfn.lower(*args_sds)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        mem_rec[attr] = getattr(mem, attr, None)
    return {
        "flops_per_device": cost.get("flops"),
        "bytes_accessed_per_device": cost.get("bytes accessed"),
        "memory_analysis": mem_rec,
        "collectives": coll,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_path: Path,
             calibrate: bool = True, **kw):
    mesh = make_production_mesh(multi_pod=multi_pod)
    jfn, args_sds, cfg = build_cell(arch, shape_name, mesh, **kw)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": SHAPES[shape_name].mode,
        "n_params": cfg.param_count(),
        "n_params_active": cfg.active_param_count(),
        "n_micro": kw.get("n_micro", 8) if SHAPES[shape_name].mode == "train" else 1,
        "n_groups": cfg.n_layers / len(cfg.block_pattern),
        "impl": kw.get("impl"), "remat": kw.get("remat"),
    }
    rec.update(_measure(jfn, args_sds))
    rec["ok"] = True

    # ---- cost calibration: 1-group and 2-group unrolled lowerings -------
    if calibrate and not multi_pod:
        for g in (1, 2):
            jfn2, sds2, _ = build_cell(arch, shape_name, mesh, groups=g,
                                       unroll=True, **kw)
            m = _measure(jfn2, sds2)
            rec[f"calib_g{g}"] = {
                "flops_per_device": m["flops_per_device"],
                "bytes_accessed_per_device": m["bytes_accessed_per_device"],
                "collective_bytes": m["collectives"]["total_bytes"],
                "collective_bytes_by_op": m["collectives"]["bytes_by_op"],
                "compile_s": m["compile_s"],
            }

    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "flops_per_device",
                       "compile_s")}))
    print("memory:", rec["memory_analysis"])
    print("collectives:", rec["collectives"]["count_by_op"],
          rec["collectives"]["total_bytes"])
    if "calib_g2" in rec:
        print("calib:", rec["calib_g1"]["flops_per_device"],
              rec["calib_g2"]["flops_per_device"])
    return rec


def run_calib_only(arch: str, shape_name: str, out_path: Path, **kw):
    """Re-run just the g1/g2 calibration lowerings and patch the JSON."""
    rec = json.loads(out_path.read_text())
    if not rec.get("ok"):
        return
    mesh = make_production_mesh(multi_pod=False)
    for g in (1, 2):
        jfn2, sds2, _ = build_cell(arch, shape_name, mesh, groups=g,
                                   unroll=True, **kw)
        m = _measure(jfn2, sds2)
        rec[f"calib_g{g}"] = {
            "flops_per_device": m["flops_per_device"],
            "bytes_accessed_per_device": m["bytes_accessed_per_device"],
            "collective_bytes": m["collectives"]["total_bytes"],
            "collective_bytes_by_op": m["collectives"]["bytes_by_op"],
            "compile_s": m["compile_s"],
        }
    out_path.write_text(json.dumps(rec, indent=1))
    print("recalibrated", arch, shape_name,
          rec["calib_g1"]["flops_per_device"],
          rec["calib_g2"]["flops_per_device"])


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every remaining cell in subprocesses")
    ap.add_argument("--calib-only", action="store_true",
                    help="refresh calibration records of one existing cell")
    ap.add_argument("--recalibrate", action="store_true",
                    help="refresh calibrations of every completed 16x16 cell")
    ap.add_argument("--impl", default="flash", choices=["naive", "flash"])
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--moe-impl", default="dispatch",
                    choices=["dense", "dispatch"])
    ap.add_argument("--micro", type=int, default=8)
    ap.add_argument("--param-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--moe-psum-bf16", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--no-calibrate", action="store_true")
    args = ap.parse_args()

    if args.recalibrate:
        for f in sorted((RESULTS_DIR / args.tag / "16x16").glob("*.json")):
            rec = json.loads(f.read_text())
            if not rec.get("ok"):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", rec["arch"], "--shape", rec["shape"],
                   "--calib-only", "--impl", args.impl, "--remat", args.remat,
                   "--moe-impl", args.moe_impl, "--micro", str(args.micro),
                   "--param-dtype", args.param_dtype, "--tag", args.tag]
            print("== RECAL", rec["arch"], rec["shape"], flush=True)
            subprocess.run(cmd)
        return

    if args.calib_only:
        assert args.arch and args.shape
        out = RESULTS_DIR / args.tag / "16x16" / \
            f"{args.arch}__{args.shape}.json"
        run_calib_only(args.arch, args.shape, out, n_micro=args.micro,
                       impl=args.impl, remat=args.remat,
                       moe_impl=args.moe_impl,
                       param_dtype=args.param_dtype,
                       moe_psum_bf16=args.moe_psum_bf16)
        return

    if args.all:
        meshes = [False, True]
        for multi in meshes:
            for arch in list_archs():
                for shape_name in SHAPES:
                    reason = cell_skip_reason(arch, shape_name)
                    mesh_tag = "2x16x16" if multi else "16x16"
                    out = RESULTS_DIR / args.tag / mesh_tag / \
                        f"{arch}__{shape_name}.json"
                    if reason:
                        out.parent.mkdir(parents=True, exist_ok=True)
                        out.write_text(json.dumps(
                            {"arch": arch, "shape": shape_name,
                             "mesh": mesh_tag, "skipped": reason}))
                        continue
                    if out.exists():
                        try:
                            if json.loads(out.read_text()).get("ok"):
                                continue
                        except Exception:
                            pass
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape_name,
                           "--impl", args.impl, "--remat", args.remat,
                           "--moe-impl", args.moe_impl,
                           "--param-dtype", args.param_dtype,
                           "--micro", str(args.micro), "--tag", args.tag]
                    if multi:
                        cmd.append("--multi-pod")
                    print("== RUN", arch, shape_name, mesh_tag, flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        out.parent.mkdir(parents=True, exist_ok=True)
                        out.write_text(json.dumps(
                            {"arch": arch, "shape": shape_name,
                             "mesh": mesh_tag, "ok": False,
                             "error": f"exit {r.returncode}"}))
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    out = RESULTS_DIR / args.tag / mesh_tag / f"{args.arch}__{args.shape}.json"
    run_cell(args.arch, args.shape, args.multi_pod, out,
             calibrate=not args.no_calibrate, n_micro=args.micro,
             impl=args.impl, remat=args.remat, moe_impl=args.moe_impl,
             param_dtype=args.param_dtype, moe_psum_bf16=args.moe_psum_bf16)


if __name__ == "__main__":
    main()
