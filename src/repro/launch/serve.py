"""LM serving launcher: prefill + batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --batch 2 --prompt-len 16 --new-tokens 8

Loads a checkpoint if ``--ckpt`` points at one (produced by
``repro.launch.train``), otherwise serves from random init (pipe-cleaner
mode).  The decode path is the same `decode_step` the decode_32k /
long_500k dry-run cells lower on the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt as ckpt_lib
from ..configs import get_config, reduced_config
from ..models.transformer import RunCfg, init_lm
from ..serve.engine import LMEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    run = RunCfg(dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params, _ = init_lm(key, cfg)
    if args.ckpt:
        from ..train.step import init_train_state
        state, _ = init_train_state(key, cfg)
        state = ckpt_lib.restore(args.ckpt, like=state)
        params = state.params
        print(f"restored params from {args.ckpt}")

    max_len = args.prompt_len + args.new_tokens
    eng = LMEngine(params, cfg, run, batch=args.batch, max_len=max_len)
    prompt = np.asarray(
        jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab))
    enc = None
    if cfg.n_encoder_layers and cfg.frontend == "audio_stub":
        enc = np.asarray(jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32))

    t0 = time.perf_counter()
    out = eng.generate(prompt, args.new_tokens, enc_embeds=enc)
    dt = time.perf_counter() - t0
    for b in range(args.batch):
        print(f"seq {b}: {out[b].tolist()}")
    print(f"{args.batch}×{args.new_tokens} tokens in {dt:.2f}s "
          f"(incl. compile; {args.batch*args.new_tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
