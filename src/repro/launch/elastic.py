"""Elastic scaling / failure handling — the control-plane contract.

On a real multi-pod deployment the pieces below compose with a cluster
scheduler (GKE/Borg-style).  What lives *in this framework* (and is
exercised by tests on virtual devices):

  1. **Topology catalogue** — the meshes a job may run on, ordered by
     preference.  ``pick_mesh(devices)`` returns the largest catalogued
     mesh that fits the currently-healthy device count (lose a pod ->
     fall back from (2,16,16) to (16,16); lose chips within a pod ->
     (8,16), etc.).
  2. **Elastic restore** — checkpoints store full logical arrays, so
     ``checkpoint.restore(..., sharding=new)`` re-lays-out ZeRO shards on
     whatever mesh was picked (tests/test_checkpoint.py).
  3. **Straggler policy** — the trainer flags steps slower than
     ``factor x EWMA`` (SPMD programs make per-step timing a global
     signal); the policy object decides evict-vs-tolerate and is where a
     deployment wires its scheduler callback.
  4. **Batch rescaling** — global batch is preserved across re-meshes by
     recomputing per-device microbatching (``rescale_batch``), keeping
     the optimizer trajectory comparable after a shrink.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

__all__ = ["TOPOLOGY_CATALOGUE", "pick_mesh", "pick_topology",
           "StragglerPolicy", "rescale_batch"]

# (devices_required, mesh_shape, axis_names) — preference order
TOPOLOGY_CATALOGUE: List[Tuple[int, Tuple[int, ...], Tuple[str, ...]]] = [
    (512, (2, 16, 16), ("pod", "data", "model")),
    (256, (16, 16), ("data", "model")),
    (128, (8, 16), ("data", "model")),
    (64, (4, 16), ("data", "model")),
    (16, (1, 16), ("data", "model")),
    (8, (2, 4), ("data", "model")),
    (4, (2, 2), ("data", "model")),
    (2, (2, 1), ("data", "model")),
    (1, (1, 1), ("data", "model")),
]


def pick_topology(healthy_devices: int):
    """Largest catalogued (shape, axes) that fits; raises if none does."""
    for need, shape, axes in TOPOLOGY_CATALOGUE:
        if healthy_devices >= need:
            return shape, axes
    raise RuntimeError("no catalogued topology fits 0 devices")


def pick_mesh(healthy_devices: int):
    """Build the largest catalogued mesh that fits the healthy devices."""
    import jax
    shape, axes = pick_topology(healthy_devices)
    return jax.make_mesh(shape, axes)


def rescale_batch(global_batch: int, seq_len: int, data_parallel: int,
                  per_device_tokens_budget: int = 1 << 16):
    """Recompute microbatching for a new data-parallel degree, preserving
    the global batch (optimizer trajectory) while respecting per-device
    activation memory."""
    assert global_batch % data_parallel == 0, \
        f"global batch {global_batch} must divide dp={data_parallel}"
    per_dev = global_batch // data_parallel
    n_micro = 1
    while per_dev // n_micro * seq_len > per_device_tokens_budget \
            and n_micro < per_dev:
        n_micro *= 2
    return {"n_micro": n_micro, "micro_batch": global_batch // n_micro}


@dataclasses.dataclass
class StragglerPolicy:
    """Decide what to do with a straggling step (see trainer EWMA hook)."""
    factor: float = 3.0
    tolerate: int = 3                     # consecutive slow steps allowed
    on_evict: Optional[Callable[[int], None]] = None
    _slow_streak: int = 0

    def observe(self, step: int, dt: float, ewma: float) -> str:
        if dt <= self.factor * ewma:
            self._slow_streak = 0
            return "ok"
        self._slow_streak += 1
        if self._slow_streak >= self.tolerate:
            if self.on_evict:
                self.on_evict(step)
            self._slow_streak = 0
            return "evict"
        return "tolerate"
