import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# Dry-run for the PAPER'S OWN workload on the production mesh: a contract
# batch on the data axis x the lattice node axis on the model axis.  Lowers
# + compiles the distributed engines (core/distributed.py), extracts the
# collective schedule and per-round costs, and sweeps the paper's L
# (round_depth) so §Perf can hillclimb the halo/sync trade-off that the
# paper tuned by hand (L=5 with costs, L=50 without).

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..core.distributed import (build_notc_sharded, build_rz_sharded,  # noqa: E402
                                plan_rounds)
from ..core.payoff import american_put  # noqa: E402
from .dryrun import RESULTS_DIR, collective_bytes  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

F64 = 8


def run_pricing_cell(kind: str, n_steps: int, contracts: int,
                     round_depth: int, collapse_lanes, multi_pod: bool,
                     capacity: int = 48):
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    W = mesh.shape["model"]

    if kind == "notc":
        f = build_notc_sharded(mesh, n_steps=n_steps, strike=100.0,
                               round_depth=round_depth,
                               collapse_lanes=collapse_lanes or None,
                               data_axes=data_axes)
        args = [jax.ShapeDtypeStruct((contracts,), jnp.float64)] * 4
        plan = plan_rounds(n_steps - 1, W, round_depth, collapse_lanes or None)
        state_bytes = F64
    else:
        f = build_rz_sharded(mesh, n_steps=n_steps,
                             payoff=american_put(100.0), capacity=capacity,
                             round_depth=round_depth,
                             collapse_lanes=collapse_lanes or None,
                             data_axes=data_axes)
        args = [jax.ShapeDtypeStruct((contracts,), jnp.float64)] * 5
        plan = plan_rounds(n_steps, W, round_depth, collapse_lanes or None)
        state_bytes = 2 * (2 * capacity + 3) * F64   # two parties' PWL SoA

    jf = jax.jit(f)
    t0 = time.time()
    lowered = jf.lower(*args)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())

    bc = contracts // (mesh.devices.size // W)     # contracts per data shard
    halo_bytes_per_round = bc * plan["halo"] * state_bytes
    rec = {
        "kind": kind, "n_steps": n_steps, "contracts": contracts,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "round_depth": round_depth, "plan": plan, "capacity": capacity,
        "flops_per_device_once": cost.get("flops"),
        "bytes_accessed_once": cost.get("bytes accessed"),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "collectives": coll,
        "halo_bytes_per_round": halo_bytes_per_round,
        "rounds": plan["rounds"],
        "compile_s": round(t_compile, 1),
        "ok": True,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="notc", choices=["notc", "tc"])
    ap.add_argument("--n-steps", type=int, default=40000)
    ap.add_argument("--contracts", type=int, default=256)
    ap.add_argument("--round-depth", type=int, default=50)
    ap.add_argument("--collapse-lanes", type=int, default=0)
    ap.add_argument("--capacity", type=int, default=48)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep-l", default=None,
                    help="comma-separated L values to sweep")
    ap.add_argument("--tag", default="pricing")
    args = ap.parse_args()

    out_dir = RESULTS_DIR / args.tag
    out_dir.mkdir(parents=True, exist_ok=True)
    ls = ([int(x) for x in args.sweep_l.split(",")] if args.sweep_l
          else [args.round_depth])
    for L in ls:
        rec = run_pricing_cell(args.kind, args.n_steps, args.contracts, L,
                               args.collapse_lanes, args.multi_pod,
                               args.capacity)
        mesh_tag = rec["mesh"]
        name = f"{args.kind}_N{args.n_steps}_L{L}_{mesh_tag}.json"
        (out_dir / name).write_text(json.dumps(rec, indent=1))
        print(json.dumps({k: rec[k] for k in
                          ("kind", "n_steps", "round_depth", "rounds",
                           "compile_s")}),
              "coll:", rec["collectives"]["count_by_op"],
              "halo/round:", rec["halo_bytes_per_round"])


if __name__ == "__main__":
    main()
