"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 [--reduced] [--data <mesh-axis>] [--model <mesh-axis>]

On real hardware the mesh axes default to the production 16x16 pod; on
this CPU container pass --data 1 --model 1 (default) and optionally
--reduced for the smoke-sized config.  The loop checkpoints and resumes
automatically (see train/trainer.py for the fault-tolerance contract).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced_config
from ..models.transformer import RunCfg
from ..train.trainer import TrainerConfig, train
from .mesh import make_test_mesh, mesh_rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots"])
    ap.add_argument("--moe-impl", default="dense",
                    choices=["dense", "dispatch"])
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated failure at this step")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    rules = None
    if args.data * args.model > 1:
        mesh = make_test_mesh(args.data, args.model)
        rules = mesh_rules(mesh)
    run = RunCfg(dtype=jnp.float32, remat=args.remat, moe_impl=args.moe_impl)
    tc = TrainerConfig(steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq, n_micro=args.micro,
                       peak_lr=args.lr, ckpt_dir=args.ckpt,
                       simulate_failure_at=args.fail_at)
    out = train(cfg, tc, run, rules)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
