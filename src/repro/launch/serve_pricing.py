"""Continuous-batching pricing service driver.

    PYTHONPATH=src python -m repro.launch.serve_pricing \
        --qps 500 --requests 1000 --deadline-ms 5 --max-batch 64 \
        [--n-steps 16,24] [--tc-fraction 0.0] [--backend jnp] [--seed 0] \
        [--devices W] [--gateway [--replicas N] [--pool thread|process]
                                 [--crash-at K]]

Synthesises a request stream (mixed payoff families, strikes, spots and
tree depths; an optional transaction-cost slice) arriving at ``--qps``,
submits it to :class:`repro.serve.scheduler.PricingService`, and ticks
the deadline loop between arrivals — the smallest real deployment shape:

    while traffic:  submit due arrivals; service.step()   # deadline tick

With ``--gateway`` the same trace goes through the asyncio
:class:`repro.serve.gateway.PricingGateway` instead: ``--replicas N``
worker replicas, a timer-driven deadline flusher (no ``step()`` loop),
and optionally ``--crash-at K`` to kill replica 0 at its ``K``-th chunk
mid-replay and watch the failover metrics (requeues, retries,
restarts).  ``--pool process`` backs each replica with a real spawned
worker process (``serve/procpool.py``) — the crash becomes a genuine
mid-chunk SIGKILL and the respawn a fresh process.

Prints the service metrics (batches, p50/p99 latency, pad waste,
contracts/sec, compile + result-cache counters) at the end.  Tuning
guidance for ``--deadline-ms``/``--max-batch`` lives in
``docs/SERVING.md``; the scheduler-vs-per-request benchmark is
``benchmarks/bench_serve.py``, the gateway availability benchmark
``benchmarks/bench_gateway.py``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..serve.engine import PriceRequest
from ..serve.scheduler import PricingService


def synth_trace(n: int, *, n_steps=(16, 24), tc_fraction: float = 0.0,
                seed: int = 0) -> list:
    """A mixed synthetic trace: put/call/bull_spread x strikes x spots x
    vols x depths, with ``tc_fraction`` of requests under transaction
    costs (those stay on one shallow depth — the RZ engine is the
    expensive path and buckets separately anyway)."""
    rng = np.random.default_rng(seed)
    payoffs = ("put", "call", "bull_spread")
    reqs = []
    for _ in range(n):
        tc = rng.random() < tc_fraction
        reqs.append(PriceRequest(
            s0=float(rng.choice(np.linspace(90.0, 110.0, 9))),
            sigma=float(rng.choice((0.15, 0.2, 0.3))),
            rate=0.1,
            maturity=float(rng.choice((0.25, 0.5))),
            cost_rate=float(rng.choice((0.005, 0.01))) if tc else 0.0,
            payoff=str(rng.choice(payoffs)),
            strike=float(rng.choice((95.0, 100.0, 105.0))),
            n_steps=int(min(n_steps)) if tc else int(rng.choice(n_steps)),
        ))
    return reqs


def drive(service: PricingService, trace, *, qps: float,
          clock=time.monotonic, sleep=time.sleep) -> dict:
    """Submit ``trace`` at ``qps`` (uniform arrivals), ticking the
    deadline loop between arrivals; returns {request id: PriceQuote}."""
    gap = 1.0 / qps if qps > 0 else 0.0
    t0 = clock()
    ids = []
    for i, req in enumerate(trace):
        due = t0 + i * gap
        while clock() < due:
            service.step()
            remaining = due - clock()
            if remaining > 0:
                sleep(min(remaining, service.deadline_s / 2 or remaining))
        ids.append(service.submit(req))
        service.step()
    service.flush()
    return {rid: service.result(rid) for rid in ids}


def drive_gateway(trace, *, replicas: int, crash_at, max_batch: int,
                  deadline_ms: float, capacity: int, backend: str,
                  n_steps: int, restart_s: float = 1.0,
                  pool_kind: str = "thread") -> tuple:
    """Replay ``trace`` through the asyncio gateway; returns
    ({rid: quote}, metrics).  ``crash_at`` injects a replica-0 crash at
    that chunk call (restarted after ``restart_s``); with
    ``pool_kind="process"`` the replicas are spawned worker processes
    and the crash is a real mid-chunk SIGKILL."""
    import asyncio

    from ..serve.gateway import PricingGateway
    from ..serve.procpool import ProcessReplica, warmup_chunk
    from ..serve.replica import FaultyReplica, LocalReplica

    if pool_kind == "process":
        wu = warmup_chunk(n_steps=n_steps, backend=backend,
                          capacity=capacity)

        def respawn(i):
            return ProcessReplica(f"replica-{i}", warmup=wu)

        def factory(i):
            faults = ({int(crash_at): "sigkill"}
                      if crash_at is not None and i == 0 else None)
            return ProcessReplica(f"replica-{i}", warmup=wu, faults=faults)
    else:
        def respawn(i):
            return LocalReplica(name=f"replica-{i}")

        def factory(i):
            if crash_at is not None and i == 0:
                return FaultyReplica(faults={int(crash_at): "crash"},
                                     name="replica-0")
            return LocalReplica(name=f"replica-{i}")
    pool = [factory(i) for i in range(replicas)]

    async def run():
        # replica_factory drives the restart_s respawn path: a crashed
        # worker comes back *healthy* and of the same pool kind
        async with PricingGateway(
                replicas=pool, max_batch=max_batch,
                deadline_ms=deadline_ms, capacity=capacity,
                backend=backend, default_n_steps=n_steps,
                restart_s=restart_s, replica_factory=respawn) as gw:
            rids = [await gw.submit(r) for r in trace]
            quotes = {rid: await gw.result(rid) for rid in rids}
            return quotes, gw.metrics()

    return asyncio.run(run())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--qps", type=float, default=500.0,
                    help="arrival rate; 0 = submit as fast as possible")
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--deadline-ms", type=float, default=5.0)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--n-steps", default="16,24",
                    help="comma-separated tree depths sampled by the trace")
    ap.add_argument("--tc-fraction", type=float, default=0.0,
                    help="fraction of requests under transaction costs "
                         "(the RZ engine is seconds-per-compile on CPU; "
                         "keep small outside TPU runs)")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--capacity", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=None,
                    help="route micro-batches onto a 1-D mesh of this many "
                         "devices, with measured-seconds shard rebalancing "
                         "(see docs/SERVING.md)")
    ap.add_argument("--gateway", action="store_true",
                    help="replay through the asyncio multi-replica gateway "
                         "instead of the cooperative service")
    ap.add_argument("--replicas", type=int, default=2,
                    help="gateway replica count (with --gateway)")
    ap.add_argument("--pool", default="thread",
                    choices=["thread", "process"],
                    help="what backs each gateway replica: in-process "
                         "worker threads, or spawned worker processes "
                         "(per-process jit caches, warmup chunk on "
                         "start, SIGKILL-and-respawn on faults; see "
                         "docs/SERVING.md)")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a replica-0 crash at this chunk call "
                         "(with --gateway; restarted after 1s; with "
                         "--pool=process the crash is a real SIGKILL)")
    args = ap.parse_args()

    depths = tuple(int(x) for x in args.n_steps.split(","))
    trace = synth_trace(args.requests, n_steps=depths,
                        tc_fraction=args.tc_fraction, seed=args.seed)

    if args.gateway:
        t0 = time.perf_counter()
        quotes, m = drive_gateway(
            trace, replicas=args.replicas, crash_at=args.crash_at,
            max_batch=args.max_batch, deadline_ms=args.deadline_ms,
            capacity=args.capacity, backend=args.backend,
            n_steps=depths[0], pool_kind=args.pool)
        wall = time.perf_counter() - t0
        assert m["completed"] == len(trace) and m["failed"] == 0
        print(f"{len(trace)} requests through the gateway, "
              f"{args.replicas} {args.pool} replicas"
              + (f", crash injected at chunk {args.crash_at}"
                 if args.crash_at is not None else ""))
        print(f"  wall            : {wall:8.2f} s "
              f"({len(trace) / wall:9.1f} requests/s end-to-end)")
        print(f"  batches         : {m['batches']:8d} "
              f"(deadline {m['deadline_flushes']} / size "
              f"{m['size_flushes']})")
        print(f"  failover        : crashes={m['replica_crashes']} "
              f"requeues={m['requeues']} retries={m['retries']} "
              f"restarts={m['replica_restarts']}")
        print(f"  healthy replicas: {m['healthy_replicas']:8d}")
        print(f"  latency p50/p99 : {m['p50_latency_ms']:8.2f} / "
              f"{m['p99_latency_ms']:.2f} ms")
        sample, q = trace[0], quotes[min(quotes)]
        print(f"  e.g. {sample.payoff} K={sample.strike:g} "
              f"S0={sample.s0:g}: ask {q.ask:.6f} bid {q.bid:.6f}")
        return

    service = PricingService(
        max_batch=args.max_batch, deadline_ms=args.deadline_ms,
        capacity=args.capacity, backend=args.backend,
        default_n_steps=depths[0], devices=args.devices)

    t0 = time.perf_counter()
    quotes = drive(service, trace, qps=args.qps)
    wall = time.perf_counter() - t0

    m = service.metrics()
    assert m["completed"] == len(trace)
    print(f"{len(trace)} requests @ {args.qps:g} qps, "
          f"deadline {args.deadline_ms:g} ms, max batch {args.max_batch}, "
          f"backend {args.backend}")
    print(f"  wall            : {wall:8.2f} s "
          f"({len(trace) / wall:9.1f} requests/s end-to-end)")
    print(f"  batches         : {m['batches']:8d} "
          f"(engines {m['engine_batches']})")
    print(f"  pad waste       : {m['pad_waste']:8.1%}")
    print(f"  result cache    : {m['cache_hits']:8d} hits")
    print(f"  compile cache   : {m['compile_hits']:8d} hits "
          f"/ {m['compile_misses']} misses")
    if args.devices:
        print(f"  shard batches   : {m['shard_batches']:8d} "
              f"(rebalances {m['rebalances']})")
    print(f"  engine time     : {m['engine_seconds']:8.2f} s "
          f"({m['contracts_per_sec']:9.1f} contracts/s in-engine)")
    print(f"  latency p50/p99 : {m['p50_latency_ms']:8.2f} / "
          f"{m['p99_latency_ms']:.2f} ms")
    sample = trace[0]
    q = quotes[min(quotes)]
    print(f"  e.g. {sample.payoff} K={sample.strike:g} "
          f"S0={sample.s0:g}: ask {q.ask:.6f} bid {q.bid:.6f}")


if __name__ == "__main__":
    main()
