"""seamless-m4t-medium — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf].  The audio frontend is a STUB per assignment:
input_specs() provides precomputed frame embeddings."""
from .base import ModelConfig, register


@register("seamless-m4t-medium")
def config() -> ModelConfig:
    # vocab: published 256206, padded to 256224 (multiple of 16) so the
    # embedding / lm-head shard over the 16-way tensor-parallel axis —
    # standard embedding padding; without it the one-hot/logit buffers
    # replicate across TP and the train cell exceeds the v5e HBM budget
    # (EXPERIMENTS.md §Dry-run).  The 18 pad ids are never emitted as
    # targets by the data pipeline.
    return ModelConfig(
        name="seamless-m4t-medium", n_layers=12, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab=256224, head_dim=64,
        block_pattern=("attn",), mlp_kind="gelu", n_encoder_layers=12,
        frontend="audio_stub",
        notes="enc-dec; MHA (kv=16); audio frontend stubbed to frame "
              "embeddings; vocab padded 256206->256224 for 16-way TP.")
