"""recurrentgemma-2b — RG-LRU + local attention 1:2 hybrid
[arXiv:2402.19427; hf]."""
from .base import ModelConfig, RecurrentConfig, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", n_layers=26, d_model=2560, n_heads=10,
        n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
        # Griffin pattern: two RG-LRU blocks then one local-attention block
        block_pattern=("rglru", "rglru", "local"),
        mlp_kind="gelu",  # GeGLU in the paper; gated gelu here
        recurrent=RecurrentConfig(lru_width=2560, d_conv=4),
        local_window=2048,
        notes="sub-quadratic: linear recurrence + windowed attention; "
              "long_500k runs.")
