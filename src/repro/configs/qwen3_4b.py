"""qwen3-4b — dense GQA transformer with qk_norm [hf:Qwen/Qwen3-8B; hf]."""
from .base import ModelConfig, register


@register("qwen3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", n_layers=36, d_model=2560, n_heads=32,
        n_kv_heads=8, d_ff=9728, vocab=151936, head_dim=128,
        block_pattern=("attn",), mlp_kind="swiglu", qk_norm=True,
        rope_theta=1_000_000.0,
        notes="qk_norm per head; GQA kv=8.")
