"""dbrx-132b — fine-grained MoE 16 experts top-4 [hf:databricks/dbrx-base;
unverified]."""
from .base import ModelConfig, MoEConfig, register


@register("dbrx-132b")
def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab=100352, head_dim=128,
        block_pattern=("attn",), mlp_kind="swiglu",
        moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
        rope_theta=500_000.0,
        notes="16 experts top-4, fine-grained MoE; GQA kv=8.")
