"""Architecture configuration registry (one module per assigned arch)."""
from .base import (  # noqa: F401
    ModelConfig, MoEConfig, RecurrentConfig, SSMConfig, ShapeConfig, SHAPES,
    get_config, list_archs, reduced_config, register,
)

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from . import (  # noqa: F401
        internlm2_1_8b, qwen3_4b, qwen3_0_6b, qwen2_5_14b,
        llama4_scout_17b_a16e, dbrx_132b, recurrentgemma_2b,
        seamless_m4t_medium, falcon_mamba_7b, chameleon_34b, pricing,
    )
    _LOADED = True
