"""Configs for the paper's own workloads (lattice pricing).

These are not LM architectures; they parameterise the lattice engines and
the production pricing-service meshes.  Kept in the same registry module
tree so launchers can list every runnable config in one place.

``platform``/``interpret``/``dtype`` select the execution policy
(``repro.core.platform``): ``platform=None`` auto-detects; ``interpret``
and ``dtype`` ``None`` defer to that platform's policy (interpret +
float64 on CPU, compiled Pallas + float32 on GPU/TPU).  The module
deliberately imports no jax so configs stay listable without touching an
accelerator; :meth:`PricingConfig.resolve_execution` does the lookup.

:class:`ExecutionConfig` is the consolidated execution surface of the
public pricing API (``repro.api.price_grid``/``price_flat``, the
serving layer's ``GridRequest``/``PricingService``/``PricingGateway``):
one frozen dataclass holding every knob that selects *how* a price is
computed — engine, backend, platform/interpret, device count, MC
statics — rather than *what* is priced.  Every field defaults to
``None`` = "resolve from policy"; :meth:`ExecutionConfig.resolved`
fills the defaults through the same platform lookup as
:meth:`PricingConfig.resolve_execution`.
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """How a pricing call executes (the consolidated kwarg surface).

    ``None`` anywhere means "resolve the default": ``engine`` routes by
    contract shape (``"auto"``), ``backend`` falls back to ``"jnp"``,
    ``platform``/``interpret`` resolve through the platform policy of
    ``core/platform.py``, ``devices`` stays single-device, and the MC
    statics take the lsmc engine's defaults.  Frozen and hashable, so a
    config can key caches and cross process boundaries; it carries no
    live jax objects (sharding is the ``devices`` *count* — each
    executor resolves its own mesh, see ``serve/core.py``).
    """
    engine: Optional[str] = None       # "auto" | "notc" | "rz" | "lsmc"
    backend: Optional[str] = None      # "jnp" | "pallas"
    platform: Optional[str] = None     # "cpu" | "gpu" | "tpu"
    interpret: Optional[bool] = None   # Pallas interpret vs compiled
    devices: Optional[int] = None      # 1-D mesh width (count, not a mesh)
    n_paths: Optional[int] = None      # lsmc paths
    mc_seed: Optional[int] = None      # lsmc PRNG seed
    basis: Optional[str] = None        # lsmc regression basis
    degree: Optional[int] = None       # ... and its degree
    antithetic: Optional[bool] = None  # lsmc antithetic pairing

    def set_fields(self) -> tuple:
        """Names of the fields explicitly set (non-``None``)."""
        return tuple(f.name for f in dataclasses.fields(self)
                     if getattr(self, f.name) is not None)

    def resolved(self) -> "ExecutionConfig":
        """Fill every ``None`` with its default.

        ``platform``/``interpret`` resolve through the same
        ``core/platform.py`` policy lookup as
        :meth:`PricingConfig.resolve_execution` (lazy import — building
        configs never touches jax; resolving them does).  ``engine``
        stays ``"auto"`` — routing needs the contract, not the config.
        """
        from ..core import platform as plat
        p = self.platform or plat.active_platform()
        return dataclasses.replace(
            self,
            engine=self.engine or "auto",
            backend=self.backend or "jnp",
            platform=p,
            interpret=plat.resolve_interpret(self.interpret, p),
            n_paths=4096 if self.n_paths is None else int(self.n_paths),
            mc_seed=0 if self.mc_seed is None else int(self.mc_seed),
            basis=self.basis or "poly",
            degree=3 if self.degree is None else int(self.degree),
            antithetic=(True if self.antithetic is None
                        else bool(self.antithetic)))


@dataclasses.dataclass(frozen=True)
class PricingConfig:
    name: str
    n_steps: int
    capacity: int = 48           # PWL knots per node
    round_depth: int = 8         # L — levels per halo round
    collapse_lanes: int = 0      # 0 = auto
    contracts: int = 256         # batch of contracts (data axis)
    cost_rate: float = 0.005
    payoff: str = "put"          # put | call | bull_spread
    strike: float = 100.0
    s0: float = 100.0
    sigma: float = 0.2
    rate: float = 0.1
    maturity: float = 0.25
    # execution policy (None = resolve from core/platform.py at run time)
    platform: Optional[str] = None   # "cpu" | "gpu" | "tpu"
    interpret: Optional[bool] = None  # Pallas interpret vs compiled
    dtype: Optional[str] = None      # "float64" | "float32"

    def resolve_execution(self) -> dict:
        """Resolve the execution knobs against the platform policy.

        Returns ``{"platform", "interpret", "dtype"}`` with every
        ``None`` replaced by the active policy's value — the dict the
        launchers pass to ``price_grid``/``price_flat``.
        """
        from ..core import platform as plat
        p = self.platform or plat.active_platform()
        interpret = plat.resolve_interpret(self.interpret, p)
        dtype = self.dtype or plat.default_dtype(p).name
        return {"platform": p, "interpret": interpret, "dtype": dtype}

    def execution(self) -> ExecutionConfig:
        """This config's execution knobs as a resolved
        :class:`ExecutionConfig` (what ``price_grid(execution=...)``
        takes)."""
        ex = self.resolve_execution()
        return ExecutionConfig(platform=ex["platform"],
                               interpret=ex["interpret"]).resolved()


PAPER_PUT = PricingConfig(name="paper-put-tc", n_steps=1500, round_depth=5)
PAPER_BULL = PricingConfig(name="paper-bull-tc", n_steps=1500, round_depth=5,
                           payoff="bull_spread", cost_rate=0.01)
PAPER_NOTC = PricingConfig(name="paper-put-notc", n_steps=40000,
                           round_depth=50, cost_rate=0.0, sigma=0.3,
                           rate=0.06, maturity=3.0)
