"""Configs for the paper's own workloads (lattice pricing).

These are not LM architectures; they parameterise the lattice engines and
the production pricing-service meshes.  Kept in the same registry module
tree so launchers can list every runnable config in one place.

``platform``/``interpret``/``dtype`` select the execution policy
(``repro.core.platform``): ``platform=None`` auto-detects; ``interpret``
and ``dtype`` ``None`` defer to that platform's policy (interpret +
float64 on CPU, compiled Pallas + float32 on GPU/TPU).  The module
deliberately imports no jax so configs stay listable without touching an
accelerator; :meth:`PricingConfig.resolve_execution` does the lookup.
"""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class PricingConfig:
    name: str
    n_steps: int
    capacity: int = 48           # PWL knots per node
    round_depth: int = 8         # L — levels per halo round
    collapse_lanes: int = 0      # 0 = auto
    contracts: int = 256         # batch of contracts (data axis)
    cost_rate: float = 0.005
    payoff: str = "put"          # put | call | bull_spread
    strike: float = 100.0
    s0: float = 100.0
    sigma: float = 0.2
    rate: float = 0.1
    maturity: float = 0.25
    # execution policy (None = resolve from core/platform.py at run time)
    platform: Optional[str] = None   # "cpu" | "gpu" | "tpu"
    interpret: Optional[bool] = None  # Pallas interpret vs compiled
    dtype: Optional[str] = None      # "float64" | "float32"

    def resolve_execution(self) -> dict:
        """Resolve the execution knobs against the platform policy.

        Returns ``{"platform", "interpret", "dtype"}`` with every
        ``None`` replaced by the active policy's value — the dict the
        launchers pass to ``price_grid``/``price_flat``.
        """
        from ..core import platform as plat
        p = self.platform or plat.active_platform()
        interpret = plat.resolve_interpret(self.interpret, p)
        dtype = self.dtype or plat.default_dtype(p).name
        return {"platform": p, "interpret": interpret, "dtype": dtype}


PAPER_PUT = PricingConfig(name="paper-put-tc", n_steps=1500, round_depth=5)
PAPER_BULL = PricingConfig(name="paper-bull-tc", n_steps=1500, round_depth=5,
                           payoff="bull_spread", cost_rate=0.01)
PAPER_NOTC = PricingConfig(name="paper-put-notc", n_steps=40000,
                           round_depth=50, cost_rate=0.0, sigma=0.3,
                           rate=0.06, maturity=3.0)
