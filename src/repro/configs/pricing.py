"""Configs for the paper's own workloads (lattice pricing).

These are not LM architectures; they parameterise the lattice engines and
the production pricing-service meshes.  Kept in the same registry module
tree so launchers can list every runnable config in one place.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PricingConfig:
    name: str
    n_steps: int
    capacity: int = 48           # PWL knots per node
    round_depth: int = 8         # L — levels per halo round
    collapse_lanes: int = 0      # 0 = auto
    contracts: int = 256         # batch of contracts (data axis)
    cost_rate: float = 0.005
    payoff: str = "put"          # put | call | bull_spread
    strike: float = 100.0
    s0: float = 100.0
    sigma: float = 0.2
    rate: float = 0.1
    maturity: float = 0.25


PAPER_PUT = PricingConfig(name="paper-put-tc", n_steps=1500, round_depth=5)
PAPER_BULL = PricingConfig(name="paper-bull-tc", n_steps=1500, round_depth=5,
                           payoff="bull_spread", cost_rate=0.01)
PAPER_NOTC = PricingConfig(name="paper-put-notc", n_steps=40000,
                           round_depth=50, cost_rate=0.0, sigma=0.3,
                           rate=0.06, maturity=3.0)
