"""falcon-mamba-7b — attention-free mamba-1 SSM [arXiv:2410.05355;
unverified]."""
from .base import ModelConfig, SSMConfig, register


@register("falcon-mamba-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", n_layers=64, d_model=4096, n_heads=1,
        n_kv_heads=1, d_ff=0, vocab=65024,
        block_pattern=("mamba",),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        notes="pure SSM; attention-free; long_500k runs.")
