"""chameleon-34b — early-fusion VQ-token VLM backbone [arXiv:2405.09818;
unverified].  VQ image tokeniser is a STUB: tokens arrive pre-quantised in
the shared vocabulary."""
from .base import ModelConfig, register


@register("chameleon-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", n_layers=48, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=22016, vocab=65536, head_dim=128,
        block_pattern=("attn",), mlp_kind="swiglu", qk_norm=True,
        frontend="vq_stub",
        notes="early fusion: image VQ tokens share the text vocab; qk-norm "
              "(chameleon uses it for training stability).")
