"""internlm2-1.8b — dense GQA transformer [arXiv:2403.17297; hf]."""
from .base import ModelConfig, register


@register("internlm2-1.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=8192, vocab=92544, head_dim=128,
        block_pattern=("attn",), mlp_kind="swiglu", rope_theta=1_000_000.0,
        notes="GQA kv=8; SwiGLU; llama-style dense decoder.")
