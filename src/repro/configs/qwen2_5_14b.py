"""qwen2.5-14b — dense GQA transformer, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from .base import ModelConfig, register


@register("qwen2.5-14b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=13824, vocab=152064, head_dim=128,
        block_pattern=("attn",), mlp_kind="swiglu", qkv_bias=True,
        rope_theta=1_000_000.0,
        notes="GQA kv=8 with QKV bias.")
