"""llama4-scout-17b-16e — MoE 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from .base import ModelConfig, MoEConfig, register


@register("llama4-scout-17b-a16e")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
        block_pattern=("attn",), mlp_kind="swiglu",
        moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                      shared_expert=True),
        rope_theta=500_000.0,
        notes="MoE top-1 of 16 routed + shared expert (llama4 style); "
              "early-fusion multimodal — text backbone per assignment.")
