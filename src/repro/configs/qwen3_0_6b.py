"""qwen3-0.6b — dense GQA transformer with qk_norm [hf:Qwen/Qwen3-8B; hf]."""
from .base import ModelConfig, register


@register("qwen3-0.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16,
        n_kv_heads=8, d_ff=3072, vocab=151936, head_dim=128,
        block_pattern=("attn",), mlp_kind="swiglu", qk_norm=True,
        rope_theta=1_000_000.0,
        notes="qk_norm per head; GQA kv=8.")
