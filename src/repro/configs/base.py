"""Model / run configuration system.

``ModelConfig`` is the single source of truth for an architecture; every
assigned architecture gets one module under :mod:`repro.configs` that
builds its exact published configuration.  ``ShapeConfig`` captures the
assigned input-shape cells (train_4k / prefill_32k / decode_32k /
long_500k).  The registry maps ``--arch`` ids to config factories.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

__all__ = [
    "MoEConfig", "SSMConfig", "RecurrentConfig", "ModelConfig",
    "ShapeConfig", "SHAPES", "register", "get_config", "list_archs",
    "reduced_config",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = False       # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM block parameters."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    dt_rank: Optional[int] = None     # default ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (recurrentgemma) block parameters."""
    lru_width: Optional[int] = None   # default d_model
    d_conv: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    # block pattern: sequence of block kinds, cycled over layers.
    #   "attn"     full-attention transformer block
    #   "local"    sliding-window attention block
    #   "rglru"    RG-LRU recurrent block
    #   "mamba"    mamba-1 SSM block (attention-free)
    block_pattern: Tuple[str, ...] = ("attn",)
    # feed-forward: "swiglu" | "gelu";  MoE replaces the FFN when set
    mlp_kind: str = "swiglu"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    local_window: int = 2048
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # encoder-decoder (seamless-m4t): encoder layer count; 0 = decoder-only
    n_encoder_layers: int = 0
    # modality frontend: "text" | "audio_stub" | "vq_stub"
    #   stubs mean input_specs() provides precomputed frame/patch embeddings
    frontend: str = "text"
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def attention_free(self) -> bool:
        return all(k == "mamba" for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no block attends over the full sequence (long_500k ok)."""
        return all(k in ("mamba", "rglru", "local") for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), used for roofline
        MODEL_FLOPS = 6 N D."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        total = v * d                                 # embedding
        if not self.tie_embeddings:
            total += v * d                            # lm head
        if self.n_encoder_layers:
            total += v * d                            # decoder embedding reuse
        n_all = self.n_layers + self.n_encoder_layers
        for layer in range(n_all):
            kind = self.block_kind(layer % self.n_layers)
            if kind in ("attn", "local"):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
            elif kind == "rglru":
                w = (self.recurrent.lru_width if self.recurrent and
                     self.recurrent.lru_width else d)
                total += 2 * d * w + w * d + 3 * w    # in x2, out, gates
            elif kind == "mamba":
                di = self.ssm.expand * d
                ds = self.ssm.d_state
                dtr = self.ssm.dt_rank or -(-d // 16)
                total += d * 2 * di + di * self.ssm.d_conv
                total += di * (dtr + 2 * ds) + dtr * di + di * ds + di
                total += di * d
            if kind != "mamba":
                if self.moe is not None:
                    e = self.moe
                    total += d * e.num_experts        # router
                    total += e.num_experts * 3 * d * e.d_ff_expert
                    if e.shared_expert:
                        total += 3 * d * self.d_ff
                elif self.d_ff:
                    mult = 3 if self.mlp_kind == "swiglu" else 2
                    total += mult * d * self.d_ff
            total += 2 * d                            # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        full = self.param_count()
        expert_p = e.num_experts * 3 * self.d_model * e.d_ff_expert
        active_p = e.top_k * 3 * self.d_model * e.d_ff_expert
        n_moe_layers = self.n_layers
        return full - n_moe_layers * (expert_p - active_p)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        from . import _load_all  # lazy import of config modules
        _load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> Sequence[str]:
    from . import _load_all
    _load_all()
    return sorted(_REGISTRY)


def reduced_config(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
                   n_heads: int = 4, vocab: int = 512) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving its *family* (block
    pattern, MoE/SSM kinds, qk_norm/bias flags)."""
    kv = max(1, min(cfg.n_kv_heads, n_heads // 2)) if cfg.n_kv_heads > 1 else 1
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, num_experts=4,
                                  top_k=min(cfg.moe.top_k, 2), d_ff_expert=96)
    ssm = dataclasses.replace(cfg.ssm, d_state=8) if cfg.ssm else None
    rec = dataclasses.replace(cfg.recurrent, lru_width=d_model) if cfg.recurrent else None
    n_enc = 2 if cfg.n_encoder_layers else 0
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=kv, head_dim=d_model // n_heads,
        d_ff=128 if cfg.d_ff else 0, vocab=vocab, moe=moe, ssm=ssm,
        recurrent=rec, n_encoder_layers=n_enc, local_window=32)
