"""Version compatibility shims for the pinned JAX toolchain.

The repo targets the ``jax.shard_map`` API (top-level export, ``check_vma``
keyword, ``axis_names`` for partial-manual meshes).  The baked-in container
toolchain ships jax 0.4.37, where the same functionality lives at
``jax.experimental.shard_map.shard_map`` with the older ``check_rep`` /
``auto`` spelling.  ``shard_map`` below presents the new surface on either
version so engine code is written once against the modern API.
"""
from __future__ import annotations

from typing import Any

import jax

__all__ = ["shard_map", "axis_size"]


def axis_size(axis_name):
    """``jax.lax.axis_size`` (static int), also on jax 0.4.x.

    On 0.4.x a ``psum`` of the literal 1 constant-folds to the mesh axis
    size as a plain Python int, which is what callers need for static
    loop bounds and permutation tables.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names: Any = None):
    """``jax.shard_map`` facade that also runs on jax 0.4.x.

    ``axis_names`` is the set of *manual* mesh axes (all axes if None), as
    in the modern API; on 0.4.x it is translated to the complementary
    ``auto`` frozenset.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
