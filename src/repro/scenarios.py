"""Scenario-grid pricing: batch whole grids of contracts through the engines.

The paper prices one American option per run; its parallelism is *within*
a contract (blocks/regions/rounds over the tree).  This module adds the
orthogonal, JAX-shaped axis: a **scenario grid** — the cartesian product
(or an explicit list) of market/contract parameters

    spot s0 x volatility sigma x rate x maturity x transaction-cost
    rate lambda x payoff family x strike(s)

is flattened into struct-of-arrays form and pushed through the lattice
engines in ONE compiled call (``vmap`` over contracts), optionally with
central-difference Greeks (delta, vega) fused into the same call.

Mixed payoff families batch together because the payoff is carried as
*data*, not code: every supported contract is an instance of the
4-parameter family

    xi(s)   = alpha * K1 + w1 * (s - K1)^+ + w2 * (s - K2)^+
    zeta(s) = zeta                                      (constant)

==============  =====  =====  ====  ====
payoff          alpha  zeta    w1    w2
==============  =====  =====  ====  ====
put(K1)           +1    -1      0     0
call(K1)          -1    +1      0     0
bull_spread       0      0     +1    -1
==============  =====  =====  ====  ====

Two engines are exposed:

  * ``price_grid_rz``    — Roux–Zastawniak ask/bid under proportional
    transaction costs (``core/rz.py`` / ``core/pwl.py``); exact for
    lambda = 0 too (ask = bid = the friction-free price).
  * ``price_grid_notc``  — friction-free binomial price; ``backend="jnp"``
    is the vectorised ``core/notc.py`` recursion, ``backend="pallas"``
    routes through the blocked lattice kernel
    (``kernels/binomial_step.py::lattice_round_param``).

Oracles: ``core/rz_ref.py`` (sequential PWL recursion) and
``core/notc.py::price_notc_np`` — see ``tests/test_scenarios.py``.

The tree depth ``n_steps`` is a *static* (shape-determining) parameter:
one grid = one compiled program.  ``repro.api.price_grid`` accepts a list
of step counts and prices one grid per distinct value.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .core.partition import (ShardPlan, plan_shards, scenario_costs,
                             shard_layout)
from .core.payoff import param_payoff
from .core.platform import resolve_interpret
from .core.rz import RZ_BACKENDS, rz_backward, rz_backward_pallas

__all__ = ["ScenarioGrid", "GridResult", "ShardExecInfo",
           "price_grid_rz", "price_grid_notc", "price_grid_lsmc",
           "route_engine", "PAYOFF_FAMILIES", "payoff_params"]

PAYOFF_FAMILIES = ("put", "call", "bull_spread")

# finite-difference bump sizes (relative in s0, absolute in sigma)
_DELTA_REL_BUMP = 1e-4
_VEGA_BUMP = 1e-4


def payoff_params(kind: str):
    """(alpha, zeta, w1, w2) of the 4-parameter payoff family.

    The strikes K1/K2 are threaded separately (they scale with the
    scenario); these four numbers only select the family.
    """
    if kind == "put":
        return (1.0, -1.0, 0.0, 0.0)
    if kind == "call":
        return (-1.0, 1.0, 0.0, 0.0)
    if kind == "bull_spread":
        return (0.0, 0.0, 1.0, -1.0)
    raise ValueError(f"unknown payoff family {kind!r}; "
                     f"supported: {PAYOFF_FAMILIES}")


@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """A flat SoA batch of pricing scenarios sharing one tree depth.

    All per-scenario fields are float64 numpy arrays of equal length
    ``n_scenarios``; ``shape`` is the logical (cartesian) grid shape the
    result surfaces are reshaped to (``(n_scenarios,)`` for explicit
    grids).  Build with :meth:`cartesian` or :meth:`explicit`.

    ``n_assets`` and ``exercise_steps`` are grid-wide contract-shape
    knobs (static like ``n_steps``): ``n_assets > 1`` means each row is
    a basket of that many i.i.d. GBM underlyings sharing the row's
    parameters, and ``exercise_steps`` (a tuple of lattice step indices,
    terminal step included) restricts exercise to a Bermudan schedule.
    ``exercise_steps=None`` means American.  Either departure from the
    1-D American default routes the grid to the ``lsmc`` engine — the
    lattice engines reject it (see :func:`route_engine`).
    """
    s0: np.ndarray
    sigma: np.ndarray
    rate: np.ndarray
    maturity: np.ndarray
    cost_rate: np.ndarray
    strike: np.ndarray
    strike2: np.ndarray
    payoff: tuple            # per-scenario family name, len n_scenarios
    n_steps: int
    shape: tuple             # logical grid shape, prod == n_scenarios
    axes: tuple = ()         # (name, values) pairs for cartesian grids
    n_assets: int = 1        # basket size (1 = the lattice engines' model)
    exercise_steps: Optional[tuple] = None   # Bermudan schedule, None=American

    def __post_init__(self):
        if self.exercise_steps is not None:
            from .core.lsmc import exercise_schedule
            object.__setattr__(self, "exercise_steps", exercise_schedule(
                self.n_steps, self.exercise_steps))
        if int(self.n_assets) < 1:
            raise ValueError(f"need n_assets >= 1, got {self.n_assets}")

    @property
    def n_scenarios(self) -> int:
        return self.s0.shape[0]

    def payoff_param_arrays(self):
        """(alpha, zeta, w1, w2) as float64 arrays over scenarios."""
        by_kind = {k: payoff_params(k) for k in set(self.payoff)}
        p = np.asarray([by_kind[k] for k in self.payoff], dtype=np.float64)
        return p[:, 0], p[:, 1], p[:, 2], p[:, 3]

    # ----------------------------------------------------------------- #
    @classmethod
    def cartesian(cls, *, s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                  cost_rate=0.0, payoff="put", strike=100.0,
                  strike2=None, n_steps: int = 100, n_assets: int = 1,
                  exercise_steps=None) -> "ScenarioGrid":
        """Cartesian product of the given axes (scalars = length-1 axes).

        ``payoff`` entries are family names from ``PAYOFF_FAMILIES``;
        ``strike2`` (second strike of ``bull_spread``) defaults to
        ``strike + 10``.  ``n_assets``/``exercise_steps`` are grid-wide,
        not axes.
        """
        def ax(v, name):
            if isinstance(v, str):
                v = (v,)
            arr = tuple(np.atleast_1d(v).tolist())
            return (name, arr)

        axes = (ax(s0, "s0"), ax(sigma, "sigma"), ax(rate, "rate"),
                ax(maturity, "maturity"), ax(cost_rate, "cost_rate"),
                ax(payoff, "payoff"), ax(strike, "strike"))
        shape = tuple(len(vals) for _, vals in axes)
        rows = list(itertools.product(*(vals for _, vals in axes)))
        cols = {name: [r[i] for r in rows]
                for i, (name, _) in enumerate(axes)}
        k1 = np.asarray(cols["strike"], np.float64)
        if strike2 is None:
            k2 = k1 + 10.0
        else:
            k2 = np.broadcast_to(np.asarray(strike2, np.float64),
                                 k1.shape).copy()
        f64 = lambda n: np.asarray(cols[n], np.float64)
        return cls(s0=f64("s0"), sigma=f64("sigma"), rate=f64("rate"),
                   maturity=f64("maturity"), cost_rate=f64("cost_rate"),
                   strike=k1, strike2=k2, payoff=tuple(cols["payoff"]),
                   n_steps=int(n_steps), shape=shape, axes=axes,
                   n_assets=int(n_assets), exercise_steps=exercise_steps)

    @classmethod
    def explicit(cls, *, s0, sigma, rate, maturity, cost_rate=0.0,
                 payoff="put", strike=100.0, strike2=None,
                 n_steps: int = 100, n_assets: int = 1,
                 exercise_steps=None) -> "ScenarioGrid":
        """Element-wise scenario list; array arguments broadcast together."""
        arrs = [np.atleast_1d(np.asarray(v, np.float64))
                for v in (s0, sigma, rate, maturity, cost_rate, strike)]
        n = max(a.shape[0] for a in arrs)
        s0a, siga, ra, ma, ka, k1 = (np.broadcast_to(a, (n,)) for a in arrs)
        if isinstance(payoff, str):
            payoff = (payoff,) * n
        if len(payoff) != n:
            raise ValueError(f"payoff has {len(payoff)} entries, "
                             f"expected {n}")
        k2 = (k1 + 10.0 if strike2 is None else
              np.broadcast_to(np.asarray(strike2, np.float64), (n,)))
        return cls(s0=s0a.copy(), sigma=siga.copy(), rate=ra.copy(),
                   maturity=ma.copy(), cost_rate=ka.copy(), strike=k1.copy(),
                   strike2=np.asarray(k2, np.float64).copy(),
                   payoff=tuple(payoff), n_steps=int(n_steps), shape=(n,),
                   n_assets=int(n_assets), exercise_steps=exercise_steps)

    def pad_to(self, to: int) -> "ScenarioGrid":
        """Flat copy padded to ``to`` scenarios by repeating the last row.

        The serving layer pads micro-batches up to a small set of bucket
        sizes so a stream of differently-sized batches hits a handful of
        compiled programs; the padded grid is flat (``shape == (to,)``) and
        callers slice results back to the first ``n_scenarios`` rows.
        Repeating a real row keeps the pad lanes numerically benign (no
        fresh PWL knot patterns, no overflow surprises).
        """
        n = self.n_scenarios
        if to < n:
            raise ValueError(f"pad_to({to}) below batch size {n}")
        if to == n and self.shape == (n,):
            return self
        pad = to - n
        rep = lambda a: np.concatenate([a, np.repeat(a[-1:], pad)])
        return ScenarioGrid(
            s0=rep(self.s0), sigma=rep(self.sigma), rate=rep(self.rate),
            maturity=rep(self.maturity), cost_rate=rep(self.cost_rate),
            strike=rep(self.strike), strike2=rep(self.strike2),
            payoff=self.payoff + (self.payoff[-1],) * pad,
            n_steps=self.n_steps, shape=(to,),
            n_assets=self.n_assets, exercise_steps=self.exercise_steps)


@dataclasses.dataclass(frozen=True)
class ShardExecInfo:
    """How a grid call was laid out over (and measured on) a device mesh.

    ``plan`` is the :class:`~repro.core.partition.ShardPlan` the call
    ran under; ``simulated`` is True when no real mesh was available and
    the identical layout executed on the local device (bit-equal
    results; see ``resolve_grid_mesh``).  ``per_shard_pieces`` is the
    *measured* peak PWL knot count of each shard's rows (all zero on the
    friction-free path) and ``measured_work`` the cost model re-evaluated
    with those measured pieces — the signal the serving layer's
    rebalance hook feeds back into the next plan.
    """
    plan: ShardPlan
    mesh_shape: tuple
    simulated: bool
    per_shard_pieces: tuple
    per_shard_rows: tuple
    measured_work: tuple


@dataclasses.dataclass
class GridResult:
    """Ask/bid surfaces (and optional Greeks) over a scenario grid.

    All arrays have ``grid.shape``.  For the friction-free engine
    ask == bid == the binomial price (``price`` is an alias for ``ask``).
    Greeks are central finite differences fused into the same compiled
    call: ``delta_* = dP/ds0``, ``vega_* = dP/dsigma``.  ``shard_info``
    is set when the call ran over a device mesh (or its single-device
    simulation).

    ``max_pieces`` is the batch-wide peak PWL knot count (the scalar the
    OverflowError check reduces to); ``row_pieces`` is the pre-reduction
    *per-scenario* peak (shape ``grid.shape``, all zeros on the
    friction-free path).  Rows are independent lanes, so a scenario's
    ``row_pieces`` entry is exactly the ``max_pieces`` it would report
    priced alone — what lets the serving layer stamp each quote with its
    own count and lets streaming requotes reproduce a full reprice's
    ``max_pieces`` without repricing untouched rows.

    ``engine`` records which engine produced the result; ``stderr`` is
    the per-scenario Monte Carlo standard error (``lsmc`` only, None
    from the deterministic lattice engines).
    """
    grid: ScenarioGrid
    ask: np.ndarray
    bid: np.ndarray
    max_pieces: int = 0
    delta_ask: Optional[np.ndarray] = None
    delta_bid: Optional[np.ndarray] = None
    vega_ask: Optional[np.ndarray] = None
    vega_bid: Optional[np.ndarray] = None
    shard_info: Optional[ShardExecInfo] = None
    row_pieces: Optional[np.ndarray] = None
    stderr: Optional[np.ndarray] = None
    engine: Optional[str] = None

    @property
    def price(self) -> np.ndarray:
        return self.ask

    @property
    def spread(self) -> np.ndarray:
        return self.ask - self.bid


# PayoffProcess whose xi/zeta close over traced per-scenario params —
# now the shared core/payoff.py::param_payoff (kept under the old name).
_param_payoff = param_payoff


def route_engine(*, any_tc: bool, n_assets: int = 1,
                 exercise_steps=None) -> str:
    """The ``engine="auto"`` routing rule — single source of truth.

    Contract *shape* decides first: a basket (``n_assets > 1``) or an
    explicit Bermudan schedule is outside the lattice engines' domain
    and must go to ``lsmc``.  Otherwise the cost rate decides between
    the two lattice engines exactly as before this engine existed:
    ``rz`` when any row carries transaction costs, else ``notc``.  Used
    by ``api.price_grid``, the serving bucket router
    (``serve/core.py::SchedulerCore.submit``) and ``PricingService`` —
    all three dispatch through this one function.
    """
    if int(n_assets) > 1 or exercise_steps is not None:
        return "lsmc"
    return "rz" if any_tc else "notc"


def _require_lattice(grid: ScenarioGrid, engine: str):
    """Lattice engines only price 1-D American contracts — fail loudly
    (not wrongly) on a grid shaped for the MC engine."""
    if grid.n_assets > 1 or grid.exercise_steps is not None:
        raise ValueError(
            f"engine {engine!r} prices single-asset American contracts "
            f"only (got n_assets={grid.n_assets}, "
            f"exercise_steps={grid.exercise_steps!r}); use the 'lsmc' "
            "engine (price_grid_lsmc) for baskets/Bermudan schedules")


# --------------------------------------------------------------------- #
# Roux–Zastawniak grid engine (transaction costs; exact at lambda = 0)
# --------------------------------------------------------------------- #
def _rz_rows(s0, sigma, rate, maturity, k, alpha, zeta, w1, w2, k1, k2,
             *, n_steps: int, capacity: int):
    """Flat-batch RZ kernel: equal-length row arrays in, rows out.

    The shardable unit — the sharded path wraps exactly this function in
    ``shard_map`` (each device prices its slice of rows), the single
    path jits it directly.
    """
    def one(s0_, sig_, r_, t_, k_, al_, ze_, w1_, w2_, k1_, k2_):
        pay = _param_payoff(al_, ze_, w1_, w2_, k1_, k2_)
        return rz_backward(s0_, sig_, r_, t_, k_, n_steps=n_steps,
                           capacity=capacity, payoff=pay)
    return jax.vmap(one)(s0, sigma, rate, maturity, k,
                         alpha, zeta, w1, w2, k1, k2)


_rz_grid_jit = partial(jax.jit, static_argnames=("n_steps", "capacity"))(
    _rz_rows)


def _rz_rows_pallas(s0, sigma, rate, maturity, k, alpha, zeta, w1, w2, k1, k2,
                    *, n_steps: int, capacity: int, levels, block,
                    interpret: bool):
    def one(s0_, sig_, r_, t_, k_, al_, ze_, w1_, w2_, k1_, k2_):
        pay = _param_payoff(al_, ze_, w1_, w2_, k1_, k2_)
        return rz_backward_pallas(s0_, sig_, r_, t_, k_, n_steps=n_steps,
                                  capacity=capacity, payoff=pay,
                                  levels=levels, block=block,
                                  interpret=interpret)
    return jax.vmap(one)(s0, sigma, rate, maturity, k,
                         alpha, zeta, w1, w2, k1, k2)


_rz_grid_pallas = partial(jax.jit, static_argnames=(
    "n_steps", "capacity", "levels", "block", "interpret"))(_rz_rows_pallas)


def _grid_inputs(grid: ScenarioGrid):
    alpha, zeta, w1, w2 = grid.payoff_param_arrays()
    return tuple(jnp.asarray(a, jnp.float64) for a in (
        grid.s0, grid.sigma, grid.rate, grid.maturity, grid.cost_rate,
        alpha, zeta, w1, w2, grid.strike, grid.strike2))


def _with_bumps(inputs, greeks: bool):
    """Stack [base, s0+, s0-, sigma+, sigma-] along the scenario axis."""
    if not greeks:
        return inputs, 1
    s0, sigma = inputs[0], inputs[1]
    ds = _DELTA_REL_BUMP * s0
    dv = _VEGA_BUMP
    variants = [
        (s0, sigma), (s0 + ds, sigma), (s0 - ds, sigma),
        (s0, sigma + dv), (s0, sigma - dv),
    ]
    out = []
    for i, a in enumerate(inputs):
        if i == 0:
            out.append(jnp.concatenate([v[0] for v in variants]))
        elif i == 1:
            out.append(jnp.concatenate([v[1] for v in variants]))
        else:
            out.append(jnp.tile(a, 5))
    return tuple(out), 5


def _split_bumps(vals, n: int, copies: int, s0, shape):
    """(surface, d/ds0, d/dsigma) from the stacked FD evaluation."""
    r = lambda a: np.asarray(a).reshape(shape)
    base = r(vals[:n])
    if copies == 1:
        return base, None, None
    ds = (_DELTA_REL_BUMP * s0).reshape(shape)
    delta = (r(vals[n:2 * n]) - r(vals[2 * n:3 * n])) / (2.0 * ds)
    vega = (r(vals[3 * n:4 * n]) - r(vals[4 * n:5 * n])) / (2.0 * _VEGA_BUMP)
    return base, delta, vega


# --------------------------------------------------------------------- #
# device-mesh sharded dispatch (1-D scenario mesh, core/distributed.py)
# --------------------------------------------------------------------- #
# Rows of a flat grid are independent, so sharding is pure layout: a
# host-side plan (core/partition.py::plan_shards) permutes rows so each
# device's slice has near-equal *predicted* work, pads every slice to the
# plan's static lane count with duplicates of in-shard rows, and runs the
# same row kernel under shard_map.  Results gather back through the
# inverse permutation; pad lanes are duplicates, so max-reductions
# (``max_pieces``) and the OverflowError check see exactly the
# single-device values.

_SHARD_JIT_CACHE: dict = {}


def _sharded_jit(rows_fn, mesh, **static):
    """jit of ``rows_fn`` shard_mapped over ``mesh`` — cached per
    (kernel, mesh, static config) like jax's own jit cache."""
    from .core.distributed import sharded_rows
    key = (rows_fn, mesh, tuple(sorted(static.items())))
    f = _SHARD_JIT_CACHE.get(key)
    if f is None:
        f = jax.jit(sharded_rows(partial(rows_fn, **static), mesh))
        _SHARD_JIT_CACHE[key] = f
    return f


def _resolve_shard(grid: ScenarioGrid, n_rows: int, copies: int, *,
                   capacity: int, mesh, devices,
                   shard_plan: Optional[ShardPlan], costs=None):
    """Normalise sharding knobs to ``(mesh_or_None, plan_or_None)``.

    A caller-supplied ``shard_plan`` (the serving layer's rebalanced
    plan) must cover the *bumped* flat batch; otherwise a fresh
    cost-model plan is made here (``costs``, when given, overrides the
    default lattice cost model — the lsmc engine passes its own).
    ``(None, None)`` means take the single-device path.
    """
    from .core.distributed import resolve_grid_mesh
    mesh, n_shards = resolve_grid_mesh(devices, mesh)
    if shard_plan is None and n_shards <= 1:
        return None, None
    if shard_plan is None:
        if costs is None:
            costs = np.tile(scenario_costs(grid.n_steps, grid.cost_rate,
                                           capacity=capacity), copies)
        shard_plan = plan_shards(costs, n_shards)
    elif n_shards > 1 and shard_plan.n_shards != n_shards:
        # also on the simulated path: a mismatch must fail identically
        # on 1-device CI and on a real mesh
        raise ValueError(f"shard_plan has {shard_plan.n_shards} shards but "
                         f"devices/mesh asked for {n_shards}")
    if shard_plan.n_rows != n_rows:
        raise ValueError(f"shard_plan covers {shard_plan.n_rows} rows, "
                         f"batch has {n_rows} (greeks bumps included)")
    return mesh, shard_plan


def _run_rows(rows_fn, jit_fn, static: dict, inputs, mesh,
              plan: Optional[ShardPlan]):
    """Run the flat-batch row kernel; sharded when ``plan`` is present.

    Returns ``(outputs, positions)`` — ``positions`` (None on the single
    path) maps original row ``i`` to its slot in the laid-out outputs.
    With a plan but no mesh the identical layout runs on the local
    device (the *simulated* mesh of ``resolve_grid_mesh``).
    """
    if plan is None:
        return jit_fn(*inputs, **static), None
    gather, positions = shard_layout(plan)
    laid_out = tuple(a[gather] for a in inputs)
    if mesh is None:
        out = jit_fn(*laid_out, **static)
    else:
        out = _sharded_jit(rows_fn, mesh, **static)(*laid_out)
    return out, positions


def _shard_exec_info(plan: ShardPlan, mesh, grid: ScenarioGrid, copies: int,
                     pieces_rows: Optional[np.ndarray]) -> ShardExecInfo:
    """Measured per-shard stats for the rebalance hook (see
    :class:`ShardExecInfo`)."""
    cr = np.tile(np.atleast_1d(np.asarray(grid.cost_rate)), copies)
    if pieces_rows is None:
        pieces_rows = np.zeros(plan.n_rows)
    costs = scenario_costs(grid.n_steps, cr,
                           pieces=np.maximum(pieces_rows, 1.0))
    per_pieces, measured = [], []
    for rows in plan.shards:
        idx = list(rows)
        per_pieces.append(int(np.max(pieces_rows[idx])) if idx else 0)
        measured.append(float(np.sum(costs[idx])) if idx else 0.0)
    return ShardExecInfo(plan=plan, mesh_shape=(plan.n_shards,),
                         simulated=mesh is None,
                         per_shard_pieces=tuple(per_pieces),
                         per_shard_rows=plan.sizes,
                         measured_work=tuple(measured))


def price_grid_rz(grid: ScenarioGrid, *, capacity: int = 48,
                  greeks: bool = False, backend: str = "jnp",
                  levels: Optional[int] = None, block: Optional[int] = None,
                  interpret: Optional[bool] = None, mesh=None,
                  devices: Optional[int] = None,
                  shard_plan: Optional[ShardPlan] = None) -> GridResult:
    """Price every scenario of ``grid`` under transaction costs.

    One jitted, vmapped call over the whole (bumped, if ``greeks``) batch;
    returns ask/bid surfaces of ``grid.shape``.  Raises ``OverflowError``
    if any scenario needs more than ``capacity`` PWL knots (re-run with a
    larger capacity), mirroring :func:`repro.core.rz.price_rz`.

    ``backend="jnp"`` walks levels with ``lax.fori_loop`` over the full
    node axis; ``backend="pallas"`` runs the blocked VMEM rounds of
    ``kernels/rz_step.py`` under the ``core/partition.py`` round schedule
    (``levels``/``block`` tune it; ``interpret`` as in the no-TC kernel).
    Both report ``max_pieces`` identically.

    ``mesh``/``devices`` shard the flat scenario batch over a 1-D device
    mesh under a cost-model :class:`~repro.core.partition.ShardPlan`
    (pass ``shard_plan`` to override, e.g. the serving layer's
    rebalanced plan); results, ``max_pieces`` and the OverflowError
    check are identical to the single-device call.

    ``interpret=None`` resolves from the platform policy
    (``core/platform.py``) before the jit cache key.
    """
    interpret = resolve_interpret(interpret)
    _require_lattice(grid, "rz")
    inputs, copies = _with_bumps(_grid_inputs(grid), greeks)
    if backend == "jnp":
        rows_fn, jit_fn = _rz_rows, _rz_grid_jit
        static = dict(n_steps=grid.n_steps, capacity=capacity)
    elif backend == "pallas":
        rows_fn, jit_fn = _rz_rows_pallas, _rz_grid_pallas
        static = dict(n_steps=grid.n_steps, capacity=capacity, levels=levels,
                      block=block, interpret=interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}; use one of "
                         f"{RZ_BACKENDS}")
    mesh, plan = _resolve_shard(grid, inputs[0].shape[0], copies,
                                capacity=capacity, mesh=mesh,
                                devices=devices, shard_plan=shard_plan)
    (ask, bid, pieces), positions = _run_rows(rows_fn, jit_fn, static,
                                              inputs, mesh, plan)
    shard_info = None
    if plan is not None:
        ask, bid = np.asarray(ask)[positions], np.asarray(bid)[positions]
        pieces = np.asarray(pieces)[positions]
        shard_info = _shard_exec_info(plan, mesh, grid, copies, pieces)
    n = grid.n_scenarios
    max_pieces = int(jnp.max(jnp.asarray(pieces)))
    if max_pieces > capacity:
        raise OverflowError(
            f"PWL capacity overflow: needed {max_pieces} > K={capacity}; "
            "re-run with a larger capacity")
    a, da, va = _split_bumps(ask, n, copies, grid.s0, grid.shape)
    b, db, vb = _split_bumps(bid, n, copies, grid.s0, grid.shape)
    row_pieces = np.asarray(pieces)[:n].reshape(grid.shape).astype(int)
    return GridResult(grid=grid, ask=a, bid=b, max_pieces=max_pieces,
                      delta_ask=da, delta_bid=db, vega_ask=va, vega_bid=vb,
                      shard_info=shard_info, row_pieces=row_pieces,
                      engine="rz")


def rz_grid_cost(grid: ScenarioGrid, *, capacity: int = 48,
                 backend: str = "jnp", levels: Optional[int] = None,
                 block: Optional[int] = None,
                 interpret: Optional[bool] = None) -> Optional[dict]:
    """XLA ``cost_analysis`` of the compiled RZ rows program.

    The roofline hook the bench lanes use: exact flops/bytes of the same
    jitted program :func:`price_grid_rz` runs (single-device path), fed
    to :func:`repro.roofline.pricing.matrix_entry`.  ``None`` when the
    backend exposes no cost model.
    """
    from .roofline.pricing import compiled_cost
    interpret = resolve_interpret(interpret)
    _require_lattice(grid, "rz")
    inputs, _ = _with_bumps(_grid_inputs(grid), False)
    if backend == "jnp":
        fn = partial(_rz_rows, n_steps=grid.n_steps, capacity=capacity)
    elif backend == "pallas":
        fn = partial(_rz_rows_pallas, n_steps=grid.n_steps,
                     capacity=capacity, levels=levels, block=block,
                     interpret=interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}; use one of "
                         f"{RZ_BACKENDS}")
    return compiled_cost(fn, *inputs)


# --------------------------------------------------------------------- #
# friction-free grid engine (core/notc.py recursion or the Pallas kernel)
# --------------------------------------------------------------------- #
def _notc_one_jnp(s0, sigma, rate, maturity, alpha, zeta, w1, w2, k1, k2,
                  *, n_steps: int):
    """Fixed-buffer backward induction with the payoff carried as data
    (the parameterised form of ``core.notc._notc_kernel``)."""
    dtype = jnp.float64
    dt = maturity / n_steps
    u = jnp.exp(sigma * jnp.sqrt(dt))
    r = jnp.exp(rate * dt)
    p = (r - 1.0 / u) / (u - 1.0 / u)
    idx = jnp.arange(n_steps + 1, dtype=dtype)

    def intrinsic(lvl):
        s = s0 * jnp.exp((2.0 * idx - lvl) * sigma * jnp.sqrt(dt))
        pay = (alpha * k1 + w1 * jnp.maximum(s - k1, 0.0)
               + w2 * jnp.maximum(s - k2, 0.0) + zeta * s)
        return jnp.where(idx <= lvl, jnp.maximum(pay, 0.0), 0.0)

    v0 = intrinsic(jnp.asarray(n_steps, dtype))

    def body(step, v):
        lvl = jnp.asarray(n_steps - 1 - step, dtype)
        cont = (p * jnp.roll(v, -1) + (1.0 - p) * v) / r
        return jnp.maximum(intrinsic(lvl), cont)

    return jax.lax.fori_loop(0, n_steps, body, v0)[0]


def _notc_rows_jnp(s0, sigma, rate, maturity, alpha, zeta, w1, w2, k1, k2,
                   *, n_steps: int):
    return jax.vmap(partial(_notc_one_jnp, n_steps=n_steps))(
        s0, sigma, rate, maturity, alpha, zeta, w1, w2, k1, k2)


_notc_grid_jnp = partial(jax.jit, static_argnames=("n_steps",))(
    _notc_rows_jnp)


def _notc_rows_pallas(s0, sigma, rate, maturity, alpha, zeta, w1, w2, k1, k2,
                      *, n_steps: int, levels: int, block: int,
                      interpret: bool):
    from .kernels.binomial_step import lattice_round_param
    dtype = jnp.float64

    def one(s0_, sig_, r_, t_, al_, ze_, w1_, w2_, k1_, k2_):
        dt = t_ / n_steps
        u = jnp.exp(sig_ * jnp.sqrt(dt))
        r = jnp.exp(r_ * dt)
        p_up = (r - 1.0 / u) / (u - 1.0 / u)
        sig = sig_ * jnp.sqrt(dt)
        P = -(-(n_steps + 1) // block) * block
        idx = jnp.arange(P, dtype=dtype)
        s_leaf = s0_ * jnp.exp((2.0 * idx - n_steps) * sig)
        pay = (al_ * k1_ + w1_ * jnp.maximum(s_leaf - k1_, 0.0)
               + w2_ * jnp.maximum(s_leaf - k2_, 0.0) + ze_ * s_leaf)
        v0 = jnp.maximum(pay, 0.0)
        rounds = -(-n_steps // levels)

        def body(rr, v):
            lvl0 = jnp.asarray(n_steps - rr * levels, dtype)
            scalars = jnp.stack([lvl0, p_up, 1.0 / r, s0_, sig,
                                 al_, ze_, w1_, w2_, k1_, k2_])
            return lattice_round_param(v, scalars, levels=levels,
                                       block=block, interpret=interpret)

        return jax.lax.fori_loop(0, rounds, body, v0)[0]

    return jax.vmap(one)(s0, sigma, rate, maturity,
                         alpha, zeta, w1, w2, k1, k2)


_notc_grid_pallas = partial(jax.jit, static_argnames=(
    "n_steps", "levels", "block", "interpret"))(_notc_rows_pallas)


def price_grid_notc(grid: ScenarioGrid, *, backend: str = "jnp",
                    greeks: bool = False, levels: int = 64,
                    block: int = 256, interpret: Optional[bool] = None,
                    mesh=None,
                    devices: Optional[int] = None,
                    shard_plan: Optional[ShardPlan] = None) -> GridResult:
    """Friction-free binomial prices for every scenario of ``grid``.

    ``backend="jnp"`` runs the vectorised ``core/notc.py`` recursion;
    ``backend="pallas"`` vmaps the blocked lattice kernel
    (``kernels/binomial_step.py``), exercising the paper's §4 block scheme
    per scenario.  ``grid.cost_rate`` is ignored (must be 0 for the result
    to be meaningful as a two-sided quote).  ``mesh``/``devices``/
    ``shard_plan`` shard the batch over a 1-D device mesh exactly as in
    :func:`price_grid_rz` (friction-free rows all cost the same, so the
    default plan is the even split).  ``interpret=None`` resolves from
    the platform policy (``core/platform.py``).
    """
    interpret = resolve_interpret(interpret)
    _require_lattice(grid, "notc")
    inputs, copies = _with_bumps(_grid_inputs(grid), greeks)
    # drop the cost-rate column (index 4) — this engine is friction-free
    args = inputs[:4] + inputs[5:]
    if backend == "jnp":
        rows_fn, jit_fn = _notc_rows_jnp, _notc_grid_jnp
        static = dict(n_steps=grid.n_steps)
    elif backend == "pallas":
        rows_fn, jit_fn = _notc_rows_pallas, _notc_grid_pallas
        static = dict(n_steps=grid.n_steps, levels=levels, block=block,
                      interpret=interpret)
    else:
        raise ValueError(f"unknown backend {backend!r}; use 'jnp' or 'pallas'")
    mesh, plan = _resolve_shard(grid, args[0].shape[0], copies,
                                capacity=1, mesh=mesh, devices=devices,
                                shard_plan=shard_plan)
    vals, positions = _run_rows(rows_fn, jit_fn, static, args, mesh, plan)
    shard_info = None
    if plan is not None:
        vals = np.asarray(vals)[positions]
        shard_info = _shard_exec_info(plan, mesh, grid, copies, None)
    n = grid.n_scenarios
    p, dp, vp = _split_bumps(vals, n, copies, grid.s0, grid.shape)
    cp = lambda a: None if a is None else a.copy()
    return GridResult(grid=grid, ask=p, bid=p.copy(), max_pieces=0,
                      delta_ask=dp, delta_bid=cp(dp),
                      vega_ask=vp, vega_bid=cp(vp), shard_info=shard_info,
                      row_pieces=np.zeros(grid.shape, dtype=int),
                      engine="notc")


# --------------------------------------------------------------------- #
# least-squares Monte Carlo grid engine (baskets / Bermudan schedules)
# --------------------------------------------------------------------- #
def price_grid_lsmc(grid: ScenarioGrid, *, n_paths: int = 4096,
                    seed: int = 0, basis: str = "poly", degree: int = 3,
                    antithetic: bool = True, greeks: bool = False,
                    mesh=None, devices: Optional[int] = None,
                    shard_plan: Optional[ShardPlan] = None) -> GridResult:
    """Longstaff–Schwartz Monte Carlo prices for every scenario of ``grid``.

    The engine for the contracts the lattice cannot shape: ``d =
    grid.n_assets`` underlyings per row (arithmetic basket payoff) and
    Bermudan ``grid.exercise_steps`` schedules — but it also prices the
    plain 1-D American grid, which is how the oracle tests lock it
    against ``rz_ref``/``notc`` (see ``tests/test_lsmc.py``).

    Deterministic for a given ``seed``: scenario row ``i`` draws from
    ``fold_in(PRNGKey(seed), i)`` (``core/lsmc.py::path_keys``), so
    results are bitwise reproducible and independent of padding or of
    the ``mesh``/``devices``/``shard_plan`` layout — the same
    shard-vs-single-device guarantee as the lattice engines, here by
    per-row key construction.  ``GridResult.stderr`` carries each
    scenario's Monte Carlo standard error.

    ``greeks`` reuses the fused central-difference bumps with **common
    random numbers** (bumped copies of a row share its key), the MC
    analogue of the lattice engines' fused FD Greeks.
    """
    from .core.lsmc import (LSMC_BASES, exercise_schedule, lsmc_rows,
                            lsmc_rows_jit, path_keys)
    if basis not in LSMC_BASES:
        raise ValueError(f"unknown basis {basis!r}; use one of {LSMC_BASES}")
    steps = exercise_schedule(grid.n_steps, grid.exercise_steps)
    inputs, copies = _with_bumps(_grid_inputs(grid), greeks)
    n = grid.n_scenarios
    # one key per scenario row, tiled over bump copies (common random
    # numbers: the FD difference cancels the MC noise, not adds to it)
    keys = jnp.tile(path_keys(seed, n), (copies, 1))
    inputs = inputs + (keys,)
    static = dict(n_steps=grid.n_steps, steps=steps, n_paths=int(n_paths),
                  n_assets=grid.n_assets, degree=int(degree), basis=basis,
                  antithetic=bool(antithetic))
    costs = np.tile(scenario_costs(grid.n_steps, grid.cost_rate,
                                   engine="lsmc", n_paths=n_paths,
                                   n_exercise=len(steps),
                                   n_assets=grid.n_assets), copies)
    mesh, plan = _resolve_shard(grid, inputs[0].shape[0], copies,
                                capacity=1, mesh=mesh, devices=devices,
                                shard_plan=shard_plan, costs=costs)
    (ask, bid, se), positions = _run_rows(lsmc_rows, lsmc_rows_jit, static,
                                          inputs, mesh, plan)
    shard_info = None
    if plan is not None:
        ask, bid = np.asarray(ask)[positions], np.asarray(bid)[positions]
        se = np.asarray(se)[positions]
        shard_info = _shard_exec_info(plan, mesh, grid, copies, None)
    a, da, va = _split_bumps(ask, n, copies, grid.s0, grid.shape)
    b, db, vb = _split_bumps(bid, n, copies, grid.s0, grid.shape)
    stderr = np.asarray(se)[:n].reshape(grid.shape)
    return GridResult(grid=grid, ask=a, bid=b, max_pieces=0,
                      delta_ask=da, delta_bid=db, vega_ask=va, vega_bid=vb,
                      shard_info=shard_info,
                      row_pieces=np.zeros(grid.shape, dtype=int),
                      stderr=stderr, engine="lsmc")
