"""Stable top-level pricing API.

Everything a user needs to price American options — one contract or a
scenario grid, with or without transaction costs — behind two functions:

  * :func:`price_american`  — one contract -> :class:`PriceQuote`
  * :func:`price_grid`      — a grid of scenarios -> ``GridResult``
    (one compiled call per tree depth)

plus the building blocks re-exported from the core:
:class:`~repro.scenarios.ScenarioGrid`,
:class:`~repro.core.lattice.LatticeModel`, and the payoff constructors.

Quickstart::

    >>> from repro.api import price_american, price_grid, ScenarioGrid
    >>> q = price_american(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
    ...                    n_steps=100, payoff="put", strike=100.0,
    ...                    cost_rate=0.005)
    >>> round(q.ask, 4), round(q.bid, 4)
    (4.6761, 0.2374)
    >>> grid = ScenarioGrid.cartesian(
    ...     s0=(95.0, 100.0, 105.0), cost_rate=(0.0, 0.01),
    ...     payoff=("put", "call"), strike=100.0, n_steps=24)
    >>> res = price_grid(grid, capacity=24)
    >>> res.ask.shape        # (s0, sigma, rate, T, lambda, payoff, strike)
    (3, 1, 1, 1, 2, 2, 1)
    >>> bool((res.spread >= -1e-12).all())   # ask >= bid everywhere
    True

The prices above are deterministic: float64 lattice engines, validated
against the sequential oracles (see ``docs/ARCHITECTURE.md``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Union

import numpy as np

from .configs.pricing import ExecutionConfig
from .core.lattice import LatticeModel
from .core.payoff import (PayoffProcess, american_call, american_put,
                          bull_spread, cash_settled)
from .scenarios import (PAYOFF_FAMILIES, GridResult, ScenarioGrid,
                        price_grid_lsmc, price_grid_notc, price_grid_rz,
                        route_engine)

__all__ = [
    "price_american", "price_grid", "price_flat", "PriceQuote", "GridResult",
    "ExecutionConfig", "ScenarioGrid", "LatticeModel", "PayoffProcess",
    "PAYOFF_FAMILIES", "american_put", "american_call", "bull_spread",
    "cash_settled", "route_engine",
]

# the individual execution kwargs warn once per process, then stay quiet
_legacy_exec_warned = False


def _reset_legacy_exec_warning() -> None:
    """Re-arm the once-per-process deprecation warning (test hook)."""
    global _legacy_exec_warned
    _legacy_exec_warned = False


def _merge_execution(fn: str, execution: Optional[ExecutionConfig], *,
                     engine=None, backend=None, platform=None,
                     interpret=None, devices=None, n_paths=None, seed=None,
                     basis=None, degree=None,
                     antithetic=None) -> ExecutionConfig:
    """Collapse ``execution=`` and the legacy individual kwargs into one
    resolved :class:`ExecutionConfig`.

    Passing both is an error (no silent precedence); passing only the
    individual kwargs keeps working through a deprecation shim that
    warns once per process.
    """
    legacy = {name: v for name, v in (
        ("engine", engine), ("backend", backend), ("platform", platform),
        ("interpret", interpret), ("devices", devices),
        ("n_paths", n_paths), ("seed", seed), ("basis", basis),
        ("degree", degree), ("antithetic", antithetic)) if v is not None}
    if execution is not None:
        if legacy:
            raise TypeError(
                f"{fn}() got both execution= and the individual kwargs "
                f"{sorted(legacy)}; set them on the ExecutionConfig instead")
        return execution.resolved()
    if legacy:
        global _legacy_exec_warned
        if not _legacy_exec_warned:
            _legacy_exec_warned = True
            warnings.warn(
                f"{fn}({', '.join(sorted(legacy))}=...): passing execution "
                "knobs as individual kwargs is deprecated; pass "
                "execution=ExecutionConfig(...) (repro.api.ExecutionConfig)",
                DeprecationWarning, stacklevel=3)
    return ExecutionConfig(
        engine=engine, backend=backend, platform=platform,
        interpret=interpret, devices=devices, n_paths=n_paths,
        mc_seed=seed, basis=basis, degree=degree,
        antithetic=antithetic).resolved()


@dataclasses.dataclass(frozen=True)
class PriceQuote:
    """Two-sided quote for one contract.

    Under proportional transaction costs the arbitrage-free price is an
    interval: ``ask`` is the seller's (upper) price, ``bid`` the buyer's
    (lower) price.  Without frictions ask == bid == the binomial price.
    ``max_pieces`` reports the peak PWL knot count (0 for the no-TC path).
    ``stderr`` is the Monte Carlo standard error when the quote came
    from the ``lsmc`` engine (0.0 from the deterministic lattices).
    """
    ask: float
    bid: float
    max_pieces: int = 0
    stderr: float = 0.0

    @property
    def mid(self) -> float:
        return 0.5 * (self.ask + self.bid)

    @property
    def spread(self) -> float:
        return self.ask - self.bid


def _mk_payoff(payoff: Union[str, PayoffProcess], strike: float,
               strike2: Optional[float]) -> PayoffProcess:
    if isinstance(payoff, PayoffProcess):
        return payoff
    if payoff == "put":
        return american_put(strike)
    if payoff == "call":
        return american_call(strike)
    if payoff == "bull_spread":
        return bull_spread(strike, strike + 10.0 if strike2 is None
                           else strike2)
    raise ValueError(f"unknown payoff {payoff!r}; "
                     f"supported: {PAYOFF_FAMILIES} or a PayoffProcess")


def price_american(*, s0: float, sigma: float, rate: float, maturity: float,
                   n_steps: int, payoff: Union[str, PayoffProcess] = "put",
                   strike: float = 100.0, strike2: Optional[float] = None,
                   cost_rate: float = 0.0, capacity: int = 48) -> PriceQuote:
    """Price one American option on a CRR binomial tree.

    With ``cost_rate`` (the proportional transaction-cost rate lambda) at
    0 this runs the classic friction-free backward induction; otherwise
    the Roux–Zastawniak PWL recursion, returning the seller/buyer price
    interval.  ``payoff`` is a family name (``put``, ``call``,
    ``bull_spread``) or any :class:`~repro.core.payoff.PayoffProcess`.
    """
    model = LatticeModel(s0=s0, sigma=sigma, rate=rate, maturity=maturity,
                         n_steps=n_steps, cost_rate=cost_rate)
    pay = _mk_payoff(payoff, strike, strike2)
    if cost_rate == 0.0:
        from .core.notc import price_notc_np
        p = price_notc_np(model, pay)
        return PriceQuote(ask=p, bid=p, max_pieces=0)
    from .core.rz import price_rz
    res = price_rz(model, pay, capacity=capacity)
    return PriceQuote(ask=res.ask, bid=res.bid, max_pieces=res.max_pieces)


def price_grid(grid: Optional[ScenarioGrid] = None, *,
               execution: Optional[ExecutionConfig] = None,
               engine: Optional[str] = None, capacity: int = 48,
               greeks: bool = False, backend: Optional[str] = None,
               n_steps: Union[int, Sequence[int], None] = None,
               levels: Optional[int] = None, block: Optional[int] = None,
               interpret: Optional[bool] = None,
               platform: Optional[str] = None,
               n_paths: Optional[int] = None, seed: Optional[int] = None,
               basis: Optional[str] = None, degree: Optional[int] = None,
               antithetic: Optional[bool] = None,
               mesh=None, devices: Optional[int] = None, shard_plan=None,
               **axes) -> Union[GridResult, list]:
    """Price a whole grid of scenarios in one compiled call.

    Pass a prebuilt :class:`ScenarioGrid`, or cartesian axes as keyword
    arguments (forwarded to :meth:`ScenarioGrid.cartesian`)::

        price_grid(s0=(95, 100, 105), cost_rate=(0.0, 0.005),
                   payoff=("put", "call"), n_steps=100)

    ``engine="auto"`` routes by contract shape, then cost rate
    (:func:`repro.scenarios.route_engine`): a multi-asset basket
    (``n_assets > 1``) or Bermudan ``exercise_steps`` grid goes to the
    least-squares Monte Carlo engine ``"lsmc"``; otherwise the
    transaction-cost lattice engine ``"rz"`` when any scenario has
    ``cost_rate > 0``, else the friction-free lattice engine ``"notc"``.
    ``backend`` selects the implementation of *either lattice* engine
    ("jnp" or "pallas" — for the TC engine the blocked PWL rounds of
    ``kernels/rz_step.py``, for the friction-free one
    ``kernels/binomial_step.py``); ``levels``/``block``/``interpret``
    tune the Pallas kernels.  ``interpret=None`` resolves from the
    platform policy of ``core/platform.py`` — interpret mode on CPU
    (no compiled Pallas lowering there), real compiled lowerings on
    GPU/TPU — and ``platform`` overrides which policy applies without
    touching the process-wide default (see ``docs/PLATFORMS.md``; TC
    ``block``/``levels`` default to the ``core/partition.py``
    schedule).  ``n_paths``/``seed``/``basis``/
    ``degree``/``antithetic`` tune the MC engine
    (:func:`repro.scenarios.price_grid_lsmc` — seeded, bitwise
    deterministic).  The tree depth is compile-time static: passing a
    *sequence* of ``n_steps`` prices one grid per distinct depth and
    returns the list of results in order.

    ``mesh``/``devices`` shard the flat scenario batch across a 1-D
    device mesh under a cost-model shard plan
    (``core/partition.py::plan_shards``; pass ``shard_plan`` to
    override).  Results are identical to the single-device call — see
    ``docs/ARCHITECTURE.md`` "Sharded grid engine".

    The execution knobs (``engine``/``backend``/``platform``/
    ``interpret``/``devices``/``n_paths``/``seed``/``basis``/``degree``/
    ``antithetic``) are consolidated in
    :class:`~repro.configs.pricing.ExecutionConfig` — pass
    ``execution=ExecutionConfig(...)``.  The individual kwargs keep
    working through a deprecation shim that warns once per process;
    passing both is a ``TypeError``.  ``mesh``/``shard_plan`` stay
    separate kwargs: they carry live/plan objects, not config.
    """
    cfg = _merge_execution("price_grid", execution, engine=engine,
                           backend=backend, platform=platform,
                           interpret=interpret, devices=devices,
                           n_paths=n_paths, seed=seed, basis=basis,
                           degree=degree, antithetic=antithetic)
    if grid is None:
        if isinstance(n_steps, (list, tuple)):
            if shard_plan is not None:
                raise TypeError(
                    "shard_plan cannot combine with a sequence of n_steps: "
                    "one plan covers one flat batch (pass mesh=/devices= "
                    "and let each depth plan itself)")
            return [price_grid(execution=cfg, capacity=capacity,
                               greeks=greeks, n_steps=int(n),
                               levels=levels, block=block, mesh=mesh,
                               **axes) for n in n_steps]
        grid = ScenarioGrid.cartesian(n_steps=int(n_steps or 100), **axes)
    elif axes or n_steps is not None:
        raise TypeError("pass either a ScenarioGrid or cartesian axes, "
                        "not both")
    eng = cfg.engine
    if eng == "auto":
        eng = route_engine(any_tc=bool(np.any(grid.cost_rate > 0.0)),
                           n_assets=grid.n_assets,
                           exercise_steps=grid.exercise_steps)
    if eng == "rz":
        return price_grid_rz(grid, capacity=capacity, greeks=greeks,
                             backend=cfg.backend, levels=levels, block=block,
                             interpret=cfg.interpret, mesh=mesh,
                             devices=cfg.devices, shard_plan=shard_plan)
    if eng == "notc":
        return price_grid_notc(grid, backend=cfg.backend, greeks=greeks,
                               levels=64 if levels is None else levels,
                               block=256 if block is None else block,
                               interpret=cfg.interpret, mesh=mesh,
                               devices=cfg.devices, shard_plan=shard_plan)
    if eng == "lsmc":
        return price_grid_lsmc(grid, n_paths=cfg.n_paths, seed=cfg.mc_seed,
                               basis=cfg.basis, degree=cfg.degree,
                               antithetic=cfg.antithetic,
                               greeks=greeks, mesh=mesh, devices=cfg.devices,
                               shard_plan=shard_plan)
    raise ValueError(f"unknown engine {eng!r}; use 'auto', 'rz', 'notc' "
                     "or 'lsmc'")


def price_flat(*, s0, sigma, rate, maturity, cost_rate=0.0, payoff="put",
               strike=100.0, strike2=None, n_steps: int = 100,
               n_assets: int = 1, exercise_steps=None,
               execution: Optional[ExecutionConfig] = None,
               engine: Optional[str] = None, capacity: int = 48,
               greeks: bool = False, backend: Optional[str] = None,
               levels: Optional[int] = None, block: Optional[int] = None,
               interpret: Optional[bool] = None,
               platform: Optional[str] = None,
               n_paths: Optional[int] = None, seed: Optional[int] = None,
               basis: Optional[str] = None, degree: Optional[int] = None,
               antithetic: Optional[bool] = None,
               pad_to: Optional[int] = None, mesh=None,
               devices: Optional[int] = None, shard_plan=None) -> GridResult:
    """Price a *flat* batch of heterogeneous contracts in one compiled call.

    The serving layer's entry point: element-wise scenario arrays (no
    cartesian product — request ``i`` is row ``i``), mixed payoff families
    batched as data (:func:`repro.core.payoff.param_payoff`).  ``pad_to``
    pads the batch by repeating the last row so a request stream reuses a
    small set of compiled batch shapes; results keep the padded length —
    slice the first ``len(s0)`` rows (the scheduler does this for you).
    ``mesh``/``devices``/``shard_plan`` shard the (padded) batch over a
    1-D device mesh as in :func:`price_grid`; a ``shard_plan`` must
    cover the padded batch.  The returned ``GridResult.row_pieces``
    carries the *per-row* PWL knot counts (0 on the no-TC path) — rows
    are independent vmap lanes, so row ``i``'s count is exactly what
    pricing contract ``i`` alone would report, which is how the serving
    layer attaches an exact ``max_pieces`` to each quote it unpads.
    ``levels``/``block``/``interpret``/``platform`` tune the Pallas
    kernels exactly as in :func:`price_grid` (``interpret=None`` =
    platform policy), so the serving layer's execution mode threads
    end-to-end.  As in :func:`price_grid`, the execution knobs
    consolidate into ``execution=ExecutionConfig(...)``; the individual
    kwargs ride the same once-per-process deprecation shim.

        >>> from repro.api import price_flat
        >>> res = price_flat(s0=(95.0, 100.0), payoff=("put", "call"),
        ...                  strike=(100.0, 90.0), sigma=0.2, rate=0.1,
        ...                  maturity=0.25, n_steps=8, pad_to=4)
        >>> res.ask.shape          # padded flat batch
        (4,)
        >>> bool(res.ask[0] > 0)
        True
    """
    cfg = _merge_execution("price_flat", execution, engine=engine,
                           backend=backend, platform=platform,
                           interpret=interpret, devices=devices,
                           n_paths=n_paths, seed=seed, basis=basis,
                           degree=degree, antithetic=antithetic)
    grid = ScenarioGrid.explicit(
        s0=s0, sigma=sigma, rate=rate, maturity=maturity,
        cost_rate=cost_rate, payoff=payoff, strike=strike, strike2=strike2,
        n_steps=n_steps, n_assets=n_assets, exercise_steps=exercise_steps)
    if pad_to is not None:
        grid = grid.pad_to(pad_to)
    return price_grid(grid, execution=cfg, capacity=capacity, greeks=greeks,
                      levels=levels, block=block, mesh=mesh,
                      shard_plan=shard_plan)
