"""Vectorised Roux–Zastawniak pricing engine (single device).

Carries the whole live tree level as fixed-capacity PWL SoA tensors
(:mod:`repro.core.pwl`) and walks levels N+1 -> 0 with ``lax.fori_loop``.
Every level update is the paper's per-node recursion, data-parallel over
nodes:

    w = max(z[i+1], z[i]);  v = cone(w / r);  z = max/min(u, v)

The node axis has static size N+2; nodes beyond the current level are
masked (their lanes hold a benign affine function so no NaNs are ever
produced, and they are never read by valid parents since node i's children
are i and i+1).

``price_rz`` is the public single-contract entry point;
``price_rz_batch`` vmaps it over a batch of contracts (strike / cost-rate /
spot grids — the "pricing desk" serving workload).  Capacity overflow is
reported via the returned ``max_pieces``; callers assert it fits.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import pwl as P
from .lattice import LatticeModel
from .payoff import PayoffProcess

__all__ = ["price_rz", "price_rz_batch", "rz_backward", "rz_level_step",
           "rz_level_step_lanes", "rz_backward_pallas", "RZResult",
           "RZ_BACKENDS"]

RZ_BACKENDS = ("jnp", "pallas")


@dataclasses.dataclass
class RZResult:
    ask: float
    bid: float
    max_pieces: int


def _benign(capacity: int, dtype) -> P.PWL:
    return P.make_affine(jnp.zeros((), dtype), jnp.zeros((), dtype), capacity, dtype)


def _select(mask, f_new: P.PWL, f_old: P.PWL) -> P.PWL:
    """Per-lane select between two PWL batches (mask over batch dims)."""
    pick = lambda a, b: jnp.where(mask[..., None] if a.ndim > mask.ndim else mask, a, b)
    return P.PWL(pick(f_new.xs, f_old.xs), pick(f_new.ys, f_old.ys),
                 jnp.where(mask, f_new.sl, f_old.sl),
                 jnp.where(mask, f_new.sr, f_old.sr),
                 jnp.where(mask, f_new.m, f_old.m))


def _shift_up(f: P.PWL) -> P.PWL:
    """Lane i <- lane i+1 (the up-move child) along the node axis (axis 0)."""
    sh = lambda a: jnp.roll(a, -1, axis=0)
    return P.PWL(sh(f.xs), sh(f.ys), sh(f.sl), sh(f.sr), sh(f.m))


def rz_level_step_lanes(z: P.PWL, lvl, params, *, capacity: int, seller: bool,
                        payoff: PayoffProcess, dtype, idx_offset=0):
    """One backward level update, returning *per-lane* piece counts.

    z: PWL batch over node axis (P lanes);  lvl: scalar level index (traced);
    params: dict with s0, sig_sqrt_dt, r, k.  ``idx_offset`` maps local lane
    j to global tree column idx_offset + j (used by the sharded engine and
    the blocked Pallas kernel).  Returns (z_new, pieces) with ``pieces`` an
    int32 vector over lanes (0 on non-live lanes) so callers that only own
    a sub-range of the lanes (kernel halos) can mask before reducing.
    """
    P_nodes = z.sl.shape[0]
    idx = idx_offset + jnp.arange(P_nodes, dtype=dtype)
    live = idx <= lvl                                  # lvl+1 valid nodes
    s = params["s0"] * jnp.exp((2.0 * idx - lvl) * params["sig_sqrt_dt"])
    no_tc = lvl == 0                                   # no costs at t = 0
    a = jnp.where(no_tc, s, (1.0 + params["k"]) * s)
    b = jnp.where(no_tc, s, (1.0 - params["k"]) * s)

    w, m1 = P.envelope2(_shift_up(z), z, capacity, take_max=True)
    w = P.scale(w, 1.0 / params["r"])
    v, m2 = P.cone_infconv(w, a, b, capacity)
    if seller:
        u = P.expense(payoff.xi(s), payoff.zeta(s), a, b, capacity, dtype)
        z_new, m3 = P.envelope2(u, v, capacity, take_max=True)
    else:
        u = P.expense(-payoff.xi(s), -payoff.zeta(s), a, b, capacity, dtype)
        z_new, m3 = P.envelope2(u, v, capacity, take_max=False)

    z_out = _select(live, z_new, z)
    pieces = jnp.where(live, jnp.maximum(jnp.maximum(m1, m2), m3), 0)
    return z_out, pieces


def rz_level_step(z: P.PWL, lvl, params, *, capacity: int, seller: bool,
                  payoff: PayoffProcess, dtype, idx_offset=0):
    """One backward level update -> (z_new, max_pieces) (scalar reduce)."""
    z_out, pieces = rz_level_step_lanes(
        z, lvl, params, capacity=capacity, seller=seller, payoff=payoff,
        dtype=dtype, idx_offset=idx_offset)
    return z_out, jnp.max(pieces)


def _leaf_level(n_steps: int, params, capacity: int, dtype,
                lanes: int | None = None) -> P.PWL:
    """z at the extra instant t = N+1 with payoff (0, 0).

    ``lanes`` (>= n_steps + 2) overrides the node-axis extent — the
    blocked Pallas engine pads it to a multiple of its block size.
    """
    P_nodes = n_steps + 2 if lanes is None else lanes
    idx = jnp.arange(P_nodes, dtype=dtype)
    s = params["s0"] * jnp.exp((2.0 * idx - (n_steps + 1)) * params["sig_sqrt_dt"])
    a = (1.0 + params["k"]) * s
    b = (1.0 - params["k"]) * s
    zero = jnp.zeros((P_nodes,), dtype)
    return P.expense(zero, zero, a, b, capacity, dtype)


def rz_backward(s0, sigma, rate, maturity, k, *, n_steps: int, capacity: int,
                payoff: PayoffProcess, dtype=jnp.float64):
    """Traceable full backward recursion -> (ask, bid, max_pieces).

    Unlike :func:`price_rz` this is not jitted and ``payoff`` need not be
    hashable/static — its xi/zeta closures may capture traced values, which
    is what the scenario-grid engine (:mod:`repro.scenarios`) relies on to
    batch heterogeneous contracts through one compiled call.
    """
    dt = maturity / n_steps
    params = dict(
        s0=s0, k=k,
        sig_sqrt_dt=sigma * jnp.sqrt(dt),
        r=jnp.exp(rate * dt),
    )
    z_s = _leaf_level(n_steps, params, capacity, dtype)
    z_b = _leaf_level(n_steps, params, capacity, dtype)

    def body(step, carry):
        z_s, z_b, pieces = carry
        lvl = jnp.asarray(n_steps - step, dtype)
        z_s, p1 = rz_level_step(z_s, lvl, params, capacity=capacity,
                                seller=True, payoff=payoff, dtype=dtype)
        z_b, p2 = rz_level_step(z_b, lvl, params, capacity=capacity,
                                seller=False, payoff=payoff, dtype=dtype)
        pieces = jnp.maximum(pieces, jnp.maximum(p1, p2))
        return z_s, z_b, pieces

    z_s, z_b, pieces = jax.lax.fori_loop(
        0, n_steps + 1, body, (z_s, z_b, jnp.zeros((), jnp.int32)))

    root = lambda z: jax.tree.map(lambda a: a[0], z)
    ask = P.eval_at(root(z_s), jnp.zeros((), dtype))
    bid = -P.eval_at(root(z_b), jnp.zeros((), dtype))
    return ask, bid, pieces


def rz_backward_pallas(s0, sigma, rate, maturity, k, *, n_steps: int,
                       capacity: int, payoff: PayoffProcess,
                       levels: int | None = None, block: int | None = None,
                       interpret: bool = True, dtype=jnp.float64):
    """Traceable TC backward recursion through the blocked Pallas kernel.

    Same contract as :func:`rz_backward` — (ask, bid, max_pieces) — but the
    level walk runs as ``kernels/rz_step.py`` rounds: each pallas_call
    advances a tile of lattice nodes ``D`` levels entirely in VMEM (the
    paper's §4 block/region rounds), with the round schedule — depth D and
    the re-balanced lane extent per round — picked statically by
    ``core/partition.py::kernel_round_plan``.

    Requires a payoff of the 4-parameter family (``payoff.params`` set):
    the kernel carries the payoff as scalar data, not closures.  ``block``
    of None runs one re-balanced block per round (no halo — the right
    choice whenever a whole level fits in VMEM); an explicit ``block``
    exercises the multi-block right-neighbour-halo scheme.
    """
    from .partition import kernel_round_plan
    from ..kernels.rz_step import rz_round
    if payoff.params is None:
        raise ValueError(
            f"backend='pallas' needs a 4-parameter-family payoff "
            f"(payoff.params set); {payoff.name!r} is closure-only. "
            "Use core.payoff.param_payoff / american_put / american_call / "
            "bull_spread, or backend='jnp'.")
    dt = maturity / n_steps
    params = dict(
        s0=s0, k=k,
        sig_sqrt_dt=sigma * jnp.sqrt(dt),
        r=jnp.exp(rate * dt),
    )
    plan = kernel_round_plan(n_steps, levels=levels, block=block)
    z_s = _leaf_level(n_steps, params, capacity, dtype, lanes=plan[0].lanes)
    z_b = _leaf_level(n_steps, params, capacity, dtype, lanes=plan[0].lanes)
    pieces = jnp.zeros((), jnp.int32)

    sc = [params["s0"], params["sig_sqrt_dt"], params["r"], params["k"],
          *payoff.params]
    for rnd in plan:
        # re-balance: shrink the lane extent to this round's live tree
        cut = lambda f: jax.tree.map(lambda a: a[:rnd.lanes], f)
        z_s, z_b = cut(z_s), cut(z_b)
        scalars = jnp.stack([jnp.asarray(v, dtype)
                             for v in (float(rnd.lvl0), *sc)])
        z_s, p1 = rz_round(z_s, scalars, levels=rnd.depth, block=rnd.block,
                           seller=True, interpret=interpret)
        z_b, p2 = rz_round(z_b, scalars, levels=rnd.depth, block=rnd.block,
                           seller=False, interpret=interpret)
        pieces = jnp.maximum(pieces, jnp.maximum(p1, p2))

    root = lambda z: jax.tree.map(lambda a: a[0], z)
    ask = P.eval_at(root(z_s), jnp.zeros((), dtype))
    bid = -P.eval_at(root(z_b), jnp.zeros((), dtype))
    return ask, bid, pieces


@partial(jax.jit, static_argnames=("n_steps", "capacity", "payoff", "dtype",
                                   "backend", "levels", "block", "interpret"))
def _price_rz_jit(s0, sigma, rate, maturity, k, *, n_steps: int, capacity: int,
                  payoff: PayoffProcess, dtype=jnp.float64,
                  backend: str = "jnp", levels=None, block=None,
                  interpret: bool = True):
    if backend == "pallas":
        return rz_backward_pallas(s0, sigma, rate, maturity, k,
                                  n_steps=n_steps, capacity=capacity,
                                  payoff=payoff, levels=levels, block=block,
                                  interpret=interpret, dtype=dtype)
    if backend != "jnp":
        raise ValueError(f"unknown backend {backend!r}; use one of "
                         f"{RZ_BACKENDS}")
    return rz_backward(s0, sigma, rate, maturity, k, n_steps=n_steps,
                       capacity=capacity, payoff=payoff, dtype=dtype)


def price_rz(model: LatticeModel, payoff: PayoffProcess,
             capacity: int = 48, *, backend: str = "jnp",
             levels: int | None = None, block: int | None = None,
             interpret: bool = True) -> RZResult:
    """Jitted vectorised ask/bid under proportional transaction costs.

    ``backend="jnp"`` walks levels with ``lax.fori_loop`` over the full
    node axis; ``backend="pallas"`` runs the blocked VMEM rounds of
    :func:`rz_backward_pallas`.  Both report overflow identically via
    ``max_pieces`` / ``OverflowError``.
    """
    ask, bid, pieces = _price_rz_jit(
        jnp.float64(model.s0), jnp.float64(model.sigma), jnp.float64(model.rate),
        jnp.float64(model.maturity), jnp.float64(model.cost_rate),
        n_steps=model.n_steps, capacity=capacity, payoff=payoff,
        backend=backend, levels=levels, block=block, interpret=interpret)
    res = RZResult(ask=float(ask), bid=float(bid), max_pieces=int(pieces))
    if res.max_pieces > capacity:
        raise OverflowError(
            f"PWL capacity overflow: needed {res.max_pieces} > K={capacity}; "
            "re-run with a larger capacity")
    return res


@partial(jax.jit, static_argnames=("n_steps", "capacity", "payoff"))
def price_rz_batch(s0, sigma, rate, maturity, k, *, n_steps: int,
                   capacity: int, payoff: PayoffProcess):
    """vmap over a batch of contracts; inputs are broadcastable 1-D arrays.

    Returns (ask, bid, max_pieces) arrays — the serving-engine workhorse.
    """
    s0, sigma, rate, maturity, k = jnp.broadcast_arrays(
        *(jnp.atleast_1d(jnp.asarray(v, jnp.float64))
          for v in (s0, sigma, rate, maturity, k)))
    fn = lambda *args: _price_rz_jit(*args, n_steps=n_steps, capacity=capacity,
                                     payoff=payoff)
    return jax.vmap(fn)(s0, sigma, rate, maturity, k)
