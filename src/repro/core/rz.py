"""Vectorised Roux–Zastawniak pricing engine (single device).

Carries the whole live tree level as fixed-capacity PWL SoA tensors
(:mod:`repro.core.pwl`) and walks levels N+1 -> 0 in ``lax.fori_loop``
rounds.  Every level update is the paper's per-node recursion,
data-parallel over nodes:

    w = max(z[i+1], z[i]);  v = cone(w / r);  z = max/min(u, v)

The node axis is static per round; nodes beyond the current level are
masked (their lanes hold a benign affine function so no NaNs are ever
produced, and they are never read by valid parents since node i's children
are i and i+1).  Both backends walk the statically re-balanced round
schedule of ``core/partition.py::kernel_round_plan`` (§4.2 lane
shedding — ~N^2/2 lane-levels) and carry the seller and buyer sides
FUSED as one (2, P) state (``rz_level_step_lanes`` with a traced
``seller`` flag array): per-side max/min is a select, so each level
costs one pass, not two.

``price_rz`` is the public single-contract entry point;
``price_rz_batch`` vmaps it over a batch of contracts (strike / cost-rate /
spot grids — the "pricing desk" serving workload).  Capacity overflow is
reported via the returned ``max_pieces``; callers assert it fits.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import pwl as P
from .lattice import LatticeModel
from .payoff import PayoffProcess
from .platform import resolve_interpret

__all__ = ["price_rz", "price_rz_batch", "rz_backward", "rz_level_step",
           "rz_level_step_lanes", "rz_backward_pallas", "RZResult",
           "RZ_BACKENDS"]

RZ_BACKENDS = ("jnp", "pallas")


@dataclasses.dataclass
class RZResult:
    ask: float
    bid: float
    max_pieces: int


def _benign(capacity: int, dtype) -> P.PWL:
    return P.make_affine(jnp.zeros((), dtype), jnp.zeros((), dtype), capacity, dtype)


def _select(mask, f_new: P.PWL, f_old: P.PWL) -> P.PWL:
    """Per-lane select between two PWL batches.

    ``mask`` broadcasts right-aligned against the batch dims (so a plain
    ``(P,)`` lane mask also serves a fused ``(2, P)`` seller+buyer
    state); the knot leaves carry one extra capacity axis, where the mask
    gains a trailing axis instead.
    """
    batch_ndim = f_new.sl.ndim
    pick = lambda a, b: jnp.where(
        mask[..., None] if a.ndim == batch_ndim + 1 else mask, a, b)
    return P.PWL(pick(f_new.xs, f_old.xs), pick(f_new.ys, f_old.ys),
                 pick(f_new.sl, f_old.sl), pick(f_new.sr, f_old.sr),
                 pick(f_new.m, f_old.m))


def _shift_up(f: P.PWL) -> P.PWL:
    """Lane i <- lane i+1 (the up-move child) along the node axis.

    The node axis is the LAST batch axis (``sl.ndim - 1``): a plain level
    state is ``(P,)``, the fused seller+buyer walk carries ``(2, P)``,
    and each side's lanes roll independently.
    """
    axis = f.sl.ndim - 1
    sh = lambda a: jnp.roll(a, -1, axis=axis)
    return P.PWL(sh(f.xs), sh(f.ys), sh(f.sl), sh(f.sr), sh(f.m))


def rz_level_step_lanes(z: P.PWL, lvl, params, *, capacity: int, seller,
                        payoff: PayoffProcess, dtype, idx_offset=0):
    """One backward level update, returning *per-lane* piece counts.

    z: PWL batch whose LAST batch axis is the node axis (P lanes);  lvl:
    scalar level index (traced); params: dict with s0, sig_sqrt_dt, r, k.
    ``idx_offset`` maps local lane j to global tree column idx_offset + j
    (used by the sharded engine and the blocked Pallas kernel).

    ``seller`` is a python bool (single-side batch, the historical form)
    or a traced boolean array broadcastable over the batch dims — e.g.
    ``jnp.array([True, False])[:, None]`` with a ``(2, P)`` state walks
    the seller (max/expense) and buyer (min/-expense) recursions in ONE
    fused pass: on this CPU the PWL ops are op-overhead-bound, so halving
    the op count per level is nearly a 2x on the whole backward walk.

    Returns (z_new, pieces) with ``pieces`` an int32 array over the batch
    (0 on non-live lanes) so callers that only own a sub-range of the
    lanes (kernel halos) can mask before reducing.
    """
    P_nodes = z.sl.shape[-1]
    idx = idx_offset + jnp.arange(P_nodes, dtype=dtype)  # (P,), broadcasts
    live = idx <= lvl                                  # lvl+1 valid nodes
    s = params["s0"] * jnp.exp((2.0 * idx - lvl) * params["sig_sqrt_dt"])
    no_tc = lvl == 0                                   # no costs at t = 0
    a = jnp.where(no_tc, s, (1.0 + params["k"]) * s)
    b = jnp.where(no_tc, s, (1.0 - params["k"]) * s)

    w, m1 = P.envelope2(_shift_up(z), z, capacity, take_max=True)
    w = P.scale(w, 1.0 / params["r"])
    v, m2 = P.cone_infconv(w, a, b, capacity)
    if isinstance(seller, bool):
        sign = 1.0 if seller else -1.0
    else:
        one = jnp.asarray(1.0, dtype)                  # keep the select in
        sign = jnp.where(seller, one, -one)            # `dtype`, not f64
    # the expense function's batch must match z's (v's) batch even when a
    # static `seller` leaves xi/zeta at the bare (P,) lane shape
    xi = jnp.broadcast_to(sign * payoff.xi(s), z.sl.shape)
    zeta = jnp.broadcast_to(sign * payoff.zeta(s), z.sl.shape)
    u = P.expense(xi, zeta, jnp.broadcast_to(a, z.sl.shape),
                  jnp.broadcast_to(b, z.sl.shape), capacity, dtype)
    z_new, m3 = P.envelope2(u, v, capacity, take_max=seller)

    z_out = _select(live, z_new, z)
    pieces = jnp.where(live, jnp.maximum(jnp.maximum(m1, m2), m3), 0)
    return z_out, pieces


def rz_level_step(z: P.PWL, lvl, params, *, capacity: int, seller: bool,
                  payoff: PayoffProcess, dtype, idx_offset=0):
    """One backward level update -> (z_new, max_pieces) (scalar reduce)."""
    z_out, pieces = rz_level_step_lanes(
        z, lvl, params, capacity=capacity, seller=seller, payoff=payoff,
        dtype=dtype, idx_offset=idx_offset)
    return z_out, jnp.max(pieces)


def _leaf_level(n_steps: int, params, capacity: int, dtype,
                lanes: int | None = None) -> P.PWL:
    """z at the extra instant t = N+1 with payoff (0, 0).

    ``lanes`` (>= n_steps + 2) overrides the node-axis extent — the
    blocked Pallas engine pads it to a multiple of its block size.
    """
    P_nodes = n_steps + 2 if lanes is None else lanes
    idx = jnp.arange(P_nodes, dtype=dtype)
    s = params["s0"] * jnp.exp((2.0 * idx - (n_steps + 1)) * params["sig_sqrt_dt"])
    a = (1.0 + params["k"]) * s
    b = (1.0 - params["k"]) * s
    zero = jnp.zeros((P_nodes,), dtype)
    return P.expense(zero, zero, a, b, capacity, dtype)


def rz_backward(s0, sigma, rate, maturity, k, *, n_steps: int, capacity: int,
                payoff: PayoffProcess, dtype=jnp.float64):
    """Traceable full backward recursion -> (ask, bid, max_pieces).

    Unlike :func:`price_rz` this is not jitted and ``payoff`` need not be
    hashable/static — its xi/zeta closures may capture traced values, which
    is what the scenario-grid engine (:mod:`repro.scenarios`) relies on to
    batch heterogeneous contracts through one compiled call.
    """
    from .partition import kernel_round_plan
    dt = maturity / n_steps
    params = dict(
        s0=s0, k=k,
        sig_sqrt_dt=sigma * jnp.sqrt(dt),
        r=jnp.exp(rate * dt),
    )
    # two structural speedups over the historical reference walk:
    #   * fused seller+buyer: one (2, P) state, per-side max/min selected
    #     by traced `seller` flags — half the ops per level of the old
    #     two-call body;
    #   * §4.2 lane shedding: the walk follows the same statically
    #     re-balanced round plan as the Pallas kernel (single-block
    #     rounds), so the lane extent shrinks with the live tree —
    #     ~N^2/2 lane-levels instead of dragging the full leaf width
    #     through every level (~N^2).
    plan = kernel_round_plan(n_steps)
    leaf = _leaf_level(n_steps, params, capacity, dtype, lanes=plan[0].lanes)
    z = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (2,) + a.shape),
                     leaf)
    sides = jnp.asarray([True, False])[:, None]        # seller, buyer
    pieces = jnp.zeros((), jnp.int32)

    for rnd in plan:
        z = jax.tree.map(lambda a, lanes=rnd.lanes: a[:, :lanes], z)
        lvl0 = jnp.asarray(float(rnd.lvl0), dtype)

        def body(j, carry, lvl0=lvl0):
            z, pieces = carry
            lvl = lvl0 - (j + 1).astype(dtype)
            z, pc = rz_level_step_lanes(z, lvl, params, capacity=capacity,
                                        seller=sides, payoff=payoff,
                                        dtype=dtype)
            return z, jnp.maximum(pieces, jnp.max(pc))

        z, pieces = jax.lax.fori_loop(0, rnd.depth, body, (z, pieces))

    root = lambda side: jax.tree.map(lambda a: a[side, 0], z)
    ask = P.eval_at(root(0), jnp.zeros((), dtype))
    bid = -P.eval_at(root(1), jnp.zeros((), dtype))
    return ask, bid, pieces


def rz_backward_pallas(s0, sigma, rate, maturity, k, *, n_steps: int,
                       capacity: int, payoff: PayoffProcess,
                       levels: int | None = None, block: int | None = None,
                       interpret: bool | None = None, dtype=jnp.float64):
    """Traceable TC backward recursion through the blocked Pallas kernel.

    Same contract as :func:`rz_backward` — (ask, bid, max_pieces) — but the
    level walk runs as ``kernels/rz_step.py`` rounds: each pallas_call
    advances a tile of lattice nodes ``D`` levels entirely in VMEM (the
    paper's §4 block/region rounds), with the round schedule — depth D and
    the re-balanced lane extent per round — picked statically by
    ``core/partition.py::kernel_round_plan``.

    Requires a payoff of the 4-parameter family (``payoff.params`` set):
    the kernel carries the payoff as scalar data, not closures.  ``block``
    of None runs one re-balanced block per round (no halo — the right
    choice whenever a whole level fits in VMEM); an explicit ``block``
    exercises the multi-block right-neighbour-halo scheme.
    """
    from .partition import kernel_round_plan
    from ..kernels.rz_step import rz_round
    if payoff.params is None:
        raise ValueError(
            f"backend='pallas' needs a 4-parameter-family payoff "
            f"(payoff.params set); {payoff.name!r} is closure-only. "
            "Use core.payoff.param_payoff / american_put / american_call / "
            "bull_spread, or backend='jnp'.")
    dt = maturity / n_steps
    params = dict(
        s0=s0, k=k,
        sig_sqrt_dt=sigma * jnp.sqrt(dt),
        r=jnp.exp(rate * dt),
    )
    plan = kernel_round_plan(n_steps, levels=levels, block=block)
    # fused sides: one (2, lanes) state, one pallas_call per round — the
    # kernel walks seller (max) and buyer (min) together, halving the op
    # and dispatch count exactly like the jnp backward
    leaf = _leaf_level(n_steps, params, capacity, dtype, lanes=plan[0].lanes)
    z = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (2,) + a.shape),
                     leaf)
    pieces = jnp.zeros((), jnp.int32)

    sc = [params["s0"], params["sig_sqrt_dt"], params["r"], params["k"],
          *payoff.params]
    for rnd in plan:
        # re-balance: shrink the lane extent to this round's live tree
        z = jax.tree.map(lambda a, lanes=rnd.lanes: a[:, :lanes], z)
        scalars = jnp.stack([jnp.asarray(v, dtype)
                             for v in (float(rnd.lvl0), *sc)])
        z, p = rz_round(z, scalars, levels=rnd.depth, block=rnd.block,
                        interpret=interpret)
        pieces = jnp.maximum(pieces, p)

    root = lambda side: jax.tree.map(lambda a: a[side, 0], z)
    ask = P.eval_at(root(0), jnp.zeros((), dtype))
    bid = -P.eval_at(root(1), jnp.zeros((), dtype))
    return ask, bid, pieces


@partial(jax.jit, static_argnames=("n_steps", "capacity", "payoff", "dtype",
                                   "backend", "levels", "block", "interpret"))
def _price_rz_jit(s0, sigma, rate, maturity, k, *, n_steps: int, capacity: int,
                  payoff: PayoffProcess, dtype=jnp.float64,
                  backend: str = "jnp", levels=None, block=None,
                  interpret: bool | None = None):
    if backend == "pallas":
        return rz_backward_pallas(s0, sigma, rate, maturity, k,
                                  n_steps=n_steps, capacity=capacity,
                                  payoff=payoff, levels=levels, block=block,
                                  interpret=interpret, dtype=dtype)
    if backend != "jnp":
        raise ValueError(f"unknown backend {backend!r}; use one of "
                         f"{RZ_BACKENDS}")
    return rz_backward(s0, sigma, rate, maturity, k, n_steps=n_steps,
                       capacity=capacity, payoff=payoff, dtype=dtype)


def price_rz(model: LatticeModel, payoff: PayoffProcess,
             capacity: int = 48, *, backend: str = "jnp",
             levels: int | None = None, block: int | None = None,
             interpret: bool | None = None) -> RZResult:
    """Jitted vectorised ask/bid under proportional transaction costs.

    ``backend="jnp"`` walks levels with ``lax.fori_loop`` over the full
    node axis; ``backend="pallas"`` runs the blocked VMEM rounds of
    :func:`rz_backward_pallas`.  Both report overflow identically via
    ``max_pieces`` / ``OverflowError``.  ``interpret=None`` resolves
    from the platform policy *here* — before the jit cache key — so a
    later ``set_platform`` never serves a stale compiled mode.
    """
    interpret = resolve_interpret(interpret)
    ask, bid, pieces = _price_rz_jit(
        jnp.float64(model.s0), jnp.float64(model.sigma), jnp.float64(model.rate),
        jnp.float64(model.maturity), jnp.float64(model.cost_rate),
        n_steps=model.n_steps, capacity=capacity, payoff=payoff,
        backend=backend, levels=levels, block=block, interpret=interpret)
    res = RZResult(ask=float(ask), bid=float(bid), max_pieces=int(pieces))
    if res.max_pieces > capacity:
        raise OverflowError(
            f"PWL capacity overflow: needed {res.max_pieces} > K={capacity}; "
            "re-run with a larger capacity")
    return res


@partial(jax.jit, static_argnames=("n_steps", "capacity", "payoff"))
def price_rz_batch(s0, sigma, rate, maturity, k, *, n_steps: int,
                   capacity: int, payoff: PayoffProcess):
    """vmap over a batch of contracts; inputs are broadcastable 1-D arrays.

    Returns (ask, bid, max_pieces) arrays — the serving-engine workhorse.
    """
    s0, sigma, rate, maturity, k = jnp.broadcast_arrays(
        *(jnp.atleast_1d(jnp.asarray(v, jnp.float64))
          for v in (s0, sigma, rate, maturity, k)))
    fn = lambda *args: _price_rz_jit(*args, n_steps=n_steps, capacity=capacity,
                                     payoff=payoff)
    return jax.vmap(fn)(s0, sigma, rate, maturity, k)
