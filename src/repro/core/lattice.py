"""Binomial lattice model parameters and geometry (paper §4.1).

Cox–Ross–Rubinstein calibration: over one of the N steps that discretise
[0, T],

    u = exp(sigma * sqrt(T/N)),   d = 1/u,   r = exp(R * T / N),

risk-neutral up probability p* = (r - d) / (u - d).  Stock price at the
node with level n (time step t = n) and column i (number of up-moves) is

    S(n, i) = S0 * u^i * d^(n-i) = S0 * u^(2i - n).

Proportional transaction costs: ask/bid stock prices S^a = (1+k) S,
S^b = (1-k) S; per the paper (and Perrakis–Lefoll / Roux–Zastawniak) no
transaction costs apply at t = 0, i.e. S^a_0 = S_0 = S^b_0.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["LatticeModel"]


@dataclasses.dataclass(frozen=True)
class LatticeModel:
    """Market/model parameters for one pricing problem."""
    s0: float          # spot at t=0
    sigma: float       # annualised volatility
    rate: float        # continuously compounded annual interest rate R
    maturity: float    # T in years
    n_steps: int       # N
    cost_rate: float = 0.0   # proportional transaction cost rate k in [0, 1)

    def __post_init__(self):
        if not (0.0 <= self.cost_rate < 1.0):
            raise ValueError("cost rate k must be in [0, 1)")
        if self.n_steps < 1:
            raise ValueError("need at least one time step")

    # one-step factors ---------------------------------------------------
    @property
    def u(self) -> float:
        return math.exp(self.sigma * math.sqrt(self.maturity / self.n_steps))

    @property
    def d(self) -> float:
        return 1.0 / self.u

    @property
    def r(self) -> float:
        return math.exp(self.rate * self.maturity / self.n_steps)

    @property
    def p_star(self) -> float:
        """Risk-neutral up-move probability (friction-free model)."""
        return (self.r - self.d) / (self.u - self.d)

    # geometry ------------------------------------------------------------
    def stock_level(self, n: int) -> np.ndarray:
        """Stock prices of all n+1 nodes at level n (float64)."""
        i = np.arange(n + 1, dtype=np.float64)
        return self.s0 * np.exp((2.0 * i - n) * self.sigma
                                * math.sqrt(self.maturity / self.n_steps))

    def ask_bid_level(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """(S^a, S^b) for all nodes at level n; no costs at n == 0."""
        s = self.stock_level(n)
        if n == 0:
            return s, s.copy()
        return (1.0 + self.cost_rate) * s, (1.0 - self.cost_rate) * s

    def with_(self, **kw) -> "LatticeModel":
        return dataclasses.replace(self, **kw)
