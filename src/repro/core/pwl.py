"""Fixed-capacity piecewise-linear function algebra — vectorised JAX.

The Roux–Zastawniak recursion carries one PWL function per lattice node.
A CPU implementation (and the paper's C one) uses per-node linked lists;
that does not vectorise.  Here every function is a fixed-capacity SoA
record so that a whole tree level is a handful of dense tensors and every
operation is a data-parallel kernel over nodes — the layout the TPU VPU
(and the Pallas kernels) want:

    xs : (..., K)  sorted knot abscissae, padding +BIG after the first m
    ys : (..., K)  knot values, padding 0
    sl : (...,)    slope left of the first knot
    sr : (...,)    slope right of the last knot
    m  : (...,)    int32 number of valid knots (>= 1)

Operations (all shape-static, jit/vmap-safe):

  * ``eval_at``       — evaluate at query points
  * ``envelope2``     — exact pointwise max/min of two functions
  * ``scale``         — positive scalar multiply (discounting)
  * ``cone_infconv``  — transaction-cost slope restriction
                        v(y) = min_{y'} [ f(y') + max(a(y'-y), b(y'-y)) ]
  * ``expense``       — the 2-piece expense function of §3 eq. (1)/(6)

The algebra is **sort-free**: every knot vector that reaches an envelope
or cone is already sorted (a maintained invariant of this module — see
``merge_sorted``), so instead of ``jnp.sort(jnp.concatenate(...))`` the
hot path uses merge-path rank computation (binary searches + gathers)
and compaction is a prefix-sum (cumsum-of-keep) map, applied as the
gather of its inverse.  No ``sort``/``argsort`` primitive appears in a
traced level step (jaxpr-asserted by ``tests/test_pwl_merge.py``), which
both speeds up the CPU hot path (measured numbers in
docs/ARCHITECTURE.md §3.2) and removes the sorts that kept the Pallas TC
kernel from ever lowering past interpret mode.

Capacity overflow is *detected*, never silent: every envelope returns the
raw knot count before truncation; engines carry the running max and the
caller asserts it fits K.  The exact oracle for everything here is
:mod:`repro.core.pwl_ref`.

Tolerance policy matches the oracle: slope comparisons are relative
(slopes are stock prices ~1e2; absolute 1e-12 tolerances make float noise
look like kinks and knot counts explode multiplicatively).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "PWL", "BIG", "make_affine", "expense", "eval_at", "scale",
    "envelope2", "cone_infconv", "merge_sorted", "from_ref", "to_ref",
]

BIG = 1e30
_REL = 1e-9
_TINY = 1e-300


def _tiny(dtype) -> float:
    """Positive-width guard threshold for ``dtype``.

    float64 keeps the historical 1e-300 (bit-compatible with every
    committed oracle/golden number); narrower dtypes get their own
    ``finfo.tiny`` — 1e-300 underflows to 0.0 in float32 and the guard
    would stop guarding.
    """
    if jnp.dtype(dtype) == jnp.float64:
        return _TINY
    return float(jnp.finfo(dtype).tiny)


def _iota32(n: int) -> jax.Array:
    """0..n-1 as int32 — index bookkeeping stays int32 regardless of x64.

    Every index vector in this module is bounded by the knot capacity
    (tens), so int32 is exact; keeping the traced dtype pinned is part of
    the kernels' lowering contract (Mosaic/Triton compiled paths carry no
    int64 — asserted by ``tests/test_lowering_contract.py``).
    """
    return jnp.arange(n, dtype=jnp.int32)


class PWL(NamedTuple):
    xs: jax.Array   # (..., K)
    ys: jax.Array   # (..., K)
    sl: jax.Array   # (...,)
    sr: jax.Array   # (...,)
    m: jax.Array    # (...,) int32

    @property
    def capacity(self) -> int:
        return self.xs.shape[-1]


# --------------------------------------------------------------------- #
# constructors
# --------------------------------------------------------------------- #
def make_affine(slope, value_at_0, capacity: int, dtype=jnp.float64) -> PWL:
    slope = jnp.asarray(slope, dtype)
    value_at_0 = jnp.asarray(value_at_0, dtype)
    shape = jnp.broadcast_shapes(slope.shape, value_at_0.shape)
    slope = jnp.broadcast_to(slope, shape)
    value_at_0 = jnp.broadcast_to(value_at_0, shape)
    xs = jnp.full(shape + (capacity,), BIG, dtype)
    xs = xs.at[..., 0].set(0.0)
    ys = jnp.zeros(shape + (capacity,), dtype)
    ys = ys.at[..., 0].set(value_at_0)
    return PWL(xs, ys, slope, slope, jnp.ones(shape, jnp.int32))


def expense(xi, zeta, s_ask, s_bid, capacity: int, dtype=jnp.float64) -> PWL:
    """u(y) = xi + (y - zeta)^- s_ask - (y - zeta)^+ s_bid  (knot at zeta)."""
    xi, zeta, s_ask, s_bid = (jnp.asarray(v, dtype) for v in (xi, zeta, s_ask, s_bid))
    shape = jnp.broadcast_shapes(xi.shape, zeta.shape, s_ask.shape, s_bid.shape)
    xi = jnp.broadcast_to(xi, shape)
    zeta = jnp.broadcast_to(zeta, shape)
    xs = jnp.full(shape + (capacity,), BIG, dtype)
    xs = xs.at[..., 0].set(zeta)
    ys = jnp.zeros(shape + (capacity,), dtype)
    ys = ys.at[..., 0].set(xi)
    return PWL(xs, ys,
               -jnp.broadcast_to(s_ask, shape), -jnp.broadcast_to(s_bid, shape),
               jnp.ones(shape, jnp.int32))


# --------------------------------------------------------------------- #
# sort-free merge of already-sorted knot vectors (merge-path ranks)
# --------------------------------------------------------------------- #
def _searchsorted(a: jax.Array, v: jax.Array, side: str) -> jax.Array:
    """Ranks of ``v`` in the ascending 1-D vector ``a`` — binary search.

    ``side="right"`` is exactly the ``sum(a <= v)`` counting the module
    used to compute with O(len(a)) comparison rows per query;
    ``side="left"`` is ``sum(a < v)``.  The unrolled binary search is
    log2(len(a)) gathers per query — ~4x cheaper at K=24..97 on CPU (the
    counting matrices were the memory-traffic hot spot, not the sorts
    alone) and free of ``sort``/``scan`` primitives.

    Hand-rolled rather than ``jnp.searchsorted``: the stock lowering
    carries int64 rank bookkeeping under x64, and the compiled-path
    lowering contract pins every index dtype in the kernels to int32
    (capacities are tens of knots, so int32 is exact).
    """
    n = a.shape[-1]
    lo = jnp.zeros(v.shape, jnp.int32)
    hi = jnp.full(v.shape, n, jnp.int32)
    for _ in range(max(1, int(n).bit_length())):       # ceil(log2(n+1))
        active = lo < hi
        mid = (lo + hi) >> 1
        am = a[jnp.clip(mid, 0, n - 1)]
        go_right = (am <= v) if side == "right" else (am < v)
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    return lo


def _merge_take(a: jax.Array, b: jax.Array, *payloads):
    """Merge ascending ``a`` and ``b``; route per-element payloads along.

    Merge-path rank computation instead of ``jnp.sort(concatenate(...))``:
    element ``a[i]`` lands at output rank ``ra[i] = i + |{j : b[j] <
    a[i]}|`` (a stable merge — ties keep every copy from ``a`` first), so
    the output position ``k`` is fed by ``a`` exactly when ``cnt_a(k) =
    |{i : ra[i] <= k}|`` steps up.  Both rank vectors come from binary
    searches (no ``sort`` primitive) and outputs are materialised by
    gathers — gathers, not the textbook rank *scatter*, because XLA:CPU
    serialises scatters while these batched gathers vectorise (and
    gathers are the smaller ask of a future Mosaic lowering).  BIG
    padding tails compare like any other value and merge to the back, so
    fixed-capacity PWL knot vectors merge without masking.

    Each payload is a ``(pa, pb)`` pair (values riding with ``a``'s /
    ``b``'s elements); returns ``(merged, *merged_payloads)``.  Both key
    vectors MUST already be ascending — the maintained invariant of every
    knot vector in this module; out-of-order inputs produce garbage
    (guarded by the oracle-differential tests in
    ``tests/test_pwl_merge.py``, not at runtime).
    """
    na, nb = a.shape[-1], b.shape[-1]
    ra = _iota32(na) + _searchsorted(b, a, "left")
    k = _iota32(na + nb)
    cnt_a = _searchsorted(ra, k, "right")    # ra is ascending by construction
    ia = jnp.clip(cnt_a - 1, 0, na - 1)
    ib = jnp.clip(k - cnt_a, 0, nb - 1)
    prev = jnp.concatenate([jnp.zeros((1,), cnt_a.dtype), cnt_a[:-1]])
    from_a = cnt_a > prev
    pick = lambda pa, pb: jnp.where(from_a, pa[ia], pb[ib])
    return (pick(a, b), *(pick(pa, pb) for pa, pb in payloads))


def _merge_take_bysort(a: jax.Array, b: jax.Array, *payloads):
    """Pre-merge-path implementation (stable argsort of the concat).

    Retained ONLY as the differential-testing reference: monkeypatching
    ``_merge_take``/``_compact`` to the ``*_bysort`` pair reconstructs
    the sort-based engine bit-for-bit (``tests/test_pwl_merge.py``) —
    stable argsort keeps ``a``'s copies first on ties, the same rule as
    the merge-path ranks.  Not used by the hot path.
    """
    order = jnp.argsort(jnp.concatenate([a, b]))
    out = (jnp.concatenate([a, b])[order],)
    for pa, pb in payloads:
        out += (jnp.concatenate([pa, pb])[order],)
    return out


def merge_sorted(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sort-free merge of two ascending knot vectors (see _merge_take)."""
    return _merge_take(a, b)[0]


# --------------------------------------------------------------------- #
# evaluation  (single function: xs (K,); use jax.vmap for batches)
# --------------------------------------------------------------------- #
def _interval_slope(f: PWL, c: jax.Array):
    """Shared interior-interval machinery of ``_eval1``/``_slope1``.

    Returns (cnt, il, slope_in).  Coincident consecutive knots make the
    interval width w == 0; the former ``dy / max(w, 1e-300)`` blew up to
    ±huge/inf there and could turn into NaN (0 * inf) in downstream
    products *before* the selecting ``jnp.where`` masked the lane — which
    is unsafe under NaN propagation (and poisons jvp/vjp through the
    untaken branch).  Guard the width on both sides of the divide instead:
    degenerate intervals get slope 0, and they are never the selected
    branch (selection implies xs[il] <= c < xs[ir], hence w > 0).
    """
    K = f.xs.shape[-1]
    cnt = _searchsorted(f.xs, c, "right")                        # (C,)
    il = jnp.clip(cnt - 1, 0, K - 1)
    ir = jnp.clip(cnt, 0, K - 1)
    w = f.xs[ir] - f.xs[il]
    ok_w = w > _tiny(f.xs.dtype)
    slope_in = jnp.where(ok_w, f.ys[ir] - f.ys[il], 0.0) \
        / jnp.where(ok_w, w, 1.0)
    return cnt, il, slope_in


def _eval1(f: PWL, c: jax.Array) -> jax.Array:
    """Evaluate one function at query points c: (C,) -> (C,)."""
    K = f.xs.shape[-1]
    cnt, il, slope_in = _interval_slope(f, c)
    v_in = f.ys[il] + slope_in * (c - f.xs[il])
    ilast = jnp.clip(f.m - 1, 0, K - 1)
    v_l = f.ys[0] + f.sl * (c - f.xs[0])
    v_r = f.ys[ilast] + f.sr * (c - f.xs[ilast])
    return jnp.where(cnt == 0, v_l, jnp.where(cnt >= f.m, v_r, v_in))


def _slope1(f: PWL, c: jax.Array) -> jax.Array:
    """Slope at (non-knot) query points c: (C,) -> (C,)."""
    cnt, _, slope_in = _interval_slope(f, c)
    return jnp.where(cnt == 0, f.sl, jnp.where(cnt >= f.m, f.sr, slope_in))


def eval_at(f: PWL, c) -> jax.Array:
    """Batched evaluation: f has leading batch dims, c broadcasts over them."""
    c = jnp.asarray(c, f.xs.dtype)
    batch = f.sl.shape
    if batch == ():
        return _eval1(f, jnp.atleast_1d(c))[0] if c.ndim == 0 else _eval1(f, c)
    cb = jnp.broadcast_to(c, batch)
    flat = jax.vmap(lambda ff, cc: _eval1(ff, cc[None])[0])
    f2 = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[len(batch):]), f)
    out = flat(f2, cb.reshape(-1))
    return out.reshape(batch)


# --------------------------------------------------------------------- #
# scaling (discounting)
# --------------------------------------------------------------------- #
def scale(f: PWL, alpha) -> PWL:
    """alpha * f with alpha > 0 (shape-preserving)."""
    alpha = jnp.asarray(alpha, f.ys.dtype)
    return PWL(f.xs, f.ys * alpha[..., None], f.sl * alpha, f.sr * alpha, f.m)


# --------------------------------------------------------------------- #
# compression: dedupe + drop collinear knots + compact to capacity
# --------------------------------------------------------------------- #
def _compact(xs, ys, keep):
    """Stable-compact kept knots to the front; returns padded xs, ys, m.

    Sort-free: kept knots are a subsequence of an already-ascending ``xs``
    (the module invariant), so their stable order is their input order —
    the prefix sum of the keep mask IS the compaction map, replacing the
    former stable-``argsort`` compaction bit-for-bit (kept knots to the
    front, exact-BIG / 0.0 padding behind).  The map is applied as a
    gather of its inverse (source of output slot ``t`` = rank of ``t+1``
    in the cumsum) rather than a position scatter: XLA:CPU serialises
    scatters, while the batched gather vectorises.
    """
    n = xs.shape[0]
    m2 = jnp.sum(keep, dtype=jnp.int32)
    ps = jnp.cumsum(keep, dtype=jnp.int32)           # kept-so-far, 1-based
    t = _iota32(n)
    src = jnp.clip(_searchsorted(ps, t + 1, "left"), 0, n - 1)
    live = t < m2
    xs2 = jnp.where(live, xs[src], BIG)
    ys2 = jnp.where(live, ys[src], 0.0)
    return xs2, ys2, m2


def _compact_bysort(xs, ys, keep):
    """Pre-merge-path stable-argsort compaction (differential tests only)."""
    key = jnp.where(keep, xs, BIG)
    order = jnp.argsort(key)          # stable; BIG (dropped) sorts to the end
    xs2 = key[order]
    ys2 = ys[order]
    m2 = jnp.sum(keep).astype(jnp.int32)
    idx = jnp.arange(xs.shape[0])
    ys2 = jnp.where(idx < m2, ys2, 0.0)
    return xs2, ys2, m2


def _compress1(xs, ys, sl, sr, valid, out_cap: int):
    """xs sorted with invalid -> BIG; returns (PWL of capacity out_cap, m_raw).

    Both passes (duplicate merge, kink-only retention) are decided on the
    RAW candidate array — the kink test's "previous/next surviving knot"
    neighbours come from prefix/suffix index scans (cummax/cummin), not
    from materialising the intermediate compaction — so only ONE compact
    runs per compress, at the very end.  Values match the historical
    compact-twice pipeline exactly: neighbours are the same elements.
    """
    n = xs.shape[0]
    idx = _iota32(n)
    # pass 1: merge (near-)duplicate knots, keep the first of each run
    prev_x = jnp.concatenate([jnp.full((1,), -BIG, xs.dtype), xs[:-1]])
    prev_valid = jnp.concatenate([jnp.zeros((1,), bool), valid[:-1]])
    dup = valid & prev_valid & (xs - prev_x <= _REL * (1.0 + jnp.abs(prev_x)))
    keep1 = valid & ~dup
    m1 = jnp.sum(keep1, dtype=jnp.int32)
    rank = jnp.cumsum(keep1, dtype=jnp.int32) - 1  # rank among pass-1 survivors
    # pass 2: drop knots where the slope does not genuinely change.
    # neighbour indices among survivors: next = suffix-min of kept indices
    # (exclusive), prev = prefix-max (exclusive)
    ni = jnp.concatenate([
        jax.lax.cummin(jnp.where(keep1, idx, n), reverse=True)[1:],
        jnp.full((1,), n, idx.dtype)])
    pi = jnp.concatenate([
        jnp.full((1,), -1, idx.dtype),
        jax.lax.cummax(jnp.where(keep1, idx, -1))[:-1]])
    nig = jnp.clip(ni, 0, n - 1)
    pig = jnp.clip(pi, 0, n - 1)
    tiny = _tiny(xs.dtype)
    s_right = jnp.where(keep1 & (rank < m1 - 1),
                        (ys[nig] - ys) / jnp.maximum(xs[nig] - xs, tiny), sr)
    s_left = jnp.where(keep1 & (rank > 0),
                       (ys - ys[pig]) / jnp.maximum(xs - xs[pig], tiny), sl)
    tol = _REL * (1.0 + jnp.maximum(jnp.abs(s_left), jnp.abs(s_right)))
    kink = jnp.abs(s_right - s_left) > tol
    keep2 = keep1 & kink
    # always retain at least one (anchor) knot: the first survivor
    keep2 = jnp.where(jnp.any(keep2), keep2, keep1 & (rank == 0))
    xs2, ys2, m2 = _compact(xs, ys, keep2)
    out = PWL(xs2[:out_cap], ys2[:out_cap], sl, sr,
              jnp.minimum(m2, out_cap))
    return out, m2


# --------------------------------------------------------------------- #
# pointwise max / min of two functions (exact, incl. crossing knots)
# --------------------------------------------------------------------- #
def _envelope1(f: PWL, g: PWL, out_cap: int, take_max):
    """Pointwise max/min — one payload merge, no per-candidate re-evals.

    Every knot of ``f`` is in the merged knot vector, so ``f`` is linear
    between consecutive merged knots; merging *with the functions' values
    as payload* therefore hands us everything per interval: the exact
    slopes (finite differences of the merged values), the crossing
    positions (anchored at the interval's left knot) and the envelope
    values at every candidate — without ever evaluating f or g at the
    ~4K candidate points like the pre-merge-path engine did.  The only
    evaluations left are each function at the *other's* knots (the
    payload seeds) and the two end-slope probes.
    """
    vfg = _eval1(f, g.xs)                  # f at g's knots (payload seed)
    vgf = _eval1(g, f.xs)                  # g at f's knots (payload seed)
    merged, vf, vg = _merge_take(f.xs, g.xs, (f.ys, vfg), (vgf, g.ys))
    return _envelope_core(f, g, merged, vf, vg, f.m + g.m, out_cap,
                          take_max)


def _interleave(a: jax.Array, b: jax.Array) -> jax.Array:
    """[a0, b0, a1, b1, ..., a_{n-1}] for a: (n,), b: (n-1,) — pure reshape.

    (b is padded with one dummy slot that the final slice drops.)
    """
    n = a.shape[0]
    pad = jnp.concatenate([b, jnp.zeros((1,), b.dtype)])
    return jnp.stack([a, pad], axis=1).reshape(2 * n)[:2 * n - 1]


def _envelope_core(f: PWL, g: PWL, merged, vf, vg, mv, out_cap: int,
                   take_max):
    """Envelope given the merged knot grid and both values on it.

    ``merged`` must contain every valid knot of BOTH functions (so each is
    linear between consecutive grid points); ``vf``/``vg`` are their
    values on the grid and ``mv`` its valid-knot count.  The crossing in
    interval i lies strictly between grid points i-1 and i, so crossings
    and grid knots interleave by construction — assembling the candidate
    vector is ONE compact of the interleaved array, not a merge.
    """
    M = merged.shape[0]
    # interval i = 0..M is (merged[i-1], merged[i]), unbounded at both ends
    i_idx = _iota32(M + 1)
    lo = jnp.where(i_idx == 0, -BIG, merged[jnp.clip(i_idx - 1, 0, M - 1)])
    hi = jnp.where(i_idx >= mv, BIG, merged[jnp.clip(i_idx, 0, M - 1)])
    # exact per-interval slopes from the merged values (guarded widths:
    # coincident knots across f/g give zero-width intervals whose slope
    # is never used — their crossing window (lo+margin, hi-margin) is
    # empty — but must not divide by ~0)
    dx = jnp.diff(merged)
    ok_dx = dx > _tiny(merged.dtype)
    inv_dx = 1.0 / jnp.where(ok_dx, dx, 1.0)
    sf_mid = jnp.where(ok_dx, jnp.diff(vf), 0.0) * inv_dx
    sg_mid = jnp.where(ok_dx, jnp.diff(vg), 0.0) * inv_dx
    sf = jnp.concatenate([f.sl[None], sf_mid, f.sr[None]])
    sg = jnp.concatenate([g.sl[None], sg_mid, g.sr[None]])
    sf = jnp.where(i_idx >= mv, f.sr, sf)    # beyond the last live knot
    sg = jnp.where(i_idx >= mv, g.sr, sg)
    denom = sf - sg
    parallel = jnp.abs(denom) <= _REL * (1.0 + jnp.maximum(jnp.abs(sf), jnp.abs(sg)))
    # crossing anchored at the interval's left knot (right knot for the
    # unbounded-left interval 0): x* solves vf + sf (x-ax) = vg + sg (x-ax)
    ai = jnp.clip(i_idx - 1, 0, M - 1)
    ax, avf, avg = merged[ai], vf[ai], vg[ai]
    x_cross = ax + (avg - avf) / jnp.where(parallel, 1.0, denom)
    margin = _REL * (1.0 + jnp.abs(x_cross))
    inside = (x_cross > lo + margin) & (x_cross < hi - margin)
    ok = (~parallel) & inside & (i_idx <= mv)
    # the crossing of interval i sits strictly between grid knots i-1 and
    # i: candidates = [cross_0, knot_0, cross_1, knot_1, ...] are already
    # in order once the dropped entries go — ONE compact, no sort, no
    # merge.  Payloads: grid knots carry max/min of the two values, a
    # crossing carries the common value of f and g there.
    cross = jnp.where(ok, x_cross, BIG)
    cross_v = jnp.where(ok, avf + sf * (x_cross - ax), 0.0)
    if isinstance(take_max, bool):               # static: fused max OR min
        hk = jnp.maximum(vf, vg) if take_max else jnp.minimum(vf, vg)
    else:                                        # traced: per-lane select
        hk = jnp.where(take_max, jnp.maximum(vf, vg), jnp.minimum(vf, vg))
    raw = _interleave(cross, merged)                            # (2M+1,)
    raw_v = _interleave(cross_v, hk)
    raw_keep = _interleave(ok, i_idx[:-1] < mv)
    cands, hv, _ = _compact(raw, raw_v, raw_keep)
    valid = cands < BIG / 2
    # end slopes from probes beyond the outermost *candidates* (crossings
    # can lie outside the span of the input knots)
    nvc = jnp.sum(valid, dtype=jnp.int32)
    pl = cands[0] - 1.0
    pr = cands[jnp.clip(nvc - 1, 0, cands.shape[0] - 1)] + 1.0
    probes = jnp.stack([pl, pr])
    fl, fr = _eval1(f, probes)
    gl, gr = _eval1(g, probes)
    tie_l = jnp.abs(fl - gl) <= _REL * (1.0 + jnp.maximum(jnp.abs(fl), jnp.abs(gl)))
    tie_r = jnp.abs(fr - gr) <= _REL * (1.0 + jnp.maximum(jnp.abs(fr), jnp.abs(gr)))
    if isinstance(take_max, bool):
        if take_max:
            sl = jnp.where(tie_l, jnp.minimum(f.sl, g.sl),
                           jnp.where(fl > gl, f.sl, g.sl))
            sr = jnp.where(tie_r, jnp.maximum(f.sr, g.sr),
                           jnp.where(fr > gr, f.sr, g.sr))
        else:
            sl = jnp.where(tie_l, jnp.maximum(f.sl, g.sl),
                           jnp.where(fl < gl, f.sl, g.sl))
            sr = jnp.where(tie_r, jnp.minimum(f.sr, g.sr),
                           jnp.where(fr < gr, f.sr, g.sr))
    else:
        sl = jnp.where(
            tie_l,
            jnp.where(take_max, jnp.minimum(f.sl, g.sl),
                      jnp.maximum(f.sl, g.sl)),
            jnp.where(jnp.where(take_max, fl > gl, fl < gl), f.sl, g.sl))
        sr = jnp.where(
            tie_r,
            jnp.where(take_max, jnp.maximum(f.sr, g.sr),
                      jnp.minimum(f.sr, g.sr)),
            jnp.where(jnp.where(take_max, fr > gr, fr < gr), f.sr, g.sr))
    hv = jnp.where(valid, hv, 0.0)
    return _compress1(cands, hv, sl, sr, valid, out_cap)


def envelope2(f: PWL, g: PWL, out_cap: int, take_max):
    """Pointwise max/min.  Batched over leading dims; returns (PWL, m_raw).

    ``take_max`` is a python bool (static — the usual case) or a traced
    boolean array broadcastable over the batch dims: per-lane max/min
    selection, which is what lets one fused level step carry the seller
    (max) and buyer (min) sides of the recursion in a single batch
    (``core/rz.py::rz_level_step_lanes`` with a ``seller`` array).
    """
    batch = f.sl.shape
    if isinstance(take_max, bool):
        if batch == ():
            return _envelope1(f, g, out_cap, take_max)
        fn = lambda ff, gg: _envelope1(ff, gg, out_cap, take_max)
        for _ in batch:
            fn = jax.vmap(fn)
        return fn(f, g)
    tm = jnp.broadcast_to(jnp.asarray(take_max, bool), batch)
    if batch == ():
        return _envelope1(f, g, out_cap, tm)
    fn = lambda ff, gg, t: _envelope1(ff, gg, out_cap, t)
    for _ in batch:
        fn = jax.vmap(fn)
    return fn(f, g, tm)


# --------------------------------------------------------------------- #
# transaction-cost slope restriction (inf-convolution with the cost cone)
# --------------------------------------------------------------------- #
def _cone1(f: PWL, a, b, out_cap: int):
    """v = min(f, lower envelope of the V_j cones); exact (see pwl_ref)."""
    K = f.xs.shape[-1]
    dtype = f.xs.dtype
    idx = _iota32(K)
    valid = idx < f.m
    A = jnp.where(valid, f.ys + a * f.xs, BIG)
    Bv = jnp.where(valid, f.ys + b * f.xs, BIG)
    SA = jax.lax.cummin(A, reverse=True)       # suffix min of ys + a*xs
    PB = jax.lax.cummin(Bv)                    # prefix min of ys + b*xs
    # crossing candidate inside each bounded interval (xs_j, xs_{j+1})
    nxt_x = jnp.concatenate([f.xs[1:], jnp.full((1,), BIG, dtype)])
    nxt_SA = jnp.concatenate([SA[1:], jnp.full((1,), BIG, dtype)])
    denom = a - b
    par = jnp.abs(denom) <= _REL * (1.0 + jnp.abs(a))
    ystar = (nxt_SA - PB) / jnp.where(par, 1.0, denom)
    margin = _REL * (1.0 + jnp.abs(ystar))
    ok = ((~par) & (idx + 1 < f.m) & (nxt_SA < BIG / 2) & (PB < BIG / 2)
          & (ystar > f.xs + margin) & (ystar < nxt_x - margin))
    # candidates: the crossing of interval j sits strictly between knots
    # j and j+1, so [x_0, ystar_0, x_1, ystar_1, ...] is already ordered
    # once dropped entries go — one compact builds the env grid, no merge
    cross = jnp.where(ok, ystar, BIG)
    cands, _, menv = _compact(_interleave(f.xs, cross[:-1]),
                              jnp.zeros((2 * K - 1,), dtype),
                              _interleave(valid, ok[:-1]))
    cvalid = cands < BIG / 2
    # env(c) = min(-a c + SA(c), -b c + PB(c))
    ge = _searchsorted(f.xs, cands, "left")                     # knots < c
    le = _searchsorted(f.xs, cands, "right")                    # knots <= c
    SA_at = jnp.where(ge < f.m, SA[jnp.clip(ge, 0, K - 1)], BIG)
    PB_at = jnp.where(le > 0, PB[jnp.clip(le - 1, 0, K - 1)], BIG)
    env_v = jnp.minimum(jnp.where(SA_at < BIG / 2, -a * cands + SA_at, BIG),
                        jnp.where(PB_at < BIG / 2, -b * cands + PB_at, BIG))
    env_v = jnp.where(cvalid, env_v, 0.0)
    env = PWL(cands, env_v, -a * jnp.ones((), dtype), -b * jnp.ones((), dtype),
              menv)
    # env's grid contains every valid knot of f (it was built from them),
    # so min(f, env) needs NO knot merge: evaluate f on env's grid and run
    # the envelope core directly — 2K-wide instead of the 3K-wide merge
    # the generic path would do.
    vf = _eval1(f, cands)
    return _envelope_core(f, env, cands, vf, env_v, menv, out_cap,
                          take_max=False)


def cone_infconv(f: PWL, a, b, out_cap: int):
    """Batched slope restriction; a, b broadcast over batch. (PWL, m_raw)."""
    batch = f.sl.shape
    a = jnp.broadcast_to(jnp.asarray(a, f.xs.dtype), batch)
    b = jnp.broadcast_to(jnp.asarray(b, f.xs.dtype), batch)
    if batch == ():
        return _cone1(f, a, b, out_cap)
    fn = lambda ff, aa, bb: _cone1(ff, aa, bb, out_cap)
    for _ in batch:
        fn = jax.vmap(fn)
    return fn(f, a, b)


# --------------------------------------------------------------------- #
# conversions to/from the exact oracle (testing)
# --------------------------------------------------------------------- #
def from_ref(ref, capacity: int, dtype=jnp.float64) -> PWL:
    import numpy as np
    m = ref.m
    if m > capacity:
        raise ValueError(f"oracle function has {m} knots > capacity {capacity}")
    xs = np.full((capacity,), BIG)
    ys = np.zeros((capacity,))
    xs[:m] = ref.xs
    ys[:m] = ref.ys
    return PWL(jnp.asarray(xs, dtype), jnp.asarray(ys, dtype),
               jnp.asarray(ref.s_left, dtype), jnp.asarray(ref.s_right, dtype),
               jnp.asarray(m, jnp.int32))


def to_ref(f: PWL):
    import numpy as np
    from .pwl_ref import PWLRef
    m = int(f.m)
    return PWLRef(np.asarray(f.xs[:m]), np.asarray(f.ys[:m]),
                  float(f.sl), float(f.sr))
