"""Fixed-capacity piecewise-linear function algebra — vectorised JAX.

The Roux–Zastawniak recursion carries one PWL function per lattice node.
A CPU implementation (and the paper's C one) uses per-node linked lists;
that does not vectorise.  Here every function is a fixed-capacity SoA
record so that a whole tree level is a handful of dense tensors and every
operation is a data-parallel kernel over nodes — the layout the TPU VPU
(and the Pallas kernels) want:

    xs : (..., K)  sorted knot abscissae, padding +BIG after the first m
    ys : (..., K)  knot values, padding 0
    sl : (...,)    slope left of the first knot
    sr : (...,)    slope right of the last knot
    m  : (...,)    int32 number of valid knots (>= 1)

Operations (all shape-static, jit/vmap-safe):

  * ``eval_at``       — evaluate at query points
  * ``envelope2``     — exact pointwise max/min of two functions
  * ``scale``         — positive scalar multiply (discounting)
  * ``cone_infconv``  — transaction-cost slope restriction
                        v(y) = min_{y'} [ f(y') + max(a(y'-y), b(y'-y)) ]
  * ``expense``       — the 2-piece expense function of §3 eq. (1)/(6)

Capacity overflow is *detected*, never silent: every envelope returns the
raw knot count before truncation; engines carry the running max and the
caller asserts it fits K.  The exact oracle for everything here is
:mod:`repro.core.pwl_ref`.

Tolerance policy matches the oracle: slope comparisons are relative
(slopes are stock prices ~1e2; absolute 1e-12 tolerances make float noise
look like kinks and knot counts explode multiplicatively).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "PWL", "BIG", "make_affine", "expense", "eval_at", "scale",
    "envelope2", "cone_infconv", "from_ref", "to_ref",
]

BIG = 1e30
_REL = 1e-9
_TINY = 1e-300


class PWL(NamedTuple):
    xs: jax.Array   # (..., K)
    ys: jax.Array   # (..., K)
    sl: jax.Array   # (...,)
    sr: jax.Array   # (...,)
    m: jax.Array    # (...,) int32

    @property
    def capacity(self) -> int:
        return self.xs.shape[-1]


# --------------------------------------------------------------------- #
# constructors
# --------------------------------------------------------------------- #
def make_affine(slope, value_at_0, capacity: int, dtype=jnp.float64) -> PWL:
    slope = jnp.asarray(slope, dtype)
    value_at_0 = jnp.asarray(value_at_0, dtype)
    shape = jnp.broadcast_shapes(slope.shape, value_at_0.shape)
    slope = jnp.broadcast_to(slope, shape)
    value_at_0 = jnp.broadcast_to(value_at_0, shape)
    xs = jnp.full(shape + (capacity,), BIG, dtype)
    xs = xs.at[..., 0].set(0.0)
    ys = jnp.zeros(shape + (capacity,), dtype)
    ys = ys.at[..., 0].set(value_at_0)
    return PWL(xs, ys, slope, slope, jnp.ones(shape, jnp.int32))


def expense(xi, zeta, s_ask, s_bid, capacity: int, dtype=jnp.float64) -> PWL:
    """u(y) = xi + (y - zeta)^- s_ask - (y - zeta)^+ s_bid  (knot at zeta)."""
    xi, zeta, s_ask, s_bid = (jnp.asarray(v, dtype) for v in (xi, zeta, s_ask, s_bid))
    shape = jnp.broadcast_shapes(xi.shape, zeta.shape, s_ask.shape, s_bid.shape)
    xi = jnp.broadcast_to(xi, shape)
    zeta = jnp.broadcast_to(zeta, shape)
    xs = jnp.full(shape + (capacity,), BIG, dtype)
    xs = xs.at[..., 0].set(zeta)
    ys = jnp.zeros(shape + (capacity,), dtype)
    ys = ys.at[..., 0].set(xi)
    return PWL(xs, ys,
               -jnp.broadcast_to(s_ask, shape), -jnp.broadcast_to(s_bid, shape),
               jnp.ones(shape, jnp.int32))


# --------------------------------------------------------------------- #
# evaluation  (single function: xs (K,); use jax.vmap for batches)
# --------------------------------------------------------------------- #
def _eval1(f: PWL, c: jax.Array) -> jax.Array:
    """Evaluate one function at query points c: (C,) -> (C,)."""
    K = f.xs.shape[-1]
    cnt = jnp.sum(f.xs[None, :] <= c[:, None], axis=-1)          # (C,)
    il = jnp.clip(cnt - 1, 0, K - 1)
    ir = jnp.clip(cnt, 0, K - 1)
    w = f.xs[ir] - f.xs[il]
    slope_in = (f.ys[ir] - f.ys[il]) / jnp.maximum(w, _TINY)
    v_in = f.ys[il] + slope_in * (c - f.xs[il])
    ilast = jnp.clip(f.m - 1, 0, K - 1)
    v_l = f.ys[0] + f.sl * (c - f.xs[0])
    v_r = f.ys[ilast] + f.sr * (c - f.xs[ilast])
    return jnp.where(cnt == 0, v_l, jnp.where(cnt >= f.m, v_r, v_in))


def _slope1(f: PWL, c: jax.Array) -> jax.Array:
    """Slope at (non-knot) query points c: (C,) -> (C,)."""
    K = f.xs.shape[-1]
    cnt = jnp.sum(f.xs[None, :] <= c[:, None], axis=-1)
    il = jnp.clip(cnt - 1, 0, K - 1)
    ir = jnp.clip(cnt, 0, K - 1)
    w = f.xs[ir] - f.xs[il]
    slope_in = (f.ys[ir] - f.ys[il]) / jnp.maximum(w, _TINY)
    return jnp.where(cnt == 0, f.sl, jnp.where(cnt >= f.m, f.sr, slope_in))


def eval_at(f: PWL, c) -> jax.Array:
    """Batched evaluation: f has leading batch dims, c broadcasts over them."""
    c = jnp.asarray(c, f.xs.dtype)
    batch = f.sl.shape
    if batch == ():
        return _eval1(f, jnp.atleast_1d(c))[0] if c.ndim == 0 else _eval1(f, c)
    cb = jnp.broadcast_to(c, batch)
    flat = jax.vmap(lambda ff, cc: _eval1(ff, cc[None])[0])
    f2 = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[len(batch):]), f)
    out = flat(f2, cb.reshape(-1))
    return out.reshape(batch)


# --------------------------------------------------------------------- #
# scaling (discounting)
# --------------------------------------------------------------------- #
def scale(f: PWL, alpha) -> PWL:
    """alpha * f with alpha > 0 (shape-preserving)."""
    alpha = jnp.asarray(alpha, f.ys.dtype)
    return PWL(f.xs, f.ys * alpha[..., None], f.sl * alpha, f.sr * alpha, f.m)


# --------------------------------------------------------------------- #
# compression: dedupe + drop collinear knots + compact to capacity
# --------------------------------------------------------------------- #
def _compact(xs, ys, keep):
    """Stable-compact kept knots to the front; returns padded xs, ys, m."""
    key = jnp.where(keep, xs, BIG)
    order = jnp.argsort(key)          # stable; BIG (dropped) sorts to the end
    xs2 = key[order]
    ys2 = ys[order]
    m2 = jnp.sum(keep).astype(jnp.int32)
    idx = jnp.arange(xs.shape[0])
    ys2 = jnp.where(idx < m2, ys2, 0.0)
    return xs2, ys2, m2


def _compress1(xs, ys, sl, sr, valid, out_cap: int):
    """xs sorted with invalid -> BIG; returns (PWL of capacity out_cap, m_raw)."""
    n = xs.shape[0]
    # pass 1: merge (near-)duplicate knots, keep the first of each run
    prev_x = jnp.concatenate([jnp.full((1,), -BIG, xs.dtype), xs[:-1]])
    prev_valid = jnp.concatenate([jnp.zeros((1,), bool), valid[:-1]])
    dup = valid & prev_valid & (xs - prev_x <= _REL * (1.0 + jnp.abs(prev_x)))
    keep1 = valid & ~dup
    xs1, ys1, m1 = _compact(xs, ys, keep1)
    # pass 2: drop knots where the slope does not genuinely change
    nxt_x = jnp.concatenate([xs1[1:], jnp.full((1,), BIG, xs.dtype)])
    nxt_y = jnp.concatenate([ys1[1:], jnp.zeros((1,), ys.dtype)])
    prv_x = jnp.concatenate([jnp.full((1,), BIG, xs.dtype), xs1[:-1]])
    prv_y = jnp.concatenate([jnp.zeros((1,), ys.dtype), ys1[:-1]])
    idx = jnp.arange(n)
    s_right = jnp.where(idx < m1 - 1,
                        (nxt_y - ys1) / jnp.maximum(nxt_x - xs1, _TINY), sr)
    s_left = jnp.where(idx > 0,
                       (ys1 - prv_y) / jnp.maximum(xs1 - prv_x, _TINY), sl)
    tol = _REL * (1.0 + jnp.maximum(jnp.abs(s_left), jnp.abs(s_right)))
    kink = jnp.abs(s_right - s_left) > tol
    keep2 = (idx < m1) & kink
    # always retain at least one (anchor) knot
    keep2 = jnp.where(jnp.any(keep2), keep2, idx == 0)
    xs2, ys2, m2 = _compact(xs1, ys1, keep2)
    out = PWL(xs2[:out_cap], ys2[:out_cap], sl, sr,
              jnp.minimum(m2, out_cap))
    return out, m2


# --------------------------------------------------------------------- #
# pointwise max / min of two functions (exact, incl. crossing knots)
# --------------------------------------------------------------------- #
def _envelope1(f: PWL, g: PWL, out_cap: int, take_max: bool):
    dtype = f.xs.dtype
    merged = jnp.sort(jnp.concatenate([f.xs, g.xs]))            # (M,)
    M = merged.shape[0]
    mv = f.m + g.m
    last = merged[jnp.clip(mv - 1, 0, M - 1)]
    # interval representatives: i = 0..M  (interval i is (merged[i-1], merged[i]))
    i_idx = jnp.arange(M + 1)
    lo = jnp.where(i_idx == 0, -BIG, merged[jnp.clip(i_idx - 1, 0, M - 1)])
    hi = jnp.where(i_idx >= mv, BIG, merged[jnp.clip(i_idx, 0, M - 1)])
    rep = jnp.where(
        i_idx == 0, merged[0] - 1.0,
        jnp.where(i_idx >= mv, last + 1.0, 0.5 * (lo + hi)))
    vf, vg = _eval1(f, rep), _eval1(g, rep)
    sf, sg = _slope1(f, rep), _slope1(g, rep)
    denom = sf - sg
    parallel = jnp.abs(denom) <= _REL * (1.0 + jnp.maximum(jnp.abs(sf), jnp.abs(sg)))
    x_cross = rep + (vg - vf) / jnp.where(parallel, 1.0, denom)
    margin = _REL * (1.0 + jnp.abs(x_cross))
    inside = (x_cross > lo + margin) & (x_cross < hi - margin)
    ok = (~parallel) & inside & (i_idx <= mv)
    cross = jnp.where(ok, x_cross, BIG)
    cands = jnp.sort(jnp.concatenate([merged, cross]))          # (2M+1,)
    valid = cands < BIG / 2
    hf, hg = _eval1(f, cands), _eval1(g, cands)
    hv = jnp.maximum(hf, hg) if take_max else jnp.minimum(hf, hg)
    # end slopes from probes beyond the outermost *candidates* (crossings can
    # lie outside the span of the input knots)
    nvc = jnp.sum(valid)
    pl = cands[0] - 1.0
    pr = cands[jnp.clip(nvc - 1, 0, cands.shape[0] - 1)] + 1.0
    fl, gl = _eval1(f, pl[None])[0], _eval1(g, pl[None])[0]
    fr, gr = _eval1(f, pr[None])[0], _eval1(g, pr[None])[0]
    tie_l = jnp.abs(fl - gl) <= _REL * (1.0 + jnp.maximum(jnp.abs(fl), jnp.abs(gl)))
    tie_r = jnp.abs(fr - gr) <= _REL * (1.0 + jnp.maximum(jnp.abs(fr), jnp.abs(gr)))
    if take_max:
        sl = jnp.where(tie_l, jnp.minimum(f.sl, g.sl), jnp.where(fl > gl, f.sl, g.sl))
        sr = jnp.where(tie_r, jnp.maximum(f.sr, g.sr), jnp.where(fr > gr, f.sr, g.sr))
    else:
        sl = jnp.where(tie_l, jnp.maximum(f.sl, g.sl), jnp.where(fl < gl, f.sl, g.sl))
        sr = jnp.where(tie_r, jnp.minimum(f.sr, g.sr), jnp.where(fr < gr, f.sr, g.sr))
    hv = jnp.where(valid, hv, 0.0)
    return _compress1(cands, hv, sl, sr, valid, out_cap)


def envelope2(f: PWL, g: PWL, out_cap: int, take_max: bool):
    """Pointwise max/min.  Batched over leading dims; returns (PWL, m_raw)."""
    batch = f.sl.shape
    if batch == ():
        return _envelope1(f, g, out_cap, take_max)
    fn = lambda ff, gg: _envelope1(ff, gg, out_cap, take_max)
    for _ in batch:
        fn = jax.vmap(fn)
    return fn(f, g)


# --------------------------------------------------------------------- #
# transaction-cost slope restriction (inf-convolution with the cost cone)
# --------------------------------------------------------------------- #
def _cone1(f: PWL, a, b, out_cap: int):
    """v = min(f, lower envelope of the V_j cones); exact (see pwl_ref)."""
    K = f.xs.shape[-1]
    dtype = f.xs.dtype
    idx = jnp.arange(K)
    valid = idx < f.m
    A = jnp.where(valid, f.ys + a * f.xs, BIG)
    Bv = jnp.where(valid, f.ys + b * f.xs, BIG)
    SA = jax.lax.cummin(A, reverse=True)       # suffix min of ys + a*xs
    PB = jax.lax.cummin(Bv)                    # prefix min of ys + b*xs
    # crossing candidate inside each bounded interval (xs_j, xs_{j+1})
    nxt_x = jnp.concatenate([f.xs[1:], jnp.full((1,), BIG, dtype)])
    nxt_SA = jnp.concatenate([SA[1:], jnp.full((1,), BIG, dtype)])
    denom = a - b
    par = jnp.abs(denom) <= _REL * (1.0 + jnp.abs(a))
    ystar = (nxt_SA - PB) / jnp.where(par, 1.0, denom)
    margin = _REL * (1.0 + jnp.abs(ystar))
    ok = ((~par) & (idx + 1 < f.m) & (nxt_SA < BIG / 2) & (PB < BIG / 2)
          & (ystar > f.xs + margin) & (ystar < nxt_x - margin))
    cross = jnp.where(ok, ystar, BIG)
    cands = jnp.sort(jnp.concatenate([f.xs, cross]))            # (2K,)
    cvalid = cands < BIG / 2
    # env(c) = min(-a c + SA(c), -b c + PB(c))
    ge = jnp.sum(f.xs[None, :] < cands[:, None], axis=-1)       # knots < c
    le = jnp.sum(f.xs[None, :] <= cands[:, None], axis=-1)      # knots <= c
    SA_at = jnp.where(ge < f.m, SA[jnp.clip(ge, 0, K - 1)], BIG)
    PB_at = jnp.where(le > 0, PB[jnp.clip(le - 1, 0, K - 1)], BIG)
    env_v = jnp.minimum(jnp.where(SA_at < BIG / 2, -a * cands + SA_at, BIG),
                        jnp.where(PB_at < BIG / 2, -b * cands + PB_at, BIG))
    env_v = jnp.where(cvalid, env_v, 0.0)
    menv = jnp.sum(cvalid).astype(jnp.int32)
    env = PWL(cands, env_v, -a * jnp.ones((), dtype), -b * jnp.ones((), dtype), menv)
    return _envelope1(f, env, out_cap, take_max=False)


def cone_infconv(f: PWL, a, b, out_cap: int):
    """Batched slope restriction; a, b broadcast over batch. (PWL, m_raw)."""
    batch = f.sl.shape
    a = jnp.broadcast_to(jnp.asarray(a, f.xs.dtype), batch)
    b = jnp.broadcast_to(jnp.asarray(b, f.xs.dtype), batch)
    if batch == ():
        return _cone1(f, a, b, out_cap)
    fn = lambda ff, aa, bb: _cone1(ff, aa, bb, out_cap)
    for _ in batch:
        fn = jax.vmap(fn)
    return fn(f, a, b)


# --------------------------------------------------------------------- #
# conversions to/from the exact oracle (testing)
# --------------------------------------------------------------------- #
def from_ref(ref, capacity: int, dtype=jnp.float64) -> PWL:
    import numpy as np
    m = ref.m
    if m > capacity:
        raise ValueError(f"oracle function has {m} knots > capacity {capacity}")
    xs = np.full((capacity,), BIG)
    ys = np.zeros((capacity,))
    xs[:m] = ref.xs
    ys[:m] = ref.ys
    return PWL(jnp.asarray(xs, dtype), jnp.asarray(ys, dtype),
               jnp.asarray(ref.s_left, dtype), jnp.asarray(ref.s_right, dtype),
               jnp.asarray(m, jnp.int32))


def to_ref(f: PWL):
    import numpy as np
    from .pwl_ref import PWLRef
    m = int(f.m)
    return PWLRef(np.asarray(f.xs[:m]), np.asarray(f.ys[:m]),
                  float(f.sl), float(f.sr))
