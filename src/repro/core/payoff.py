"""American option payoff processes (xi_t, zeta_t).

Under transaction costs an American option's payoff is a *portfolio*
process (xi, zeta): on exercise at time t the seller delivers xi_t units
of cash and zeta_t units of stock (paper §3).  Examples:

  * physically-settled American put, strike K:   (K, -1) at every t <= N
  * physically-settled American call, strike K:  (-K, +1)
  * cash-settled payoffs:  zeta = 0 and xi = g(S_t)  (e.g. bull spread
    (S-95)^+ - (S-105)^+ in the paper's experiments)

``zeta`` may depend on the node only through the stock price; the engines
evaluate payoffs level-by-level from the vector of node stock prices.  The
extra time instant t = N+1 added by the Roux–Zastawniak algorithms always
carries payoff (0, 0) and is handled inside the engines, not here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

__all__ = [
    "PayoffProcess", "american_put", "american_call", "bull_spread",
    "cash_settled",
]


@dataclasses.dataclass(frozen=True)
class PayoffProcess:
    """(xi, zeta) as functions of the stock-price vector of one level.

    ``xi``/``zeta`` are written in jnp so they are traceable inside jitted
    engines; they also accept plain numpy arrays (the reference oracles
    convert results back with ``np.asarray``).
    """
    name: str
    xi: Callable
    zeta: Callable

    # scalar intrinsic value xi + zeta * S (used by the no-TC engine)
    def intrinsic(self, s) -> np.ndarray:
        return np.asarray(self.xi(s) + self.zeta(s) * s)


def american_put(strike: float) -> PayoffProcess:
    """Physically settled put: deliver (K, -1) — holder sells stock at K."""
    k = float(strike)
    return PayoffProcess(
        name=f"put(K={k:g})",
        xi=lambda s: jnp.full_like(s, k),
        zeta=lambda s: jnp.full_like(s, -1.0),
    )


def american_call(strike: float) -> PayoffProcess:
    """Physically settled call: deliver (-K, +1)."""
    k = float(strike)
    return PayoffProcess(
        name=f"call(K={k:g})",
        xi=lambda s: jnp.full_like(s, -k),
        zeta=lambda s: jnp.full_like(s, 1.0),
    )


def cash_settled(name: str, g: Callable) -> PayoffProcess:
    return PayoffProcess(name=name, xi=g, zeta=lambda s: jnp.zeros_like(s))


def bull_spread(k_long: float = 95.0, k_short: float = 105.0) -> PayoffProcess:
    """Paper §5: cash-settled (S-95)^+ - (S-105)^+ American bull spread."""
    kl, ks = float(k_long), float(k_short)
    return cash_settled(
        f"bull_spread({kl:g},{ks:g})",
        lambda s: jnp.maximum(s - kl, 0.0) - jnp.maximum(s - ks, 0.0),
    )
