"""American option payoff processes (xi_t, zeta_t).

Under transaction costs an American option's payoff is a *portfolio*
process (xi, zeta): on exercise at time t the seller delivers xi_t units
of cash and zeta_t units of stock (paper §3).  Examples:

  * physically-settled American put, strike K:   (K, -1) at every t <= N
  * physically-settled American call, strike K:  (-K, +1)
  * cash-settled payoffs:  zeta = 0 and xi = g(S_t)  (e.g. bull spread
    (S-95)^+ - (S-105)^+ in the paper's experiments)

``zeta`` may depend on the node only through the stock price; the engines
evaluate payoffs level-by-level from the vector of node stock prices.  The
extra time instant t = N+1 added by the Roux–Zastawniak algorithms always
carries payoff (0, 0) and is handled inside the engines, not here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

__all__ = [
    "PayoffProcess", "american_put", "american_call", "bull_spread",
    "cash_settled", "param_payoff",
]


@dataclasses.dataclass(frozen=True)
class PayoffProcess:
    """(xi, zeta) as functions of the stock-price vector of one level.

    ``xi``/``zeta`` are written in jnp so they are traceable inside jitted
    engines; they also accept plain numpy arrays (the reference oracles
    convert results back with ``np.asarray``).

    ``params``, when set, is the ``(alpha, zeta, w1, w2, k1, k2)`` tuple of
    the 4-parameter payoff *family* (payoff-as-data, see
    :func:`param_payoff`).  Engines that carry the payoff as kernel scalars
    (the Pallas backends) require it; closure-only payoffs leave it None.
    """
    name: str
    xi: Callable
    zeta: Callable
    params: tuple = None

    # scalar intrinsic value xi + zeta * S (used by the no-TC engine)
    def intrinsic(self, s) -> np.ndarray:
        return np.asarray(self.xi(s) + self.zeta(s) * s)


def param_payoff(alpha, zeta, w1, w2, k1, k2,
                 name: str = "param") -> PayoffProcess:
    """The 4-parameter payoff family with the parameters carried as data:

        xi(s)   = alpha*k1 + w1*(s - k1)^+ + w2*(s - k2)^+
        zeta(s) = zeta                                  (constant)

    (put: alpha=1, zeta=-1; call: alpha=-1, zeta=+1; bull spread: w1=1,
    w2=-1.)  The arguments may be traced scalars — the scenario-grid
    engines batch heterogeneous contracts by closing xi/zeta over traced
    per-scenario parameters — or plain floats.
    """
    def xi(s):
        return (alpha * k1 + w1 * jnp.maximum(s - k1, 0.0)
                + w2 * jnp.maximum(s - k2, 0.0))

    def zeta_fn(s):
        return jnp.full_like(s, zeta)

    return PayoffProcess(name=name, xi=xi, zeta=zeta_fn,
                         params=(alpha, zeta, w1, w2, k1, k2))


def american_put(strike: float) -> PayoffProcess:
    """Physically settled put: deliver (K, -1) — holder sells stock at K."""
    k = float(strike)
    return PayoffProcess(
        name=f"put(K={k:g})",
        xi=lambda s: jnp.full_like(s, k),
        zeta=lambda s: jnp.full_like(s, -1.0),
        params=(1.0, -1.0, 0.0, 0.0, k, k),
    )


def american_call(strike: float) -> PayoffProcess:
    """Physically settled call: deliver (-K, +1)."""
    k = float(strike)
    return PayoffProcess(
        name=f"call(K={k:g})",
        xi=lambda s: jnp.full_like(s, -k),
        zeta=lambda s: jnp.full_like(s, 1.0),
        params=(-1.0, 1.0, 0.0, 0.0, k, k),
    )


def cash_settled(name: str, g: Callable,
                 params: tuple = None) -> PayoffProcess:
    return PayoffProcess(name=name, xi=g, zeta=lambda s: jnp.zeros_like(s),
                         params=params)


def bull_spread(k_long: float = 95.0, k_short: float = 105.0) -> PayoffProcess:
    """Paper §5: cash-settled (S-95)^+ - (S-105)^+ American bull spread."""
    kl, ks = float(k_long), float(k_short)
    return cash_settled(
        f"bull_spread({kl:g},{ks:g})",
        lambda s: jnp.maximum(s - kl, 0.0) - jnp.maximum(s - ks, 0.0),
        params=(0.0, 0.0, 1.0, -1.0, kl, ks),
    )
