"""Exact piecewise-linear (PWL) function algebra — NumPy reference oracle.

This is the ground-truth implementation of the function algebra that the
Roux–Zastawniak (2009) pricing algorithms operate on.  Every function is a
continuous piecewise-linear map ``f: R -> R`` represented by

  * ``xs``  — sorted knot abscissae, shape (m,), m >= 1
  * ``ys``  — knot values f(xs), shape (m,)
  * ``s_left``  — slope on (-inf, xs[0]]
  * ``s_right`` — slope on [xs[-1], +inf)

Interior slopes are implied by the knots.  Knot *values* (not an anchored
integral) are stored so repeated operations do not accumulate drift.

The operations required by the pricing recursion are

  * pointwise ``maximum`` / ``minimum`` of two PWL functions,
  * positive affine rescaling (discounting),
  * ``cone_infconv`` — the transaction-cost slope restriction
    ``v(y) = min_{y'} [ f(y') + c(y' - y) ]`` with the rebalancing cost
    ``c(d) = max(a*d, b*d)``, ``a >= b > 0`` (ask/bid prices of the stock).

All of these return exact results (up to float64 rounding); the fixed
capacity vectorised JAX implementation in :mod:`repro.core.pwl` is validated
against this oracle by the unit and hypothesis tests.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

__all__ = ["PWLRef", "expense_function", "pwl_max", "pwl_min", "cone_infconv"]

# Relative tolerances.  Slopes here are stock prices (~1e2); absolute 1e-12
# comparisons would treat float-noise slope differences as genuine kinks and
# the knot count then cascades multiplicatively through the recursion (seen
# experimentally: >1000 knots at N=25 vs the true handful).  All slope
# equality checks are therefore relative.
_REL = 1e-9


def _slope_close(sa: float, sb: float) -> bool:
    return abs(sa - sb) <= _REL * (1.0 + max(abs(sa), abs(sb)))


@dataclasses.dataclass
class PWLRef:
    xs: np.ndarray      # (m,) sorted knots
    ys: np.ndarray      # (m,) values at knots
    s_left: float       # slope left of xs[0]
    s_right: float      # slope right of xs[-1]

    # ------------------------------------------------------------------ #
    # construction helpers
    # ------------------------------------------------------------------ #
    def __post_init__(self) -> None:
        self.xs = np.asarray(self.xs, dtype=np.float64)
        self.ys = np.asarray(self.ys, dtype=np.float64)
        if self.xs.ndim != 1 or self.xs.shape != self.ys.shape or self.xs.size < 1:
            raise ValueError("xs/ys must be 1-D, same shape, size >= 1")
        if np.any(np.diff(self.xs) < 0):
            raise ValueError("xs must be sorted")
        self.s_left = float(self.s_left)
        self.s_right = float(self.s_right)

    @staticmethod
    def affine(slope: float, value_at_0: float) -> "PWLRef":
        return PWLRef(np.array([0.0]), np.array([float(value_at_0)]), slope, slope)

    @staticmethod
    def from_slopes(breaks: Iterable[float], slopes: Iterable[float],
                    value_at_0: float) -> "PWLRef":
        """Build from breakpoints (len m) and slopes (len m+1) and f(0)."""
        breaks = np.asarray(list(breaks), dtype=np.float64)
        slopes = np.asarray(list(slopes), dtype=np.float64)
        if breaks.size == 0:
            return PWLRef.affine(float(slopes[0]), value_at_0)
        if slopes.size != breaks.size + 1:
            raise ValueError("need len(slopes) == len(breaks) + 1")
        # integrate the slope step function from 0 to each knot to get values;
        # if y < 0 the sum of slope*(bb-aa) over [y, 0] equals f(0) - f(y).
        ys = np.empty_like(breaks)

        def _eval2(y: float) -> float:
            lo, hi = (0.0, y) if y >= 0 else (y, 0.0)
            cuts = np.unique(np.clip(breaks, lo, hi))
            cuts = np.concatenate([[lo], cuts, [hi]])
            total = 0.0
            for aa, bb in zip(cuts[:-1], cuts[1:]):
                if bb <= aa:
                    continue
                mid = 0.5 * (aa + bb)
                k = int(np.searchsorted(breaks, mid, side="right"))
                total += slopes[k] * (bb - aa)
            return value_at_0 + total if y >= 0 else value_at_0 - total
        for i, x in enumerate(breaks):
            ys[i] = _eval2(float(x))
        return PWLRef(breaks, ys, float(slopes[0]), float(slopes[-1])).compress()

    # ------------------------------------------------------------------ #
    # basic queries
    # ------------------------------------------------------------------ #
    @property
    def m(self) -> int:
        return int(self.xs.size)

    def slopes(self) -> np.ndarray:
        """All m+1 slopes, left to right."""
        if self.m == 1:
            return np.array([self.s_left, self.s_right])
        interior = np.diff(self.ys) / np.diff(self.xs)
        return np.concatenate([[self.s_left], interior, [self.s_right]])

    def __call__(self, y):
        y = np.asarray(y, dtype=np.float64)
        out = np.interp(y, self.xs, self.ys)
        left = y < self.xs[0]
        right = y > self.xs[-1]
        out = np.where(left, self.ys[0] + self.s_left * (y - self.xs[0]), out)
        out = np.where(right, self.ys[-1] + self.s_right * (y - self.xs[-1]), out)
        return out if out.ndim else float(out)

    def is_convex(self, tol: float = 1e-9) -> bool:
        s = self.slopes()
        return bool(np.all(np.diff(s) >= -tol))

    # ------------------------------------------------------------------ #
    # transforms
    # ------------------------------------------------------------------ #
    def scale(self, alpha: float) -> "PWLRef":
        """alpha * f, alpha > 0."""
        if alpha <= 0:
            raise ValueError("alpha must be > 0")
        return PWLRef(self.xs, alpha * self.ys, alpha * self.s_left,
                      alpha * self.s_right)

    def add_const(self, c: float) -> "PWLRef":
        return PWLRef(self.xs, self.ys + c, self.s_left, self.s_right)

    def neg(self) -> "PWLRef":
        return PWLRef(self.xs, -self.ys, -self.s_left, -self.s_right)

    def compress(self, tol: float | None = None) -> "PWLRef":
        """Drop knots whose removal leaves the function (numerically) unchanged.

        Uses a *relative* slope tolerance by default; also merges knots that
        coincide up to relative spacing (crossing-insertion float noise).
        """
        xs, ys = self.xs, self.ys
        # 1) merge (near-)duplicate knots, keeping the first
        if xs.size > 1:
            span = 1.0 + np.abs(xs[:-1])
            dup = np.diff(xs) <= _REL * span
            keep = np.concatenate([[True], ~dup])
            xs, ys = xs[keep], ys[keep]
        if xs.size <= 1:
            return PWLRef(xs, ys, self.s_left, self.s_right)
        # 2) drop knots with no genuine slope change
        tmp = PWLRef(xs, ys, self.s_left, self.s_right)
        s = tmp.slopes()
        if tol is None:
            scale = 1.0 + np.maximum(np.abs(s[:-1]), np.abs(s[1:]))
            keep = np.abs(np.diff(s)) > _REL * scale
        else:
            keep = np.abs(np.diff(s)) > tol
        if not np.any(keep):
            # fully affine: keep a single anchor knot
            return PWLRef(xs[:1], ys[:1], self.s_left, self.s_right)
        return PWLRef(xs[keep], ys[keep], self.s_left, self.s_right)

    # ------------------------------------------------------------------ #
    # sanity
    # ------------------------------------------------------------------ #
    def assert_finite(self) -> None:
        assert np.all(np.isfinite(self.xs)) and np.all(np.isfinite(self.ys))
        assert np.isfinite(self.s_left) and np.isfinite(self.s_right)


# ---------------------------------------------------------------------- #
# pointwise max / min
# ---------------------------------------------------------------------- #
def _envelope(f: PWLRef, g: PWLRef, take_max: bool) -> PWLRef:
    """Pointwise max (or min) of two PWL functions — exact."""
    knots = np.unique(np.concatenate([f.xs, g.xs]))
    # candidate crossing in every interval (including the two unbounded ends)
    pts = list(knots)
    edges = np.concatenate([[-np.inf], knots, [np.inf]])
    for lo, hi in zip(edges[:-1], edges[1:]):
        # slopes and values of both functions on (lo, hi)
        if np.isinf(lo) and np.isinf(hi):
            ref = 0.0
        elif np.isinf(lo):
            ref = hi - 1.0
        elif np.isinf(hi):
            ref = lo + 1.0
        else:
            if hi - lo <= _REL * (1.0 + abs(lo)):
                continue
            ref = 0.5 * (lo + hi)
        sf = _slope_at(f, ref)
        sg = _slope_at(g, ref)
        if _slope_close(sf, sg):
            continue  # (near-)parallel: crossing position is pure noise
        vf = f(ref)
        vg = g(ref)
        x_cross = ref + (vg - vf) / (sf - sg)
        margin = _REL * (1.0 + abs(x_cross))
        if lo + margin < x_cross < hi - margin:
            pts.append(x_cross)
    xs = np.unique(np.asarray(pts, dtype=np.float64))
    vf = f(xs)
    vg = g(xs)
    ys = np.maximum(vf, vg) if take_max else np.minimum(vf, vg)
    # end slopes: evaluate beyond the outermost knots
    probe_l = xs[0] - 1.0
    probe_r = xs[-1] + 1.0
    fl, gl = f(probe_l), g(probe_l)
    fr, gr = f(probe_r), g(probe_r)
    if take_max:
        s_left = f.s_left if fl >= gl else g.s_left
        s_right = f.s_right if fr >= gr else g.s_right
    else:
        s_left = f.s_left if fl <= gl else g.s_left
        s_right = f.s_right if fr <= gr else g.s_right
    return PWLRef(xs, ys, s_left, s_right).compress()


def _slope_at(f: PWLRef, y: float) -> float:
    """Slope of f at a non-knot point y."""
    if y < f.xs[0]:
        return f.s_left
    if y > f.xs[-1]:
        return f.s_right
    i = int(np.searchsorted(f.xs, y, side="right"))
    if i >= f.m:
        return f.s_right
    if i == 0:
        return f.s_left
    return float((f.ys[i] - f.ys[i - 1]) / (f.xs[i] - f.xs[i - 1]))


def pwl_max(f: PWLRef, g: PWLRef) -> PWLRef:
    return _envelope(f, g, take_max=True)


def pwl_min(f: PWLRef, g: PWLRef) -> PWLRef:
    return _envelope(f, g, take_max=False)


# ---------------------------------------------------------------------- #
# transaction-cost slope restriction (inf-convolution with the cost cone)
# ---------------------------------------------------------------------- #
def cone_infconv(f: PWLRef, a: float, b: float) -> PWLRef:
    """v(y) = min_{y'} [ f(y') + c(y' - y) ],  c(d) = max(a d, b d), a >= b.

    Financially: the least cash needed at stock holding ``y`` so that after a
    single rebalancing trade (buy at ask ``a``, sell at bid ``b``) the
    portfolio lands in the epigraph of ``f``.  For convex ``f`` this equals
    clipping the slopes of ``f`` to ``[-a, -b]``; this implementation is the
    general (also non-convex) exact form:

      the inner objective is PWL in y', so the minimiser is a knot of f or
      y' = y itself; hence
      v = min( f,  min_j V_j ),   V_j(y) = f(x_j) + c(x_j - y)

    where V_j is the convex 2-piece "V" with slopes (-a, -b) and apex at
    (x_j, f(x_j)).  Boundedness requires s_left(f) <= -b and s_right(f) >= -a.
    """
    if not (a >= b > 0 or (a == b and a > 0)):
        raise ValueError(f"need a >= b > 0, got a={a}, b={b}")
    if f.s_left > -b + 1e-9 or f.s_right < -a - 1e-9:
        raise ValueError(
            "inf-convolution unbounded below: end slopes outside [-a,-b] cone "
            f"(s_left={f.s_left}, s_right={f.s_right}, a={a}, b={b})")
    out = f
    for xj, yj in zip(f.xs, f.ys):
        if a == b:
            vj = PWLRef.affine(-a, yj + a * xj)
        else:
            vj = PWLRef(np.array([xj]), np.array([yj]), -a, -b)
        out = pwl_min(out, vj)
    return out.compress()


# ---------------------------------------------------------------------- #
# expense functions (eq. (1) and (6) of the paper)
# ---------------------------------------------------------------------- #
def expense_function(xi: float, zeta: float, s_ask: float, s_bid: float) -> PWLRef:
    """u(y) = xi + (y - zeta)^- * s_ask - (y - zeta)^+ * s_bid.

    2-piece convex PWL with slopes (-s_ask, -s_bid) and knot at zeta.
    The buyer's expense function (eq. 6) is obtained by calling this with
    (-xi, -zeta).
    """
    # value at the knot y = zeta is exactly xi
    if s_ask == s_bid:
        return PWLRef.affine(-s_ask, xi + zeta * s_ask)
    return PWLRef(np.array([zeta]), np.array([xi]), -s_ask, -s_bid)
