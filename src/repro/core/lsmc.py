"""Parallel least-squares Monte Carlo (Longstaff–Schwartz) Bermudan engine.

The third pricing engine, opening the workload the binomial lattice
structurally cannot price: ``d > 1`` underlyings and Bermudan exercise
schedules.  Follows the multi-core LSMC decomposition of Doan et al.
2008 and the massively-parallel American-style MC pricing of
Pagès–Wilbertz 2011 (see PAPERS.md): paths are embarrassingly parallel,
scenarios vmap into one compiled call, and the flat scenario batch
shards over the existing 1-D grid mesh
(``core/distributed.py::grid_mesh``) with **no new collectives** — the
per-row reductions (mean / standard error) stay inside the row.

Model and estimator
-------------------
* ``d = n_assets`` independent GBMs share the row's ``(s0, sigma,
  rate)``; the payoff applies to the **arithmetic basket mean**
  ``b = mean_j S_j`` through the same 4-parameter payoff family the
  lattice engines batch as data (``core/payoff.py``).  For ``d = 1``
  this is exactly the single-asset model of the lattice engines — the
  overlapping domain the oracle tests lock against.
* Antithetic GBM path generation under an **explicit PRNG key per
  scenario row** (:func:`path_keys`): results are bitwise deterministic
  for a given ``seed`` and independent of batching/sharding layout.
* Regression basis: plain polynomial or Laguerre in the moneyness
  ``b / K1``, pluggable ``degree``; the continuation value is fit by
  masked ridge-regularised normal equations over in-the-money paths
  only (the classic Longstaff–Schwartz restriction).
* Backward induction runs over a static Bermudan ``exercise_steps``
  schedule (a subset of lattice steps, terminal step mandatory; step 0
  is handled deterministically as ``max(intrinsic(s0), MC estimate)``).
* Output per scenario: the price and its Monte Carlo **standard
  error** (antithetic pair-level, ``ddof=1``) — the honest tolerance
  every MC test asserts against (``tests/_stats.py``).

Transaction costs
-----------------
Under ``cost_rate = λ > 0`` the engine quotes the crude *premium
convention* ``ask = (1+λ)·P``, ``bid = (1−λ)·P`` (costs charged on the
option trade itself, not the hedge).  This is NOT the Roux–Zastawniak
hedging interval — the 1-D TC domain stays with the ``rz`` engine; see
``docs/KNOWN_ISSUES.md``.  ``λ = 0`` degenerates to ``ask = bid = P``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["LSMC_BASES", "exercise_schedule", "path_keys",
           "simulate_basket", "basis_matrix", "lsmc_rows", "lsmc_rows_jit"]

LSMC_BASES = ("poly", "laguerre")

# ridge added to the (moneyness-normalised) Gram matrix so an all-OTM
# date — a singular regression — degrades to beta = 0 instead of NaN
_RIDGE = 1e-10


def exercise_schedule(n_steps: int,
                      exercise_steps: Optional[Sequence[int]]) -> Tuple[int, ...]:
    """Normalise a Bermudan schedule to an ascending tuple of step indices.

    ``None`` means American-on-the-lattice-clock: every step ``0..N``.
    An explicit schedule must stay within ``0..N`` and **include the
    terminal step** ``N`` (an option that can never pay at expiry is a
    different contract, almost certainly a bug).
    """
    if exercise_steps is None:
        return tuple(range(n_steps + 1))
    steps = tuple(sorted({int(s) for s in exercise_steps}))
    if not steps:
        raise ValueError("exercise_steps must not be empty")
    if steps[0] < 0 or steps[-1] > n_steps:
        raise ValueError(f"exercise_steps {steps} outside 0..{n_steps}")
    if steps[-1] != n_steps:
        raise ValueError(
            f"exercise_steps must include the terminal step {n_steps} "
            f"(got {steps})")
    return steps


def path_keys(seed: int, n_rows: int) -> jnp.ndarray:
    """Per-row PRNG key data, derived from ``seed`` by **row index**.

    Returned as a ``(n_rows, 2)`` uint32 array so keys travel as plain
    row data through the same gather/pad shard layout as every other
    column — which is exactly why sharded results are bit-equal to the
    single-device call (rows are independent, each carries its own
    key).  Row ``i`` always gets the same key for a given seed, so a
    contract's quote does not depend on how large the batch was padded.
    """
    key = jax.random.PRNGKey(int(seed))
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(int(n_rows), dtype=jnp.uint32))


def basis_matrix(x: jnp.ndarray, degree: int, kind: str) -> jnp.ndarray:
    """Regression design matrix over the moneyness ``x`` — ``(P, degree+1)``.

    ``kind="poly"``: monomials ``1, x, ..., x^degree``;
    ``kind="laguerre"``: Laguerre polynomials ``L_0..L_degree`` via the
    three-term recurrence (the Longstaff–Schwartz choice, numerically
    tamer than raw monomials at higher degree).
    """
    if degree < 0:
        raise ValueError("need degree >= 0")
    if kind == "poly":
        cols = [jnp.ones_like(x)]
        for d in range(1, degree + 1):
            cols.append(cols[-1] * x)
    elif kind == "laguerre":
        cols = [jnp.ones_like(x)]
        if degree >= 1:
            cols.append(1.0 - x)
        for k in range(1, degree):
            cols.append(((2 * k + 1 - x) * cols[-1] - k * cols[-2])
                        / (k + 1))
    else:
        raise ValueError(f"unknown basis {kind!r}; use one of {LSMC_BASES}")
    return jnp.stack(cols, axis=-1)


def simulate_basket(s0, sigma, rate, maturity, key, *, n_steps: int,
                    steps: Tuple[int, ...], n_paths: int, n_assets: int,
                    antithetic: bool):
    """Antithetic GBM basket paths at the schedule's positive steps.

    Returns ``(b, t)``: ``b`` is the arithmetic basket mean, shape
    ``(n_paths, n_sim)`` over the simulated exercise dates, ``t`` the
    corresponding year-fraction times ``(n_sim,)``.  ``steps`` entries
    at 0 are skipped (the t=0 state is the deterministic ``s0``).  With
    ``antithetic`` the first ``n_paths//2`` rows use draws ``+Z`` and
    the second half ``-Z`` (``n_paths`` must be even).
    """
    sim = tuple(s for s in steps if s > 0)
    if not sim:
        raise ValueError("schedule has no positive step to simulate")
    if antithetic and n_paths % 2:
        raise ValueError("antithetic sampling needs an even n_paths")
    dtype = jnp.float64
    frac = jnp.asarray(sim, dtype) / n_steps
    t = maturity * frac                                     # (n_sim,)
    dts = jnp.diff(t, prepend=jnp.zeros((1,), dtype))       # (n_sim,)
    m = n_paths // 2 if antithetic else n_paths
    z = jax.random.normal(key, (m, len(sim), n_assets), dtype)
    if antithetic:
        z = jnp.concatenate([z, -z], axis=0)
    drift = (rate - 0.5 * sigma * sigma) * dts
    shock = sigma * jnp.sqrt(dts)
    logs = jnp.cumsum(drift[None, :, None] + shock[None, :, None] * z,
                      axis=1)
    b = jnp.mean(s0 * jnp.exp(logs), axis=2)                # (P, n_sim)
    return b, t


def _payoff_pos(b, alpha, zeta, w1, w2, k1, k2):
    """Intrinsic value of the 4-parameter payoff family, floored at 0
    (identical to the lattice engines' convention)."""
    pay = (alpha * k1 + w1 * jnp.maximum(b - k1, 0.0)
           + w2 * jnp.maximum(b - k2, 0.0) + zeta * b)
    return jnp.maximum(pay, 0.0)


def _lsmc_row(s0, sigma, rate, maturity, k, alpha, zeta, w1, w2, k1, k2,
              key, *, n_steps: int, steps: Tuple[int, ...], n_paths: int,
              n_assets: int, degree: int, basis: str, antithetic: bool):
    """One scenario row -> (ask, bid, stderr).  All hyperparameters are
    static; everything else is traced, so the whole batch vmaps."""
    b, t = simulate_basket(s0, sigma, rate, maturity, key, n_steps=n_steps,
                           steps=steps, n_paths=n_paths, n_assets=n_assets,
                           antithetic=antithetic)
    P = b.shape[0]
    h = _payoff_pos(b, alpha, zeta, w1, w2, k1, k2)         # (P, n_sim)
    v = h[:, -1]
    # moneyness scale for the regression — strike-normalised so the Gram
    # matrix is O(1) regardless of the contract's price level
    scale = jnp.where(k1 > 0.0, k1, jnp.where(s0 > 0.0, s0, 1.0))
    n_sim = b.shape[1]
    if n_sim > 1:
        df_step = jnp.exp(-rate * jnp.diff(t))              # (n_sim-1,)
        xs = (jnp.flip(b[:, :-1].T, 0), jnp.flip(h[:, :-1].T, 0),
              jnp.flip(df_step, 0))

        def body(val, x):
            bj, hj, dfj = x
            val = val * dfj
            phi = basis_matrix(bj / scale, degree, basis)    # (P, q)
            itm = hj > 0.0
            a = phi * itm[:, None]
            gram = a.T @ a / P + _RIDGE * jnp.eye(degree + 1)
            beta = jnp.linalg.solve(gram, a.T @ (val * itm) / P)
            cont = phi @ beta
            return jnp.where(itm & (hj > cont), hj, val), None

        v, _ = jax.lax.scan(body, v, xs)
    v = v * jnp.exp(-rate * t[0])                           # first date -> 0
    if antithetic:
        m = P // 2
        pair = 0.5 * (v[:m] + v[m:])
        price = jnp.mean(pair)
        se = jnp.std(pair, ddof=1) / jnp.sqrt(1.0 * m)
    else:
        price = jnp.mean(v)
        se = jnp.std(v, ddof=1) / jnp.sqrt(1.0 * P)
    if steps[0] == 0:
        # exercise at t=0 is deterministic: the basket is s0 exactly
        price = jnp.maximum(_payoff_pos(s0, alpha, zeta, w1, w2, k1, k2),
                            price)
    # premium convention for cost_rate > 0 (see module docstring); the
    # reported stderr is that of the frictionless estimate
    return (1.0 + k) * price, (1.0 - k) * price, se


def lsmc_rows(s0, sigma, rate, maturity, k, alpha, zeta, w1, w2, k1, k2,
              keys, *, n_steps: int, steps: Tuple[int, ...], n_paths: int,
              n_assets: int, degree: int, basis: str, antithetic: bool):
    """Flat-batch LSMC kernel: equal-length row arrays in, rows out.

    The shardable unit, mirroring ``scenarios._rz_rows`` — the sharded
    path wraps exactly this function in ``shard_map`` (each device
    prices its slice of rows), the single path jits it directly.
    ``keys`` is the ``(rows, 2)`` uint32 per-row key column
    (:func:`path_keys`).
    """
    one = partial(_lsmc_row, n_steps=n_steps, steps=steps, n_paths=n_paths,
                  n_assets=n_assets, degree=degree, basis=basis,
                  antithetic=antithetic)
    return jax.vmap(one)(s0, sigma, rate, maturity, k,
                         alpha, zeta, w1, w2, k1, k2, keys)


lsmc_rows_jit = partial(jax.jit, static_argnames=(
    "n_steps", "steps", "n_paths", "n_assets", "degree", "basis",
    "antithetic"))(lsmc_rows)
