"""Distributed lattice engine — the paper's parallel algorithm on a TPU mesh.

This is the TPU-native adaptation of the paper's §4 block/round scheme
(DESIGN.md §2).  The tree's node (column) axis is sharded over the mesh's
``model`` axis; contracts (the pricing-desk batch) are sharded over
``data`` (and ``pod``).  The backward induction runs in *rounds*: one
``lax.ppermute`` halo exchange of ``L`` lanes per round, then ``L`` local
level-steps whose valid window shrinks by one lane per step — exactly the
paper's region-A/region-B dependency pattern, with the signal ``G_i``
replaced by the halo fetch and the barrier by SPMD program order.

Near the root the live tree no longer spans the shards: the paper sheds
processors (p <- p-1); here the engine switches — at a *statically known*
round boundary — to a collapse phase: one ``all_gather`` of the live
prefix, after which every shard finishes the remaining levels redundantly
with no further collectives (the same trick Solomon et al. use for their
GPU->CPU switch; redundant compute is cheaper than latency-bound
collectives on a <= few-hundred-lane tail).

Two node states are supported through the same harness:
  * the transaction-cost PWL state (``build_rz_sharded``)  — paper §3/4,
  * the scalar no-TC state (``build_notc_sharded``)        — paper appendix.

Tunables (hillclimbed in EXPERIMENTS.md §Perf):
  * ``round_depth``  L — halo width / levels per sync (the paper's L),
  * ``collapse_lanes`` — live width at which to switch to phase 2.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS

from . import pwl as P
from ..compat import shard_map
from .payoff import PayoffProcess
from .rz import rz_level_step

__all__ = ["plan_rounds", "build_rz_sharded", "build_notc_sharded",
           "GRID_AXIS", "grid_mesh", "resolve_grid_mesh", "sharded_rows"]

# --------------------------------------------------------------------- #
# scenario-axis mesh: shard the *contract batch* of the grid engines
# --------------------------------------------------------------------- #
# The engines above shard the lattice *node* axis of one contract (the
# paper's §4 scheme verbatim).  The grid engines go the other way: every
# row of a flat scenario batch is independent, so the batch shards over a
# 1-D device mesh with no collectives in the hot loop at all — the shard
# assignment itself (``core/partition.py::plan_shards``) is where the
# paper's §4.2 re-balancing reappears, at device granularity.

GRID_AXIS = "scenarios"


def grid_mesh(devices: int | None = None, *,
              axis_name: str = GRID_AXIS) -> Mesh:
    """1-D mesh over the first ``devices`` local devices (all if None)."""
    import numpy as np
    devs = jax.devices()
    w = len(devs) if devices is None else int(devices)
    if w < 1:
        raise ValueError("need devices >= 1")
    if w > len(devs):
        raise ValueError(
            f"asked for {w} devices but the process sees {len(devs)}; "
            "on CPU, launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={w} "
            "(or pass devices<=device_count / use the simulated path via "
            "resolve_grid_mesh)")
    return Mesh(np.array(devs[:w]), (axis_name,))


def resolve_grid_mesh(devices: int | None = None, mesh: Mesh | None = None):
    """Normalise the grid engines' ``devices=``/``mesh=`` knobs.

    Returns ``(mesh_or_None, n_shards)``:

      * an explicit 1-D ``mesh`` wins (``n_shards`` = its size);
      * ``devices`` in (None, 0, 1) -> the single-device path;
      * ``devices <= jax.device_count()`` -> a fresh :func:`grid_mesh`;
      * ``devices >  jax.device_count()`` -> the **simulated** sharded
        path: no mesh, but the same plan/permute/pad layout executed on
        the local device.  Rows are independent, so the numbers are
        bit-identical to a real mesh run — this is how single-device CI
        exercises every shard plan (see docs/KNOWN_ISSUES.md).
    """
    if mesh is not None:
        if len(mesh.shape) != 1:
            raise ValueError(f"grid mesh must be 1-D, got {dict(mesh.shape)}")
        if devices is not None and int(devices) != mesh.devices.size:
            raise ValueError(f"devices={devices} conflicts with the given "
                             f"{mesh.devices.size}-device mesh — pass one "
                             "or the other")
        return mesh, mesh.devices.size
    if devices is None or int(devices) <= 1:
        return None, 1
    w = int(devices)
    if w <= len(jax.devices()):
        return grid_mesh(w), w
    return None, w


def sharded_rows(fn, mesh: Mesh):
    """shard_map a flat-batch row function over a 1-D grid mesh.

    ``fn`` maps equal-length 1-D row arrays to a pytree of equal-length
    1-D row arrays; every input/output shards along the mesh's single
    axis.  There are no collectives: per-shard reductions (``max_pieces``)
    stay per-row and reduce on the host after the gather, so overflow
    semantics cannot diverge from the single-device path.
    """
    axis = mesh.axis_names[0]
    spec = PS(axis)
    return shard_map(fn, mesh=mesh,
                     in_specs=spec, out_specs=spec, check_vma=False)


# --------------------------------------------------------------------- #
# static round plan
# --------------------------------------------------------------------- #
def plan_rounds(n_steps: int, n_shards: int, round_depth: int,
                collapse_lanes: int | None = None):
    """Static partition of the N+1 backward levels into phase-1 rounds and
    a phase-2 (collapsed) tail.  Returns dict of static ints."""
    total_lanes = n_steps + 2
    shard_lanes = -(-total_lanes // n_shards)          # ceil
    halo = min(round_depth, shard_lanes)               # need halo <= S
    if collapse_lanes is None:
        collapse_lanes = max(shard_lanes, 2 * halo + 2)
    total_levels = n_steps + 1                         # levels N .. 0
    # phase 2 handles levels c-1 .. 0 (c levels); keep c <= collapse_lanes-1
    c_target = min(total_levels, max(collapse_lanes - 1, 1))
    rounds = -(-(total_levels - c_target) // halo) if total_levels > c_target else 0
    c = total_levels - rounds * halo                   # exact tail levels
    phase2_lanes = min(n_shards * shard_lanes, c + 1)  # live width at tail
    return dict(n_shards=n_shards, shard_lanes=shard_lanes, halo=halo,
                rounds=rounds, tail_levels=c, phase2_lanes=max(phase2_lanes, 1),
                total_lanes=n_shards * shard_lanes)


def _right_halo_perm(n_shards: int):
    """ppermute pairs: shard i receives the halo from shard i+1 (wrapping;
    the wrapped lanes land on the rightmost shard whose lanes are beyond the
    live tree and masked)."""
    return [(i, (i - 1) % n_shards) for i in range(n_shards)]


# --------------------------------------------------------------------- #
# generic sharded backward harness
# --------------------------------------------------------------------- #
def _run_sharded(state, scalars, *, plan, axis_name, n_steps,
                 level_step, finish):
    """Inside-shard_map body for one *contract batch* shard.

    state: pytree with arrays (bc, S, ...)  — lane axis second.
    scalars: pytree of per-contract (bc,) arrays (s0, sigma, ...).
    level_step(state_slice, lvl, scalars_slice, idx_offset) -> (state, stat)
    finish(state_slice, scalars_slice) -> result pytree (per contract)
    """
    S = plan["shard_lanes"]
    H = plan["halo"]
    W = plan["n_shards"]
    R = plan["rounds"]
    P2 = plan["phase2_lanes"]
    N = n_steps
    shard = jax.lax.axis_index(axis_name)
    offset = (shard * S).astype(jnp.float64)

    take = lambda a, n: a[:, :n]
    stat0 = jnp.zeros((), jnp.int32)

    def steps(buf, lvl0, scal, idx_off, depth):
        """depth local level-steps on one contract's lane buffer."""
        def body(j, carry):
            buf, stat = carry
            lvl = lvl0 - j
            buf, st = level_step(buf, lvl, scal, idx_off)
            return buf, jnp.maximum(stat, st)
        return jax.lax.fori_loop(0, depth, body, (buf, stat0))

    # ---- phase 1: distributed rounds with halo exchange ----------------
    # The halo is PACKED: every state leaf (PWL knots/values/slopes/counts
    # for both parties) is flattened into ONE (bc, H, width) f64 buffer so
    # each round issues a single ppermute instead of one per leaf — a
    # beyond-paper optimisation (collective-latency bound regime, see
    # EXPERIMENTS.md §Perf pricing cell).  int32 counts survive the f64
    # round-trip exactly (values <= PWL capacity).
    def _pack(halo_tree):
        leaves = jax.tree.leaves(halo_tree)
        bc_, hh = leaves[0].shape[0], leaves[0].shape[1]
        flat = [l.astype(jnp.float64).reshape(bc_, hh, -1) for l in leaves]
        return jnp.concatenate(flat, axis=-1), [l.shape for l in leaves], \
            [l.dtype for l in leaves]

    def _unpack(buf, shapes, dtypes, tree_like):
        out = []
        off = 0
        for s, dt in zip(shapes, dtypes):
            w = 1
            for d in s[2:]:
                w *= d
            piece = buf[:, :, off:off + w].reshape(s).astype(dt)
            out.append(piece)
            off += w
        return jax.tree.unflatten(jax.tree.structure(tree_like), out)

    def round_body(r, carry):
        state, stat = carry
        halo = jax.tree.map(lambda a: take(a, H), state)
        packed, shapes, dtypes = _pack(halo)
        packed = jax.lax.ppermute(packed, axis_name, _right_halo_perm(W))
        halo = _unpack(packed, shapes, dtypes, halo)
        buf = jax.tree.map(lambda a, h: jnp.concatenate([a, h], axis=1),
                           state, halo)
        lvl0 = jnp.asarray(N - r * H, jnp.float64)
        buf, st = jax.vmap(
            lambda b, sc: steps(b, lvl0, sc, offset, H),
            in_axes=(0, 0))(buf, scalars)
        state = jax.tree.map(lambda a: a[:, :S], buf)
        return state, jnp.maximum(stat, jnp.max(st))

    state, stat = jax.lax.fori_loop(0, R, round_body, (state, stat0))

    # ---- phase 2: collapse — gather live prefix, finish redundantly ----
    full = jax.tree.map(
        lambda a: jax.lax.all_gather(a, axis_name, axis=1, tiled=True), state)
    tail = jax.tree.map(lambda a: take(a, P2), full)
    lvl0 = jnp.asarray(plan["tail_levels"] - 1, jnp.float64)
    tail, st = jax.vmap(
        lambda b, sc: steps(b, lvl0, sc, jnp.zeros((), jnp.float64),
                            plan["tail_levels"]),
        in_axes=(0, 0))(tail, scalars)
    stat = jnp.maximum(stat, jnp.max(st))
    stat = jax.lax.pmax(stat, axis_name)

    res = jax.vmap(finish)(tail, scalars)
    return res, stat


# --------------------------------------------------------------------- #
# transaction-cost (PWL state) engine
# --------------------------------------------------------------------- #
def build_rz_sharded(mesh: Mesh, *, n_steps: int, payoff: PayoffProcess,
                     capacity: int = 48, round_depth: int = 8,
                     collapse_lanes: int | None = None,
                     data_axes=("data",), model_axis: str = "model",
                     dtype=jnp.float64) -> Callable:
    """Returns jit-able ``f(s0, sigma, rate, maturity, k) -> (ask, bid, st)``
    over a contract batch sharded on ``data_axes`` with the lattice node
    axis sharded over ``model_axis``."""
    W = 1
    for ax in (model_axis,):
        W *= mesh.shape[ax]
    plan = plan_rounds(n_steps, W, round_depth, collapse_lanes)
    S, T = plan["shard_lanes"], plan["total_lanes"]

    def level_step_tc(zpair, lvl, scal, idx_off):
        z_s, z_b = zpair
        params = dict(s0=scal["s0"], k=scal["k"],
                      sig_sqrt_dt=scal["sig_sqrt_dt"], r=scal["r"])
        z_s, p1 = rz_level_step(z_s, lvl, params, capacity=capacity,
                                seller=True, payoff=payoff, dtype=dtype,
                                idx_offset=idx_off)
        z_b, p2 = rz_level_step(z_b, lvl, params, capacity=capacity,
                                seller=False, payoff=payoff, dtype=dtype,
                                idx_offset=idx_off)
        return (z_s, z_b), jnp.maximum(p1, p2)

    def finish_tc(zpair, scal):
        z_s, z_b = zpair
        root = lambda z: jax.tree.map(lambda a: a[0], z)
        ask = P.eval_at(root(z_s), jnp.zeros((), dtype))
        bid = -P.eval_at(root(z_b), jnp.zeros((), dtype))
        return ask, bid

    def leaf_state(scal, lanes, idx_off):
        idx = idx_off + jnp.arange(lanes, dtype=dtype)
        s = scal["s0"] * jnp.exp((2.0 * idx - (n_steps + 1)) * scal["sig_sqrt_dt"])
        a = (1.0 + scal["k"]) * s
        b = (1.0 - scal["k"]) * s
        zero = jnp.zeros((lanes,), dtype)
        z = P.expense(zero, zero, a, b, capacity, dtype)
        return (z, z)

    def sharded_body(s0, sigma, rate, maturity, k):
        # (bc,) per-contract scalars on this data shard
        dt = maturity / n_steps
        scal = dict(s0=s0, k=k, sig_sqrt_dt=sigma * jnp.sqrt(dt),
                    r=jnp.exp(rate * dt))
        shard = jax.lax.axis_index(model_axis)
        offset = (shard * S).astype(dtype)
        state = jax.vmap(lambda sc: leaf_state(sc, S, offset))(scal)
        (ask, bid), stat = _run_sharded(
            state, scal, plan=plan, axis_name=model_axis, n_steps=n_steps,
            level_step=level_step_tc, finish=finish_tc)
        return ask, bid, stat

    cspec = PS(data_axes if len(data_axes) > 1 else data_axes[0])
    f = shard_map(
        sharded_body, mesh=mesh,
        in_specs=(cspec,) * 5,
        out_specs=(cspec, cspec, PS()),
        check_vma=False)
    return f


# --------------------------------------------------------------------- #
# no-transaction-cost (scalar state) engine — the appendix workload
# --------------------------------------------------------------------- #
def build_notc_sharded(mesh: Mesh, *, n_steps: int, strike: float,
                       kind: str = "put", round_depth: int = 50,
                       collapse_lanes: int | None = None,
                       data_axes=("data",), model_axis: str = "model",
                       dtype=jnp.float64) -> Callable:
    """Scalar backward induction, node axis sharded (appendix algorithm).

    Without transaction costs there is no extra time instant: the leaf is
    level N (N+1 nodes) and N levels are processed — hence the plan is laid
    out for ``n_steps - 1`` (plan_rounds internally adds the +1s).
    """
    W = mesh.shape[model_axis]
    plan = plan_rounds(n_steps - 1, W, round_depth, collapse_lanes)
    S = plan["shard_lanes"]

    def intrinsic(idx, lvl, scal):
        s = scal["s0"] * jnp.exp((2.0 * idx - lvl) * scal["sig_sqrt_dt"])
        pay = strike - s if kind == "put" else s - strike
        return jnp.maximum(pay, 0.0)

    def level_step_sc(v, lvl, scal, idx_off):
        lanes = v.shape[0]
        idx = idx_off + jnp.arange(lanes, dtype=dtype)
        live = idx <= lvl
        cont = (scal["p"] * jnp.roll(v, -1) + (1.0 - scal["p"]) * v) / scal["r"]
        vnew = jnp.maximum(intrinsic(idx, lvl, scal), cont)
        return jnp.where(live, vnew, v), jnp.zeros((), jnp.int32)

    def finish_sc(v, scal):
        return (v[0],)

    def sharded_body(s0, sigma, rate, maturity):
        dt = maturity / n_steps
        u = jnp.exp(sigma * jnp.sqrt(dt))
        r = jnp.exp(rate * dt)
        scal = dict(s0=s0, sig_sqrt_dt=sigma * jnp.sqrt(dt), r=r,
                    p=(r - 1.0 / u) / (u - 1.0 / u))
        shard = jax.lax.axis_index(model_axis)
        offset = (shard * S).astype(dtype)

        def leaf(sc):
            idx = offset + jnp.arange(S, dtype=dtype)
            return intrinsic(idx, jnp.asarray(n_steps, dtype), sc)

        state = jax.vmap(leaf)(scal)
        # leaf here is level N (no extra instant without costs): levels N-1..0
        (price,), stat = _run_sharded(
            state, scal, plan=plan, axis_name=model_axis,
            n_steps=n_steps - 1, level_step=level_step_sc, finish=finish_sc)
        return price

    cspec = PS(data_axes if len(data_axes) > 1 else data_axes[0])
    f = shard_map(
        sharded_body, mesh=mesh,
        in_specs=(cspec,) * 4, out_specs=cspec,
        check_vma=False)
    return f
