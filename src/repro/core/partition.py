"""The paper's partition/round schedule (Algorithm 1) — exact simulation.

This module reproduces, in pure Python, the block/region partition scheme of
§4.2 and the per-thread node counts of §4.3 (paper Table I).  It is used

  * to validate our reading of Algorithm 1 against the paper's own measured
    node counts (benchmark ``table1_node_counts``),
  * as the cost model behind the speedup simulator for paper Tables II/III
    (this container has one CPU core, so wall-clock pthread speedups cannot
    be re-measured; the schedule + a measured per-node cost can), and
  * to pick the round depth L and collapse threshold of the distributed
    shard_map engine (the same L/sync trade-off, see DESIGN.md §2).

Conventions from the paper:
  * the tree has levels t = 0 .. N+1 (the extra instant), level t has t+1
    nodes;
  * a *round* processes D = min(L, q-1) levels, q = per-thread node count
    at the base level;
  * thread i owns columns [s_i, e_i) in every level of the round (region A
    plus region B);  the last thread owns the remainder;
  * workloads are re-balanced before every round; p is reduced while the
    next base level has fewer than 2p nodes.

The pseudo-code reassigns ``n`` mid-loop (line 25: ``n <- B + 1`` *after*
``B <- B - D``), which makes the in-loop ``floor((n+1)/p)`` operate on
(node count + 1) from the second round on, while the text of §4.2 says
``floor((n+1)/p)`` with n+1 = node count.  Both variants are implemented.

**Finding** (see benchmark ``table1_node_counts``): the *text* semantics
(``literal=False``, the default) reproduces every cell of paper Table I
EXACTLY (9/9 cells, 0 node error); the literal pseudo-code overcounts by
~0.13-0.17%.  Line 25 of Algorithm 1 is evidently a typo (it should read
``n <- B``) and the authors' implementation used the text semantics.
"""
from __future__ import annotations

import dataclasses
from typing import List

__all__ = ["RoundInfo", "ScheduleResult", "simulate_schedule",
           "table1_reference", "pick_round_depth", "kernel_round_plan",
           "KernelRound", "DEFAULT_KERNEL_L"]

# Default per-round depth for the blocked Pallas kernels.  The paper's
# measured optimum L = 5 reflects pthread signal/barrier costs; for a
# fused VMEM round the per-round cost is one kernel dispatch, so larger L
# wins until the halo-staleness bound D <= block binds (see
# kernels/rz_step.py).
DEFAULT_KERNEL_L = 64


@dataclasses.dataclass
class RoundInfo:
    base_level: int          # B: level whose nodes are already done
    depth: int               # D: levels processed this round
    p: int                   # threads active this round
    per_thread: List[int]    # nodes processed by each ORIGINAL thread id
    sync_events: int         # signals + barrier (cost model input)


@dataclasses.dataclass
class ScheduleResult:
    n_steps: int
    L: int
    p0_nodes: int            # nodes processed by thread 0 (incl. leaf init)
    per_thread: List[int]
    rounds: List[RoundInfo]
    total_nodes: int         # all nodes in the tree, levels 0..N+1

    @property
    def makespan_nodes(self) -> int:
        """Schedule length if every node costs 1 and threads run in parallel:
        sum over rounds of the busiest thread's nodes (plus leaf init)."""
        init = max(self._init_counts)
        return init + sum(max(r.per_thread) for r in self.rounds)

    _init_counts: List[int] = dataclasses.field(default_factory=list)


def simulate_schedule(n_steps: int, p: int, L: int,
                      literal: bool = False) -> ScheduleResult:
    """Run Algorithm 1's schedule and count nodes per (original) thread."""
    if p < 1 or L < 1 or n_steps < 1:
        raise ValueError("need p >= 1, L >= 1, N >= 1")
    N = n_steps
    p_orig = p

    counts = [0] * p_orig
    rounds: List[RoundInfo] = []

    # --- initialisation at the leaf level t = N+1 (N+2 nodes) -------------
    n = N + 1                       # as in Algorithm 1 line 2 (level index)
    q = (n + 1) // p
    bounds = [(i * q, (i + 1) * q if i != p - 1 else n + 1) for i in range(p)]
    init_counts = [e - s for s, e in bounds]
    for i, c in enumerate(init_counts):
        counts[i] += c

    B = N + 1
    while B > 0:
        q = (n + 1) // p
        D = min(L, q - 1)
        D = max(D, 1)
        per_round = [0] * p_orig
        for C in range(B - 1, B - D - 1, -1):       # levels processed
            width = C + 1
            for i in range(p):
                s, e = bounds[i]
                got = max(0, min(e, width) - s)
                per_round[i] += got
        for i in range(p_orig):
            counts[i] += per_round[i]
        # each inner thread signals its left neighbour once; one barrier
        rounds.append(RoundInfo(base_level=B, depth=D, p=p,
                                per_thread=per_round,
                                sync_events=(p - 1) + 1))
        B = B - D
        if B <= 0:
            break
        # --- re-balance for the next round --------------------------------
        if literal:
            n = B + 1               # pseudo-code line 25 (count semantics)
        else:
            n = B                   # text semantics: n = base level index
        node_count = B + 1
        while node_count < 2 * p and p > 1:
            p = max(p - 1, 1)
        q = (n + 1) // p
        bounds = [(i * q, (i + 1) * q if i != p - 1 else n + 1)
                  for i in range(p)]

    total = (N + 2) * (N + 3) // 2
    res = ScheduleResult(n_steps=N, L=L, p0_nodes=counts[0],
                         per_thread=counts, rounds=rounds, total_nodes=total)
    res._init_counts = init_counts
    return res


@dataclasses.dataclass(frozen=True)
class KernelRound:
    """One round of the blocked-kernel schedule (all fields static).

    ``lvl0`` is the base level B whose node values exist when the round
    starts; the round computes levels ``B-1 .. B-depth``.  ``lanes`` is the
    (re-balanced) node-axis extent the round operates on — a multiple of
    ``block`` — and ``nblk = lanes // block`` is the kernel grid size.
    """
    lvl0: int
    depth: int
    lanes: int
    block: int

    @property
    def nblk(self) -> int:
        return self.lanes // self.block


def pick_round_depth(base_level: int, block: int | None,
                     L: int | None = None) -> int:
    """Round depth D for the blocked kernels — Algorithm 1's ``D = min(L,
    q-1)`` with q = nodes per thread, specialised to fixed-size blocks.

    A multi-block round carries one right-neighbour halo block, so stale
    data reaches the owned lanes after ``block`` steps: D <= block.  A
    single-block round has no halo (the whole live level is in VMEM) and D
    is bounded only by the remaining levels.
    """
    L = DEFAULT_KERNEL_L if L is None else L
    d = min(L, base_level)
    if block is not None and base_level + 1 > block:   # multi-block: halo bound
        d = min(d, block)
    return max(1, d)


def kernel_round_plan(n_steps: int, *, levels: int | None = None,
                      block: int | None = None) -> List[KernelRound]:
    """Static round schedule for the blocked Pallas TC engine.

    Mirrors Algorithm 1's outer loop: the base level B starts at N+1 (the
    extra instant) and each round advances ``D = pick_round_depth(B)``
    levels.  Before every round the lane extent is **re-balanced** to the
    live tree — the kernel analogue of the paper shedding threads as the
    tree narrows (§4.2): a round at base level B only needs lanes
    ``0..B``, so later rounds run on ever smaller (statically shaped)
    arrays instead of dragging the full leaf-level width to the root.

    ``block`` of None means one block per round sized to the live level
    (pure re-balancing, no halo); otherwise lanes are padded to a multiple
    of ``block`` and rounds with more than one block use the
    right-neighbour halo scheme of ``kernels/rz_step.py``.
    """
    if n_steps < 1:
        raise ValueError("need n_steps >= 1")
    if block is not None and block < 1:
        raise ValueError("need block >= 1")
    B = n_steps + 1
    plan: List[KernelRound] = []
    while B > 0:
        D = pick_round_depth(B, block, levels)
        live = B + 1                       # input lanes 0..B
        if block is None or live <= block:
            lanes, blk = live, live        # single block, no halo
        else:
            lanes = -(-live // block) * block
            blk = block
        plan.append(KernelRound(lvl0=B, depth=D, lanes=lanes, block=blk))
        B -= D
    return plan


def table1_reference() -> dict:
    """Paper Table I: actual node counts of thread p_0, L = 5."""
    return {
        (2, 1200): 362_999, (2, 1350): 458_999, (2, 1500): 566_249,
        (4, 1200): 181_198, (4, 1350): 229_161, (4, 1500): 282_748,
        (8, 1200): 90_311, (8, 1350): 114_255, (8, 1500): 141_008,
    }
