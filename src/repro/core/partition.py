"""The paper's partition/round schedule (Algorithm 1) — exact simulation.

This module reproduces, in pure Python, the block/region partition scheme of
§4.2 and the per-thread node counts of §4.3 (paper Table I).  It is used

  * to validate our reading of Algorithm 1 against the paper's own measured
    node counts (benchmark ``table1_node_counts``),
  * as the cost model behind the speedup simulator for paper Tables II/III
    (this container has one CPU core, so wall-clock pthread speedups cannot
    be re-measured; the schedule + a measured per-node cost can), and
  * to pick the round depth L and collapse threshold of the distributed
    shard_map engine (the same L/sync trade-off, see DESIGN.md §2).

Conventions from the paper:
  * the tree has levels t = 0 .. N+1 (the extra instant), level t has t+1
    nodes;
  * a *round* processes D = min(L, q-1) levels, q = per-thread node count
    at the base level;
  * thread i owns columns [s_i, e_i) in every level of the round (region A
    plus region B);  the last thread owns the remainder;
  * workloads are re-balanced before every round; p is reduced while the
    next base level has fewer than 2p nodes.

The pseudo-code reassigns ``n`` mid-loop (line 25: ``n <- B + 1`` *after*
``B <- B - D``), which makes the in-loop ``floor((n+1)/p)`` operate on
(node count + 1) from the second round on, while the text of §4.2 says
``floor((n+1)/p)`` with n+1 = node count.  Both variants are implemented.

**Finding** (see benchmark ``table1_node_counts``): the *text* semantics
(``literal=False``, the default) reproduces every cell of paper Table I
EXACTLY (9/9 cells, 0 node error); the literal pseudo-code overcounts by
~0.13-0.17%.  Line 25 of Algorithm 1 is evidently a typo (it should read
``n <- B``) and the authors' implementation used the text semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["RoundInfo", "ScheduleResult", "simulate_schedule",
           "table1_reference", "pick_round_depth", "kernel_round_plan",
           "KernelRound", "DEFAULT_KERNEL_L",
           "ShardPlan", "scenario_costs", "plan_shards", "shard_layout",
           "replan_shards", "ShardRebalancer"]

# Default per-round depth for the blocked Pallas kernels.  The paper's
# measured optimum L = 5 reflects pthread signal/barrier costs; for a
# fused VMEM round the per-round cost is one kernel dispatch, so larger L
# wins until the halo-staleness bound D <= block binds (see
# kernels/rz_step.py).
DEFAULT_KERNEL_L = 64


@dataclasses.dataclass
class RoundInfo:
    base_level: int          # B: level whose nodes are already done
    depth: int               # D: levels processed this round
    p: int                   # threads active this round
    per_thread: List[int]    # nodes processed by each ORIGINAL thread id
    sync_events: int         # signals + barrier (cost model input)


@dataclasses.dataclass
class ScheduleResult:
    n_steps: int
    L: int
    p0_nodes: int            # nodes processed by thread 0 (incl. leaf init)
    per_thread: List[int]
    rounds: List[RoundInfo]
    total_nodes: int         # all nodes in the tree, levels 0..N+1

    @property
    def makespan_nodes(self) -> int:
        """Schedule length if every node costs 1 and threads run in parallel:
        sum over rounds of the busiest thread's nodes (plus leaf init)."""
        init = max(self._init_counts)
        return init + sum(max(r.per_thread) for r in self.rounds)

    _init_counts: List[int] = dataclasses.field(default_factory=list)


def simulate_schedule(n_steps: int, p: int, L: int,
                      literal: bool = False) -> ScheduleResult:
    """Run Algorithm 1's schedule and count nodes per (original) thread."""
    if p < 1 or L < 1 or n_steps < 1:
        raise ValueError("need p >= 1, L >= 1, N >= 1")
    N = n_steps
    p_orig = p

    counts = [0] * p_orig
    rounds: List[RoundInfo] = []

    # --- initialisation at the leaf level t = N+1 (N+2 nodes) -------------
    n = N + 1                       # as in Algorithm 1 line 2 (level index)
    q = (n + 1) // p
    bounds = [(i * q, (i + 1) * q if i != p - 1 else n + 1) for i in range(p)]
    init_counts = [e - s for s, e in bounds]
    for i, c in enumerate(init_counts):
        counts[i] += c

    B = N + 1
    while B > 0:
        q = (n + 1) // p
        D = min(L, q - 1)
        D = max(D, 1)
        per_round = [0] * p_orig
        for C in range(B - 1, B - D - 1, -1):       # levels processed
            width = C + 1
            for i in range(p):
                s, e = bounds[i]
                got = max(0, min(e, width) - s)
                per_round[i] += got
        for i in range(p_orig):
            counts[i] += per_round[i]
        # each inner thread signals its left neighbour once; one barrier
        rounds.append(RoundInfo(base_level=B, depth=D, p=p,
                                per_thread=per_round,
                                sync_events=(p - 1) + 1))
        B = B - D
        if B <= 0:
            break
        # --- re-balance for the next round --------------------------------
        if literal:
            n = B + 1               # pseudo-code line 25 (count semantics)
        else:
            n = B                   # text semantics: n = base level index
        node_count = B + 1
        while node_count < 2 * p and p > 1:
            p = max(p - 1, 1)
        q = (n + 1) // p
        bounds = [(i * q, (i + 1) * q if i != p - 1 else n + 1)
                  for i in range(p)]

    total = (N + 2) * (N + 3) // 2
    res = ScheduleResult(n_steps=N, L=L, p0_nodes=counts[0],
                         per_thread=counts, rounds=rounds, total_nodes=total)
    res._init_counts = init_counts
    return res


@dataclasses.dataclass(frozen=True)
class KernelRound:
    """One round of the blocked-kernel schedule (all fields static).

    ``lvl0`` is the base level B whose node values exist when the round
    starts; the round computes levels ``B-1 .. B-depth``.  ``lanes`` is the
    (re-balanced) node-axis extent the round operates on — a multiple of
    ``block`` — and ``nblk = lanes // block`` is the kernel grid size.
    """
    lvl0: int
    depth: int
    lanes: int
    block: int

    @property
    def nblk(self) -> int:
        return self.lanes // self.block


def pick_round_depth(base_level: int, block: int | None,
                     L: int | None = None) -> int:
    """Round depth D for the blocked kernels — Algorithm 1's ``D = min(L,
    q-1)`` with q = nodes per thread, specialised to fixed-size blocks.

    A multi-block round carries one right-neighbour halo block, so stale
    data reaches the owned lanes after ``block`` steps: D <= block.  A
    single-block round has no halo (the whole live level is in VMEM) and D
    is bounded only by the remaining levels.
    """
    L = DEFAULT_KERNEL_L if L is None else L
    d = min(L, base_level)
    if block is not None and base_level + 1 > block:   # multi-block: halo bound
        d = min(d, block)
    return max(1, d)


def kernel_round_plan(n_steps: int, *, levels: int | None = None,
                      block: int | None = None) -> List[KernelRound]:
    """Static round schedule for the blocked Pallas TC engine.

    Mirrors Algorithm 1's outer loop: the base level B starts at N+1 (the
    extra instant) and each round advances ``D = pick_round_depth(B)``
    levels.  Before every round the lane extent is **re-balanced** to the
    live tree — the kernel analogue of the paper shedding threads as the
    tree narrows (§4.2): a round at base level B only needs lanes
    ``0..B``, so later rounds run on ever smaller (statically shaped)
    arrays instead of dragging the full leaf-level width to the root.

    ``block`` of None means one block per round sized to the live level
    (pure re-balancing, no halo); otherwise lanes are padded to a multiple
    of ``block`` and rounds with more than one block use the
    right-neighbour halo scheme of ``kernels/rz_step.py``.
    """
    if n_steps < 1:
        raise ValueError("need n_steps >= 1")
    if block is not None and block < 1:
        raise ValueError("need block >= 1")
    B = n_steps + 1
    plan: List[KernelRound] = []
    while B > 0:
        D = pick_round_depth(B, block, levels)
        live = B + 1                       # input lanes 0..B
        if block is None or live <= block:
            lanes, blk = live, live        # single block, no halo
        else:
            lanes = -(-live // block) * block
            blk = block
        plan.append(KernelRound(lvl0=B, depth=D, lanes=lanes, block=blk))
        B -= D
    return plan


# ===================================================================== #
# scenario-axis shard planner — §4.2 re-balancing lifted to a device mesh
# ===================================================================== #
#
# The paper re-partitions the *node* axis across threads before every
# round because the live tree shrinks.  The scenario-grid engine has the
# orthogonal axis: a flat batch of contracts whose per-row cost is uneven
# (transaction-cost rows run the PWL sweep, ~max_pieces x a frictionless
# row; deeper trees cost ~N^2).  The planner below assigns whole scenario
# rows to devices of a 1-D mesh so the *predicted* per-device work is
# equal, and the rebalancer re-plans from the previous flush's measured
# per-shard seconds — the device-level analogue of the paper's per-round
# processor reassignment (p <- p-1 / bounds recomputed).
#
# Everything here is pure Python/numpy over static ints: a plan is made
# on the host before the compiled call, exactly like ``kernel_round_plan``.

def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Static assignment of scenario rows to the shards of a 1-D mesh.

    ``shards[d]`` holds the original row indices device ``d`` owns;
    ``work[d]`` is the predicted cost of those rows under the cost model
    the plan was made with.  ``lanes`` is the per-device row count after
    padding — every device gets exactly ``lanes`` rows (shorter shards
    repeat one of their own rows; an empty shard repeats row 0), so the
    compiled program sees one static shape ``(n_shards * lanes,)``.
    """
    n_shards: int
    shards: Tuple[Tuple[int, ...], ...]
    work: Tuple[float, ...]
    lanes: int
    n_rows: int

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(len(s) for s in self.shards)

    @property
    def padded_rows(self) -> int:
        return self.n_shards * self.lanes

    @property
    def work_spread(self) -> float:
        """(max - min) / mean of predicted per-shard work over non-empty
        shards — the planner's balance figure (0 = perfectly equal)."""
        w = [x for x, s in zip(self.work, self.shards) if s]
        if not w:
            return 0.0
        mean = sum(w) / len(w)
        return (max(w) - min(w)) / mean if mean > 0 else 0.0


def scenario_costs(n_steps: int, cost_rate, *, capacity: int = 48,
                   pieces=None, engine: Optional[str] = None,
                   n_paths: int = 4096, n_exercise: Optional[int] = None,
                   n_assets: int = 1) -> np.ndarray:
    """Predicted relative cost of each scenario row of a flat grid.

    Cost model (see docs/ARCHITECTURE.md "Engine matrix"):

      * a frictionless lattice row is one backward induction over the
        tree: ~``(N+1)^2 / 2`` node updates -> cost ``N^2``;
      * a transaction-cost row runs the Roux–Zastawniak PWL sweep at
        every node: ~``pieces`` knots of work per node -> cost
        ``N^2 * pieces``.  Before anything has run, ``pieces`` is the
        worst-case ``capacity``; after a flush the *measured*
        ``max_pieces`` is a much tighter estimate (feed it back here);
      * an ``engine="lsmc"`` row simulates ``n_paths`` basket paths of
        ``n_assets`` GBMs at ``n_exercise`` dates and regresses at each
        -> cost ``n_paths * n_exercise * n_assets``, identical across
        rows (MC work does not depend on the row's lambda).

    ``cost_rate`` is the per-row lambda array; ``pieces`` may be a scalar
    or a per-row array.  Returns a float64 array of per-row costs.
    """
    cr = np.atleast_1d(np.asarray(cost_rate, np.float64))
    if engine == "lsmc":
        n_ex = (n_steps + 1) if n_exercise is None else int(n_exercise)
        cost = float(n_paths) * max(n_ex, 1) * max(int(n_assets), 1)
        return np.full(cr.shape, cost)
    base = float(n_steps) ** 2
    if pieces is None:
        pieces = capacity
    mult = np.broadcast_to(np.asarray(pieces, np.float64), cr.shape)
    return base * np.where(cr > 0.0, np.maximum(mult, 1.0), 1.0)


def plan_shards(costs: Sequence[float], n_shards: int, *,
                device_speed: Optional[Sequence[float]] = None,
                lanes_pow2: bool = False) -> ShardPlan:
    """Assign rows to ``n_shards`` devices, equalising predicted work.

    Greedy LPT (longest-processing-time): rows sorted by descending cost
    are placed on the device with the smallest predicted *finish time*
    ``(load + cost) / speed``.  ``device_speed`` (relative, default all
    1.0) is how the rebalancer steers work away from shards that ran
    slow last flush.  With uneven costs the shard *sizes* come out
    uneven while the per-device work stays near-equal — the device-level
    mirror of the paper's ``floor((n+1)/p)`` bounds recomputation.

    ``lanes_pow2`` rounds the per-device lane count up to a power of two
    so a stream of slightly different batches reuses compiled shapes
    (the serving layer's pad-to-bucket discipline, per device).
    """
    costs = np.asarray(costs, np.float64)
    n = costs.shape[0]
    W = int(n_shards)
    if W < 1:
        raise ValueError("need n_shards >= 1")
    if np.any(costs < 0):
        raise ValueError("row costs must be >= 0")
    speed = (np.ones(W) if device_speed is None
             else np.asarray(device_speed, np.float64))
    if speed.shape != (W,) or np.any(speed <= 0):
        raise ValueError(f"device_speed must be {W} positive factors")

    members: List[List[int]] = [[] for _ in range(W)]
    load = np.zeros(W)
    # stable sort: equal-cost rows keep index order -> deterministic plans
    for i in np.argsort(-costs, kind="stable"):
        d = int(np.argmin((load + costs[i]) / speed))
        members[d].append(int(i))
        load[d] += costs[i]
    for m in members:
        m.sort()                     # contiguous-looking, deterministic
    lanes = max(1, max(len(m) for m in members))
    if lanes_pow2:
        lanes = _next_pow2(lanes)
    return ShardPlan(n_shards=W,
                     shards=tuple(tuple(m) for m in members),
                     work=tuple(float(x) for x in load),
                     lanes=lanes, n_rows=n)


def shard_layout(plan: ShardPlan):
    """Materialise a plan as gather/scatter index maps.

    Returns ``(gather_idx, positions)``:

      * ``gather_idx`` — int array of length ``plan.padded_rows``; row
        ``j`` of the device-laid-out batch is original row
        ``gather_idx[j]``.  Each device's window of ``lanes`` rows holds
        its assigned rows followed by pad repeats of its last row (row 0
        for an empty shard) — pads are duplicates of *real* rows, so
        max-reductions (``max_pieces``!) and OverflowError behaviour are
        untouched by construction.
      * ``positions`` — int array of length ``plan.n_rows``;
        ``positions[i]`` is where original row ``i`` landed, so results
        come back as ``out[i] = y[positions[i]]``.
    """
    gather = np.zeros(plan.padded_rows, np.int64)
    positions = np.full(plan.n_rows, -1, np.int64)
    for d, rows in enumerate(plan.shards):
        base = d * plan.lanes
        fill = rows[-1] if rows else 0
        for slot in range(plan.lanes):
            src = rows[slot] if slot < len(rows) else fill
            gather[base + slot] = src
            if slot < len(rows):
                positions[rows[slot]] = base + slot
    if np.any(positions < 0):
        raise ValueError("plan does not cover every row exactly once")
    return gather, positions


def _speed_from_seconds(work, per_shard_seconds) -> np.ndarray:
    """Relative device speeds implied by measured per-shard seconds.

    A shard that did ``work`` units in ``seconds`` ran at ``work/seconds``
    units/s; normalising by the mean gives dimensionless speed factors
    for the next LPT pass.  Shards with no work (or no measured time)
    get speed 1.0 — no evidence, no steering.
    """
    w = np.asarray(work, np.float64)
    s = np.asarray(per_shard_seconds, np.float64)
    if w.shape != s.shape:
        raise ValueError(f"work {w.shape} vs seconds {s.shape}")
    ok = (w > 0) & (s > 0)
    speed = np.ones_like(w)
    if np.any(ok):
        raw = np.where(ok, w / np.where(ok, s, 1.0), np.nan)
        speed = np.where(ok, raw / np.nanmean(raw), 1.0)
    return speed


def replan_shards(costs: Sequence[float], prev: ShardPlan,
                  per_shard_seconds: Sequence[float], *,
                  n_shards: Optional[int] = None,
                  lanes_pow2: bool = False) -> ShardPlan:
    """Re-plan ``costs`` using the previous flush's measured seconds.

    The rebalance hook: measured per-shard wall seconds against the
    previous plan's predicted work yield per-device speed factors, and
    the next plan's LPT pass equalises *finish time* instead of raw
    work.  ``costs`` may describe a different batch than ``prev`` — the
    calibration is per-device, not per-row, exactly like the paper
    re-deriving thread bounds each round from the current live width.
    """
    speed = _speed_from_seconds(prev.work, per_shard_seconds)
    return plan_shards(costs, n_shards or prev.n_shards,
                       device_speed=speed, lanes_pow2=lanes_pow2)


class ShardRebalancer:
    """Keeps per-stream device-speed estimates and plans each flush.

    One instance serves many independent streams (the serving layer keys
    by bucket): :meth:`plan` makes the next plan with the stream's
    current speed estimates, :meth:`observe` folds a flush's measured
    per-shard seconds in with an EMA so one noisy measurement cannot
    flip the assignment (``ema=1.0`` trusts only the last flush).
    """

    def __init__(self, *, ema: float = 0.5):
        if not 0.0 < ema <= 1.0:
            raise ValueError("ema must be in (0, 1]")
        self.ema = float(ema)
        self._speed: Dict[object, np.ndarray] = {}

    def speed(self, key, n_shards: int) -> np.ndarray:
        got = self._speed.get(key)
        if got is None or got.shape[0] != n_shards:
            return np.ones(n_shards)
        return got.copy()            # callers cannot corrupt the estimate

    def plan(self, key, costs, n_shards: int, *,
             lanes_pow2: bool = False) -> ShardPlan:
        return plan_shards(costs, n_shards,
                           device_speed=self.speed(key, n_shards),
                           lanes_pow2=lanes_pow2)

    def observe(self, key, plan: ShardPlan, per_shard_seconds) -> np.ndarray:
        """Fold one flush's measurement in; returns the updated speeds."""
        obs = _speed_from_seconds(plan.work, per_shard_seconds)
        cur = self.speed(key, plan.n_shards)
        new = (1.0 - self.ema) * cur + self.ema * obs
        new = np.maximum(new, 1e-6)
        self._speed[key] = new / np.mean(new)
        return self._speed[key].copy()


def table1_reference() -> dict:
    """Paper Table I: actual node counts of thread p_0, L = 5."""
    return {
        (2, 1200): 362_999, (2, 1350): 458_999, (2, 1500): 566_249,
        (4, 1200): 181_198, (4, 1350): 229_161, (4, 1500): 282_748,
        (8, 1200): 90_311, (8, 1350): 114_255, (8, 1500): 141_008,
    }
