"""Platform configuration layer: ``platform={cpu,gpu,tpu}`` policy.

Every Pallas call site in the repo takes an ``interpret=`` knob whose
default is ``None`` — *resolve from the platform policy* — instead of the
historical hard-coded ``interpret=True``.  This module owns that policy:

  * which platform is active (detected from jax, or pinned by
    :func:`set_platform` — the SNIPPETS/bayespec ``jax_platform_name``
    idiom);
  * whether Pallas kernels run compiled or in interpret mode there
    (:func:`resolve_interpret` / :func:`supports_compiled_pallas` — CPU
    has **no** compiled Pallas lowering on the pinned jax 0.4.37:
    ``pallas_call(interpret=False)`` raises ``ValueError: Only interpret
    mode is supported on CPU backend.``, so CPU policy is interpret);
  * the compiled-path dtype policy (:func:`default_dtype` — float64 on
    CPU where interpret mode is CPU-exact, float32 on GPU/TPU where the
    compiled lowerings carry no f64);
  * the XLA flags a platform wants (:func:`xla_flags` /
    :func:`apply_xla_flags` — the GPU set is the latency-hiding
    scheduler / async-collectives exemplar named by the ROADMAP).

What each kernel *promises* to a compiled lowering (no sorts, int32
bookkeeping, declared dynamic gathers) is the per-kernel contract
registry in :mod:`repro.kernels.contracts`, asserted by
``tests/test_lowering_contract.py``; this module only decides which
lowering runs where.  See ``docs/PLATFORMS.md``.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

__all__ = [
    "PLATFORMS", "PlatformPolicy", "POLICIES", "detect_platform",
    "active_platform", "set_platform", "resolve_interpret",
    "supports_compiled_pallas", "default_dtype", "xla_flags",
    "apply_xla_flags", "platform_summary",
]

PLATFORMS = ("cpu", "gpu", "tpu")

# The GPU flag set follows the bayespec exemplar in SNIPPETS.md: Triton
# fusions plus the latency-hiding scheduler / async collectives that the
# ROADMAP's backend-matrix item calls out.  TPU and CPU need no flags —
# Mosaic is the default TPU lowering and CPU is the interpret oracle.
_GPU_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


@dataclasses.dataclass(frozen=True)
class PlatformPolicy:
    """Per-platform lowering/dtype defaults the pricing stack resolves."""
    platform: str
    interpret: bool            # default for every `interpret=None` knob
    compiled_pallas: bool      # does pallas_call(interpret=False) lower?
    default_dtype: str         # "float64" | "float32" dtype policy
    xla_flags: tuple[str, ...] = ()


POLICIES: dict[str, PlatformPolicy] = {
    "cpu": PlatformPolicy("cpu", interpret=True, compiled_pallas=False,
                          default_dtype="float64"),
    "gpu": PlatformPolicy("gpu", interpret=False, compiled_pallas=True,
                          default_dtype="float32", xla_flags=_GPU_XLA_FLAGS),
    "tpu": PlatformPolicy("tpu", interpret=False, compiled_pallas=True,
                          default_dtype="float32"),
}

# Explicit override installed by set_platform(); None = detect from jax.
_OVERRIDE: str | None = None


def _validate(platform: str) -> str:
    platform = str(platform).lower()
    if platform not in PLATFORMS:
        raise ValueError(
            f"unknown platform {platform!r}; expected one of {PLATFORMS}")
    return platform


def detect_platform() -> str:
    """Platform jax is actually executing on (``jax.default_backend()``)."""
    backend = jax.default_backend()
    return backend if backend in PLATFORMS else "cpu"


def active_platform() -> str:
    """The platform policy resolution uses: override if set, else detected."""
    return _OVERRIDE if _OVERRIDE is not None else detect_platform()


def set_platform(platform: str | None, *, configure_jax: bool = True) -> str:
    """Pin the active platform (``None`` resets to auto-detect).

    With ``configure_jax=True`` (default) this also applies the
    platform's XLA flags and sets ``jax_platform_name`` — the bayespec
    idiom — which only takes full effect *before* the jax backend
    initialises; afterwards jax keeps its existing devices and only the
    policy side (interpret/dtype resolution) changes.  Pass
    ``configure_jax=False`` to change policy resolution alone (what the
    CPU test-suite does to exercise gpu/tpu policy branches).
    """
    global _OVERRIDE
    if platform is None:
        _OVERRIDE = None
        return detect_platform()
    platform = _validate(platform)
    _OVERRIDE = platform
    if configure_jax:
        apply_xla_flags(platform)
        jax.config.update("jax_platform_name", platform)
    return platform


def resolve_interpret(interpret: bool | None = None,
                      platform: str | None = None) -> bool:
    """Resolve an ``interpret=`` knob: explicit wins, else platform policy."""
    if interpret is not None:
        return bool(interpret)
    key = _validate(platform) if platform is not None else active_platform()
    return POLICIES[key].interpret


def supports_compiled_pallas(platform: str | None = None) -> bool:
    """True where ``pallas_call(interpret=False)`` has a real lowering."""
    key = _validate(platform) if platform is not None else active_platform()
    return POLICIES[key].compiled_pallas


def default_dtype(platform: str | None = None):
    """The platform's dtype policy (f64 interpret oracle, f32 compiled)."""
    key = _validate(platform) if platform is not None else active_platform()
    return jnp.dtype(POLICIES[key].default_dtype)


def xla_flags(platform: str | None = None) -> tuple[str, ...]:
    key = _validate(platform) if platform is not None else active_platform()
    return POLICIES[key].xla_flags


def apply_xla_flags(platform: str | None = None) -> str:
    """Append the platform's XLA flags to ``XLA_FLAGS`` (idempotent).

    XLA reads the env var at backend initialisation, so call this before
    the first jax computation (``launch/price.py --platform`` does).
    Returns the resulting ``XLA_FLAGS`` value.
    """
    current = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in xla_flags(platform) if f not in current]
    if missing:
        current = " ".join(filter(None, [current, *missing]))
        os.environ["XLA_FLAGS"] = current
    return current


def platform_summary() -> dict:
    """One-dict description of the resolved policy (benches embed this)."""
    key = active_platform()
    pol = POLICIES[key]
    return {
        "platform": key,
        "detected": detect_platform(),
        "interpret": pol.interpret,
        "compiled_pallas": pol.compiled_pallas,
        "default_dtype": pol.default_dtype,
        "xla_flags": list(pol.xla_flags),
        "jax_version": jax.__version__,
    }
