"""Sequential reference implementation of the Roux–Zastawniak algorithms.

Computes the ask price (Algorithm 3.1) and bid price (Algorithm 3.5) of an
American option under proportional transaction costs by exact backward
induction on the recombining binomial tree, carrying one piecewise-linear
expense function per node (see :mod:`repro.core.pwl_ref`).

This is the correctness oracle for the vectorised JAX engine
(:mod:`repro.core.rz`) and the distributed engine
(:mod:`repro.core.distributed`).  It mirrors the paper's §3 exactly:

  level N+1:  z = u with payoff (0, 0)              (extra time instant)
  level n<=N: w = max(z_up, z_down)                 (worst case over moves)
              v = cone_infconv(w / r, S^a_n, S^b_n) (rebalancing)
              z = max(u_n, v)   [seller]  /  min(u_n, v)   [buyer]
  ask = z_0(0),  bid = -z'_0(0)

No transaction costs apply at t = 0 (S^a_0 = S_0 = S^b_0), following the
paper §4.1.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .lattice import LatticeModel
from .payoff import PayoffProcess
from .pwl_ref import PWLRef, cone_infconv, expense_function, pwl_max, pwl_min

__all__ = ["price_ref", "PriceResult"]


@dataclasses.dataclass
class PriceResult:
    ask: float
    bid: float
    max_pieces: int           # max knot count seen (sizes the fixed-K engine)
    z_seller_root: PWLRef
    z_buyer_root: PWLRef


def _leaf_functions(model: LatticeModel, n_level: int) -> tuple[list, list]:
    """z at the extra time instant t = N+1: payoff (0,0) for both parties."""
    s = model.s0 * np.exp(
        (2.0 * np.arange(n_level + 1, dtype=np.float64) - n_level)
        * model.sigma * np.sqrt(model.maturity / model.n_steps))
    k = model.cost_rate
    seller = [expense_function(0.0, 0.0, (1 + k) * si, (1 - k) * si) for si in s]
    buyer = [expense_function(0.0, 0.0, (1 + k) * si, (1 - k) * si) for si in s]
    return seller, buyer


def price_ref(model: LatticeModel, payoff: PayoffProcess,
              max_level: Optional[int] = None) -> PriceResult:
    """Exact sequential ask/bid prices (float64).

    ``max_level`` (testing hook) stops the recursion early and returns the
    functions at that level's first node instead of the root.
    """
    n = model.n_steps
    r = model.r
    k = model.cost_rate

    zs, zb = _leaf_functions(model, n + 1)
    max_pieces = 2

    for lvl in range(n, -1, -1):
        s_vec = model.stock_level(lvl)
        s_ask, s_bid = model.ask_bid_level(lvl)
        xi = payoff.xi(s_vec)
        zeta = payoff.zeta(s_vec)
        new_s: list[PWLRef] = []
        new_b: list[PWLRef] = []
        for i in range(lvl + 1):
            a_i = float(s_ask[i])
            b_i = float(s_bid[i])
            # seller -------------------------------------------------------
            w = pwl_max(zs[i + 1], zs[i]).scale(1.0 / r)
            v = cone_infconv(w, a_i, b_i)
            u = expense_function(float(xi[i]), float(zeta[i]), a_i, b_i)
            z = pwl_max(u, v)
            new_s.append(z)
            # buyer --------------------------------------------------------
            wb = pwl_max(zb[i + 1], zb[i]).scale(1.0 / r)
            vb = cone_infconv(wb, a_i, b_i)
            ub = expense_function(-float(xi[i]), -float(zeta[i]), a_i, b_i)
            # the buyer *chooses* between exercising and waiting
            zbuy = pwl_min(ub, vb)
            new_b.append(zbuy)
            max_pieces = max(max_pieces, z.m, zbuy.m, w.m, wb.m, v.m, vb.m)
        zs, zb = new_s, new_b
        if max_level is not None and lvl == max_level:
            break

    return PriceResult(
        ask=float(zs[0](0.0)),
        bid=float(-zb[0](0.0)),
        max_pieces=max_pieces,
        z_seller_root=zs[0],
        z_buyer_root=zb[0],
    )
