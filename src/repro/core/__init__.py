"""Core: the paper's contribution — lattice pricing under transaction costs.

Pricing requires float64 (prices are compared at 1e-6 and tighter); enable
x64 on import of the core package.  The LM model stack uses explicit
float32/bfloat16 dtypes throughout and is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from .lattice import LatticeModel            # noqa: E402,F401
from .payoff import (                        # noqa: E402,F401
    PayoffProcess, american_call, american_put, bull_spread, cash_settled,
)
from .notc import price_notc_jax, price_notc_np   # noqa: E402,F401
from .rz_ref import PriceResult, price_ref        # noqa: E402,F401
