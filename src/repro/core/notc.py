"""Classic binomial American option pricing without transaction costs.

This is the paper's Appendix workload: scalar backward induction

    pi_N = intrinsic(S_N)
    pi_n(i) = max( intrinsic(S_n(i)),
                   ( p* pi_{n+1}(i+1) + (1-p*) pi_{n+1}(i) ) / r )

It doubles as (a) the friction-free sanity anchor for the transaction-cost
engine (k = 0 must make ask = bid = this price) and (b) the workload of the
Pallas lattice kernel (:mod:`repro.kernels.binomial_step`).

Two implementations:

  * :func:`price_notc_np`   — trivially simple numpy loop (oracle).
  * :func:`price_notc_jax`  — jitted JAX version with a fixed-width buffer
    and ``lax.fori_loop`` over levels (runs fine on CPU, targets TPU VPU).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .lattice import LatticeModel
from .payoff import PayoffProcess

__all__ = ["price_notc_np", "price_notc_jax", "intrinsic_grid"]


def intrinsic_grid(model: LatticeModel, payoff: PayoffProcess, level: int) -> np.ndarray:
    s = model.stock_level(level)
    return np.maximum(payoff.intrinsic(s), 0.0)


def price_notc_np(model: LatticeModel, payoff: PayoffProcess) -> float:
    """Numpy oracle — O(N^2), vectorised per level."""
    n = model.n_steps
    r = model.r
    p = model.p_star
    v = intrinsic_grid(model, payoff, n)
    for lvl in range(n - 1, -1, -1):
        cont = (p * v[1:lvl + 2] + (1.0 - p) * v[:lvl + 1]) / r
        v = np.maximum(intrinsic_grid(model, payoff, lvl), cont)
    return float(v[0])


@partial(jax.jit, static_argnames=("n_steps", "kind"))
def _notc_kernel(s0, sigma, rate, maturity, strike, *, n_steps: int, kind: str):
    """Fixed-buffer backward induction.  kind in {put, call}."""
    dt = maturity / n_steps
    u = jnp.exp(sigma * jnp.sqrt(dt))
    r = jnp.exp(rate * dt)
    p = (r - 1.0 / u) / (u - 1.0 / u)
    q = 1.0 - p

    idx = jnp.arange(n_steps + 1, dtype=jnp.float64)

    def intrinsic(lvl):
        s = s0 * jnp.exp((2.0 * idx - lvl) * sigma * jnp.sqrt(dt))
        pay = strike - s if kind == "put" else s - strike
        # mask out columns beyond the level
        return jnp.where(idx <= lvl, jnp.maximum(pay, 0.0), 0.0)

    v0 = intrinsic(jnp.float64(n_steps))

    def body(step, v):
        lvl = n_steps - 1 - step
        cont = (p * jnp.roll(v, -1) + q * v) / r
        return jnp.maximum(intrinsic(lvl.astype(jnp.float64)), cont)

    v = jax.lax.fori_loop(0, n_steps, body, v0)
    return v[0]


def price_notc_jax(model: LatticeModel, payoff: PayoffProcess) -> float:
    """Jitted JAX pricer for vanilla puts/calls (the Appendix workload)."""
    name = payoff.name
    if name.startswith("put"):
        kind, strike = "put", _strike_of(name)
    elif name.startswith("call"):
        kind, strike = "call", _strike_of(name)
    else:
        raise ValueError(f"price_notc_jax supports vanilla put/call, got {name}")
    out = _notc_kernel(
        jnp.float64(model.s0), jnp.float64(model.sigma), jnp.float64(model.rate),
        jnp.float64(model.maturity), jnp.float64(strike),
        n_steps=model.n_steps, kind=kind)
    return float(out)


def _strike_of(name: str) -> float:
    return float(name.split("K=")[1].rstrip(")"))
