"""Property-based triangle tests for the sort-free PWL envelope algebra.

Every property runs the merge-path engine AND the retained sort-based
engine (``_merge_take_bysort``/``_compact_bysort``, swapped in by
``tests/test_pwl_merge.py::sort_based_engine``) on the same inputs and
demands bitwise-identical results — knot positions, values, end slopes
and the raw (pre-truncation) overflow counts — then checks both against
the exact ``pwl_ref`` oracle wherever the raw count fits the capacity.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pwl as P  # noqa: E402
from repro.core import pwl_ref as R  # noqa: E402

from test_pwl_merge import _assert_pwl_identical, sort_based_engine  # noqa: E402

_settings = settings(max_examples=60, deadline=None)
CAP = 32
_QS = np.linspace(-8.0, 8.0, 97)

knots = st.integers(1, 5).flatmap(
    lambda m: st.tuples(
        st.lists(st.floats(-5, 5), min_size=m, max_size=m),
        st.lists(st.floats(-100, 100), min_size=m, max_size=m)))
end_slopes = st.tuples(st.floats(-150, -60), st.floats(-50, -5))


def _pwl(xs, ys, sl, sr):
    xs = np.sort(np.asarray(xs)) + np.arange(len(xs)) * 1e-3
    return R.PWLRef(xs, np.asarray(ys), sl, sr)


@given(knots, knots, end_slopes, end_slopes, st.integers(2, CAP),
       st.booleans())
@_settings
def test_merge_path_envelope_vs_oracle_and_sort(kf, kg, ef, eg, cap,
                                                take_max):
    """Triangle property: merge-path == sort-based bitwise (knots, values,
    m_raw overflow counts), and both == the pwl_ref oracle wherever the
    raw count fits the output capacity."""
    f = _pwl(kf[0], kf[1], *ef)
    g = _pwl(kg[0], kg[1], *eg)
    F, G = P.from_ref(f, CAP), P.from_ref(g, CAP)
    new, m_new = P.envelope2(F, G, cap, take_max=take_max)
    with sort_based_engine():
        old, m_old = P.envelope2(F, G, cap, take_max=take_max)
    _assert_pwl_identical((new, m_new), (old, m_old), "hypothesis envelope")
    want = (R.pwl_max if take_max else R.pwl_min)(f, g)
    if want.m > cap:
        assert int(m_new) > cap          # overflow reported, never silent
    if int(m_new) <= cap:
        np.testing.assert_allclose(P.to_ref(new)(_QS), want(_QS), atol=1e-7)


@given(knots, end_slopes, st.floats(80, 140), st.floats(20, 70))
@_settings
def test_merge_path_cone_vs_oracle_and_sort(kf, ef, a, b):
    f = _pwl(kf[0], kf[1], min(ef[0], -b - 1), max(ef[1], -a))
    F = P.from_ref(f, CAP)
    new, m_new = P.cone_infconv(F, a, b, CAP)
    with sort_based_engine():
        old, m_old = P.cone_infconv(F, a, b, CAP)
    _assert_pwl_identical((new, m_new), (old, m_old), "hypothesis cone")
    want = R.cone_infconv(f, a, b)
    assert int(m_new) <= CAP
    np.testing.assert_allclose(P.to_ref(new)(_QS), want(_QS), atol=1e-7)
