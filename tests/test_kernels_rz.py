"""Blocked Pallas TC kernel (``kernels/rz_step.py``) — oracle-locked.

Every configuration of the transaction-cost Pallas engine must reproduce
the exact sequential recursion (``core/rz_ref.py``) and the vectorised
jnp engine bit-for-bit at the 1e-9 price tolerance, with identical
``max_pieces`` overflow reporting.  The kernel is also checked white-box:
one ``rz_round`` call equals the equivalent chain of
``rz_level_step_lanes`` updates on its owned lanes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeModel, american_put, bull_spread,
                        cash_settled, price_notc_np, price_ref)
from repro.core import pwl as P
from repro.core.partition import kernel_round_plan
from repro.core.rz import (price_rz, rz_backward, rz_backward_pallas,
                           rz_level_step_lanes, _leaf_level)
from repro.kernels.rz_step import RZ_SCALARS, rz_round

TOL = 1e-9


def _model(n=10, k=0.01, **kw):
    return LatticeModel(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                        n_steps=n, cost_rate=k, **kw)


@pytest.mark.parametrize("payoff", [american_put(100.0),
                                    bull_spread(95.0, 105.0)])
def test_pallas_matches_oracle_and_jnp(payoff):
    m = _model()
    ref = price_ref(m, payoff)
    r_jnp = price_rz(m, payoff, capacity=16)
    r_pal = price_rz(m, payoff, capacity=16, backend="pallas")
    assert r_pal.ask == pytest.approx(ref.ask, abs=TOL)
    assert r_pal.bid == pytest.approx(ref.bid, abs=TOL)
    assert r_pal.ask == pytest.approx(r_jnp.ask, abs=TOL)
    assert r_pal.bid == pytest.approx(r_jnp.bid, abs=TOL)
    assert r_pal.max_pieces == r_jnp.max_pieces


def test_pallas_blocked_halo_rounds_match():
    """Multi-block rounds (right-neighbour halo BlockSpec) == jnp."""
    m = _model(n=10)
    pay = american_put(100.0)
    r_jnp = price_rz(m, pay, capacity=16)
    r_pal = price_rz(m, pay, capacity=16, backend="pallas",
                     levels=3, block=4)
    assert r_pal.ask == pytest.approx(r_jnp.ask, abs=TOL)
    assert r_pal.bid == pytest.approx(r_jnp.bid, abs=TOL)
    assert r_pal.max_pieces == r_jnp.max_pieces


def test_pallas_lambda0_collapses_to_notc():
    """k = 0: ask == bid == the friction-free binomial price."""
    m = _model(n=12, k=0.0)
    pay = american_put(100.0)
    want = price_notc_np(m, pay)
    r = price_rz(m, pay, capacity=16, backend="pallas")
    assert r.ask == pytest.approx(want, abs=TOL)
    assert r.bid == pytest.approx(want, abs=TOL)


def test_pallas_rejects_closure_only_payoff():
    """The kernel carries the payoff as data; closure-only payoffs must
    fail loudly, not silently misprice."""
    pay = cash_settled("weird", lambda s: jnp.maximum(90.0 - 0.5 * s, 0.0))
    assert pay.params is None
    with pytest.raises(ValueError, match="pallas"):
        price_rz(_model(), pay, capacity=16, backend="pallas")


def test_pallas_overflow_reported_identically():
    """Overflow contract parity: same max_pieces from both backends, and
    both raise OverflowError when it exceeds the capacity."""
    m = _model(n=12)
    pay = bull_spread(95.0, 105.0)
    args = (jnp.float64(m.s0), jnp.float64(m.sigma), jnp.float64(m.rate),
            jnp.float64(m.maturity), jnp.float64(m.cost_rate))
    kw = dict(n_steps=m.n_steps, capacity=3, payoff=pay)
    *_, p_jnp = jax.jit(lambda *a: rz_backward(*a, **kw))(*args)
    *_, p_pal = jax.jit(lambda *a: rz_backward_pallas(*a, **kw))(*args)
    assert int(p_jnp) == int(p_pal) > 3
    for backend in ("jnp", "pallas"):
        with pytest.raises(OverflowError):
            price_rz(m, pay, capacity=3, backend=backend)


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32],
                         ids=["float64", "float32"])
def test_rz_round_equals_level_step_chain(dtype):
    """White-box: one blocked round == ``levels`` full-width level steps
    on the owned live lanes (the region-A/halo construction is exact).

    The comparable observable is dtype-dependent, and the split is the
    documented per-dtype tolerance story:

    * **float64** — knot arrays compare at 1e-12 (not bitwise: the
      kernel's fused ``fori_loop`` body lets LLVM contract mul-adds
      into FMAs that the eagerly-executed reference chain doesn't, a
      ±1-ulp effect) and knot counts are exact.
    * **float32** (the compiled GPU/TPU dtype) — only function *values*
      are stable, at ~1e-4 on values O(500) (a few f32 ulps; measured
      3e-5).  Knot *structure* is not: near this model's degenerate
      regions the true continuation is affine, so envelope crossings
      are ties that f32 rounding resolves differently under the
      kernel's FMA ordering than under the eager chain, creating
      *different spurious knots* on each side (and inflating
      ``max_pieces`` — capacity headroom must be budgeted for f32).
    """
    n_steps, capacity, block, levels = 9, 12, 4, 3
    pay = american_put(100.0)
    dt = 0.25 / n_steps
    params = dict(s0=jnp.asarray(100.0, dtype), k=jnp.asarray(0.01, dtype),
                  sig_sqrt_dt=0.2 * jnp.sqrt(jnp.asarray(dt, dtype)),
                  r=jnp.exp(jnp.asarray(0.1 * dt, dtype)))
    lanes = 12                                   # n_steps+2=11 -> pad to 3 blocks
    z = _leaf_level(n_steps, params, capacity, dtype, lanes=lanes)

    # reference: full-width level steps
    z_ref, lvl0 = z, n_steps + 1
    pieces_ref = jnp.zeros((lanes,), jnp.int32)
    for j in range(levels):
        z_ref, pc = rz_level_step_lanes(
            z_ref, jnp.asarray(lvl0 - (j + 1), dtype), params,
            capacity=capacity, seller=True, payoff=pay, dtype=dtype)
        pieces_ref = jnp.maximum(pieces_ref, pc)

    scalars = jnp.stack([jnp.asarray(v, dtype) for v in
                         (lvl0, 100.0, float(params["sig_sqrt_dt"]),
                          float(params["r"]), 0.01, *pay.params)])
    assert scalars.shape == (RZ_SCALARS,)
    # single-side round (sellers=(True,)): the kernel's fused side axis
    # must reproduce the plain full-width chain exactly
    z1 = jax.tree.map(lambda a: a[None], z)
    z_krn, pieces = rz_round(z1, scalars, levels=levels, block=block,
                             sellers=(True,))
    live = np.arange(lanes) <= lvl0 - levels     # live lanes at the new base
    if dtype == jnp.float64:
        for a_ref, a_krn, name in zip(z_ref, z_krn,
                                      ("xs", "ys", "sl", "sr", "m")):
            a_ref = np.asarray(a_ref)[live]
            a_krn = np.asarray(a_krn)[0][live]
            if name == "m":
                np.testing.assert_array_equal(a_ref, a_krn)
            else:
                np.testing.assert_allclose(a_ref, a_krn, rtol=0, atol=1e-12)
        assert int(pieces) == int(jnp.max(pieces_ref))
    else:
        # f32: compare the functions, not their (unstable) knot arrays
        ysq = jnp.linspace(-4.0, 4.0, 81).astype(dtype)

        def _values(xs, ys, sl, sr, m):
            def one(a, b, c, d, e):
                f = P.PWL(a, b, c, d, e)
                return jax.vmap(lambda q: P.eval_at(f, q))(ysq)
            return jax.vmap(one)(xs, ys, sl, sr, m)

        v_ref = np.asarray(_values(*z_ref))[live]
        v_krn = np.asarray(_values(*(a[0] for a in z_krn)))[live]
        np.testing.assert_allclose(v_krn, v_ref, rtol=0, atol=2e-4)

    # fused (seller, buyer) round: the seller row must be bit-identical
    # to the single-side seller round (side fusion itself changes no
    # values — both run through the same compiled kernel structure)
    z2 = jax.tree.map(lambda a: jnp.stack([a, a]), z)
    z_krn2, _ = rz_round(z2, scalars, levels=levels, block=block,
                         sellers=(True, False))
    for a_one, a_two in zip(z_krn, z_krn2):
        np.testing.assert_array_equal(np.asarray(a_one)[0][live],
                                      np.asarray(a_two)[0][live])


@pytest.mark.parametrize("levels,block", [(None, None), (2, None), (3, 4)])
def test_round_plan_is_respected(levels, block):
    """The engine prices through exactly the partition.py schedule."""
    plan = kernel_round_plan(10, levels=levels, block=block)
    assert sum(r.depth for r in plan) == 11
    r = price_rz(_model(), american_put(100.0), capacity=16,
                 backend="pallas", levels=levels, block=block)
    ref = price_ref(_model(), american_put(100.0))
    assert r.ask == pytest.approx(ref.ask, abs=TOL)
