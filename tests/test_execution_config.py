"""The consolidated execution surface: ExecutionConfig + the shim.

PRs 1-8 accreted execution kwargs (engine/backend/platform/interpret/
devices/n_paths/seed/basis/degree/antithetic) onto ``price_grid``/
``price_flat``/``GridRequest``/``PricingService``; this PR consolidates
them into one frozen :class:`repro.configs.pricing.ExecutionConfig`.
Covered here:

* ``resolved()`` fills every ``None`` through the platform policy of
  ``core/platform.py`` (interpret/float64 on CPU) and is idempotent;
* ``execution=`` produces bitwise-identical prices to the legacy
  kwargs, for both lattice engines and lsmc;
* the deprecation shim warns exactly once per process, and passing
  both surfaces at once is a hard ``TypeError``;
* the serving layer honours it end to end: ``PricingService``/
  ``PricingGateway`` constructor overrides, ``GridRequest.execution``,
  and ``PricingConfig.execution()``.
"""
import dataclasses
import warnings

import numpy as np
import pytest

import repro.api as api
from repro.api import ExecutionConfig, price_grid
from repro.configs.pricing import PAPER_PUT
from repro.core import platform as plat
from repro.serve.engine import GridRequest
from repro.serve.scheduler import PricingService

N_STEPS = 8


@pytest.fixture(autouse=True)
def _fresh_warning_state():
    api._reset_legacy_exec_warning()
    yield
    api._reset_legacy_exec_warning()


def _grid_kw():
    return dict(s0=(95.0, 100.0, 105.0), cost_rate=(0.0, 0.01),
                n_steps=N_STEPS, capacity=16)


# ---------------------------------------------------------------------- #
# the dataclass itself
# ---------------------------------------------------------------------- #
def test_resolved_fills_defaults_from_platform_policy():
    cfg = ExecutionConfig().resolved()
    p = plat.active_platform()
    assert cfg.platform == p
    assert cfg.interpret == plat.resolve_interpret(None, p)
    assert cfg.engine == "auto" and cfg.backend == "jnp"
    assert cfg.n_paths == 4096 and cfg.mc_seed == 0
    assert cfg.basis == "poly" and cfg.degree == 3
    assert cfg.antithetic is True
    # idempotent: resolving a resolved config changes nothing
    assert cfg.resolved() == cfg


def test_set_fields_and_frozen_hashable():
    cfg = ExecutionConfig(backend="pallas", n_paths=512)
    assert cfg.set_fields() == ("backend", "n_paths")
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.backend = "jnp"
    assert hash(cfg) == hash(ExecutionConfig(backend="pallas", n_paths=512))


def test_pricing_config_execution_is_resolved():
    cfg = PAPER_PUT.execution()
    assert cfg.platform is not None and cfg.interpret is not None
    assert cfg.resolved() == cfg


# ---------------------------------------------------------------------- #
# api surface: execution= vs the legacy kwargs
# ---------------------------------------------------------------------- #
def test_execution_matches_legacy_kwargs_bitwise():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = price_grid(engine="auto", backend="jnp", **_grid_kw())
    new = price_grid(execution=ExecutionConfig(engine="auto",
                                               backend="jnp"),
                     **_grid_kw())
    np.testing.assert_array_equal(np.asarray(legacy.ask),
                                  np.asarray(new.ask))
    np.testing.assert_array_equal(np.asarray(legacy.bid),
                                  np.asarray(new.bid))
    assert legacy.max_pieces == new.max_pieces


def test_execution_matches_legacy_kwargs_lsmc():
    kw = dict(s0=(95.0, 100.0), n_steps=N_STEPS, n_assets=2, capacity=16)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = price_grid(n_paths=256, seed=3, **kw)
    new = price_grid(execution=ExecutionConfig(n_paths=256, mc_seed=3),
                     **kw)
    np.testing.assert_array_equal(np.asarray(legacy.ask),
                                  np.asarray(new.ask))


def test_legacy_kwargs_warn_exactly_once_per_process():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        price_grid(backend="jnp", **_grid_kw())
        price_grid(backend="jnp", **_grid_kw())
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "ExecutionConfig" in str(dep[0].message)
    assert "backend" in str(dep[0].message)


def test_both_surfaces_at_once_is_a_type_error():
    with pytest.raises(TypeError, match="both execution="):
        price_grid(execution=ExecutionConfig(), backend="jnp",
                   **_grid_kw())


# ---------------------------------------------------------------------- #
# serving layer
# ---------------------------------------------------------------------- #
def test_pricing_service_constructor_override():
    svc = PricingService(execution=ExecutionConfig(backend="jnp",
                                                   n_paths=512, mc_seed=9),
                         default_n_steps=N_STEPS, capacity=16)
    assert svc.backend == "jnp"
    assert svc.core.n_paths == 512 and svc.core.mc_seed == 9


def test_gateway_constructor_override():
    from repro.serve.gateway import PricingGateway
    gw = PricingGateway(execution=ExecutionConfig(n_paths=128, mc_seed=4),
                        default_n_steps=N_STEPS, capacity=16)
    assert gw.core.n_paths == 128 and gw.core.mc_seed == 4


def test_grid_request_execution_field_wins():
    svc = PricingService(default_n_steps=N_STEPS, capacity=16,
                         min_grid_bucket=4)
    base = svc.price_grid(GridRequest(s0=(95.0, 100.0), n_steps=N_STEPS,
                                      backend="jnp"))
    via_cfg = svc.price_grid(GridRequest(
        s0=(95.0, 100.0), n_steps=N_STEPS, backend="pallas",
        execution=ExecutionConfig(backend="jnp")))
    np.testing.assert_array_equal(np.asarray(base.ask),
                                  np.asarray(via_cfg.ask))
