"""Fault-injection harness for the asyncio multi-replica gateway.

The deliverable under test is *robustness*: with replicas crashing,
hanging past the timeout, or raising request errors mid-flush, the
gateway must re-queue the in-flight chunk to a healthy replica (bounded
retry + exponential backoff, all counted in metrics), deliver 100% of
submitted quotes, and every delivered quote must still match the
``price_american`` oracle at 1e-9 — including its per-contract
``max_pieces``.  Faults are injected with
``repro.serve.replica.FaultyReplica`` (a call-indexed fault schedule);
overload degradation and the shedding threshold are exercised with a
wedged replica so nothing ever completes.
"""
import asyncio

import numpy as np
import pytest

from repro.api import price_american
from repro.serve.engine import PriceRequest
from repro.serve.gateway import (GatewayOverloaded, PricingGateway)
from repro.serve.replica import FaultyReplica, LocalReplica
from repro.serve.streaming import StreamingBook, Tick

pytestmark = pytest.mark.gateway

TOL = 1e-9
N_STEPS = 8
CAPACITY = 16


def _req(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25, cost_rate=0.0, **kw):
    kw.setdefault("n_steps", N_STEPS)
    return PriceRequest(s0=s0, sigma=sigma, rate=rate, maturity=maturity,
                        cost_rate=cost_rate, **kw)


def _mixed_requests():
    """Both buckets (frictionless + TC), mixed payoff families/strikes."""
    return [
        _req(s0=95.0, payoff="put", strike=100.0),
        _req(s0=105.0, payoff="bull_spread", strike=95.0),
        _req(s0=100.0, payoff="call", strike=95.0),
        _req(s0=98.0, payoff="put", strike=100.0, cost_rate=0.01),
        _req(s0=102.0, payoff="call", strike=95.0, cost_rate=0.005),
        _req(s0=100.0, payoff="put", strike=105.0, cost_rate=0.01),
    ]


def _assert_oracle(req, quote):
    ref = price_american(
        s0=req.s0, sigma=req.sigma, rate=req.rate, maturity=req.maturity,
        n_steps=req.n_steps, payoff=req.payoff or "put",
        strike=req.strike if req.strike is not None else 100.0,
        cost_rate=req.cost_rate, capacity=CAPACITY)
    assert abs(quote.ask - ref.ask) < TOL
    assert abs(quote.bid - ref.bid) < TOL
    assert quote.max_pieces == ref.max_pieces


async def _submit_await_all(gw, reqs):
    rids = [await gw.submit(r) for r in reqs]
    return [await gw.result(rid) for rid in rids]


def test_crashed_replica_chunk_requeued_no_request_dropped():
    """A replica crash mid-run: its in-flight chunk fails over to the
    healthy replica; every quote arrives and matches the oracle."""
    crashy = FaultyReplica(faults={0: "crash"}, name="crashy")

    async def main():
        async with PricingGateway(
                replicas=[crashy, LocalReplica("good")], max_batch=4,
                deadline_ms=2.0, capacity=CAPACITY,
                default_n_steps=N_STEPS, retry_backoff_s=0.01,
                result_cache_size=0) as gw:
            reqs = _mixed_requests()
            quotes = await _submit_await_all(gw, reqs)
            return reqs, quotes, gw.metrics(), gw.replica_states()

    reqs, quotes, m, states = asyncio.run(main())
    for req, quote in zip(reqs, quotes):
        _assert_oracle(req, quote)
    assert m["completed"] == m["requests"] == len(reqs)   # nothing dropped
    assert m["failed"] == 0
    assert m["replica_crashes"] == 1
    assert m["requeues"] >= 1 and m["retries"] >= 1       # chunk re-queued
    assert m["backoffs"] >= 1 and m["backoff_seconds"] > 0
    assert m["healthy_replicas"] == 1
    dead = [s for s in states if not s["healthy"]]
    assert [s["dead_reason"] for s in dead] == ["crashed"]


def test_hung_replica_times_out_and_chunk_fails_over():
    """A replica that hangs past ``replica_timeout_s`` is declared dead;
    its chunk re-queues to the healthy replica (sticky bucket re-homed),
    and the hung worker thread is released at teardown."""
    hangy = FaultyReplica(faults={0: "hang"}, hang_s=30.0, name="hangy")

    async def main():
        async with PricingGateway(
                replicas=[hangy, LocalReplica("good")], max_batch=4,
                deadline_ms=2.0, capacity=CAPACITY,
                default_n_steps=N_STEPS, retry_backoff_s=0.01,
                replica_timeout_s=0.5, result_cache_size=0) as gw:
            reqs = _mixed_requests()
            quotes = await _submit_await_all(gw, reqs)
            return reqs, quotes, gw.metrics()

    try:
        reqs, quotes, m = asyncio.run(main())
    finally:
        hangy.release()
    for req, quote in zip(reqs, quotes):
        _assert_oracle(req, quote)
    assert m["completed"] == len(reqs) and m["failed"] == 0
    assert m["replica_hangs"] == 1
    assert m["requeues"] >= 1
    assert m["affinity_moves"] >= 1        # sticky bucket moved to 'good'
    assert m["healthy_replicas"] == 1


def test_crash_plus_hang_together_still_delivers_everything():
    """The acceptance scenario: one replica crashed AND another hung
    mid-run — the surviving replica still delivers 100% of quotes, all
    at 1e-9 vs price_american."""
    crashy = FaultyReplica(faults={0: "crash"}, name="crashy")
    hangy = FaultyReplica(faults={0: "hang"}, hang_s=30.0, name="hangy")

    async def main():
        async with PricingGateway(
                replicas=[crashy, hangy, LocalReplica("good")],
                max_batch=4, deadline_ms=2.0, capacity=CAPACITY,
                default_n_steps=N_STEPS, retry_backoff_s=0.01,
                replica_timeout_s=0.5, result_cache_size=0) as gw:
            reqs = _mixed_requests()
            quotes = await _submit_await_all(gw, reqs)
            return reqs, quotes, gw.metrics()

    try:
        reqs, quotes, m = asyncio.run(main())
    finally:
        hangy.release()
    for req, quote in zip(reqs, quotes):
        _assert_oracle(req, quote)
    assert m["completed"] == m["requests"] == len(reqs)
    assert m["failed"] == 0
    assert m["replica_crashes"] == 1 and m["replica_hangs"] == 1
    assert m["healthy_replicas"] == 1


def test_overflow_mid_flush_retries_on_same_replica():
    """An OverflowError is a *request* error, not a replica failure:
    the chunk is re-queued (with backoff) but the replica stays healthy
    and prices the retry itself."""
    flaky = FaultyReplica(faults={0: "overflow"}, name="flaky")

    async def main():
        async with PricingGateway(
                replicas=[flaky], max_batch=4, deadline_ms=2.0,
                capacity=CAPACITY, default_n_steps=N_STEPS,
                retry_backoff_s=0.01, result_cache_size=0) as gw:
            reqs = [_req(s0=97.0, cost_rate=0.01),
                    _req(s0=103.0, cost_rate=0.01, payoff="call",
                         strike=95.0)]
            quotes = await _submit_await_all(gw, reqs)
            return reqs, quotes, gw.metrics()

    reqs, quotes, m = asyncio.run(main())
    for req, quote in zip(reqs, quotes):
        _assert_oracle(req, quote)
    assert m["retries"] == 1 and m["requeues"] == 1
    assert m["backoffs"] == 1
    assert m["replica_crashes"] == m["replica_hangs"] == 0
    assert m["healthy_replicas"] == 1      # overflow does not kill it
    assert flaky.calls == 2                # failed call + successful retry


def test_retries_exhausted_delivers_the_error_not_silence():
    """When every retry fails, the error is *delivered* on each request's
    future — failure is an answer; nothing is dropped on the floor."""
    bad = FaultyReplica(faults={i: "overflow" for i in range(10)},
                        name="always-bad")

    async def main():
        async with PricingGateway(
                replicas=[bad], max_batch=4, deadline_ms=2.0,
                capacity=CAPACITY, default_n_steps=N_STEPS,
                max_retries=1, retry_backoff_s=0.0,
                result_cache_size=0) as gw:
            rid = await gw.submit(_req(s0=99.0, cost_rate=0.01))
            with pytest.raises(OverflowError):
                await gw.result(rid)
            return gw.metrics()

    m = asyncio.run(main())
    assert m["failed"] == 1
    assert m["requeues"] == 2              # initial failure + failed retry
    assert m["retries"] == 1               # bounded by max_retries


def test_single_replica_crash_restarts_after_backoff():
    """With restart_s set, a dead replica pool respawns via the factory
    and the waiting chunk completes on the fresh replica."""
    async def main():
        async with PricingGateway(
                replicas=[FaultyReplica(faults={0: "crash"})],
                max_batch=4, deadline_ms=2.0, capacity=CAPACITY,
                default_n_steps=N_STEPS, retry_backoff_s=0.01,
                restart_s=0.05, result_cache_size=0) as gw:
            reqs = [_req(s0=96.0), _req(s0=104.0, payoff="call",
                                        strike=95.0)]
            quotes = await _submit_await_all(gw, reqs)
            return reqs, quotes, gw.metrics()

    reqs, quotes, m = asyncio.run(main())
    for req, quote in zip(reqs, quotes):
        _assert_oracle(req, quote)
    assert m["replica_crashes"] == 1
    assert m["replica_restarts"] == 1
    assert m["healthy_replicas"] == 1
    assert m["failed"] == 0


def test_sustained_overload_halves_max_batch_then_sheds():
    """Under sustained overload (a wedged replica, unbounded intake) the
    gateway degrades gracefully — effective max_batch halves down to
    min_batch — before it finally refuses work with GatewayOverloaded."""
    wedged = FaultyReplica(faults={i: "hang" for i in range(64)},
                           hang_s=30.0, name="wedged")

    async def main():
        gw = PricingGateway(
            replicas=[wedged], max_batch=4, deadline_ms=1000.0,
            capacity=CAPACITY, default_n_steps=N_STEPS,
            replica_timeout_s=20.0, overload_factor=1.0,
            overload_grace_s=0.0, shed_factor=4.0)
        await gw.start()
        try:
            with pytest.raises(GatewayOverloaded):
                for i in range(64):
                    await gw.submit(_req(s0=90.0 + 0.25 * i))
            return gw.metrics(), gw.effective_max_batch
        finally:
            await gw.aclose(drain=False)

    try:
        m, eff = asyncio.run(main())
    finally:
        wedged.release()
    assert m["degraded"] >= 2              # 4 -> 2 -> 1
    assert eff == 1
    assert m["shed"] == 1
    assert m["replica_crashes"] == 0       # wedged, not yet timed out


def test_streaming_survives_replica_crash_mid_feed():
    """Streaming mode rides the same failover: a replica crash between
    ticks loses no requote, and the incrementally maintained book still
    equals a full reprice of the post-tick book."""
    crashy = FaultyReplica(faults={1: "crash"}, name="crashy")

    async def main():
        async with PricingGateway(
                replicas=[crashy, LocalReplica("good")], max_batch=8,
                deadline_ms=2.0, capacity=CAPACITY, retry_backoff_s=0.01,
                result_cache_size=0) as gw:
            book = StreamingBook.mixed(n_underlyings=2, per_underlying=4,
                                       n_steps=(N_STEPS,),
                                       capacity=CAPACITY)
            book.full_reprice()
            ticks = [Tick(0, "s0", 104.0), Tick(1, "sigma", 0.3),
                     Tick(0, "s0", 97.5), Tick(1, "s0", 101.0)]
            summary = await gw.run_stream(book, ticks)
            return book, summary, gw.metrics()

    book, summary, m = asyncio.run(main())
    assert m["replica_crashes"] == 1
    assert summary["ticks"] == 4
    assert summary["rows_requoted"] == 16   # 4 rows per underlying tick
    assert summary["staleness_p99_ms"] > 0
    reference = book.copy()
    reference.full_reprice()
    np.testing.assert_allclose(book.ask, reference.ask, rtol=0, atol=TOL)
    np.testing.assert_allclose(book.bid, reference.bid, rtol=0, atol=TOL)
    np.testing.assert_array_equal(book.row_pieces, reference.row_pieces)
    assert book.max_pieces == reference.max_pieces
