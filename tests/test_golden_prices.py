"""Golden-price snapshots: bit-stability of the 108-scenario mixed grid.

``tests/golden/grid108.json`` commits the exact float64 ask/bid surfaces
(and ``max_pieces``) of a mixed 108-scenario cartesian grid — puts,
calls and bull spreads across spots, vols, strikes and cost rates,
lambda = 0 rows included — priced through **both** TC backends (the
vectorised jnp engine and the blocked Pallas rounds).  The oracle suites
pin correctness to ~1e-9; this suite pins *bit stability*: any change to
the summation order, the PWL algebra, dtype handling or the platform
default that moves even one ULP shows up as a diff of a committed file
and must be reviewed (and regenerated) deliberately, never absorbed
silently by a tolerance band.

Regenerate after an intentional numeric change::

    PYTHONPATH=src python tests/test_golden_prices.py --regen

JSON round-trips float64 exactly (Python emits shortest-round-trip
repr), so equality below is bitwise.
"""
import json
import pathlib

import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 flag side effect)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "grid108.json"
BACKENDS = ("jnp", "pallas")


def _grid():
    from repro.scenarios import ScenarioGrid
    return ScenarioGrid.cartesian(
        s0=(95.0, 105.0), sigma=(0.15, 0.25),
        cost_rate=(0.0, 0.005, 0.01),
        payoff=("put", "call", "bull_spread"),
        strike=(95.0, 100.0, 105.0), n_steps=10)


def _compute() -> dict:
    from repro.api import ExecutionConfig, price_grid
    grid = _grid()
    out = {"n_scenarios": int(grid.n_scenarios),
           "n_steps": int(grid.n_steps), "capacity": 16, "engines": {}}
    for backend in BACKENDS:
        res = price_grid(grid, capacity=16,
                         execution=ExecutionConfig(backend=backend))
        out["engines"][backend] = {
            "engine": res.engine,
            "ask": np.asarray(res.ask).ravel().tolist(),
            "bid": np.asarray(res.bid).ravel().tolist(),
            "max_pieces": int(res.max_pieces),
        }
    return out


def _golden() -> dict:
    if not GOLDEN.exists():
        pytest.fail(f"{GOLDEN} missing — regenerate with "
                    "PYTHONPATH=src python tests/test_golden_prices.py "
                    "--regen")
    return json.loads(GOLDEN.read_text())


def test_golden_grid_is_bit_stable():
    fresh, golden = _compute(), _golden()
    assert fresh["n_scenarios"] == golden["n_scenarios"] == 108
    for backend in BACKENDS:
        f, g = fresh["engines"][backend], golden["engines"][backend]
        assert f["engine"] == g["engine"]
        assert f["max_pieces"] == g["max_pieces"]
        for side in ("ask", "bid"):
            fa, ga = np.asarray(f[side]), np.asarray(g[side])
            # bitwise: == on float64, with the indices of any drift named
            if not np.array_equal(fa, ga):
                bad = np.flatnonzero(fa != ga)
                ulps = (fa.view(np.int64) - ga.view(np.int64))[bad]
                pytest.fail(
                    f"{backend}/{side} drifted at rows {bad[:8].tolist()} "
                    f"(ULP deltas {ulps[:8].tolist()}); if intentional, "
                    "regenerate tests/golden/grid108.json (--regen)")


def test_golden_backends_agree_and_prices_sane():
    """Cross-checks *within* the committed file: the two backends must
    agree to 1e-9 and satisfy basic no-arbitrage shape (ask >= bid,
    both finite, non-negative)."""
    golden = _golden()
    a_jnp = np.asarray(golden["engines"]["jnp"]["ask"])
    a_pal = np.asarray(golden["engines"]["pallas"]["ask"])
    b_jnp = np.asarray(golden["engines"]["jnp"]["bid"])
    b_pal = np.asarray(golden["engines"]["pallas"]["bid"])
    np.testing.assert_allclose(a_pal, a_jnp, rtol=0, atol=1e-9)
    np.testing.assert_allclose(b_pal, b_jnp, rtol=0, atol=1e-9)
    for a, b in ((a_jnp, b_jnp), (a_pal, b_pal)):
        assert np.isfinite(a).all() and np.isfinite(b).all()
        assert (a >= b - 1e-12).all(), "ask below bid"
        assert (a >= -1e-12).all() and (b >= -1e-12).all()


def test_golden_capacity_headroom():
    """The committed snapshot must not sit at the capacity cliff — a
    regen that lands max_pieces == capacity would make the snapshot
    flaky under any future knot-count change."""
    golden = _golden()
    for backend in BACKENDS:
        assert golden["engines"][backend]["max_pieces"] < golden["capacity"]


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(_compute(), indent=1) + "\n")
        print(f"wrote {GOLDEN}")
    else:
        print(__doc__)
