"""Ring attention (context parallelism) == naive attention, exact."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.mark.slow
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
def test_ring_attention_matches_naive(causal, window):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.models.context_parallel import make_ring_attention
        from repro.models.layers import _attn_naive, _mask_bias

        causal, window = {causal}, {window}
        B, S, KVH, G, hd = 2, 128, 2, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, KVH, G, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)

        mesh = Mesh(np.array(jax.devices()).reshape(4,), ("model",))
        ring = jax.jit(make_ring_attention(mesh, "model", causal=causal,
                                           window=window))
        got = np.asarray(ring(q, k, v))

        pos = jnp.arange(S)
        bias = _mask_bias(pos, pos, causal=causal, window=window)
        want = np.asarray(_attn_naive(q, k, v, bias))
        err = np.max(np.abs(got - want))
        assert err < 2e-5, err
        print("RING_OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "RING_OK" in r.stdout
