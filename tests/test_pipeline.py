"""Pipeline parallelism (GPipe over the pod axis): loss/grad parity."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.configs import get_config, reduced_config
from repro.train.pipeline import split_stages

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_split_stages_shapes():
    import dataclasses
    import jax
    from repro.models.transformer import init_lm

    cfg = dataclasses.replace(reduced_config(get_config("qwen3-0.6b")),
                              n_layers=4)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    pp = split_stages(params, cfg, stages=2)
    leaf = jax.tree.leaves(pp["stages"])[0]
    assert leaf.shape[:2] == (2, 2)          # (stages, reps per stage)


def test_split_stages_rejects_uneven():
    import dataclasses
    import jax
    from repro.models.transformer import init_lm

    cfg = dataclasses.replace(reduced_config(get_config("qwen3-0.6b")),
                              n_layers=3)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        split_stages(params, cfg, stages=2)


@pytest.mark.slow
# Versioned quarantine, NOT an xfail: on jax 0.4.x the partial-manual
# shard_map (axis_names={'pod'}, data axis auto) lowers axis_index to a
# PartitionId instruction the SPMD partitioner rejects with
# "UNIMPLEMENTED: PartitionId instruction is not supported for SPMD
# partitioning".  The failure mode is a ~15-minute subprocess crash, so an
# xfail would burn the whole slow-lane budget documenting a known
# toolchain gap.  The guard keys on the `jax.shard_map` top-level export
# (the repro/compat.py probe, present from jax 0.5), so the test re-arms
# itself the moment the pinned toolchain moves.  Tracked in
# docs/KNOWN_ISSUES.md ("Open" section).
@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map needs jax >= 0.5 (PartitionId "
           "unsupported by the 0.4.x SPMD partitioner); see "
           "docs/KNOWN_ISSUES.md")
def test_pipelined_loss_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from jax.sharding import Mesh
        from repro.configs import get_config, reduced_config
        from repro.models.transformer import RunCfg, init_lm, lm_loss
        from repro.train.pipeline import make_pp_loss, split_stages

        cfg = dataclasses.replace(reduced_config(get_config("internlm2-1.8b")),
                                  n_layers=4)
        run = RunCfg(dtype=jnp.float32)
        key = jax.random.PRNGKey(0)
        params, _ = init_lm(key, cfg)
        n_micro, mb, S = 3, 2, 16
        batch = {"tokens": jax.random.randint(key, (n_micro, mb, S), 0, cfg.vocab),
                 "targets": jax.random.randint(jax.random.PRNGKey(1),
                                               (n_micro, mb, S), 0, cfg.vocab)}
        ref = np.mean([float(lm_loss(params, jax.tree.map(lambda a: a[i], batch),
                                     cfg, run)[1]["loss"])
                       for i in range(n_micro)])
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("pod", "data"))
        pp = split_stages(params, cfg, stages=2)
        loss_fn = make_pp_loss(cfg, run, mesh, stages=2, pipe_axis="pod")
        got = float(jax.jit(loss_fn)(pp, batch))
        assert abs(got - ref) < 1e-4, (got, ref)
        g = jax.jit(jax.grad(loss_fn))(pp, batch)
        gn = float(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                       for x in jax.tree.leaves(g)) ** 0.5)
        assert np.isfinite(gn) and gn > 0
        print("PIPELINE_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "PIPELINE_OK" in r.stdout
