"""Distributed engines on virtual devices (subprocess: needs its own
XLA_FLAGS before jax init).  Covers the shard_map lattice halo engine,
the MoE dispatch == dense equivalence, and a 2x2-mesh train step."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 4, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_lattice_engines_match_oracles():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        import repro.core
        from jax.sharding import Mesh
        from repro.core import LatticeModel, american_put, price_notc_np
        from repro.core.rz import price_rz
        from repro.core.distributed import build_rz_sharded, build_notc_sharded

        mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
        # no-TC N=200 vs numpy oracle
        f = jax.jit(build_notc_sharded(mesh, n_steps=200, strike=100.0,
                                       round_depth=16))
        got = np.asarray(f(jnp.array([100.0, 95.0]), jnp.full((2,), 0.3),
                           jnp.full((2,), 0.06), jnp.full((2,), 3.0)))
        for i, s in enumerate([100.0, 95.0]):
            m = LatticeModel(s0=s, sigma=0.3, rate=0.06, maturity=3.0,
                             n_steps=200)
            assert abs(got[i] - price_notc_np(m, american_put(100.0))) < 1e-9
        # TC N=25 vs single-device engine
        put = american_put(100.0)
        f2 = jax.jit(build_rz_sharded(mesh, n_steps=25, payoff=put,
                                      capacity=24, round_depth=4))
        ask, bid, _ = f2(jnp.full((2,), 100.0), jnp.full((2,), 0.2),
                         jnp.full((2,), 0.1), jnp.full((2,), 0.25),
                         jnp.array([0.005, 0.01]))
        for i, k in enumerate([0.005, 0.01]):
            m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25,
                             n_steps=25, cost_rate=k)
            r = price_rz(m, put, capacity=24)
            assert abs(float(ask[i]) - r.ask) < 1e-9
            assert abs(float(bid[i]) - r.bid) < 1e-9
        print("LATTICE_OK")
    """)
    assert "LATTICE_OK" in out


@pytest.mark.slow
def test_moe_dispatch_matches_dense_on_mesh():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_config, reduced_config
        from repro.models import layers as L
        from repro.models.sharding import MeshRules
        import dataclasses

        cfg = reduced_config(get_config("dbrx-132b"))
        # 4 experts over tp=2; batch 4 over dp=2
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
        rules = MeshRules(mesh=mesh, fsdp=("data",), tp=("model",))
        key = jax.random.PRNGKey(0)
        p, _ = L.init_moe(key, cfg)
        x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
        want, aux_d = L.moe_dense(p, x, cfg, jnp.float32)
        # capacity_factor high enough that nothing drops -> exact match
        cfg2 = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        got, aux = jax.jit(lambda pp, xx: L.moe_dispatch(
            pp, xx, cfg2, rules, jnp.float32))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)
        print("MOE_OK")
    """)
    assert "MOE_OK" in out


@pytest.mark.slow
def test_train_step_on_mesh_matches_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_config, reduced_config
        from repro.models.transformer import RunCfg
        from repro.models.sharding import MeshRules
        from repro.optim.adamw import AdamWConfig
        from repro.train.step import init_train_state, make_train_step

        cfg = reduced_config(get_config("qwen3-0.6b"))
        run = RunCfg(dtype=jnp.float32)
        key = jax.random.PRNGKey(0)
        state, _ = init_train_state(key, cfg)
        batch = {"tokens": jax.random.randint(key, (2, 4, 32), 0, cfg.vocab),
                 "targets": jax.random.randint(key, (2, 4, 32), 0, cfg.vocab)}
        # single device
        s1, m1 = jax.jit(make_train_step(cfg, run, AdamWConfig()))(state, batch)
        # 2x2 mesh with sharding constraints
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
        rules = MeshRules(mesh=mesh, fsdp=("data",), tp=("model",))
        s2, m2 = jax.jit(make_train_step(cfg, run, AdamWConfig(),
                                         rules))(state, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (
            float(m1["loss"]), float(m2["loss"]))
        d = jax.tree.reduce(lambda a, b: a + float(jnp.max(jnp.abs(b))),
                            jax.tree.map(lambda a, b: a - b,
                                         s1.params, s2.params), 0.0)
        print("TRAIN_MESH_OK maxdiff", d)
        assert d < 1e-2
    """)
    assert "TRAIN_MESH_OK" in out
