"""Property-based tests of the full pricing recursion (hypothesis).

Invariants from the paper's §3 (no-arbitrage interval structure) over
random market parameters — the system-level complement to the per-op
properties in test_pwl_hypothesis.py.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (LatticeModel, american_put, price_notc_np,  # noqa: E402
                        price_ref)

_settings = settings(max_examples=12, deadline=None)

markets = st.fixed_dictionaries({
    "s0": st.floats(80.0, 120.0),
    "sigma": st.floats(0.1, 0.4),
    "rate": st.floats(0.0, 0.1),
    "maturity": st.floats(0.1, 1.0),
    "k": st.floats(0.0005, 0.01),
})


@given(markets)
@_settings
def test_bid_below_classic_below_ask(m):
    model = LatticeModel(s0=m["s0"], sigma=m["sigma"], rate=m["rate"],
                         maturity=m["maturity"], n_steps=8,
                         cost_rate=m["k"])
    put = american_put(100.0)
    res = price_ref(model, put)
    classic = price_notc_np(model, put)
    assert res.bid <= classic + 1e-9
    assert classic <= res.ask + 1e-9
    assert res.ask >= 0.0 and res.bid >= -1e-12


@given(markets)
@_settings
def test_ask_dominates_immediate_exercise(m):
    """The seller must be able to cover exercise at t=0: ask >= intrinsic
    (cash needed to deliver (K, -1) with no stock: K - S0 when positive,
    evaluated without t=0 costs)."""
    model = LatticeModel(s0=m["s0"], sigma=m["sigma"], rate=m["rate"],
                         maturity=m["maturity"], n_steps=8,
                         cost_rate=m["k"])
    res = price_ref(model, american_put(100.0))
    intrinsic = max(100.0 - m["s0"], 0.0)
    assert res.ask >= intrinsic - 1e-9


@given(markets, st.floats(1.5, 3.0))
@_settings
def test_spread_monotone_in_k(m, factor):
    model = LatticeModel(s0=m["s0"], sigma=m["sigma"], rate=m["rate"],
                         maturity=m["maturity"], n_steps=8,
                         cost_rate=m["k"])
    put = american_put(100.0)
    lo = price_ref(model, put)
    hi = price_ref(model.with_(cost_rate=min(m["k"] * factor, 0.05)), put)
    assert hi.ask >= lo.ask - 1e-9
    assert hi.bid <= lo.bid + 1e-9
