"""Pallas flash attention kernel: sweep shapes/dtypes/masks vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_ref
from repro.models.layers import _attn_naive, _mask_bias


def _make(B, T, S, H, KVH, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,T,S,H,KVH,hd", [
    (1, 128, 128, 4, 2, 32),     # GQA
    (2, 64, 64, 2, 2, 16),       # MHA
    (1, 128, 128, 4, 1, 64),     # MQA
    (1, 256, 256, 2, 2, 32),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_kernel_vs_naive(dtype, tol, B, T, S, H, KVH, hd, causal, window):
    q, k, v = _make(B, T, S, H, KVH, hd, dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    # oracle: naive materialised scores
    G = H // KVH
    bias = _mask_bias(jnp.arange(T), jnp.arange(S), causal=causal,
                      window=window)
    want = _attn_naive(q.reshape(B, T, KVH, G, hd), k, v,
                       bias).reshape(B, T, H, hd)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_kernel_vs_flash_ref():
    q, k, v = _make(1, 128, 128, 4, 2, 32, jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32,
                          interpret=True)
    want = flash_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_block_size_invariance():
    q, k, v = _make(1, 128, 128, 2, 2, 32, jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_kv=128, interpret=True)
    b = flash_attention(q, k, v, block_q=32, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6,
                               atol=2e-6)
