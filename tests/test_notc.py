"""No-transaction-cost engine: the paper's appendix workload."""
import pytest

from repro.core import (LatticeModel, american_put, price_notc_jax,
                        price_notc_np)


def test_jax_matches_numpy_oracle():
    m = LatticeModel(s0=100, sigma=0.3, rate=0.06, maturity=3.0, n_steps=500)
    put = american_put(100.0)
    assert price_notc_jax(m, put) == pytest.approx(price_notc_np(m, put),
                                                   abs=1e-10)


def test_appendix_price_13_906():
    """Paper appendix: American put K=100, S0=100, T=3, sigma=0.3, R=0.06
    prices at 13.906 (8-byte doubles, N up to 40000).  CRR converges
    O(1/N); N=5000 is within half a cent."""
    m = LatticeModel(s0=100, sigma=0.3, rate=0.06, maturity=3.0, n_steps=5000)
    p = price_notc_jax(m, american_put(100.0))
    assert p == pytest.approx(13.906, abs=5e-3)


def test_american_geq_european_and_intrinsic():
    m = LatticeModel(s0=90, sigma=0.3, rate=0.06, maturity=1.0, n_steps=300)
    put = american_put(100.0)
    am = price_notc_np(m, put)
    # European via plain discounted expectation on the same lattice
    import numpy as np
    n, r, p = m.n_steps, m.r, m.p_star
    v = np.maximum(100.0 - m.stock_level(n), 0.0)
    for lvl in range(n - 1, -1, -1):
        v = (p * v[1:lvl + 2] + (1 - p) * v[:lvl + 1]) / r
    eu = float(v[0])
    assert am >= eu - 1e-12
    assert am >= 100.0 - 90.0 - 1e-12      # intrinsic


def test_monotone_in_spot():
    put = american_put(100.0)
    prices = [price_notc_np(
        LatticeModel(s0=s, sigma=0.2, rate=0.05, maturity=0.5, n_steps=200),
        put) for s in (90.0, 100.0, 110.0)]
    assert prices[0] > prices[1] > prices[2]
