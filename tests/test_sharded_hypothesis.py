"""Property-based parity of the sharded scenario-grid engine (hypothesis).

Random mesh sizes {1, 2, 4, 8} x random mixed TC/no-TC batches x the
cost-model shard planner (whose plans are uneven whenever the row costs
are): the sharded engine must be numerically invisible — ask, bid and
``max_pieces`` equal the single-device engine at 1e-9, and a batch that
overflows the PWL capacity raises OverflowError on BOTH paths, never
just one.  Complements the fixed-grid tests in test_sharded_grid.py.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.scenarios import ScenarioGrid, price_grid_rz  # noqa: E402

TOL = 1e-9
_settings = settings(max_examples=10, deadline=None)

# one tree depth and a handful of batch sizes: every distinct shape is a
# fresh XLA compile, so the strategy reuses a small, bounded shape set
_N_STEPS = 6

grids = st.integers(4, 8).flatmap(lambda n: st.fixed_dictionaries({
    "s0": st.lists(st.floats(80.0, 120.0), min_size=n, max_size=n),
    "sigma": st.lists(st.floats(0.1, 0.4), min_size=n, max_size=n),
    "cost_rate": st.lists(st.sampled_from([0.0, 0.005, 0.01]),
                          min_size=n, max_size=n),
    "payoff": st.lists(st.sampled_from(["put", "call", "bull_spread"]),
                       min_size=n, max_size=n),
}))


@pytest.mark.shard
# capacity 2 overflows whenever the batch has a TC row (pieces >= 3 at
# N=6), so the OverflowError-on-both-paths branch is really drawn
@given(grids, st.sampled_from([1, 2, 4, 8]), st.sampled_from([16, 2]))
@_settings
def test_sharded_matches_single_device_property(g, devices, capacity):
    grid = ScenarioGrid.explicit(
        s0=np.asarray(g["s0"]), sigma=np.asarray(g["sigma"]), rate=0.1,
        maturity=0.25, cost_rate=np.asarray(g["cost_rate"]),
        payoff=tuple(g["payoff"]), strike=100.0, n_steps=_N_STEPS)
    try:
        want = price_grid_rz(grid, capacity=capacity)
    except OverflowError:
        with pytest.raises(OverflowError):
            price_grid_rz(grid, capacity=capacity, devices=devices)
        return
    got = price_grid_rz(grid, capacity=capacity, devices=devices)
    np.testing.assert_allclose(got.ask, want.ask, atol=TOL)
    np.testing.assert_allclose(got.bid, want.bid, atol=TOL)
    assert got.max_pieces == want.max_pieces
