"""Trainer: loss goes down; checkpoint/restart is bit-exact; straggler and
failure-injection paths."""
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.transformer import RunCfg
from repro.train.trainer import TrainerConfig, train

CFG = reduced_config(get_config("internlm2-1.8b"))
RUN = RunCfg(dtype=jnp.float32)


def _tc(tmp, **kw):
    base = dict(steps=12, global_batch=4, seq_len=32, n_micro=2,
                peak_lr=5e-3, warmup=2, ckpt_every=4, log_every=100,
                ckpt_dir=str(tmp))
    base.update(kw)
    return TrainerConfig(**base)


def test_loss_decreases(tmp_path):
    out = train(CFG, _tc(tmp_path / "a", steps=15), RUN, log=lambda *a: None)
    first = np.mean(out["losses"][:3])
    last = np.mean(out["losses"][-3:])
    assert last < first


def test_restart_bit_exact(tmp_path):
    """Kill at step 8 (simulated), resume, final losses match an
    uninterrupted run exactly (synthetic data is step-keyed)."""
    log = lambda *a: None
    ref = train(CFG, _tc(tmp_path / "ref"), RUN, log=log)

    with pytest.raises(RuntimeError, match="simulated node failure"):
        train(CFG, _tc(tmp_path / "kill", simulate_failure_at=8), RUN, log=log)
    resumed = train(CFG, _tc(tmp_path / "kill"), RUN, log=log)

    # resumed run restarts from the step-8 checkpoint -> losses for steps
    # 8..11 must equal the reference run's bit for bit
    np.testing.assert_array_equal(np.asarray(resumed["losses"][-4:]),
                                  np.asarray(ref["losses"][-4:]))


def test_checkpoint_written_and_resumable(tmp_path):
    from repro.checkpoint import ckpt
    train(CFG, _tc(tmp_path / "c", steps=8), RUN, log=lambda *a: None)
    assert ckpt.latest_step(tmp_path / "c") == 8
