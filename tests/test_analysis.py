"""Invariant-analyzer suite (marker: ``analysis``).

Positive half: every checker in ``repro.analysis`` runs clean over the
real tree modulo the checked-in waivers, no waiver is stale, and the
``tools/analyze.py`` CLI gates on exactly that state.

Negative half: each checker is fed a synthetic defect — the known bug
classes this package exists to catch — and must report it with the
right rule anchored at ``file:line``:

* blocking call / lock cycle in async serving code (concurrency),
* an unlocked write to a ``GUARDED_BY`` attribute (guarded-by, the
  PR 6 metrics-race class),
* a compile/bucket key that drops a program field — including an
  in-test revert of PR 7's frictionless-Bermudan bucket collision
  (compile-key),
* a dataclass field missing from ``to_wire``/``from_wire`` or opaque
  by type (wire-schema, the PR 9 ``mesh`` class).

Plus the runtime pieces: shadow-mode lock/owner enforcement, the
single-acquisition metrics snapshot, the LSMC program-knob plumbing,
and a jaxpr-differential fuzz tying "traced program changed" to
"compile key changed".
"""
import dataclasses
import json
import subprocess
import sys
import textwrap
import threading

import pytest

import repro.core  # noqa: F401  (x64 flag side effect)
from repro import analysis
from repro.analysis import (compile_key, concurrency, engine, guarded,
                            shadow, source_scan, wire)
from repro.analysis.engine import apply_waivers, load_waivers

pytestmark = pytest.mark.analysis

REPO = engine.REPO_ROOT
WAIVER_FILE = REPO / "tools" / "analysis_waivers.toml"
ANALYZE = REPO / "tools" / "analyze.py"


def _write(tmp_path, name, src):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src).strip() + "\n")
    return p


# --------------------------------------------------------------------- #
# positive runs: the real tree is clean modulo checked-in waivers
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", sorted(analysis.CHECKERS))
def test_repo_clean_per_checker(name):
    findings = analysis.CHECKERS[name]()
    unwaived, _, _ = apply_waivers(findings, load_waivers(WAIVER_FILE))
    assert unwaived == [], "\n".join(f.format() for f in unwaived)


def test_checked_in_waivers_all_used_none_stale():
    findings = analysis.run_all()
    _, waived, stale = apply_waivers(findings, load_waivers(WAIVER_FILE))
    assert stale == [], f"stale waivers: {stale}"
    assert waived, "the checked-in waiver file should excuse something"


# --------------------------------------------------------------------- #
# waiver hygiene
# --------------------------------------------------------------------- #
def test_waiver_with_empty_reason_rejected(tmp_path):
    p = _write(tmp_path, "w.toml", """
        [[waiver]]
        checker = "source-scan"
        file = "x.py"
        symbol = "f"
        reason = "   "
    """)
    with pytest.raises(ValueError, match="empty reason"):
        load_waivers(p)


def test_waiver_with_missing_key_rejected(tmp_path):
    p = _write(tmp_path, "w.toml", """
        [[waiver]]
        checker = "source-scan"
        file = "x.py"
        reason = "because"
    """)
    with pytest.raises(ValueError, match="missing required keys"):
        load_waivers(p)


def test_missing_waiver_file_means_no_waivers(tmp_path):
    assert load_waivers(tmp_path / "none.toml") == []


# --------------------------------------------------------------------- #
# source-scan negative controls
# --------------------------------------------------------------------- #
def test_interpret_hardcode_flags_call_not_default(tmp_path):
    _write(tmp_path, "mod.py", """
        def run(x, interpret=True):      # a default is policy, fine
            return kernel(x, interpret=True)
    """)
    (f,) = source_scan.scan_interpret_hardcode(tmp_path)
    assert f.rule == "interpret-hardcode"
    assert f.file.endswith("mod.py") and f.line == 2
    assert f.symbol == "run"


def test_sort_ban_flags_hot_path_argsort(tmp_path):
    _write(tmp_path, "core/pwl.py", """
        import jax.numpy as jnp
        def merge(x):
            return jnp.argsort(x)
    """)
    _write(tmp_path, "core/other.py", """
        import jax.numpy as jnp
        def fine(x):
            return jnp.argsort(x)        # not a banned module
    """)
    (f,) = source_scan.scan_sort_ban(tmp_path)
    assert f.rule == "sort-ban" and f.symbol == "merge" and f.line == 3
    assert f.file.endswith("core/pwl.py")


def test_pallas_coverage_both_directions(tmp_path):
    _write(tmp_path, "kernels/knew.py", """
        from jax.experimental import pallas as pl
        def f(x):
            return pl.pallas_call(lambda r, o: None)(x)
    """)
    findings = source_scan.scan_pallas_coverage(
        tmp_path, declared={"repro.ghost"})
    rules = {f.rule: f for f in findings}
    assert rules["pallas-uncovered"].symbol == "repro.kernels.knew"
    assert rules["pallas-stale-contract"].symbol == "repro.ghost"


# --------------------------------------------------------------------- #
# concurrency negative controls
# --------------------------------------------------------------------- #
def test_blocking_call_in_async_def_flagged(tmp_path):
    p = _write(tmp_path, "srv.py", """
        import time
        class S:
            async def handler(self):
                time.sleep(1.0)
    """)
    findings = concurrency.check_blocking_in_async(p)
    assert [f.rule for f in findings] == ["blocking-in-async"]
    assert findings[0].line == 4 and findings[0].symbol == "S.handler"


def test_executor_routed_blocking_call_exempt(tmp_path):
    p = _write(tmp_path, "srv.py", """
        import time
        class S:
            async def handler(self, loop):
                await loop.run_in_executor(None, time.sleep, 1.0)
    """)
    assert concurrency.check_blocking_in_async(p) == []


def test_lock_order_cycle_detected(tmp_path):
    p = _write(tmp_path, "locks.py", """
        import threading
        class A:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
            def one(self):
                with self._a:
                    with self._b:
                        pass
            def two(self):
                with self._b:
                    with self._a:
                        pass
    """)
    findings = [f for f in concurrency.check_files([p])
                if f.rule == "lock-cycle"]
    assert findings, "the ABBA cycle must be reported"
    assert findings[0].file.endswith("locks.py") and findings[0].line > 0


def test_consistent_lock_order_is_clean(tmp_path):
    p = _write(tmp_path, "locks.py", """
        import threading
        class A:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
            def one(self):
                with self._a:
                    with self._b:
                        pass
            def two(self):
                with self._a:
                    with self._b:
                        pass
    """)
    assert [f for f in concurrency.check_files([p])
            if f.rule == "lock-cycle"] == []


# --------------------------------------------------------------------- #
# guarded-by negative controls
# --------------------------------------------------------------------- #
def test_unguarded_write_flagged_guarded_write_clean(tmp_path):
    p = _write(tmp_path, "g.py", """
        import threading
        class C:
            GUARDED_BY = {"count": "_lock"}
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0
            def good(self):
                with self._lock:
                    self.count += 1
            def bad(self):
                self.count += 1
    """)
    findings = guarded.check_files([p])
    assert [(f.rule, f.symbol, f.line) for f in findings] == [
        ("unguarded-write", "C.bad.count", 11)]
    assert findings[0].file.endswith("g.py")


def test_undeclared_shared_write_flagged(tmp_path):
    p = _write(tmp_path, "g.py", """
        import threading
        class C:
            GUARDED_BY = {"count": "_lock"}
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0
            def sneak(self):
                self.extra = 1
    """)
    findings = guarded.check_files([p])
    assert [(f.rule, f.symbol) for f in findings] == [
        ("undeclared-attr", "C.sneak.extra")]


def test_locked_helper_called_without_lock_flagged(tmp_path):
    p = _write(tmp_path, "g.py", """
        import threading
        class C:
            GUARDED_BY = {"count": "_lock"}
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0
            def _bump_locked(self):
                self.count += 1
            def bad(self):
                self._bump_locked()
            def good(self):
                with self._lock:
                    self._bump_locked()
    """)
    findings = guarded.check_files([p])
    assert [(f.rule, f.symbol) for f in findings] == [
        ("locked-helper-call", "C.bad._bump_locked")]


# --------------------------------------------------------------------- #
# compile-key negative controls + the PR 7 reproduction
# --------------------------------------------------------------------- #
def test_key_probe_catches_a_dropped_field():
    from repro.serve.core import SchedulerCore

    def lossy(chunk, greeks=False):
        k = SchedulerCore.chunk_compile_key(chunk, greeks)
        return k[:4] + (None,) + k[5:]       # drop resolved interpret

    findings = compile_key.check_key_probes(key_fn=lossy)
    assert [(f.rule, f.symbol) for f in findings] == [
        ("key-omits-field", "ChunkSpec.interpret")]
    assert findings[0].file == "src/repro/serve/core.py"
    assert findings[0].line > 0


def test_bucket_probe_reproduces_pr7_collision():
    """Revert PR 7's fix in-test: a bucket function keyed only on
    (n_steps, has-cost) coalesces the frictionless Bermudan into the
    frictionless-American bucket — the exact wrong-engine bug."""
    findings = compile_key.check_bucket_probes(
        bucket_fn=lambda key: (key[8], key[4] > 0.0))
    collisions = [f for f in findings if f.rule == "bucket-collision"]
    assert any("american-vs-bermudan-frictionless" in f.message
               for f in collisions)
    assert all(f.file == "src/repro/serve/core.py" and f.line > 0
               for f in collisions)


def test_bucket_probe_catches_data_split():
    # bucketing on strike splits data-identical programs
    findings = compile_key.check_bucket_probes(
        bucket_fn=lambda key: (key[8], key[10], key[6]))
    assert any(f.rule == "bucket-split" and "strike-is-data" in f.message
               for f in findings)


def test_real_scheduler_keys_pass_all_probes():
    assert compile_key.check_key_probes() == []
    assert compile_key.check_bucket_probes() == []


# --------------------------------------------------------------------- #
# wire-schema negative control (the PR 9 mesh class)
# --------------------------------------------------------------------- #
def test_wire_static_flags_uncovered_and_opaque_fields(tmp_path):
    p = _write(tmp_path, "w.py", """
        import dataclasses
        from typing import Any
        @dataclasses.dataclass
        class ChunkSpec:
            n_steps: int
            mesh: Any
            tag: str = "x"
            def to_wire(self):
                return {"n_steps": int(self.n_steps)}
            @staticmethod
            def from_wire(wire):
                return ChunkSpec(n_steps=int(wire["n_steps"]),
                                 mesh=None)
    """)
    findings = wire.check_wire_static(p, classes=("ChunkSpec",),
                                      codecs=set())
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f.symbol)
    assert "ChunkSpec.mesh" in by_rule["wire-opaque-type"]
    assert "ChunkSpec.mesh" in by_rule["wire-missing-encode"]
    assert "ChunkSpec.tag" in by_rule["wire-missing-encode"]
    assert "ChunkSpec.tag" in by_rule["wire-missing-decode"]
    assert all(f.file.endswith("w.py") and f.line > 0 for f in findings)


def test_wire_roundtrip_preserves_lsmc_program_knobs():
    from repro.serve.core import ChunkSpec
    spec = ChunkSpec(
        bucket=(8, "lsmc", 2, (4, 8)), requests=[], n_steps=8,
        engine="lsmc", capacity=16, backend="jnp", padded=2,
        cols=((100.0, 95.0), (0.2, 0.2), (0.1, 0.1), (0.25, 0.25),
              (0.0, 0.0), ("put", "put"), (100.0, 95.0), (110.0, 110.0)),
        n_assets=2, exercise_steps=(4, 8), n_paths=256, mc_seed=3,
        basis="laguerre", degree=4, antithetic=False)
    back = ChunkSpec.from_wire(json.loads(json.dumps(spec.to_wire())))
    assert (back.basis, back.degree, back.antithetic) == ("laguerre", 4, False)
    # a v1 peer that predates the knobs still decodes, with the defaults
    old = spec.to_wire()
    for k in ("basis", "degree", "antithetic"):
        old.pop(k)
    legacy = ChunkSpec.from_wire(old)
    assert (legacy.basis, legacy.degree, legacy.antithetic) == ("poly", 3, True)


# --------------------------------------------------------------------- #
# differential fuzz: traced-program change => compile-key change
# --------------------------------------------------------------------- #
def test_lsmc_jaxpr_difference_implies_key_difference():
    """Every LSMC program knob that changes the traced jaxpr must change
    ``SchedulerCore.chunk_compile_key`` — the PR 7 bug class, asserted
    against the real kernel rather than a hand-kept field list."""
    import jax
    import jax.numpy as jnp
    from repro.core.lsmc import lsmc_rows, path_keys
    from repro.serve.core import SchedulerCore

    base = dict(n_steps=4, steps=(2, 4), n_paths=16, n_assets=1,
                degree=2, basis="poly", antithetic=True)
    variants = [{"n_paths": 32}, {"degree": 3}, {"basis": "laguerre"},
                {"antithetic": False}, {"steps": (4,)}, {"n_assets": 2}]

    def jaxpr_text(params):
        row = tuple(jnp.asarray([v]) for v in
                    (100.0, 0.2, 0.1, 0.25, 0.0, 0.0, -1.0, 0.0, 1.0,
                     100.0, 100.0))
        keys = path_keys(0, 1)
        closed = lambda *a: lsmc_rows(*a, **params)  # noqa: E731
        return str(jax.make_jaxpr(closed)(*row, keys))

    def chunk_of(params):
        from repro.serve.core import ChunkSpec
        return ChunkSpec(
            bucket=(params["n_steps"], "lsmc", params["n_assets"],
                    params["steps"]),
            requests=[], n_steps=params["n_steps"], engine="lsmc",
            capacity=16, backend="jnp", padded=1,
            cols=((100.0,), (0.2,), (0.1,), (0.25,), (0.0,), ("put",),
                  (100.0,), (110.0,)),
            n_assets=params["n_assets"], exercise_steps=params["steps"],
            n_paths=params["n_paths"], mc_seed=0, interpret=True,
            basis=params["basis"], degree=params["degree"],
            antithetic=params["antithetic"])

    base_jaxpr = jaxpr_text(base)
    base_key = SchedulerCore.chunk_compile_key(chunk_of(base))
    for delta in variants:
        params = {**base, **delta}
        key = SchedulerCore.chunk_compile_key(chunk_of(params))
        if jaxpr_text(params) != base_jaxpr:
            assert key != base_key, (
                f"{delta} changes the traced program but not the "
                "compile key — stale-program reuse")
        # all six knobs are program-role: the key must split regardless
        assert key != base_key, f"{delta} did not perturb the key"


# --------------------------------------------------------------------- #
# runtime shadow mode
# --------------------------------------------------------------------- #
def test_shadow_lock_tracks_owner():
    lk = shadow.ShadowLock()
    assert not lk.held_by_me() and not lk.locked()
    with lk:
        assert lk.held_by_me() and lk.locked()
    assert not lk.locked()


def test_shadow_flags_unlocked_metrics_write():
    from repro.serve.core import ServiceMetrics
    uninstall = shadow.install([ServiceMetrics])
    try:
        m = ServiceMetrics()
        with pytest.raises(shadow.GuardViolation, match="guarded by"):
            m.requests += 1                  # the PR 6 race, live
        with m._lock:
            m.requests += 1                  # disciplined write passes
        assert m.snapshot()["requests"] == 1
    finally:
        uninstall()
    m2 = ServiceMetrics()
    m2.requests += 1                         # uninstalled: back to normal
    assert m2.requests == 1


def test_shadow_flags_cross_thread_owner_write():
    from repro.serve.core import SchedulerCore
    uninstall = shadow.install([SchedulerCore])
    try:
        core = SchedulerCore(max_batch=4)
        core._next_id = 7                    # pins this thread as owner
        raised = []

        def hostile():
            try:
                core._next_id = 8
            except shadow.GuardViolation as e:
                raised.append(e)

        t = threading.Thread(target=hostile)
        t.start()
        t.join()
        assert raised and "owner-confined" in str(raised[0])
        core._next_id = 9                    # owner thread still may write
    finally:
        uninstall()


# --------------------------------------------------------------------- #
# metrics snapshot: exactly one lock acquisition (torn-read regression)
# --------------------------------------------------------------------- #
class _CountingLock:
    def __init__(self):
        self._inner = threading.RLock()      # reentrant so a regression
        self.acquisitions = 0                # shows as a count, not a hang

    def __enter__(self):
        self.acquisitions += 1
        self._inner.acquire()
        return self

    def __exit__(self, *exc):
        self._inner.release()


def test_gateway_snapshot_is_single_acquisition():
    from repro.serve.gateway import GatewayMetrics
    m = GatewayMetrics()
    lock = _CountingLock()
    m._lock = lock
    snap = m.snapshot()
    assert lock.acquisitions == 1, (
        "GatewayMetrics.snapshot must read base and gateway counters "
        "under ONE acquisition — two means a torn read window")
    assert "requests" in snap and "staleness_p99_ms" in snap


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
FAST_CHECKERS = ("source-scan", "concurrency", "guarded-by")


def _cli(*argv, **kw):
    return subprocess.run(
        [sys.executable, str(ANALYZE), *argv],
        capture_output=True, text=True, cwd=REPO, **kw)


def test_cli_clean_run_exits_zero_and_dumps_json(tmp_path):
    out = _cli("--fail-on-findings", "--json", str(tmp_path / "f.json"),
               *[a for c in FAST_CHECKERS for a in ("--checker", c)])
    assert out.returncode == 0, out.stdout + out.stderr
    data = json.loads((tmp_path / "f.json").read_text())
    assert data["unwaived"] == []
    assert data["stale_waivers"] == []
    assert {w["finding"]["rule"] for w in data["waived"]} >= {"sort-ban"}


def test_cli_unwaived_findings_exit_one(tmp_path):
    empty = _write(tmp_path, "none.toml", "# no waivers")
    out = _cli("--fail-on-findings", "--waivers", str(empty),
               "--checker", "source-scan")
    assert out.returncode == 1
    assert "sort-ban" in out.stdout


def test_cli_bad_waiver_file_exits_two(tmp_path):
    bad = _write(tmp_path, "bad.toml", """
        [[waiver]]
        checker = "source-scan"
        file = "x.py"
        symbol = "f"
        reason = ""
    """)
    out = _cli("--waivers", str(bad), "--checker", "source-scan")
    assert out.returncode == 2
    assert "empty reason" in out.stderr


def test_cli_unknown_checker_exits_two():
    out = _cli("--checker", "no-such-checker")
    assert out.returncode == 2
    assert "unknown checker" in out.stderr


def test_cli_list_checkers_matches_registry():
    out = _cli("--list-checkers")
    assert out.returncode == 0
    assert out.stdout.split() == list(analysis.CHECKERS)
