"""End-to-end behaviour tests for the paper's system.

The three pillars, exercised through the public API:
  1. pricing under transaction costs (the paper's contribution) matches
     the exact sequential oracle and the friction-free anchor;
  2. a reduced LM trains for real steps with checkpoints;
  3. the pricing *service* answers batched requests correctly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LatticeModel, american_put, bull_spread,
                        price_notc_np, price_ref)
from repro.core.rz import price_rz


def test_paper_pipeline_end_to_end():
    """Price the paper's American put (scaled-down N) with and without
    costs; every invariant of §3/§5 must hold simultaneously."""
    put = american_put(100.0)
    m0 = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=24)
    classic = price_notc_np(m0, put)

    spreads = []
    for k in (0.0, 0.0025, 0.005):
        got = price_rz(m0.with_(cost_rate=k), put, capacity=32)
        ref = price_ref(m0.with_(cost_rate=k), put)
        assert got.ask == pytest.approx(ref.ask, abs=1e-9)
        assert got.bid == pytest.approx(ref.bid, abs=1e-9)
        assert got.bid <= classic + 1e-9 <= got.ask + 1e-9
        spreads.append(got.ask - got.bid)
    assert spreads[0] == pytest.approx(0.0, abs=1e-9)
    assert spreads[0] < spreads[1] < spreads[2]


def test_bull_spread_cash_settled():
    m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=16,
                     cost_rate=0.01)
    got = price_rz(m, bull_spread(), capacity=48)
    ref = price_ref(m, bull_spread())
    assert got.ask == pytest.approx(ref.ask, abs=1e-9)
    assert got.bid == pytest.approx(ref.bid, abs=1e-9)
    # a bull spread pays in [0, 10]: prices must sit inside
    assert 0.0 <= got.bid <= got.ask <= 10.0


def test_train_then_serve_roundtrip(tmp_path):
    """Train a tiny LM a few steps, checkpoint, restore into a serving
    engine, generate — the full lifecycle."""
    from repro.checkpoint import ckpt
    from repro.configs import get_config, reduced_config
    from repro.models.transformer import RunCfg
    from repro.serve.engine import LMEngine
    from repro.train.trainer import TrainerConfig, train

    cfg = reduced_config(get_config("internlm2-1.8b"))
    run = RunCfg(dtype=jnp.float32)
    out = train(cfg, TrainerConfig(steps=6, global_batch=4, seq_len=32,
                                   n_micro=1, ckpt_every=6, log_every=100,
                                   ckpt_dir=str(tmp_path)),
                run, log=lambda *a: None)
    assert np.isfinite(out["final_loss"])

    from repro.train.step import init_train_state
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    restored = ckpt.restore(tmp_path, like=state)
    eng = LMEngine(restored.params, cfg, run, batch=2, max_len=16)
    toks = eng.generate(np.zeros((2, 8), np.int32), 4)
    assert toks.shape == (2, 4)
    assert np.all((0 <= toks) & (toks < cfg.vocab))
