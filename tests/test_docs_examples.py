"""Execute the documentation's code snippets so they cannot rot.

Covers: every ```python fenced block in README.md (the quickstart) and
docs/SERVING.md (the operator's guide), the doctests embedded in the
``repro.api`` / ``repro.scenarios`` docstrings, the runnable examples'
import surface, and every relative markdown link in README.md +
docs/*.md (``tools/check_links.py`` — the same check the CI docs lane
runs).  Snippets are executed in one shared namespace per document, in
order, so later blocks may use earlier blocks' names (as a reader
would).
"""
import doctest
import importlib.util
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks(path: Path):
    return _FENCE.findall(path.read_text())


def test_readme_python_snippets_execute():
    blocks = _python_blocks(ROOT / "README.md")
    assert blocks, "README.md has no ```python blocks"
    ns: dict = {}
    for block in blocks:
        exec(compile(block, "README.md", "exec"), ns)
    # the quickstart leaves its results in scope — sanity-check them
    assert ns["q"].ask > ns["q"].bid
    assert ns["res"].grid.n_scenarios == 18


def test_serving_guide_snippets_execute():
    """docs/SERVING.md is doctested end-to-end: the operator's guide
    cannot drift from the scheduler API."""
    blocks = _python_blocks(ROOT / "docs" / "SERVING.md")
    assert blocks, "docs/SERVING.md has no ```python blocks"
    ns: dict = {}
    for block in blocks:
        exec(compile(block, "docs/SERVING.md", "exec"), ns)
    # the guide's running example leaves the service in scope
    m = ns["service"].metrics()
    assert m["completed"] == m["requests"] == 4
    assert m["cache_hits"] == 1
    # ... and the gateway section leaves its results in scope too
    assert ns["gw_metrics"]["completed"] == 2
    assert ns["gw_metrics"]["replica_crashes"] == 0
    assert ns["stream_summary"]["staleness_p99_ms"] >= 0.0


def test_platforms_guide_snippets_execute():
    """docs/PLATFORMS.md documents the platform policy with executable
    assertions — the guide cannot drift from ``core/platform.py``.  The
    snippets pin the gpu/tpu policy branches via
    ``configure_jax=False``, so the platform override is restored even
    on failure."""
    blocks = _python_blocks(ROOT / "docs" / "PLATFORMS.md")
    assert blocks, "docs/PLATFORMS.md has no ```python blocks"
    from repro.core import platform as plat
    ns: dict = {}
    try:
        for block in blocks:
            exec(compile(block, "docs/PLATFORMS.md", "exec"), ns)
        # the guide's running example leaves the summary in scope
        assert ns["summary"]["platform"] == plat.detect_platform()
    finally:
        plat.set_platform(None)


def test_analysis_guide_snippets_execute():
    """docs/ANALYSIS.md's python blocks run the real checkers: the
    guard-map examples and the clean-run contract (no unwaived
    findings, no stale waivers) — the guide cannot drift from
    ``repro.analysis`` or from the repo actually being clean."""
    blocks = _python_blocks(ROOT / "docs" / "ANALYSIS.md")
    assert blocks, "docs/ANALYSIS.md has no ```python blocks"
    ns: dict = {}
    for block in blocks:
        exec(compile(block, "docs/ANALYSIS.md", "exec"), ns)
    assert ns["unwaived"] == [] and ns["stale"] == []


def test_analysis_doc_mentions_real_paths():
    """Every repo path ANALYSIS.md references must exist."""
    text = (ROOT / "docs" / "ANALYSIS.md").read_text()
    for ref in set(re.findall(
            r"`((?:src|tests|tools)/[\w./*-]+)`", text)):
        assert (ROOT / ref).exists(), ref


def test_platforms_doc_mentions_real_paths():
    """Every repo path PLATFORMS.md references must exist."""
    text = (ROOT / "docs" / "PLATFORMS.md").read_text()
    for ref in set(re.findall(
            r"`((?:src|tests|tools|benchmarks)/[\w./*-]+)`", text)):
        if "*" in ref:
            assert list(ROOT.glob(ref)), ref
        else:
            assert (ROOT / ref).exists(), ref


def test_markdown_links_resolve():
    """Every relative link in README.md and docs/*.md points at a real
    file (same checker the CI docs lane runs standalone)."""
    spec = importlib.util.spec_from_file_location(
        "check_links", ROOT / "tools" / "check_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    for path in [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]:
        assert mod.broken_links(path) == [], path.name


def test_architecture_doc_mentions_real_modules():
    """Every src path ARCHITECTURE.md references must exist."""
    text = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    for mod in set(re.findall(r"`(?:src/repro/|)((?:core|kernels|serve|"
                              r"launch)/\w+\.py|scenarios\.py|api\.py|"
                              r"compat\.py)`", text)):
        assert (ROOT / "src" / "repro" / mod).exists(), mod


@pytest.mark.parametrize("module_name", ["repro.api", "repro.scenarios"])
def test_module_doctests(module_name):
    import importlib
    mod = importlib.import_module(module_name)
    results = doctest.testmod(mod, verbose=False,
                              optionflags=doctest.NORMALIZE_WHITESPACE)
    assert results.failed == 0, f"{results.failed} doctest failures"
    if module_name == "repro.api":
        assert results.attempted > 0, "repro.api doctests not collected"


def test_examples_are_importable():
    """The examples' public entry points exist (full runs are manual —
    they are sized for demonstration, not the test budget)."""
    import importlib.util
    for name in ("quickstart", "scenario_grid"):
        path = ROOT / "examples" / f"{name}.py"
        spec = importlib.util.spec_from_file_location(f"examples_{name}",
                                                      path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        assert callable(mod.main)
