"""Least-squares Monte Carlo engine: seeded oracle locks + determinism.

The lattice engines are the repo's exact oracles for 1-D American
contracts, so the LSMC engine is *locked* against them under fixed PRNG
seeds: the deterministic keys make the k-standard-error asserts
reproducible (see tests/_stats.py).  The remaining gap between LSMC (an
exact-GBM simulator) and a CRR tree is the tree's own discretisation
error, which shrinks like 1/n_steps — the locks use a deep tree so the
MC standard error dominates.
"""
import numpy as np
import pytest

from _stats import assert_within_se, rmse

from repro.core import LatticeModel, american_put, price_notc_np, price_ref
from repro.core.lsmc import (LSMC_BASES, basis_matrix, exercise_schedule,
                             path_keys)
from repro.scenarios import (ScenarioGrid, price_grid_lsmc, price_grid_notc,
                             price_grid_rz, route_engine)

pytestmark = pytest.mark.mc

N_DEEP = 200          # oracle tree depth: CRR bias ~0.015 << 3*SE here
PATHS = 8192
MKT = dict(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25)


def _american_grid(n_steps=N_DEEP, **kw):
    merged = {**MKT, **kw}
    return ScenarioGrid.cartesian(n_steps=n_steps, strike=100.0,
                                  payoff="put", **merged)


def _oracle_put(n_steps=N_DEEP):
    m = LatticeModel(n_steps=n_steps, cost_rate=0.0, **MKT)
    return price_notc_np(m, american_put(100.0))


# ---------------------------------------------------------------- oracles

def test_lsmc_locks_to_notc_oracle_within_3se():
    res = price_grid_lsmc(_american_grid(), n_paths=PATHS, seed=0)
    se = float(res.stderr.ravel()[0])
    assert se > 0.0
    assert_within_se(res.ask.ravel()[0], _oracle_put(), se,
                     k=3.0, label="lsmc vs notc american put")


def test_lsmc_locks_to_rz_reference_at_zero_costs():
    """At cost_rate=0 the RZ reference collapses to the classic binomial
    price, giving a second, independent oracle for the same lock."""
    m = LatticeModel(n_steps=64, cost_rate=0.0, **MKT)
    ref = price_ref(m, american_put(100.0))
    assert ref.ask == pytest.approx(ref.bid, abs=1e-10)
    res = price_grid_lsmc(_american_grid(n_steps=64), n_paths=PATHS, seed=0)
    se = float(res.stderr.ravel()[0])
    # shallower tree -> allow its CRR discretisation gap explicitly
    assert_within_se(res.ask.ravel()[0], ref.ask, se, k=3.0, extra=0.06,
                     label="lsmc vs rz_ref (lambda=0)")


@pytest.mark.parametrize("basis", LSMC_BASES)
def test_both_bases_lock_to_oracle(basis):
    res = price_grid_lsmc(_american_grid(), n_paths=PATHS, seed=0,
                          basis=basis)
    assert_within_se(res.ask.ravel()[0], _oracle_put(),
                     float(res.stderr.ravel()[0]), k=3.0,
                     label=f"lsmc[{basis}] vs notc")


def test_convergence_in_paths_monotone():
    """RMSE over 3 seeds shrinks from 1k to 16k paths (~4x in theory)."""
    target = _oracle_put()
    errs = []
    for paths in (1024, 4096, 16384):
        vals = [float(price_grid_lsmc(_american_grid(), n_paths=paths,
                                      seed=s).ask.ravel()[0])
                for s in (0, 1, 2)]
        errs.append(rmse(vals, target))
    assert errs[-1] < errs[0]


# ------------------------------------------------- determinism / sharding

def test_repeat_and_shard_and_pad_bit_equal():
    grid = ScenarioGrid.cartesian(s0=(90.0, 100.0, 110.0), sigma=0.2,
                                  rate=0.1, maturity=0.25, n_steps=50,
                                  strike=100.0, exercise_steps=(10, 25, 50))
    a = price_grid_lsmc(grid, n_paths=1024, seed=3)
    b = price_grid_lsmc(grid, n_paths=1024, seed=3)
    np.testing.assert_array_equal(a.ask, b.ask)
    np.testing.assert_array_equal(a.stderr, b.stderr)
    # simulated mesh: identical layout, bit-equal results
    c = price_grid_lsmc(grid, n_paths=1024, seed=3, devices=4)
    np.testing.assert_array_equal(a.ask, c.ask)
    # padding repeats the last row; real rows keep their index-derived keys
    d = price_grid_lsmc(grid.pad_to(8), n_paths=1024, seed=3)
    np.testing.assert_array_equal(a.ask.ravel(), d.ask.ravel()[:3])


def test_seed_changes_price_but_stays_in_band():
    target = _oracle_put()
    r0 = price_grid_lsmc(_american_grid(), n_paths=PATHS, seed=0)
    r1 = price_grid_lsmc(_american_grid(), n_paths=PATHS, seed=1)
    assert float(r0.ask.ravel()[0]) != float(r1.ask.ravel()[0])
    for r, s in ((r0, 0), (r1, 1)):
        assert_within_se(r.ask.ravel()[0], target,
                         float(r.stderr.ravel()[0]), k=4.0,
                         label=f"seed={s}")


def test_path_keys_are_fold_in_per_row():
    import jax
    keys = np.asarray(path_keys(7, 4))
    assert keys.shape == (4, 2)
    expect = np.asarray(jax.random.fold_in(jax.random.PRNGKey(7), 2))
    np.testing.assert_array_equal(keys[2], expect)


# ---------------------------------------------------- conventions / guards

def test_tc_premium_convention_and_spread():
    grid = _american_grid(n_steps=50, cost_rate=0.01)
    res = price_grid_lsmc(grid, n_paths=2048, seed=0)
    ask, bid = float(res.ask.ravel()[0]), float(res.bid.ravel()[0])
    mid = 0.5 * (ask + bid)
    assert bid < mid < ask
    assert ask == pytest.approx(mid * 1.01, rel=1e-12)
    assert bid == pytest.approx(mid * 0.99, rel=1e-12)


def test_basket_bermudan_prices_and_se_finite():
    grid = ScenarioGrid.cartesian(s0=(95.0, 105.0), n_steps=40,
                                  strike=100.0, n_assets=3,
                                  exercise_steps=(10, 20, 40))
    res = price_grid_lsmc(grid, n_paths=1024, seed=0)
    assert res.engine == "lsmc"
    assert np.all(np.isfinite(res.ask)) and np.all(res.ask >= 0.0)
    assert np.all(res.stderr > 0.0)
    # basket-mean put is worth less than the 1-D put (diversification)
    one = price_grid_lsmc(
        ScenarioGrid.cartesian(s0=(95.0, 105.0), n_steps=40, strike=100.0,
                               exercise_steps=(10, 20, 40)),
        n_paths=1024, seed=0)
    assert np.all(res.ask < one.ask)


def test_schedule_validation():
    assert exercise_schedule(10, None) == tuple(range(11))
    assert exercise_schedule(10, (10, 3)) == (3, 10)
    with pytest.raises(ValueError):
        exercise_schedule(10, (3, 5))        # missing terminal step
    with pytest.raises(ValueError):
        exercise_schedule(10, (0, 11, 10))   # out of range
    with pytest.raises(ValueError):
        exercise_schedule(10, ())


def test_lattice_engines_reject_mc_contracts():
    basket = ScenarioGrid.cartesian(n_steps=20, n_assets=2)
    bermudan = ScenarioGrid.cartesian(n_steps=20, exercise_steps=(5, 20))
    for grid in (basket, bermudan):
        with pytest.raises(ValueError, match="lsmc"):
            price_grid_notc(grid)
        with pytest.raises(ValueError, match="lsmc"):
            price_grid_rz(grid)


def test_route_engine_table():
    assert route_engine(any_tc=False) == "notc"
    assert route_engine(any_tc=True) == "rz"
    assert route_engine(any_tc=False, n_assets=2) == "lsmc"
    assert route_engine(any_tc=True, n_assets=2) == "lsmc"
    assert route_engine(any_tc=True, exercise_steps=(5, 10)) == "lsmc"


def test_basis_matrix_shapes_and_laguerre_values():
    x = np.asarray([0.5, 1.0, 2.0])
    poly = np.asarray(basis_matrix(x, 2, "poly"))
    np.testing.assert_allclose(poly[:, 1], x)
    np.testing.assert_allclose(poly[:, 2], x * x)
    lag = np.asarray(basis_matrix(x, 2, "laguerre"))
    np.testing.assert_allclose(lag[:, 1], 1.0 - x)
    np.testing.assert_allclose(lag[:, 2], 1.0 - 2.0 * x + 0.5 * x * x)
    with pytest.raises(ValueError):
        basis_matrix(x, 2, "hermite")
