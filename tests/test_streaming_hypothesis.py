"""Differential property: streaming incremental requotes == full reprice.

For ANY tick sequence over a mixed 108-style book (both engines, mixed
payoff families/strikes/depths), the incrementally maintained book must
be indistinguishable from a full reprice of the post-tick book:

* ask/bid bit-equal (asserted at the repo-wide 1e-9, rtol=0);
* per-row ``max_pieces`` (``GridResult.row_pieces``) *exactly* equal —
  grid-engine lanes are independent, so a row's PWL knot count cannot
  depend on which batch priced it;
* OverflowError parity — a tick sequence that pushes some touched row
  past the PWL ``capacity`` budget blows up incrementally iff the full
  reprice blows up (untouched rows already priced within budget cannot
  start overflowing).

The random-sequence property runs under Hypothesis (installed in CI via
requirements-ci.txt; skipped locally when absent — the same fixed
sequences run unconditionally below so the property logic is always
exercised).
"""
import numpy as np
import pytest

from repro.api import price_american
from repro.serve.streaming import StreamingBook, Tick, synth_ticks

pytestmark = pytest.mark.gateway

TOL = 1e-9

# base vol 0.3 prices comfortably inside the books below at these
# depths; see _tight_book for the calibrated overflow boundary
_SIGMA0 = 0.3


def _book(capacity: int = 48) -> StreamingBook:
    return StreamingBook.mixed(n_underlyings=2, per_underlying=4,
                               n_steps=(6, 8), sigma0=_SIGMA0,
                               capacity=capacity)


def _tight_book() -> StreamingBook:
    """Two rows against a tight PWL budget (capacity=4), calibrated so
    the overflow boundary is a *tick* away: the TC put needs 3 knots at
    sigma=0.3 (fits) but 5 in the sigma<=0.2 region (overflows) — drawn
    sequences genuinely cross the boundary."""
    return StreamingBook(
        underlying=[0, 1], s0=[100.0, 101.0], sigma=[_SIGMA0, _SIGMA0],
        rate=0.05, maturity=0.5, cost_rate=[0.01, 0.0],
        payoff=["put", "call"], strike=[100.0, 95.0], strike2=None,
        n_steps=[8, 6], capacity=4)


def _run_differential(ticks, make_book) -> None:
    """The property: incremental and full-reprice books agree exactly
    (quotes, row_pieces, max_pieces, and OverflowError behaviour)."""
    book = make_book()
    try:
        book.full_reprice()
    except OverflowError:
        # initial book already over budget: the reference blows up too
        # and there is no incremental state to diff
        with pytest.raises(OverflowError):
            make_book().full_reprice()
        return
    inc_err = None
    try:
        for tick in ticks:
            book.requote(book.apply(tick))
    except OverflowError as e:
        inc_err = e
    reference = book.copy()          # same post-tick inputs
    ref_err = None
    try:
        reference.full_reprice()
    except OverflowError as e:
        ref_err = e
    assert (inc_err is None) == (ref_err is None), (
        f"OverflowError parity violated: incremental={inc_err!r} "
        f"full={ref_err!r}")
    if inc_err is None:
        np.testing.assert_allclose(book.ask, reference.ask,
                                   rtol=0, atol=TOL)
        np.testing.assert_allclose(book.bid, reference.bid,
                                   rtol=0, atol=TOL)
        np.testing.assert_array_equal(book.row_pieces,
                                      reference.row_pieces)
        assert book.max_pieces == reference.max_pieces


# --------------------------------------------------------------------- #
# fixed sequences (always run, hypothesis or not)
# --------------------------------------------------------------------- #
def test_differential_on_fixed_sequences():
    for seed in (0, 1):
        _run_differential(synth_ticks(6, n_underlyings=2, seed=seed,
                                      sigma_range=(0.28, 0.42)), _book)


def test_differential_interleaved_spot_and_vol():
    _run_differential([Tick(0, "s0", 93.0), Tick(1, "sigma", 0.33),
                       Tick(0, "sigma", 0.41), Tick(1, "s0", 108.0),
                       Tick(0, "s0", 101.5)], _book)


def test_streaming_book_rows_match_price_american():
    """Ties the chain to the oracle: every row of a repriced book equals
    pricing that contract alone, including its per-row max_pieces."""
    book = _book(48)
    book.full_reprice()
    book.requote(book.apply(Tick(0, "s0", 104.0)))
    for i in range(book.n_rows):
        ref = price_american(
            s0=float(book.s0[i]), sigma=float(book.sigma[i]),
            rate=float(book.rate[i]), maturity=float(book.maturity[i]),
            n_steps=int(book.n_steps[i]), payoff=str(book.payoff[i]),
            strike=float(book.strike[i]),
            strike2=float(book.strike2[i]),
            cost_rate=float(book.cost_rate[i]), capacity=48)
        assert abs(book.ask[i] - ref.ask) < TOL
        assert abs(book.bid[i] - ref.bid) < TOL
        assert book.row_pieces[i] == ref.max_pieces


def test_overflow_parity_tick_pushes_row_over_budget():
    """A vol tick into the high-knot region overflows capacity=4 on the
    incremental path AND on the full reprice — never one without the
    other (the parity half of the property, pinned deterministically)."""
    book = _tight_book()
    book.full_reprice()              # pieces <= 3 everywhere: fits
    idx = book.apply(Tick(0, "sigma", 0.2))   # the put row now needs 5
    with pytest.raises(OverflowError):
        book.requote(idx)
    reference = book.copy()
    with pytest.raises(OverflowError):
        reference.full_reprice()


def test_overflow_parity_safe_tick_stays_safe():
    """Same tight capacity, but ticks that stay in the low-knot
    region: neither path overflows and they still agree."""
    _run_differential([Tick(0, "sigma", 0.35), Tick(1, "s0", 103.0)],
                      _tight_book)


# --------------------------------------------------------------------- #
# the random-sequence property (CI: hypothesis from requirements-ci.txt;
# guarded import — the fixed-sequence tests above must run regardless)
# --------------------------------------------------------------------- #
try:
    import hypothesis
    from hypothesis import strategies as st
except ImportError:              # pragma: no cover - CI always has it
    hypothesis = None

if hypothesis is not None:
    @st.composite
    def _tick(draw):
        u = draw(st.integers(min_value=0, max_value=1))
        if draw(st.booleans()):
            return Tick(u, "sigma", draw(st.floats(min_value=0.18,
                                                   max_value=0.45)))
        return Tick(u, "s0", draw(st.floats(min_value=85.0,
                                            max_value=115.0)))

    @hypothesis.settings(
        max_examples=10, deadline=None, derandomize=True,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    @hypothesis.given(ticks=st.lists(_tick(), max_size=5),
                      tight=st.booleans())
    def test_streaming_differential_property(ticks, tight):
        """Random tick sequences, both a tight and a roomy PWL budget:
        the incremental book always equals the full post-tick
        reprice."""
        _run_differential(ticks, _tight_book if tight else _book)
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI runs it)")
    def test_streaming_differential_property():
        pass
