"""Checkpoint: roundtrip, atomicity, async, GC, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


@pytest.fixture
def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.int32(7)},
            "tup": (jnp.zeros((2,)), jnp.ones((3,), jnp.float64))}


def test_roundtrip(tmp_path, tree):
    ckpt.save(tmp_path, 3, tree)
    out = ckpt.restore(tmp_path, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_and_multiple(tmp_path, tree):
    for s in (1, 5, 3):
        ckpt.save(tmp_path, s, tree)
    assert ckpt.latest_step(tmp_path) == 5


def test_torn_checkpoint_ignored(tmp_path, tree):
    ckpt.save(tmp_path, 1, tree)
    # simulate a crash mid-write: tmp dir left behind, no meta.json
    torn = tmp_path / "step_0000000009"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1
    out = ckpt.restore(tmp_path, like=tree)
    assert out is not None


def test_async_checkpointer_and_gc(tmp_path, tree):
    saver = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        saver.save(s, tree)
    saver.wait()
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [3, 4]


def test_elastic_restore_with_new_sharding(tmp_path, tree):
    """Restore with explicit target shardings (single-device here, but the
    code path is the multi-mesh one: numpy -> device_put(sharding))."""
    from jax.sharding import NamedSharding, PartitionSpec as PS
    mesh = jax.make_mesh((1,), ("data",))
    ckpt.save(tmp_path, 1, tree)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, PS()), tree)
    out = ckpt.restore(tmp_path, like=tree, sharding=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
