"""Real-process fault suite for the process-backed replica pool.

Mirrors ``test_gateway_faults.py`` with the faults made *real*: the
replica is a spawned worker process (``serve/procpool.py``), a crash is
a mid-chunk ``kill -9`` the worker inflicts on itself, a hang is a
worker that stops answering its pipe, and death is detected by pipe EOF
or the process sentinel — not by an injected Python exception.  The
contract under test is unchanged: zero dropped requests, every delivered
quote at 1e-9 vs ``price_american`` (including ``max_pieces``), and the
gateway's failover metrics telling the true story.

Marked ``procpool`` (its own CI lane) and skipped where the ``spawn``
start method is unavailable.  Each test spawns 1-2 real workers; the
warmup chunk each worker prices on start keeps per-test wall time to a
few seconds of jax import + one tiny compile.
"""
import asyncio
import multiprocessing
import os

import pytest

from repro.api import price_american
from repro.serve.core import ChunkSpec, _Pending
from repro.serve.engine import PriceRequest
from repro.serve.gateway import PricingGateway
from repro.serve.procpool import ProcessReplica, ReplicaPool, warmup_chunk
from repro.serve.replica import ReplicaCrash


def _spawn_available() -> bool:
    try:
        multiprocessing.get_context("spawn")
        return True
    except ValueError:
        return False


pytestmark = [
    pytest.mark.procpool,
    pytest.mark.skipif(not _spawn_available(),
                       reason="multiprocessing spawn context unavailable"),
]

TOL = 1e-9
N_STEPS = 8
CAPACITY = 16
WARMUP = None   # built lazily: warmup_chunk imports nothing heavy, but
                # sharing one wire dict across tests keeps them honest
                # about warmup being plain data


def _warmup() -> dict:
    global WARMUP
    if WARMUP is None:
        WARMUP = warmup_chunk(n_steps=N_STEPS, capacity=CAPACITY)
    return WARMUP


def _req(s0=100.0, cost_rate=0.0, **kw):
    kw.setdefault("n_steps", N_STEPS)
    return PriceRequest(s0=s0, sigma=0.2, rate=0.1, maturity=0.25,
                        cost_rate=cost_rate, **kw)


def _mixed_requests():
    return [
        _req(s0=95.0, payoff="put", strike=100.0),
        _req(s0=105.0, payoff="bull_spread", strike=95.0),
        _req(s0=100.0, payoff="call", strike=95.0),
        _req(s0=98.0, payoff="put", strike=100.0, cost_rate=0.01),
        _req(s0=102.0, payoff="call", strike=95.0, cost_rate=0.005),
        _req(s0=100.0, payoff="put", strike=105.0, cost_rate=0.01),
    ]


def _key(req):
    return (req.s0, req.sigma, req.rate, req.maturity, req.cost_rate,
            req.payoff or "put",
            req.strike if req.strike is not None else 100.0, req.n_steps)


def _oracle_refs(reqs):
    """{scenario key: (ask, bid, max_pieces)} oracle references.

    Frictionless scenarios go through the independent single-contract
    ``price_american`` (ms each).  TC scenarios batch into ONE
    ``price_flat`` call: the single-contract rz path recompiles per
    *distinct* scenario (~10 s each on this CPU — ~50 distinct would be
    the whole test budget), while payoff-as-data batching pays one
    compile, and ``row_pieces[i]`` is exactly the single-contract
    ``max_pieces`` (rows are independent vmap lanes; batch-vs-single
    parity itself is pinned by the 108-grid oracle suite and the
    thread-pool fault tests)."""
    from repro.api import price_flat
    refs = {}
    tc_keys = sorted({_key(r) for r in reqs if r.cost_rate > 0})
    if tc_keys:
        assert len({k[7] for k in tc_keys}) == 1    # one depth per call
        cols = list(zip(*tc_keys))
        res = price_flat(s0=cols[0], sigma=cols[1], rate=cols[2],
                         maturity=cols[3], cost_rate=cols[4],
                         payoff=cols[5], strike=cols[6],
                         n_steps=tc_keys[0][7], capacity=CAPACITY)
        for i, k in enumerate(tc_keys):
            refs[k] = (float(res.ask[i]), float(res.bid[i]),
                       int(res.row_pieces[i]))
    for k in {_key(r) for r in reqs if r.cost_rate == 0}:
        ref = price_american(s0=k[0], sigma=k[1], rate=k[2], maturity=k[3],
                             cost_rate=k[4], payoff=k[5], strike=k[6],
                             n_steps=k[7], capacity=CAPACITY)
        refs[k] = (ref.ask, ref.bid, ref.max_pieces)
    return refs


def _assert_oracle_batch(reqs, quotes):
    refs = _oracle_refs(reqs)
    for req, quote in zip(reqs, quotes):
        ask, bid, pieces = refs[_key(req)]
        assert abs(quote.ask - ask) < TOL
        assert abs(quote.bid - bid) < TOL
        assert quote.max_pieces == pieces


def _one_row_chunk(s0=95.0):
    key = (s0, 0.2, 0.1, 0.25, 0.0, "put", 100.0, 110.0, N_STEPS, 1, None)
    return ChunkSpec(
        bucket=(N_STEPS, "notc"), requests=[_Pending(0, key, 0.0)],
        n_steps=N_STEPS, engine="notc", capacity=CAPACITY, backend="jnp",
        padded=1,
        cols=((s0,), (0.2,), (0.1,), (0.25,), (0.0,), ("put",),
              (100.0,), (110.0,)))


async def _submit_await_all(gw, reqs):
    rids = [await gw.submit(r) for r in reqs]
    return [await gw.result(rid) for rid in rids]


# ---------------------------------------------------------------------- #
# the replica alone
# ---------------------------------------------------------------------- #
def test_process_replica_prices_in_another_process_at_oracle():
    """The baseline: a chunk priced in a *different* pid matches the
    in-process oracle to 1e-9 (spawn + wire schema change nothing)."""
    rep = ProcessReplica("p0", warmup=_warmup())
    try:
        assert rep.pid is not None and rep.pid != os.getpid()
        res = rep.price_chunk(_one_row_chunk(s0=95.0))
        assert rep.warmup_seconds > 0.0      # warmup really priced
        ref = price_american(s0=95.0, sigma=0.2, rate=0.1, maturity=0.25,
                             n_steps=N_STEPS, capacity=CAPACITY)
        assert abs(res.ask[0] - ref.ask) < TOL
        assert abs(res.bid[0] - ref.bid) < TOL
        assert rep.alive
    finally:
        rep.close()
    assert not rep.alive


def test_hung_worker_is_sigkilled_by_the_call_deadline():
    """A worker that stops answering is killed with SIGKILL (exitcode
    -9) once the per-call deadline lapses, and the crash says so."""
    rep = ProcessReplica("hangy", warmup=_warmup(), faults={0: "hang"},
                         call_timeout_s=1.0)
    try:
        with pytest.raises(ReplicaCrash, match="SIGKILL"):
            rep.price_chunk(_one_row_chunk())
        assert rep._proc.exitcode == -9
        # dead stays dead: the pool factory, not this object, respawns
        with pytest.raises(ReplicaCrash, match="dead"):
            rep.price_chunk(_one_row_chunk())
    finally:
        rep.close()


def test_worker_that_never_acks_the_warmup_is_killed():
    rep = ProcessReplica("mute", warmup=_warmup(), hang_warmup=True,
                         warmup_timeout_s=1.0)
    try:
        with pytest.raises(ReplicaCrash, match="warmup"):
            rep.price_chunk(_one_row_chunk())
        assert rep._proc.exitcode == -9
    finally:
        rep.close()


def test_pipe_eof_on_result_read_is_a_crash():
    rep = ProcessReplica("eof", warmup=_warmup(), faults={0: "exit"})
    try:
        with pytest.raises(ReplicaCrash, match="EOF|exited"):
            rep.price_chunk(_one_row_chunk())
    finally:
        rep.close()


# ---------------------------------------------------------------------- #
# behind the gateway: the failover machinery on real processes
# ---------------------------------------------------------------------- #
def test_sigkill_mid_chunk_fails_over_zero_dropped():
    """The headline: replica-0's worker is SIGKILLed *while pricing*;
    the chunk requeues to the surviving process and 100% of quotes
    arrive at 1e-9 — the thread-pool contract, now against kill -9."""
    wu = _warmup()

    def factory(i):
        return ProcessReplica(f"proc-{i}", warmup=wu,
                              faults={0: "sigkill"} if i == 0 else None)

    async def main():
        async with PricingGateway(
                replicas=[factory(0), factory(1)], max_batch=4,
                deadline_ms=2.0, capacity=CAPACITY,
                default_n_steps=N_STEPS, retry_backoff_s=0.01,
                result_cache_size=0) as gw:
            reqs = _mixed_requests()
            quotes = await _submit_await_all(gw, reqs)
            return reqs, quotes, gw.metrics(), gw.replica_states()

    reqs, quotes, m, states = asyncio.run(main())
    _assert_oracle_batch(reqs, quotes)
    assert m["completed"] == m["requests"] == len(reqs)
    assert m["failed"] == 0
    assert m["replica_crashes"] == 1
    assert m["requeues"] >= 1
    assert m["healthy_replicas"] == 1
    dead = [s for s in states if not s["healthy"]]
    assert [s["dead_reason"] for s in dead] == ["crashed"]


def test_pipe_eof_behind_gateway_fails_over():
    wu = _warmup()
    replicas = [ProcessReplica("proc-0", warmup=wu, faults={0: "exit"}),
                ProcessReplica("proc-1", warmup=wu)]

    async def main():
        async with PricingGateway(
                replicas=replicas, max_batch=4, deadline_ms=2.0,
                capacity=CAPACITY, default_n_steps=N_STEPS,
                retry_backoff_s=0.01, result_cache_size=0) as gw:
            reqs = _mixed_requests()[:3]     # one frictionless bucket
            quotes = await _submit_await_all(gw, reqs)
            return reqs, quotes, gw.metrics()

    reqs, quotes, m = asyncio.run(main())
    _assert_oracle_batch(reqs, quotes)
    assert m["failed"] == 0 and m["completed"] == len(reqs)
    assert m["replica_crashes"] == 1


def test_restart_respawns_a_fresh_process():
    """restart_s + the pool factory: the SIGKILLed worker is replaced by
    a brand-new process (fresh pid) that prices the waiting chunk."""
    wu = _warmup()
    pool = ReplicaPool("process", warmup=wu)
    first = ProcessReplica("replica-0", warmup=wu, faults={0: "sigkill"})
    first_pid = first.pid

    async def main():
        async with PricingGateway(
                replicas=[first], max_batch=4, deadline_ms=2.0,
                capacity=CAPACITY, default_n_steps=N_STEPS,
                retry_backoff_s=0.01, restart_s=0.05,
                replica_factory=pool.factory,
                result_cache_size=0) as gw:
            reqs = [_req(s0=96.0), _req(s0=104.0, payoff="call",
                                        strike=95.0)]
            quotes = await _submit_await_all(gw, reqs)
            pids = [getattr(s.replica, "pid", None) for s in gw._slots]
            return reqs, quotes, gw.metrics(), pids

    reqs, quotes, m, pids = asyncio.run(main())
    _assert_oracle_batch(reqs, quotes)
    assert m["replica_crashes"] == 1
    assert m["replica_restarts"] == 1
    assert m["failed"] == 0
    assert pids[0] is not None and pids[0] != first_pid


@pytest.mark.slow
def test_thousand_request_trace_survives_sigkill_mid_flight():
    """The acceptance criterion: 2 process replicas replay the
    1k-request mixed trace while replica-0's worker takes a real
    mid-chunk SIGKILL — zero dropped requests, every quote at 1e-9."""
    from repro.launch.serve_pricing import drive_gateway, synth_trace
    trace = synth_trace(1000, n_steps=(N_STEPS,), tc_fraction=0.05, seed=7)
    # deadline_ms is generous on purpose: the replay submits the whole
    # trace at once, so a tight deadline flushes early partial buckets
    # at every pow-2 size and each fresh worker pays a compile per
    # shape.  100 ms lets buckets fill to max_batch first — one shape
    # per engine per worker, which is what a warm deployment looks like
    # (deadline *timing* is pinned by test_gateway_deadline.py).
    quotes, m = drive_gateway(
        trace, replicas=2, crash_at=2, max_batch=64, deadline_ms=100.0,
        capacity=CAPACITY, backend="jnp", n_steps=N_STEPS,
        restart_s=0.5, pool_kind="process")
    assert m["completed"] == m["requests"] == len(trace)
    assert m["failed"] == 0
    assert m["replica_crashes"] == 1
    by_rid = [quotes[rid] for rid in sorted(quotes)]
    _assert_oracle_batch(trace, by_rid)
