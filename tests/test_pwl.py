"""PWL algebra: exact oracle unit tests + JAX fixed-capacity vs oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pwl_ref as R
from repro.core import pwl as P


def test_worked_example_seller_ask_50():
    """Paper §3 one-step example: z_t(0) = 50."""
    r = 1.18
    z_u = R.expense_function(130.0, -1.0, 144.0, 96.0)
    z_d = R.expense_function(130.0, -1.0, 100.0, 200.0 / 3.0)
    w = R.pwl_max(z_u, z_d).scale(1.0 / r)
    v = R.cone_infconv(w, 120.0, 80.0)
    u_t = R.expense_function(130.0, -1.0, 120.0, 80.0)
    z = R.pwl_max(u_t, v)
    assert z(0.0) == pytest.approx(50.0, abs=1e-12)
    # eq. (5): z_t = u_t everywhere (the example's claim)
    ys = np.linspace(-3, 3, 61)
    np.testing.assert_allclose(z(ys), u_t(ys), rtol=1e-12)


def test_worked_example_buyer_bid_10():
    """Paper §3 / eq. (7): -z_t(0) = 10."""
    r = 1.18
    z_u = R.expense_function(-130.0, 1.0, 144.0, 96.0)
    z_d = R.expense_function(-130.0, 1.0, 100.0, 200.0 / 3.0)
    w = R.pwl_max(z_u, z_d).scale(1.0 / r)
    v = R.cone_infconv(w, 120.0, 80.0)
    u_t = R.expense_function(-130.0, 1.0, 120.0, 80.0)
    z = R.pwl_min(u_t, v)
    assert -z(0.0) == pytest.approx(10.0, abs=1e-12)


def _random_ref(rng, max_m=6):
    m = int(rng.integers(1, max_m + 1))
    xs = np.sort(rng.normal(0, 2, m)) + np.arange(m) * 0.05
    ys = rng.normal(0, 50, m)
    sl = rng.uniform(-150, -50)
    sr = rng.uniform(-100, -10)
    return R.PWLRef(xs, ys, sl, sr)


def _slopes(ref):
    out = [ref.s_left, ref.s_right]
    for j in range(ref.m - 1):
        out.append((ref.ys[j + 1] - ref.ys[j]) / (ref.xs[j + 1] - ref.xs[j]))
    return np.asarray(out)


def _well_conditioned_pair(rng, min_gap):
    """Draw (f, g) whose cross-function slope gaps all exceed ``min_gap``.

    An envelope crossing between segments of slopes s_f, s_g sits at an
    abscissa computed by dividing a value difference by (s_f - s_g); at
    float32 a gap of ~1e-2 on slopes of magnitude ~100 pushes the
    intersection error past O(1) in x (tens in value) — an inherent
    conditioning limit of the dtype, not an algebra bug.  float64 passes
    unconditioned draws (min_gap=0), so the rejection only shapes the
    float32 sample.
    """
    while True:
        f, g = _random_ref(rng), _random_ref(rng)
        if min_gap == 0.0:
            return f, g
        gap = np.min(np.abs(_slopes(f)[:, None] - _slopes(g)[None, :]))
        if gap >= min_gap:
            return f, g


# Per-dtype tolerances against the float64 numpy oracle.  float64 runs
# the same algebra as the oracle, so 1e-8 is slack; float32 is the
# compiled GPU/TPU dtype — knot abscissae come out of envelope
# intersections (a divide by a slope difference) with ~eps_f32 relative
# noise that the steep test slopes (|s| up to 150 on values O(10^3))
# amplify to ~1e-2 absolute near crossing points, so float32 draws are
# additionally conditioned (``min_gap``) to keep those crossings
# resolvable at all — see ``_well_conditioned_pair``.
DTYPE_TOL = [(jnp.float64, dict(rtol=1e-8, atol=1e-8), 0.0),
             (jnp.float32, dict(rtol=1e-4, atol=5e-2), 1.0)]
_DTYPE_IDS = ["float64", "float32"]


@pytest.mark.parametrize("dtype,tol,min_gap", DTYPE_TOL, ids=_DTYPE_IDS)
@pytest.mark.parametrize("take_max", [True, False])
def test_envelope_matches_oracle(rng, take_max, dtype, tol, min_gap):
    K = 16
    ysq = jnp.linspace(-8.0, 8.0, 101)
    for _ in range(60):
        f, g = _well_conditioned_pair(rng, min_gap)
        ref = (R.pwl_max if take_max else R.pwl_min)(f, g)
        h, _ = P.envelope2(P.from_ref(f, K, dtype), P.from_ref(g, K, dtype),
                           K, take_max)
        assert h.xs.dtype == dtype
        got = np.asarray(jax.vmap(lambda c, h=h: P.eval_at(h, c))(ysq))
        np.testing.assert_allclose(got, ref(np.asarray(ysq)), **tol)


@pytest.mark.parametrize("dtype,tol,min_gap", DTYPE_TOL, ids=_DTYPE_IDS)
def test_cone_matches_oracle(rng, dtype, tol, min_gap):
    K = 16
    ysq = jnp.linspace(-8.0, 8.0, 101)
    for _ in range(60):
        f = _random_ref(rng)
        a = float(rng.uniform(80, 140))
        b = float(rng.uniform(20, 70))
        f.s_left = min(f.s_left, -b - 1.0)
        f.s_right = max(f.s_right, -a)
        ref = R.cone_infconv(f, a, b)
        v, _ = P.cone_infconv(P.from_ref(f, K, dtype), a, b, K)
        assert v.xs.dtype == dtype
        got = np.asarray(jax.vmap(lambda c, v=v: P.eval_at(v, c))(ysq))
        np.testing.assert_allclose(got, ref(np.asarray(ysq)), **tol)


@pytest.mark.parametrize("dtype,tol,min_gap", DTYPE_TOL, ids=_DTYPE_IDS)
def test_cone_equal_ask_bid_degenerates_to_affine(rng, dtype, tol, min_gap):
    f = _random_ref(rng)
    a = 100.0
    f.s_left = min(f.s_left, -a)
    f.s_right = max(f.s_right, -a)
    ref = R.cone_infconv(f, a, a)
    assert ref.m == 1 and ref.s_left == pytest.approx(ref.s_right)
    # the fixed-capacity path must degenerate identically at both dtypes
    v, _ = P.cone_infconv(P.from_ref(f, 16, dtype), a, a, 16)
    ysq = jnp.linspace(-8.0, 8.0, 101)
    got = np.asarray(jax.vmap(lambda c: P.eval_at(v, c))(ysq))
    np.testing.assert_allclose(got, ref(np.asarray(ysq)), **tol)


def test_compress_idempotent(rng):
    for _ in range(20):
        f = _random_ref(rng)
        c1 = f.compress()
        c2 = c1.compress()
        assert c1.m == c2.m
        ys = np.linspace(-5, 5, 51)
        np.testing.assert_allclose(c1(ys), f(ys), rtol=1e-9)


def test_expense_function_shape():
    u = R.expense_function(130.0, -1.0, 120.0, 80.0)
    # u(y) = 130 + (y+1)^- *120 - (y+1)^+ *80  (paper eq. (1) example)
    assert u(-1.0) == pytest.approx(130.0)
    assert u(0.0) == pytest.approx(50.0)
    assert u(-2.0) == pytest.approx(250.0)
