"""CI tooling: the bench-regression gate and the benchmark registry.

``tools/check_bench.py`` is the PR lane's perf ratchet: these tests pin
its gating semantics (tolerance band, ratio-only fallback on config
mismatch, fail-on-missing) with synthetic reports, plus the
``benchmarks.run`` registry surface (``--list``, module-name aliases,
unknown-name fail-fast) that the satellite bugfix added.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

from tools.check_bench import check  # noqa: E402


def _rz_report(cps_jnp=1.0, cps_pallas=1.8, ratio=1.8, n_steps=96):
    return {
        "bench": "rz_grid_backends", "n_steps": n_steps, "contracts": 2,
        "capacity": 24, "repeats": 1, "levels": None, "block": None,
        "interpret": True, "device": "cpu",
        "jnp": {"seconds": 1.0, "contracts_per_sec": cps_jnp},
        "pallas": {"seconds": 1.0, "contracts_per_sec": cps_pallas},
        "pallas_over_jnp": ratio,
    }


def _serve_report(cps=20000.0, speedup=40.0):
    return {
        "bench": "serve_scheduler_vs_per_request", "requests": 1000,
        "max_batch": 64, "n_steps": [16, 24], "tc_fraction": 0.0,
        "capacity": 16, "seed": 0, "device": "cpu",
        "scheduler": {"seconds": 0.05, "contracts_per_sec": cps},
        "baseline": {"seconds": 1.8, "contracts_per_sec": 550.0},
        "speedup": speedup, "speedup_nocache": 6.0,
    }


def test_gate_passes_within_tolerance():
    assert check(_rz_report(cps_pallas=1.5), _rz_report(), tol=0.25) == []
    # improvements never fail
    assert check(_rz_report(cps_pallas=9.9, ratio=9.0), _rz_report(),
                 tol=0.25) == []


def test_gate_fails_beyond_25_percent():
    fails = check(_rz_report(cps_jnp=0.5, cps_pallas=1.2, ratio=2.4),
                  _rz_report(), tol=0.25)
    assert len(fails) == 2          # both backends regressed > 25%
    assert any("jnp.contracts_per_sec" in f for f in fails)
    assert any("pallas.contracts_per_sec" in f for f in fails)
    # boundary: exactly at the floor passes
    assert check(_rz_report(cps_jnp=0.75), _rz_report(), tol=0.25) == []


def test_config_mismatch_gates_ratios_only():
    """The nightly lane (N=512) against the PR-lane baseline (N=96):
    machine-dependent contracts/sec must NOT gate, the dimensionless
    pallas/jnp ratio must."""
    nightly = _rz_report(cps_jnp=0.01, cps_pallas=0.02, ratio=1.7,
                         n_steps=512)
    assert check(nightly, _rz_report(), tol=0.25) == []
    nightly_bad = _rz_report(cps_jnp=0.01, cps_pallas=0.012, ratio=1.2,
                             n_steps=512)
    fails = check(nightly_bad, _rz_report(ratio=1.8), tol=0.25)
    assert len(fails) == 1 and "pallas_over_jnp" in fails[0]


def test_serve_gate_and_wrong_baseline():
    assert check(_serve_report(), _serve_report(), tol=0.25) == []
    fails = check(_serve_report(cps=1000.0, speedup=2.0), _serve_report(),
                  tol=0.25)
    assert any("scheduler.contracts_per_sec" in f for f in fails)
    assert any("speedup" in f for f in fails)
    # rz fresh vs serve baseline: one clear failure, not a KeyError
    fails = check(_rz_report(), _serve_report(), tol=0.25)
    assert len(fails) == 1 and "wrong baseline" in fails[0]


def _pwl_report(env_ops=20000.0, cone_ops=10000.0, step_ops=5000.0):
    return {
        "bench": "pwl_envelope_ops", "lanes": 514, "capacity": 24,
        "repeats": 30, "device": "cpu",
        "envelope": {"seconds": 0.02, "ops_per_sec": env_ops},
        "cone": {"seconds": 0.05, "ops_per_sec": cone_ops},
        "level_step": {"seconds": 0.1, "ops_per_sec": step_ops},
    }


def test_pwl_bench_gate():
    assert check(_pwl_report(), _pwl_report(), tol=0.25) == []
    fails = check(_pwl_report(env_ops=1000.0), _pwl_report(), tol=0.25)
    assert len(fails) == 1 and "envelope.ops_per_sec" in fails[0]


def _matrix_cell(op="envelope2", backend="jnp", platform="cpu",
                 flops_rate=5e9, bytes_rate=6e9):
    return {"op": op, "backend": backend, "platform": platform,
            "dtype": "float64", "flops": 1e8, "bytes": 1.2e8,
            "seconds": 0.02, "achieved_flops_per_sec": flops_rate,
            "frac_peak_flops": flops_rate / 24e9,
            "achieved_bytes_per_sec": bytes_rate,
            "frac_peak_bw": bytes_rate / 20e9,
            "intensity_flops_per_byte": 0.83, "bound": "memory"}


def _with_matrix(report, cells):
    report["roofline"] = {"matrix": cells}
    return report


def test_matrix_cells_gate_like_throughput():
    base = _with_matrix(_pwl_report(), [_matrix_cell()])
    ok = _with_matrix(_pwl_report(), [_matrix_cell(flops_rate=4.5e9)])
    assert check(ok, base, tol=0.25) == []
    slow = _with_matrix(_pwl_report(), [_matrix_cell(flops_rate=1e9)])
    fails = check(slow, base, tol=0.25)
    assert len(fails) == 1
    assert "roofline[envelope2/jnp/cpu/float64]" in fails[0]
    assert "achieved_flops_per_sec" in fails[0]


def test_matrix_missing_same_platform_cell_fails():
    base = _with_matrix(_pwl_report(), [_matrix_cell(),
                                        _matrix_cell(op="cone_infconv")])
    fresh = _with_matrix(_pwl_report(), [_matrix_cell()])
    fails = check(fresh, base, tol=0.25)
    assert len(fails) == 1 and "cone_infconv" in fails[0]
    assert "missing" in fails[0]


def test_matrix_other_platform_cells_are_skipped():
    """The CPU lane must not fail the GPU/TPU columns of the matrix."""
    base = _with_matrix(_pwl_report(), [
        _matrix_cell(),
        _matrix_cell(platform="gpu", flops_rate=5e12),
        _matrix_cell(platform="tpu", flops_rate=9e13)])
    fresh = _with_matrix(_pwl_report(), [_matrix_cell()])
    assert check(fresh, base, tol=0.25) == []


def test_matrix_not_gated_on_config_mismatch():
    """Machine-dependent cells follow the throughput rule: a different
    bench config (deeper tree) gates ratios only, never the matrix."""
    base = _with_matrix(_pwl_report(), [_matrix_cell()])
    fresh = _with_matrix(_pwl_report(), [_matrix_cell(flops_rate=1e8)])
    fresh["lanes"] = 9999
    assert check(fresh, base, tol=0.25) == []


def test_matrix_absent_from_old_baseline_is_tolerated():
    """A fresh artifact with a matrix gates fine against a pre-matrix
    baseline (rollout path: baseline refresh starts the gating)."""
    fresh = _with_matrix(_pwl_report(), [_matrix_cell()])
    assert check(fresh, _pwl_report(), tol=0.25) == []


def test_non_finite_metrics_are_rejected():
    """Infinity/NaN in either file must fail the gate, never be compared:
    a ratio against inf passes every tolerance band silently (this is the
    pre-fix ``ServiceMetrics.snapshot()`` artifact bug)."""
    inf_fresh = _rz_report()
    inf_fresh["pallas"]["contracts_per_sec"] = float("inf")
    fails = check(inf_fresh, _rz_report(), tol=0.25)
    assert any("pallas.contracts_per_sec" in f and "not a finite number" in f
               for f in fails)
    # a fresh value gated against an inf baseline would always "pass"
    inf_base = _rz_report()
    inf_base["pallas"]["contracts_per_sec"] = float("inf")
    fails = check(_rz_report(), inf_base, tol=0.25)
    assert any("baseline" in f and "regenerate" in f for f in fails)
    nan_fresh = _rz_report(ratio=float("nan"))
    fails = check(nan_fresh, _rz_report(), tol=0.25)
    assert any("pallas_over_jnp" in f for f in fails)
    # the exact artifact path: json round-trips Infinity by default, the
    # gate must still catch it after loading
    loaded = json.loads(json.dumps(inf_fresh))
    assert loaded["pallas"]["contracts_per_sec"] == float("inf")
    assert check(loaded, _rz_report(), tol=0.25) != []
    # strings and None are equally not comparable metrics
    str_fresh = _rz_report()
    str_fresh["jnp"]["contracts_per_sec"] = "fast"
    fails = check(str_fresh, _rz_report(), tol=0.25)
    assert any("jnp.contracts_per_sec" in f for f in fails)


def test_cli_exit_codes(tmp_path):
    fresh, base = tmp_path / "fresh.json", tmp_path / "base.json"
    fresh.write_text(json.dumps(_rz_report()))
    base.write_text(json.dumps(_rz_report()))
    cmd = [sys.executable, str(ROOT / "tools" / "check_bench.py")]
    ok = subprocess.run(cmd + ["--fresh", str(fresh), "--baseline",
                               str(base)], capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    fresh.write_text(json.dumps(_rz_report(cps_pallas=0.5, ratio=0.5)))
    bad = subprocess.run(cmd + ["--fresh", str(fresh), "--baseline",
                                str(base)], capture_output=True, text=True)
    assert bad.returncode == 1
    assert "BENCH REGRESSION" in bad.stdout
    missing = subprocess.run(cmd + ["--fresh", str(tmp_path / "no.json"),
                                    "--baseline", str(base)],
                             capture_output=True, text=True)
    assert missing.returncode == 1
    # --write-baseline seeds/refreshes instead of gating
    seed = subprocess.run(cmd + ["--fresh", str(fresh), "--baseline",
                                 str(tmp_path / "new" / "b.json"),
                                 "--write-baseline"],
                          capture_output=True, text=True)
    assert seed.returncode == 0
    assert json.loads((tmp_path / "new" / "b.json").read_text())["bench"] \
        == "rz_grid_backends"


def test_committed_baselines_match_ci_lane_configs():
    """The repo must ship baselines for exactly what the CI bench jobs
    produce (bench kind + PR-lane config), else the gate dry-rots."""
    base_dir = ROOT / "benchmarks" / "baselines"
    rz = json.loads((base_dir / "BENCH_rz.json").read_text())
    assert rz["bench"] == "rz_grid_backends"
    assert rz["n_steps"] == 96          # the PR-lane canary depth
    # since the jnp backend walks the same §4.2 re-balanced round plan as
    # the kernel, the two backends are ~at parity on CPU (the kernel's
    # remaining value is the TPU-ready block structure): the ratio is a
    # drift canary around 1, no longer a banked Pallas win
    assert 0.7 < rz["pallas_over_jnp"] < 1.5
    serve = json.loads((base_dir / "BENCH_serve.json").read_text())
    assert serve["bench"] == "serve_scheduler_vs_per_request"
    assert serve["requests"] == 1000
    assert serve["speedup"] > 2.0
    pwl = json.loads((base_dir / "BENCH_pwl.json").read_text())
    assert pwl["bench"] == "pwl_envelope_ops"
    assert pwl["lanes"] == 514          # node-axis width of the N=512 tree
    for metric in ("envelope", "cone", "level_step"):
        assert pwl[metric]["ops_per_sec"] > 0
    # both bench baselines must carry the roofline matrix (per-backend /
    # per-platform achieved-vs-peak cells) and the platform stamp, so
    # the matrix gate is armed, not dormant
    for rep, ops in ((rz, {("rz_grid", "jnp"), ("rz_grid", "pallas")}),
                     (pwl, {("envelope2", "jnp"), ("cone_infconv", "jnp"),
                            ("level_step", "jnp")})):
        assert rep["platform"]["platform"] in ("cpu", "gpu", "tpu")
        cells = rep["roofline"]["matrix"]
        assert {(c["op"], c["backend"]) for c in cells} == ops
        for c in cells:
            assert c["achieved_flops_per_sec"] > 0
            assert c["achieved_bytes_per_sec"] > 0
            assert 0 < c["frac_peak_flops"] <= 1.0
            assert c["bound"] in ("compute", "memory")


# --------------------------------------------------------------------- #
# benchmarks.run registry (the silently-skipped-bench bugfix)
# --------------------------------------------------------------------- #
def test_benchmarks_run_list_registers_newest_benches():
    """--list must name every bench, including rz_pallas and serve (the
    two the umbrella runner used to skip), without importing jax."""
    r = subprocess.run([sys.executable, "-m", "benchmarks.run", "--list"],
                       capture_output=True, text=True, cwd=ROOT, timeout=60)
    assert r.returncode == 0, r.stdout + r.stderr
    for name in ("table1", "grid", "rz_pallas", "serve", "pwl"):
        assert name in r.stdout, f"{name} missing from --list"
    assert "bench_rz_pallas" in r.stdout and "bench_serve" in r.stdout
    assert "bench_pwl" in r.stdout


def test_benchmarks_run_aliases_and_unknown():
    from benchmarks.run import resolve
    assert resolve("serve") == "serve"
    assert resolve("bench_serve") == "serve"
    assert resolve("bench_rz_pallas") == "rz_pallas"
    assert resolve("bench_pwl") == "pwl"
    with pytest.raises(SystemExit, match="unknown bench"):
        resolve("nope")
