"""Cross-engine conformance matrix: engine x backend x mesh width.

The contract under test: ``engine="auto"`` is a *router*, not a fourth
engine — for every lattice bucket it must be bit-equal to the explicit
engine it routes to, across backends and mesh widths, and multi-asset /
Bermudan contracts must land on the ``lsmc`` Monte Carlo engine.  The
same guarantee is asserted through every entry point: ``api.price_grid``,
``PricingService`` (continuous batching) and the raw ``ChunkSpec`` /
``execute_chunk`` path the gateway replicas use.

shard-marked: under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the CI shard lane) the ``devices=2/8`` cells exercise the real
``shard_map`` path; on one device they run the bit-identical simulated
layout (docs/KNOWN_ISSUES.md).
"""
import numpy as np
import pytest

from repro.api import price_flat, price_grid
from repro.scenarios import (ScenarioGrid, price_grid_lsmc, price_grid_notc,
                             price_grid_rz)
from repro.serve.core import ChunkSpec, SchedulerCore, execute_chunk
from repro.serve.engine import GridRequest, PriceRequest
from repro.serve.scheduler import PricingService

pytestmark = pytest.mark.shard

BACKENDS = ("jnp", "pallas")
MESHES = (None, 2, 8)      # None = plain jit; 2/8 = (simulated) mesh widths

AXES = dict(s0=(90.0, 100.0, 110.0), sigma=(0.15, 0.25), rate=0.1,
            maturity=0.25, strike=100.0, payoff="put", n_steps=16)


def _bit_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------ lattice buckets: auto==explicit

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("devices", MESHES)
def test_auto_bit_equal_to_rz_lattice(backend, devices):
    kw = dict(cost_rate=(0.0, 0.01), capacity=24, backend=backend,
              devices=devices, **AXES)
    auto = price_grid(engine="auto", **kw)
    explicit = price_grid(engine="rz", **kw)
    assert auto.engine == "rz"
    _bit_equal(auto.ask, explicit.ask)
    _bit_equal(auto.bid, explicit.bid)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("devices", MESHES)
def test_auto_bit_equal_to_notc_lattice(backend, devices):
    kw = dict(cost_rate=0.0, backend=backend, devices=devices, **AXES)
    auto = price_grid(engine="auto", **kw)
    explicit = price_grid(engine="notc", **kw)
    assert auto.engine == "notc"
    _bit_equal(auto.ask, explicit.ask)
    assert auto.stderr is None


@pytest.mark.parametrize("devices", MESHES)
def test_auto_routes_mc_contracts_to_lsmc(devices):
    for kw in (dict(n_assets=2), dict(exercise_steps=(8, 16))):
        res = price_grid(engine="auto", n_paths=512, seed=0,
                         devices=devices, **AXES, **kw)
        assert res.engine == "lsmc"
        assert res.stderr is not None and np.all(res.stderr > 0.0)
        grid = ScenarioGrid.cartesian(**AXES, **kw)
        explicit = price_grid_lsmc(grid, n_paths=512, seed=0,
                                   devices=devices)
        _bit_equal(res.ask, explicit.ask)
        _bit_equal(res.stderr, explicit.stderr)


@pytest.mark.parametrize("devices", MESHES)
def test_lsmc_mesh_width_invariance(devices):
    """Shard layout must not change MC draws: every mesh width bit-equal
    to the single-device result (keys are per-row data)."""
    grid = ScenarioGrid.cartesian(n_assets=2, **AXES)
    base = price_grid_lsmc(grid, n_paths=512, seed=0)
    res = price_grid_lsmc(grid, n_paths=512, seed=0, devices=devices)
    _bit_equal(base.ask, res.ask)
    _bit_equal(base.stderr, res.stderr)


# ------------------------------------------------------- service path

def _mixed_requests():
    return [
        PriceRequest(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                     cost_rate=0.0),
        PriceRequest(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                     cost_rate=0.01),
        PriceRequest(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                     cost_rate=0.0, n_assets=3),
        PriceRequest(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                     cost_rate=0.0, exercise_steps=(4, 8)),
    ]


def test_service_buckets_split_by_engine():
    svc = PricingService(max_batch=8, default_n_steps=8, n_paths=512,
                        mc_seed=5)
    rids = [svc.submit(r) for r in _mixed_requests()]
    svc.flush()
    quotes = [svc.result(r) for r in rids]
    assert all(q is not None for q in quotes)
    assert svc.metrics()["engine_batches"] == {
        "notc": 1, "rz": 1, "lsmc": 2}
    # MC quotes carry a standard error; lattice quotes report 0
    assert quotes[0].stderr == 0.0 and quotes[1].stderr == 0.0
    assert quotes[2].stderr > 0.0 and quotes[3].stderr > 0.0


def test_service_lsmc_quote_bit_equal_to_explicit():
    svc = PricingService(max_batch=8, default_n_steps=8, n_paths=512,
                        mc_seed=5)
    rid = svc.submit(PriceRequest(s0=100.0, sigma=0.2, rate=0.1,
                                  maturity=0.25, cost_rate=0.0,
                                  exercise_steps=(4, 8)))
    svc.flush()
    q = svc.result(rid)
    ref = price_flat(s0=(100.0,), sigma=0.2, rate=0.1, maturity=0.25,
                     cost_rate=0.0, strike=100.0, n_steps=8,
                     exercise_steps=(4, 8), engine="lsmc", n_paths=512,
                     seed=5)
    assert q.ask == float(np.asarray(ref.ask).ravel()[0])
    assert q.stderr == float(np.asarray(ref.stderr).ravel()[0])


def test_service_grid_request_routes_to_lsmc():
    svc = PricingService(max_batch=8, default_n_steps=8, n_paths=512)
    res = svc.price_grid(GridRequest(s0=(95.0, 105.0), n_steps=8,
                                     n_assets=2))
    assert res.engine == "lsmc"
    assert res.stderr is not None and res.stderr.shape == res.ask.shape
    explicit = price_grid_lsmc(
        ScenarioGrid.cartesian(s0=(95.0, 105.0), n_steps=8, n_assets=2),
        n_paths=512, seed=0)
    _bit_equal(res.ask, explicit.ask)


# ------------------------------------------- gateway ChunkSpec executor path

def test_execute_chunk_lsmc_matches_scenarios_path():
    core = SchedulerCore(max_batch=8, default_n_steps=8, n_paths=512,
                         mc_seed=9)
    for r in _mixed_requests():
        core.submit(r)
    chunks = [core.take_chunk(b) for b in list(core.buckets)]
    lsmc_chunks = [c for c in chunks if c.engine == "lsmc"]
    assert len(lsmc_chunks) == 2
    for chunk in lsmc_chunks:
        assert chunk.n_paths == 512 and chunk.mc_seed == 9
        res = execute_chunk(chunk)       # the replica executor
        assert np.all(res.stderr[:chunk.n] > 0.0)
        grid = ScenarioGrid.explicit(
            s0=np.asarray(chunk.cols[0]), sigma=np.asarray(chunk.cols[1]),
            rate=np.asarray(chunk.cols[2]),
            maturity=np.asarray(chunk.cols[3]),
            cost_rate=np.asarray(chunk.cols[4]),
            payoff=tuple(chunk.cols[5]),
            strike=np.asarray(chunk.cols[6]),
            strike2=np.asarray(chunk.cols[7]), n_steps=chunk.n_steps,
            n_assets=chunk.n_assets, exercise_steps=chunk.exercise_steps)
        ref = price_grid_lsmc(grid.pad_to(chunk.padded), n_paths=512,
                              seed=9)
        _bit_equal(res.ask, ref.ask.ravel())
        _bit_equal(res.stderr, ref.stderr.ravel())


def test_bucket_keys_never_collide_across_engines():
    """Regression for the tentpole bugfix: an lsmc request must never
    coalesce into a lattice bucket of the same depth (pre-fix the bucket
    key was ``(n_steps, bool(tc))`` and a frictionless Bermudan request
    landed in the notc bucket)."""
    core = SchedulerCore(max_batch=64, default_n_steps=8)
    for r in _mixed_requests():
        core.submit(r)
    buckets = list(core.buckets)
    assert len(buckets) == 4
    lattice = {b for b in buckets if b[1] in ("notc", "rz")}
    mc = {b for b in buckets if b[1] == "lsmc"}
    assert len(lattice) == 2 and len(mc) == 2
    assert all(len(b) == 2 for b in lattice)
    # MC buckets carry the contract shape: same depth, distinct buckets
    assert {b[0] for b in mc} == {8}
    assert len({b[2:] for b in mc}) == 2
    # distinct compile keys too (engine + MC extras are key components)
    core2 = SchedulerCore(max_batch=64, default_n_steps=8)
    core2.compile_key_seen(8, 8, "notc", False)
    core2.compile_key_seen(8, 8, "lsmc", False, extra=(4096, 1, (4, 8)))
    core2.compile_key_seen(8, 8, "lsmc", False, extra=(4096, 1, (4, 8)))
    m = core2.metrics_.snapshot()
    assert m["compile_misses"] == 2 and m["compile_hits"] == 1
