"""Seeded-statistics helpers for the Monte Carlo test layer.

Every MC test in this repo runs under *fixed* PRNG seeds — the lsmc
engine derives per-row keys from ``fold_in(PRNGKey(seed), row)`` so a
given (seed, row, schedule, paths) tuple prices bit-identically across
runs, mesh sizes and the service path.  That makes statistical asserts
deterministic in CI: the k-standard-error bound below either always
holds or never does for a given seed, so a pass is reproducible and a
tolerance bump is an explicit, reviewed decision.

``assert_within_se`` accepts an ``extra`` absolute allowance for known
deterministic bias between the two estimators being compared — for the
LSMC-vs-lattice locks that is the CRR-binomial-vs-exact-GBM
discretisation gap, which shrinks like 1/n_steps and is *not* covered
by the MC standard error.
"""
import math

import numpy as np

__all__ = ["assert_within_se", "bs_put", "rmse"]


def assert_within_se(value, target, se, *, k=3.0, extra=0.0, label=""):
    """Assert ``|value - target| <= k * se + extra`` with a readable
    failure message quoting the gap in standard-error units."""
    value, target, se = float(value), float(target), float(se)
    if not math.isfinite(value):
        raise AssertionError(f"{label or 'value'} is not finite: {value}")
    if se < 0.0:
        raise AssertionError(f"{label or 'value'}: negative stderr {se}")
    gap = abs(value - target)
    bound = k * se + extra
    if gap > bound:
        units = gap / se if se > 0.0 else math.inf
        raise AssertionError(
            f"{label or 'value'}: |{value:.6f} - {target:.6f}| = {gap:.6f} "
            f"exceeds {k:g}*SE + {extra:g} = {bound:.6f} "
            f"(gap = {units:.2f} SE)")


def bs_put(s0, strike, rate, sigma, maturity):
    """Black–Scholes European put (closed form, ``math.erf`` only)."""
    if maturity <= 0.0 or sigma <= 0.0:
        return max(strike * math.exp(-rate * max(maturity, 0.0)) - s0, 0.0)
    v = sigma * math.sqrt(maturity)
    d1 = (math.log(s0 / strike) + (rate + 0.5 * sigma * sigma) * maturity) / v
    d2 = d1 - v

    def cdf(x):
        return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))

    return strike * math.exp(-rate * maturity) * cdf(-d2) - s0 * cdf(-d1)


def rmse(values, target):
    """Root-mean-square error of a sample of estimates vs a scalar."""
    v = np.asarray(values, dtype=float)
    return float(np.sqrt(np.mean((v - float(target)) ** 2)))
