"""Property-based tests (hypothesis) for the PWL algebra invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pwl_ref as R

_settings = settings(max_examples=60, deadline=None)


def _pwl(xs, ys, sl, sr):
    xs = np.sort(np.asarray(xs)) + np.arange(len(xs)) * 1e-3
    return R.PWLRef(xs, np.asarray(ys), sl, sr)


knots = st.integers(1, 5).flatmap(
    lambda m: st.tuples(
        st.lists(st.floats(-5, 5), min_size=m, max_size=m),
        st.lists(st.floats(-100, 100), min_size=m, max_size=m)))
end_slopes = st.tuples(st.floats(-150, -60), st.floats(-50, -5))


@given(knots, knots, end_slopes, end_slopes)
@_settings
def test_max_dominates_both(kf, kg, ef, eg):
    f = _pwl(kf[0], kf[1], *ef)
    g = _pwl(kg[0], kg[1], *eg)
    h = R.pwl_max(f, g)
    ys = np.linspace(-8, 8, 81)
    assert np.all(h(ys) >= f(ys) - 1e-7)
    assert np.all(h(ys) >= g(ys) - 1e-7)
    assert np.all(np.abs(h(ys) - np.maximum(f(ys), g(ys))) < 1e-6)


@given(knots, knots, end_slopes, end_slopes)
@_settings
def test_min_is_pointwise(kf, kg, ef, eg):
    f = _pwl(kf[0], kf[1], *ef)
    g = _pwl(kg[0], kg[1], *eg)
    h = R.pwl_min(f, g)
    ys = np.linspace(-8, 8, 81)
    assert np.all(np.abs(h(ys) - np.minimum(f(ys), g(ys))) < 1e-6)


@given(knots, end_slopes, st.floats(80, 140), st.floats(20, 70))
@_settings
def test_cone_lower_bound_and_slopes(kf, ef, a, b):
    """v <= f pointwise; v has slopes within [-a, -b]; v is the identity
    when f already satisfies the slope constraint (convex case)."""
    f = _pwl(kf[0], kf[1], min(ef[0], -b - 1), max(ef[1], -a))
    v = R.cone_infconv(f, a, b)
    ys = np.linspace(-8, 8, 81)
    assert np.all(v(ys) <= f(ys) + 1e-7)
    s = v.slopes()
    assert np.all(s >= -a - 1e-7) and np.all(s <= -b + 1e-7)


@given(knots, end_slopes, st.floats(80, 140), st.floats(20, 70),
       st.floats(1.001, 1.2))
@_settings
def test_cone_monotone_in_spread(kf, ef, a, b, widen):
    """Widening the bid-ask spread (a up, b down) raises the rebalancing
    cost c(d) = max(a d, b d) pointwise, so the hedging expense v can only
    increase: v_wide >= v_narrow.  (This is the per-step mechanism behind
    the paper's Fig. 9: ask prices increase with the cost rate k.)"""
    f = _pwl(kf[0], kf[1], min(ef[0], -b * widen - 1), max(ef[1], -a * widen))
    v_narrow = R.cone_infconv(f, a, b)
    v_wide = R.cone_infconv(f, a * widen, b / widen)
    ys = np.linspace(-6, 6, 61)
    assert np.all(v_wide(ys) >= v_narrow(ys) - 1e-6)


@given(knots, end_slopes, st.floats(0.5, 2.0))
@_settings
def test_scale_linearity(kf, ef, alpha):
    f = _pwl(kf[0], kf[1], *ef)
    ys = np.linspace(-5, 5, 41)
    np.testing.assert_allclose(f.scale(alpha)(ys), alpha * f(ys), rtol=1e-9)


# ---------------------------------------------------------------------- #
# fixed-capacity SoA engine (core/pwl.py) vs the exact oracle
# ---------------------------------------------------------------------- #
CAP = 32          # roomy capacity: these properties are about *values*
_QS = np.linspace(-8.0, 8.0, 97)


def _soa(ref):
    from repro.core import pwl as P
    return P.from_ref(ref, CAP)


@given(knots, end_slopes, st.floats(80, 140), st.floats(20, 70))
@_settings
def test_soa_cone_matches_oracle(kf, ef, a, b):
    """core/pwl.py::cone_infconv == pwl_ref oracle, values and end slopes."""
    from repro.core import pwl as P
    f = _pwl(kf[0], kf[1], min(ef[0], -b - 1), max(ef[1], -a))
    want = R.cone_infconv(f, a, b)
    got, m_raw = P.cone_infconv(_soa(f), a, b, CAP)
    assert int(m_raw) <= CAP          # capacity sized for the property
    got_ref = P.to_ref(got)
    np.testing.assert_allclose(got_ref(_QS), want(_QS), atol=1e-7)
    assert abs(got_ref.s_left - want.s_left) < 1e-7 * (1 + abs(want.s_left))
    assert abs(got_ref.s_right - want.s_right) < 1e-7 * (1 + abs(want.s_right))
    # overflow contract on the cone: a too-small output capacity must be
    # *reported* via the raw count, never silently truncated away
    tiny = 2
    _, m_tiny = P.cone_infconv(_soa(f), a, b, tiny)
    if want.m > tiny:
        assert int(m_tiny) > tiny
    assert int(m_tiny) == int(m_raw)  # raw count is capacity-independent


@given(knots, end_slopes, st.floats(20, 140))
@_settings
def test_soa_cone_lambda0_degenerate(kf, ef, a):
    """lambda = 0 collapses the cost cone to a line (a == b): the
    inf-convolution must still be exact, not NaN/divide-by-zero, in both
    implementations (this is the k=0 'no transaction costs' path and the
    t=0 'no costs at time zero' path of the engines)."""
    from repro.core import pwl as P
    f = _pwl(kf[0], kf[1], min(ef[0], -a - 1), max(ef[1], -a))
    want = R.cone_infconv(f, a, a)
    got, m_raw = P.cone_infconv(_soa(f), a, a, CAP)
    got_ref = P.to_ref(got)
    vals = got_ref(_QS)
    assert np.all(np.isfinite(vals))
    np.testing.assert_allclose(vals, want(_QS), atol=1e-7)
    # a == b: result slopes all equal -a (an affine function)
    assert np.all(np.abs(got_ref.slopes() + a) < 1e-6 * (1 + a))


@given(st.floats(-50, 50), st.floats(-3, 3), st.floats(80, 140),
       st.floats(20, 70))
@_settings
def test_soa_expense_matches_oracle(xi, zeta, s_ask, s_bid):
    """core/pwl.py::expense == pwl_ref.expense_function (eq. (1)/(6)),
    including the degenerate s_ask == s_bid (lambda = 0) form."""
    from repro.core import pwl as P
    for ask, bid in ((s_ask, s_bid), (s_ask, s_ask)):   # incl. degenerate
        want = R.expense_function(xi, zeta, ask, bid)
        got = P.to_ref(P.expense(xi, zeta, ask, bid, CAP))
        np.testing.assert_allclose(got(_QS), want(_QS), atol=1e-8)
        # value at the kink is exactly xi by construction
        np.testing.assert_allclose(got(zeta), xi, atol=1e-9)


@given(knots, knots, end_slopes, end_slopes, st.integers(2, 4))
@_settings
def test_soa_envelope_overflow_is_reported_never_silent(kf, kg, ef, eg, cap):
    """The overflow contract of docs/ARCHITECTURE.md §2: every envelope
    returns the raw knot count BEFORE truncation.  Whenever the exact
    result needs more knots than the output capacity, m_raw must say so
    (m_raw > cap); and whenever m_raw fits, the truncated result must be
    the exact oracle envelope — overflow is detected, never silent."""
    from repro.core import pwl as P
    f = _pwl(kf[0], kf[1], *ef)
    g = _pwl(kg[0], kg[1], *eg)
    want = R.pwl_max(f, g)
    got, m_raw = P.envelope2(P.from_ref(f, CAP), P.from_ref(g, CAP),
                             cap, take_max=True)
    m_raw = int(m_raw)
    if want.m > cap:
        assert m_raw > cap, (
            f"oracle needs {want.m} knots > cap={cap} but m_raw={m_raw} "
            "reported a fit: silent truncation")
    if m_raw <= cap:
        np.testing.assert_allclose(P.to_ref(got)(_QS), want(_QS), atol=1e-7)
