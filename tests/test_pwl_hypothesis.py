"""Property-based tests (hypothesis) for the PWL algebra invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pwl_ref as R

_settings = settings(max_examples=60, deadline=None)


def _pwl(xs, ys, sl, sr):
    xs = np.sort(np.asarray(xs)) + np.arange(len(xs)) * 1e-3
    return R.PWLRef(xs, np.asarray(ys), sl, sr)


knots = st.integers(1, 5).flatmap(
    lambda m: st.tuples(
        st.lists(st.floats(-5, 5), min_size=m, max_size=m),
        st.lists(st.floats(-100, 100), min_size=m, max_size=m)))
end_slopes = st.tuples(st.floats(-150, -60), st.floats(-50, -5))


@given(knots, knots, end_slopes, end_slopes)
@_settings
def test_max_dominates_both(kf, kg, ef, eg):
    f = _pwl(kf[0], kf[1], *ef)
    g = _pwl(kg[0], kg[1], *eg)
    h = R.pwl_max(f, g)
    ys = np.linspace(-8, 8, 81)
    assert np.all(h(ys) >= f(ys) - 1e-7)
    assert np.all(h(ys) >= g(ys) - 1e-7)
    assert np.all(np.abs(h(ys) - np.maximum(f(ys), g(ys))) < 1e-6)


@given(knots, knots, end_slopes, end_slopes)
@_settings
def test_min_is_pointwise(kf, kg, ef, eg):
    f = _pwl(kf[0], kf[1], *ef)
    g = _pwl(kg[0], kg[1], *eg)
    h = R.pwl_min(f, g)
    ys = np.linspace(-8, 8, 81)
    assert np.all(np.abs(h(ys) - np.minimum(f(ys), g(ys))) < 1e-6)


@given(knots, end_slopes, st.floats(80, 140), st.floats(20, 70))
@_settings
def test_cone_lower_bound_and_slopes(kf, ef, a, b):
    """v <= f pointwise; v has slopes within [-a, -b]; v is the identity
    when f already satisfies the slope constraint (convex case)."""
    f = _pwl(kf[0], kf[1], min(ef[0], -b - 1), max(ef[1], -a))
    v = R.cone_infconv(f, a, b)
    ys = np.linspace(-8, 8, 81)
    assert np.all(v(ys) <= f(ys) + 1e-7)
    s = v.slopes()
    assert np.all(s >= -a - 1e-7) and np.all(s <= -b + 1e-7)


@given(knots, end_slopes, st.floats(80, 140), st.floats(20, 70),
       st.floats(1.001, 1.2))
@_settings
def test_cone_monotone_in_spread(kf, ef, a, b, widen):
    """Widening the bid-ask spread (a up, b down) raises the rebalancing
    cost c(d) = max(a d, b d) pointwise, so the hedging expense v can only
    increase: v_wide >= v_narrow.  (This is the per-step mechanism behind
    the paper's Fig. 9: ask prices increase with the cost rate k.)"""
    f = _pwl(kf[0], kf[1], min(ef[0], -b * widen - 1), max(ef[1], -a * widen))
    v_narrow = R.cone_infconv(f, a, b)
    v_wide = R.cone_infconv(f, a * widen, b / widen)
    ys = np.linspace(-6, 6, 61)
    assert np.all(v_wide(ys) >= v_narrow(ys) - 1e-6)


@given(knots, end_slopes, st.floats(0.5, 2.0))
@_settings
def test_scale_linearity(kf, ef, alpha):
    f = _pwl(kf[0], kf[1], *ef)
    ys = np.linspace(-5, 5, 41)
    np.testing.assert_allclose(f.scale(alpha)(ys), alpha * f(ys), rtol=1e-9)
