"""Roux–Zastawniak pricing: oracle + vectorised engine, paper anchors."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (LatticeModel, american_call, american_put,
                        bull_spread, price_notc_np, price_ref)
from repro.core.rz import price_rz, price_rz_batch

PUT = american_put(100.0)


def test_zero_costs_reduce_to_classic_binomial():
    m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=20,
                     cost_rate=0.0)
    res = price_ref(m, PUT)
    classic = price_notc_np(m, PUT)
    assert res.ask == pytest.approx(classic, abs=1e-10)
    assert res.bid == pytest.approx(classic, abs=1e-10)


@pytest.mark.parametrize("n,k", [(10, 0.005), (20, 0.01), (25, 0.02)])
def test_jax_engine_matches_oracle_put(n, k):
    m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=n,
                     cost_rate=k)
    ref = price_ref(m, PUT)
    got = price_rz(m, PUT, capacity=24)
    assert got.ask == pytest.approx(ref.ask, abs=1e-9)
    assert got.bid == pytest.approx(ref.bid, abs=1e-9)


def test_jax_engine_matches_oracle_bull_spread():
    m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=15,
                     cost_rate=0.01)
    bs = bull_spread()
    ref = price_ref(m, bs)
    got = price_rz(m, bs, capacity=48)
    assert got.ask == pytest.approx(ref.ask, abs=1e-9)
    assert got.bid == pytest.approx(ref.bid, abs=1e-9)


def test_spread_monotone_in_cost_rate():
    """Paper Fig. 9 ordering: bid(k2) <= bid(k1) <= pi(0) <= ask(k1) <= ask(k2)."""
    m0 = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=20)
    classic = price_notc_np(m0, PUT)
    asks, bids = [], []
    for k in (0.0025, 0.005):
        r = price_ref(m0.with_(cost_rate=k), PUT)
        asks.append(r.ask)
        bids.append(r.bid)
    assert bids[1] <= bids[0] + 1e-12 <= classic + 1e-9
    assert classic - 1e-9 <= asks[0] <= asks[1] + 1e-12


def test_call_prices_sane():
    m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=15,
                     cost_rate=0.01)
    call = american_call(100.0)
    r = price_ref(m, call)
    assert r.ask >= r.bid >= 0.0
    # ask at least intrinsic at the money forward-ish
    assert r.ask > 0.5


def test_batched_contracts():
    got = price_rz_batch(
        jnp.array([100.0, 95.0]), jnp.array([0.2, 0.2]),
        jnp.array([0.1, 0.1]), jnp.array([0.25, 0.25]),
        jnp.array([0.005, 0.005]),
        n_steps=12, capacity=24, payoff=PUT)
    ask, bid, _ = (np.asarray(x) for x in got)
    for i, s0 in enumerate([100.0, 95.0]):
        m = LatticeModel(s0=s0, sigma=0.2, rate=0.1, maturity=0.25,
                         n_steps=12, cost_rate=0.005)
        ref = price_ref(m, PUT)
        assert ask[i] == pytest.approx(ref.ask, abs=1e-9)
        assert bid[i] == pytest.approx(ref.bid, abs=1e-9)
    # a put is worth more at lower spot
    assert ask[1] > ask[0]


def test_capacity_overflow_detected():
    m = LatticeModel(s0=100, sigma=0.2, rate=0.1, maturity=0.25, n_steps=25,
                     cost_rate=0.01)
    with pytest.raises(OverflowError):
        price_rz(m, bull_spread(), capacity=4)
