"""Paper Table I reproduction + schedule invariants.

The Table I tests are the paper-validation gate promoted from the
``table1`` benchmark: they pin, forever,

  * that our reading of Algorithm 1 with the *text* semantics of §4.2
    (``literal=False``, line 25 read as ``n <- B``) reproduces every one
    of the paper's 9 published thread-p0 node counts EXACTLY, and
  * the §4.2 "line 25 typo" finding: the pseudo-code as literally printed
    (``n <- B + 1``) OVERcounts every cell by ~0.13-0.17% — so the
    authors' own implementation must have used the text semantics.
"""
import pytest

from repro.core.partition import (kernel_round_plan, pick_round_depth,
                                  simulate_schedule, table1_reference)


def test_table1_exact_reproduction():
    """Every cell of paper Table I (thread p0 node counts, L=5) EXACTLY."""
    cells = table1_reference()
    assert len(cells) == 9          # the full published (p, N) grid
    for (p, n), want in cells.items():
        got = simulate_schedule(n, p, 5).p0_nodes
        assert got == want, f"p={p} N={n}: got {got}, paper says {want}"


def test_literal_pseudocode_overcounts():
    """Algorithm 1 line 25 as literally printed drifts high in EVERY cell
    — the typo finding (see partition.py docstring).  Pinned: strictly
    more nodes than the paper's counts, within the ~0.13-0.17% band."""
    for (p, n), want in table1_reference().items():
        lit = simulate_schedule(n, p, 5, literal=True).p0_nodes
        assert lit > want, f"p={p} N={n}: literal variant must overcount"
        rel = (lit - want) / want
        assert 0.0005 < rel < 0.005, (p, n, rel)


@pytest.mark.parametrize("n,p,L", [(100, 3, 5), (250, 8, 5), (1000, 4, 50),
                                   (37, 2, 3), (64, 8, 1)])
def test_all_nodes_processed_exactly_once(n, p, L):
    res = simulate_schedule(n, p, L)
    assert sum(res.per_thread) == res.total_nodes


@pytest.mark.parametrize("n,p,L", [(200, 4, 5), (500, 8, 10)])
def test_depth_bounds(n, p, L):
    res = simulate_schedule(n, p, L)
    for r in res.rounds:
        assert 1 <= r.depth <= L
        assert max(r.per_thread) >= 1


def test_estimate_n2_over_2p():
    """§4.3: thread p0 processes ~ N^2/2p nodes; error shrinks with N."""
    errs = []
    for n in (600, 1200, 2400):
        res = simulate_schedule(n, 4, 5)
        est = n * n / 8
        errs.append(abs(res.p0_nodes - est) / est)
    assert errs[-1] < errs[0] < 0.02


@pytest.mark.parametrize("n,levels,block", [
    (10, None, None), (100, None, None), (512, 64, None),
    (100, 5, 16), (512, None, 128), (37, 3, 4),
])
def test_kernel_round_plan_covers_all_levels(n, levels, block):
    """The Pallas round schedule walks N+1 -> 0 exactly, respects the
    halo bound D <= block on multi-block rounds, and re-balances lanes to
    the live tree (monotone shrink, always covering lanes 0..B)."""
    plan = kernel_round_plan(n, levels=levels, block=block)
    b = n + 1
    prev_lanes = plan[0].lanes
    for rnd in plan:
        assert rnd.lvl0 == b
        assert 1 <= rnd.depth <= rnd.lvl0
        assert rnd.lanes % rnd.block == 0
        assert rnd.lanes >= rnd.lvl0 + 1          # input lanes 0..B live
        assert rnd.lanes <= prev_lanes            # re-balance only shrinks
        if rnd.nblk > 1:
            assert rnd.depth <= rnd.block         # halo staleness bound
            assert rnd.block == block
        prev_lanes = rnd.lanes
        b -= rnd.depth
    assert b == 0                                 # reached the root


def test_pick_round_depth_matches_algorithm1_rule():
    """D = min(L, base) single-block; the halo caps D at block otherwise."""
    assert pick_round_depth(100, None, L=5) == 5
    assert pick_round_depth(3, None, L=5) == 3        # short final round
    assert pick_round_depth(100, 8, L=64) == 8        # multi-block: D <= block
    assert pick_round_depth(7, 8, L=64) == 7          # fits one block: no cap
    assert pick_round_depth(1, 4, L=5) == 1


def test_makespan_speedup_scales():
    """Schedule-level speedup grows with p (paper §4.3: S = O(p))."""
    serial = simulate_schedule(1000, 1, 5).makespan_nodes
    s4 = serial / simulate_schedule(1000, 4, 5).makespan_nodes
    s8 = serial / simulate_schedule(1000, 8, 5).makespan_nodes
    assert 3.2 < s4 <= 4.000001
    assert 6.0 < s8 <= 8.000001
    assert s8 > s4


# ===================================================================== #
# scenario-axis shard planner (the §4.2 re-balancing on a device mesh)
# ===================================================================== #
import numpy as np

from repro.core.partition import (ShardRebalancer, plan_shards,
                                  replan_shards, scenario_costs,
                                  shard_layout)


def test_scenario_costs_model():
    """TC rows cost ~pieces x a frictionless row; trees cost ~N^2;
    measured pieces tighten the worst-case capacity estimate."""
    c = scenario_costs(100, [0.0, 0.01], capacity=48)
    assert c[1] == pytest.approx(48.0 * c[0])
    assert scenario_costs(200, [0.0])[0] == pytest.approx(4.0 * c[0])
    m = scenario_costs(100, [0.0, 0.01], capacity=48, pieces=6)
    assert m[1] == pytest.approx(6.0 * m[0])
    per_row = scenario_costs(100, [0.01, 0.01], capacity=48,
                             pieces=np.array([4.0, 8.0]))
    assert per_row[1] == pytest.approx(2.0 * per_row[0])
    # lambda = 0 rows never get the PWL multiplier
    assert scenario_costs(100, [0.0], capacity=48, pieces=40)[0] == c[0]


def test_plan_shards_uneven_sizes_even_work():
    """The acceptance-gate property: on the 108-row mixed grid (72 TC +
    36 frictionless rows) the planner's shard *sizes* come out uneven
    while predicted per-device work stays within 10%."""
    # the tests' canonical mixed grid: cost_rate axis (0, 0.005, 0.01)
    cr = np.tile([0.0, 0.005, 0.01], 36)
    costs = scenario_costs(10, cr, capacity=16)
    plan = plan_shards(costs, 8)
    assert plan.n_rows == 108 and sum(plan.sizes) == 108
    assert len(set(plan.sizes)) > 1          # uneven row counts ...
    assert plan.work_spread < 0.10           # ... near-equal work
    # every row appears exactly once
    assert sorted(i for s in plan.shards for i in s) == list(range(108))


def test_plan_shards_uniform_and_edges():
    plan = plan_shards(np.ones(12), 4)
    assert plan.sizes == (3, 3, 3, 3) and plan.work_spread == 0.0
    assert plan.lanes == 3 and plan.padded_rows == 12
    # more shards than rows: empty shards allowed, lanes >= 1
    plan = plan_shards(np.ones(3), 8)
    assert sum(plan.sizes) == 3 and plan.lanes == 1
    assert plan.work_spread == 0.0           # spread over non-empty shards
    # pow2 lane rounding (the serving layer's compile-shape discipline)
    plan = plan_shards(np.ones(10), 2, lanes_pow2=True)
    assert plan.lanes == 8 and plan.padded_rows == 16
    with pytest.raises(ValueError):
        plan_shards(np.ones(4), 0)
    with pytest.raises(ValueError):
        plan_shards(np.array([1.0, -1.0]), 2)
    with pytest.raises(ValueError):
        plan_shards(np.ones(4), 2, device_speed=[1.0, 0.0])


def test_plan_shards_determinism():
    cr = np.tile([0.0, 0.01], 20)
    costs = scenario_costs(50, cr, capacity=32)
    assert plan_shards(costs, 4) == plan_shards(costs, 4)


def test_plan_shards_speed_steering():
    """A device reported 2x faster should end with ~2x the work."""
    plan = plan_shards(np.ones(300), 2, device_speed=[2.0, 1.0])
    w0, w1 = plan.work
    assert w0 / w1 == pytest.approx(2.0, rel=0.05)


def test_shard_layout_roundtrip_and_pad_locality():
    cr = np.tile([0.0, 0.01, 0.01], 11)      # 33 rows, uneven costs
    plan = plan_shards(scenario_costs(8, cr, capacity=8), 4)
    gather, positions = shard_layout(plan)
    assert gather.shape == (plan.padded_rows,)
    assert positions.shape == (33,)
    # inverse property: laying out then reading back restores every row
    assert (gather[positions] == np.arange(33)).all()
    # pads duplicate rows of the SAME shard (so per-shard stats and
    # max-reductions cannot leak across shards)
    for d, rows in enumerate(plan.shards):
        window = gather[d * plan.lanes:(d + 1) * plan.lanes]
        assert set(window) <= (set(rows) or {0})


def test_replan_moves_work_off_slow_shard():
    """The rebalance hook: a shard measured 3x slower sheds work."""
    costs = np.ones(120)
    plan = plan_shards(costs, 4)
    even = plan.work[0]
    plan2 = replan_shards(costs, plan, [3.0, 1.0, 1.0, 1.0])
    assert plan2.work[0] < 0.5 * even        # slow device sheds most work
    assert sum(plan2.sizes) == 120
    # measured seconds matching predictions keep the plan balanced
    plan3 = replan_shards(costs, plan, [1.0, 1.0, 1.0, 1.0])
    assert plan3.work_spread < 1e-9


def test_rebalancer_ema_and_reset():
    rb = ShardRebalancer(ema=0.5)
    costs = np.ones(64)
    plan = rb.plan("bucket", costs, 4)
    assert plan.work_spread < 1e-9           # no evidence -> even split
    sp = rb.observe("bucket", plan, [2.0, 1.0, 1.0, 1.0])
    assert sp[0] < sp[1]                     # slow shard -> lower speed
    # EMA: a second identical observation moves the estimate further
    sp2 = rb.observe("bucket", rb.plan("bucket", costs, 4),
                     [2.0, 1.0, 1.0, 1.0])
    assert sp2[0] < sp[0]
    plan2 = rb.plan("bucket", costs, 4)
    assert plan2.work[0] < plan.work[0]
    # unknown keys and shard-count changes fall back to neutral speeds
    assert (rb.speed("other", 4) == 1.0).all()
    assert (rb.speed("bucket", 8) == 1.0).all()
    with pytest.raises(ValueError):
        ShardRebalancer(ema=0.0)
