"""Paper Table I reproduction + schedule invariants."""
import pytest

from repro.core.partition import simulate_schedule, table1_reference


def test_table1_exact_reproduction():
    """Every cell of paper Table I (thread p0 node counts, L=5) EXACTLY."""
    for (p, n), want in table1_reference().items():
        got = simulate_schedule(n, p, 5).p0_nodes
        assert got == want, f"p={p} N={n}: got {got}, paper says {want}"


def test_literal_pseudocode_overcounts():
    """Algorithm 1 line 25 as literally printed drifts ~0.1-0.2% high —
    documents the typo finding (see partition.py docstring)."""
    for (p, n), want in table1_reference().items():
        lit = simulate_schedule(n, p, 5, literal=True).p0_nodes
        assert lit != want
        assert abs(lit - want) / want < 0.005


@pytest.mark.parametrize("n,p,L", [(100, 3, 5), (250, 8, 5), (1000, 4, 50),
                                   (37, 2, 3), (64, 8, 1)])
def test_all_nodes_processed_exactly_once(n, p, L):
    res = simulate_schedule(n, p, L)
    assert sum(res.per_thread) == res.total_nodes


@pytest.mark.parametrize("n,p,L", [(200, 4, 5), (500, 8, 10)])
def test_depth_bounds(n, p, L):
    res = simulate_schedule(n, p, L)
    for r in res.rounds:
        assert 1 <= r.depth <= L
        assert max(r.per_thread) >= 1


def test_estimate_n2_over_2p():
    """§4.3: thread p0 processes ~ N^2/2p nodes; error shrinks with N."""
    errs = []
    for n in (600, 1200, 2400):
        res = simulate_schedule(n, 4, 5)
        est = n * n / 8
        errs.append(abs(res.p0_nodes - est) / est)
    assert errs[-1] < errs[0] < 0.02


def test_makespan_speedup_scales():
    """Schedule-level speedup grows with p (paper §4.3: S = O(p))."""
    serial = simulate_schedule(1000, 1, 5).makespan_nodes
    s4 = serial / simulate_schedule(1000, 4, 5).makespan_nodes
    s8 = serial / simulate_schedule(1000, 8, 5).makespan_nodes
    assert 3.2 < s4 <= 4.000001
    assert 6.0 < s8 <= 8.000001
    assert s8 > s4
