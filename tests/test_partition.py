"""Paper Table I reproduction + schedule invariants.

The Table I tests are the paper-validation gate promoted from the
``table1`` benchmark: they pin, forever,

  * that our reading of Algorithm 1 with the *text* semantics of §4.2
    (``literal=False``, line 25 read as ``n <- B``) reproduces every one
    of the paper's 9 published thread-p0 node counts EXACTLY, and
  * the §4.2 "line 25 typo" finding: the pseudo-code as literally printed
    (``n <- B + 1``) OVERcounts every cell by ~0.13-0.17% — so the
    authors' own implementation must have used the text semantics.
"""
import pytest

from repro.core.partition import (kernel_round_plan, pick_round_depth,
                                  simulate_schedule, table1_reference)


def test_table1_exact_reproduction():
    """Every cell of paper Table I (thread p0 node counts, L=5) EXACTLY."""
    cells = table1_reference()
    assert len(cells) == 9          # the full published (p, N) grid
    for (p, n), want in cells.items():
        got = simulate_schedule(n, p, 5).p0_nodes
        assert got == want, f"p={p} N={n}: got {got}, paper says {want}"


def test_literal_pseudocode_overcounts():
    """Algorithm 1 line 25 as literally printed drifts high in EVERY cell
    — the typo finding (see partition.py docstring).  Pinned: strictly
    more nodes than the paper's counts, within the ~0.13-0.17% band."""
    for (p, n), want in table1_reference().items():
        lit = simulate_schedule(n, p, 5, literal=True).p0_nodes
        assert lit > want, f"p={p} N={n}: literal variant must overcount"
        rel = (lit - want) / want
        assert 0.0005 < rel < 0.005, (p, n, rel)


@pytest.mark.parametrize("n,p,L", [(100, 3, 5), (250, 8, 5), (1000, 4, 50),
                                   (37, 2, 3), (64, 8, 1)])
def test_all_nodes_processed_exactly_once(n, p, L):
    res = simulate_schedule(n, p, L)
    assert sum(res.per_thread) == res.total_nodes


@pytest.mark.parametrize("n,p,L", [(200, 4, 5), (500, 8, 10)])
def test_depth_bounds(n, p, L):
    res = simulate_schedule(n, p, L)
    for r in res.rounds:
        assert 1 <= r.depth <= L
        assert max(r.per_thread) >= 1


def test_estimate_n2_over_2p():
    """§4.3: thread p0 processes ~ N^2/2p nodes; error shrinks with N."""
    errs = []
    for n in (600, 1200, 2400):
        res = simulate_schedule(n, 4, 5)
        est = n * n / 8
        errs.append(abs(res.p0_nodes - est) / est)
    assert errs[-1] < errs[0] < 0.02


@pytest.mark.parametrize("n,levels,block", [
    (10, None, None), (100, None, None), (512, 64, None),
    (100, 5, 16), (512, None, 128), (37, 3, 4),
])
def test_kernel_round_plan_covers_all_levels(n, levels, block):
    """The Pallas round schedule walks N+1 -> 0 exactly, respects the
    halo bound D <= block on multi-block rounds, and re-balances lanes to
    the live tree (monotone shrink, always covering lanes 0..B)."""
    plan = kernel_round_plan(n, levels=levels, block=block)
    b = n + 1
    prev_lanes = plan[0].lanes
    for rnd in plan:
        assert rnd.lvl0 == b
        assert 1 <= rnd.depth <= rnd.lvl0
        assert rnd.lanes % rnd.block == 0
        assert rnd.lanes >= rnd.lvl0 + 1          # input lanes 0..B live
        assert rnd.lanes <= prev_lanes            # re-balance only shrinks
        if rnd.nblk > 1:
            assert rnd.depth <= rnd.block         # halo staleness bound
            assert rnd.block == block
        prev_lanes = rnd.lanes
        b -= rnd.depth
    assert b == 0                                 # reached the root


def test_pick_round_depth_matches_algorithm1_rule():
    """D = min(L, base) single-block; the halo caps D at block otherwise."""
    assert pick_round_depth(100, None, L=5) == 5
    assert pick_round_depth(3, None, L=5) == 3        # short final round
    assert pick_round_depth(100, 8, L=64) == 8        # multi-block: D <= block
    assert pick_round_depth(7, 8, L=64) == 7          # fits one block: no cap
    assert pick_round_depth(1, 4, L=5) == 1


def test_makespan_speedup_scales():
    """Schedule-level speedup grows with p (paper §4.3: S = O(p))."""
    serial = simulate_schedule(1000, 1, 5).makespan_nodes
    s4 = serial / simulate_schedule(1000, 4, 5).makespan_nodes
    s8 = serial / simulate_schedule(1000, 8, 5).makespan_nodes
    assert 3.2 < s4 <= 4.000001
    assert 6.0 < s8 <= 8.000001
    assert s8 > s4
