"""Serving engines: LM greedy generation consistency + pricing service
(the continuous-batching scheduler: deadline flush, bucket/compile reuse,
pad-unpad correctness vs the ``price_american`` oracle, heterogeneous
payoff batches, engine="auto" routing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import price_american
from repro.configs import get_config, reduced_config
from repro.models.transformer import RunCfg, init_lm, lm_loss, prefill
from repro.serve.engine import (GridRequest, LMEngine, PriceRequest,
                                PricingEngine)
from repro.serve.scheduler import PricingService

RUN = RunCfg(dtype=jnp.float32)

TOL = 1e-9


def _req(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25, cost_rate=0.0, **kw):
    return PriceRequest(s0=s0, sigma=sigma, rate=rate, maturity=maturity,
                        cost_rate=cost_rate, **kw)


def _oracle(req, *, n_steps, capacity=32):
    return price_american(
        s0=req.s0, sigma=req.sigma, rate=req.rate, maturity=req.maturity,
        n_steps=n_steps, payoff=req.payoff or "put",
        strike=req.strike if req.strike is not None else 100.0,
        cost_rate=req.cost_rate, capacity=capacity)


def test_lm_engine_matches_full_forward():
    """Greedy tokens from the engine == argmax over repeated full prefills
    (the no-cache reference)."""
    cfg = reduced_config(get_config("qwen3-0.6b"))
    key = jax.random.PRNGKey(0)
    params, _ = init_lm(key, cfg)
    B, S0, NNEW = 2, 8, 4
    prompt = np.asarray(jax.random.randint(key, (B, S0), 0, cfg.vocab))

    eng = LMEngine(params, cfg, RUN, batch=B, max_len=S0 + NNEW)
    got = eng.generate(prompt, NNEW)

    # reference: re-prefill from scratch each step
    toks = prompt.copy()
    want = []
    for _ in range(NNEW):
        logits, _ = prefill(params, {"tokens": jnp.asarray(toks)}, cfg, RUN)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        want.append(nxt)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    want = np.stack(want, axis=1)
    np.testing.assert_array_equal(got, want)


def test_fresh_service_snapshot_is_strict_json():
    """Regression: before any engine flush, ``engine_seconds`` is 0 and
    ``contracts_per_sec`` used to come out ``float("inf")`` —
    ``json.dumps`` then emitted the non-standard ``Infinity`` token into
    the BENCH_serve.json artifact.  A fresh service must report 0.0 and
    serialise as strict JSON."""
    import json

    svc = PricingService(max_batch=4, default_n_steps=8)
    snap = svc.metrics()
    assert snap["contracts_per_sec"] == 0.0
    assert snap["engine_seconds"] == 0.0
    # allow_nan=False makes json.dumps raise on inf/nan anywhere in the
    # snapshot; strict parsers (and tools/check_bench.py) reject those
    parsed = json.loads(json.dumps(snap, allow_nan=False))
    assert parsed["contracts_per_sec"] == 0.0


def test_scheduler_deadline_flush():
    """A partial bucket sits until its oldest request ages past the
    deadline; step() before that is a no-op, after it a flush."""
    t = [0.0]
    svc = PricingService(max_batch=64, deadline_ms=10.0, default_n_steps=8,
                         clock=lambda: t[0])
    ids = [svc.submit(_req(s0=s)) for s in (95.0, 100.0, 105.0)]
    assert svc.pending_count == 3 and svc.result(ids[0]) is None
    t[0] = 0.005
    svc.step()
    assert svc.pending_count == 3          # 5 ms < 10 ms deadline
    t[0] = 0.011
    svc.step()
    assert svc.pending_count == 0
    for rid in ids:
        assert svc.result(rid) is not None
    m = svc.metrics()
    assert m["batches"] == 1 and m["contracts"] == 3
    assert m["padded"] == 4                # 3 requests pad to the 4-bucket


def test_scheduler_size_trigger_and_compile_cache():
    """Full buckets flush inside submit; a repeated (padded batch,
    n_steps, engine) shape is a compile-cache hit, and a repeated
    scenario is a result-cache hit that never reaches the engines."""
    svc = PricingService(max_batch=4, deadline_ms=1e9, default_n_steps=8)
    for s in (90.0, 95.0, 100.0, 105.0):
        svc.submit(_req(s0=s))
    m = svc.metrics()
    assert m["batches"] == 1               # size trigger, no flush() needed
    assert m["compile_misses"] == 1 and m["compile_hits"] == 0
    for s in (91.0, 96.0, 101.0, 106.0):   # same bucket shape, new data
        svc.submit(_req(s0=s))
    m = svc.metrics()
    assert m["batches"] == 2
    assert m["compile_misses"] == 1 and m["compile_hits"] == 1
    rid = svc.submit(_req(s0=95.0))        # seen scenario: LRU short-circuit
    m = svc.metrics()
    assert svc.result(rid) is not None and m["cache_hits"] == 1
    assert m["batches"] == 2               # no engine work


def test_scheduler_pad_unpad_heterogeneous_vs_oracle():
    """A mixed put/call/bull_spread batch (padded 5 -> 8) is one compiled
    no-TC call and every unpadded quote matches price_american at 1e-9."""
    svc = PricingService(max_batch=8, default_n_steps=8)
    reqs = [
        _req(s0=95.0, payoff="put", strike=100.0),
        _req(s0=100.0, payoff="call", strike=95.0),
        _req(s0=105.0, payoff="bull_spread", strike=95.0),
        _req(s0=98.0, sigma=0.3, payoff="put", strike=105.0),
        _req(s0=102.0, maturity=0.5, payoff="call", strike=100.0),
    ]
    ids = [svc.submit(r) for r in reqs]
    svc.flush()
    m = svc.metrics()
    assert m["batches"] == 1 and m["engine_batches"] == {"notc": 1, "rz": 0,
                                                         "lsmc": 0}
    assert m["padded"] == 8 and m["contracts"] == 5
    for req, rid in zip(reqs, ids):
        q = svc.result(rid)
        ref = _oracle(req, n_steps=8)
        assert q.ask == pytest.approx(ref.ask, abs=TOL)
        assert q.bid == pytest.approx(ref.bid, abs=TOL)
        assert q.ask == q.bid              # frictionless: point quote


def test_scheduler_tc_bucket_vs_oracle():
    """TC requests bucket separately from frictionless ones (different
    engine program); RZ quotes match the price_american interval."""
    svc = PricingService(max_batch=8, default_n_steps=8, capacity=16)
    tc = [_req(s0=s, cost_rate=0.005) for s in (95.0, 100.0, 105.0)]
    free = [_req(s0=s) for s in (95.0, 100.0)]
    ids = [svc.submit(r) for r in tc + free]
    svc.flush()
    m = svc.metrics()
    assert m["engine_batches"] == {"notc": 1, "rz": 1, "lsmc": 0}
    for req, rid in zip(tc + free, ids):
        q = svc.result(rid)
        ref = _oracle(req, n_steps=8, capacity=16)
        assert q.ask == pytest.approx(ref.ask, abs=TOL)
        assert q.bid == pytest.approx(ref.bid, abs=TOL)
    assert svc.result(ids[0]).ask > svc.result(ids[0]).bid   # real spread


def test_scheduler_requeues_batch_on_engine_error(monkeypatch):
    """An engine exception (e.g. PWL capacity OverflowError) must not
    lose in-flight requests: the chunk is re-queued and a later flush
    completes it."""
    svc = PricingService(max_batch=8, default_n_steps=8)
    ids = [svc.submit(_req(s0=s)) for s in (95.0, 100.0, 105.0)]

    def _boom(**kw):
        raise OverflowError("PWL capacity overflow")

    monkeypatch.setattr("repro.api.price_flat", _boom)
    with pytest.raises(OverflowError):
        svc.flush()
    assert svc.pending_count == 3          # nothing silently dropped
    monkeypatch.undo()
    svc.flush()
    for rid in ids:
        assert svc.result(rid) is not None
    assert svc.metrics()["completed"] == 3
    # a compile is only counted once the engine call succeeds: the failed
    # flush must not have registered the batch shape as "compiled"
    assert svc.metrics()["compile_misses"] == 1

    # size-trigger path: submit() must still hand back the request id and
    # defer the engine error to the next step()/flush()
    monkeypatch.setattr("repro.api.price_flat", _boom)
    svc2 = PricingService(max_batch=2, default_n_steps=8)
    r1 = svc2.submit(_req(s0=90.0))
    r2 = svc2.submit(_req(s0=91.0))        # fills the bucket -> boom inside
    assert isinstance(r1, int) and isinstance(r2, int)
    assert svc2.pending_count == 2         # re-queued, ids still claimable
    with pytest.raises(OverflowError):
        svc2.step()                        # deferred error surfaces here
    monkeypatch.undo()
    svc2.flush()
    assert svc2.result(r1) is not None and svc2.result(r2) is not None


def test_engine_per_request_payoff_and_strike():
    """Regression (PR 3): flush used to drop per-request payoff/strike on
    the floor (one fixed payoff compiled at __init__).  They are now
    batched as payoff data; None fields take the engine defaults."""
    eng = PricingEngine(None, n_steps=8, batch=4, capacity=16,
                        payoff="call", strike=90.0)
    explicit = _req(s0=100.0, payoff="put", strike=100.0)
    defaulted = _req(s0=100.0)             # -> engine's call K=90
    ids = [eng.submit(explicit), eng.submit(defaulted)]
    out = eng.flush()
    want_put = _oracle(explicit, n_steps=8)
    want_call = price_american(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                               n_steps=8, payoff="call", strike=90.0)
    assert out[ids[0]][0] == pytest.approx(want_put.ask, abs=TOL)
    assert out[ids[1]][0] == pytest.approx(want_call.ask, abs=TOL)
    assert out[ids[0]][0] != pytest.approx(out[ids[1]][0], abs=1e-3)


def test_grid_request_engine_auto_routing(monkeypatch):
    """GridRequest routes engine="auto": all-frictionless grids take the
    no-TC path (price_grid_rz must NOT be called), any positive
    cost_rate the RZ path.  Stubs make the routing observable without
    compiling the RZ engine."""
    from repro.scenarios import GridResult

    calls = []

    def _stub(tag):
        def f(grid, **kw):
            calls.append(tag)
            z = np.zeros(grid.n_scenarios)
            return GridResult(grid=grid, ask=z, bid=z.copy())
        return f

    monkeypatch.setattr("repro.api.price_grid_rz", _stub("rz"))
    monkeypatch.setattr("repro.api.price_grid_notc", _stub("notc"))
    eng = PricingEngine(None, n_steps=8, batch=4, capacity=16)
    eng.price_grid(GridRequest(s0=(95.0, 100.0), cost_rate=0.0, n_steps=8))
    assert calls == ["notc"]
    eng.price_grid(GridRequest(s0=(95.0, 100.0), cost_rate=(0.0, 0.01),
                               n_steps=8))
    assert calls == ["notc", "rz"]
    assert eng.service.metrics()["engine_batches"] == {"notc": 1, "rz": 1,
                                                       "lsmc": 0}

    monkeypatch.undo()
    res = eng.price_grid(GridRequest(s0=(95.0, 100.0), cost_rate=0.0,
                                     n_steps=8))
    ref = price_american(s0=95.0, sigma=0.2, rate=0.1, maturity=0.25,
                         n_steps=8, payoff="put", strike=100.0)
    assert res.max_pieces == 0             # no-TC path: no PWL knots
    np.testing.assert_allclose(res.ask, res.bid, atol=TOL)
    assert res.ask.ravel()[0] == pytest.approx(ref.ask, abs=TOL)


def test_serve_pricing_driver_roundtrip():
    """The launch driver submits a synthetic trace and completes it."""
    from repro.launch.serve_pricing import drive, synth_trace

    svc = PricingService(max_batch=16, deadline_ms=1.0, default_n_steps=8)
    trace = synth_trace(30, n_steps=(8,), tc_fraction=0.0, seed=1)
    quotes = drive(svc, trace, qps=0.0)
    assert len(quotes) == 30 and all(q is not None for q in quotes.values())
    m = svc.metrics()
    assert m["completed"] == 30
    assert m["p99_latency_ms"] >= m["p50_latency_ms"] >= 0.0


def test_pricing_engine_batches_and_pads():
    from repro.core import LatticeModel, american_put
    from repro.core.rz import price_rz

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = PricingEngine(mesh, n_steps=12, batch=4, capacity=24,
                        round_depth=4)
    reqs = [PriceRequest(s0=s, sigma=0.2, rate=0.1, maturity=0.25,
                         cost_rate=0.005) for s in (95.0, 100.0, 105.0)]
    ids = [eng.submit(r) for r in reqs]
    out = eng.flush()
    assert set(out) == set(ids)
    for rid, req in zip(ids, reqs):
        m = LatticeModel(s0=req.s0, sigma=0.2, rate=0.1, maturity=0.25,
                         n_steps=12, cost_rate=0.005)
        ref = price_rz(m, american_put(100.0), capacity=24)
        ask, bid = out[rid]
        assert ask == pytest.approx(ref.ask, abs=1e-9)
        assert bid == pytest.approx(ref.bid, abs=1e-9)


def test_service_metrics_thread_safe_under_concurrent_flushes():
    """Regression (PR 6): gateway flushes complete on replica worker
    threads concurrently, so ServiceMetrics mutation must be locked.
    The unlocked implementation (bare ``self.field += 1``) loses updates
    under a read-modify-write race; with a tiny switch interval this
    test catches it reliably."""
    import sys
    import threading

    from repro.serve.core import ServiceMetrics

    m = ServiceMetrics(latency_window=256)
    n_threads, n_iters = 4, 2000
    start = threading.Barrier(n_threads)

    def hammer(tid):
        start.wait()
        for i in range(n_iters):
            m.bump(requests=1, cache_hits=1)
            m.record_flush(contracts=2, padded=4,
                           engine="rz" if tid % 2 else "notc",
                           seconds=0.001, latencies=[1e-4, 2e-4])
            m.add_latency(3e-4)
            m.snapshot()           # concurrent reads must not torment writers

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)    # force frequent preemption at bytecode
    try:                           # boundaries, where the race lives
        threads = [threading.Thread(target=hammer, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sys.setswitchinterval(old)

    total = n_threads * n_iters
    snap = m.snapshot()
    assert snap["requests"] == total
    assert snap["cache_hits"] == total
    assert snap["batches"] == total
    assert snap["contracts"] == 2 * total
    assert snap["padded"] == 4 * total
    assert snap["completed"] == 2 * total
    assert snap["engine_seconds"] == pytest.approx(0.001 * total)
    assert snap["engine_batches"]["rz"] + snap["engine_batches"]["notc"] \
        == total
    # the latency window stayed bounded despite concurrent appends
    assert len(m.latencies) <= 2 * m.latency_window
