"""Serving engines: LM greedy generation consistency + pricing service."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.transformer import RunCfg, init_lm, lm_loss, prefill
from repro.serve.engine import LMEngine, PriceRequest, PricingEngine

RUN = RunCfg(dtype=jnp.float32)


def test_lm_engine_matches_full_forward():
    """Greedy tokens from the engine == argmax over repeated full prefills
    (the no-cache reference)."""
    cfg = reduced_config(get_config("qwen3-0.6b"))
    key = jax.random.PRNGKey(0)
    params, _ = init_lm(key, cfg)
    B, S0, NNEW = 2, 8, 4
    prompt = np.asarray(jax.random.randint(key, (B, S0), 0, cfg.vocab))

    eng = LMEngine(params, cfg, RUN, batch=B, max_len=S0 + NNEW)
    got = eng.generate(prompt, NNEW)

    # reference: re-prefill from scratch each step
    toks = prompt.copy()
    want = []
    for _ in range(NNEW):
        logits, _ = prefill(params, {"tokens": jnp.asarray(toks)}, cfg, RUN)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        want.append(nxt)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
    want = np.stack(want, axis=1)
    np.testing.assert_array_equal(got, want)


def test_pricing_engine_batches_and_pads():
    from repro.core import LatticeModel, american_put
    from repro.core.rz import price_rz

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = PricingEngine(mesh, n_steps=12, batch=4, capacity=24,
                        round_depth=4)
    reqs = [PriceRequest(s0=s, sigma=0.2, rate=0.1, maturity=0.25,
                         cost_rate=0.005) for s in (95.0, 100.0, 105.0)]
    ids = [eng.submit(r) for r in reqs]
    out = eng.flush()
    assert set(out) == set(ids)
    for rid, req in zip(ids, reqs):
        m = LatticeModel(s0=req.s0, sigma=0.2, rate=0.1, maturity=0.25,
                         n_steps=12, cost_rate=0.005)
        ref = price_rz(m, american_put(100.0), capacity=24)
        ask, bid = out[rid]
        assert ask == pytest.approx(ref.ask, abs=1e-9)
        assert bid == pytest.approx(ref.bid, abs=1e-9)
